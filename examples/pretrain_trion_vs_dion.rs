//! Head-to-head pre-training: Trion vs Dion on the same preset, same seed,
//! same data order — the Table-1/Figure-3 comparison as a runnable example.
//!
//! Prints the paired loss trajectory, the memory gap (one shared DCT matrix
//! + r indices/layer vs a per-layer C×r projector) and the ZeRO broadcast
//! volume gap (§2.3).
//!
//! ```bash
//! cargo run --release --offline --example pretrain_trion_vs_dion [steps]
//! ```

use fft_subspace::optim::OptimizerKind;
use fft_subspace::runtime::{Manifest, Runtime};
use fft_subspace::train::{TrainConfig, Trainer};
use fft_subspace::util::human;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);
    let manifest = Manifest::load("artifacts")?;
    let rt = Runtime::new()?;

    let mut results = Vec::new();
    for kind in [OptimizerKind::Trion, OptimizerKind::Dion] {
        let mut cfg = TrainConfig {
            preset: "micro".into(),
            optimizer: kind.clone(),
            steps,
            workers: 2,
            run_name: format!("example_{}", kind.name()),
            ..Default::default()
        };
        cfg.opt.rank = 32; // r/d = 1/4 on micro
        let mut trainer = Trainer::new(&manifest, &rt, cfg)?;
        let s = trainer.run(&manifest, &rt)?;
        println!(
            "{:<6} train {:.4}  val ppl {:.2}  opt-mem {}  bcast {}  wall {}",
            s.optimizer,
            s.mean_tail_loss,
            s.val_ppl,
            human::bytes(s.optimizer_state_bytes),
            human::bytes(s.update_broadcast_bytes),
            human::duration(s.wall_secs),
        );
        results.push(s);
    }
    let (t, d) = (&results[0], &results[1]);
    println!("\n== Trion vs Dion ==");
    println!(
        "loss:      trion {:.4} vs dion {:.4}  ({})",
        t.mean_tail_loss,
        d.mean_tail_loss,
        if t.mean_tail_loss <= d.mean_tail_loss {
            "trion ≤ dion — matches Table 1"
        } else {
            "dion lower"
        }
    );
    println!(
        "opt state: trion {} vs dion {}  ({:.1}% less)",
        human::bytes(t.optimizer_state_bytes),
        human::bytes(d.optimizer_state_bytes),
        100.0 * (1.0 - t.optimizer_state_bytes as f64 / d.optimizer_state_bytes as f64)
    );
    println!(
        "update broadcast: trion {} vs dion {}  ({:.1}x smaller)",
        human::bytes(t.update_broadcast_bytes),
        human::bytes(d.update_broadcast_bytes),
        d.update_broadcast_bytes as f64 / t.update_broadcast_bytes.max(1) as f64
    );
    Ok(())
}
