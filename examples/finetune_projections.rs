//! Fine-tuning example (Tables 7–8 scenario): pre-train a small base model
//! once, then fine-tune it on the structured arithmetic task with three
//! different projection strategies and compare exact-match accuracy,
//! memory and runtime.
//!
//! ```bash
//! cargo run --release --offline --example finetune_projections
//! ```

use fft_subspace::optim::OptimizerKind;
use fft_subspace::projection::ProjectionKind;
use fft_subspace::runtime::{Manifest, Runtime};
use fft_subspace::train::finetune::Finetuner;
use fft_subspace::train::{TrainConfig, Trainer};
use fft_subspace::util::human;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let rt = Runtime::new()?;

    // 1. pre-train a nano base model (the "Llama-2-7B" of this testbed)
    println!("pre-training base model (nano, AdamW, 150 steps)…");
    let mut pt_cfg = TrainConfig {
        preset: "nano".into(),
        optimizer: OptimizerKind::AdamW,
        steps: 150,
        lr: 3e-3,
        workers: 2,
        run_name: "example_ft_base".into(),
        eval_every: 0,
        ..Default::default()
    };
    pt_cfg.opt.seed = 42;
    let mut pt = Trainer::new(&manifest, &rt, pt_cfg)?;
    let base_summary = pt.run(&manifest, &rt)?;
    println!("base val ppl: {:.2}\n", base_summary.val_ppl);
    let base = pt.params.clone();

    // 2. fine-tune with three optimizers / projections
    let cases: Vec<(OptimizerKind, Option<ProjectionKind>, &str)> = vec![
        (OptimizerKind::Frugal, Some(ProjectionKind::Svd), "FRUGAL + SVD (baseline)"),
        (
            OptimizerKind::Frugal,
            Some(ProjectionKind::Dct {
                norm: fft_subspace::projection::RankNorm::L2,
                use_makhoul: true,
            }),
            "FRUGAL + DCT (this paper)",
        ),
        (OptimizerKind::DctAdamW, None, "DCT-AdamW (this paper)"),
    ];
    println!("fine-tuning on the arithmetic task (rank 16, 250 steps):");
    for (kind, proj, label) in cases {
        let mut cfg = TrainConfig {
            preset: "nano".into(),
            optimizer: kind,
            steps: 250,
            lr: 1e-3,
            run_name: String::new(),
            ..Default::default()
        };
        cfg.opt.rank = 16;
        cfg.opt.update_interval = 50;
        if let Some(p) = proj {
            cfg.opt.projection = p;
        }
        let mut ft = Finetuner::new(&manifest, &rt, cfg, Some(base.clone()))?;
        let s = ft.run(&manifest, &rt)?;
        println!(
            "  {:<26} loss {:.4}  exact-match {:>5.1}%  opt-mem {}  wall {}",
            label,
            s.final_train_loss,
            s.accuracy * 100.0,
            human::bytes(s.optimizer_state_bytes),
            human::duration(s.wall_secs),
        );
    }
    Ok(())
}
