//! Quickstart: the minimal end-to-end use of the public API.
//!
//! Loads the AOT artifacts, trains the `nano` preset for 50 steps with
//! **Trion** through the full stack (PJRT fwd/bwd → simulated 2-worker DDP
//! ring all-reduce → ZeRO-scheduled optimizer), prints the loss curve and
//! the optimizer memory/communication report.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use fft_subspace::optim::OptimizerKind;
use fft_subspace::runtime::{Manifest, Runtime};
use fft_subspace::train::{TrainConfig, Trainer};
use fft_subspace::util::human;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let rt = Runtime::new()?;

    let mut cfg = TrainConfig {
        preset: "nano".into(),
        optimizer: OptimizerKind::Trion,
        steps: 50,
        workers: 2,
        run_name: "quickstart".into(),
        ..Default::default()
    };
    cfg.opt.rank = 16; // r/d = 1/4

    let mut trainer = Trainer::new(&manifest, &rt, cfg)?;
    let summary = trainer.run(&manifest, &rt)?;

    println!("\n== quickstart summary ==");
    println!("optimizer:        {}", summary.optimizer);
    println!("final train loss: {:.4}", summary.final_train_loss);
    println!("val loss / ppl:   {:.4} / {:.2}", summary.val_loss, summary.val_ppl);
    println!(
        "optimizer state:  {} total, {} per ZeRO worker",
        human::bytes(summary.optimizer_state_bytes),
        human::bytes(summary.per_worker_state_bytes)
    );
    println!(
        "communication:    {} moved; low-rank update broadcasts {} \
         (full-parameter equivalent {})",
        human::bytes(summary.comm_bytes),
        human::bytes(summary.update_broadcast_bytes),
        human::bytes(summary.full_broadcast_bytes)
    );
    println!("wall time:        {}", human::duration(summary.wall_secs));
    println!("loss curve:       {}", summary.metrics_path.display());
    Ok(())
}
