//! **End-to-end validation driver** (DESIGN.md §5): trains the largest
//! 1-core-feasible preset through every layer of the system at once —
//!
//! * L2/L1: AOT-lowered JAX fwd/bwd executed via PJRT,
//! * L3: simulated multi-worker DDP with ring all-reduce + byte accounting,
//! * the paper's optimizer (Trion) **running through the AOT per-layer
//!   update graphs where shapes match** (`use_aot_optimizer`), i.e. the
//!   Pallas DCT-similarity / Newton–Schulz kernels on the update path,
//! * ZeRO owner-computes-and-broadcast accounting (§2.3),
//!
//! and logs the loss curve to `runs/e2e/metrics.jsonl`. The recorded run
//! lives in EXPERIMENTS.md §End-to-End.
//!
//! ```bash
//! cargo run --release --offline --example e2e_pretrain [preset] [steps]
//! # defaults: micro 300   (use `small`/`base` with a bigger time budget)
//! ```

use fft_subspace::optim::OptimizerKind;
use fft_subspace::runtime::{Manifest, Runtime};
use fft_subspace::train::{checkpoint, TrainConfig, Trainer};
use fft_subspace::util::human;

fn main() -> anyhow::Result<()> {
    let preset = std::env::args().nth(1).unwrap_or_else(|| "micro".into());
    let steps: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let manifest = Manifest::load("artifacts")?;
    let rt = Runtime::new()?;

    let mut cfg = TrainConfig {
        preset: preset.clone(),
        optimizer: OptimizerKind::Trion,
        steps,
        workers: 4,
        out_dir: "runs".into(),
        run_name: "e2e".into(),
        eval_every: 50,
        corpus_tokens: 1 << 21, // 2M-token corpus
        use_aot_optimizer: true,
        ..Default::default()
    };
    cfg.opt.rank = 32;
    // AOT graphs were lowered with matmul similarities + L2 ranking
    cfg.opt.projection = fft_subspace::projection::ProjectionKind::Dct {
        norm: fft_subspace::projection::RankNorm::L2,
        use_makhoul: false,
    };

    println!(
        "e2e: preset={preset} steps={steps} workers=4 optimizer=trion(aot) rank=32"
    );
    let mut trainer = Trainer::new(&manifest, &rt, cfg)?;
    let initial_loss = (manifest.model_spec(&preset)?.vocab as f64).ln();
    let summary = trainer.run(&manifest, &rt)?;
    checkpoint::save("runs/e2e/final.bin", &trainer.params)?;

    println!("\n== end-to-end summary ==");
    println!("optimizer:       {}", summary.optimizer);
    println!(
        "loss:            {:.3} (uniform) → {:.4} train, {:.4} val (ppl {:.2})",
        initial_loss, summary.mean_tail_loss, summary.val_loss, summary.val_ppl
    );
    println!("wall:            {}", human::duration(summary.wall_secs));
    println!("phases:          {}", summary.phase_summary);
    println!(
        "optimizer state: {} ({} per ZeRO worker)",
        human::bytes(summary.optimizer_state_bytes),
        human::bytes(summary.per_worker_state_bytes)
    );
    println!(
        "comm:            {} total; update broadcasts {} vs full {} \
         ({:.1}x saving)",
        human::bytes(summary.comm_bytes),
        human::bytes(summary.update_broadcast_bytes),
        human::bytes(summary.full_broadcast_bytes),
        summary.full_broadcast_bytes as f64 / summary.update_broadcast_bytes.max(1) as f64
    );
    println!("metrics:         {}", summary.metrics_path.display());
    println!("checkpoint:      runs/e2e/final.bin");

    anyhow::ensure!(
        summary.mean_tail_loss < initial_loss - 0.5,
        "e2e training did not learn (loss {:.3} → {:.3})",
        initial_loss,
        summary.mean_tail_loss
    );
    println!("\ne2e OK: all three layers composed and the model learned.");
    Ok(())
}
