//! Low-rank projection substrate (§2.1 + every baseline the paper sweeps).
//!
//! A [`Projection`] owns one layer's subspace state and maps gradients
//! between the full space `R^{R×C}` and the low-rank space `R^{R×r}`
//! (right-projection; callers transpose for left-projection, exactly as the
//! paper prescribes projecting the *smaller* dimension).
//!
//! Implementations:
//!
//! | name        | subspace source                        | per-layer state |
//! |-------------|----------------------------------------|-----------------|
//! | `DctSelect` | dynamic column selection over a shared  DCT matrix (Makhoul fast path) | `r` int32 indices |
//! | `SvdProj`   | top-r right singular vectors (Jacobi)  | `C×r` floats |
//! | `BlockPower`| LDAdam block power iteration            | `C×r` floats |
//! | `RandomSemiOrtho` | QR of a fresh Gaussian             | `C×r` floats |
//! | `RandPerm`  | random coordinate subset                | `r` int32 indices |
//!
//! The shared DCT matrix is counted once per device ([`SharedDct`]), which
//! is the paper's memory argument: every other method stores a projector
//! per layer.

mod dct_select;
mod baselines;

use std::sync::Arc;

use anyhow::Result;

use crate::tensor::{Matrix, Workspace};
use crate::util::codec::ByteReader;

pub use baselines::{BlockPower, RandPerm, RandomSemiOrtho, SvdProj};
pub use dct_select::{
    select_top_columns, select_top_columns_into, DctSelect, SharedDct,
};

/// Ranking norm for dynamic column selection (§2.1: ℓ1 or ℓ2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankNorm {
    L1,
    L2,
}

/// Which projection family an optimizer should instantiate.
#[derive(Clone, Debug, PartialEq)]
pub enum ProjectionKind {
    /// DCT dynamic column selection; `use_makhoul` switches the similarity
    /// computation between the FFT fast path and plain matmul.
    Dct { norm: RankNorm, use_makhoul: bool },
    Svd,
    BlockPower { iters: usize },
    Random,
    RandPerm,
}

impl ProjectionKind {
    pub fn name(&self) -> &'static str {
        match self {
            ProjectionKind::Dct { .. } => "dct",
            ProjectionKind::Svd => "svd",
            ProjectionKind::BlockPower { .. } => "block_power",
            ProjectionKind::Random => "random",
            ProjectionKind::RandPerm => "randperm",
        }
    }

    /// Construct one layer's projection state for a `R×C` layer at rank `r`.
    /// `shared_dct` must cover dimension `C` for the DCT kind.
    pub fn build(
        &self,
        cols: usize,
        rank: usize,
        shared_dct: Option<Arc<SharedDct>>,
        seed: u64,
    ) -> Box<dyn Projection> {
        let rank = rank.min(cols);
        match self {
            ProjectionKind::Dct { norm, use_makhoul } => {
                let shared = shared_dct.expect("DCT projection needs a SharedDct");
                assert_eq!(shared.dim(), cols, "shared DCT dim mismatch");
                Box::new(DctSelect::new(shared, rank, *norm, *use_makhoul))
            }
            ProjectionKind::Svd => Box::new(SvdProj::new(cols, rank)),
            ProjectionKind::BlockPower { iters } => {
                Box::new(BlockPower::new(cols, rank, *iters))
            }
            ProjectionKind::Random => Box::new(RandomSemiOrtho::new(cols, rank, seed)),
            ProjectionKind::RandPerm => Box::new(RandPerm::new(cols, rank, seed)),
        }
    }
}

/// One layer's projection state.
pub trait Projection: Send {
    /// Recompute the subspace from the current gradient/accumulator and
    /// return the projected matrix `G·Q_r (R×r)` (reusing the similarity
    /// computation where the method allows — the DCT path selects columns
    /// of `S` directly instead of re-multiplying).
    fn refresh_and_project(&mut self, g: &Matrix) -> Matrix;

    /// Project with the *current* subspace (no refresh).
    fn project(&self, g: &Matrix) -> Matrix;

    /// Back-project `low (R×r)` to the full space: `low · Q_rᵀ (R×C)`.
    fn back(&self, low: &Matrix) -> Matrix;

    /// Materialize the current basis `Q_r (C×r)`.
    fn basis(&self) -> Matrix;

    /// Subspace rotation `R = Q_prevᵀ·Q_crt (r×r)` between the previous
    /// basis and the current one (LDAdam / DCT-AdamW momentum rotation).
    fn rotation_from(&self, prev_basis: &Matrix) -> Matrix {
        crate::tensor::matmul_at_b(prev_basis, &self.basis())
    }

    // -- workspace-backed variants (the optimizer hot path) ---------------
    //
    // Defaults delegate to the allocating methods so every implementation
    // stays correct; the hot implementations (DctSelect, the dense-basis
    // baselines) override them with true `_into` kernels. The contract is
    // bit-identical output in `out` (resized in place) with no allocation
    // beyond workspace warmup.

    /// Allocation-free [`Projection::refresh_and_project`].
    fn refresh_and_project_into(&mut self, g: &Matrix, out: &mut Matrix, _ws: &mut Workspace) {
        let low = self.refresh_and_project(g);
        out.copy_from(&low);
    }

    /// Allocation-free [`Projection::project`].
    fn project_into(&self, g: &Matrix, out: &mut Matrix, _ws: &mut Workspace) {
        let low = self.project(g);
        out.copy_from(&low);
    }

    /// Allocation-free [`Projection::back`].
    fn back_into(&self, low: &Matrix, out: &mut Matrix, _ws: &mut Workspace) {
        let full = self.back(low);
        out.copy_from(&full);
    }

    /// Allocation-free [`Projection::basis`].
    fn basis_into(&self, out: &mut Matrix) {
        let b = self.basis();
        out.copy_from(&b);
    }

    /// Allocation-free [`Projection::rotation_from`].
    fn rotation_into(&self, prev_basis: &Matrix, out: &mut Matrix, _ws: &mut Workspace) {
        let rot = self.rotation_from(prev_basis);
        out.copy_from(&rot);
    }

    /// For index-selection bases (DCT column selection, RandPerm): the
    /// currently selected column indices, ascending. `None` for dense
    /// bases. Drives the typed fixed-basis rotation dispatch (the engine's
    /// index-matching moment rotation only exists when this is `Some`).
    fn indices(&self) -> Option<&[usize]> {
        None
    }

    // -- fused-step-plan hooks (engine/plan.rs) ---------------------------
    //
    // A fused plan batches a whole shape group's expensive pass into one
    // pool dispatch and hands each layer its precomputed block. These hooks
    // declare what a family supports; families that support neither fall
    // back to the grouped per-layer path, which is bit-identical by
    // construction.

    /// `Some(use_makhoul)` when the refresh's similarity pass is a
    /// row-independent transform against a shared basis (`S = G·Q`) that a
    /// plan may compute batched across layers, finishing the refresh with
    /// [`Projection::refresh_from_sims`]. `None` for every other family
    /// (their refreshes aren't separable into a stacked row transform).
    fn batched_sims(&self) -> Option<bool> {
        None
    }

    /// Finish a refresh whose similarity block `s = g·Q (R×C)` was computed
    /// by a batched pass: run the selection tail and write `g·Q_r` into
    /// `out`. Must be bit-identical to [`Projection::refresh_and_project_into`]
    /// when `s` holds exactly what the inline pass would have computed.
    /// Only callable when [`Projection::batched_sims`] is `Some`.
    fn refresh_from_sims(&mut self, _g: &Matrix, _s: &Matrix, _out: &mut Matrix, _ws: &mut Workspace) {
        unreachable!("{}: refresh_from_sims without batched_sims support", self.name());
    }

    /// Borrow the materialized dense basis `Q_r (C×r)` when one is held in
    /// memory (so a plan can batch `project` as a stacked matmul against
    /// it). `None` for gather-based projections (RandPerm), whose project
    /// is not a matmul.
    fn basis_ref(&self) -> Option<&Matrix> {
        None
    }

    /// Subspace-quality gauges from the most recent refresh (captured-energy
    /// ratio, projection residual norm, basis overlap with the previous
    /// selection) — the observability feed for the adaptive-rank open item.
    /// `None` until a refresh has run, or for families that don't track
    /// them. Only `DctSelect` reports today: its column-norm ranking already
    /// computes the per-column energies the gauges need, so reporting costs
    /// two reductions over data that exists anyway.
    fn quality(&self) -> Option<crate::obs::SubspaceQuality> {
        None
    }

    /// Serialize the persistent subspace state for checkpoint v2: selected
    /// indices, dense bases, warm-start flags and RNG streams — everything
    /// a later step reads, so a restored projection continues bit-
    /// identically. Implementations with cross-step state must override
    /// both hooks (the defaults write/read nothing, correct only for
    /// genuinely stateless projections).
    fn save_state(&self, _out: &mut Vec<u8>) {}

    /// Twin of [`Projection::save_state`]; the receiver was built from the
    /// same spec, so shapes/config are already in place.
    fn load_state(&mut self, _r: &mut ByteReader) -> Result<()> {
        Ok(())
    }

    /// Persistent per-layer state bytes (what lives in optimizer memory
    /// between steps — *not* transient compute buffers).
    fn state_bytes(&self) -> u64;

    /// Per-device shared state bytes (counted once across all layers).
    fn shared_bytes(&self) -> u64 {
        0
    }

    fn rank(&self) -> usize;

    fn name(&self) -> &'static str;
}

/// Reconstruction error `‖G − (G·Q_r)·Q_rᵀ‖F` — Figure 1's metric.
pub fn reconstruction_error(g: &Matrix, proj: &dyn Projection) -> f64 {
    let low = proj.project(g);
    let back = proj.back(&low);
    g.sub(&back).fro_norm()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg64};

    fn all_kinds() -> Vec<ProjectionKind> {
        vec![
            ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true },
            ProjectionKind::Dct { norm: RankNorm::L1, use_makhoul: false },
            ProjectionKind::Svd,
            ProjectionKind::BlockPower { iters: 3 },
            ProjectionKind::Random,
            ProjectionKind::RandPerm,
        ]
    }

    #[test]
    fn prop_back_projection_is_contraction() {
        // §4.1: for orthonormal bases, ‖G − QrQrᵀG‖ ≤ ‖G‖ (all kinds).
        proptest::check("projection-contraction", 6, |rng| {
            let rows = proptest::size(rng, 2, 24);
            let cols = proptest::size(rng, 4, 32);
            // SVD-family bases only exist up to min(rows, cols) vectors.
            let r = proptest::size(rng, 1, cols.min(rows).min(8));
            let g = Matrix::randn(rows, cols, 1.0, rng);
            let shared = Arc::new(SharedDct::new(cols));
            for kind in all_kinds() {
                let mut p = kind.build(cols, r, Some(shared.clone()), 7);
                let low = p.refresh_and_project(&g);
                assert_eq!(low.shape(), (rows, r));
                let err = reconstruction_error(&g, p.as_ref());
                assert!(
                    err <= g.fro_norm() * (1.0 + 1e-4),
                    "{}: err={} > norm={}",
                    kind.name(),
                    err,
                    g.fro_norm()
                );
            }
        });
    }

    #[test]
    fn prop_projection_roundtrip_idempotent() {
        // back(project(back(project(g)))) == back(project(g)) for
        // orthonormal bases: projecting twice adds nothing.
        proptest::check("projection-idempotent", 6, |rng| {
            let g = Matrix::randn(12, 16, 1.0, rng);
            let shared = Arc::new(SharedDct::new(16));
            for kind in all_kinds() {
                let mut p = kind.build(16, 5, Some(shared.clone()), 3);
                let low = p.refresh_and_project(&g);
                let once = p.back(&low);
                let twice = p.back(&p.project(&once));
                assert!(
                    once.max_abs_diff(&twice) < 1e-4,
                    "{} not idempotent",
                    kind.name()
                );
            }
        });
    }

    #[test]
    fn prop_into_variants_bit_identical_for_all_kinds() {
        // Every projection family, every `_into` override: outputs must be
        // bit-identical to the allocating path even into dirty buffers.
        proptest::check("projection-into==allocating", 6, |rng| {
            let rows = proptest::size(rng, 2, 20);
            let cols = proptest::size(rng, 4, 28);
            let r = proptest::size(rng, 1, cols.min(rows).min(6));
            let g = Matrix::randn(rows, cols, 1.0, rng);
            let shared = Arc::new(SharedDct::new(cols));
            let mut ws = crate::tensor::Workspace::new();
            let mut out = Matrix::randn(3, 3, 1.0, rng); // dirty
            for kind in all_kinds() {
                // two independently-built instances must agree step by step
                let mut p_alloc = kind.build(cols, r, Some(shared.clone()), 7);
                let mut p_into = kind.build(cols, r, Some(shared.clone()), 7);
                let low = p_alloc.refresh_and_project(&g);
                p_into.refresh_and_project_into(&g, &mut out, &mut ws);
                assert_eq!(out, low, "{}: refresh_and_project", kind.name());

                p_into.project_into(&g, &mut out, &mut ws);
                assert_eq!(out, p_alloc.project(&g), "{}: project", kind.name());

                p_into.back_into(&low, &mut out, &mut ws);
                assert_eq!(out, p_alloc.back(&low), "{}: back", kind.name());

                p_into.basis_into(&mut out);
                assert_eq!(out, p_alloc.basis(), "{}: basis", kind.name());

                let prev = p_alloc.basis();
                p_into.rotation_into(&prev, &mut out, &mut ws);
                assert_eq!(out, p_alloc.rotation_from(&prev), "{}: rotation", kind.name());

                // a second refresh pins the warm-start branches too
                // (BlockPower seeds from its previous basis; the seeded
                // kinds advance their RNGs in lockstep)
                let low2 = p_alloc.refresh_and_project(&g);
                p_into.refresh_and_project_into(&g, &mut out, &mut ws);
                assert_eq!(out, low2, "{}: warm refresh_and_project", kind.name());
                p_into.basis_into(&mut out);
                assert_eq!(out, p_alloc.basis(), "{}: warm basis", kind.name());
            }
        });
    }

    #[test]
    fn bases_are_orthonormal() {
        let mut rng = Pcg64::seed(0);
        let g = Matrix::randn(20, 24, 1.0, &mut rng);
        let shared = Arc::new(SharedDct::new(24));
        for kind in all_kinds() {
            let mut p = kind.build(24, 6, Some(shared.clone()), 11);
            p.refresh_and_project(&g);
            let b = p.basis();
            let gram = crate::tensor::matmul_at_b(&b, &b);
            assert!(
                gram.max_abs_diff(&Matrix::eye(6)) < 1e-3,
                "{} basis not orthonormal",
                kind.name()
            );
        }
    }

    #[test]
    fn dct_memory_is_rank_indices_only() {
        let shared = Arc::new(SharedDct::new(64));
        let kind = ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true };
        let p = kind.build(64, 16, Some(shared), 0);
        assert_eq!(p.state_bytes(), 16 * 4); // r int32 indices
        assert_eq!(p.shared_bytes(), 64 * 64 * 4); // one DCT matrix
        // while SVD stores the full projector per layer:
        let svd = ProjectionKind::Svd.build(64, 16, None, 0);
        assert_eq!(svd.state_bytes(), 64 * 16 * 4);
    }
}
