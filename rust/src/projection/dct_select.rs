//! DCT dynamic column selection (§2.1 + Appendix B) — the paper's method.
//!
//! One [`SharedDct`] per device holds the `C×C` DCT-II matrix and a Makhoul
//! FFT plan, built once at training start. Each layer keeps only the `r`
//! selected column indices; the effective projector `Q_r = Q[:, idx]` is
//! re-gathered on demand.

use std::sync::Arc;

use crate::fft::{dct2_matrix, MakhoulPlan};
use crate::tensor::{matmul, matmul_a_bt, Matrix};

use super::{Projection, RankNorm};

/// Per-device shared DCT state: the orthogonal matrix + the FFT plan.
pub struct SharedDct {
    q: Matrix,          // DCT-II, C×C
    plan: MakhoulPlan,  // fast similarity path
}

impl SharedDct {
    pub fn new(dim: usize) -> Self {
        SharedDct { q: dct2_matrix(dim), plan: MakhoulPlan::new(dim) }
    }

    pub fn dim(&self) -> usize {
        self.q.rows
    }

    pub fn matrix(&self) -> &Matrix {
        &self.q
    }

    /// Similarities `S = G·Q` — Makhoul FFT path or plain matmul.
    pub fn similarities(&self, g: &Matrix, use_makhoul: bool) -> Matrix {
        if use_makhoul {
            self.plan.run(g)
        } else {
            matmul(g, &self.q)
        }
    }

    pub fn bytes(&self) -> u64 {
        self.q.bytes()
    }
}

/// Rank the columns of `s` by norm and return the indices of the largest
/// `r`, in ascending index order (deterministic tie-break by index — keeps
/// the rust-native path bit-identical with the AOT graphs).
pub fn select_top_columns(s: &Matrix, r: usize, norm: RankNorm) -> Vec<usize> {
    let scores = match norm {
        RankNorm::L1 => s.col_l1_norms(),
        RankNorm::L2 => s.col_l2_norms(),
    };
    let mut order: Vec<usize> = (0..s.cols).collect();
    order.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut idx = order[..r.min(order.len())].to_vec();
    idx.sort_unstable();
    idx
}

/// One layer's DCT-selection state: `r` column indices into the shared Q.
pub struct DctSelect {
    shared: Arc<SharedDct>,
    rank: usize,
    norm: RankNorm,
    use_makhoul: bool,
    idx: Vec<usize>,
    basis_cache: Matrix, // Q[:, idx] (C×r) — transient, rebuilt on refresh
}

impl DctSelect {
    pub fn new(shared: Arc<SharedDct>, rank: usize, norm: RankNorm,
               use_makhoul: bool) -> Self {
        let rank = rank.min(shared.dim());
        let idx: Vec<usize> = (0..rank).collect();
        let basis_cache = shared.matrix().select_columns(&idx);
        DctSelect { shared, rank, norm, use_makhoul, idx, basis_cache }
    }

    pub fn indices(&self) -> &[usize] {
        &self.idx
    }

    /// Refresh returning both the similarity matrix and the projection —
    /// Trion consumes `S` for the momentum error feedback as well.
    pub fn refresh_full(&mut self, g: &Matrix) -> (Matrix, Matrix) {
        let s = self.shared.similarities(g, self.use_makhoul);
        self.idx = select_top_columns(&s, self.rank, self.norm);
        self.basis_cache = self.shared.matrix().select_columns(&self.idx);
        let low = s.select_columns(&self.idx);
        (s, low)
    }
}

impl Projection for DctSelect {
    fn refresh_and_project(&mut self, g: &Matrix) -> Matrix {
        let (_, low) = self.refresh_full(g);
        low
    }

    fn project(&self, g: &Matrix) -> Matrix {
        // G·Q_r without forming full S: gather then multiply.
        matmul(g, &self.basis_cache)
    }

    fn back(&self, low: &Matrix) -> Matrix {
        matmul_a_bt(low, &self.basis_cache)
    }

    fn basis(&self) -> Matrix {
        self.basis_cache.clone()
    }

    fn state_bytes(&self) -> u64 {
        (self.idx.len() * 4) as u64 // r int32 indices — the paper's claim
    }

    fn shared_bytes(&self) -> u64 {
        self.shared.bytes()
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn name(&self) -> &'static str {
        "dct"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg64};

    #[test]
    fn selection_maximizes_column_norms() {
        let mut rng = Pcg64::seed(0);
        let s = Matrix::randn(10, 12, 1.0, &mut rng);
        let idx = select_top_columns(&s, 4, RankNorm::L2);
        let norms = s.col_l2_norms();
        let min_selected = idx.iter().map(|&i| norms[i]).fold(f32::MAX, f32::min);
        let max_rejected = (0..12)
            .filter(|i| !idx.contains(i))
            .map(|i| norms[i])
            .fold(0.0f32, f32::max);
        assert!(min_selected >= max_rejected);
    }

    #[test]
    fn selection_indices_sorted_unique() {
        let mut rng = Pcg64::seed(1);
        let s = Matrix::randn(6, 20, 1.0, &mut rng);
        for norm in [RankNorm::L1, RankNorm::L2] {
            let idx = select_top_columns(&s, 7, norm);
            assert_eq!(idx.len(), 7);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted, idx);
        }
    }

    #[test]
    fn prop_contractive_bound_holds() {
        // §4.1: ‖G − G·Q_r·Q_rᵀ‖²F ≤ (1 − r/n)·‖G‖²F
        proptest::check("dct-contractive", 10, |rng| {
            let rows = proptest::size(rng, 2, 20);
            let cols = proptest::size(rng, 4, 40);
            let r = proptest::size(rng, 1, cols - 1);
            let g = Matrix::randn(rows, cols, 1.0, rng);
            let shared = Arc::new(SharedDct::new(cols));
            let mut p = DctSelect::new(shared, r, RankNorm::L2, false);
            let low = p.refresh_and_project(&g);
            let err_sq = g.sub(&p.back(&low)).fro_norm_sq();
            let bound = (1.0 - r as f64 / cols as f64) * g.fro_norm_sq();
            assert!(err_sq <= bound * (1.0 + 1e-4) + 1e-6,
                    "err²={err_sq} bound={bound}");
        });
    }

    #[test]
    fn selection_beats_fixed_prefix() {
        // Dynamic selection must be at least as good as always taking the
        // first r DCT columns (the static strategy).
        let mut rng = Pcg64::seed(3);
        let g = Matrix::randn(16, 32, 1.0, &mut rng);
        let shared = Arc::new(SharedDct::new(32));
        let r = 8;
        let mut dynamic = DctSelect::new(shared.clone(), r, RankNorm::L2, false);
        let low = dynamic.refresh_and_project(&g);
        let err_dyn = g.sub(&dynamic.back(&low)).fro_norm_sq();

        let prefix: Vec<usize> = (0..r).collect();
        let q_r = shared.matrix().select_columns(&prefix);
        let low_fix = matmul(&g, &q_r);
        let err_fix = g.sub(&matmul_a_bt(&low_fix, &q_r)).fro_norm_sq();
        assert!(err_dyn <= err_fix + 1e-5);
    }

    #[test]
    fn makhoul_and_matmul_paths_agree() {
        let mut rng = Pcg64::seed(4);
        let g = Matrix::randn(14, 24, 1.0, &mut rng);
        let shared = Arc::new(SharedDct::new(24));
        let mut a = DctSelect::new(shared.clone(), 6, RankNorm::L2, true);
        let mut b = DctSelect::new(shared, 6, RankNorm::L2, false);
        let la = a.refresh_and_project(&g);
        let lb = b.refresh_and_project(&g);
        assert_eq!(a.indices(), b.indices());
        assert!(la.max_abs_diff(&lb) < 1e-4);
    }

    #[test]
    fn full_rank_selection_is_lossless() {
        let mut rng = Pcg64::seed(5);
        let g = Matrix::randn(9, 16, 1.0, &mut rng);
        let shared = Arc::new(SharedDct::new(16));
        let mut p = DctSelect::new(shared, 16, RankNorm::L2, false);
        let low = p.refresh_and_project(&g);
        assert!(g.sub(&p.back(&low)).fro_norm() < 1e-4);
    }
}
