//! DCT dynamic column selection (§2.1 + Appendix B) — the paper's method.
//!
//! One [`SharedDct`] per device holds the `C×C` DCT-II matrix and a Makhoul
//! FFT plan (both process-cached per order, built once). Each layer keeps
//! only the `r` selected column indices; the effective projector
//! `Q_r = Q[:, idx]` is re-gathered on demand.
//!
//! The hot path is fully workspace-backed: `refresh_and_project_into` runs
//! Makhoul into a pooled buffer, ranks columns with an O(C) partition
//! (`select_nth_unstable_by`, not a full sort) and gathers the selection in
//! place — zero heap allocations at steady state. The similarity row batch
//! and the column-norm ranking are SIMD-vectorized underneath (the Makhoul
//! plan kernels, the blocked matmul and `Matrix::col_{sq,abs}_sums_into`
//! all route through `crate::simd`), bit-identical to the scalar path for
//! every backend.

use std::sync::Arc;

use anyhow::Result;

use crate::fft::{cached_dct2_matrix, cached_plan, MakhoulPlan};
use crate::parallel::{SendPtr, ThreadPool};
use crate::tensor::{
    all_finite, matmul, matmul_a_bt, matmul_a_bt_into, matmul_into,
    matmul_into_on, matmul_rows_batched_on, Matrix, Workspace,
};
use crate::util::codec::{self, ByteReader};

use super::{Projection, RankNorm};

/// Per-device shared DCT state: the orthogonal matrix + the FFT plan.
/// Both members come from the per-order process caches, so constructing a
/// `SharedDct` after the first is index lookups, not trig.
pub struct SharedDct {
    q: Arc<Matrix>,         // DCT-II, C×C (shared per order)
    plan: Arc<MakhoulPlan>, // fast similarity path (shared per order)
}

impl SharedDct {
    pub fn new(dim: usize) -> Self {
        SharedDct { q: cached_dct2_matrix(dim), plan: cached_plan(dim) }
    }

    pub fn dim(&self) -> usize {
        self.q.rows
    }

    pub fn matrix(&self) -> &Matrix {
        self.q.as_ref()
    }

    /// Similarities `S = G·Q` — Makhoul FFT path or plain matmul.
    pub fn similarities(&self, g: &Matrix, use_makhoul: bool) -> Matrix {
        if use_makhoul {
            self.plan.run(g)
        } else {
            matmul(g, self.q.as_ref())
        }
    }

    /// Allocation-free [`SharedDct::similarities`].
    pub fn similarities_into(&self, g: &Matrix, use_makhoul: bool, out: &mut Matrix) {
        if use_makhoul {
            self.plan.run_into(g, out);
        } else {
            matmul_into(g, self.q.as_ref(), out);
        }
    }

    /// Row-parallel [`SharedDct::similarities_into`] — per-row-batched
    /// Makhoul execution (or row-blocked matmul); bit-identical to the
    /// sequential path for any thread count.
    pub fn similarities_into_on(
        &self,
        pool: &ThreadPool,
        g: &Matrix,
        use_makhoul: bool,
        out: &mut Matrix,
    ) {
        if use_makhoul {
            self.plan.run_into_on(pool, g, out);
        } else {
            matmul_into_on(pool, g, self.q.as_ref(), out);
        }
    }

    /// Group-batched [`SharedDct::similarities_into_on`]: `dsts.len()` jobs
    /// of `rows_per_job` rows each, stacked into **one** pool dispatch
    /// partitioned over the concatenated rows (the fused step plans' refresh
    /// pass). Job `l` reads `src(l)` (`rows_per_job×C`) and writes its
    /// similarity block through `dsts[l]`. Both underlying kernels are
    /// per-row transforms, so stacking never regroups any element's FP
    /// summation — bit-identical to `dsts.len()` per-layer calls.
    pub fn similarities_rows_batched_on<'a>(
        &self,
        pool: &ThreadPool,
        rows_per_job: usize,
        use_makhoul: bool,
        src: &(impl Fn(usize) -> &'a Matrix + Sync),
        dsts: &[SendPtr<f32>],
    ) {
        if use_makhoul {
            self.plan.run_rows_batched_on(pool, rows_per_job, src, dsts);
        } else {
            let q = self.q.as_ref();
            matmul_rows_batched_on(pool, rows_per_job, src, &|_| q, dsts);
        }
    }

    pub fn bytes(&self) -> u64 {
        self.q.bytes()
    }
}

/// Rank the columns of `s` by norm and return the indices of the largest
/// `r`, in ascending index order (deterministic tie-break by index — keeps
/// the rust-native path bit-identical with the AOT graphs).
pub fn select_top_columns(s: &Matrix, r: usize, norm: RankNorm) -> Vec<usize> {
    let mut ws = Workspace::new();
    let mut idx = Vec::new();
    select_top_columns_into(s, r, norm, &mut ws, &mut idx);
    idx
}

/// Allocation-free [`select_top_columns`]: O(C) average instead of
/// O(C log C) — `select_nth_unstable_by` partitions the top `r` under the
/// exact comparator the old full sort used (score descending, index
/// ascending on ties), then only the `r` winners are index-sorted.
///
/// Returns `(captured, total)`: the score mass of the selected columns and
/// of all columns, from the same f64 accumulator the ranking uses. Under
/// `RankNorm::L2` these are Frobenius energies (`‖S[:,idx]‖²F`, `‖S‖²F`),
/// which is exactly what the obs subspace-quality gauges need; callers that
/// only want the indices ignore the return value.
pub fn select_top_columns_into(
    s: &Matrix,
    r: usize,
    norm: RankNorm,
    ws: &mut Workspace,
    idx: &mut Vec<usize>,
) -> (f64, f64) {
    let c = s.cols;
    // Column norms through the same shared accumulation kernel
    // `col_l1_norms`/`col_l2_norms` use (`Matrix::col_{sq,abs}_sums_into`),
    // so ranking (ties included) is bit-equivalent to the sorting
    // implementation by construction.
    let mut acc = ws.take_f64(c);
    match norm {
        RankNorm::L2 => s.col_sq_sums_into(&mut acc),
        RankNorm::L1 => s.col_abs_sums_into(&mut acc),
    }
    let mut scores = ws.take_f32(c);
    match norm {
        RankNorm::L2 => {
            for (sc, &a) in scores.iter_mut().zip(acc.iter()) {
                *sc = a.sqrt() as f32;
            }
        }
        RankNorm::L1 => {
            for (sc, &a) in scores.iter_mut().zip(acc.iter()) {
                *sc = a as f32;
            }
        }
    }

    let k = r.min(c);
    let mut order = ws.take_usize(c);
    for (i, o) in order.iter_mut().enumerate() {
        *o = i;
    }
    if k > 0 && k < c {
        order.select_nth_unstable_by(k - 1, |&a, &b| {
            scores[b]
                .partial_cmp(&scores[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
    }
    idx.clear();
    idx.extend_from_slice(&order[..k]);
    idx.sort_unstable();

    // Gauge bookkeeping: two reductions over the accumulator we already
    // computed for the ranking — no extra passes over `s`.
    let total: f64 = acc.iter().sum();
    let captured: f64 = idx.iter().map(|&j| acc[j]).sum();

    ws.give_usize(order);
    ws.give_f32(scores);
    ws.give_f64(acc);
    (captured, total)
}

/// `|a ∩ b|` for two ascending index slices — one merge pass, no
/// allocation. Feeds the basis-overlap gauge between consecutive refreshes.
fn sorted_overlap(a: &[usize], b: &[usize]) -> usize {
    let (mut i, mut j, mut n) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

/// One layer's DCT-selection state: `r` column indices into the shared Q.
pub struct DctSelect {
    shared: Arc<SharedDct>,
    rank: usize,
    norm: RankNorm,
    use_makhoul: bool,
    idx: Vec<usize>,
    basis_cache: Matrix, // Q[:, idx] (C×r) — transient, rebuilt on refresh
    /// Previous refresh's selection — overlap gauge input. Preallocated to
    /// `rank` so steady-state refreshes never grow it.
    prev_idx: Vec<usize>,
    /// Gauges from the last workspace-path refresh; `None` until one runs
    /// (the constructor prefix is not a fitted subspace).
    quality: Option<crate::obs::SubspaceQuality>,
}

impl DctSelect {
    pub fn new(shared: Arc<SharedDct>, rank: usize, norm: RankNorm,
               use_makhoul: bool) -> Self {
        let rank = rank.min(shared.dim());
        let idx: Vec<usize> = (0..rank).collect();
        let basis_cache = shared.matrix().select_columns(&idx);
        DctSelect {
            shared,
            rank,
            norm,
            use_makhoul,
            idx,
            basis_cache,
            prev_idx: Vec::with_capacity(rank),
            quality: None,
        }
    }

    pub fn indices(&self) -> &[usize] {
        &self.idx
    }

    /// Refresh returning both the similarity matrix and the projection —
    /// Trion consumes `S` for the momentum error feedback as well.
    pub fn refresh_full(&mut self, g: &Matrix) -> (Matrix, Matrix) {
        let s = self.shared.similarities(g, self.use_makhoul);
        // Non-finite input: NaN column norms would rank arbitrarily
        // (partial_cmp ties), silently replacing a good selection with a
        // garbage one. Keep the previous indices/basis; callers still get
        // S (itself non-finite) and the gathered columns.
        if !all_finite(&g.data) {
            let low = s.select_columns(&self.idx);
            return (s, low);
        }
        self.idx = select_top_columns(&s, self.rank, self.norm);
        self.shared.matrix().select_columns_into(&self.idx, &mut self.basis_cache);
        let low = s.select_columns(&self.idx);
        (s, low)
    }

    /// Selection tail shared by the inline and fused refresh paths: rank
    /// the columns of `s = g·Q`, roll the gauges, rebuild the basis cache
    /// and gather `g·Q_r` into `out`. Every op from here on is identical
    /// whether `s` was computed inline or by a group-batched pass — the
    /// fused-plan bit-identity hinges on exactly that.
    fn refresh_tail(&mut self, s: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        let had_refresh = self.quality.is_some();
        self.prev_idx.clear();
        self.prev_idx.extend_from_slice(&self.idx);
        let (captured, total) =
            select_top_columns_into(s, self.rank, self.norm, ws, &mut self.idx);
        // Gauges (§4.1): under L2 ranking, total = ‖S‖²F = ‖G‖²F (Q is
        // orthonormal) and captured = ‖S[:,idx]‖²F = ‖G·Q_r‖²F, so the
        // residual √(total−captured) is exactly ‖G − G·Q_r·Q_rᵀ‖F by
        // Pythagoras. Under L1 the ratio is captured score mass instead.
        // Overlap against the constructor prefix would be meaningless, so
        // the first fitted refresh reports 0.
        let overlap = if had_refresh && !self.idx.is_empty() {
            sorted_overlap(&self.prev_idx, &self.idx) as f32 / self.idx.len() as f32
        } else {
            0.0
        };
        self.quality = Some(crate::obs::SubspaceQuality {
            energy_ratio: if total > 0.0 { (captured / total) as f32 } else { 1.0 },
            resid_norm: (total - captured).max(0.0).sqrt() as f32,
            overlap,
        });
        self.shared.matrix().select_columns_into(&self.idx, &mut self.basis_cache);
        s.select_columns_into(&self.idx, out);
    }
}

impl Projection for DctSelect {
    fn refresh_and_project(&mut self, g: &Matrix) -> Matrix {
        let (_, low) = self.refresh_full(g);
        low
    }

    fn project(&self, g: &Matrix) -> Matrix {
        // G·Q_r without forming full S: gather then multiply.
        matmul(g, &self.basis_cache)
    }

    fn back(&self, low: &Matrix) -> Matrix {
        matmul_a_bt(low, &self.basis_cache)
    }

    fn basis(&self) -> Matrix {
        self.basis_cache.clone()
    }

    // -- workspace-backed hot path ---------------------------------------

    fn refresh_and_project_into(&mut self, g: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        // Non-finite gradient: keep the previous selection/basis instead of
        // re-ranking columns on NaN norms (ROADMAP §Fault tolerance). The
        // quality gauges also keep their last-good values — a NaN energy
        // ratio would be noise, not signal.
        if !all_finite(&g.data) {
            matmul_into(g, &self.basis_cache, out);
            return;
        }
        // fully overwritten by similarities_into → non-zeroing checkout
        let mut s = ws.take_uninit(g.rows, self.shared.dim());
        self.shared.similarities_into(g, self.use_makhoul, &mut s);
        self.refresh_tail(&s, out, ws);
        ws.give(s);
    }

    fn batched_sims(&self) -> Option<bool> {
        Some(self.use_makhoul)
    }

    fn refresh_from_sims(&mut self, g: &Matrix, s: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        // Same non-finite guard as the inline path — the batched similarity
        // pass ran unconditionally, but ranking on NaN norms must not
        // replace a good selection (the similarities themselves are cheap
        // and discarded in that case).
        if !all_finite(&g.data) {
            matmul_into(g, &self.basis_cache, out);
            return;
        }
        debug_assert_eq!((s.rows, s.cols), (g.rows, self.shared.dim()));
        self.refresh_tail(s, out, ws);
    }

    fn basis_ref(&self) -> Option<&Matrix> {
        Some(&self.basis_cache)
    }

    fn project_into(&self, g: &Matrix, out: &mut Matrix, _ws: &mut Workspace) {
        matmul_into(g, &self.basis_cache, out);
    }

    fn back_into(&self, low: &Matrix, out: &mut Matrix, _ws: &mut Workspace) {
        matmul_a_bt_into(low, &self.basis_cache, out);
    }

    fn basis_into(&self, out: &mut Matrix) {
        out.copy_from(&self.basis_cache);
    }

    fn indices(&self) -> Option<&[usize]> {
        Some(&self.idx)
    }

    fn quality(&self) -> Option<crate::obs::SubspaceQuality> {
        self.quality
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        codec::put_indices(out, &self.idx);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        let idx = r.take_indices()?;
        // validate before installing: a corrupt blob must be Err, not a
        // later OOB gather or a silently different rank
        anyhow::ensure!(
            idx.len() == self.rank,
            "checkpointed DCT selection has {} indices, expected rank {}",
            idx.len(),
            self.rank
        );
        anyhow::ensure!(
            idx.iter().all(|&i| i < self.shared.dim()),
            "checkpointed DCT indices out of range for dim {}",
            self.shared.dim()
        );
        self.idx = idx;
        // the basis cache is derived state — rebuild it from the indices
        self.shared.matrix().select_columns_into(&self.idx, &mut self.basis_cache);
        Ok(())
    }

    fn state_bytes(&self) -> u64 {
        (self.idx.len() * 4) as u64 // r int32 indices — the paper's claim
    }

    fn shared_bytes(&self) -> u64 {
        self.shared.bytes()
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn name(&self) -> &'static str {
        "dct"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg64};

    #[test]
    fn selection_maximizes_column_norms() {
        let mut rng = Pcg64::seed(0);
        let s = Matrix::randn(10, 12, 1.0, &mut rng);
        let idx = select_top_columns(&s, 4, RankNorm::L2);
        let norms = s.col_l2_norms();
        let min_selected = idx.iter().map(|&i| norms[i]).fold(f32::MAX, f32::min);
        let max_rejected = (0..12)
            .filter(|i| !idx.contains(i))
            .map(|i| norms[i])
            .fold(0.0f32, f32::max);
        assert!(min_selected >= max_rejected);
    }

    #[test]
    fn selection_indices_sorted_unique() {
        let mut rng = Pcg64::seed(1);
        let s = Matrix::randn(6, 20, 1.0, &mut rng);
        for norm in [RankNorm::L1, RankNorm::L2] {
            let idx = select_top_columns(&s, 7, norm);
            assert_eq!(idx.len(), 7);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted, idx);
        }
    }

    /// Reference implementation: the pre-partition full sort.
    fn select_by_full_sort(s: &Matrix, r: usize, norm: RankNorm) -> Vec<usize> {
        let scores = match norm {
            RankNorm::L1 => s.col_l1_norms(),
            RankNorm::L2 => s.col_l2_norms(),
        };
        let mut order: Vec<usize> = (0..s.cols).collect();
        order.sort_by(|&a, &b| {
            scores[b]
                .partial_cmp(&scores[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut idx = order[..r.min(order.len())].to_vec();
        idx.sort_unstable();
        idx
    }

    #[test]
    fn prop_partition_matches_full_sort_with_ties() {
        // The O(C) partition must pick the exact same set as the O(C log C)
        // sort, including under heavy ties (duplicated columns).
        proptest::check("partition==sort", 12, |rng| {
            let rows = proptest::size(rng, 1, 8);
            let cols = proptest::size(rng, 2, 40);
            let mut s = Matrix::randn(rows, cols, 1.0, rng);
            // duplicate a few columns to force exact score ties
            for _ in 0..cols / 3 {
                let from = proptest::size(rng, 0, cols - 1);
                let to = proptest::size(rng, 0, cols - 1);
                for i in 0..rows {
                    *s.at_mut(i, to) = s.at(i, from);
                }
            }
            for norm in [RankNorm::L1, RankNorm::L2] {
                for r in [0usize, 1, cols / 2, cols, cols + 3] {
                    let got = select_top_columns(&s, r, norm);
                    let want = select_by_full_sort(&s, r, norm);
                    assert_eq!(got, want, "cols={cols} r={r} norm={norm:?}");
                }
            }
        });
    }

    #[test]
    fn select_into_reuses_buffers() {
        let mut rng = Pcg64::seed(2);
        let s = Matrix::randn(5, 30, 1.0, &mut rng);
        let mut ws = Workspace::new();
        let mut idx = Vec::new();
        select_top_columns_into(&s, 6, RankNorm::L2, &mut ws, &mut idx);
        let first = idx.clone();
        // second call reuses idx + pooled scratch and must agree
        select_top_columns_into(&s, 6, RankNorm::L2, &mut ws, &mut idx);
        assert_eq!(idx, first);
        assert_eq!(idx, select_top_columns(&s, 6, RankNorm::L2));
    }

    #[test]
    fn prop_contractive_bound_holds() {
        // §4.1: ‖G − G·Q_r·Q_rᵀ‖²F ≤ (1 − r/n)·‖G‖²F
        proptest::check("dct-contractive", 10, |rng| {
            let rows = proptest::size(rng, 2, 20);
            let cols = proptest::size(rng, 4, 40);
            let r = proptest::size(rng, 1, cols - 1);
            let g = Matrix::randn(rows, cols, 1.0, rng);
            let shared = Arc::new(SharedDct::new(cols));
            let mut p = DctSelect::new(shared, r, RankNorm::L2, false);
            let low = p.refresh_and_project(&g);
            let err_sq = g.sub(&p.back(&low)).fro_norm_sq();
            let bound = (1.0 - r as f64 / cols as f64) * g.fro_norm_sq();
            assert!(err_sq <= bound * (1.0 + 1e-4) + 1e-6,
                    "err²={err_sq} bound={bound}");
        });
    }

    #[test]
    fn selection_beats_fixed_prefix() {
        // Dynamic selection must be at least as good as always taking the
        // first r DCT columns (the static strategy).
        let mut rng = Pcg64::seed(3);
        let g = Matrix::randn(16, 32, 1.0, &mut rng);
        let shared = Arc::new(SharedDct::new(32));
        let r = 8;
        let mut dynamic = DctSelect::new(shared.clone(), r, RankNorm::L2, false);
        let low = dynamic.refresh_and_project(&g);
        let err_dyn = g.sub(&dynamic.back(&low)).fro_norm_sq();

        let prefix: Vec<usize> = (0..r).collect();
        let q_r = shared.matrix().select_columns(&prefix);
        let low_fix = matmul(&g, &q_r);
        let err_fix = g.sub(&matmul_a_bt(&low_fix, &q_r)).fro_norm_sq();
        assert!(err_dyn <= err_fix + 1e-5);
    }

    #[test]
    fn makhoul_and_matmul_paths_agree() {
        let mut rng = Pcg64::seed(4);
        let g = Matrix::randn(14, 24, 1.0, &mut rng);
        let shared = Arc::new(SharedDct::new(24));
        let mut a = DctSelect::new(shared.clone(), 6, RankNorm::L2, true);
        let mut b = DctSelect::new(shared, 6, RankNorm::L2, false);
        let la = a.refresh_and_project(&g);
        let lb = b.refresh_and_project(&g);
        assert_eq!(a.indices(), b.indices());
        assert!(la.max_abs_diff(&lb) < 1e-4);
    }

    #[test]
    fn refresh_into_matches_refresh_full() {
        let mut rng = Pcg64::seed(6);
        let g = Matrix::randn(9, 26, 1.0, &mut rng);
        let shared = Arc::new(SharedDct::new(26));
        let mut p1 = DctSelect::new(shared.clone(), 5, RankNorm::L2, true);
        let mut p2 = DctSelect::new(shared, 5, RankNorm::L2, true);
        let (_, low1) = p1.refresh_full(&g);
        let mut ws = Workspace::new();
        let mut low2 = Matrix::zeros(1, 1);
        p2.refresh_and_project_into(&g, &mut low2, &mut ws);
        assert_eq!(low1, low2);
        assert_eq!(p1.indices(), p2.indices());
        assert_eq!(p1.basis(), p2.basis());
    }

    #[test]
    fn quality_gauges_track_energy_and_overlap() {
        let mut rng = Pcg64::seed(7);
        let g = Matrix::randn(10, 24, 1.0, &mut rng);
        let shared = Arc::new(SharedDct::new(24));
        let mut p = DctSelect::new(shared.clone(), 6, RankNorm::L2, true);
        assert!(p.quality().is_none()); // no fitted refresh yet
        let mut ws = Workspace::new();
        let mut low = Matrix::zeros(1, 1);
        p.refresh_and_project_into(&g, &mut low, &mut ws);
        let q1 = p.quality().unwrap();
        assert!(q1.energy_ratio > 0.0 && q1.energy_ratio <= 1.0);
        assert_eq!(q1.overlap, 0.0); // first fitted refresh: no predecessor
        // the residual gauge must equal the directly computed error
        // ‖G − G·Q_r·Q_rᵀ‖F (Pythagoras under an orthonormal Q)
        let err = g.sub(&p.back(&low)).fro_norm();
        assert!(
            (q1.resid_norm as f64 - err).abs() < 1e-3 * (1.0 + err),
            "resid gauge {} vs direct {err}",
            q1.resid_norm
        );
        // same gradient again → same selection → full overlap, same energy
        p.refresh_and_project_into(&g, &mut low, &mut ws);
        let q2 = p.quality().unwrap();
        assert_eq!(q2.overlap, 1.0);
        assert_eq!(q2.energy_ratio, q1.energy_ratio);

        // a non-finite refresh keeps the last-good gauges
        let mut bad = g.clone();
        bad.data[0] = f32::NAN;
        p.refresh_and_project_into(&bad, &mut low, &mut ws);
        assert_eq!(p.quality().unwrap(), q2);

        // full-rank selection captures all energy, residual ≈ 0
        let mut full = DctSelect::new(shared, 24, RankNorm::L2, false);
        full.refresh_and_project_into(&g, &mut low, &mut ws);
        let qf = full.quality().unwrap();
        assert!((qf.energy_ratio - 1.0).abs() < 1e-5);
        assert!(qf.resid_norm < 1e-2);
    }

    #[test]
    fn full_rank_selection_is_lossless() {
        let mut rng = Pcg64::seed(5);
        let g = Matrix::randn(9, 16, 1.0, &mut rng);
        let shared = Arc::new(SharedDct::new(16));
        let mut p = DctSelect::new(shared, 16, RankNorm::L2, false);
        let low = p.refresh_and_project(&g);
        assert!(g.sub(&p.back(&low)).fro_norm() < 1e-4);
    }

    #[test]
    fn shared_dct_replicas_share_storage() {
        let a = SharedDct::new(32);
        let b = SharedDct::new(32);
        assert!(std::ptr::eq(
            a.matrix() as *const Matrix,
            b.matrix() as *const Matrix
        ));
    }
}
