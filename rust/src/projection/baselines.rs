//! Baseline projections the paper compares against: SVD (GaLore / FRUGAL /
//! FIRA), block power iteration (LDAdam), random semi-orthogonal and random
//! permutation (FRUGAL's ablations).

use anyhow::Result;

use crate::linalg::{block_power_iter, qr_q_into, qr_thin, svd_right_vectors_into, svd_thin};
use crate::tensor::{
    all_finite, matmul, matmul_a_bt, matmul_a_bt_into, matmul_at_b_into,
    matmul_into, Matrix, Workspace,
};
use crate::util::codec::{self, ByteReader};
use crate::util::Pcg64;

use super::Projection;

/// Shared implementation for methods that materialize `Q_r (C×r)` —
/// including the allocation-free `_into` family. Each baseline overrides
/// `refresh_and_project_into` with a workspace-backed refresh as well
/// (Jacobi SVD, block power + `qr_q_into`, QR-of-Gaussian), so refresh
/// *and* project steps are allocation-free at steady state.
macro_rules! dense_basis_impl {
    () => {
        fn project(&self, g: &Matrix) -> Matrix {
            matmul(g, &self.q_r)
        }

        fn back(&self, low: &Matrix) -> Matrix {
            matmul_a_bt(low, &self.q_r)
        }

        fn basis(&self) -> Matrix {
            self.q_r.clone()
        }

        fn project_into(&self, g: &Matrix, out: &mut Matrix, _ws: &mut Workspace) {
            matmul_into(g, &self.q_r, out);
        }

        fn back_into(&self, low: &Matrix, out: &mut Matrix, _ws: &mut Workspace) {
            matmul_a_bt_into(low, &self.q_r, out);
        }

        fn basis_into(&self, out: &mut Matrix) {
            out.copy_from(&self.q_r);
        }

        fn rotation_into(&self, prev_basis: &Matrix, out: &mut Matrix, _ws: &mut Workspace) {
            matmul_at_b_into(prev_basis, &self.q_r, out);
        }

        fn state_bytes(&self) -> u64 {
            self.q_r.bytes()
        }

        fn rank(&self) -> usize {
            self.q_r.cols
        }

        fn basis_ref(&self) -> Option<&Matrix> {
            Some(&self.q_r)
        }
    };
}

/// Top-r right singular vectors (one-sided Jacobi SVD per refresh).
pub struct SvdProj {
    q_r: Matrix,
}

impl SvdProj {
    pub fn new(cols: usize, rank: usize) -> Self {
        let rank = rank.min(cols);
        // Identity-prefix init; first refresh replaces it.
        let mut q = Matrix::zeros(cols, rank);
        for j in 0..rank {
            *q.at_mut(j, j) = 1.0;
        }
        SvdProj { q_r: q }
    }
}

impl Projection for SvdProj {
    fn refresh_and_project(&mut self, g: &Matrix) -> Matrix {
        // Non-finite input would poison the Jacobi sweep (NaN rotations →
        // NaN basis, corrupting every later projection). Keep the previous
        // basis and project only — graceful degradation, same contract as
        // every refresh path (see ROADMAP §Fault tolerance).
        if !all_finite(&g.data) {
            return self.project(g);
        }
        let svd = svd_thin(g);
        self.q_r = svd.right_vectors(self.q_r.cols);
        self.project(g)
    }

    /// Workspace-backed refresh: the identical Jacobi sweep as
    /// [`svd_thin`] (`svd_right_vectors_into` — bit-identical, pinned by
    /// the `_into` property test in `projection/mod.rs`) with every f64
    /// work buffer pooled, so the GaLore refresh step is allocation-free at
    /// steady state.
    fn refresh_and_project_into(&mut self, g: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        if !all_finite(&g.data) {
            matmul_into(g, &self.q_r, out);
            return;
        }
        svd_right_vectors_into(g, self.q_r.cols, &mut self.q_r, ws);
        matmul_into(g, &self.q_r, out);
    }

    dense_basis_impl!();

    fn save_state(&self, out: &mut Vec<u8>) {
        codec::put_matrix(out, &self.q_r);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        // rank can shrink below the configured r when the gradient is
        // wider than tall (right_vectors clamps to min(m, n)) — accept the
        // checkpointed shape as long as the C dimension matches and the
        // rank never exceeds the configured one
        let q = r.take_matrix()?;
        anyhow::ensure!(
            q.rows == self.q_r.rows && q.cols <= self.q_r.cols,
            "checkpointed SVD basis is {}x{}, expected {}x(≤{})",
            q.rows,
            q.cols,
            self.q_r.rows,
            self.q_r.cols
        );
        self.q_r = q;
        Ok(())
    }

    fn name(&self) -> &'static str {
        "svd"
    }
}

/// LDAdam-style block power iteration, warm-started from the previous basis.
pub struct BlockPower {
    q_r: Matrix,
    iters: usize,
    warm: bool,
}

impl BlockPower {
    pub fn new(cols: usize, rank: usize, iters: usize) -> Self {
        let rank = rank.min(cols);
        let mut q = Matrix::zeros(cols, rank);
        for j in 0..rank {
            *q.at_mut(j, j) = 1.0;
        }
        BlockPower { q_r: q, iters, warm: false }
    }
}

impl Projection for BlockPower {
    fn refresh_and_project(&mut self, g: &Matrix) -> Matrix {
        // non-finite input: keep the previous basis (see SvdProj)
        if !all_finite(&g.data) {
            return self.project(g);
        }
        let warm = if self.warm { Some(&self.q_r) } else { None };
        self.q_r = block_power_iter(g, self.q_r.cols, self.iters, warm);
        self.warm = true;
        self.project(g)
    }

    /// Workspace-backed refresh: the same block power sweep as
    /// [`block_power_iter`] (same matmuls, same `qr_q_into` Householder
    /// arithmetic — bit-identical; the `_into` property test in
    /// `projection/mod.rs` pins both the cold-start and the warm-start
    /// refresh against the allocating path) with every temporary pooled,
    /// so the LDAdamW-style refresh-every-step loop runs allocation-free
    /// at steady state. Only the cold-start Gaussian seed (first refresh
    /// ever) allocates.
    fn refresh_and_project_into(&mut self, g: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        if !all_finite(&g.data) {
            matmul_into(g, &self.q_r, out);
            return;
        }
        let c = g.cols;
        let r = self.q_r.cols.min(c);
        let mut v = ws.take_uninit(c, r);
        if self.warm && self.q_r.shape() == (c, r) {
            v.copy_from(&self.q_r);
        } else {
            // cold start: the fixed-seed Gaussian block_power_iter uses
            let mut rng = Pcg64::seed(0x9e3779b97f4a7c15);
            v.copy_from(&Matrix::randn(c, r, 1.0, &mut rng));
        }
        let mut q = ws.take_uninit(c, r);
        qr_q_into(&v, &mut q, ws);
        let mut gv = ws.take_uninit(g.rows, r);
        for _ in 0..self.iters.max(1) {
            matmul_into(g, &q, &mut gv);
            matmul_at_b_into(g, &gv, &mut v); // v doubles as the GᵀGV buffer
            qr_q_into(&v, &mut q, ws);
        }
        self.q_r.copy_from(&q);
        self.warm = true;
        ws.give(gv);
        ws.give(q);
        ws.give(v);
        matmul_into(g, &self.q_r, out);
    }

    dense_basis_impl!();

    fn save_state(&self, out: &mut Vec<u8>) {
        codec::put_matrix(out, &self.q_r);
        codec::put_u8(out, self.warm as u8);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        r.take_matrix_into(&mut self.q_r)?;
        self.warm = r.take_u8()? != 0;
        Ok(())
    }

    fn name(&self) -> &'static str {
        "block_power"
    }
}

/// Random semi-orthogonal basis (QR of a fresh Gaussian per refresh) —
/// FRUGAL's `Random` projection.
pub struct RandomSemiOrtho {
    q_r: Matrix,
    rng: Pcg64,
}

impl RandomSemiOrtho {
    pub fn new(cols: usize, rank: usize, seed: u64) -> Self {
        let rank = rank.min(cols);
        let mut rng = Pcg64::new(seed, 0x7a11_5eed);
        let g = Matrix::randn(cols, rank, 1.0, &mut rng);
        let (q, _) = qr_thin(&g);
        RandomSemiOrtho { q_r: q, rng }
    }
}

impl Projection for RandomSemiOrtho {
    fn refresh_and_project(&mut self, g: &Matrix) -> Matrix {
        // The Gaussian refresh itself never sees g, but a poisoned step's
        // refresh must not advance the RNG either — a rolled-back replay
        // (or a skip-equivalent reference run) has to land on the same
        // draw sequence. Uniform rule: non-finite input refreshes nothing.
        if !all_finite(&g.data) {
            return self.project(g);
        }
        let fresh = Matrix::randn(self.q_r.rows, self.q_r.cols, 1.0, &mut self.rng);
        let (q, _) = qr_thin(&fresh);
        self.q_r = q;
        self.project(g)
    }

    /// Workspace-backed refresh: the fresh Gaussian draws into a pooled
    /// buffer (same RNG consumption as `Matrix::randn`) and the Q factor
    /// comes from `qr_q_into` — bit-identical to `qr_thin`'s Q (property-
    /// pinned in `linalg/qr.rs`), with zero steady-state allocations.
    fn refresh_and_project_into(&mut self, g: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        if !all_finite(&g.data) {
            matmul_into(g, &self.q_r, out);
            return;
        }
        let (c, r) = self.q_r.shape();
        let mut fresh = ws.take_uninit(c, r);
        self.rng.fill_normal(&mut fresh.data, 1.0);
        qr_q_into(&fresh, &mut self.q_r, ws);
        ws.give(fresh);
        matmul_into(g, &self.q_r, out);
    }

    dense_basis_impl!();

    fn save_state(&self, out: &mut Vec<u8>) {
        codec::put_matrix(out, &self.q_r);
        let (state, inc) = self.rng.state_parts();
        codec::put_u128(out, state);
        codec::put_u128(out, inc);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        r.take_matrix_into(&mut self.q_r)?;
        let state = r.take_u128()?;
        let inc = r.take_u128()?;
        self.rng = Pcg64::from_state_parts(state, inc);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

/// Random coordinate subset (FRUGAL's `RandPerm`): the basis is `r` distinct
/// standard basis vectors, so project/back are gathers — no matmul at all.
pub struct RandPerm {
    cols: usize,
    idx: Vec<usize>,
    rng: Pcg64,
}

impl RandPerm {
    pub fn new(cols: usize, rank: usize, seed: u64) -> Self {
        let rank = rank.min(cols);
        let mut rng = Pcg64::new(seed, 0x9e37_79b9);
        let mut idx = rng.sample_indices(cols, rank);
        idx.sort_unstable();
        RandPerm { cols, idx, rng }
    }
}

impl Projection for RandPerm {
    fn refresh_and_project(&mut self, g: &Matrix) -> Matrix {
        // non-finite input: keep the current coordinate subset and don't
        // advance the RNG (see RandomSemiOrtho)
        if !all_finite(&g.data) {
            return self.project(g);
        }
        let mut idx = self.rng.sample_indices(self.cols, self.idx.len());
        idx.sort_unstable();
        self.idx = idx;
        self.project(g)
    }

    fn project(&self, g: &Matrix) -> Matrix {
        g.select_columns(&self.idx)
    }

    fn back(&self, low: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(low.rows, self.cols);
        let mut ws = Workspace::new();
        self.back_into(low, &mut out, &mut ws);
        out
    }

    fn project_into(&self, g: &Matrix, out: &mut Matrix, _ws: &mut Workspace) {
        g.select_columns_into(&self.idx, out);
    }

    fn back_into(&self, low: &Matrix, out: &mut Matrix, _ws: &mut Workspace) {
        out.resize_to(low.rows, self.cols);
        for i in 0..low.rows {
            let src = low.row(i);
            let dst = &mut out.data[i * self.cols..(i + 1) * self.cols];
            for (k, &j) in self.idx.iter().enumerate() {
                dst[j] = src[k];
            }
        }
    }

    fn basis(&self) -> Matrix {
        let mut q = Matrix::zeros(self.cols, self.idx.len());
        for (k, &j) in self.idx.iter().enumerate() {
            *q.at_mut(j, k) = 1.0;
        }
        q
    }

    fn indices(&self) -> Option<&[usize]> {
        Some(&self.idx)
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        codec::put_indices(out, &self.idx);
        let (state, inc) = self.rng.state_parts();
        codec::put_u128(out, state);
        codec::put_u128(out, inc);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        let idx = r.take_indices()?;
        // validate against the built config before installing: a corrupt
        // blob must surface as Err, never as an OOB gather later
        anyhow::ensure!(
            idx.len() == self.idx.len(),
            "checkpointed randperm has {} indices, expected {}",
            idx.len(),
            self.idx.len()
        );
        anyhow::ensure!(
            idx.iter().all(|&i| i < self.cols),
            "checkpointed randperm indices out of range for dim {}",
            self.cols
        );
        self.idx = idx;
        let state = r.take_u128()?;
        let inc = r.take_u128()?;
        self.rng = Pcg64::from_state_parts(state, inc);
        Ok(())
    }

    fn state_bytes(&self) -> u64 {
        (self.idx.len() * 4) as u64
    }

    fn rank(&self) -> usize {
        self.idx.len()
    }

    fn name(&self) -> &'static str {
        "randperm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn svd_projection_minimizes_reconstruction_error() {
        // SVD must beat (or tie) every other baseline on reconstruction.
        let mut rng = Pcg64::seed(0);
        let g = Matrix::randn(24, 16, 1.0, &mut rng);
        let r = 4;
        let errs: Vec<(String, f64)> = {
            let mut out = Vec::new();
            let mut svd = SvdProj::new(16, r);
            let low = svd.refresh_and_project(&g);
            out.push(("svd".into(), g.sub(&svd.back(&low)).fro_norm()));
            let mut bp = BlockPower::new(16, r, 6);
            let low = bp.refresh_and_project(&g);
            out.push(("bp".into(), g.sub(&bp.back(&low)).fro_norm()));
            let mut rnd = RandomSemiOrtho::new(16, r, 1);
            let low = rnd.refresh_and_project(&g);
            out.push(("rand".into(), g.sub(&rnd.back(&low)).fro_norm()));
            let mut perm = RandPerm::new(16, r, 1);
            let low = perm.refresh_and_project(&g);
            out.push(("perm".into(), g.sub(&perm.back(&low)).fro_norm()));
            out
        };
        let svd_err = errs[0].1;
        for (name, e) in &errs[1..] {
            assert!(svd_err <= e + 1e-4, "svd {svd_err} vs {name} {e}");
        }
    }

    #[test]
    fn block_power_approaches_svd_error() {
        let mut rng = Pcg64::seed(1);
        // low-rank + noise structure so the subspace is identifiable
        let u = Matrix::randn(30, 3, 2.0, &mut rng);
        let v = Matrix::randn(3, 20, 1.0, &mut rng);
        let mut g = matmul(&u, &v);
        g.axpy(1.0, &Matrix::randn(30, 20, 0.1, &mut rng));

        let mut svd = SvdProj::new(20, 3);
        let low = svd.refresh_and_project(&g);
        let err_svd = g.sub(&svd.back(&low)).fro_norm();

        let mut bp = BlockPower::new(20, 3, 8);
        let low = bp.refresh_and_project(&g);
        let err_bp = g.sub(&bp.back(&low)).fro_norm();
        assert!(err_bp <= err_svd * 1.05, "bp={err_bp} svd={err_svd}");
    }

    #[test]
    fn randperm_roundtrip_preserves_selected_coords() {
        let mut rng = Pcg64::seed(2);
        let g = Matrix::randn(6, 12, 1.0, &mut rng);
        let mut p = RandPerm::new(12, 5, 3);
        let low = p.refresh_and_project(&g);
        let back = p.back(&low);
        for (k, &j) in p.idx.iter().enumerate() {
            for i in 0..6 {
                assert_eq!(back.at(i, j), g.at(i, j), "coord {k}");
            }
        }
    }

    #[test]
    fn prop_random_semi_ortho_changes_every_refresh() {
        proptest::check("random-refresh", 4, |rng| {
            let g = Matrix::randn(8, 10, 1.0, rng);
            let mut p = RandomSemiOrtho::new(10, 3, rng.next_u64());
            let b0 = p.basis();
            p.refresh_and_project(&g);
            let b1 = p.basis();
            assert!(b0.max_abs_diff(&b1) > 1e-3);
        });
    }
}
