//! CSV + JSONL metric sinks. Every experiment writes both: CSV for the
//! table renderers, JSONL for loss-curve figures (step, wallclock, loss…).

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::Result;

use super::json::Json;

/// Append-oriented CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
    pub path: PathBuf,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(File::create(&path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, columns: header.len(), path })
    }

    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        assert_eq!(cells.len(), self.columns, "csv row width mismatch");
        let escaped: Vec<String> = cells
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') || c.contains('\n') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        writeln!(self.out, "{}", escaped.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// JSONL sink for per-step metric records.
///
/// Records auto-flush every [`JsonlWriter::FLUSH_EVERY`] lines (and callers
/// flush explicitly at noteworthy events — guard trips, rollbacks, run
/// end), so a killed run leaves a valid JSONL prefix on disk instead of
/// whatever happened to escape the `BufWriter` — pinned by the mid-stream
/// kill test in `tests/obs_determinism.rs`.
pub struct JsonlWriter {
    out: BufWriter<File>,
    pub path: PathBuf,
    since_flush: usize,
}

impl JsonlWriter {
    /// Flush cadence in records — small enough that a crash loses seconds
    /// of telemetry, large enough that flushing never shows up in profiles.
    pub const FLUSH_EVERY: usize = 32;

    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        Ok(JsonlWriter {
            out: BufWriter::new(File::create(&path)?),
            path,
            since_flush: 0,
        })
    }

    pub fn record(&mut self, v: &Json) -> Result<()> {
        writeln!(self.out, "{}", v.to_string())?;
        self.since_flush += 1;
        if self.since_flush >= Self::FLUSH_EVERY {
            self.flush()?;
        }
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        self.since_flush = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj, s};

    #[test]
    fn csv_escapes_and_counts() {
        let dir = std::env::temp_dir().join("fft_subspace_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into(), "x,\"y\"".into()]).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,\"x,\"\"y\"\"\"\n");
    }

    #[test]
    fn jsonl_lines_parse_back() {
        let dir = std::env::temp_dir().join("fft_subspace_jsonl_test");
        let path = dir.join("t.jsonl");
        let mut w = JsonlWriter::create(&path).unwrap();
        w.record(&obj(vec![("step", num(1.0)), ("tag", s("ok"))])).unwrap();
        w.record(&obj(vec![("step", num(2.0))])).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            Json::parse(l).unwrap();
        }
    }
}
