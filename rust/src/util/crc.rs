//! CRC-32 (IEEE 802.3, reflected polynomial `0xEDB8_8320`) — the integrity
//! footer on v2 checkpoints.
//!
//! Hand-rolled on purpose: the project is dependency-free, and a 256-entry
//! table built in a `const fn` is the whole algorithm. The variant matches
//! zlib's `crc32()` (init `0xFFFF_FFFF`, final xor-out), so footers can be
//! cross-checked with any standard tool: `crc32(b"123456789") ==
//! 0xCBF4_3926`.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `bytes` (zlib-compatible: init all-ones, reflected, xor-out).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // canonical check value for this CRC variant
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // independently computed with zlib's crc32()
        assert_eq!(crc32(b"FFTSUBv2"), 0x7BD8_8274);
    }

    #[test]
    fn sensitive_to_single_bit_flips() {
        let base = crc32(b"checkpoint payload bytes");
        let mut flipped = b"checkpoint payload bytes".to_vec();
        for i in 0..flipped.len() {
            flipped[i] ^= 1;
            assert_ne!(crc32(&flipped), base, "flip at byte {i} undetected");
            flipped[i] ^= 1;
        }
    }
}
