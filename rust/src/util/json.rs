//! Minimal JSON parser + writer (no `serde` in the offline environment).
//!
//! Supports the full JSON grammar we produce/consume: the artifact
//! `manifest.json` (read), run configs (read) and metric records (write).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Numbers are kept as f64 (manifest values fit).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    /// Shape helper: `[a, b]` → `Vec<usize>`.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for metric records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, got {:?}",
                c as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos..self.pos + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                }
                c => {
                    // Re-decode multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk =
                            std::str::from_utf8(&self.bytes[start..start + width])?;
                        out.push_str(chunk);
                        self.pos = start + width;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_manifest_like() {
        let src = r#"{"artifacts":[{"name":"a","shape":[2,3],"ok":true}],
                      "n":1.5,"none":null,"s":"x\"y\n"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("n").unwrap().as_f64().unwrap(), 1.5);
        assert_eq!(
            v.req("artifacts").unwrap().as_arr().unwrap()[0]
                .req("shape").unwrap().as_shape().unwrap(),
            vec![2, 3]
        );
        // writer output re-parses to the same value
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, round);
    }

    #[test]
    fn parse_nested_and_unicode() {
        let v = Json::parse(r#"{"a":{"b":[[1],[2,3]]},"u":"éé"}"#).unwrap();
        assert_eq!(v.req("u").unwrap().as_str().unwrap(), "éé");
        assert!(v.req("a").unwrap().get("b").is_some());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let v = Json::parse("[-1.25e-2, 3E4, -7]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), -0.0125);
        assert_eq!(a[1].as_f64().unwrap(), 30000.0);
        assert_eq!(a[2].as_f64().unwrap(), -7.0);
    }
}
