//! Wall-clock timing + per-phase accumulators for the step-time breakdown
//! (compute / collective / optimizer) reported by the experiment harness.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Named phase accumulator: `phases.time("optimizer", || ...)`.
#[derive(Default, Debug, Clone)]
pub struct PhaseTimes {
    totals: BTreeMap<&'static str, Duration>,
    counts: BTreeMap<&'static str, u64>,
}

impl PhaseTimes {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    pub fn add(&mut self, phase: &'static str, d: Duration) {
        *self.totals.entry(phase).or_default() += d;
        *self.counts.entry(phase).or_default() += 1;
    }

    pub fn total(&self, phase: &str) -> Duration {
        self.totals.get(phase).copied().unwrap_or_default()
    }

    pub fn secs(&self, phase: &str) -> f64 {
        self.total(phase).as_secs_f64()
    }

    pub fn merge(&mut self, other: &PhaseTimes) {
        for (k, v) in &other.totals {
            *self.totals.entry(k).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k).or_default() += *v;
        }
    }

    pub fn summary(&self) -> String {
        let mut rows: Vec<String> = self
            .totals
            .iter()
            .map(|(k, v)| {
                let n = self.counts[k].max(1);
                format!(
                    "{k}: {:.3}s total, {:.3}ms/call ×{n}",
                    v.as_secs_f64(),
                    v.as_secs_f64() * 1e3 / n as f64
                )
            })
            .collect();
        rows.sort();
        rows.join(" | ")
    }

    pub fn phases(&self) -> impl Iterator<Item = (&'static str, Duration)> + '_ {
        self.totals.iter().map(|(k, v)| (*k, *v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_phases() {
        let mut p = PhaseTimes::new();
        let x = p.time("a", || 40 + 2);
        assert_eq!(x, 42);
        p.time("a", || ());
        p.time("b", || ());
        assert!(p.total("a") >= Duration::ZERO);
        assert_eq!(p.counts["a"], 2);
        assert_eq!(p.counts["b"], 1);
        assert!(p.summary().contains("a:"));
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimes::new();
        a.add("x", Duration::from_millis(10));
        let mut b = PhaseTimes::new();
        b.add("x", Duration::from_millis(5));
        a.merge(&b);
        assert_eq!(a.total("x"), Duration::from_millis(15));
        assert_eq!(a.counts["x"], 2);
    }
}
