//! Human-readable formatting for the experiment tables (the paper reports
//! "1h 53m", "42.42 GB", "15.29 PPL" style values).

/// `6842s` → `"1h 54m"`, `95s` → `"1m 35s"`, `4.2s` → `"4.2s"`.
pub fn duration(secs: f64) -> String {
    if secs < 60.0 {
        return format!("{secs:.1}s");
    }
    let total = secs.round() as u64;
    let (h, m, s) = (total / 3600, (total % 3600) / 60, total % 60);
    if h > 0 {
        format!("{h}h {m}m")
    } else {
        format!("{m}m {s}s")
    }
}

/// Bytes → MiB/GiB string.
pub fn bytes(n: u64) -> String {
    const MIB: f64 = 1024.0 * 1024.0;
    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
    let x = n as f64;
    if x >= GIB {
        format!("{:.2} GiB", x / GIB)
    } else if x >= MIB {
        format!("{:.2} MiB", x / MIB)
    } else {
        format!("{:.1} KiB", x / 1024.0)
    }
}

/// Parameter count → `"14.4M"` / `"1.3B"`.
pub fn params(n: u64) -> String {
    let x = n as f64;
    if x >= 1e9 {
        format!("{:.1}B", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.1}M", x / 1e6)
    } else {
        format!("{:.0}k", x / 1e3)
    }
}

/// Fixed-width table cell padding.
pub fn pad(s: &str, width: usize) -> String {
    if s.len() >= width {
        s.to_string()
    } else {
        format!("{s}{}", " ".repeat(width - s.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations() {
        assert_eq!(duration(4.23), "4.2s");
        assert_eq!(duration(95.0), "1m 35s");
        assert_eq!(duration(6840.0), "1h 54m");
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(bytes(512), "0.5 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(bytes(45_560_000_000), "42.43 GiB");
    }

    #[test]
    fn param_counts() {
        assert_eq!(params(860_000), "860k");
        assert_eq!(params(14_360_000), "14.4M");
        assert_eq!(params(1_300_000_000), "1.3B");
    }

    #[test]
    fn padding() {
        assert_eq!(pad("ab", 4), "ab  ");
        assert_eq!(pad("abcdef", 4), "abcdef");
    }
}
