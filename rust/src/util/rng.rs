//! Deterministic PRNG (PCG-XSH-RR 64/32 pair → 64-bit) + distributions.
//!
//! Every stochastic component in the crate (init, data synthesis, projection
//! baselines, property tests) draws from this generator so runs are exactly
//! reproducible from a single seed recorded in the run config.

/// PCG64: two independent PCG-XSH-RR 32-bit output streams combined.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seeded constructor; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Raw generator state `(state, inc)` for checkpointing — restoring
    /// via [`Pcg64::from_state_parts`] continues the exact stream.
    pub fn state_parts(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg64::state_parts`].
    pub fn from_state_parts(state: u128, inc: u128) -> Self {
        Pcg64 { state, inc }
    }

    /// Next raw 64 bits (PCG-XSL-RR 128/64).
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, bound)` (Lemire rejection).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, std²).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Zipf-distributed value in `[0, n)` with exponent `s` (inverse-CDF on
    /// a precomputed table is the loader's job; this is the slow exact path).
    pub fn zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.next_f64();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

/// Precompute a Zipf CDF table for `n` items with exponent `s`.
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).collect();
    let z: f64 = w.iter().sum();
    let mut acc = 0.0;
    for v in w.iter_mut() {
        acc += *v / z;
        *v = acc;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_parts_roundtrip_continues_the_stream() {
        let mut a = Pcg64::seed(3);
        for _ in 0..17 {
            a.next_u64();
        }
        let (st, inc) = a.state_parts();
        let mut b = Pcg64::from_state_parts(st, inc);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Pcg64::seed(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg64::seed(1);
        for bound in [1u64, 2, 3, 7, 100, 12345] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seed(5);
        let idx = r.sample_indices(50, 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let cdf = zipf_cdf(100, 1.1);
        let mut r = Pcg64::seed(3);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[r.zipf(&cdf)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[60]);
    }
}
