//! Infrastructure utilities the offline environment forces us to own:
//! RNG (no `rand`), JSON (no `serde`), CSV/JSONL sinks, timers, human
//! formatting and a tiny property-testing harness (no `proptest`).

pub mod rng;
pub mod json;
pub mod codec;
pub mod crc;
pub mod csv;
pub mod timer;
pub mod human;
pub mod proptest;

pub use rng::Pcg64;
pub use timer::Timer;
