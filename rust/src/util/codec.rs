//! Minimal little-endian binary codec for checkpoint serialization.
//!
//! The optimizer-state layer (checkpoint v2) serializes heterogeneous
//! per-layer state — typed stores, index lists, RNG streams, dense bases —
//! through these helpers so every writer has a bounds-checked reader twin.
//! The format is positional: each policy reads exactly what it wrote, and
//! the enclosing blob carries a spec fingerprint so a reader can never be
//! paired with a writer of a different composition.
//!
//! Writers are free functions appending to a `Vec<u8>`; [`ByteReader`] is a
//! cursor over a borrowed slice whose every `take_*` is bounds-checked and
//! fails with context instead of panicking — corrupt or truncated blobs
//! surface as `Err`, never as OOB reads or huge allocations (readers of
//! length-prefixed payloads validate the prefix against the bytes actually
//! remaining before allocating).

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::Matrix;

// ---- writers -----------------------------------------------------------

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u128(out: &mut Vec<u8>, v: u128) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// u32 length prefix + UTF-8 bytes.
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// u32 rows + u32 cols + f32 payload (bit-exact).
pub fn put_matrix(out: &mut Vec<u8>, m: &Matrix) {
    put_u32(out, m.rows as u32);
    put_u32(out, m.cols as u32);
    out.reserve(m.data.len() * 4);
    for &v in &m.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// u32 count + u32 per index (column indices are always < 2³²).
pub fn put_indices(out: &mut Vec<u8>, idx: &[usize]) {
    put_u32(out, idx.len() as u32);
    for &i in idx {
        put_u32(out, i as u32);
    }
}

/// u32 count + raw f32 payload (bit-exact).
pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    out.reserve(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// u32 count + raw u16 payload.
pub fn put_u16s(out: &mut Vec<u8>, xs: &[u16]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// u32 count + raw i8 payload.
pub fn put_i8s(out: &mut Vec<u8>, xs: &[i8]) {
    put_u32(out, xs.len() as u32);
    out.extend(xs.iter().map(|&x| x as u8));
}

// ---- reader ------------------------------------------------------------

/// Bounds-checked cursor over a serialized state blob.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.remaining(),
            "state blob truncated: need {n} bytes at offset {}, have {}",
            self.pos,
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn take_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn take_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn take_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn take_u128(&mut self) -> Result<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    pub fn take_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn take_str(&mut self) -> Result<String> {
        let len = self.take_u32()? as usize;
        let bytes = self.take(len).context("reading string payload")?;
        String::from_utf8(bytes.to_vec()).context("state blob string not UTF-8")
    }

    /// Twin of [`put_matrix`], allocating (the length prefix is validated
    /// against the remaining bytes before the buffer is sized).
    pub fn take_matrix(&mut self) -> Result<Matrix> {
        let rows = self.take_u32()? as usize;
        let cols = self.take_u32()? as usize;
        let elems = rows
            .checked_mul(cols)
            .filter(|&e| e.checked_mul(4).is_some_and(|b| b <= self.remaining()))
            .with_context(|| {
                format!("matrix header claims {rows}x{cols} but only {} bytes remain", self.remaining())
            })?;
        let mut data = Vec::with_capacity(elems);
        for _ in 0..elems {
            data.push(self.take_f32()?);
        }
        Ok(Matrix::from_vec(rows, cols, data))
    }

    /// Twin of [`put_matrix`] writing into an existing matrix of the same
    /// shape (the shape is part of the optimizer spec, so a mismatch means
    /// the blob belongs to a different model).
    pub fn take_matrix_into(&mut self, m: &mut Matrix) -> Result<()> {
        let rows = self.take_u32()? as usize;
        let cols = self.take_u32()? as usize;
        ensure!(
            (rows, cols) == m.shape(),
            "checkpointed matrix is {rows}x{cols}, expected {}x{}",
            m.rows,
            m.cols
        );
        for v in &mut m.data {
            *v = self.take_f32()?;
        }
        Ok(())
    }

    pub fn take_indices(&mut self) -> Result<Vec<usize>> {
        let n = self.take_u32()? as usize;
        ensure!(n * 4 <= self.remaining(), "index list truncated");
        (0..n).map(|_| Ok(self.take_u32()? as usize)).collect()
    }

    /// Twin of [`put_f32s`] writing into an existing buffer of equal length
    /// (one bounds check for the whole payload, not one per element).
    pub fn take_f32s_into(&mut self, xs: &mut [f32]) -> Result<()> {
        let n = self.take_u32()? as usize;
        ensure!(n == xs.len(), "f32 payload is {n} elements, expected {}", xs.len());
        let bytes = self.take(n * 4)?;
        for (x, c) in xs.iter_mut().zip(bytes.chunks_exact(4)) {
            *x = f32::from_le_bytes(c.try_into().unwrap());
        }
        Ok(())
    }

    /// Twin of [`put_u16s`] writing into an existing buffer of equal length.
    pub fn take_u16s_into(&mut self, xs: &mut [u16]) -> Result<()> {
        let n = self.take_u32()? as usize;
        ensure!(n == xs.len(), "u16 payload is {n} elements, expected {}", xs.len());
        let bytes = self.take(n * 2)?;
        for (x, c) in xs.iter_mut().zip(bytes.chunks_exact(2)) {
            *x = u16::from_le_bytes(c.try_into().unwrap());
        }
        Ok(())
    }

    /// Twin of [`put_i8s`] writing into an existing buffer of equal length.
    pub fn take_i8s_into(&mut self, xs: &mut [i8]) -> Result<()> {
        let n = self.take_u32()? as usize;
        ensure!(n == xs.len(), "i8 payload is {n} elements, expected {}", xs.len());
        let bytes = self.take(n)?;
        for (x, &b) in xs.iter_mut().zip(bytes) {
            *x = b as i8;
        }
        Ok(())
    }

    /// Assert the blob was fully consumed — trailing bytes mean the writer
    /// and reader disagree about the format.
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("state blob has {} unread trailing bytes", self.remaining());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn roundtrip_all_types() {
        let mut rng = Pcg64::seed(0);
        let m = Matrix::randn(3, 5, 1.0, &mut rng);
        let mut out = Vec::new();
        put_u8(&mut out, 7);
        put_u32(&mut out, 0xdead_beef);
        put_u64(&mut out, u64::MAX - 1);
        put_u128(&mut out, u128::MAX / 3);
        put_f32(&mut out, -0.0);
        put_str(&mut out, "fingerprint ü");
        put_matrix(&mut out, &m);
        put_indices(&mut out, &[0, 5, 17]);
        put_f32s(&mut out, &[-0.0, 3.5, f32::MIN_POSITIVE]);
        put_u16s(&mut out, &[1, 2, 65535]);
        put_i8s(&mut out, &[-128, 0, 127]);

        let mut r = ByteReader::new(&out);
        assert_eq!(r.take_u8().unwrap(), 7);
        assert_eq!(r.take_u32().unwrap(), 0xdead_beef);
        assert_eq!(r.take_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.take_u128().unwrap(), u128::MAX / 3);
        assert_eq!(r.take_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.take_str().unwrap(), "fingerprint ü");
        assert_eq!(r.take_matrix().unwrap(), m);
        assert_eq!(r.take_indices().unwrap(), vec![0, 5, 17]);
        let mut f32s = [0f32; 3];
        r.take_f32s_into(&mut f32s).unwrap();
        assert_eq!(f32s[0].to_bits(), (-0.0f32).to_bits());
        assert_eq!(f32s[1], 3.5);
        assert_eq!(f32s[2], f32::MIN_POSITIVE);
        let mut u16s = [0u16; 3];
        r.take_u16s_into(&mut u16s).unwrap();
        assert_eq!(u16s, [1, 2, 65535]);
        let mut i8s = [0i8; 3];
        r.take_i8s_into(&mut i8s).unwrap();
        assert_eq!(i8s, [-128, 0, 127]);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut out = Vec::new();
        put_u64(&mut out, 9);
        let mut r = ByteReader::new(&out[..5]);
        assert!(r.take_u64().is_err());
    }

    #[test]
    fn oversized_matrix_header_rejected_before_allocating() {
        let mut out = Vec::new();
        put_u32(&mut out, u32::MAX); // rows
        put_u32(&mut out, u32::MAX); // cols — rows*cols overflows usize too
        put_f32(&mut out, 1.0);
        let mut r = ByteReader::new(&out);
        assert!(r.take_matrix().is_err());
    }

    #[test]
    fn matrix_into_checks_shape() {
        let mut out = Vec::new();
        put_matrix(&mut out, &Matrix::zeros(2, 2));
        let mut dst = Matrix::zeros(3, 3);
        assert!(ByteReader::new(&out).take_matrix_into(&mut dst).is_err());
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut out = Vec::new();
        put_u32(&mut out, 1);
        put_u8(&mut out, 0);
        let mut r = ByteReader::new(&out);
        r.take_u32().unwrap();
        assert!(r.finish().is_err());
    }
}
