//! Tiny property-testing harness (no `proptest` crate offline).
//!
//! `check(name, cases, |rng| { ... })` runs a closure over `cases` seeded
//! RNGs; a panic inside the closure reports the failing case seed so the
//! exact instance can be replayed with `replay(seed, f)`.

use super::rng::Pcg64;

/// Run `f` over `cases` independent seeded generators. On failure, re-raise
/// with the offending seed in the message.
pub fn check(name: &str, cases: u64, mut f: impl FnMut(&mut Pcg64)) {
    for case in 0..cases {
        let seed = 0x5eed_0000 + case;
        let mut rng = Pcg64::seed(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed at seed {seed:#x}: {msg}");
        }
    }
}

/// Replay one failing case.
pub fn replay(seed: u64, mut f: impl FnMut(&mut Pcg64)) {
    let mut rng = Pcg64::seed(seed);
    f(&mut rng);
}

/// Uniform usize in `[lo, hi]`.
pub fn size(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
    lo + rng.usize_below(hi - lo + 1)
}

/// A random matrix buffer (row-major) with standard-normal entries.
pub fn normal_vec(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 1.0);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        check("add-commutes", 16, |rng| {
            let a = rng.next_f64();
            let b = rng.next_f64();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 1, |_| panic!("boom"));
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn size_respects_bounds() {
        let mut rng = Pcg64::seed(0);
        for _ in 0..100 {
            let v = size(&mut rng, 3, 9);
            assert!((3..=9).contains(&v));
        }
    }
}
