//! FFT / DCT substrate — the paper's O(n² log n) fast path.
//!
//! * [`complex`] — iterative radix-2 Cooley–Tukey + Bluestein chirp-z for
//!   arbitrary lengths (the DCT side must work for any `d_model`); plans
//!   carry their own scratch and never allocate after construction.
//! * [`dct`]     — DCT-II/III orthogonal matrices per Appendix A, with a
//!   per-order [`cached_dct2_matrix`] so replicas share one `Arc<Matrix>`.
//! * [`makhoul`] — Makhoul's N-point fast DCT-II (Appendix D): permute →
//!   real-input FFT (N/2-point complex for even N, split butterfly) →
//!   multiply by `W_k = exp(-iπk/2N)` → real part → orthonormal scale.
//!   [`cached_plan`] memoizes one plan per length.
//!
//! `makhoul::dct2_rows(G)` is bit-for-bit checked against `G · dct::dct2(C)`
//! in tests and raced against blocked matmul in `bench_makhoul` (Tables 4–5).
//!
//! Both process caches report hit/build counts through `obs`
//! (`fft_plan_*` / `dct2_cache_*`), so a run can show whether plan reuse
//! actually happens (it should: hits ≫ builds after the first step).

pub mod complex;
pub mod dct;
pub mod makhoul;

pub use complex::{fft_inplace, Complex};
pub use dct::{cached_dct2_matrix, dct2_matrix, dct3_matrix};
pub use makhoul::{cached_plan, dct2_rows, MakhoulPlan};
