//! Makhoul's N-point fast DCT-II (Appendix D), real-input edition.
//!
//! For each row `x` of the input matrix:
//!   1. permute: `[a,b,c,d,e,f] → [a,c,e,f,d,b]` (evens ascending, odds
//!      descending, cached per length),
//!   2. DFT of the permuted *real* signal — for even `N` the N real samples
//!      pack into an N/2-point **complex** FFT (`z_j = v_{2j} + i·v_{2j+1}`)
//!      and the full spectrum is reconstructed with one split butterfly:
//!      half the flops and half the memory traffic of the old N-point
//!      complex transform. Odd `N` falls back to the full complex path
//!      (Bluestein underneath).
//!   3. multiply by `W_k = exp(-iπk/2N)` (cached per length), take the real
//!      part, apply the orthonormal scaling (`sqrt(2/N)`, DC row `sqrt(1/N)`).
//!
//! Equivalent to `G · dct2_matrix(N)` at O(R·N log N) instead of O(R·N²) —
//! the object of Tables 4–5 and the Appendix C speedup claim.
//!
//! Plans hold a pool of complex scratch buffers (behind a `Mutex`-guarded
//! stack, so `run`/`run_into` work through `&self`/`Arc`): after warmup a
//! plan performs **zero heap allocations**, and [`cached_plan`] memoizes
//! plans per length so repeated `SharedDct`/`dct2_rows` construction
//! (tests, experiment sweeps) stops rebuilding twiddles from scratch.
//!
//! [`MakhoulPlan::run_into_on`] executes the row transforms in parallel:
//! rows are independent and written to disjoint output slabs, so the
//! parallel path is bit-identical to sequential for any thread count; each
//! chunk pops its own [`Scratch`] from the pool (the pool's high-water mark
//! is the peak chunk concurrency, reached during warmup).
//!
//! The split butterfly and the `W_k`-twiddle/scale output loops run through
//! the [`crate::simd`] complex-pair lane layer (two `k` per step, reversed
//! loads for the conjugate-symmetric operands); every backend and
//! `FFT_SUBSPACE_SIMD=0` produce the same bits — see `crate::simd` for the
//! contract.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::parallel::{par_row_slabs, SendPtr, ThreadPool};
use crate::simd::{Simd, C64_LANES};
use crate::tensor::Matrix;

use super::complex::{Complex, FftPlan};

/// Real-input split path for even lengths: an N/2-point complex plan plus
/// the split twiddles `t_k = exp(-2πik/N)`.
struct SplitPlan {
    half: FftPlan,
    twiddle: Vec<Complex>, // k in 0..N/2
}

/// Per-call scratch: `z` holds the packed (even) or full (odd) complex
/// signal, `v` the reconstructed half-spectrum `V[0..=N/2]`. Pooled per
/// plan; concurrent row chunks each pop their own.
struct Scratch {
    z: Vec<Complex>,
    v: Vec<Complex>,
}

impl Scratch {
    fn new(n: usize) -> Scratch {
        Scratch {
            z: vec![Complex::ZERO; if n % 2 == 0 { n / 2 } else { n }],
            v: vec![Complex::ZERO; n / 2 + 1],
        }
    }
}

/// Reusable plan: permutation, twiddle multipliers, FFT plan and scratch are
/// all computed once per length (the paper: "computed once at the start of
/// training").
pub struct MakhoulPlan {
    pub n: usize,
    perm: Vec<usize>,
    w: Vec<Complex>,
    scale: Vec<f64>,
    /// Even lengths: real-input half-size path. Odd lengths: `None`.
    split: Option<SplitPlan>,
    /// Full-length complex plan — the odd-length hot path. `None` for even
    /// lengths, where production runs never need it.
    full: Option<FftPlan>,
    /// Lazily-built full-length plan for the test/bench-only
    /// [`MakhoulPlan::run_full_complex`] reference on even lengths — keeps
    /// production plans free of a second Bluestein embedding while keeping
    /// repeated reference runs (benchmarks!) free of per-call plan builds.
    reference: OnceLock<FftPlan>,
    scratch: Mutex<Vec<Scratch>>,
}

impl MakhoulPlan {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        // evens ascending then odds descending
        let mut perm = Vec::with_capacity(n);
        perm.extend((0..n).step_by(2));
        let mut odds: Vec<usize> = (1..n).step_by(2).collect();
        odds.reverse();
        perm.extend(odds);
        let w = (0..n)
            .map(|k| {
                Complex::from_polar(
                    1.0,
                    -std::f64::consts::PI * k as f64 / (2.0 * n as f64),
                )
            })
            .collect();
        let base = (2.0 / n as f64).sqrt();
        let mut scale = vec![base; n];
        scale[0] = (1.0 / n as f64).sqrt();
        let split = if n % 2 == 0 {
            let h = n / 2;
            let twiddle = (0..h)
                .map(|k| {
                    Complex::from_polar(
                        1.0,
                        -2.0 * std::f64::consts::PI * k as f64 / n as f64,
                    )
                })
                .collect();
            Some(SplitPlan { half: FftPlan::new(h), twiddle })
        } else {
            None
        };
        let scratch = Mutex::new(vec![Scratch::new(n)]);
        let full = if split.is_none() { Some(FftPlan::new(n)) } else { None };
        MakhoulPlan { n, perm, w, scale, split, full, reference: OnceLock::new(), scratch }
    }

    /// DCT-II of one row via the real-input split butterfly (even `n`).
    fn run_row_split(&self, sp: &SplitPlan, sc: &mut Scratch, row: &[f32], out: &mut [f32]) {
        let n = self.n;
        let h = n / 2;
        // pack permuted real pairs into h complex samples (resize: the
        // full-complex reference path may have left `z` at length n)
        sc.z.clear();
        sc.z.resize(h, Complex::ZERO);
        for j in 0..h {
            sc.z[j] = Complex::new(
                row[self.perm[2 * j]] as f64,
                row[self.perm[2 * j + 1]] as f64,
            );
        }
        sp.half.forward(&mut sc.z);
        // Split butterfly: with E/O the DFTs of the even/odd-indexed
        // samples, Z[k] = E[k] + i·O[k] and conj(Z[-k]) = E[k] − i·O[k], so
        //   V[k]   = E[k] + t_k·O[k]      (k < h)
        //   V[h]   = E[0] − O[0]
        split_butterfly(&sc.z, &sp.twiddle, &mut sc.v[..h]);
        {
            let z0 = sc.z[0];
            // E[0] = Re(Z[0]), O[0] = Im(Z[0])
            sc.v[h] = Complex::new(z0.re - z0.im, 0.0);
        }
        // real part of V[k]·W[k]; upper half via conjugate symmetry
        twiddle_scale_forward(&sc.v[..h + 1], &self.w, &self.scale, out, h + 1);
        twiddle_scale_mirror(&sc.v, &self.w, &self.scale, out, h + 1);
    }

    /// DCT-II of one row via the full N-point complex FFT (odd lengths and
    /// the reference path for tests/benches).
    fn run_row_full(&self, fft: &FftPlan, sc: &mut Scratch, row: &[f32], out: &mut [f32]) {
        sc.z.clear();
        sc.z
            .extend(self.perm.iter().map(|&p| Complex::new(row[p] as f64, 0.0)));
        fft.forward(&mut sc.z);
        twiddle_scale_forward(&sc.z, &self.w, &self.scale, out, self.n);
    }

    /// DCT-II of one row through whichever path the plan carries.
    fn run_row(&self, sc: &mut Scratch, src: &[f32], dst: &mut [f32]) {
        match (&self.split, &self.full) {
            (Some(sp), _) => self.run_row_split(sp, sc, src, dst),
            (None, Some(fft)) => self.run_row_full(fft, sc, src, dst),
            (None, None) => unreachable!("plan has neither split nor full path"),
        }
    }

    /// Pop a scratch from the plan's pool, creating one only when more
    /// callers than ever before run concurrently.
    fn take_scratch(&self) -> Scratch {
        self.scratch
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_else(|| Scratch::new(self.n))
    }

    fn put_scratch(&self, sc: Scratch) {
        self.scratch.lock().unwrap().push(sc);
    }

    /// Row-wise DCT-II of a matrix (the `S = Makhoul(B)` of Algorithm 1).
    pub fn run(&self, g: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(g.rows, g.cols);
        self.run_into(g, &mut out);
        out
    }

    /// Allocation-free [`MakhoulPlan::run`]: writes into `out` (resized in
    /// place) using only plan-owned scratch.
    pub fn run_into(&self, g: &Matrix, out: &mut Matrix) {
        assert_eq!(g.cols, self.n);
        out.resize_for_overwrite(g.rows, g.cols);
        let mut sc = self.take_scratch();
        for i in 0..g.rows {
            let src = g.row(i);
            let dst = &mut out.data[i * g.cols..(i + 1) * g.cols];
            self.run_row(&mut sc, src, dst);
        }
        self.put_scratch(sc);
    }

    /// Row-parallel [`MakhoulPlan::run_into`]: rows are partitioned into
    /// contiguous chunks across the pool, each chunk transforming into its
    /// disjoint output slab with its own pooled scratch. Rows are
    /// independent, so the result is bit-identical to sequential for any
    /// thread count; allocation-free once the scratch pool has seen the
    /// peak chunk concurrency.
    pub fn run_into_on(&self, pool: &ThreadPool, g: &Matrix, out: &mut Matrix) {
        assert_eq!(g.cols, self.n);
        out.resize_for_overwrite(g.rows, g.cols);
        let cols = self.n;
        par_row_slabs(pool, g.rows, cols, &mut out.data, |slab, lo, hi| {
            let mut sc = self.take_scratch();
            for i in lo..hi {
                let dst = &mut slab[(i - lo) * cols..(i - lo + 1) * cols];
                self.run_row(&mut sc, g.row(i), dst);
            }
            self.put_scratch(sc);
        });
    }

    /// Group-batched [`MakhoulPlan::run_into_on`]: `dsts.len()` independent
    /// row-wise transforms of `rows_per_job` rows each (all width `self.n`),
    /// stacked into **one** pool dispatch partitioned over the concatenated
    /// rows — the fused step plans' refresh pass. Job `l` reads `src(l)` and
    /// writes through `dsts[l]` (a writable `rows_per_job × n` slab; slabs
    /// must be mutually disjoint). Each row transform is independent and
    /// fully overwrites its output row, so any chunking of the flattened row
    /// space is bit-identical to per-job [`MakhoulPlan::run_into`] calls.
    pub fn run_rows_batched_on<'a>(
        &self,
        pool: &ThreadPool,
        rows_per_job: usize,
        src: &(impl Fn(usize) -> &'a Matrix + Sync),
        dsts: &[SendPtr<f32>],
    ) {
        let n = self.n;
        let total = dsts.len() * rows_per_job;
        if total == 0 {
            return;
        }
        let (per, n_chunks) = crate::parallel::partition(pool.threads(), total);
        pool.par_chunks(n_chunks, |c| {
            let lo = c * per;
            let hi = (lo + per).min(total);
            let mut sc = self.take_scratch();
            for f in lo..hi {
                let l = f / rows_per_job;
                let i = f % rows_per_job;
                let g = src(l);
                debug_assert_eq!(g.cols, n);
                debug_assert_eq!(g.rows, rows_per_job);
                // SAFETY: row i of job l's slab — chunks cover disjoint
                // ranges of the flattened row space and slabs are disjoint
                // per the caller contract, so no two chunks alias.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(dsts[l].0.add(i * n), n)
                };
                self.run_row(&mut sc, g.row(i), dst);
            }
            self.put_scratch(sc);
        });
    }

    /// Reference transform through the full complex FFT regardless of
    /// parity — used by property tests and `bench_makhoul` to race the
    /// real-input path against the pre-split implementation.
    pub fn run_full_complex(&self, g: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(g.rows, g.cols);
        self.run_full_complex_into(g, &mut out);
        out
    }

    /// Allocation-free [`MakhoulPlan::run_full_complex`] (after the lazy
    /// reference plan initializes) — lets benches compare the two FFT paths
    /// without per-iteration output allocation skewing the ratio.
    pub fn run_full_complex_into(&self, g: &Matrix, out: &mut Matrix) {
        assert_eq!(g.cols, self.n);
        out.resize_for_overwrite(g.rows, g.cols);
        // Even-length plans don't carry a full-length FFT; build one lazily
        // on first reference run so repeated runs (benches) only time the
        // transform itself.
        let fft = match &self.full {
            Some(f) => f,
            None => self.reference.get_or_init(|| FftPlan::new(self.n)),
        };
        let mut sc = self.take_scratch();
        for i in 0..g.rows {
            let src = g.row(i);
            let dst = &mut out.data[i * g.cols..(i + 1) * g.cols];
            self.run_row_full(fft, &mut sc, src, dst);
        }
        self.put_scratch(sc);
    }
}

// ---- SIMD kernels (see `crate::simd` for the bit-identity contract) ----

/// The split butterfly `V[k] = E[k] + t_k·O[k]` for `k in 0..h`, two `k`
/// per lane step. The conjugate-symmetric operand `Z[-k]` is a reversed
/// contiguous load (`swap_pairs` of `z[h−k−1..]`); every per-element op —
/// `(z_k + z̄_{−k})·0.5`, `(z_k − z̄_{−k})·(−i/2)`, `e + t·o` — is the exact
/// `Complex` method sequence, repeated verbatim in the `k = 0` / remainder
/// scalar path.
#[inline(always)]
fn split_butterfly_g<S: Simd>(z: &[Complex], tw: &[Complex], v: &mut [Complex]) {
    let h = v.len();
    debug_assert!(z.len() >= h && tw.len() >= h);
    let neg_half_i = Complex::new(0.0, -0.5);
    {
        // k = 0: the conjugate partner is z[0] itself
        let zk = z[0];
        let zc = z[0].conj();
        let e = zk.add(zc).scale(0.5);
        let o = zk.sub(zc).mul(neg_half_i);
        v[0] = e.add(tw[0].mul(o));
    }
    let half = S::splat64(0.5);
    let oc = S::splatc(neg_half_i);
    let mut k = 1;
    while k + C64_LANES <= h {
        let zk = S::loadc(&z[k..]);
        // [z[h−k−1], z[h−k]] reversed → conj(Z[−k]) for lanes k, k+1
        let zc = S::conjc(S::swap_pairs(S::loadc(&z[h - k - 1..])));
        let e = S::mul64(S::add64(zk, zc), half);
        let o = S::cmul(S::sub64(zk, zc), oc);
        let res = S::add64(e, S::cmul(S::loadc(&tw[k..]), o));
        S::storec(&mut v[k..], res);
        k += C64_LANES;
    }
    while k < h {
        let zk = z[k];
        let zc = z[h - k].conj();
        let e = zk.add(zc).scale(0.5);
        let o = zk.sub(zc).mul(neg_half_i);
        v[k] = e.add(tw[k].mul(o));
        k += 1;
    }
}

crate::simd_dispatch! {
    fn split_butterfly(z: &[Complex], tw: &[Complex], v: &mut [Complex]) = split_butterfly_g
}

/// `out[k] = (v[k]·w[k]).re · scale[k]` as f32 for `k in 0..count`: the
/// complex product runs in lanes, the real-part extract + f64 scale + f32
/// round run in shared scalar code on the lane array (identical bits on
/// every backend; the discarded im lanes cost nothing correctness-wise).
#[inline(always)]
fn twiddle_scale_forward_g<S: Simd>(
    v: &[Complex],
    w: &[Complex],
    scale: &[f64],
    out: &mut [f32],
    count: usize,
) {
    debug_assert!(v.len() >= count && w.len() >= count && scale.len() >= count);
    let mut k = 0;
    while k + C64_LANES <= count {
        let m = S::to_array64(S::cmul(S::loadc(&v[k..]), S::loadc(&w[k..])));
        out[k] = (m[0] * scale[k]) as f32;
        out[k + 1] = (m[2] * scale[k + 1]) as f32;
        k += C64_LANES;
    }
    while k < count {
        out[k] = (v[k].mul(w[k]).re * scale[k]) as f32;
        k += 1;
    }
}

crate::simd_dispatch! {
    fn twiddle_scale_forward(
        v: &[Complex], w: &[Complex], scale: &[f64], out: &mut [f32], count: usize
    ) = twiddle_scale_forward_g
}

/// The conjugate-symmetry upper half `out[k] = (conj(v[n−k])·w[k]).re ·
/// scale[k]` for `k in start..out.len()` — reversed loads of `v` against
/// forward loads of `w`/`scale`, same finish as
/// [`twiddle_scale_forward_g`].
#[inline(always)]
fn twiddle_scale_mirror_g<S: Simd>(
    v: &[Complex],
    w: &[Complex],
    scale: &[f64],
    out: &mut [f32],
    start: usize,
) {
    let n = out.len();
    debug_assert!(w.len() >= n && scale.len() >= n);
    let mut k = start;
    while k + C64_LANES <= n {
        // [v[n−k−1], v[n−k]] reversed → v[n−k], v[n−(k+1)] for lanes k, k+1
        let vv = S::conjc(S::swap_pairs(S::loadc(&v[n - k - 1..])));
        let m = S::to_array64(S::cmul(vv, S::loadc(&w[k..])));
        out[k] = (m[0] * scale[k]) as f32;
        out[k + 1] = (m[2] * scale[k + 1]) as f32;
        k += C64_LANES;
    }
    while k < n {
        out[k] = (v[n - k].conj().mul(w[k]).re * scale[k]) as f32;
        k += 1;
    }
}

crate::simd_dispatch! {
    fn twiddle_scale_mirror(
        v: &[Complex], w: &[Complex], scale: &[f64], out: &mut [f32], start: usize
    ) = twiddle_scale_mirror_g
}

/// Process-wide plan cache: one immutable plan per length, shared by every
/// `SharedDct` replica and every one-shot [`dct2_rows`] call. Plans are
/// small (twiddles + scratch) and **intentionally retained for the process
/// lifetime** — the paper's "computed once at the start of training" taken
/// literally. There is no eviction: a sweep over many distinct widths keeps
/// one plan per width resident (O(n) each), which is the trade accepted for
/// never rebuilding twiddles; this transient memory is not part of
/// `MemoryReport` (persistent optimizer state only).
static PLAN_CACHE: Mutex<BTreeMap<usize, Arc<MakhoulPlan>>> = Mutex::new(BTreeMap::new());

pub fn cached_plan(n: usize) -> Arc<MakhoulPlan> {
    let mut cache = PLAN_CACHE.lock().unwrap();
    crate::obs::count_fft_plan(cache.contains_key(&n));
    cache
        .entry(n)
        .or_insert_with(|| Arc::new(MakhoulPlan::new(n)))
        .clone()
}

/// One-shot row-wise fast DCT-II (plan-cached; repeated calls at the same
/// width reuse twiddles and scratch).
pub fn dct2_rows(g: &Matrix) -> Matrix {
    cached_plan(g.cols).run(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dct::dct2_matrix;
    use crate::tensor::{matmul, Matrix};
    use crate::util::{proptest, Pcg64};

    #[test]
    fn permutation_matches_paper_example() {
        let plan = MakhoulPlan::new(6);
        // [a,b,c,d,e,f] -> [a,c,e,f,d,b] == indices [0,2,4,5,3,1]
        assert_eq!(plan.perm, vec![0, 2, 4, 5, 3, 1]);
    }

    #[test]
    fn permutation_odd_length() {
        let plan = MakhoulPlan::new(5);
        assert_eq!(plan.perm, vec![0, 2, 4, 3, 1]);
    }

    #[test]
    fn matches_matmul_dct_pow2() {
        let mut rng = Pcg64::seed(0);
        for n in [2usize, 8, 64, 128] {
            let g = Matrix::randn(10, n, 1.0, &mut rng);
            let want = matmul(&g, &dct2_matrix(n));
            let got = dct2_rows(&g);
            let err = want.max_abs_diff(&got);
            assert!(err < 1e-4, "n={n} err={err}");
        }
    }

    #[test]
    fn matches_matmul_dct_arbitrary() {
        let mut rng = Pcg64::seed(1);
        for n in [3usize, 5, 6, 7, 12, 17, 96, 100, 257] {
            let g = Matrix::randn(6, n, 1.0, &mut rng);
            let want = matmul(&g, &dct2_matrix(n));
            let got = dct2_rows(&g);
            let err = want.max_abs_diff(&got);
            assert!(err < 1e-4, "n={n} err={err}");
        }
    }

    #[test]
    fn prop_makhoul_equals_matmul() {
        proptest::check("makhoul==matmul", 10, |rng| {
            let r = proptest::size(rng, 1, 24);
            let c = proptest::size(rng, 2, 80);
            let g = Matrix::randn(r, c, 1.0, rng);
            let want = matmul(&g, &dct2_matrix(c));
            let got = dct2_rows(&g);
            assert!(want.max_abs_diff(&got) < 1e-4);
        });
    }

    #[test]
    fn prop_split_path_matches_full_complex_reference() {
        // The real-input split butterfly against the N-point complex FFT it
        // replaced — both in f64 internally, so they agree far below the
        // f32 output resolution.
        proptest::check("real-split==full-complex", 10, |rng| {
            let r = proptest::size(rng, 1, 8);
            let c = 2 * proptest::size(rng, 1, 64); // even widths
            let g = Matrix::randn(r, c, 1.0, rng);
            let plan = MakhoulPlan::new(c);
            let split = plan.run(&g);
            let full = plan.run_full_complex(&g);
            assert!(
                split.max_abs_diff(&full) < 1e-5,
                "c={c} diff={}",
                split.max_abs_diff(&full)
            );
        });
    }

    #[test]
    fn run_into_matches_run_with_dirty_buffer() {
        let mut rng = Pcg64::seed(9);
        for n in [6usize, 12, 40, 63] {
            let plan = MakhoulPlan::new(n);
            let g = Matrix::randn(5, n, 1.0, &mut rng);
            let mut out = Matrix::randn(2, 3, 1.0, &mut rng); // wrong shape, dirty
            plan.run_into(&g, &mut out);
            assert_eq!(out, plan.run(&g), "n={n}");
        }
    }

    #[test]
    fn run_into_on_bit_identical_any_thread_count() {
        // Even (split), odd (Bluestein), and pow2 widths; pools 1..8.
        let mut rng = Pcg64::seed(13);
        let pools = [
            crate::parallel::ThreadPool::new(1),
            crate::parallel::ThreadPool::new(3),
            crate::parallel::ThreadPool::new(8),
        ];
        for n in [6usize, 17, 24, 64] {
            let plan = MakhoulPlan::new(n);
            let g = Matrix::randn(11, n, 1.0, &mut rng);
            let mut want = Matrix::zeros(1, 1);
            plan.run_into(&g, &mut want);
            for pool in &pools {
                let mut got = Matrix::randn(2, 2, 1.0, &mut rng); // dirty
                plan.run_into_on(pool, &g, &mut got);
                assert_eq!(got, want, "n={n} threads={}", pool.threads());
            }
        }
    }

    #[test]
    fn rows_batched_bit_identical_to_per_job() {
        // The stacked group dispatch must reproduce the exact bits of
        // per-job run_into calls for every thread count and chunking.
        let mut rng = Pcg64::seed(21);
        let pools = [
            crate::parallel::ThreadPool::new(1),
            crate::parallel::ThreadPool::new(3),
            crate::parallel::ThreadPool::new(8),
        ];
        for n in [6usize, 17, 64] {
            let plan = MakhoulPlan::new(n);
            let jobs: Vec<Matrix> =
                (0..5).map(|_| Matrix::randn(7, n, 1.0, &mut rng)).collect();
            let want: Vec<Matrix> = jobs.iter().map(|g| plan.run(g)).collect();
            let mut outs: Vec<Matrix> =
                (0..5).map(|_| Matrix::randn(7, n, 1.0, &mut rng)).collect(); // dirty
            for pool in &pools {
                let dsts: Vec<SendPtr<f32>> =
                    outs.iter_mut().map(|o| SendPtr(o.data.as_mut_ptr())).collect();
                plan.run_rows_batched_on(pool, 7, &|l| &jobs[l], &dsts);
                for (o, w) in outs.iter().zip(&want) {
                    assert_eq!(o, w, "n={n} threads={}", pool.threads());
                }
            }
        }
    }

    #[test]
    fn plan_reuse_is_consistent() {
        let mut rng = Pcg64::seed(2);
        let plan = MakhoulPlan::new(40);
        let a = Matrix::randn(4, 40, 1.0, &mut rng);
        let b = Matrix::randn(4, 40, 1.0, &mut rng);
        let got_a1 = plan.run(&a);
        let _ = plan.run(&b);
        let got_a2 = plan.run(&a);
        assert_eq!(got_a1, got_a2);
    }

    #[test]
    fn cached_plan_is_shared_per_length() {
        let p1 = cached_plan(48);
        let p2 = cached_plan(48);
        assert!(Arc::ptr_eq(&p1, &p2));
        let p3 = cached_plan(49);
        assert!(!Arc::ptr_eq(&p1, &p3));
    }

    #[test]
    fn energy_preserved() {
        let mut rng = Pcg64::seed(3);
        let g = Matrix::randn(7, 33, 1.0, &mut rng);
        let s = dct2_rows(&g);
        let rel = (s.fro_norm() - g.fro_norm()).abs() / g.fro_norm();
        assert!(rel < 1e-6, "rel={rel}");
    }

    #[test]
    fn energy_preserved_even_split_path() {
        let mut rng = Pcg64::seed(4);
        let g = Matrix::randn(7, 34, 1.0, &mut rng);
        let s = dct2_rows(&g);
        let rel = (s.fro_norm() - g.fro_norm()).abs() / g.fro_norm();
        assert!(rel < 1e-6, "rel={rel}");
    }
}
