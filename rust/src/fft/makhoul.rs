//! Makhoul's N-point fast DCT-II (Appendix D).
//!
//! For each row `x` of the input matrix:
//!   1. permute: `[a,b,c,d,e,f] → [a,c,e,f,d,b]` (evens ascending, odds
//!      descending, cached per length),
//!   2. FFT of the permuted signal,
//!   3. multiply by `W_k = exp(-iπk/2N)` (cached per length),
//!   4. real part + orthonormal scaling (`sqrt(2/N)`, DC row `sqrt(1/N)`).
//!
//! Equivalent to `G · dct2_matrix(N)` at O(R·N log N) instead of O(R·N²) —
//! the object of Tables 4–5 and the Appendix C speedup claim.

use crate::tensor::Matrix;

use super::complex::{Complex, FftPlan};

/// Reusable plan: permutation, twiddle multipliers and the FFT plan are all
/// computed once per length (the paper: "computed once at the start of
/// training").
pub struct MakhoulPlan {
    pub n: usize,
    perm: Vec<usize>,
    w: Vec<Complex>,
    scale: Vec<f64>,
    fft: FftPlan,
}

impl MakhoulPlan {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        // evens ascending then odds descending
        let mut perm = Vec::with_capacity(n);
        perm.extend((0..n).step_by(2));
        let mut odds: Vec<usize> = (1..n).step_by(2).collect();
        odds.reverse();
        perm.extend(odds);
        let w = (0..n)
            .map(|k| {
                Complex::from_polar(
                    1.0,
                    -std::f64::consts::PI * k as f64 / (2.0 * n as f64),
                )
            })
            .collect();
        let base = (2.0 / n as f64).sqrt();
        let mut scale = vec![base; n];
        scale[0] = (1.0 / n as f64).sqrt();
        MakhoulPlan { n, perm, w, scale, fft: FftPlan::new(n) }
    }

    /// DCT-II of one row into `out` (both length `n`), using `buf` as the
    /// complex workspace.
    pub fn run_row(&self, row: &[f32], out: &mut [f32], buf: &mut Vec<Complex>) {
        debug_assert_eq!(row.len(), self.n);
        buf.clear();
        buf.extend(self.perm.iter().map(|&p| Complex::new(row[p] as f64, 0.0)));
        self.fft.forward(buf);
        for k in 0..self.n {
            out[k] = (buf[k].mul(self.w[k]).re * self.scale[k]) as f32;
        }
    }

    /// Row-wise DCT-II of a matrix (the `S = Makhoul(B)` of Algorithm 1).
    pub fn run(&self, g: &Matrix) -> Matrix {
        assert_eq!(g.cols, self.n);
        let mut out = Matrix::zeros(g.rows, g.cols);
        let mut buf = Vec::with_capacity(self.n);
        for i in 0..g.rows {
            let (src, dst) = (g.row(i), i);
            // split borrow: copy row out via raw index range
            let dst_slice =
                &mut out.data[dst * g.cols..(dst + 1) * g.cols];
            self.run_row(src, dst_slice, &mut buf);
        }
        out
    }
}

/// One-shot row-wise fast DCT-II.
pub fn dct2_rows(g: &Matrix) -> Matrix {
    MakhoulPlan::new(g.cols).run(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dct::dct2_matrix;
    use crate::tensor::matmul;
    use crate::util::{proptest, Pcg64};

    #[test]
    fn permutation_matches_paper_example() {
        let plan = MakhoulPlan::new(6);
        // [a,b,c,d,e,f] -> [a,c,e,f,d,b] == indices [0,2,4,5,3,1]
        assert_eq!(plan.perm, vec![0, 2, 4, 5, 3, 1]);
    }

    #[test]
    fn permutation_odd_length() {
        let plan = MakhoulPlan::new(5);
        assert_eq!(plan.perm, vec![0, 2, 4, 3, 1]);
    }

    #[test]
    fn matches_matmul_dct_pow2() {
        let mut rng = Pcg64::seed(0);
        for n in [2usize, 8, 64, 128] {
            let g = Matrix::randn(10, n, 1.0, &mut rng);
            let want = matmul(&g, &dct2_matrix(n));
            let got = dct2_rows(&g);
            let err = want.max_abs_diff(&got);
            assert!(err < 1e-4, "n={n} err={err}");
        }
    }

    #[test]
    fn matches_matmul_dct_arbitrary() {
        let mut rng = Pcg64::seed(1);
        for n in [3usize, 5, 7, 12, 17, 96, 100, 257] {
            let g = Matrix::randn(6, n, 1.0, &mut rng);
            let want = matmul(&g, &dct2_matrix(n));
            let got = dct2_rows(&g);
            let err = want.max_abs_diff(&got);
            assert!(err < 1e-4, "n={n} err={err}");
        }
    }

    #[test]
    fn prop_makhoul_equals_matmul() {
        proptest::check("makhoul==matmul", 10, |rng| {
            let r = proptest::size(rng, 1, 24);
            let c = proptest::size(rng, 2, 80);
            let g = Matrix::randn(r, c, 1.0, rng);
            let want = matmul(&g, &dct2_matrix(c));
            let got = dct2_rows(&g);
            assert!(want.max_abs_diff(&got) < 1e-4);
        });
    }

    #[test]
    fn plan_reuse_is_consistent() {
        let mut rng = Pcg64::seed(2);
        let plan = MakhoulPlan::new(40);
        let a = Matrix::randn(4, 40, 1.0, &mut rng);
        let b = Matrix::randn(4, 40, 1.0, &mut rng);
        let got_a1 = plan.run(&a);
        let _ = plan.run(&b);
        let got_a2 = plan.run(&a);
        assert_eq!(got_a1, got_a2);
    }

    #[test]
    fn energy_preserved() {
        let mut rng = Pcg64::seed(3);
        let g = Matrix::randn(7, 33, 1.0, &mut rng);
        let s = dct2_rows(&g);
        let rel = (s.fro_norm() - g.fro_norm()).abs() / g.fro_norm();
        assert!(rel < 1e-6, "rel={rel}");
    }
}
