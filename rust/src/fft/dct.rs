//! DCT-II / DCT-III orthogonal matrices (Appendix A).
//!
//! `dct3_matrix(n)[i][j] = sqrt(2/n) · cos(i(2j+1)π / 2n)` with the first
//! row divided by `sqrt(2)`; DCT-II is its transpose. One `d×d` instance is
//! materialized per device replica at training start (the paper's memory
//! story: one matrix per GPU for the whole network + r indices per layer).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::tensor::Matrix;

/// Orthogonal DCT-III matrix of order `n`.
pub fn dct3_matrix(n: usize) -> Matrix {
    let inv_sqrt2 = 1.0 / (2.0f64).sqrt();
    let scale = (2.0 / n as f64).sqrt();
    Matrix::from_fn(n, n, |i, j| {
        let ang = (i as f64) * (2.0 * j as f64 + 1.0) * std::f64::consts::PI
            / (2.0 * n as f64);
        let mut v = scale * ang.cos();
        if i == 0 {
            v *= inv_sqrt2;
        }
        v as f32
    })
}

/// Orthogonal DCT-II matrix of order `n` (= DCT-IIIᵀ). This is the `Q` in
/// `S = G·Q`: column `k` is the k-th cosine basis vector, so `S` is the
/// row-wise type-II DCT of `G`.
pub fn dct2_matrix(n: usize) -> Matrix {
    dct3_matrix(n).transpose()
}

/// Process-wide cache of DCT-II matrices: `SharedDct::new` is called per
/// optimizer construction (experiment sweeps build hundreds), and the
/// matrix is immutable — one `Arc<Matrix>` per order is the "one matrix per
/// device" of the paper's memory story, taken literally. Like the Makhoul
/// plan cache, entries are **retained for the process lifetime** (no
/// eviction): each distinct order keeps a C×C f32 matrix resident (~16 MB
/// at C=2048), the accepted trade for never recomputing the basis.
static DCT2_CACHE: Mutex<BTreeMap<usize, Arc<Matrix>>> = Mutex::new(BTreeMap::new());

pub fn cached_dct2_matrix(n: usize) -> Arc<Matrix> {
    let mut cache = DCT2_CACHE.lock().unwrap();
    crate::obs::count_dct2_cache(cache.contains_key(&n));
    cache
        .entry(n)
        .or_insert_with(|| Arc::new(dct2_matrix(n)))
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::proptest;

    #[test]
    fn orthogonality_various_orders() {
        for n in [2usize, 3, 5, 8, 17, 64, 96, 128] {
            let q = dct2_matrix(n);
            let gram = matmul(&q.transpose(), &q);
            let err = gram.max_abs_diff(&Matrix::eye(n));
            assert!(err < 2e-5, "n={n} err={err}");
        }
    }

    #[test]
    fn closed_form_entries() {
        let n = 16;
        let d = dct3_matrix(n);
        for i in 0..n {
            for j in 0..n {
                let mut want = (2.0 / n as f64).sqrt()
                    * ((i as f64) * (2.0 * j as f64 + 1.0) * std::f64::consts::PI
                        / (2.0 * n as f64))
                        .cos();
                if i == 0 {
                    want /= (2.0f64).sqrt();
                }
                assert!((d.at(i, j) as f64 - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn dct2_is_transpose_of_dct3() {
        let a = dct2_matrix(20);
        let b = dct3_matrix(20).transpose();
        assert_eq!(a, b);
    }

    #[test]
    fn prop_projection_preserves_energy() {
        // ‖G·Q‖F == ‖G‖F for orthogonal Q (basis change preserves energy).
        proptest::check("dct-energy", 8, |rng| {
            let m = proptest::size(rng, 1, 32);
            let n = proptest::size(rng, 2, 48);
            let g = Matrix::randn(m, n, 1.0, rng);
            let q = dct2_matrix(n);
            let s = matmul(&g, &q);
            let rel = (s.fro_norm() - g.fro_norm()).abs() / g.fro_norm().max(1e-9);
            assert!(rel < 1e-5, "rel={rel}");
        });
    }

    #[test]
    fn cached_matrix_matches_fresh_and_is_shared() {
        let fresh = dct2_matrix(24);
        let c1 = cached_dct2_matrix(24);
        let c2 = cached_dct2_matrix(24);
        assert_eq!(*c1, fresh);
        assert!(Arc::ptr_eq(&c1, &c2));
    }

    #[test]
    fn first_column_is_constant_vector() {
        // Column 0 of DCT-II (row 0 of DCT-III) is the normalized DC basis.
        let q = dct2_matrix(9);
        let want = 1.0 / 3.0; // sqrt(1/9)
        for i in 0..9 {
            assert!((q.at(i, 0) - want).abs() < 1e-6);
        }
    }
}
