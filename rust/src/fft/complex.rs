//! Complex FFT: iterative radix-2 Cooley–Tukey with precomputed twiddles,
//! plus Bluestein's chirp-z algorithm so *any* length (odd `d_model`s
//! included) runs in O(n log n).
//!
//! Plans are allocation-free after construction: the Bluestein embedding
//! keeps a pool of padded work buffers inside the plan (a `Mutex`-guarded
//! stack keeps `forward` callable through `&self`/`Arc`). Concurrent
//! callers pop distinct buffers, so row-parallel Makhoul execution runs
//! Bluestein widths without serializing; the pool's high-water mark equals
//! the peak concurrency (one buffer per thread), reached during warmup.
//!
//! The butterfly and pointwise-product loops run through the
//! [`crate::simd`] complex-pair lane layer: radix-2 plans carry
//! **per-stage contiguous twiddle tables** (copied from the canonical
//! `exp(-2πik/n)` table, so the values are bit-identical to the strided
//! lookup they replace) and each butterfly/chirp product is the exact
//! `Complex::mul`/`add`/`sub` op sequence per pair — every backend and
//! `FFT_SUBSPACE_SIMD=0` return the same bits.

use std::sync::Mutex;

use crate::simd::{Simd, C64_LANES};

/// Minimal complex number (no `num-complex` offline). `#[repr(C)]` so a
/// `&[Complex]` is a valid interleaved `re,im,…` f64 buffer for the
/// [`crate::simd`] complex-pair lane ops.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
#[repr(C)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        Complex { re, im }
    }

    #[inline]
    pub fn from_polar(r: f64, theta: f64) -> Self {
        Complex { re: r * theta.cos(), im: r * theta.sin() }
    }

    #[inline]
    pub fn conj(self) -> Self {
        Complex { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn mul(self, o: Complex) -> Self {
        Complex {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }

    #[inline]
    pub fn add(self, o: Complex) -> Self {
        Complex { re: self.re + o.re, im: self.im + o.im }
    }

    #[inline]
    pub fn sub(self, o: Complex) -> Self {
        Complex { re: self.re - o.re, im: self.im - o.im }
    }

    #[inline]
    pub fn scale(self, s: f64) -> Self {
        Complex { re: self.re * s, im: self.im * s }
    }

    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }
}

/// Reusable FFT plan: twiddle factors for a fixed power-of-two length, or a
/// Bluestein embedding for non-power-of-two lengths.
pub struct FftPlan {
    pub n: usize,
    /// Radix-2 butterfly factors as **per-stage contiguous tables** (stage
    /// `s` holds the `2^(s+1)`-point butterfly's `2^s` factors
    /// `exp(-2πik·step/n)` back to back, copied from the canonical size-n/2
    /// table so the values are bit-identical to the strided lookup they
    /// replace). Unit-stride twiddle streams are what lets the SIMD
    /// butterfly run two complex pairs per step. Total size `n − 1`
    /// complex; empty for Bluestein lengths.
    stage_twiddles: Vec<Vec<Complex>>,
    bluestein: Option<Box<BluesteinPlan>>,
}

struct BluesteinPlan {
    m: usize,                 // padded power-of-two length ≥ 2n-1
    chirp: Vec<Complex>,      // a_k = exp(-iπk²/n)
    b_fft: Vec<Complex>,      // FFT of the chirp filter
    inner: FftPlan,           // radix-2 plan of length m
    scratch: Mutex<Vec<Vec<Complex>>>, // pool of padded work buffers
}

impl FftPlan {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        if n.is_power_of_two() {
            let twiddles: Vec<Complex> = (0..n / 2)
                .map(|k| {
                    Complex::from_polar(1.0, -2.0 * std::f64::consts::PI * k as f64 / n as f64)
                })
                .collect();
            // contiguous per-stage copies of the exact same values
            let mut stage_twiddles = Vec::new();
            let mut len = 2;
            while len <= n {
                let step = n / len;
                stage_twiddles
                    .push((0..len / 2).map(|k| twiddles[k * step]).collect());
                len <<= 1;
            }
            FftPlan { n, stage_twiddles, bluestein: None }
        } else {
            let m = (2 * n - 1).next_power_of_two();
            let inner = FftPlan::new(m);
            // chirp a_k = exp(-iπ k²/n); filter b_k = exp(+iπ k²/n)
            let chirp: Vec<Complex> = (0..n)
                .map(|k| {
                    let ang = -std::f64::consts::PI * ((k as u128 * k as u128) % (2 * n as u128)) as f64
                        / n as f64;
                    Complex::from_polar(1.0, ang)
                })
                .collect();
            let mut b = vec![Complex::ZERO; m];
            for k in 0..n {
                let v = chirp[k].conj();
                b[k] = v;
                if k != 0 {
                    b[m - k] = v;
                }
            }
            inner.forward(&mut b);
            FftPlan {
                n,
                stage_twiddles: Vec::new(),
                bluestein: Some(Box::new(BluesteinPlan {
                    m,
                    chirp,
                    b_fft: b,
                    inner,
                    scratch: Mutex::new(vec![vec![Complex::ZERO; m]]),
                })),
            }
        }
    }

    /// In-place forward DFT of `buf` (`buf.len() == self.n`).
    pub fn forward(&self, buf: &mut [Complex]) {
        assert_eq!(buf.len(), self.n);
        match &self.bluestein {
            None => self.radix2(buf),
            Some(bp) => self.bluestein_forward(bp, buf),
        }
    }

    fn radix2(&self, buf: &mut [Complex]) {
        let n = self.n;
        // bit-reversal permutation
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            if i < j {
                buf.swap(i, j);
            }
        }
        radix2_stages(buf, &self.stage_twiddles);
    }

    fn bluestein_forward(&self, bp: &BluesteinPlan, buf: &mut [Complex]) {
        let n = self.n;
        let m = bp.m;
        // Pop a padded buffer from the plan's pool (creating one only when
        // more threads than ever before run this plan concurrently), work
        // outside the lock, push it back.
        let mut a = bp.scratch.lock().unwrap().pop().unwrap_or_default();
        a.clear();
        a.resize(m, Complex::ZERO);
        cmul_pairs(&mut a[..n], buf, &bp.chirp);
        bp.inner.forward(&mut a);
        cmul_inplace(&mut a, &bp.b_fft);
        inverse_given_forward(&bp.inner, &mut a);
        cmul_pairs(buf, &a[..n], &bp.chirp);
        bp.scratch.lock().unwrap().push(a);
    }
}

/// Inverse DFT via conjugation: `ifft(x) = conj(fft(conj(x)))/n`.
fn inverse_given_forward(plan: &FftPlan, buf: &mut [Complex]) {
    conj_inplace(buf);
    plan.forward(buf);
    conj_scale_inplace(buf, 1.0 / plan.n as f64);
}

// ---- SIMD kernels (see `crate::simd` for the bit-identity contract) ----

/// All butterfly stages over a bit-reversed buffer; `stages[s]` is the
/// contiguous twiddle table of the `2^(s+1)`-point stage. Two pairs per
/// lane step; per-element op sequence is exactly `u ± hi·w` in
/// `Complex::mul`/`add`/`sub` order, identical in the scalar tail.
#[inline(always)]
fn radix2_stages_g<S: Simd>(buf: &mut [Complex], stages: &[Vec<Complex>]) {
    let n = buf.len();
    let mut len = 2;
    for tw in stages {
        let half = len / 2;
        for start in (0..n).step_by(len) {
            let (lo, hi) = buf[start..start + len].split_at_mut(half);
            let mut k = 0;
            while k + C64_LANES <= half {
                let u = S::loadc(&lo[k..]);
                let v = S::cmul(S::loadc(&hi[k..]), S::loadc(&tw[k..]));
                S::storec(&mut lo[k..], S::add64(u, v));
                S::storec(&mut hi[k..], S::sub64(u, v));
                k += C64_LANES;
            }
            while k < half {
                let u = lo[k];
                let v = hi[k].mul(tw[k]);
                lo[k] = u.add(v);
                hi[k] = u.sub(v);
                k += 1;
            }
        }
        len <<= 1;
    }
}

crate::simd_dispatch! {
    fn radix2_stages(buf: &mut [Complex], stages: &[Vec<Complex>]) = radix2_stages_g
}

/// `out[k] = x[k]·y[k]` (the Bluestein chirp modulation).
#[inline(always)]
fn cmul_pairs_g<S: Simd>(out: &mut [Complex], x: &[Complex], y: &[Complex]) {
    let n = out.len();
    debug_assert!(x.len() >= n && y.len() >= n);
    let mut k = 0;
    while k + C64_LANES <= n {
        S::storec(&mut out[k..], S::cmul(S::loadc(&x[k..]), S::loadc(&y[k..])));
        k += C64_LANES;
    }
    while k < n {
        out[k] = x[k].mul(y[k]);
        k += 1;
    }
}

crate::simd_dispatch! {
    fn cmul_pairs(out: &mut [Complex], x: &[Complex], y: &[Complex]) = cmul_pairs_g
}

/// `x[k] = x[k]·y[k]` (the Bluestein frequency-domain filter product).
#[inline(always)]
fn cmul_inplace_g<S: Simd>(x: &mut [Complex], y: &[Complex]) {
    let n = x.len();
    debug_assert!(y.len() >= n);
    let mut k = 0;
    while k + C64_LANES <= n {
        let prod = S::cmul(S::loadc(&x[k..]), S::loadc(&y[k..]));
        S::storec(&mut x[k..], prod);
        k += C64_LANES;
    }
    while k < n {
        x[k] = x[k].mul(y[k]);
        k += 1;
    }
}

crate::simd_dispatch! {
    fn cmul_inplace(x: &mut [Complex], y: &[Complex]) = cmul_inplace_g
}

/// `x[k] = conj(x[k])` — exact sign flips.
#[inline(always)]
fn conj_inplace_g<S: Simd>(x: &mut [Complex]) {
    let n = x.len();
    let mut k = 0;
    while k + C64_LANES <= n {
        let c = S::conjc(S::loadc(&x[k..]));
        S::storec(&mut x[k..], c);
        k += C64_LANES;
    }
    while k < n {
        x[k] = x[k].conj();
        k += 1;
    }
}

crate::simd_dispatch! {
    fn conj_inplace(x: &mut [Complex]) = conj_inplace_g
}

/// `x[k] = conj(x[k])·s` (the 1/n inverse normalization).
#[inline(always)]
fn conj_scale_inplace_g<S: Simd>(x: &mut [Complex], s: f64) {
    let n = x.len();
    let scale = S::splat64(s);
    let mut k = 0;
    while k + C64_LANES <= n {
        let c = S::mul64(S::conjc(S::loadc(&x[k..])), scale);
        S::storec(&mut x[k..], c);
        k += C64_LANES;
    }
    while k < n {
        x[k] = x[k].conj().scale(s);
        k += 1;
    }
}

crate::simd_dispatch! {
    fn conj_scale_inplace(x: &mut [Complex], s: f64) = conj_scale_inplace_g
}

/// One-shot forward FFT (plans a fresh transform; hot paths should hold a
/// [`FftPlan`] / [`super::MakhoulPlan`] instead).
pub fn fft_inplace(buf: &mut [Complex]) {
    FftPlan::new(buf.len()).forward(buf);
}

/// One-shot inverse FFT.
pub fn ifft_inplace(buf: &mut [Complex]) {
    let plan = FftPlan::new(buf.len());
    inverse_given_forward(&plan, buf);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg64};

    fn naive_dft(x: &[Complex]) -> Vec<Complex> {
        let n = x.len();
        (0..n)
            .map(|k| {
                let mut acc = Complex::ZERO;
                for (j, &v) in x.iter().enumerate() {
                    let ang = -2.0 * std::f64::consts::PI * (k * j) as f64 / n as f64;
                    acc = acc.add(v.mul(Complex::from_polar(1.0, ang)));
                }
                acc
            })
            .collect()
    }

    fn rand_signal(rng: &mut Pcg64, n: usize) -> Vec<Complex> {
        (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect()
    }

    #[test]
    fn fft_matches_naive_dft_pow2() {
        let mut rng = Pcg64::seed(0);
        for n in [1usize, 2, 4, 8, 64, 256] {
            let x = rand_signal(&mut rng, n);
            let want = naive_dft(&x);
            let mut got = x.clone();
            fft_inplace(&mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!(a.sub(*b).abs() < 1e-8 * (n as f64), "n={n}");
            }
        }
    }

    #[test]
    fn fft_matches_naive_dft_arbitrary_lengths() {
        let mut rng = Pcg64::seed(1);
        for n in [3usize, 5, 6, 7, 12, 17, 40, 96, 100, 257] {
            let x = rand_signal(&mut rng, n);
            let want = naive_dft(&x);
            let mut got = x.clone();
            fft_inplace(&mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!(a.sub(*b).abs() < 1e-7 * (n as f64), "n={n}");
            }
        }
    }

    #[test]
    fn prop_fft_ifft_roundtrip() {
        proptest::check("fft∘ifft=id", 16, |rng| {
            let n = proptest::size(rng, 1, 200);
            let x = rand_signal(rng, n);
            let mut y = x.clone();
            fft_inplace(&mut y);
            ifft_inplace(&mut y);
            for (a, b) in y.iter().zip(&x) {
                assert!(a.sub(*b).abs() < 1e-9 * (n as f64 + 1.0));
            }
        });
    }

    #[test]
    fn parseval_energy_preserved() {
        let mut rng = Pcg64::seed(2);
        let n = 128;
        let x = rand_signal(&mut rng, n);
        let ex: f64 = x.iter().map(|c| c.abs() * c.abs()).sum();
        let mut y = x;
        fft_inplace(&mut y);
        let ey: f64 = y.iter().map(|c| c.abs() * c.abs()).sum::<f64>() / n as f64;
        assert!((ex - ey).abs() < 1e-8 * ex);
    }

    #[test]
    fn impulse_has_flat_spectrum() {
        let mut x = vec![Complex::ZERO; 16];
        x[0] = Complex::new(1.0, 0.0);
        fft_inplace(&mut x);
        for v in x {
            assert!((v.re - 1.0).abs() < 1e-12 && v.im.abs() < 1e-12);
        }
    }
}
