//! **Table 7**: fine-tuning with FRUGAL / FIRA / LDAdamW / DCT-AdamW at
//! r ∈ {low, high}. Paper: Llama-2-7B on GSM-8k; here: pre-trained `nano`
//! on the arithmetic task corpus (DESIGN.md substitution) with exact-match
//! accuracy. Claims: DCT recovers SVD/block-power accuracy with lower
//! memory and runtime.

use anyhow::Result;

use crate::optim::OptimizerKind;
use crate::projection::{ProjectionKind, RankNorm};
use crate::runtime::{Manifest, Runtime};
use crate::train::finetune::Finetuner;
use crate::train::{checkpoint, TrainConfig, Trainer};
use crate::util::human;

use super::{render_table, write_csv, ExpOptions};

/// Pre-train once (or reuse a cached checkpoint) so every FT run starts
/// from the same weights — as the paper fine-tunes one base model.
pub fn pretrained_params(
    manifest: &Manifest,
    rt: &Runtime,
    opts: &ExpOptions,
    preset: &str,
    steps: usize,
) -> Result<Vec<crate::tensor::Matrix>> {
    let path = std::path::PathBuf::from(&opts.out_dir)
        .join("checkpoints")
        .join(format!("{preset}_base_s{steps}.bin"));
    if let Ok(params) = checkpoint::load(&path) {
        return Ok(params);
    }
    println!("  (pre-training {preset} base model for {steps} steps…)");
    let mut cfg = TrainConfig {
        preset: preset.into(),
        optimizer: OptimizerKind::AdamW,
        steps,
        lr: 3e-3,
        seed: opts.seed,
        out_dir: opts.out_dir.clone(),
        run_name: format!("{preset}_base_pretrain"),
        eval_every: 0,
        ..Default::default()
    };
    cfg.opt.seed = opts.seed;
    let mut tr = Trainer::new(manifest, rt, cfg)?;
    tr.run(manifest, rt)?;
    checkpoint::save(&path, &tr.params)?;
    Ok(tr.params)
}

pub fn run(manifest: &Manifest, rt: &Runtime, opts: &ExpOptions) -> Result<()> {
    let preset = "nano";
    let pt_steps = if opts.quick { 40 } else { 250 };
    let ft_steps = if opts.quick { 40 } else { 300 };
    let ranks: &[usize] = if opts.quick { &[8] } else { &[8, 32] };
    let base = pretrained_params(manifest, rt, opts, preset, pt_steps)?;

    let dct = ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true };
    let cases: Vec<(OptimizerKind, Option<ProjectionKind>, &str)> = vec![
        (OptimizerKind::Frugal, Some(ProjectionKind::Svd), "frugal+svd"),
        (OptimizerKind::Frugal, Some(dct.clone()), "frugal+dct"),
        (OptimizerKind::Fira, Some(ProjectionKind::Svd), "fira+svd"),
        (OptimizerKind::Fira, Some(dct), "fira+dct"),
        (OptimizerKind::LdAdamW, None, "ldadamw"),
        (OptimizerKind::DctAdamW, None, "dct-adamw"),
    ];

    let mut rows = Vec::new();
    for &rank in ranks {
        for (kind, proj, label) in &cases {
            let mut cfg = TrainConfig {
                preset: preset.into(),
                optimizer: kind.clone(),
                steps: ft_steps,
                lr: 1e-3,
                seed: opts.seed,
                out_dir: opts.out_dir.clone(),
                ..Default::default()
            };
            cfg.opt.rank = rank;
            cfg.opt.seed = opts.seed;
            cfg.opt.update_interval = match kind {
                OptimizerKind::Frugal | OptimizerKind::Fira => 50,
                _ => 1,
            };
            if let Some(p) = proj {
                cfg.opt.projection = p.clone();
            }
            let mut ft = Finetuner::new(manifest, rt, cfg, Some(base.clone()))?;
            let sum = ft.run(manifest, rt)?;
            println!(
                "  r={rank} {label}: loss {:.4} acc {:.1}% mem {} wall {}",
                sum.final_train_loss,
                sum.accuracy * 100.0,
                human::bytes(sum.optimizer_state_bytes),
                human::duration(sum.wall_secs),
            );
            rows.push(vec![
                rank.to_string(),
                label.to_string(),
                format!("{:.4}", sum.final_train_loss),
                format!("{:.2}", sum.accuracy * 100.0),
                sum.optimizer_state_bytes.to_string(),
                format!("{:.2}", sum.wall_secs),
            ]);
        }
    }
    let headers = ["rank", "optimizer", "train_loss", "acc_pct", "opt_state_bytes", "wall_secs"];
    println!("\nTable 7 (fine-tuning, task corpus):\n{}", render_table(&headers, &rows));
    let path = write_csv(opts, "table7", &headers, &rows)?;
    println!("csv: {}", path.display());
    Ok(())
}
