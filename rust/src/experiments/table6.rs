//! **Table 6 + Figure 4**: FRUGAL × {SVD, DCT, RandPerm, Random} and
//! FIRA × {SVD, DCT} pre-training, with AdamW for reference, plus one
//! beyond-the-paper engine grid point (GaLore cadence + DCT source + Q8
//! error feedback) built from config overrides alone.
//! Claims under test: DCT ≈ SVD quality at lower runtime/memory; DCT beats
//! RandPerm/Random by ~1 ppl; FIRA+DCT slightly better than FIRA+SVD.

use anyhow::Result;

use crate::optim::OptimizerKind;
use crate::projection::{ProjectionKind, RankNorm};
use crate::runtime::{Manifest, Runtime};
use crate::train::{TrainConfig, Trainer};
use crate::util::human;

use super::{render_table, write_csv, ExpOptions};

pub fn run(manifest: &Manifest, rt: &Runtime, opts: &ExpOptions) -> Result<()> {
    // micro (the 800M analog) costs ~2 min/run on one core; opt in with
    // FFT_SUBSPACE_TABLE6_MICRO=1 — nano preserves the same orderings.
    let micro = std::env::var("FFT_SUBSPACE_TABLE6_MICRO").is_ok();
    let steps = if opts.quick { 30 } else { 250 };
    let preset = if micro && !opts.quick { "micro" } else { "nano" };
    let rank = if opts.quick || !micro { 16 } else { 32 };
    let dct = ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true };

    // (kind, projection, engine grid point?, state dtype) — the GaLore
    // case is an `OptimizerSpec` combination no published method covers
    // (GaLore cadence + DCT source + Q8 error feedback), expressed purely
    // through the config override keys `source=` / `residual=` /
    // `ef-mode=`; the final DCT-AdamW row flips the fifth composition axis
    // (`state-dtype=bf16`) to record the typed-storage memory saving next
    // to its quality cost in the same table.
    let mut cases: Vec<(OptimizerKind, Option<ProjectionKind>, bool, Option<&str>)> = vec![
        (OptimizerKind::AdamW, None, false, None),
        (OptimizerKind::Frugal, Some(ProjectionKind::Svd), false, None),
        (OptimizerKind::Frugal, Some(dct.clone()), false, None),
        (OptimizerKind::Frugal, Some(ProjectionKind::RandPerm), false, None),
        (OptimizerKind::Frugal, Some(ProjectionKind::Random), false, None),
        (OptimizerKind::Fira, Some(ProjectionKind::Svd), false, None),
        (OptimizerKind::Fira, Some(dct.clone()), false, None),
        (OptimizerKind::GaLore, None, true, None),
        (OptimizerKind::DctAdamW, Some(dct.clone()), false, Some("bf16")),
    ];
    if opts.quick {
        cases.truncate(5);
    }

    let mut rows = Vec::new();
    for (kind, proj, engine_combo, state_dtype) in cases {
        let mut cfg = TrainConfig {
            preset: preset.into(),
            optimizer: kind.clone(),
            steps,
            lr: 3e-3,
            seed: opts.seed,
            out_dir: opts.out_dir.clone(),
            workers: 2,
            ..Default::default()
        };
        cfg.opt.rank = rank;
        cfg.opt.seed = opts.seed;
        cfg.opt.update_interval = 50; // FRUGAL/FIRA refresh cadence (paper: 200)
        if let Some(p) = proj {
            cfg.opt.projection = p;
        }
        if engine_combo {
            cfg.apply("source", "dct")?;
            cfg.apply("residual", "ef")?;
            cfg.apply("ef-mode", "q8")?;
        }
        if let Some(d) = state_dtype {
            cfg.apply("state-dtype", d)?;
        }
        let mut tr = Trainer::new(manifest, rt, cfg)?;
        let sum = tr.run(manifest, rt)?;
        println!(
            "  {}: train ppl {:.2} val ppl {:.2} mem {} wall {}",
            sum.optimizer,
            sum.train_ppl(),
            sum.val_ppl,
            human::bytes(sum.optimizer_state_bytes),
            human::duration(sum.wall_secs),
        );
        rows.push(vec![
            sum.optimizer.clone(),
            format!("{:.4}", sum.mean_tail_loss),
            format!("{:.2}", sum.train_ppl()),
            format!("{:.4}", sum.val_loss),
            format!("{:.2}", sum.val_ppl),
            sum.optimizer_state_bytes.to_string(),
            format!("{:.2}", sum.wall_secs),
            format!("{:.3}", sum.optimizer_secs),
            sum.metrics_path.display().to_string(),
        ]);
    }
    let headers = [
        "optimizer", "train_loss", "train_ppl", "val_loss", "val_ppl",
        "opt_state_bytes", "wall_secs", "optimizer_secs", "metrics",
    ];
    println!(
        "\nTable 6 (FRUGAL/FIRA projection sweep, {preset}, rank {rank}):\n{}",
        render_table(&headers, &rows)
    );
    let path = write_csv(opts, "table6", &headers, &rows)?;
    println!("csv: {} (fig4 curves: per-run metrics.jsonl)", path.display());
    Ok(())
}
