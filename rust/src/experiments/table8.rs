//! **Table 8**: DCT-AdamW vs GaLore fine-tuning, both refreshing the
//! subspace every `T_u = 200` steps (the GaLore regime), AdamW full-rank
//! for reference. Paper: Qwen-2.5-7B on GSM-8k; here the task-corpus
//! analog. Claim: DCT-AdamW edges out GaLore's accuracy at equal or lower
//! memory/runtime.

use anyhow::Result;

use crate::optim::OptimizerKind;
use crate::runtime::{Manifest, Runtime};
use crate::train::finetune::Finetuner;
use crate::train::TrainConfig;
use crate::util::human;

use super::{render_table, table7, write_csv, ExpOptions};

pub fn run(manifest: &Manifest, rt: &Runtime, opts: &ExpOptions) -> Result<()> {
    let preset = "nano";
    let pt_steps = if opts.quick { 40 } else { 250 };
    let ft_steps = if opts.quick { 40 } else { 300 };
    let ranks: &[usize] = if opts.quick { &[8] } else { &[8, 32] };
    let base = table7::pretrained_params(manifest, rt, opts, preset, pt_steps)?;

    let mut rows = Vec::new();
    // Full-rank AdamW reference first.
    {
        let mut cfg = base_cfg(preset, OptimizerKind::AdamW, ft_steps, opts);
        cfg.opt.rank = 0;
        let mut ft = Finetuner::new(manifest, rt, cfg, Some(base.clone()))?;
        let sum = ft.run(manifest, rt)?;
        rows.push(row("full", "adamw", &sum));
        print_sum("full", &sum);
    }
    for &rank in ranks {
        for kind in [OptimizerKind::DctAdamW, OptimizerKind::GaLore] {
            let mut cfg = base_cfg(preset, kind.clone(), ft_steps, opts);
            cfg.opt.rank = rank;
            cfg.opt.update_interval = 200; // both in the GaLore T_u regime
            cfg.opt.ef_mode = crate::optim::common::EfMode::None; // paper: no EF here
            let mut ft = Finetuner::new(manifest, rt, cfg, Some(base.clone()))?;
            let sum = ft.run(manifest, rt)?;
            rows.push(row(&rank.to_string(), sum.optimizer.clone().as_str(), &sum));
            print_sum(&format!("r={rank}"), &sum);
        }
    }
    let headers = ["rank", "optimizer", "train_loss", "acc_pct", "opt_state_bytes", "wall_secs"];
    println!("\nTable 8 (DCT-AdamW vs GaLore, T_u=200):\n{}", render_table(&headers, &rows));
    let path = write_csv(opts, "table8", &headers, &rows)?;
    println!("csv: {}", path.display());
    Ok(())
}

fn base_cfg(preset: &str, kind: OptimizerKind, steps: usize, opts: &ExpOptions) -> TrainConfig {
    let mut cfg = TrainConfig {
        preset: preset.into(),
        optimizer: kind,
        steps,
        lr: 1e-3,
        seed: opts.seed,
        out_dir: opts.out_dir.clone(),
        ..Default::default()
    };
    cfg.opt.seed = opts.seed;
    cfg
}

fn row(rank: &str, opt: &str, s: &crate::train::finetune::FinetuneSummary) -> Vec<String> {
    vec![
        rank.to_string(),
        opt.to_string(),
        format!("{:.4}", s.final_train_loss),
        format!("{:.2}", s.accuracy * 100.0),
        s.optimizer_state_bytes.to_string(),
        format!("{:.2}", s.wall_secs),
    ]
}

fn print_sum(tag: &str, s: &crate::train::finetune::FinetuneSummary) {
    println!(
        "  {tag} {}: loss {:.4} acc {:.1}% mem {} wall {}",
        s.optimizer,
        s.final_train_loss,
        s.accuracy * 100.0,
        human::bytes(s.optimizer_state_bytes),
        human::duration(s.wall_secs),
    );
}
