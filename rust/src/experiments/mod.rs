//! Experiment harness: one module per table/figure of the paper.
//!
//! Every experiment prints the paper-shaped rows, writes a CSV under
//! `runs/experiments/`, and the loss-curve figures fall out of the
//! per-run `metrics.jsonl` files. `--quick` shrinks step counts so the
//! whole battery fits a CI budget; full mode is the EXPERIMENTS.md record.

pub mod table1;
pub mod table2;
pub mod table3;
pub mod table6;
pub mod table7;
pub mod table8;
pub mod fig1;

use anyhow::Result;

use crate::runtime::{Manifest, Runtime};

/// Shared experiment options.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    pub quick: bool,
    pub out_dir: String,
    pub seed: u64,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions { quick: false, out_dir: "runs".into(), seed: 42 }
    }
}

pub fn run(name: &str, manifest: &Manifest, rt: &Runtime, opts: &ExpOptions) -> Result<()> {
    match name {
        "table1" | "fig3" => table1::run(manifest, rt, opts),
        "table2" | "fig2" => table2::run(manifest, rt, opts),
        "table3" => table3::run(),
        "table6" | "fig4" => table6::run(manifest, rt, opts),
        "table7" => table7::run(manifest, rt, opts),
        "table8" => table8::run(manifest, rt, opts),
        "fig1" => fig1::run(manifest, rt, opts),
        "all" => {
            for e in ["table1", "table2", "table3", "table6", "table7", "table8", "fig1"] {
                println!("\n================ {e} ================");
                run(e, manifest, rt, opts)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment {other:?} (table1|table2|table3|table6|table7|table8|fig1|all)"
        ),
    }
}

/// Render an aligned text table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    let line = |cells: Vec<String>| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| crate::util::human::pad(c, widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let mut out = String::new();
    out.push_str(&line(headers.iter().map(|s| s.to_string()).collect()));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&line(row.clone()));
        out.push('\n');
    }
    out
}

/// Write rows to `runs/experiments/<name>.csv`.
pub fn write_csv(
    opts: &ExpOptions,
    name: &str,
    headers: &[&str],
    rows: &[Vec<String>],
) -> Result<std::path::PathBuf> {
    let path = std::path::PathBuf::from(&opts.out_dir)
        .join("experiments")
        .join(format!("{name}.csv"));
    let mut w = crate::util::csv::CsvWriter::create(&path, headers)?;
    for row in rows {
        w.row(row)?;
    }
    w.flush()?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["a", "metric"],
            &[
                vec!["x".into(), "1.0".into()],
                vec!["longer".into(), "2.25".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("a     "));
        assert!(lines[3].starts_with("longer"));
    }
}
