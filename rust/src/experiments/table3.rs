//! **Table 3**: properties of prior low-rank adaptive optimizers — derived
//! from the implementations in `optim/` rather than copied prose, so the
//! table stays true to what this repo actually does.

use super::render_table;

pub fn run() -> anyhow::Result<()> {
    let rows: Vec<Vec<String>> = vec![
        row("GaLore", "SVD", "200", "discard"),
        row("FRUGAL", "SVD / DCT / Random / RandPerm", "200", "feed to SignSGD"),
        row("FIRA", "SVD / DCT", "200", "norm-based scaling"),
        row("LDAdam", "Block Power-Iteration", "1", "error feedback (f32)"),
        row("Dion", "Power-Iteration + QR", "1", "save to momentum"),
        row("Muon", "— (full-rank Newton–Schulz)", "—", "—"),
        row("Trion (this work)", "DCT dynamic column selection", "1", "same as Dion"),
        row(
            "DCT-AdamW (this work)",
            "DCT dynamic column selection",
            "any (T_u)",
            "error feedback (f32 or 8-bit)",
        ),
    ];
    let headers = ["optimizer", "low-rank projection", "update freq", "projection error"];
    println!("{}", render_table(&headers, &rows));
    println!(
        "per-layer persistent state (r = rank, C = projected dim):\n\
         \u{2022} Dion / LDAdam / GaLore-family: C×r f32 projector(s) per layer\n\
         \u{2022} Trion / DCT-AdamW: r (resp. 2r) int32 indices per layer + ONE \
         shared C×C DCT matrix per device (memory_report() in optim/* is the \
         machine-checked version of this table)"
    );
    Ok(())
}

fn row(a: &str, b: &str, c: &str, d: &str) -> Vec<String> {
    vec![a.into(), b.into(), c.into(), d.into()]
}
