//! **Figure 1 + Appendix F**: per-layer projection errors ‖B_t − O_t‖ for
//! Dion vs Trion over training, on the first transformer block's linear
//! layers. Claim under test: Trion's dynamic selection yields a lower (and
//! for some layers decreasing) projection error, Dion's stays flat.

use anyhow::Result;

use crate::optim::OptimizerKind;
use crate::runtime::{Manifest, Runtime};
use crate::train::{TrainConfig, Trainer};
use crate::util::json::Json;

use super::{render_table, write_csv, ExpOptions};

pub fn run(manifest: &Manifest, rt: &Runtime, opts: &ExpOptions) -> Result<()> {
    let steps = if opts.quick { 20 } else { 120 };
    let mut rows = Vec::new();
    let mut per_run = Vec::new();
    for kind in [OptimizerKind::Dion, OptimizerKind::Trion] {
        let mut cfg = TrainConfig {
            preset: "nano".into(),
            optimizer: kind.clone(),
            steps,
            seed: opts.seed,
            out_dir: opts.out_dir.clone(),
            run_name: format!("fig1_{}", kind.name()),
            workers: 2,
            ..Default::default()
        };
        cfg.opt.rank = 16; // r/d = 1/4 on nano, mirroring d=640, r=128
        cfg.opt.instrument = true;
        cfg.opt.seed = opts.seed;
        let mut tr = Trainer::new(manifest, rt, cfg)?;
        let sum = tr.run(manifest, rt)?;
        per_run.push(sum.metrics_path.clone());

        // pull the block-0 projection-error series out of metrics.jsonl
        let text = std::fs::read_to_string(&sum.metrics_path)?;
        let mut first: Option<(f64, Vec<(String, f64)>)> = None;
        let mut last: Option<(f64, Vec<(String, f64)>)> = None;
        for line in text.lines() {
            let j = Json::parse(line)?;
            let (Some(step), Some(errs)) = (j.get("step"), j.get("proj_errors")) else {
                continue;
            };
            let mut layer_errs = Vec::new();
            if let Json::Obj(m) = errs {
                for (k, v) in m {
                    if k.starts_with("block0.") {
                        layer_errs.push((k.clone(), v.as_f64()?));
                    }
                }
            }
            if layer_errs.is_empty() {
                continue;
            }
            let entry = (step.as_f64()?, layer_errs);
            if first.is_none() {
                first = Some(entry.clone());
            }
            last = Some(entry);
        }
        if let (Some((s0, e0)), Some((s1, e1))) = (first, last) {
            for ((name, v0), (_, v1)) in e0.iter().zip(&e1) {
                rows.push(vec![
                    kind.name().to_string(),
                    name.clone(),
                    format!("{s0}"),
                    format!("{v0:.4}"),
                    format!("{s1}"),
                    format!("{v1:.4}"),
                ]);
            }
        }
    }
    let headers = ["optimizer", "layer", "step_first", "err_first", "step_last", "err_last"];
    println!("\nFigure 1 (projection errors, block 0):\n{}", render_table(&headers, &rows));
    let path = write_csv(opts, "fig1", &headers, &rows)?;
    println!(
        "csv: {} — full per-step series in {:?}",
        path.display(),
        per_run
    );

    // Headline check: Trion's mean final error ≤ Dion's.
    let mean = |opt: &str| -> f64 {
        let sel: Vec<f64> = rows
            .iter()
            .filter(|r| r[0] == opt)
            .filter_map(|r| r[5].parse().ok())
            .collect();
        sel.iter().sum::<f64>() / sel.len().max(1) as f64
    };
    let (t, d) = (mean("trion"), mean("dion"));
    println!("mean final projection error: trion={t:.4} dion={d:.4} ({})",
             if t <= d { "trion lower — matches the paper" } else { "dion lower" });
    Ok(())
}
