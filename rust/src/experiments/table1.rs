//! **Table 1 + Figure 3**: Trion vs Dion across model sizes and ranks —
//! train/val loss & ppl, optimizer memory, wall time, and the
//! rank-(in)dependence of the optimizer step.
//!
//! Paper: Llama 350M/800M/1.3B (d = 1024/2048/2048), r ∈ {128, 256, 512},
//! i.e. r/d ∈ {1/16 … 1/2}. Here: nano/micro/small (d = 64/128/256) with
//! the same r/d grid — the claims under test (Trion ≤ Dion loss, ~10% less
//! optimizer memory, rank-independent runtime) are ratio claims.

use anyhow::Result;

use crate::optim::OptimizerKind;
use crate::runtime::{Manifest, Runtime};
use crate::train::{TrainConfig, Trainer};
use crate::util::human;

use super::{render_table, write_csv, ExpOptions};

pub fn run(manifest: &Manifest, rt: &Runtime, opts: &ExpOptions) -> Result<()> {
    // `small` (the 1.3B analog) triples the battery's wall time on one
    // core; include it with FFT_SUBSPACE_TABLE1_SMALL=1.
    let with_small = std::env::var("FFT_SUBSPACE_TABLE1_SMALL").is_ok();
    let presets: &[(&str, usize)] = if opts.quick {
        &[("nano", 64)]
    } else if with_small {
        &[("nano", 64), ("micro", 128), ("small", 256)]
    } else {
        &[("nano", 64), ("micro", 128)]
    };
    // r/d sweep matching the paper's 1/16..1/2 grid
    let ratios: &[(usize, usize)] = if opts.quick {
        &[(1, 8), (1, 2)]
    } else {
        &[(1, 16), (1, 8), (1, 4), (1, 2)]
    };
    let steps = if opts.quick { 30 } else { 200 };

    let mut rows = Vec::new();
    for &(preset, d) in presets {
        for &(num, den) in ratios {
            let rank = (d * num / den).max(2);
            for kind in [OptimizerKind::Trion, OptimizerKind::Dion] {
                let mut cfg = TrainConfig {
                    preset: preset.into(),
                    optimizer: kind.clone(),
                    steps,
                    seed: opts.seed,
                    out_dir: opts.out_dir.clone(),
                    workers: 2, // 1-core testbed: 2 simulated workers keep DDP exercised
                    ..Default::default()
                };
                cfg.opt.rank = rank;
                cfg.opt.seed = opts.seed;
                let mut tr = Trainer::new(manifest, rt, cfg)?;
                let sum = tr.run(manifest, rt)?;
                println!(
                    "  {preset} r={rank} {}: train {:.3} val {:.3} (ppl {:.2}) mem {} wall {} opt {:.1}s",
                    sum.optimizer,
                    sum.mean_tail_loss,
                    sum.val_loss,
                    sum.val_ppl,
                    human::bytes(sum.optimizer_state_bytes),
                    human::duration(sum.wall_secs),
                    sum.optimizer_secs,
                );
                rows.push(vec![
                    preset.to_string(),
                    format!("{num}/{den}"),
                    rank.to_string(),
                    sum.optimizer.clone(),
                    format!("{:.4}", sum.mean_tail_loss),
                    format!("{:.2}", sum.train_ppl()),
                    format!("{:.4}", sum.val_loss),
                    format!("{:.2}", sum.val_ppl),
                    sum.optimizer_state_bytes.to_string(),
                    format!("{:.2}", sum.wall_secs),
                    format!("{:.3}", sum.optimizer_secs),
                    sum.update_broadcast_bytes.to_string(),
                    sum.metrics_path.display().to_string(),
                ]);
            }
        }
    }
    let headers = [
        "preset", "r/d", "rank", "optimizer", "train_loss", "train_ppl",
        "val_loss", "val_ppl", "opt_state_bytes", "wall_secs",
        "optimizer_secs", "update_bcast_bytes", "metrics",
    ];
    println!("\nTable 1 (Trion vs Dion):\n{}", render_table(&headers, &rows));
    let path = write_csv(opts, "table1", &headers, &rows)?;
    println!("csv: {} (fig3 series: per-run metrics.jsonl)", path.display());

    // Rank-independence check (the paper's runtime claim): Trion's
    // optimizer time should be ~flat in rank; Dion's should grow.
    summarize_rank_dependence(&rows);
    Ok(())
}

fn summarize_rank_dependence(rows: &[Vec<String>]) {
    let mut by_opt: std::collections::BTreeMap<String, Vec<(usize, f64)>> =
        Default::default();
    for r in rows {
        let rank: usize = r[2].parse().unwrap_or(0);
        let secs: f64 = r[10].parse().unwrap_or(0.0);
        by_opt.entry(r[3].clone()).or_default().push((rank, secs));
    }
    println!("optimizer-time vs rank (rank-independence claim):");
    for (opt, mut v) in by_opt {
        v.sort_by(|a, b| a.0.cmp(&b.0));
        let series: Vec<String> =
            v.iter().map(|(r, s)| format!("r{r}:{s:.3}s")).collect();
        println!("  {opt}: {}", series.join("  "));
    }
}
