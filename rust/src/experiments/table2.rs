//! **Table 2 + Figure 2**: AdamW vs LDAdamW vs DCT-AdamW pre-training.
//! Paper: Llama-800M, 100 tokens/param, rank ~d/2, DCT-AdamW with 8-bit
//! EF + ZeRO. Here: micro preset with an extended token budget, same
//! three-way comparison; the claims under test are DCT-AdamW ≤ LDAdamW
//! loss, much lower memory, and ~25% faster optimizer time.

use anyhow::Result;

use crate::optim::common::EfMode;
use crate::optim::OptimizerKind;
use crate::runtime::{Manifest, Runtime};
use crate::train::{TrainConfig, Trainer};
use crate::util::human;

use super::{render_table, write_csv, ExpOptions};

pub fn run(manifest: &Manifest, rt: &Runtime, opts: &ExpOptions) -> Result<()> {
    let steps = if opts.quick { 30 } else { 250 };
    let preset = if opts.quick { "nano" } else { "micro" };
    let rank = if opts.quick { 16 } else { 64 };

    let mut rows = Vec::new();
    for kind in [
        OptimizerKind::AdamW,
        OptimizerKind::LdAdamW,
        OptimizerKind::DctAdamW,
    ] {
        let mut cfg = TrainConfig {
            preset: preset.into(),
            optimizer: kind.clone(),
            steps,
            lr: 3e-3, // Adam-family lr
            seed: opts.seed,
            out_dir: opts.out_dir.clone(),
            workers: 2,
            ..Default::default()
        };
        cfg.opt.rank = rank;
        cfg.opt.seed = opts.seed;
        cfg.opt.ef_mode = EfMode::Q8; // the paper's 8-bit EF
        cfg.opt.update_interval = 1;
        let mut tr = Trainer::new(manifest, rt, cfg)?;
        let sum = tr.run(manifest, rt)?;
        println!(
            "  {}: train ppl {:.2} val ppl {:.2} mem {} wall {} opt {:.1}s",
            sum.optimizer,
            sum.train_ppl(),
            sum.val_ppl,
            human::bytes(sum.optimizer_state_bytes),
            human::duration(sum.wall_secs),
            sum.optimizer_secs,
        );
        rows.push(vec![
            sum.optimizer.clone(),
            format!("{:.4}", sum.mean_tail_loss),
            format!("{:.2}", sum.train_ppl()),
            format!("{:.4}", sum.val_loss),
            format!("{:.2}", sum.val_ppl),
            sum.optimizer_state_bytes.to_string(),
            sum.per_worker_state_bytes.to_string(),
            format!("{:.2}", sum.wall_secs),
            format!("{:.3}", sum.optimizer_secs),
            sum.metrics_path.display().to_string(),
        ]);
    }
    let headers = [
        "optimizer", "train_loss", "train_ppl", "val_loss", "val_ppl",
        "opt_state_bytes", "zero_per_worker_bytes", "wall_secs",
        "optimizer_secs", "metrics",
    ];
    println!(
        "\nTable 2 (AdamW / LDAdamW / DCT-AdamW, {preset}, rank {rank}):\n{}",
        render_table(&headers, &rows)
    );
    let path = write_csv(opts, "table2", &headers, &rows)?;
    println!("csv: {} (fig2 curve: per-run metrics.jsonl)", path.display());
    Ok(())
}
