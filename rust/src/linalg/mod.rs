//! Dense linear-algebra substrate for the baselines the paper compares
//! against: Householder QR (Dion's power iteration), one-sided Jacobi SVD
//! (GaLore / FRUGAL / FIRA projections), block power iteration (LDAdam) and
//! the quintic Newton–Schulz orthogonalization (Muon / Trion).

pub mod qr;
pub mod svd;
pub mod newton_schulz;
pub mod power_iter;

pub use newton_schulz::{newton_schulz, newton_schulz_into};
pub use power_iter::{block_power_iter, power_iter_qr};
pub use qr::{qr_q_into, qr_thin};
pub use svd::{svd_right_vectors_into, svd_thin, Svd};
