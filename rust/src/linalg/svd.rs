//! Thin SVD via one-sided Jacobi rotations.
//!
//! The GaLore / FRUGAL / FIRA baselines take the top-r left or right
//! singular vectors of the gradient. One-sided Jacobi is simple, accurate
//! (works column-by-column on AᵀA implicitly) and fast enough at the layer
//! sizes we train; convergence is quadratic once rotations get small.

use crate::tensor::{Matrix, Workspace};

/// Thin SVD result: `a == u · diag(s) · vᵀ` with singular values sorted
/// descending.
pub struct Svd {
    pub u: Matrix,      // m×k
    pub s: Vec<f32>,    // k
    pub vt: Matrix,     // k×n
}

/// One-sided Jacobi SVD of `a (m×n)`; `k = min(m, n)`.
pub fn svd_thin(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    let transposed = m < n;
    // Work on a tall matrix (m >= n) in f64.
    let (wm, wn) = if transposed { (n, m) } else { (m, n) };
    let mut u: Vec<f64> = if transposed {
        let t = a.transpose();
        t.data.iter().map(|&v| v as f64).collect()
    } else {
        a.data.iter().map(|&v| v as f64).collect()
    };
    // v accumulates the right rotations: starts as identity (wn×wn).
    let mut v = vec![0.0f64; wn * wn];
    for i in 0..wn {
        v[i * wn + i] = 1.0;
    }

    let max_sweeps = 60;
    let eps = 1e-12;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..wn {
            for q in (p + 1)..wn {
                // Compute the 2x2 Gram block for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..wm {
                    let up = u[i * wn + p];
                    let uq = u[i * wn + q];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() + 1e-300 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the off-diagonal.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..wm {
                    let up = u[i * wn + p];
                    let uq = u[i * wn + q];
                    u[i * wn + p] = c * up - s * uq;
                    u[i * wn + q] = s * up + c * uq;
                }
                for i in 0..wn {
                    let vp = v[i * wn + p];
                    let vq = v[i * wn + q];
                    v[i * wn + p] = c * vp - s * vq;
                    v[i * wn + q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }

    // Singular values = column norms of the rotated U; normalize columns.
    let mut s: Vec<f64> = (0..wn)
        .map(|j| {
            (0..wm)
                .map(|i| u[i * wn + j] * u[i * wn + j])
                .sum::<f64>()
                .sqrt()
        })
        .collect();
    // Sort descending by singular value.
    let mut order: Vec<usize> = (0..wn).collect();
    order.sort_by(|&a_, &b_| s[b_].partial_cmp(&s[a_]).unwrap());

    let mut u_sorted = vec![0.0f64; wm * wn];
    let mut v_sorted = vec![0.0f64; wn * wn];
    let mut s_sorted = vec![0.0f64; wn];
    for (newj, &oldj) in order.iter().enumerate() {
        let sv = s[oldj];
        s_sorted[newj] = sv;
        let inv = if sv > 1e-300 { 1.0 / sv } else { 0.0 };
        for i in 0..wm {
            u_sorted[i * wn + newj] = u[i * wn + oldj] * inv;
        }
        for i in 0..wn {
            v_sorted[i * wn + newj] = v[i * wn + oldj];
        }
    }
    s = s_sorted;

    let uf = Matrix::from_vec(wm, wn, u_sorted.iter().map(|&x| x as f32).collect());
    let vf = Matrix::from_vec(wn, wn, v_sorted.iter().map(|&x| x as f32).collect());
    let sf: Vec<f32> = s.iter().map(|&x| x as f32).collect();

    if transposed {
        // a = (u' s v'ᵀ)ᵀ = v' s u'ᵀ → U = v', Vᵀ = u'ᵀ
        Svd { u: vf, s: sf, vt: uf.transpose() }
    } else {
        Svd { u: uf, s: sf, vt: vf.transpose() }
    }
}

/// Workspace-backed top-r right singular vectors: writes
/// `svd_thin(a).right_vectors(r)` into `out` **bit-identically** (pinned by
/// the `_into` property test in `projection/mod.rs`) with every temporary
/// pooled, so the GaLore-style SVD refresh runs allocation-free at steady
/// state (`tests/alloc_steady_state.rs`).
///
/// Same one-sided Jacobi sweep as [`svd_thin`] — identical constants,
/// identical per-element f64 summation orders — with two storage-only
/// differences: the rotated-U/V work matrices live in pooled `f64` buffers,
/// and the sorted right vectors are gathered column-by-column into `out`
/// instead of materializing the full sorted factors. The descending sort
/// uses `sort_unstable_by` with an ascending-index tie-break, which is the
/// exact total order of [`svd_thin`]'s stable sort (stable sort preserves
/// the ascending initial order on ties), so ranking matches to the bit and
/// the in-place sort never allocates a merge buffer.
pub fn svd_right_vectors_into(a: &Matrix, r: usize, out: &mut Matrix, ws: &mut Workspace) {
    let (m, n) = a.shape();
    let transposed = m < n;
    // Work on a tall matrix (m >= n) in f64.
    let (wm, wn) = if transposed { (n, m) } else { (m, n) };
    let mut u = ws.take_f64(wm * wn);
    if transposed {
        // exact widen of aᵀ, matching `a.transpose()` element for element
        for i in 0..wm {
            for j in 0..wn {
                u[i * wn + j] = a.at(j, i) as f64;
            }
        }
    } else {
        for (dst, &src) in u.iter_mut().zip(a.data.iter()) {
            *dst = src as f64;
        }
    }
    // v accumulates the right rotations: starts as identity (wn×wn);
    // take_f64 zeroes the buffer.
    let mut v = ws.take_f64(wn * wn);
    for i in 0..wn {
        v[i * wn + i] = 1.0;
    }

    let max_sweeps = 60;
    let eps = 1e-12;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..wn {
            for q in (p + 1)..wn {
                // Compute the 2x2 Gram block for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..wm {
                    let up = u[i * wn + p];
                    let uq = u[i * wn + q];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() + 1e-300 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the off-diagonal.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..wm {
                    let up = u[i * wn + p];
                    let uq = u[i * wn + q];
                    u[i * wn + p] = c * up - s * uq;
                    u[i * wn + q] = s * up + c * uq;
                }
                for i in 0..wn {
                    let vp = v[i * wn + p];
                    let vq = v[i * wn + q];
                    v[i * wn + p] = c * vp - s * vq;
                    v[i * wn + q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }

    // Singular values = column norms of the rotated U.
    let mut s = ws.take_f64(wn);
    for (j, sj) in s.iter_mut().enumerate() {
        *sj = (0..wm)
            .map(|i| u[i * wn + j] * u[i * wn + j])
            .sum::<f64>()
            .sqrt();
    }
    // Descending order; ties broken by ascending index — the stable-sort
    // total order of svd_thin, without the stable sort's allocation.
    let mut order = ws.take_usize(wn);
    for (i, o) in order.iter_mut().enumerate() {
        *o = i;
    }
    order.sort_unstable_by(|&x, &y| {
        s[y].partial_cmp(&s[x]).unwrap().then(x.cmp(&y))
    });

    // Right vectors live in C-space (n rows): the rotated/normalized U
    // columns in the transposed case, the accumulated V columns otherwise —
    // exactly what svd_thin's u_sorted/v_sorted → right_vectors(r) yields.
    let rr = r.min(wn);
    out.resize_for_overwrite(n, rr);
    for (newj, &oldj) in order[..rr].iter().enumerate() {
        if transposed {
            let sv = s[oldj];
            let inv = if sv > 1e-300 { 1.0 / sv } else { 0.0 };
            for i in 0..wm {
                out.data[i * rr + newj] = (u[i * wn + oldj] * inv) as f32;
            }
        } else {
            for i in 0..wn {
                out.data[i * rr + newj] = v[i * wn + oldj] as f32;
            }
        }
    }

    ws.give_usize(order);
    ws.give_f64(s);
    ws.give_f64(v);
    ws.give_f64(u);
}

impl Svd {
    /// Top-r left singular vectors (m×r) — GaLore's left projector.
    pub fn left_vectors(&self, r: usize) -> Matrix {
        let r = r.min(self.u.cols);
        self.u.select_columns(&(0..r).collect::<Vec<_>>())
    }

    /// Top-r right singular vectors (n×r) — GaLore's right projector.
    pub fn right_vectors(&self, r: usize) -> Matrix {
        let r = r.min(self.vt.rows);
        let v = self.vt.transpose();
        v.select_columns(&(0..r).collect::<Vec<_>>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_at_b};
    use crate::util::proptest;

    fn reconstruct(svd: &Svd) -> Matrix {
        let k = svd.s.len();
        let mut us = svd.u.clone();
        for i in 0..us.rows {
            for j in 0..k {
                *us.at_mut(i, j) *= svd.s[j];
            }
        }
        matmul(&us, &svd.vt)
    }

    #[test]
    fn prop_reconstruction_and_orthogonality() {
        proptest::check("svd: A=USVᵀ", 10, |rng| {
            let m = proptest::size(rng, 2, 40);
            let n = proptest::size(rng, 2, 40);
            let a = Matrix::randn(m, n, 1.0, rng);
            let svd = svd_thin(&a);
            let err = reconstruct(&svd).max_abs_diff(&a);
            assert!(err < 1e-3, "{m}x{n} err={err}");
            let k = m.min(n);
            let gram_u = matmul_at_b(&svd.u, &svd.u);
            assert!(gram_u.max_abs_diff(&Matrix::eye(k)) < 1e-3);
            let v = svd.vt.transpose();
            let gram_v = matmul_at_b(&v, &v);
            assert!(gram_v.max_abs_diff(&Matrix::eye(k)) < 1e-3);
        });
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        let mut rng = crate::util::Pcg64::seed(0);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let svd = svd_thin(&a);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(svd.s.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn known_diagonal_case() {
        let a = Matrix::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let svd = svd_thin(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-5);
        assert!((svd.s[1] - 2.0).abs() < 1e-5);
        assert!((svd.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn low_rank_matrix_has_small_tail() {
        let mut rng = crate::util::Pcg64::seed(1);
        let u = Matrix::randn(30, 3, 1.0, &mut rng);
        let v = Matrix::randn(3, 18, 1.0, &mut rng);
        let a = matmul(&u, &v);
        let svd = svd_thin(&a);
        assert!(svd.s[3] < 1e-3 * svd.s[0]);
    }

    #[test]
    fn top_r_projection_is_best_approximation() {
        // Eckart–Young sanity: SVD rank-2 error ≤ DCT-selection rank-2 error.
        let mut rng = crate::util::Pcg64::seed(2);
        let a = Matrix::randn(16, 10, 1.0, &mut rng);
        let svd = svd_thin(&a);
        let v2 = svd.right_vectors(2);
        let proj = matmul(&matmul(&a, &v2), &v2.transpose());
        let err_svd = a.sub(&proj).fro_norm_sq();
        let tail: f64 = svd.s[2..].iter().map(|&s| (s as f64) * (s as f64)).sum();
        assert!((err_svd - tail).abs() < 1e-3 * tail.max(1.0));
    }

    #[test]
    fn prop_right_vectors_into_bit_identical() {
        // the workspace-backed gather must reproduce
        // svd_thin(a).right_vectors(r) to the bit, tall and wide, with
        // reused (dirty) workspaces
        proptest::check("svd_right_vectors_into==svd_thin", 8, |rng| {
            let m = proptest::size(rng, 2, 24);
            let n = proptest::size(rng, 2, 24);
            let r = proptest::size(rng, 1, n.min(m));
            let a = Matrix::randn(m, n, 1.0, rng);
            let want = svd_thin(&a).right_vectors(r);
            let mut ws = crate::tensor::Workspace::new();
            let mut got = Matrix::zeros(1, 1);
            svd_right_vectors_into(&a, r, &mut got, &mut ws);
            assert_eq!(got, want, "{m}x{n} r={r}");
            svd_right_vectors_into(&a, r, &mut got, &mut ws);
            assert_eq!(got, want, "warm workspace {m}x{n} r={r}");
        });
    }

    #[test]
    fn wide_matrix_transposed_path() {
        let mut rng = crate::util::Pcg64::seed(3);
        let a = Matrix::randn(5, 24, 1.0, &mut rng);
        let svd = svd_thin(&a);
        assert_eq!(svd.u.shape(), (5, 5));
        assert_eq!(svd.vt.shape(), (5, 24));
        assert!(reconstruct(&svd).max_abs_diff(&a) < 1e-3);
    }
}
