//! Thin QR via Householder reflections.
//!
//! Used by Dion's power-iteration step (`P_t = QR(B_t P_{t-1})`) and to
//! generate random semi-orthogonal baselines (FRUGAL's `Random` projection).
//! Rank-revealing enough for our use: zero columns yield zero R diagonal and
//! an orthonormal completion from the remaining reflectors.

use crate::tensor::{Matrix, Workspace};

/// Workspace-backed Q factor of the thin QR — bit-identical to
/// [`qr_thin`]'s Q (same f64 Householder sweep, same summation orders; the
/// per-column reflector vectors live in one flat pooled buffer instead of a
/// `Vec<Vec<f64>>`, which changes storage, not arithmetic). Skips building
/// R. Zero heap allocations once `ws` is warm — this is what lets the
/// LDAdamW block-power refresh run inside the allocation-free step loop.
pub fn qr_q_into(a: &Matrix, q_out: &mut Matrix, ws: &mut Workspace) {
    let (m, n) = a.shape();
    assert!(m >= n, "qr_q_into needs m >= n, got {m}x{n}");
    let mut r = ws.take_f64(m * n);
    for (dst, &src) in r.iter_mut().zip(a.data.iter()) {
        *dst = src as f64;
    }
    // Householder vector for column k occupies vs[k*m .. k*m + (m-k)];
    // take_f64 zeroes the buffer, matching qr_thin's `vec![0.0; m-k]` init.
    let mut vs = ws.take_f64(m * n);

    for k in 0..n {
        let mut norm2 = 0.0f64;
        for i in k..m {
            let v = r[i * n + k];
            norm2 += v * v;
        }
        let norm = norm2.sqrt();
        let v = &mut vs[k * m..k * m + (m - k)];
        if norm > 0.0 {
            let alpha = if r[k * n + k] >= 0.0 { -norm } else { norm };
            v[0] = r[k * n + k] - alpha;
            for i in k + 1..m {
                v[i - k] = r[i * n + k];
            }
            let vnorm2: f64 = v.iter().map(|x| x * x).sum();
            if vnorm2 > 1e-300 {
                for j in k..n {
                    let mut dot = 0.0f64;
                    for i in k..m {
                        dot += v[i - k] * r[i * n + j];
                    }
                    let f = 2.0 * dot / vnorm2;
                    for i in k..m {
                        r[i * n + j] -= f * v[i - k];
                    }
                }
            } else {
                for x in v.iter_mut() {
                    *x = 0.0;
                }
            }
        }
    }

    // Accumulate Q = H_0 H_1 ... H_{n-1} applied to the thin identity.
    let mut q = ws.take_f64(m * n);
    for j in 0..n {
        q[j * n + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k * m..k * m + (m - k)];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 <= 1e-300 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0f64;
            for i in k..m {
                dot += v[i - k] * q[i * n + j];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                q[i * n + j] -= f * v[i - k];
            }
        }
    }

    q_out.resize_to(m, n);
    for (dst, &src) in q_out.data.iter_mut().zip(q.iter()) {
        *dst = src as f32;
    }
    ws.give_f64(q);
    ws.give_f64(vs);
    ws.give_f64(r);
}

/// Thin QR of `a (m×n)`, `m ≥ n`: returns `(Q (m×n), R (n×n))` with
/// `Q·R == a` and `QᵀQ == I`.
pub fn qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = a.shape();
    assert!(m >= n, "qr_thin needs m >= n, got {m}x{n}");
    // Work in f64 for stability; the factors round back to f32.
    let mut r: Vec<f64> = a.data.iter().map(|&v| v as f64).collect();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n); // Householder vectors

    for k in 0..n {
        // norm of the k-th column below the diagonal
        let mut norm2 = 0.0f64;
        for i in k..m {
            let v = r[i * n + k];
            norm2 += v * v;
        }
        let norm = norm2.sqrt();
        let mut v = vec![0.0f64; m - k];
        if norm > 0.0 {
            let alpha = if r[k * n + k] >= 0.0 { -norm } else { norm };
            v[0] = r[k * n + k] - alpha;
            for i in k + 1..m {
                v[i - k] = r[i * n + k];
            }
            let vnorm2: f64 = v.iter().map(|x| x * x).sum();
            if vnorm2 > 1e-300 {
                // apply H = I - 2vvᵀ/(vᵀv) to R[k.., k..]
                for j in k..n {
                    let mut dot = 0.0f64;
                    for i in k..m {
                        dot += v[i - k] * r[i * n + j];
                    }
                    let f = 2.0 * dot / vnorm2;
                    for i in k..m {
                        r[i * n + j] -= f * v[i - k];
                    }
                }
            } else {
                v = vec![0.0; m - k];
            }
        }
        vs.push(v);
    }

    // Accumulate Q = H_0 H_1 ... H_{n-1} applied to the thin identity.
    let mut q = vec![0.0f64; m * n];
    for j in 0..n {
        q[j * n + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 <= 1e-300 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0f64;
            for i in k..m {
                dot += v[i - k] * q[i * n + j];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                q[i * n + j] -= f * v[i - k];
            }
        }
    }

    let q_m = Matrix::from_vec(m, n, q.iter().map(|&v| v as f32).collect());
    let mut r_m = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            *r_m.at_mut(i, j) = r[i * n + j] as f32;
        }
    }
    (q_m, r_m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_at_b};
    use crate::util::proptest;

    #[test]
    fn reconstructs_and_orthogonal() {
        proptest::check("qr: A=QR, QᵀQ=I", 12, |rng| {
            let n = proptest::size(rng, 1, 24);
            let m = n + proptest::size(rng, 0, 40);
            let a = Matrix::randn(m, n, 1.0, rng);
            let (q, r) = qr_thin(&a);
            assert!(matmul(&q, &r).max_abs_diff(&a) < 1e-3);
            let gram = matmul_at_b(&q, &q);
            assert!(gram.max_abs_diff(&Matrix::eye(n)) < 1e-4);
        });
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = crate::util::Pcg64::seed(0);
        let a = Matrix::randn(10, 6, 1.0, &mut rng);
        let (_, r) = qr_thin(&a);
        for i in 0..6 {
            for j in 0..i {
                assert_eq!(r.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn handles_rank_deficiency() {
        // two identical columns
        let mut rng = crate::util::Pcg64::seed(1);
        let col = Matrix::randn(12, 1, 1.0, &mut rng);
        let mut a = Matrix::zeros(12, 2);
        for i in 0..12 {
            *a.at_mut(i, 0) = col.at(i, 0);
            *a.at_mut(i, 1) = col.at(i, 0);
        }
        let (q, r) = qr_thin(&a);
        assert!(matmul(&q, &r).max_abs_diff(&a) < 1e-4);
        // R[1,1] ≈ 0 reveals the deficiency
        assert!(r.at(1, 1).abs() < 1e-4);
    }

    #[test]
    fn prop_q_into_bit_identical_to_qr_thin() {
        // qr_q_into must produce qr_thin's Q to the bit, including
        // rank-deficient inputs (duplicated columns) and reused workspaces.
        proptest::check("qr_q_into==qr_thin.0", 10, |rng| {
            let n = proptest::size(rng, 1, 16);
            let m = n + proptest::size(rng, 0, 24);
            let mut a = Matrix::randn(m, n, 1.0, rng);
            if n >= 2 && rng.next_u64() % 2 == 0 {
                // force a zero R diagonal via a duplicated column
                for i in 0..m {
                    *a.at_mut(i, 1) = a.at(i, 0);
                }
            }
            let (want, _) = qr_thin(&a);
            let mut ws = Workspace::new();
            let mut got = Matrix::zeros(1, 1);
            qr_q_into(&a, &mut got, &mut ws);
            assert_eq!(got, want, "first pass {m}x{n}");
            // pooled buffers must not leak state into a second factorization
            qr_q_into(&a, &mut got, &mut ws);
            assert_eq!(got, want, "warm-workspace pass {m}x{n}");
        });
    }

    #[test]
    fn square_orthogonal_input_is_fixed_point_up_to_sign() {
        let q0 = crate::fft::dct2_matrix(8);
        let (q, r) = qr_thin(&q0);
        // |diag(R)| == 1 and Q matches q0 up to column signs
        for j in 0..8 {
            assert!((r.at(j, j).abs() - 1.0).abs() < 1e-4);
            let sign = r.at(j, j).signum();
            for i in 0..8 {
                assert!((q.at(i, j) * sign - q0.at(i, j)).abs() < 1e-4);
            }
        }
    }
}
