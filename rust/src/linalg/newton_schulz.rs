//! Quintic Newton–Schulz orthogonalization (Muon coefficients).
//!
//! Pushes the singular values of the input toward 1 with five iterations of
//! `X ← aX + X(bA + cA²)`, `A = XᵀX`. Mirrors `kernels/ref.py::newton_schulz`
//! exactly (same coefficients, same frobenius pre-normalization, same
//! transpose trick for wide inputs) — the rust-native Trion path and the AOT
//! pallas-kernel path must agree to float tolerance.
//!
//! [`newton_schulz_into`] is the workspace-backed hot path (Trion calls it
//! every step): all four iteration temporaries come from the caller's
//! [`Workspace`] pool and every multiply is an `_into` kernel, so the
//! steady-state call performs zero heap allocations (pinned with the rest
//! of the Trion step in `tests/alloc_steady_state.rs`). The allocating
//! [`newton_schulz`] delegates to it, so the two are bit-identical by
//! construction.

use crate::tensor::{all_finite, matmul_at_b_into, matmul_into, Matrix, Workspace};

/// Muon's quintic coefficients (Jordan et al., 2024).
pub const NS_COEFFS: (f32, f32, f32) = (3.4445, -4.7750, 2.0315);

/// Orthogonalize `x` with `steps` Newton–Schulz iterations.
pub fn newton_schulz(x: &Matrix, steps: usize) -> Matrix {
    let mut ws = Workspace::new();
    let mut out = Matrix::zeros(0, 0);
    newton_schulz_into(x, steps, &mut out, &mut ws);
    out
}

/// Allocation-free [`newton_schulz`]: writes the orthogonalized matrix into
/// `out` (resized in place) using only pooled workspace scratch.
pub fn newton_schulz_into(x: &Matrix, steps: usize, out: &mut Matrix, ws: &mut Workspace) {
    // Non-finite input: the iteration can only amplify NaN/Inf (the Gram
    // products smear a single poisoned entry across the whole matrix), so
    // pass the input through untouched and let the caller's guard decide
    // what to do with the step (ROADMAP §Fault tolerance).
    if !all_finite(&x.data) {
        out.copy_from(x);
        return;
    }
    let (a, b, c) = NS_COEFFS;
    let transposed = x.rows < x.cols;
    let (wr, wc) = if transposed { (x.cols, x.rows) } else { (x.rows, x.cols) };
    // every temporary is fully overwritten before being read
    let mut w = ws.take_uninit(wr, wc);
    if transposed {
        x.transpose_into(&mut w);
    } else {
        w.copy_from(x);
    }
    let norm = w.fro_norm() as f32 + 1e-7;
    w.scale(1.0 / norm);
    let mut gram = ws.take_uninit(wc, wc);
    let mut gram2 = ws.take_uninit(wc, wc);
    let mut w_poly = ws.take_uninit(wr, wc);
    for _ in 0..steps {
        matmul_at_b_into(&w, &w, &mut gram); // r×r
        matmul_into(&gram, &gram, &mut gram2);
        // poly = b·A + c·A² (built in gram2)
        gram2.scale(c);
        gram2.axpy(b, &gram);
        // w = a·w + w·poly
        matmul_into(&w, &gram2, &mut w_poly);
        w.scale(a);
        w.axpy(1.0, &w_poly);
    }
    if transposed {
        w.transpose_into(out);
    } else {
        out.copy_from(&w);
    }
    ws.give(w_poly);
    ws.give(gram2);
    ws.give(gram);
    ws.give(w);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd_thin;
    use crate::util::{proptest, Pcg64};

    #[test]
    fn singular_values_pushed_toward_one() {
        let mut rng = Pcg64::seed(0);
        let x = Matrix::randn(48, 8, 1.0, &mut rng);
        let o = newton_schulz(&x, 10);
        let svd = svd_thin(&o);
        for &s in &svd.s {
            assert!(s > 0.5 && s < 1.5, "s={s}");
        }
    }

    #[test]
    fn preserves_singular_subspace_directions() {
        // NS approximates UVᵀ of the input: column space must match.
        let mut rng = Pcg64::seed(1);
        let x = Matrix::randn(24, 4, 1.0, &mut rng);
        let o = newton_schulz(&x, 8);
        // project o's columns onto x's column space; residual should be ~0
        let (qx, _) = crate::linalg::qr_thin(&x);
        let coeff = matmul_at_b(&qx, &o);
        let proj = matmul(&qx, &coeff);
        let resid = o.sub(&proj).fro_norm() / o.fro_norm();
        assert!(resid < 1e-3, "resid={resid}");
    }

    #[test]
    fn wide_input_uses_transpose_trick() {
        let mut rng = Pcg64::seed(2);
        let x = Matrix::randn(6, 40, 1.0, &mut rng);
        let o = newton_schulz(&x, 8);
        assert_eq!(o.shape(), (6, 40));
        let svd = svd_thin(&o);
        for &s in svd.s.iter().take(6) {
            assert!(s > 0.5 && s < 1.5, "s={s}");
        }
    }

    #[test]
    fn prop_output_norm_bounded() {
        proptest::check("ns-bounded", 8, |rng| {
            let m = proptest::size(rng, 4, 40);
            let n = proptest::size(rng, 1, 12);
            let x = Matrix::randn(m, n, 1.0, rng);
            let o = newton_schulz(&x, 5);
            // ‖O‖F ≤ sqrt(min(m,n)) · 1.5 (singular values near 1)
            let bound = ((m.min(n)) as f64).sqrt() * 1.6;
            assert!(o.fro_norm() <= bound, "norm={} bound={bound}", o.fro_norm());
        });
    }

    #[test]
    fn non_finite_input_passes_through_unmodified() {
        let mut rng = Pcg64::seed(3);
        let mut x = Matrix::randn(10, 6, 1.0, &mut rng);
        x.data[17] = f32::NAN;
        let o = newton_schulz(&x, 5);
        assert_eq!(o.shape(), x.shape());
        for (a, b) in o.data.iter().zip(x.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn zero_input_stays_finite() {
        let x = Matrix::zeros(8, 3);
        let o = newton_schulz(&x, 5);
        assert!(o.data.iter().all(|v| v.is_finite()));
    }
}
