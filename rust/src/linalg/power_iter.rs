//! Power-iteration projection builders.
//!
//! * [`power_iter_qr`] — Dion's single power-iteration step with QR
//!   orthonormalization (`P_t = QR(B · P_{t-1})`), whose cost grows with
//!   rank — the overhead Trion eliminates.
//! * [`block_power_iter`] — LDAdam's block power method approximating the
//!   top-r right singular subspace with a handful of QR-orthonormalized
//!   multiplications, warm-started from the previous step's projector.

use crate::tensor::{matmul, matmul_at_b, Matrix};

use super::qr::qr_thin;

/// One Dion-style power-iteration refresh: given the accumulator `b (R×C)`
/// and the previous right basis `p_prev (C×r)`, returns the updated
/// orthonormal left basis `p (R×r)`.
pub fn power_iter_qr(b: &Matrix, p_prev: &Matrix) -> Matrix {
    let z = matmul(b, p_prev); // R×r
    let (q, _) = qr_thin(&z);
    q
}

/// Block power iteration for the top-r *right* singular subspace of
/// `g (R×C)`: iterate `V ← orth(Gᵀ·(G·V))`. `warm_start` seeds with the
/// previous projector (LDAdam's trick); otherwise a seeded Gaussian.
pub fn block_power_iter(
    g: &Matrix,
    r: usize,
    iters: usize,
    warm_start: Option<&Matrix>,
) -> Matrix {
    let c = g.cols;
    let r = r.min(c);
    let mut v = match warm_start {
        Some(w) if w.shape() == (c, r) => w.clone(),
        _ => {
            let mut rng = crate::util::Pcg64::seed(0x9e3779b97f4a7c15);
            Matrix::randn(c, r, 1.0, &mut rng)
        }
    };
    let (q0, _) = qr_thin(&v);
    v = q0;
    for _ in 0..iters.max(1) {
        let gv = matmul(g, &v); // R×r
        let gtgv = matmul_at_b(g, &gv); // C×r
        let (q, _) = qr_thin(&gtgv);
        v = q;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd_thin;
    use crate::tensor::matmul_a_bt;
    use crate::util::{proptest, Pcg64};

    #[test]
    fn power_iter_qr_is_orthonormal() {
        let mut rng = Pcg64::seed(0);
        let b = Matrix::randn(30, 20, 1.0, &mut rng);
        let p_prev = Matrix::randn(20, 4, 1.0, &mut rng);
        let p = power_iter_qr(&b, &p_prev);
        let gram = matmul_at_b(&p, &p);
        assert!(gram.max_abs_diff(&Matrix::eye(4)) < 1e-4);
    }

    #[test]
    fn block_power_converges_to_top_subspace() {
        // Construct a matrix with a dominant rank-2 right subspace and check
        // the block power iteration captures most of its energy.
        let mut rng = Pcg64::seed(1);
        let u = Matrix::randn(40, 2, 3.0, &mut rng);
        let vtrue = {
            let (q, _) = qr_thin(&Matrix::randn(16, 2, 1.0, &mut rng));
            q
        };
        let signal = matmul_a_bt(&u, &vtrue); // 40×16, rank 2, large
        let mut noise = Matrix::randn(40, 16, 0.05, &mut rng);
        noise.axpy(1.0, &signal);
        let g = noise;

        let v = block_power_iter(&g, 2, 8, None);
        // projection of g onto span(v) captures almost all energy
        let gv = matmul(&g, &v);
        let captured = gv.fro_norm_sq();
        let total = g.fro_norm_sq();
        assert!(captured / total > 0.95, "captured={}", captured / total);
    }

    #[test]
    fn warm_start_speeds_up_convergence() {
        let mut rng = Pcg64::seed(2);
        let g = Matrix::randn(30, 12, 1.0, &mut rng);
        let svd = svd_thin(&g);
        let vstar = svd.right_vectors(3);
        // warm start at the true subspace: 1 iteration stays there
        let v = block_power_iter(&g, 3, 1, Some(&vstar));
        let overlap = matmul_at_b(&vstar, &v);
        // |det|≈1 ⇔ same subspace; check via frobenius of overlap ≈ sqrt(3)
        let f = overlap.fro_norm();
        assert!((f * f - 3.0).abs() < 0.05, "overlap^2={}", f * f);
    }

    #[test]
    fn prop_block_power_output_orthonormal() {
        proptest::check("bpi-orthonormal", 8, |rng| {
            let m = proptest::size(rng, 4, 30);
            let n = proptest::size(rng, 4, 30);
            let r = proptest::size(rng, 1, n.min(m).min(6));
            let g = Matrix::randn(m, n, 1.0, rng);
            let v = block_power_iter(&g, r, 3, None);
            let gram = matmul_at_b(&v, &v);
            assert!(gram.max_abs_diff(&Matrix::eye(v.cols)) < 1e-3);
        });
    }
}
