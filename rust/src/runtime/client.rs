//! PJRT client wrapper: compile HLO text once, execute many times.
//!
//! One process-wide CPU client (PJRT client construction is expensive and
//! the CPU plugin is a singleton anyway); [`Executable`]s are cheap handles
//! around `PjRtLoadedExecutable` plus their manifest signature, with
//! Matrix⇄Literal conversion and shape checking at the boundary.

use anyhow::{bail, Context, Result};

use crate::tensor::Matrix;

use super::artifacts::{ArtifactSpec, IoSpec};

/// PJRT CPU client handle.
///
/// `xla::PjRtClient` is `Rc`-backed (not `Send`), so the runtime — and
/// everything holding executables — lives on one thread. The coordinator's
/// "workers" are therefore *simulated* (cooperatively scheduled on the
/// driver thread) rather than OS threads; on this 1-core testbed that is
/// also the faster design (DESIGN.md §Hardware-Adaptation).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an artifact's HLO text into an executable.
    pub fn load(&self, spec: &ArtifactSpec) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", spec.name))?;
        Ok(Executable {
            exe,
            name: spec.name.clone(),
            inputs: spec.inputs.clone(),
            outputs: spec.outputs.clone(),
        })
    }
}

/// A runtime input value.
pub enum Value {
    F32(Matrix),
    I32(Vec<i32>, Vec<usize>), // data + shape
    Scalar(f32),
}

impl Value {
    pub fn tokens(data: Vec<i32>, shape: Vec<usize>) -> Value {
        Value::I32(data, shape)
    }

    fn to_literal(&self, spec: &IoSpec) -> Result<xla::Literal> {
        match self {
            Value::F32(m) => {
                if m.len() != spec.elements() {
                    bail!(
                        "input {}: got {} elements, artifact wants {:?}",
                        spec.name, m.len(), spec.shape
                    );
                }
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                Ok(xla::Literal::vec1(&m.data).reshape(&dims)?)
            }
            Value::I32(data, shape) => {
                if data.len() != spec.elements() || shape != &spec.shape {
                    bail!(
                        "input {}: got shape {shape:?}, artifact wants {:?}",
                        spec.name, spec.shape
                    );
                }
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                Ok(xla::Literal::vec1(data.as_slice()).reshape(&dims)?)
            }
            Value::Scalar(v) => Ok(xla::Literal::from(*v)),
        }
    }
}

/// A compiled artifact with typed execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Outputs come back as matrices (scalars are 1×1; int32 outputs are
/// converted to f32 matrices holding the integer values — index lists).
pub struct Outputs {
    pub values: Vec<Matrix>,
}

impl Outputs {
    pub fn scalar(&self, i: usize) -> f32 {
        self.values[i].data[0]
    }

    /// Interpret output `i` as an index list.
    pub fn indices(&self, i: usize) -> Vec<usize> {
        self.values[i].data.iter().map(|&v| v as usize).collect()
    }
}

impl Executable {
    /// Execute with positional inputs matching the manifest signature.
    pub fn run(&self, inputs: &[Value]) -> Result<Outputs> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "{}: got {} inputs, artifact wants {}",
                self.name, inputs.len(), self.inputs.len()
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&self.inputs)
            .map(|(v, spec)| v.to_literal(spec))
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True → always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != self.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.name, parts.len(), self.outputs.len()
            );
        }
        let mut values = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&self.outputs) {
            let (rows, cols) = match spec.shape.as_slice() {
                [] => (1, 1),
                [n] => (1, *n),
                [r, c] => (*r, *c),
                s => bail!("output {} has unsupported rank {s:?}", spec.name),
            };
            let data: Vec<f32> = if spec.dtype == "i32" {
                lit.to_vec::<i32>()?.into_iter().map(|v| v as f32).collect()
            } else {
                lit.to_vec::<f32>()?
            };
            values.push(Matrix::from_vec(rows, cols, data));
        }
        Ok(Outputs { values })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    /// Artifact-backed tests need `make artifacts` AND a real PJRT plugin;
    /// in environments without either (e.g. the offline stub `xla` crate)
    /// they skip instead of failing.
    fn setup() -> Option<(Manifest, Runtime)> {
        crate::runtime::testing::pjrt_setup("PJRT test")
    }

    #[test]
    fn kernel_dct2_matrix_matches_rust() {
        let (m, rt) = match setup() {
            Some(x) => x,
            None => return,
        };
        let exe = rt.load(m.find("kernel_dct2_matrix").unwrap()).unwrap();
        let out = exe.run(&[]).unwrap();
        let q_jax = &out.values[0];
        let q_rust = crate::fft::dct2_matrix(q_jax.rows);
        assert!(q_jax.max_abs_diff(&q_rust) < 1e-5);
    }

    #[test]
    fn kernel_similarity_norms_matches_rust() {
        let (m, rt) = match setup() {
            Some(x) => x,
            None => return,
        };
        let spec = m.find("kernel_dct_similarity_norms").unwrap();
        let (r, c) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
        let mut rng = crate::util::Pcg64::seed(0);
        let g = Matrix::randn(r, c, 1.0, &mut rng);
        let q = crate::fft::dct2_matrix(c);
        let exe = rt.load(spec).unwrap();
        let out = exe
            .run(&[Value::F32(g.clone()), Value::F32(q.clone())])
            .unwrap();
        // pallas kernel (AOT, via PJRT) vs rust-native matmul + norms
        let s_rust = crate::tensor::matmul(&g, &q);
        assert!(out.values[0].max_abs_diff(&s_rust) < 1e-4);
        let norms_rust = s_rust.col_l2_norms();
        for (a, b) in out.values[1].data.iter().zip(&norms_rust) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn kernel_makhoul_matches_rust_fft() {
        let (m, rt) = match setup() {
            Some(x) => x,
            None => return,
        };
        let spec = m.find("kernel_makhoul_dct2").unwrap();
        let (r, c) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
        let mut rng = crate::util::Pcg64::seed(1);
        let g = Matrix::randn(r, c, 1.0, &mut rng);
        let exe = rt.load(spec).unwrap();
        let out = exe.run(&[Value::F32(g.clone())]).unwrap();
        let s_rust = crate::fft::dct2_rows(&g);
        assert!(out.values[0].max_abs_diff(&s_rust) < 1e-4);
    }

    #[test]
    fn kernel_newton_schulz_matches_rust() {
        let (m, rt) = match setup() {
            Some(x) => x,
            None => return,
        };
        let spec = m.find("kernel_newton_schulz").unwrap();
        let (r, k) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
        let mut rng = crate::util::Pcg64::seed(2);
        let x = Matrix::randn(r, k, 1.0, &mut rng);
        let exe = rt.load(spec).unwrap();
        let out = exe.run(&[Value::F32(x.clone())]).unwrap();
        let o_rust = crate::linalg::newton_schulz(&x, 5);
        assert!(
            out.values[0].max_abs_diff(&o_rust) < 1e-3,
            "err={}",
            out.values[0].max_abs_diff(&o_rust)
        );
    }

    #[test]
    fn input_shape_mismatch_rejected() {
        let (m, rt) = match setup() {
            Some(x) => x,
            None => return,
        };
        let exe = rt.load(m.find("kernel_newton_schulz").unwrap()).unwrap();
        let bad = Matrix::zeros(3, 3);
        assert!(exe.run(&[Value::F32(bad)]).is_err());
        assert!(exe.run(&[]).is_err());
    }
}
