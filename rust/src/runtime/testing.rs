//! Shared PJRT test-support: the skip-or-require setup helper that was
//! previously copy-pasted across four test modules (runtime/client,
//! runtime/artifacts, train/aot_optim, tests/integration).
//!
//! Artifact-backed tests need `make artifacts` AND a real PJRT plugin; in
//! environments without either (e.g. the offline stub `xla` crate) they
//! *skip* (print + return `None`) instead of failing. Setting
//! `FFT_SUBSPACE_REQUIRE_PJRT=1` (real-PJRT CI) turns every skip into a
//! loud panic.
//!
//! This module is ordinary `pub` (not `#[cfg(test)]`) because integration
//! tests under `rust/tests/` link the library like any downstream crate;
//! it is `doc(hidden)` to stay out of the public API surface.

use std::path::PathBuf;

use super::{Manifest, Runtime};

/// True when `FFT_SUBSPACE_REQUIRE_PJRT` is set non-empty and not "0".
pub fn pjrt_required() -> bool {
    std::env::var("FFT_SUBSPACE_REQUIRE_PJRT").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// The crate-local artifact directory `make artifacts` writes into.
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Load the artifact manifest, or skip the calling test (`None`, with a
/// consistent "skipping <what>" message). Panics if PJRT is required.
pub fn manifest_or_skip(what: &str) -> Option<Manifest> {
    match Manifest::load(artifacts_dir()) {
        Ok(m) => Some(m),
        Err(e) if pjrt_required() => {
            panic!("FFT_SUBSPACE_REQUIRE_PJRT set but artifacts missing: {e}")
        }
        Err(e) => {
            eprintln!("skipping {what} (run `make artifacts`): {e}");
            None
        }
    }
}

/// Manifest plus a live PJRT runtime, or skip the calling test (`None`).
/// Panics if PJRT is required but unavailable.
pub fn pjrt_setup(what: &str) -> Option<(Manifest, Runtime)> {
    let m = manifest_or_skip(what)?;
    match Runtime::new() {
        Ok(rt) => Some((m, rt)),
        Err(e) if pjrt_required() => {
            panic!("FFT_SUBSPACE_REQUIRE_PJRT set but PJRT unavailable: {e:#}")
        }
        Err(e) => {
            eprintln!("skipping {what}: {e:#}");
            None
        }
    }
}
