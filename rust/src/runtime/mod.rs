//! PJRT runtime: load the AOT HLO-text artifacts and execute them from the
//! rust training loop. Python never runs here — `artifacts/manifest.json`
//! (written once by `python -m compile.aot`) is the entire contract.

pub mod artifacts;
pub mod client;
#[doc(hidden)]
pub mod testing;

pub use artifacts::{ArtifactSpec, Manifest, ModelSpec, ParamSpec};
pub use client::{Executable, Runtime};
