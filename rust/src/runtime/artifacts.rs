//! `artifacts/manifest.json` parsing: the contract between `compile/aot.py`
//! and the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::optim::{LayerMeta, ParamKind};
use crate::util::json::Json;

/// One tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl IoSpec {
    fn parse(v: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.as_shape()?,
            dtype: v.get("dtype").map(|d| d.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_else(|| "f32".to_string()),
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One model parameter (ordered) from a fwdbwd artifact's meta.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: ParamKind,
}

impl ParamSpec {
    /// As optimizer layer metadata (1-D tensors become 1×n).
    pub fn layer_meta(&self) -> LayerMeta {
        let (rows, cols) = match self.shape.as_slice() {
            [n] => (1, *n),
            [r, c] => (*r, *c),
            s => panic!("unsupported param rank: {s:?}"),
        };
        LayerMeta { name: self.name.clone(), rows, cols, kind: self.kind }
    }
}

/// Model-level metadata attached to fwdbwd/eval artifacts.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub preset: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub num_params: usize,
    pub batch_per_worker: usize,
    pub params: Vec<ParamSpec>,
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactSpec {
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .ok_or_else(|| anyhow!("artifact {} missing meta {key}", self.name))?
            .as_usize()
    }
}

/// The parsed manifest + artifact directory.
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
    pub default_rank: usize,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text)?;
        let mut artifacts = Vec::new();
        for a in root.req("artifacts")?.as_arr()? {
            let inputs = a.req("inputs")?.as_arr()?.iter()
                .map(IoSpec::parse).collect::<Result<Vec<_>>>()?;
            let outputs = a.req("outputs")?.as_arr()?.iter()
                .map(IoSpec::parse).collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec {
                name: a.req("name")?.as_str()?.to_string(),
                file: dir.join(a.req("file")?.as_str()?),
                kind: a.req("kind")?.as_str()?.to_string(),
                inputs,
                outputs,
                meta: a.req("meta")?.as_obj()?.clone(),
            });
        }
        let default_rank = root
            .get("defaults")
            .and_then(|d| d.get("rank"))
            .map(|r| r.as_usize())
            .transpose()?
            .unwrap_or(32);
        Ok(Manifest { dir, artifacts, default_rank })
    }

    pub fn find(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    /// Model spec for a preset (from its fwdbwd artifact meta).
    pub fn model_spec(&self, preset: &str) -> Result<ModelSpec> {
        let art = self.find(&format!("fwdbwd_{preset}"))?;
        let meta = &art.meta;
        let get = |k: &str| -> Result<usize> {
            meta.get(k)
                .ok_or_else(|| anyhow!("missing meta {k}"))?
                .as_usize()
        };
        let mut params = Vec::new();
        for p in meta
            .get("params")
            .ok_or_else(|| anyhow!("missing params meta"))?
            .as_arr()?
        {
            params.push(ParamSpec {
                name: p.req("name")?.as_str()?.to_string(),
                shape: p.req("shape")?.as_shape()?,
                kind: ParamKind::parse(p.req("kind")?.as_str()?),
            });
        }
        if params.is_empty() {
            bail!("preset {preset} has no params");
        }
        Ok(ModelSpec {
            preset: preset.to_string(),
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            seq_len: get("seq_len")?,
            vocab: get("vocab")?,
            num_params: get("num_params")?,
            batch_per_worker: get("batch_per_worker")?,
            params,
        })
    }

    /// Per-layer optimizer update artifact for an oriented shape, if one
    /// was exported (`trion_{R}x{C}_r{r}` / `dctadamw_…` / `dion_…`).
    pub fn optimizer_graph(
        &self,
        family: &str,
        rows: usize,
        cols: usize,
        rank: usize,
    ) -> Option<&ArtifactSpec> {
        let name = format!("{family}_{rows}x{cols}_r{rank}");
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn presets(&self) -> Vec<String> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == "fwdbwd")
            .map(|a| a.name.trim_start_matches("fwdbwd_").to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `None` (→ skip) when `make artifacts` hasn't been run in this
    /// environment; the parsing logic itself is covered by the synthetic
    /// manifest test below either way.
    fn manifest_or_skip() -> Option<Manifest> {
        crate::runtime::testing::manifest_or_skip("manifest test")
    }

    #[test]
    fn parses_synthetic_manifest() {
        // A self-contained manifest exercise that needs no artifacts on
        // disk. Per-process dir: concurrent test runs must not race on the
        // manifest file.
        let dir = std::env::temp_dir()
            .join(format!("fft_subspace_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"defaults":{"rank":16},"artifacts":[
                {"name":"fwdbwd_nano","file":"f.hlo.txt","kind":"fwdbwd",
                 "inputs":[{"name":"w","shape":[2,3]},
                           {"name":"tokens","shape":[1,4],"dtype":"i32"}],
                 "outputs":[{"name":"loss","shape":[]}],
                 "meta":{"d_model":64,"n_layers":1,"seq_len":4,"vocab":257,
                         "num_params":6,"batch_per_worker":1,
                         "params":[{"name":"w","shape":[2,3],"kind":"linear"}]}}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.default_rank, 16);
        assert_eq!(m.presets(), vec!["nano".to_string()]);
        let spec = m.model_spec("nano").unwrap();
        assert_eq!(spec.d_model, 64);
        assert_eq!(spec.params[0].kind, ParamKind::Linear);
        assert_eq!(m.find("fwdbwd_nano").unwrap().inputs[1].dtype, "i32");
        assert!(m.optimizer_graph("trion", 2, 3, 16).is_none());
    }

    #[test]
    fn loads_real_manifest() {
        let m = match manifest_or_skip() {
            Some(m) => m,
            None => return,
        };
        assert!(m.artifacts.len() >= 10);
        assert!(m.presets().contains(&"nano".to_string()));
    }

    #[test]
    fn model_spec_roundtrip() {
        let m = match manifest_or_skip() {
            Some(m) => m,
            None => return,
        };
        let spec = m.model_spec("nano").unwrap();
        assert_eq!(spec.d_model, 64);
        assert_eq!(spec.params[0].name, "embed");
        assert_eq!(spec.params[0].kind, ParamKind::Embed);
        let total: usize = spec.params.iter().map(|p| p.shape.iter().product::<usize>()).sum();
        assert_eq!(total, spec.num_params);
    }

    #[test]
    fn fwdbwd_signature_consistent() {
        let m = match manifest_or_skip() {
            Some(m) => m,
            None => return,
        };
        let spec = m.model_spec("nano").unwrap();
        let art = m.find("fwdbwd_nano").unwrap();
        // inputs = params + tokens; outputs = loss + grads
        assert_eq!(art.inputs.len(), spec.params.len() + 1);
        assert_eq!(art.outputs.len(), spec.params.len() + 1);
        assert_eq!(art.inputs.last().unwrap().dtype, "i32");
        assert_eq!(art.outputs[0].shape, Vec::<usize>::new());
    }

    #[test]
    fn optimizer_graph_lookup() {
        let m = match manifest_or_skip() {
            Some(m) => m,
            None => return,
        };
        let r = m.default_rank;
        assert!(m.optimizer_graph("trion", 64, 64, r).is_some());
        assert!(m.optimizer_graph("trion", 7, 7, r).is_none());
    }

    #[test]
    fn layer_meta_from_param_spec() {
        let p = ParamSpec {
            name: "n".into(),
            shape: vec![64],
            kind: ParamKind::Norm,
        };
        let meta = p.layer_meta();
        assert_eq!((meta.rows, meta.cols), (1, 64));
    }
}
