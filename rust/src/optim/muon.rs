//! Muon (Jordan et al., 2024): SGD-momentum whose hidden-layer updates are
//! orthogonalized with full-size Newton–Schulz. The "fast convergence at
//! extra FLOPs" baseline — Trion's point of departure (§5): the NS here
//! materializes and multiplies full `R×C` matrices every step.

use std::collections::BTreeMap;

use crate::linalg::newton_schulz;
use crate::tensor::Matrix;

use super::common::{
    deorient, orient, shape_factor, AdamState, LayerMeta, MemoryReport, Optimizer,
    OptimizerConfig,
};

enum LayerState {
    /// Hidden linear layer: momentum buffer, NS-orthogonalized update.
    Momentum(Matrix),
    /// Everything else: dense AdamW.
    Adam(AdamState),
}

pub struct Muon {
    metas: Vec<LayerMeta>,
    states: Vec<LayerState>,
    mu: f32,
    ns_steps: usize,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    step: u64,
    instrument: bool,
    errors: BTreeMap<String, f64>,
}

impl Muon {
    pub fn new(metas: &[LayerMeta], cfg: &OptimizerConfig) -> Self {
        let states = metas
            .iter()
            .map(|m| {
                if m.kind.low_rank_eligible() {
                    LayerState::Momentum(Matrix::zeros(m.rows, m.cols))
                } else {
                    LayerState::Adam(AdamState::new(m.rows, m.cols))
                }
            })
            .collect();
        Muon {
            metas: metas.to_vec(),
            states,
            mu: cfg.mu,
            ns_steps: cfg.ns_steps,
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            weight_decay: cfg.weight_decay,
            step: 0,
            instrument: cfg.instrument,
            errors: BTreeMap::new(),
        }
    }
}

impl Optimizer for Muon {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        for i in 0..params.len() {
            let meta = &self.metas[i];
            match &mut self.states[i] {
                LayerState::Adam(st) => {
                    st.update(
                        &mut params[i], &grads[i], lr, self.beta1, self.beta2,
                        self.eps, 0.0, self.step,
                    );
                }
                LayerState::Momentum(m) => {
                    // Nesterov-style momentum accumulation: M ← μM + G
                    m.scale(self.mu);
                    m.axpy(1.0, &grads[i]);
                    let b = orient(meta, m);
                    let o = newton_schulz(&b, self.ns_steps);
                    if self.instrument {
                        self.errors
                            .insert(meta.name.clone(), b.sub(&o).fro_norm());
                    }
                    let (rr, cc) = b.shape();
                    let o_full = deorient(meta, o);
                    // θ ← (1 − λη)θ − η·max(1, sqrt(R/C))·O
                    params[i].scale(1.0 - lr * self.weight_decay);
                    params[i].axpy(-lr * shape_factor(rr, cc), &o_full);
                }
            }
        }
    }

    fn memory_report(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        for st in &self.states {
            match st {
                LayerState::Momentum(m) => r.add("momentum", m.bytes()),
                LayerState::Adam(a) => {
                    r.add("adam_m", a.m.bytes());
                    r.add("adam_v", a.v.bytes());
                }
            }
        }
        r
    }

    fn name(&self) -> &str {
        "muon"
    }

    fn projection_errors(&self) -> Option<&BTreeMap<String, f64>> {
        if self.instrument {
            Some(&self.errors)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::optim::common::ParamKind;
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn converges_on_quadratic() {
        let mut rng = Pcg64::seed(0);
        let t = Matrix::randn(8, 8, 0.5, &mut rng);
        let metas = vec![LayerMeta::new("w", 8, 8, ParamKind::Linear)];
        let cfg = OptimizerConfig { weight_decay: 0.0, mu: 0.9, ..Default::default() };
        let mut opt = Muon::new(&metas, &cfg);
        let mut params = vec![Matrix::zeros(8, 8)];
        for _ in 0..400 {
            let g = params[0].sub(&t).scaled(2.0);
            opt.step(&mut params, &[g], 0.02);
        }
        let err = params[0].sub(&t).fro_norm();
        assert!(err < 0.5, "err={err}");
    }

    #[test]
    fn uses_half_the_state_of_adam_on_linear() {
        let metas = vec![LayerMeta::new("w", 10, 10, ParamKind::Linear)];
        let cfg = OptimizerConfig::default();
        let muon = Muon::new(&metas, &cfg).memory_report().total();
        let adam = super::super::AdamW::new(&metas, &cfg).memory_report().total();
        assert_eq!(muon * 2, adam);
    }

    #[test]
    fn update_is_orthogonalized() {
        // after one step from zero momentum the applied update should have
        // singular values near 1 (scaled by lr)
        let mut rng = Pcg64::seed(1);
        let metas = vec![LayerMeta::new("w", 16, 4, ParamKind::Linear)];
        let cfg = OptimizerConfig { weight_decay: 0.0, ..Default::default() };
        let mut opt = Muon::new(&metas, &cfg);
        let mut params = vec![Matrix::zeros(16, 4)];
        let g = Matrix::randn(16, 4, 1.0, &mut rng);
        opt.step(&mut params, &[g], 1.0);
        // params = -shape_factor * O; singular values of O ∈ [0.5, 1.5]
        let sf = shape_factor(16, 4);
        let svd = crate::linalg::svd_thin(&params[0]);
        for &s in &svd.s {
            assert!(s / sf > 0.3 && s / sf < 1.6, "s={s}");
        }
    }

    #[test]
    fn instrumentation_records_layers() {
        let metas = vec![LayerMeta::new("w", 6, 6, ParamKind::Linear)];
        let cfg = OptimizerConfig { instrument: true, ..Default::default() };
        let mut opt = Muon::new(&metas, &cfg);
        let mut rng = Pcg64::seed(2);
        let mut params = vec![Matrix::zeros(6, 6)];
        let g = Matrix::randn(6, 6, 1.0, &mut rng);
        opt.step(&mut params, &[g], 0.01);
        assert!(opt.projection_errors().unwrap().contains_key("w"));
    }
}
