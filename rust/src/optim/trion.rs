//! **Trion** (Algorithm 1) — the paper's first contribution.
//!
//! Dion's power-iteration + QR is replaced by DCT dynamic column selection:
//!
//! 1. `B_t = M_{t-1} + G_t`
//! 2. `S_t = Makhoul(B_t)` (or `B_t·D_C`) — rank-*independent*
//! 3. `i_t = top-r columns of S_t by ℓ1/ℓ2 norm`
//! 4. `b_t = S_t[:, i_t]`, `Q_t = D_C[:, i_t]`
//! 5. `M_t = B_t − (1−μ)·b_t·Q_tᵀ` (error feedback)
//! 6. `o_t = NewtonSchulz(b_t)` on the **low-rank** momentum (R×r)
//! 7. `O_t = o_t·Q_tᵀ`, `θ ← (1−λη)θ − η·max(1,√(R/C))·O_t`
//!
//! State: one shared DCT matrix per device (deduplicated in the memory
//! report) + momentum + `r` column indices per layer. In the ZeRO schedule
//! only `o_t` (R×r) and `i_t` (r ints) are broadcast; receivers reconstruct
//! `O_t` locally from their DCT replica (§2.3).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::linalg::newton_schulz_into;
use crate::parallel::{ShardedWorkspace, ThreadPool};
use crate::projection::{DctSelect, Projection, RankNorm, SharedDct};
use crate::tensor::Matrix;

use super::common::{
    pool_for, shape_factor, shared_dct_registry, step_layers_parallel,
    AdamState, LayerMeta, MemoryReport, Optimizer, OptimizerConfig,
};

enum LayerState {
    LowRank {
        momentum: Matrix,  // R×C (oriented)
        select: DctSelect, // r indices into the shared DCT
    },
    Adam(AdamState),
}

pub struct Trion {
    metas: Vec<LayerMeta>,
    states: Vec<LayerState>,
    shared: BTreeMap<usize, Arc<SharedDct>>,
    pool: Arc<ThreadPool>,
    shards: ShardedWorkspace,
    rank: usize,
    mu: f32,
    ns_steps: usize,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    step: u64,
    instrument: bool,
    errors: BTreeMap<String, f64>,
}

impl Trion {
    pub fn new(metas: &[LayerMeta], cfg: &OptimizerConfig) -> Self {
        let shared = shared_dct_registry(metas);
        let (norm, use_makhoul) = match &cfg.projection {
            crate::projection::ProjectionKind::Dct { norm, use_makhoul } => {
                (*norm, *use_makhoul)
            }
            _ => (RankNorm::L2, true),
        };
        let states = metas
            .iter()
            .map(|m| {
                if m.kind.low_rank_eligible() {
                    let (rr, cc) = m.oriented();
                    let select = DctSelect::new(
                        shared[&cc].clone(),
                        cfg.rank.min(cc),
                        norm,
                        use_makhoul,
                    );
                    LayerState::LowRank { momentum: Matrix::zeros(rr, cc), select }
                } else {
                    LayerState::Adam(AdamState::new(m.rows, m.cols))
                }
            })
            .collect();
        let pool = pool_for(cfg);
        let shards = ShardedWorkspace::for_pool(&pool);
        Trion {
            metas: metas.to_vec(),
            states,
            shared,
            pool,
            shards,
            rank: cfg.rank,
            mu: cfg.mu,
            ns_steps: cfg.ns_steps,
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            weight_decay: cfg.weight_decay,
            step: 0,
            instrument: cfg.instrument,
            errors: BTreeMap::new(),
        }
    }

    /// Column indices currently selected for a layer (test/bench hook).
    pub fn indices(&self, layer: usize) -> Option<&[usize]> {
        match &self.states[layer] {
            LayerState::LowRank { select, .. } => Some(select.indices()),
            _ => None,
        }
    }
}

impl Optimizer for Trion {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let t = self.step;
        let (beta1, beta2, eps, weight_decay, mu, ns_steps, instrument) = (
            self.beta1, self.beta2, self.eps, self.weight_decay, self.mu,
            self.ns_steps, self.instrument,
        );
        let metas = &self.metas;
        // Per-layer errors land in a shared map under a mutex; values are
        // per-layer-deterministic and BTreeMap orders by key, so the
        // instrumented output is identical for any thread count.
        let errors = Mutex::new(std::mem::take(&mut self.errors));
        let pool = Arc::clone(&self.pool);
        step_layers_parallel(
            &pool,
            &mut self.shards,
            &mut self.states,
            params,
            grads,
            |i, state, param, grad, ws| {
                let meta = &metas[i];
                match state {
                    LayerState::Adam(st) => {
                        st.update(param, grad, lr, beta1, beta2, eps, 0.0, t)
                    }
                    LayerState::LowRank { momentum, select } => {
                        let (rr, cc) = meta.oriented();
                        let r = select.rank();
                        // B = M + G — accumulate the gradient straight into
                        // the momentum, transposing on the fly for wide layers
                        if meta.needs_transpose() {
                            momentum.axpy_t(1.0, grad);
                        } else {
                            momentum.axpy(1.0, grad);
                        }
                        // S = DCT(B); select top-r; b = S[:, i_t]  (one pass)
                        let mut b_low = ws.take_uninit(rr, r);
                        select.refresh_and_project_into(momentum, &mut b_low, ws);
                        // error feedback: M = B − (1−μ)·b·Qᵀ
                        let mut back = ws.take_uninit(rr, cc);
                        select.back_into(&b_low, &mut back, ws);
                        momentum.axpy(-(1.0 - mu), &back);
                        // Newton–Schulz on the LOW-RANK momentum (R×r),
                        // workspace-backed so the whole step stays
                        // allocation-free (tests/alloc_steady_state.rs)
                        let mut o_low = ws.take_uninit(rr, r);
                        newton_schulz_into(&b_low, ns_steps, &mut o_low, ws);
                        if instrument {
                            // restore B while `back` still holds back(b_low),
                            // then repurpose `back` for O — computed only once
                            let mut b_now = ws.take_uninit(rr, cc);
                            b_now.copy_from(momentum);
                            b_now.axpy(1.0 - mu, &back);
                            select.back_into(&o_low, &mut back, ws); // back = O
                            b_now.axpy(-1.0, &back);
                            errors
                                .lock()
                                .unwrap()
                                .insert(meta.name.clone(), b_now.fro_norm());
                            ws.give(b_now);
                        } else {
                            // O = o·Qᵀ, applied without materializing the
                            // transpose
                            select.back_into(&o_low, &mut back, ws);
                        }
                        param.scale(1.0 - lr * weight_decay);
                        let scale = -lr * shape_factor(rr, cc);
                        if meta.needs_transpose() {
                            param.axpy_t(scale, &back);
                        } else {
                            param.axpy(scale, &back);
                        }
                        ws.give(o_low);
                        ws.give(back);
                        ws.give(b_low);
                    }
                }
            },
        );
        self.errors = errors.into_inner().unwrap();
    }

    fn memory_report(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        for st in &self.states {
            match st {
                LayerState::LowRank { momentum, select } => {
                    r.add("momentum", momentum.bytes());
                    r.add("indices", select.state_bytes()); // r int32 / layer
                }
                LayerState::Adam(a) => {
                    r.add("adam_m", a.m.bytes());
                    r.add("adam_v", a.v.bytes());
                }
            }
        }
        for (dim, dct) in &self.shared {
            r.share(&format!("dct_matrix_{dim}"), dct.bytes());
        }
        r
    }

    fn name(&self) -> &'static str {
        "trion"
    }

    fn projection_errors(&self) -> Option<&BTreeMap<String, f64>> {
        if self.instrument {
            Some(&self.errors)
        } else {
            None
        }
    }

    fn broadcast_bytes(&self, meta: &LayerMeta) -> u64 {
        if meta.kind.low_rank_eligible() {
            // o_t (R×r floats) + i_t (r int32): §2.3's communication saving
            let (rr, cc) = meta.oriented();
            let r = self.rank.min(cc);
            (rr * r * 4 + r * 4) as u64
        } else {
            (meta.rows * meta.cols * 4) as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::optim::common::ParamKind;
    use super::*;
    use crate::projection::Projection;
    use crate::util::Pcg64;

    fn cfg(rank: usize) -> OptimizerConfig {
        OptimizerConfig { rank, weight_decay: 0.0, mu: 0.9, ..Default::default() }
    }

    #[test]
    fn converges_on_quadratic() {
        let mut rng = Pcg64::seed(0);
        let t = Matrix::randn(10, 8, 0.5, &mut rng);
        let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
        let mut opt = Trion::new(&metas, &cfg(4));
        let mut params = vec![Matrix::zeros(10, 8)];
        for _ in 0..500 {
            let g = params[0].sub(&t).scaled(2.0);
            opt.step(&mut params, &[g], 0.02);
        }
        let err = params[0].sub(&t).fro_norm() / t.fro_norm();
        assert!(err < 0.35, "rel err={err}");
    }

    #[test]
    fn memory_beats_dion() {
        // Same model: Trion stores r ints/layer + one shared DCT; Dion
        // stores a C×r f32 projector per layer. For enough layers Trion wins.
        let metas: Vec<LayerMeta> = (0..12)
            .map(|i| LayerMeta::new(&format!("w{i}"), 128, 128, ParamKind::Linear))
            .collect();
        let c = cfg(64);
        let trion = Trion::new(&metas, &c).memory_report();
        let dion = super::super::Dion::new(&metas, &c).memory_report();
        assert!(
            trion.total() < dion.total(),
            "trion={} dion={}",
            trion.total(),
            dion.total()
        );
        // and the per-layer index cost is exactly r·4 bytes
        assert_eq!(trion.per_layer["indices"], 12 * 64 * 4);
    }

    #[test]
    fn broadcast_is_low_rank() {
        let metas = vec![LayerMeta::new("w", 128, 64, ParamKind::Linear)];
        let opt = Trion::new(&metas, &cfg(8));
        let full = (128 * 64 * 4) as u64;
        let low = opt.broadcast_bytes(&metas[0]);
        assert!(low < full / 4, "low={low} full={full}");
    }

    #[test]
    fn update_lies_in_selected_subspace() {
        let mut rng = Pcg64::seed(3);
        let metas = vec![LayerMeta::new("w", 12, 10, ParamKind::Linear)];
        let mut opt = Trion::new(&metas, &cfg(3));
        let mut params = vec![Matrix::zeros(12, 10)];
        let g = Matrix::randn(12, 10, 1.0, &mut rng);
        opt.step(&mut params, &[g], 1.0);
        // params = -sf·O where O = o·Q_rᵀ: projecting onto Q_r is lossless
        if let LayerState::LowRank { select, .. } = &opt.states[0] {
            let o = params[0].scaled(-1.0);
            let low = select.project(&o);
            let back = select.back(&low);
            assert!(o.max_abs_diff(&back) < 1e-4);
        } else {
            panic!();
        }
    }

    #[test]
    fn mu_one_keeps_full_momentum() {
        let metas = vec![LayerMeta::new("w", 8, 6, ParamKind::Linear)];
        let c = OptimizerConfig { rank: 2, mu: 1.0, weight_decay: 0.0, ..Default::default() };
        let mut opt = Trion::new(&metas, &c);
        let mut rng = Pcg64::seed(4);
        let mut params = vec![Matrix::zeros(8, 6)];
        let g = Matrix::randn(8, 6, 1.0, &mut rng);
        opt.step(&mut params, &[g.clone()], 0.01);
        if let LayerState::LowRank { momentum, .. } = &opt.states[0] {
            assert!(momentum.max_abs_diff(&g) < 1e-5);
        } else {
            panic!();
        }
    }

    #[test]
    fn projection_error_below_dion_on_dct_friendly_signal() {
        // Construct gradients with smooth (low-frequency) row structure —
        // the regime where DCT selection captures more energy than one
        // power-iteration step. Mirrors the Figure-1 experiment.
        let metas = vec![LayerMeta::new("w", 32, 24, ParamKind::Linear)];
        let mut c = cfg(4);
        c.instrument = true;
        let mut trion = Trion::new(&metas, &c);
        let mut dion = super::super::Dion::new(&metas, &c);
        let mut pt = vec![Matrix::zeros(32, 24)];
        let mut pd = vec![Matrix::zeros(32, 24)];
        let mut rng = Pcg64::seed(5);
        let mut last = (0.0, 0.0);
        for step in 0..30 {
            let phase = step as f32 * 0.1;
            let g = Matrix::from_fn(32, 24, |i, j| {
                ((j as f32 * 0.3 + phase).sin() + 0.05 * rng.normal_f32())
                    * (1.0 + i as f32 / 32.0)
            });
            trion.step(&mut pt, &[g.clone()], 0.01);
            dion.step(&mut pd, &[g], 0.01);
            last = (
                trion.errors["w"],
                dion.projection_errors().unwrap()["w"],
            );
        }
        assert!(last.0 <= last.1 * 1.2, "trion={} dion={}", last.0, last.1);
    }
}
