//! Dense AdamW (Loshchilov & Hutter, 2019) — the full-rank reference in
//! every table of the paper.
//!
//! Allocation-free at steady state: `AdamState::update_ws` fuses the moment
//! update and parameter write in one in-place pass for f32 stores, and
//! stages non-f32 stores through the owned [`Workspace`] pool. With
//! `state-dtype=bf16|q8` this baseline becomes the "low-precision Adam"
//! reference row of the memory sweep (`make bench-mem`).

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::tensor::{Matrix, StateDtype, Workspace};
use crate::util::codec::{self, ByteReader};

use super::common::{AdamState, LayerMeta, MemoryReport, Optimizer, OptimizerConfig};

pub struct AdamW {
    states: Vec<AdamState>,
    metas: Vec<LayerMeta>,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    state_dtype: StateDtype,
    step: u64,
    /// De/quantization scratch for non-f32 moment stores (unused — and
    /// never touched — on the f32 default path).
    ws: Workspace,
}

impl AdamW {
    pub fn new(metas: &[LayerMeta], cfg: &OptimizerConfig) -> Self {
        AdamW {
            states: metas
                .iter()
                .map(|m| AdamState::with_dtype(cfg.state_dtype, m.rows, m.cols))
                .collect(),
            metas: metas.to_vec(),
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            weight_decay: cfg.weight_decay,
            state_dtype: cfg.state_dtype,
            step: 0,
            ws: Workspace::new(),
        }
    }

    fn fingerprint(&self) -> String {
        // hyper-parameters feed every post-resume step, so they are part
        // of the resume contract (per-store shape checks cover the rest)
        format!(
            "adamw layers={} state={} b1={} b2={} eps={} wd={}",
            self.metas.len(),
            self.state_dtype.name(),
            self.beta1,
            self.beta2,
            self.eps,
            self.weight_decay
        )
    }
}

impl Optimizer for AdamW {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        assert_eq!(params.len(), self.states.len());
        self.step += 1;
        for ((p, g), (st, meta)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.states.iter_mut().zip(&self.metas))
        {
            // Decoupled weight decay only on weight matrices, not norms.
            let wd = if meta.kind == super::ParamKind::Norm {
                0.0
            } else {
                self.weight_decay
            };
            st.update_ws(p, g, lr, self.beta1, self.beta2, self.eps, wd, self.step, &mut self.ws);
        }
    }

    fn memory_report(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        for st in &self.states {
            r.add("adam_m", st.m.bytes());
            r.add("adam_v", st.v.bytes());
        }
        r
    }

    fn name(&self) -> &str {
        "adamw"
    }

    fn projection_errors(&self) -> Option<&BTreeMap<String, f64>> {
        None
    }

    fn save_state(&self) -> Option<Vec<u8>> {
        let mut out = Vec::new();
        codec::put_str(&mut out, &self.fingerprint());
        codec::put_u64(&mut out, self.step);
        for st in &self.states {
            st.save(&mut out);
        }
        Some(out)
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(bytes);
        let fp = r.take_str()?;
        ensure!(
            fp == self.fingerprint(),
            "checkpoint fingerprint {fp:?} != this optimizer {:?}",
            self.fingerprint()
        );
        self.step = r.take_u64()?;
        for st in &mut self.states {
            st.load_from(&mut r)?;
        }
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::common::ParamKind;
    use crate::util::Pcg64;

    fn quad_setup() -> (Vec<LayerMeta>, Vec<Matrix>, Matrix) {
        // minimize ‖P − T‖² for a random target T; grad = 2(P − T)
        let mut rng = Pcg64::seed(0);
        let t = Matrix::randn(6, 6, 1.0, &mut rng);
        let metas = vec![LayerMeta::new("w", 6, 6, ParamKind::Linear)];
        let params = vec![Matrix::zeros(6, 6)];
        (metas, params, t)
    }

    #[test]
    fn converges_on_quadratic() {
        let (metas, mut params, t) = quad_setup();
        let cfg = OptimizerConfig { weight_decay: 0.0, ..Default::default() };
        let mut opt = AdamW::new(&metas, &cfg);
        let mut last = f64::MAX;
        for _ in 0..300 {
            let g = params[0].sub(&t).scaled(2.0);
            opt.step(&mut params, &[g], 0.05);
            last = params[0].sub(&t).fro_norm_sq();
        }
        assert!(last < 1e-2, "final err {last}");
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let metas = vec![LayerMeta::new("w", 3, 3, ParamKind::Linear)];
        let cfg = OptimizerConfig { weight_decay: 0.5, ..Default::default() };
        let mut opt = AdamW::new(&metas, &cfg);
        let mut params = vec![Matrix::eye(3)];
        let zero_g = vec![Matrix::zeros(3, 3)];
        let before = params[0].fro_norm();
        opt.step(&mut params, &zero_g, 0.1);
        assert!(params[0].fro_norm() < before);
    }

    #[test]
    fn norm_params_skip_weight_decay() {
        let metas = vec![LayerMeta::new("n", 1, 4, ParamKind::Norm)];
        let cfg = OptimizerConfig { weight_decay: 0.5, ..Default::default() };
        let mut opt = AdamW::new(&metas, &cfg);
        let mut params = vec![Matrix::from_vec(1, 4, vec![1.0; 4])];
        opt.step(&mut params, &[Matrix::zeros(1, 4)], 0.1);
        assert_eq!(params[0].data, vec![1.0; 4]);
    }

    #[test]
    fn memory_is_two_buffers_per_param() {
        let metas = vec![
            LayerMeta::new("a", 4, 5, ParamKind::Linear),
            LayerMeta::new("b", 2, 3, ParamKind::Norm),
        ];
        let opt = AdamW::new(&metas, &OptimizerConfig::default());
        let rep = opt.memory_report();
        assert_eq!(rep.total(), 2 * (4 * 5 * 4 + 2 * 3 * 4) as u64);
    }
}
