//! **DCT-AdamW** (Algorithms 2–3) — the paper's second contribution.
//!
//! LDAdamW with the block power iteration replaced by DCT dynamic column
//! selection. Because the basis is a *fixed* orthogonal matrix, the
//! subspace rotation collapses to a 0/1 index-matching matrix:
//! `R = Q_prevᵀ·Q_crt = I[idx_prev, idx_crt]` — rotating the moments is a
//! permutation-with-drop, no matmul needed. Per-layer state is two sets of
//! `r` indices (vs LDAdam's two `C×r` projectors) plus optional quantized
//! error feedback (8-bit is the paper's lowest non-degrading resolution).
//!
//! The step is allocation-free at steady state: every temporary (oriented
//! gradient, similarities, projection, back-projection, update) lives in
//! the optimizer's per-shard [`Workspace`] pools, enforced by the
//! counting-allocator test in `tests/alloc_steady_state.rs`. Layers step
//! concurrently through `step_layers_parallel` — disjoint layers, one
//! workspace shard per chunk — and the result is bit-identical for any
//! thread count (`tests/parallel_determinism.rs`).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::parallel::{ShardedWorkspace, ThreadPool};
use crate::projection::{DctSelect, Projection, RankNorm, SharedDct};
use crate::tensor::{Matrix, Workspace};

use super::common::{
    adam_moments_into, pool_for, shared_dct_registry, step_layers_parallel,
    take_oriented_owned, AdamScalars, AdamState, LayerMeta, MemoryReport,
    Optimizer, OptimizerConfig,
};
use super::error_feedback::EfBuffer;

enum LayerState {
    LowRank {
        select: DctSelect,
        idx_prev: Vec<usize>,
        m: Matrix, // R×r
        v: Matrix, // R×r
        ef: EfBuffer,
        first: bool,
    },
    Adam(AdamState),
}

pub struct DctAdamW {
    metas: Vec<LayerMeta>,
    states: Vec<LayerState>,
    shared: BTreeMap<usize, Arc<SharedDct>>,
    pool: Arc<ThreadPool>,
    shards: ShardedWorkspace,
    update_interval: usize,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    step: u64,
}

/// Rotate subspace moments for a *fixed orthogonal basis*: since
/// `QᵀQ = I`, `R[i][j] = 1 ⇔ idx_prev[i] == idx_crt[j]`, so `m·R` keeps the
/// columns whose index survives and zeroes the rest.
pub fn rotate_fixed_basis(m: &Matrix, idx_prev: &[usize], idx_crt: &[usize]) -> Matrix {
    let mut out = m.clone();
    let mut ws = Workspace::new();
    rotate_fixed_basis_into(&mut out, idx_prev, idx_crt, &mut ws);
    out
}

/// In-place [`rotate_fixed_basis`]: `m` (R×|prev|) becomes the rotated
/// R×|crt| matrix, staging through a workspace buffer.
pub fn rotate_fixed_basis_into(
    m: &mut Matrix,
    idx_prev: &[usize],
    idx_crt: &[usize],
    ws: &mut Workspace,
) {
    debug_assert_eq!(m.cols, idx_prev.len());
    let mut out = ws.take(m.rows, idx_crt.len());
    // Both index lists are sorted ascending — merge them.
    let (mut a, mut b) = (0usize, 0usize);
    while a < idx_prev.len() && b < idx_crt.len() {
        match idx_prev[a].cmp(&idx_crt[b]) {
            std::cmp::Ordering::Less => a += 1,
            std::cmp::Ordering::Greater => b += 1,
            std::cmp::Ordering::Equal => {
                for i in 0..m.rows {
                    out.data[i * idx_crt.len() + b] = m.data[i * m.cols + a];
                }
                a += 1;
                b += 1;
            }
        }
    }
    m.copy_from(&out);
    ws.give(out);
}

impl DctAdamW {
    pub fn new(metas: &[LayerMeta], cfg: &OptimizerConfig) -> Self {
        let shared = shared_dct_registry(metas);
        let (norm, use_makhoul) = match &cfg.projection {
            crate::projection::ProjectionKind::Dct { norm, use_makhoul } => {
                (*norm, *use_makhoul)
            }
            _ => (RankNorm::L2, true),
        };
        let states = metas
            .iter()
            .map(|meta| {
                if meta.kind.low_rank_eligible() {
                    let (rr, cc) = meta.oriented();
                    let r = cfg.rank.min(cc);
                    LayerState::LowRank {
                        select: DctSelect::new(shared[&cc].clone(), r, norm, use_makhoul),
                        idx_prev: (0..r).collect(),
                        m: Matrix::zeros(rr, r),
                        v: Matrix::zeros(rr, r),
                        ef: EfBuffer::new(cfg.ef_mode, rr, cc),
                        first: true,
                    }
                } else {
                    LayerState::Adam(AdamState::new(meta.rows, meta.cols))
                }
            })
            .collect();
        let pool = pool_for(cfg);
        let shards = ShardedWorkspace::for_pool(&pool);
        DctAdamW {
            metas: metas.to_vec(),
            states,
            shared,
            pool,
            shards,
            update_interval: cfg.update_interval.max(1),
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            weight_decay: cfg.weight_decay,
            step: 0,
        }
    }
}

impl Optimizer for DctAdamW {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let t = self.step;
        let refresh = t == 1 || t % self.update_interval as u64 == 0;
        let (beta1, beta2, eps, weight_decay) =
            (self.beta1, self.beta2, self.eps, self.weight_decay);
        let metas = &self.metas;
        let pool = Arc::clone(&self.pool);
        step_layers_parallel(
            &pool,
            &mut self.shards,
            &mut self.states,
            params,
            grads,
            |i, state, param, grad, ws| {
                let meta = &metas[i];
                match state {
                    LayerState::Adam(st) => st.update(
                        param, grad, lr, beta1, beta2, eps, weight_decay, t,
                    ),
                    LayerState::LowRank { select, idx_prev, m, v, ef, first } => {
                        let (rr, cc) = meta.oriented();
                        let r = select.rank();
                        // oriented gradient (owned: EF mutates it)
                        let mut g = take_oriented_owned(meta, grad, ws);
                        ef.add_into(&mut g); // G ← G + Ξ
                        let mut g_low = ws.take_uninit(rr, r);
                        if refresh {
                            // remember the outgoing indices, then refresh
                            idx_prev.clear();
                            idx_prev.extend_from_slice(select.indices());
                            select.refresh_and_project_into(&g, &mut g_low, ws);
                            if !*first {
                                // rotation = index matching (fixed basis!)
                                rotate_fixed_basis_into(m, idx_prev, select.indices(), ws);
                                rotate_fixed_basis_into(v, idx_prev, select.indices(), ws);
                                // |v·R| — rotation here is 0/1 so abs is a
                                // no-op, kept for parity with Algorithm 2
                                for x in &mut v.data {
                                    *x = x.abs();
                                }
                            }
                            *first = false;
                        } else {
                            select.project_into(&g, &mut g_low, ws);
                        }
                        // Ξ ← G − g·Qᵀ  (residual built in the back buffer)
                        let mut back = ws.take_uninit(rr, cc);
                        select.back_into(&g_low, &mut back, ws);
                        back.sub_from(&g);
                        ef.store(&back);
                        // AdamW in the subspace — the shared fused kernel
                        let sc = AdamScalars::new(beta1, beta2, eps, t);
                        let mut u_low = ws.take_uninit(rr, r);
                        adam_moments_into(
                            &mut u_low.data, &g_low.data, &mut m.data, &mut v.data, &sc,
                        );
                        // U = u·Qᵀ, applied in the original orientation
                        // without materializing a transpose
                        select.back_into(&u_low, &mut back, ws);
                        param.scale(1.0 - lr * weight_decay);
                        if meta.needs_transpose() {
                            param.axpy_t(-lr, &back);
                        } else {
                            param.axpy(-lr, &back);
                        }
                        ws.give(u_low);
                        ws.give(back);
                        ws.give(g_low);
                        ws.give(g);
                    }
                }
            },
        );
    }

    fn memory_report(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        for st in &self.states {
            match st {
                LayerState::LowRank { select, idx_prev, m, v, ef, .. } => {
                    r.add("adam_m_low", m.bytes());
                    r.add("adam_v_low", v.bytes());
                    // two sets of r indices — the paper's memory claim
                    r.add("indices", select.state_bytes());
                    r.add("indices_prev", (idx_prev.len() * 4) as u64);
                    r.add("ef", ef.bytes());
                }
                LayerState::Adam(a) => {
                    r.add("adam_m", a.m.bytes());
                    r.add("adam_v", a.v.bytes());
                }
            }
        }
        for (dim, dct) in &self.shared {
            r.share(&format!("dct_matrix_{dim}"), dct.bytes());
        }
        r
    }

    fn name(&self) -> &'static str {
        "dct-adamw"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::common::{EfMode, ParamKind};
    use crate::util::Pcg64;

    fn cfg(rank: usize) -> OptimizerConfig {
        OptimizerConfig { rank, weight_decay: 0.0, ..Default::default() }
    }

    #[test]
    fn rotation_matches_matmul_definition() {
        // rotate_fixed_basis == m · (Q[:,prev]ᵀ Q[:,crt]) for orthogonal Q
        let mut rng = Pcg64::seed(0);
        let q = crate::fft::dct2_matrix(12);
        let prev = vec![0, 3, 5, 9];
        let crt = vec![3, 4, 9, 11];
        let m = Matrix::randn(6, 4, 1.0, &mut rng);
        let got = rotate_fixed_basis(&m, &prev, &crt);
        let qp = q.select_columns(&prev);
        let qc = q.select_columns(&crt);
        let rot = crate::tensor::matmul_at_b(&qp, &qc);
        let want = crate::tensor::matmul(&m, &rot);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn rotation_into_handles_rank_change() {
        let mut rng = Pcg64::seed(7);
        let mut m = Matrix::randn(3, 4, 1.0, &mut rng);
        let want = rotate_fixed_basis(&m, &[0, 2, 5, 7], &[2, 3, 7]);
        let mut ws = Workspace::new();
        rotate_fixed_basis_into(&mut m, &[0, 2, 5, 7], &[2, 3, 7], &mut ws);
        assert_eq!(m, want);
        assert_eq!(m.shape(), (3, 3));
    }

    #[test]
    fn converges_on_quadratic() {
        let mut rng = Pcg64::seed(1);
        let t = Matrix::randn(10, 8, 0.5, &mut rng);
        let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
        let mut opt = DctAdamW::new(&metas, &cfg(4));
        let mut params = vec![Matrix::zeros(10, 8)];
        for _ in 0..500 {
            let g = params[0].sub(&t).scaled(2.0);
            opt.step(&mut params, &[g], 0.05);
        }
        let err = params[0].sub(&t).fro_norm() / t.fro_norm();
        assert!(err < 0.15, "rel err={err}");
    }

    #[test]
    fn memory_far_below_ldadamw() {
        let metas: Vec<LayerMeta> = (0..8)
            .map(|i| LayerMeta::new(&format!("w{i}"), 128, 128, ParamKind::Linear))
            .collect();
        let c = OptimizerConfig { rank: 64, ef_mode: EfMode::Q8, ..Default::default() };
        let dct = DctAdamW::new(&metas, &c).memory_report();
        let ld = super::super::LdAdamW::new(&metas, &c).memory_report();
        assert!(
            dct.total() < ld.total(),
            "dct={} ld={}",
            dct.total(),
            ld.total()
        );
        // index state is exactly 2·r·4 bytes per layer
        assert_eq!(
            dct.per_layer["indices"] + dct.per_layer["indices_prev"],
            8 * 2 * 64 * 4
        );
    }

    #[test]
    fn t_u_respected_like_galore() {
        let metas = vec![LayerMeta::new("w", 12, 10, ParamKind::Linear)];
        let mut c = cfg(3);
        c.update_interval = 4;
        let mut opt = DctAdamW::new(&metas, &c);
        let mut rng = Pcg64::seed(2);
        let mut params = vec![Matrix::zeros(12, 10)];
        let mut all_idx = Vec::new();
        for _ in 0..5 {
            let g = Matrix::randn(12, 10, 1.0, &mut rng);
            opt.step(&mut params, &[g], 0.01);
            if let LayerState::LowRank { select, .. } = &opt.states[0] {
                all_idx.push(select.indices().to_vec());
            }
        }
        // t=1 refreshes; t=2,3 reuse the same indices
        assert_eq!(all_idx[0], all_idx[1]);
        assert_eq!(all_idx[1], all_idx[2]);
        // t=4 refreshed: idx_prev must now hold the pre-refresh indices
        if let LayerState::LowRank { idx_prev, .. } = &opt.states[0] {
            assert_eq!(idx_prev, &all_idx[2]);
        }
    }

    #[test]
    fn wide_layer_transposed_update_matches_tall_layout() {
        // A wide layer (orient → transpose) must produce the transpose of
        // the update its tall twin produces from the transposed gradient.
        let mut rng = Pcg64::seed(8);
        let g = Matrix::randn(6, 15, 1.0, &mut rng); // wide 6×15 → oriented 15×6
        let metas_wide = vec![LayerMeta::new("w", 6, 15, ParamKind::Linear)];
        let metas_tall = vec![LayerMeta::new("w", 15, 6, ParamKind::Linear)];
        let mut wide = DctAdamW::new(&metas_wide, &cfg(3));
        let mut tall = DctAdamW::new(&metas_tall, &cfg(3));
        let mut pw = vec![Matrix::zeros(6, 15)];
        let mut pt = vec![Matrix::zeros(15, 6)];
        for _ in 0..3 {
            wide.step(&mut pw, &[g.clone()], 0.01);
            tall.step(&mut pt, &[g.transpose()], 0.01);
        }
        assert!(pw[0].max_abs_diff(&pt[0].transpose()) < 1e-6);
    }

    #[test]
    fn ef_q8_tracks_out_of_subspace_gradient() {
        let metas = vec![LayerMeta::new("w", 8, 8, ParamKind::Linear)];
        let mut c = cfg(1);
        c.ef_mode = EfMode::Q8;
        let mut opt = DctAdamW::new(&metas, &c);
        let mut rng = Pcg64::seed(3);
        let g0 = Matrix::randn(8, 8, 1.0, &mut rng);
        let mut params = vec![Matrix::zeros(8, 8)];
        for _ in 0..60 {
            opt.step(&mut params, &[g0.clone()], 0.01);
        }
        let mut agree = 0;
        for k in 0..64 {
            if params[0].data[k] * g0.data[k] < 0.0 {
                agree += 1;
            }
        }
        assert!(agree > 45, "agree={agree}/64");
    }

    #[test]
    fn no_ef_mode_allocates_nothing() {
        let metas = vec![LayerMeta::new("w", 16, 16, ParamKind::Linear)];
        let mut c = cfg(4);
        c.ef_mode = EfMode::None;
        let rep = DctAdamW::new(&metas, &c).memory_report();
        assert_eq!(rep.per_layer["ef"], 0);
    }
}
