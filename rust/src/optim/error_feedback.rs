//! Error-feedback buffers (§2.4): full-precision f32 or 8-bit quantized
//! (MicroAdam-style symmetric per-tensor quantization — the paper reports
//! 8 bits as the lowest resolution that does not degrade the optimizer).
//!
//! Since the typed-storage refactor this is a thin facade over
//! [`StateStore`] — the f32/Q8 pack/unpack code that used to live here is
//! the store's (SIMD-dispatched) implementation now, byte-for-byte the same
//! arithmetic. The engine's `EfResidual` policy holds a `StateStore`
//! directly; this wrapper keeps the historical `EfBuffer` API for the
//! frozen legacy step loops in `tests/engine_equivalence.rs` and maps
//! [`EfMode`] onto the matching [`StateDtype`] (`None` ⇒ no store at all).

use crate::optim::common::EfMode;
use crate::tensor::{Matrix, StateDtype, StateStore};

impl EfMode {
    /// The storage dtype backing this EF resolution (`None` ⇒ no buffer).
    pub fn state_dtype(self) -> Option<StateDtype> {
        match self {
            EfMode::None => None,
            EfMode::F32 => Some(StateDtype::F32),
            EfMode::Q8 => Some(StateDtype::Q8),
        }
    }
}

/// A single layer's EF buffer — an optional typed store.
pub struct EfBuffer {
    store: Option<StateStore>,
}

impl EfBuffer {
    pub fn new(mode: EfMode, rows: usize, cols: usize) -> Self {
        EfBuffer { store: mode.state_dtype().map(|d| StateStore::zeros(d, rows, cols)) }
    }

    /// Add the stored error into `g` in place (`G ← G + Ξ`).
    pub fn add_into(&self, g: &mut Matrix) {
        if let Some(st) = &self.store {
            st.add_into(g);
        }
    }

    /// Store a new error (`Ξ ← err`), quantizing if configured.
    pub fn store(&mut self, err: &Matrix) {
        if let Some(st) = &mut self.store {
            st.store_from(err);
        }
    }

    /// Persistent bytes of this buffer.
    pub fn bytes(&self) -> u64 {
        self.store.as_ref().map_or(0, |st| st.bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn f32_roundtrip_exact() {
        let mut rng = Pcg64::seed(0);
        let err = Matrix::randn(5, 7, 1.0, &mut rng);
        let mut buf = EfBuffer::new(EfMode::F32, 5, 7);
        buf.store(&err);
        let mut g = Matrix::zeros(5, 7);
        buf.add_into(&mut g);
        assert_eq!(g, err);
    }

    #[test]
    fn q8_roundtrip_bounded() {
        let mut rng = Pcg64::seed(1);
        let err = Matrix::randn(8, 9, 1.0, &mut rng);
        let mut buf = EfBuffer::new(EfMode::Q8, 8, 9);
        buf.store(&err);
        let mut g = Matrix::zeros(8, 9);
        buf.add_into(&mut g);
        let max = err.abs_max();
        let tol = max / 127.0 * 0.51 + 1e-6;
        assert!(g.max_abs_diff(&err) <= tol, "{} > {tol}", g.max_abs_diff(&err));
    }

    #[test]
    fn q8_uses_quarter_memory() {
        let buf8 = EfBuffer::new(EfMode::Q8, 10, 10);
        let buf32 = EfBuffer::new(EfMode::F32, 10, 10);
        assert_eq!(buf32.bytes(), 400);
        assert_eq!(buf8.bytes(), 104);
        assert_eq!(EfBuffer::new(EfMode::None, 10, 10).bytes(), 0);
    }

    #[test]
    fn none_mode_is_noop() {
        let mut buf = EfBuffer::new(EfMode::None, 3, 3);
        let mut rng = Pcg64::seed(2);
        buf.store(&Matrix::randn(3, 3, 1.0, &mut rng));
        let mut g = Matrix::zeros(3, 3);
        buf.add_into(&mut g);
        assert_eq!(g, Matrix::zeros(3, 3));
    }

    #[test]
    fn zero_error_stores_cleanly() {
        let mut buf = EfBuffer::new(EfMode::Q8, 4, 4);
        buf.store(&Matrix::zeros(4, 4));
        let mut g = Matrix::zeros(4, 4);
        buf.add_into(&mut g);
        assert!(g.data.iter().all(|&v| v == 0.0));
    }
}
