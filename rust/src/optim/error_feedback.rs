//! Error-feedback buffers (§2.4): full-precision f32 or 8-bit quantized
//! (MicroAdam-style symmetric per-tensor quantization — the paper reports
//! 8 bits as the lowest resolution that does not degrade the optimizer).

use crate::optim::common::EfMode;
use crate::tensor::Matrix;

/// A single layer's EF buffer.
pub enum EfBuffer {
    None { rows: usize, cols: usize },
    F32(Matrix),
    /// int8 payload + per-tensor scale.
    Q8 { q: Vec<i8>, scale: f32, rows: usize, cols: usize },
}

impl EfBuffer {
    pub fn new(mode: EfMode, rows: usize, cols: usize) -> Self {
        match mode {
            EfMode::None => EfBuffer::None { rows, cols },
            EfMode::F32 => EfBuffer::F32(Matrix::zeros(rows, cols)),
            EfMode::Q8 => EfBuffer::Q8 {
                q: vec![0; rows * cols],
                scale: 0.0,
                rows,
                cols,
            },
        }
    }

    /// Add the stored error into `g` in place (`G ← G + Ξ`).
    pub fn add_into(&self, g: &mut Matrix) {
        match self {
            EfBuffer::None { .. } => {}
            EfBuffer::F32(e) => g.axpy(1.0, e),
            EfBuffer::Q8 { q, scale, .. } => {
                if *scale != 0.0 {
                    for (gv, &qv) in g.data.iter_mut().zip(q.iter()) {
                        *gv += qv as f32 * scale;
                    }
                }
            }
        }
    }

    /// Store a new error (`Ξ ← err`), quantizing if configured.
    pub fn store(&mut self, err: &Matrix) {
        match self {
            EfBuffer::None { .. } => {}
            EfBuffer::F32(e) => e.data.copy_from_slice(&err.data),
            EfBuffer::Q8 { q, scale, .. } => {
                let max = err.abs_max();
                let s = max / 127.0 + 1e-12;
                *scale = s;
                for (qv, &ev) in q.iter_mut().zip(err.data.iter()) {
                    *qv = (ev / s).round().clamp(-127.0, 127.0) as i8;
                }
            }
        }
    }

    /// Persistent bytes of this buffer.
    pub fn bytes(&self) -> u64 {
        match self {
            EfBuffer::None { .. } => 0,
            EfBuffer::F32(m) => m.bytes(),
            EfBuffer::Q8 { q, .. } => q.len() as u64 + 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn f32_roundtrip_exact() {
        let mut rng = Pcg64::seed(0);
        let err = Matrix::randn(5, 7, 1.0, &mut rng);
        let mut buf = EfBuffer::new(EfMode::F32, 5, 7);
        buf.store(&err);
        let mut g = Matrix::zeros(5, 7);
        buf.add_into(&mut g);
        assert_eq!(g, err);
    }

    #[test]
    fn q8_roundtrip_bounded() {
        let mut rng = Pcg64::seed(1);
        let err = Matrix::randn(8, 9, 1.0, &mut rng);
        let mut buf = EfBuffer::new(EfMode::Q8, 8, 9);
        buf.store(&err);
        let mut g = Matrix::zeros(8, 9);
        buf.add_into(&mut g);
        let max = err.abs_max();
        let tol = max / 127.0 * 0.51 + 1e-6;
        assert!(g.max_abs_diff(&err) <= tol, "{} > {tol}", g.max_abs_diff(&err));
    }

    #[test]
    fn q8_uses_quarter_memory() {
        let buf8 = EfBuffer::new(EfMode::Q8, 10, 10);
        let buf32 = EfBuffer::new(EfMode::F32, 10, 10);
        assert_eq!(buf32.bytes(), 400);
        assert_eq!(buf8.bytes(), 104);
        assert_eq!(EfBuffer::new(EfMode::None, 10, 10).bytes(), 0);
    }

    #[test]
    fn none_mode_is_noop() {
        let mut buf = EfBuffer::new(EfMode::None, 3, 3);
        let mut rng = Pcg64::seed(2);
        buf.store(&Matrix::randn(3, 3, 1.0, &mut rng));
        let mut g = Matrix::zeros(3, 3);
        buf.add_into(&mut g);
        assert_eq!(g, Matrix::zeros(3, 3));
    }

    #[test]
    fn zero_error_stores_cleanly() {
        let mut buf = EfBuffer::new(EfMode::Q8, 4, 4);
        buf.store(&Matrix::zeros(4, 4));
        let mut g = Matrix::zeros(4, 4);
        buf.add_into(&mut g);
        assert!(g.data.iter().all(|&v| v == 0.0));
    }
}
