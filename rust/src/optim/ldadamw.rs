//! LDAdamW (Robert et al., 2025): low-dimensional AdamW with block
//! power-iteration projections refreshed **every step**, smooth subspace
//! transition (momentum rotation `R = Q_prevᵀ·Q_crt`) and full error
//! feedback. Stores *two consecutive projection matrices per layer* — the
//! memory overhead DCT-AdamW's index-only state removes.

use std::sync::Arc;

use crate::parallel::{ShardedWorkspace, ThreadPool};
use crate::projection::{BlockPower, Projection};
use crate::tensor::{matmul_into, Matrix};

use super::common::{
    adam_moments_into, pool_for, step_layers_parallel, take_oriented_owned,
    AdamScalars, AdamState, LayerMeta, MemoryReport, Optimizer, OptimizerConfig,
};
use super::error_feedback::EfBuffer;
use crate::optim::common::EfMode;

enum LayerState {
    LowRank {
        proj: BlockPower,
        prev_basis: Matrix, // C×r — the second stored projector
        m: Matrix,          // R×r
        v: Matrix,          // R×r
        ef: EfBuffer,       // R×C error feedback
        first: bool,
    },
    Adam(AdamState),
}

pub struct LdAdamW {
    metas: Vec<LayerMeta>,
    states: Vec<LayerState>,
    pool: Arc<ThreadPool>,
    shards: ShardedWorkspace,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    step: u64,
}

impl LdAdamW {
    pub fn new(metas: &[LayerMeta], cfg: &OptimizerConfig) -> Self {
        let states = metas
            .iter()
            .map(|meta| {
                if meta.kind.low_rank_eligible() {
                    let (rr, cc) = meta.oriented();
                    let r = cfg.rank.min(cc).min(rr);
                    LayerState::LowRank {
                        proj: BlockPower::new(cc, r, 2),
                        prev_basis: Matrix::zeros(cc, r),
                        m: Matrix::zeros(rr, r),
                        v: Matrix::zeros(rr, r),
                        // LDAdam's EF is full-precision
                        ef: EfBuffer::new(EfMode::F32, rr, cc),
                        first: true,
                    }
                } else {
                    LayerState::Adam(AdamState::new(meta.rows, meta.cols))
                }
            })
            .collect();
        let pool = pool_for(cfg);
        let shards = ShardedWorkspace::for_pool(&pool);
        LdAdamW {
            metas: metas.to_vec(),
            states,
            pool,
            shards,
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            weight_decay: cfg.weight_decay,
            step: 0,
        }
    }
}

impl Optimizer for LdAdamW {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let t = self.step;
        let (beta1, beta2, eps, weight_decay) =
            (self.beta1, self.beta2, self.eps, self.weight_decay);
        let metas = &self.metas;
        let pool = Arc::clone(&self.pool);
        step_layers_parallel(
            &pool,
            &mut self.shards,
            &mut self.states,
            params,
            grads,
            |i, state, param, grad, ws| {
                let meta = &metas[i];
                match state {
                    LayerState::Adam(st) => st.update(
                        param, grad, lr, beta1, beta2, eps, weight_decay, t,
                    ),
                    LayerState::LowRank { proj, prev_basis, m, v, ef, first } => {
                        let (rr, cc) = meta.oriented();
                        let r = proj.rank();
                        // oriented gradient (owned: EF mutates it)
                        let mut g = take_oriented_owned(meta, grad, ws);
                        // G ← G + Ξ (error feedback)
                        ef.add_into(&mut g);
                        // refresh subspace every step (block power, warm start)
                        let mut g_low = ws.take_uninit(rr, r);
                        proj.refresh_and_project_into(&g, &mut g_low, ws);
                        // rotate moments into the new subspace
                        if !*first {
                            let mut rot = ws.take_uninit(r, r);
                            proj.rotation_into(prev_basis, &mut rot, ws);
                            let mut tmp = ws.take_uninit(rr, r);
                            matmul_into(m, &rot, &mut tmp);
                            m.copy_from(&tmp);
                            matmul_into(v, &rot, &mut tmp);
                            v.copy_from(&tmp);
                            for x in &mut v.data {
                                *x = x.abs();
                            }
                            ws.give(tmp);
                            ws.give(rot);
                        }
                        *first = false;
                        proj.basis_into(prev_basis);
                        // store new projection error (residual in `back`)
                        let mut back = ws.take_uninit(rr, cc);
                        proj.back_into(&g_low, &mut back, ws);
                        back.sub_from(&g);
                        ef.store(&back);
                        // Adam math in the subspace — the shared fused kernel
                        let sc = AdamScalars::new(beta1, beta2, eps, t);
                        let mut u_low = ws.take_uninit(rr, r);
                        adam_moments_into(
                            &mut u_low.data, &g_low.data, &mut m.data, &mut v.data, &sc,
                        );
                        proj.back_into(&u_low, &mut back, ws);
                        param.scale(1.0 - lr * weight_decay);
                        if meta.needs_transpose() {
                            param.axpy_t(-lr, &back);
                        } else {
                            param.axpy(-lr, &back);
                        }
                        ws.give(u_low);
                        ws.give(back);
                        ws.give(g_low);
                        ws.give(g);
                    }
                }
            },
        );
    }

    fn memory_report(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        for st in &self.states {
            match st {
                LayerState::LowRank { proj, prev_basis, m, v, ef, .. } => {
                    r.add("adam_m_low", m.bytes());
                    r.add("adam_v_low", v.bytes());
                    // two consecutive projectors per layer (LDAdam's cost)
                    r.add("projector", proj.state_bytes());
                    r.add("projector_prev", prev_basis.bytes());
                    r.add("ef", ef.bytes());
                }
                LayerState::Adam(a) => {
                    r.add("adam_m", a.m.bytes());
                    r.add("adam_v", a.v.bytes());
                }
            }
        }
        r
    }

    fn name(&self) -> &'static str {
        "ldadamw"
    }
}

#[cfg(test)]
mod tests {
    use crate::optim::common::ParamKind;
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn converges_on_quadratic() {
        let mut rng = Pcg64::seed(0);
        let t = Matrix::randn(10, 8, 0.5, &mut rng);
        let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
        let cfg = OptimizerConfig { rank: 4, weight_decay: 0.0, ..Default::default() };
        let mut opt = LdAdamW::new(&metas, &cfg);
        let mut params = vec![Matrix::zeros(10, 8)];
        for _ in 0..500 {
            let g = params[0].sub(&t).scaled(2.0);
            opt.step(&mut params, &[g], 0.05);
        }
        let err = params[0].sub(&t).fro_norm() / t.fro_norm();
        // EF lets the low-rank optimizer recover near-full-rank targets
        assert!(err < 0.15, "rel err={err}");
    }

    #[test]
    fn stores_two_projectors_and_full_ef() {
        let metas = vec![LayerMeta::new("w", 16, 12, ParamKind::Linear)];
        let cfg = OptimizerConfig { rank: 4, ..Default::default() };
        let rep = LdAdamW::new(&metas, &cfg).memory_report();
        assert_eq!(rep.per_layer["projector"], 12 * 4 * 4);
        assert_eq!(rep.per_layer["projector_prev"], 12 * 4 * 4);
        assert_eq!(rep.per_layer["ef"], 16 * 12 * 4);
    }

    #[test]
    fn error_feedback_recovers_out_of_subspace_signal() {
        // A constant gradient orthogonal to the chosen subspace must still
        // move parameters once EF accumulates.
        let metas = vec![LayerMeta::new("w", 8, 8, ParamKind::Linear)];
        let cfg = OptimizerConfig { rank: 1, weight_decay: 0.0, ..Default::default() };
        let mut opt = LdAdamW::new(&metas, &cfg);
        let mut rng = Pcg64::seed(1);
        let g0 = Matrix::randn(8, 8, 1.0, &mut rng);
        let mut params = vec![Matrix::zeros(8, 8)];
        for _ in 0..50 {
            opt.step(&mut params, &[g0.clone()], 0.01);
        }
        // all coordinates moved in direction -g0 (sign agreement mostly)
        let mut agree = 0;
        for k in 0..64 {
            if params[0].data[k] * g0.data[k] < 0.0 {
                agree += 1;
            }
        }
        assert!(agree > 48, "agree={agree}/64");
    }
}
