//! The composable subspace-optimizer engine.
//!
//! One [`SubspaceEngine`] owns everything the six hand-written low-rank
//! optimizers used to duplicate — layer metas, per-layer states, the thread
//! pool + [`ShardedWorkspace`], the step counter, the dense-AdamW fallback
//! for non-eligible parameters and exact memory accounting — and expresses
//! each method as a composition of four policy axes:
//!
//! | axis | trait / type | implementations |
//! |------|--------------|-----------------|
//! | subspace source | [`SubspaceSource`] | any [`ProjectionKind`] + refresh cadence |
//! | moment rotation | [`RotationPolicy`] | none / fixed-basis index matching / dense `QᵀQ` |
//! | residual        | [`ResidualPolicy`] | discard / error feedback (f32, Q8) / FIRA scaling / SignSGD |
//! | update rule     | [`UpdateRule`]     | fused subspace AdamW / Newton–Schulz momentum |
//!
//! Configurations are built with the [`OptimizerSpec`] builder; the six
//! published methods are presets whose engines are **bit-identical** to the
//! deleted hand-written optimizers (`tests/engine_equivalence.rs` pins the
//! trajectories against frozen copies of the legacy step loops). The PR-1
//! zero-allocation and PR-2 any-thread-count determinism contracts live in
//! exactly one place now: the engine steps layers through
//! [`step_layers_parallel`] with every temporary pooled per shard
//! (`tests/alloc_steady_state.rs`, `tests/parallel_determinism.rs`).

pub mod residual;
pub mod rotation;
pub mod rule;
pub mod source;
pub mod spec;

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::parallel::{ShardedWorkspace, ThreadPool};
use crate::projection::{ProjectionKind, SharedDct};
use crate::tensor::Matrix;

use super::common::{
    pool_for_threads, shared_dct_registry, step_layers_parallel, AdamState,
    LayerMeta, MemoryReport, Optimizer,
};

pub use residual::{DiscardResidual, EfResidual, FiraResidual, ResidualPolicy, SignResidual};
pub use rotation::{
    rotate_fixed_basis, rotate_fixed_basis_into, DenseRotation, FixedBasisRotation,
    NoRotation, RotationPolicy,
};
pub use rule::{Hyper, NewtonSchulzMomentum, StepCtx, SubspaceAdamW, UpdateRule};
pub use source::SubspaceSource;
pub use spec::{BroadcastKind, OptimizerSpec, ResidualKind, RotationKind, UpdateRuleKind};

/// One layer's engine state: the composed low-rank policies for eligible
/// (hidden linear) parameters, dense AdamW for everything else.
enum EngineLayer {
    Dense(AdamState),
    LowRank(LowRankLayer),
}

struct LowRankLayer {
    source: SubspaceSource,
    rotation: Box<dyn RotationPolicy>,
    residual: Box<dyn ResidualPolicy>,
    rule: Box<dyn UpdateRule>,
}

/// The single step loop behind every composed low-rank optimizer.
pub struct SubspaceEngine {
    spec: OptimizerSpec,
    name: String,
    metas: Vec<LayerMeta>,
    states: Vec<EngineLayer>,
    /// Shared per-device DCT state, deduplicated per oriented column
    /// dimension — the paper's memory argument.
    shared: BTreeMap<usize, Arc<SharedDct>>,
    pool: Arc<ThreadPool>,
    shards: ShardedWorkspace,
    step: u64,
    /// Effective ZeRO broadcast model: the spec's choice, downgraded to
    /// `Full` when the source is a dense basis — the low-rank payload
    /// (`o_t` + indices) only exists when receivers can reconstruct `Q_r`
    /// from `r` int32 indices and their shared-basis replica (§2.3).
    broadcast: BroadcastKind,
    /// Figure-1 instrumentation (Newton–Schulz rule only).
    instrumented: bool,
    errors: BTreeMap<String, f64>,
}

impl OptimizerSpec {
    /// Build the engine for a model. Panics on compositions that don't
    /// exist (fixed-basis rotation without an index-selection source;
    /// policy axes on the Newton–Schulz rule, whose residual handling is
    /// inherent).
    pub fn build(&self, metas: &[LayerMeta]) -> SubspaceEngine {
        if let Err(e) = self.validate() {
            panic!("{e}");
        }
        let shared = if matches!(self.projection, ProjectionKind::Dct { .. }) {
            shared_dct_registry(metas)
        } else {
            BTreeMap::new()
        };
        let states = metas
            .iter()
            .enumerate()
            .map(|(i, meta)| {
                if meta.kind.low_rank_eligible() {
                    let (rr, cc) = meta.oriented();
                    let r = self.rank.min(cc);
                    let proj = self.projection.build(
                        cc,
                        r,
                        shared.get(&cc).cloned(),
                        self.seed ^ ((i as u64) << self.seed_shift),
                    );
                    let source = SubspaceSource::new(proj, self.update_interval);
                    let rotation: Box<dyn RotationPolicy> = match self.rotation {
                        RotationKind::None => Box::new(NoRotation),
                        RotationKind::FixedBasis => Box::new(FixedBasisRotation::new(r)),
                        RotationKind::Dense => Box::new(DenseRotation::new(cc, r)),
                    };
                    let residual: Box<dyn ResidualPolicy> = match self.residual {
                        ResidualKind::Discard => Box::new(DiscardResidual),
                        ResidualKind::ErrorFeedback(mode) => {
                            Box::new(EfResidual::new(mode, rr, cc))
                        }
                        ResidualKind::FiraScale => Box::new(FiraResidual),
                        ResidualKind::SignDescent => Box::new(SignResidual { scale: 1.0 }),
                    };
                    let rule: Box<dyn UpdateRule> = match self.rule {
                        UpdateRuleKind::SubspaceAdamW => Box::new(SubspaceAdamW::new(rr, r)),
                        UpdateRuleKind::NewtonSchulz => {
                            Box::new(NewtonSchulzMomentum::new(rr, cc, self.mu, self.ns_steps))
                        }
                    };
                    EngineLayer::LowRank(LowRankLayer { source, rotation, residual, rule })
                } else {
                    EngineLayer::Dense(AdamState::new(meta.rows, meta.cols))
                }
            })
            .collect();
        let pool = pool_for_threads(self.threads);
        let shards = ShardedWorkspace::for_pool(&pool);
        let instrumented = self.instrument && self.rule == UpdateRuleKind::NewtonSchulz;
        // The indices-only payload exists iff receivers can rebuild the
        // basis from r int32 (index-selection source) AND the update stays
        // inside the subspace (no full-rank residual term in the update —
        // FIRA scaling / SignSGD add one; EF only feeds the *next* step's
        // gradient). Anything else downgrades to full-update accounting.
        let low_rank_payload_exists = matches!(
            self.projection,
            ProjectionKind::Dct { .. } | ProjectionKind::RandPerm
        ) && matches!(
            self.residual,
            ResidualKind::Discard | ResidualKind::ErrorFeedback(_)
        );
        let broadcast =
            if low_rank_payload_exists { self.broadcast } else { BroadcastKind::Full };
        SubspaceEngine {
            name: self.resolve_name(),
            spec: self.clone(),
            metas: metas.to_vec(),
            states,
            shared,
            pool,
            shards,
            step: 0,
            broadcast,
            instrumented,
            errors: BTreeMap::new(),
        }
    }
}

impl SubspaceEngine {
    pub fn spec(&self) -> &OptimizerSpec {
        &self.spec
    }

    /// Column indices currently selected for a layer (index-selection
    /// sources only) — test/bench hook.
    pub fn indices(&self, layer: usize) -> Option<&[usize]> {
        match &self.states[layer] {
            EngineLayer::LowRank(l) => l.source.indices(),
            EngineLayer::Dense(_) => None,
        }
    }

    /// The rotation policy's snapshot of the pre-refresh indices — test
    /// hook.
    pub fn snapshot_indices(&self, layer: usize) -> Option<&[usize]> {
        match &self.states[layer] {
            EngineLayer::LowRank(l) => l.rotation.snapshot_indices(),
            EngineLayer::Dense(_) => None,
        }
    }

    /// Materialized basis `Q_r` of a layer's source — test hook.
    pub fn basis(&self, layer: usize) -> Option<Matrix> {
        match &self.states[layer] {
            EngineLayer::LowRank(l) => Some(l.source.basis()),
            EngineLayer::Dense(_) => None,
        }
    }

    /// Full-rank momentum of a layer (Newton–Schulz rule) — test hook.
    pub fn momentum(&self, layer: usize) -> Option<&Matrix> {
        match &self.states[layer] {
            EngineLayer::LowRank(l) => l.rule.momentum(),
            EngineLayer::Dense(_) => None,
        }
    }
}

impl Optimizer for SubspaceEngine {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let t = self.step;
        let hyper = Hyper {
            beta1: self.spec.beta1,
            beta2: self.spec.beta2,
            eps: self.spec.eps,
            weight_decay: self.spec.weight_decay,
        };
        let dense_wd = self.spec.dense_weight_decay.unwrap_or(self.spec.weight_decay);
        let errors = Mutex::new(std::mem::take(&mut self.errors));
        let errors_ref = if self.instrumented { Some(&errors) } else { None };
        let metas = &self.metas;
        let pool = Arc::clone(&self.pool);
        step_layers_parallel(
            &pool,
            &mut self.shards,
            &mut self.states,
            params,
            grads,
            |i, state, param, grad, ws| match state {
                EngineLayer::Dense(st) => st.update(
                    param, grad, lr, hyper.beta1, hyper.beta2, hyper.eps, dense_wd, t,
                ),
                EngineLayer::LowRank(l) => {
                    let ctx = StepCtx { t, lr, hyper, errors: errors_ref };
                    l.rule.step_layer(
                        &metas[i],
                        &mut l.source,
                        l.rotation.as_mut(),
                        l.residual.as_mut(),
                        param,
                        grad,
                        &ctx,
                        ws,
                    );
                }
            },
        );
        self.errors = errors.into_inner().unwrap();
    }

    fn memory_report(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        for st in &self.states {
            match st {
                EngineLayer::Dense(a) => {
                    r.add("adam_m", a.m.bytes());
                    r.add("adam_v", a.v.bytes());
                }
                EngineLayer::LowRank(l) => {
                    l.rule.memory(&mut r);
                    // index-selection sources store r int32 per layer — the
                    // paper's memory claim; dense bases store C×r floats
                    let family =
                        if l.source.indices().is_some() { "indices" } else { "projector" };
                    r.add(family, l.source.state_bytes());
                    l.rotation.memory(&mut r);
                    l.residual.memory(&mut r);
                }
            }
        }
        for (dim, dct) in &self.shared {
            r.share(&format!("dct_matrix_{dim}"), dct.bytes());
        }
        r
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn projection_errors(&self) -> Option<&BTreeMap<String, f64>> {
        if self.instrumented {
            Some(&self.errors)
        } else {
            None
        }
    }

    fn broadcast_bytes(&self, meta: &LayerMeta) -> u64 {
        if meta.kind.low_rank_eligible() && self.broadcast == BroadcastKind::LowRankFactor {
            // o_t (R×r floats) + i_t (r int32): §2.3's communication saving
            let (rr, cc) = meta.oriented();
            let r = self.spec.rank.min(cc);
            (rr * r * 4 + r * 4) as u64
        } else {
            (meta.rows * meta.cols * 4) as u64
        }
    }
}

#[cfg(test)]
mod tests;
