//! The composable subspace-optimizer engine.
//!
//! One [`SubspaceEngine`] owns everything the six hand-written low-rank
//! optimizers used to duplicate — layer metas, per-layer states, the thread
//! pool + [`ShardedWorkspace`], the step counter, the dense-AdamW fallback
//! for non-eligible parameters and exact memory accounting — and expresses
//! each method as a composition of four policy axes:
//!
//! | axis | trait / type | implementations |
//! |------|--------------|-----------------|
//! | subspace source | [`SubspaceSource`] | any [`ProjectionKind`] + refresh cadence |
//! | moment rotation | [`RotationPolicy`] | none / fixed-basis index matching / dense `QᵀQ` |
//! | residual        | [`ResidualPolicy`] | discard / error feedback (f32, Q8) / FIRA scaling / SignSGD |
//! | update rule     | [`UpdateRule`]     | fused subspace AdamW / Newton–Schulz momentum |
//! | state storage   | [`StateDtype`](crate::tensor::StateDtype) | f32 (bit-exact) / bf16 / q8 typed stores |
//!
//! The engine is also durable: [`SubspaceEngine::serialize_state`] /
//! [`SubspaceEngine::restore_state`] round-trip every cross-step byte
//! (step counter, typed stores, subspace/rotation/residual auxiliaries),
//! which is the substrate of the checkpoint-v2 `resume=` contract — a
//! restored engine continues the uninterrupted trajectory to the bit
//! (`tests/resume_determinism.rs`).
//!
//! Configurations are built with the [`OptimizerSpec`] builder; the six
//! published methods are presets whose engines are **bit-identical** to the
//! deleted hand-written optimizers (`tests/engine_equivalence.rs` pins the
//! trajectories against frozen copies of the legacy step loops). The PR-1
//! zero-allocation and PR-2 any-thread-count determinism contracts live in
//! exactly one place now: the engine steps layers through
//! [`step_layers_parallel`] with every temporary pooled per shard
//! (`tests/alloc_steady_state.rs`, `tests/parallel_determinism.rs`).

pub mod plan;
pub mod residual;
pub mod rotation;
pub mod rule;
pub mod source;
pub mod spec;

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::parallel::{ShardedWorkspace, ThreadPool};
use crate::projection::{ProjectionKind, SharedDct};
use crate::tensor::{Matrix, Workspace};
use crate::util::codec::{self, ByteReader};

use super::common::{
    pool_for_threads, shared_dct_registry, step_layers_parallel, AdamState,
    LayerMeta, MemoryReport, Optimizer, SubspaceCommView,
};

pub use plan::StepPlanMode;
pub use residual::{
    DiscardResidual, EfResidual, FiraResidual, Residual, ResidualPolicy, SignResidual,
};
pub use rotation::{
    rotate_fixed_basis, rotate_fixed_basis_into, DenseRotation, FixedBasisRotation,
    NoRotation, Rotation, RotationPolicy,
};
pub use rule::{Hyper, NewtonSchulzMomentum, Rule, StepCtx, SubspaceAdamW, UpdateRule};
pub use source::SubspaceSource;
pub use spec::{BroadcastKind, OptimizerSpec, ResidualKind, RotationKind, UpdateRuleKind};

/// One layer's engine state: the composed low-rank policies for eligible
/// (hidden linear) parameters, dense AdamW for everything else.
enum EngineLayer {
    Dense(AdamState),
    LowRank(LowRankLayer),
}

struct LowRankLayer {
    source: SubspaceSource,
    // Closed policy enums, not `Box<dyn>`: the step plan (engine/plan.rs)
    // monomorphizes each group's chain over them, so the hot loop pays no
    // virtual hops and `Rule`'s fused entry points stay reachable.
    rotation: Rotation,
    residual: Residual,
    rule: Rule,
    /// `(step, gauges)` captured at this layer's most recent subspace
    /// refresh (obs tiers only); drained by [`Optimizer::refresh_gauges`].
    last_quality: Option<(u64, crate::obs::SubspaceQuality)>,
}

/// The single step loop behind every composed low-rank optimizer.
pub struct SubspaceEngine {
    spec: OptimizerSpec,
    name: String,
    metas: Vec<LayerMeta>,
    states: Vec<EngineLayer>,
    /// Shared per-device DCT state, deduplicated per oriented column
    /// dimension — the paper's memory argument.
    shared: BTreeMap<usize, Arc<SharedDct>>,
    pool: Arc<ThreadPool>,
    shards: ShardedWorkspace,
    step: u64,
    /// Effective ZeRO broadcast model: the spec's choice, downgraded to
    /// `Full` when the source is a dense basis — the low-rank payload
    /// (`o_t` + indices) only exists when receivers can reconstruct `Q_r`
    /// from `r` int32 indices and their shared-basis replica (§2.3).
    broadcast: BroadcastKind,
    /// Figure-1 instrumentation (Newton–Schulz rule only).
    instrumented: bool,
    errors: BTreeMap<String, f64>,
    /// Per-chunk span-event rings, chunk-indexed like `shards` (ring `k` ↔
    /// workspace shard `k`), merged in fixed lane order by
    /// [`Optimizer::drain_events`]. Zero-capacity when the process can't
    /// trace at build time, so `obs=off` runs pay nothing.
    rings: crate::obs::RingSet,
    /// Compiled step program (`step-plan=fused`, the default). Derived
    /// state: rebuilt on [`SubspaceEngine::restore_state`], invisible to
    /// the checkpoint fingerprint, empty under `step-plan=interpreted`.
    plan: plan::EnginePlan,
}

impl OptimizerSpec {
    /// Build the engine for a model. Panics on compositions that don't
    /// exist (fixed-basis rotation without an index-selection source;
    /// policy axes on the Newton–Schulz rule, whose residual handling is
    /// inherent).
    pub fn build(&self, metas: &[LayerMeta]) -> SubspaceEngine {
        if let Err(e) = self.validate() {
            panic!("{e}");
        }
        let shared = if matches!(self.projection, ProjectionKind::Dct { .. }) {
            shared_dct_registry(metas)
        } else {
            BTreeMap::new()
        };
        let states = metas
            .iter()
            .enumerate()
            .map(|(i, meta)| {
                if meta.kind.low_rank_eligible() {
                    let (rr, cc) = meta.oriented();
                    let r = self.rank.min(cc);
                    let proj = self.projection.build(
                        cc,
                        r,
                        shared.get(&cc).cloned(),
                        self.seed ^ ((i as u64) << self.seed_shift),
                    );
                    let source = SubspaceSource::new(proj, self.update_interval);
                    let rotation = match self.rotation {
                        RotationKind::None => Rotation::None(NoRotation),
                        RotationKind::FixedBasis => {
                            Rotation::Fixed(FixedBasisRotation::new(r))
                        }
                        RotationKind::Dense => Rotation::Dense(DenseRotation::new(cc, r)),
                    };
                    let residual = match self.residual {
                        ResidualKind::Discard => Residual::Discard(DiscardResidual),
                        ResidualKind::ErrorFeedback(mode) => {
                            Residual::Ef(EfResidual::new(mode, rr, cc))
                        }
                        ResidualKind::FiraScale => Residual::Fira(FiraResidual),
                        ResidualKind::SignDescent => {
                            Residual::Sign(SignResidual { scale: 1.0 })
                        }
                    };
                    let rule = match self.rule {
                        UpdateRuleKind::SubspaceAdamW => {
                            Rule::Adam(SubspaceAdamW::new(self.state_dtype, rr, r))
                        }
                        UpdateRuleKind::NewtonSchulz => Rule::Ns(NewtonSchulzMomentum::new(
                            self.state_dtype,
                            rr,
                            cc,
                            self.mu,
                            self.ns_steps,
                        )),
                    };
                    EngineLayer::LowRank(LowRankLayer {
                        source,
                        rotation,
                        residual,
                        rule,
                        last_quality: None,
                    })
                } else {
                    EngineLayer::Dense(AdamState::with_dtype(
                        self.state_dtype,
                        meta.rows,
                        meta.cols,
                    ))
                }
            })
            .collect();
        let pool = pool_for_threads(self.threads);
        let shards = ShardedWorkspace::for_pool(&pool);
        let plan_start = crate::obs::now_us();
        let plan = match self.step_plan {
            StepPlanMode::Fused => plan::EnginePlan::build(self, metas, &states, &shared),
            StepPlanMode::Interpreted => plan::EnginePlan::empty(),
        };
        let plan_dur = crate::obs::now_us().saturating_sub(plan_start);
        // One event ring per possible chunk, capacity covering one step's
        // spans per chunk (≤ 6 per layer) with headroom — rings are drained
        // every step by the trainer, so this never fills in practice. The
        // fused plan splits a chunk's layers across per-group dispatches,
        // so ring k can absorb up to one extra partial chunk per group
        // (plus the group-level batch spans on ring 0) — hence the
        // group-count term. When the run can't trace the rings are
        // zero-capacity (pushes become counted drops), keeping `obs=off`
        // builds allocation-free here.
        let lanes = pool.threads();
        let ring_cap = if crate::obs::tracing() {
            metas.len().div_ceil(lanes.max(1)) * 8 + 16 + 8 * (plan.group_count() + 1)
        } else {
            0
        };
        let rings = crate::obs::RingSet::new(lanes, ring_cap);
        if crate::obs::tracing() && ring_cap > 0 {
            // SAFETY: the rings were just built; no other thread holds them.
            unsafe { rings.lane(0) }.push(crate::obs::Event {
                name: "plan-build",
                layer: crate::obs::Event::NO_LAYER,
                lane: 0,
                start_us: plan_start,
                dur_us: plan_dur,
            });
        }
        let instrumented = self.instrument && self.rule == UpdateRuleKind::NewtonSchulz;
        // The indices-only payload exists iff receivers can rebuild the
        // basis from r int32 (index-selection source) AND the update stays
        // inside the subspace (no full-rank residual term in the update —
        // FIRA scaling / SignSGD add one; EF only feeds the *next* step's
        // gradient). Anything else downgrades to full-update accounting.
        let low_rank_payload_exists = matches!(
            self.projection,
            ProjectionKind::Dct { .. } | ProjectionKind::RandPerm
        ) && matches!(
            self.residual,
            ResidualKind::Discard | ResidualKind::ErrorFeedback(_)
        );
        let broadcast =
            if low_rank_payload_exists { self.broadcast } else { BroadcastKind::Full };
        SubspaceEngine {
            name: self.resolve_name(),
            spec: self.clone(),
            metas: metas.to_vec(),
            states,
            shared,
            pool,
            shards,
            step: 0,
            broadcast,
            instrumented,
            errors: BTreeMap::new(),
            rings,
            plan,
        }
    }
}

impl SubspaceEngine {
    pub fn spec(&self) -> &OptimizerSpec {
        &self.spec
    }

    /// Column indices currently selected for a layer (index-selection
    /// sources only) — test/bench hook.
    pub fn indices(&self, layer: usize) -> Option<&[usize]> {
        match &self.states[layer] {
            EngineLayer::LowRank(l) => l.source.indices(),
            EngineLayer::Dense(_) => None,
        }
    }

    /// The rotation policy's snapshot of the pre-refresh indices — test
    /// hook.
    pub fn snapshot_indices(&self, layer: usize) -> Option<&[usize]> {
        match &self.states[layer] {
            EngineLayer::LowRank(l) => l.rotation.snapshot_indices(),
            EngineLayer::Dense(_) => None,
        }
    }

    /// Materialized basis `Q_r` of a layer's source — test hook.
    pub fn basis(&self, layer: usize) -> Option<Matrix> {
        match &self.states[layer] {
            EngineLayer::LowRank(l) => Some(l.source.basis()),
            EngineLayer::Dense(_) => None,
        }
    }

    /// Full-rank momentum of a layer (Newton–Schulz rule), materialized to
    /// f32 — test hook.
    pub fn momentum(&self, layer: usize) -> Option<Matrix> {
        match &self.states[layer] {
            EngineLayer::LowRank(l) => l.rule.momentum(),
            EngineLayer::Dense(_) => None,
        }
    }

    /// Composition fingerprint written into checkpoint-v2 state blobs:
    /// resuming requires the identical composition — same axes, same rank,
    /// same seed, same state dtype AND the same numeric hyper-parameters
    /// (betas/eps/decay/μ/NS steps feed every post-resume step, so a
    /// mismatch would silently break the bit-identical-resume contract).
    pub fn fingerprint(&self) -> String {
        let s = &self.spec;
        format!(
            "{} rank={} seed={} shift={} b1={} b2={} eps={} wd={} dwd={:?} \
             mu={} ns={} {}",
            self.name,
            s.rank,
            s.seed,
            s.seed_shift,
            s.beta1,
            s.beta2,
            s.eps,
            s.weight_decay,
            s.dense_weight_decay,
            s.mu,
            s.ns_steps,
            s.describe()
        )
    }

    /// Serialize every piece of resumable state: the step counter plus, per
    /// layer, the rule stores, the subspace source (indices / bases / RNG
    /// streams / warm flags), the rotation snapshot and the residual buffer
    /// — everything `step` reads across step boundaries, so a fresh engine
    /// restored from this blob continues the trajectory to the bit
    /// (`tests/resume_determinism.rs`).
    pub fn serialize_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        codec::put_str(&mut out, &self.fingerprint());
        codec::put_u64(&mut out, self.step);
        codec::put_u32(&mut out, self.states.len() as u32);
        for st in &self.states {
            match st {
                EngineLayer::Dense(a) => {
                    codec::put_u8(&mut out, 0);
                    a.save(&mut out);
                }
                EngineLayer::LowRank(l) => {
                    codec::put_u8(&mut out, 1);
                    l.source.save_state(&mut out);
                    l.rotation.save_state(&mut out);
                    l.residual.save_state(&mut out);
                    l.rule.save_state(&mut out);
                }
            }
        }
        out
    }

    /// Twin of [`SubspaceEngine::serialize_state`].
    pub fn restore_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(bytes);
        let fp = r.take_str()?;
        ensure!(
            fp == self.fingerprint(),
            "checkpoint was saved by {fp:?}, this engine is {:?} — resume \
             needs the identical composition (preset, rank, seed, \
             state-dtype)",
            self.fingerprint()
        );
        self.step = r.take_u64()?;
        let n = r.take_u32()? as usize;
        ensure!(n == self.states.len(), "checkpoint has {n} layers, model has {}", self.states.len());
        for st in &mut self.states {
            let tag = r.take_u8()?;
            match st {
                EngineLayer::Dense(a) => {
                    ensure!(tag == 0, "layer tag mismatch (dense expected)");
                    a.load_from(&mut r)?;
                }
                EngineLayer::LowRank(l) => {
                    ensure!(tag == 1, "layer tag mismatch (low-rank expected)");
                    l.source.load_state(&mut r)?;
                    l.rotation.load_state(&mut r)?;
                    l.residual.load_state(&mut r)?;
                    l.rule.load_state(&mut r)?;
                }
            }
        }
        // Plans are derived state — never in the blob, never in the
        // fingerprint — so rebuild here (this also covers trainer rollback,
        // which restores through `load_state`).
        self.plan = match self.spec.step_plan {
            StepPlanMode::Fused => {
                plan::EnginePlan::build(&self.spec, &self.metas, &self.states, &self.shared)
            }
            StepPlanMode::Interpreted => plan::EnginePlan::empty(),
        };
        r.finish()
    }
}

impl Optimizer for SubspaceEngine {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let t = self.step;
        let hyper = Hyper {
            beta1: self.spec.beta1,
            beta2: self.spec.beta2,
            eps: self.spec.eps,
            weight_decay: self.spec.weight_decay,
        };
        let dense_wd = self.spec.dense_weight_decay.unwrap_or(self.spec.weight_decay);
        let errors = Mutex::new(std::mem::take(&mut self.errors));
        let errors_ref = if self.instrumented { Some(&errors) } else { None };
        let metas = &self.metas;
        let pool = Arc::clone(&self.pool);
        // Obs decisions once per step, not per layer: span recording only
        // under `obs=trace` on sampled steps, gauge capture under any
        // enabled tier. Both are side channels — the update math below is
        // identical across tiers (`tests/obs_determinism.rs`).
        let sampled = crate::obs::tracing() && crate::obs::sample_hit(t);
        let gauge_step = crate::obs::enabled() && crate::obs::sample_hit(t);
        let rings = &self.rings;
        match self.spec.step_plan {
            StepPlanMode::Fused => self.plan.run_step(
                metas,
                &mut self.states,
                params,
                grads,
                &pool,
                &mut self.shards,
                rings,
                sampled,
                gauge_step,
                t,
                lr,
                hyper,
                dense_wd,
                errors_ref,
            ),
            // The interpreted per-layer loop: retained verbatim as the
            // differential-testing oracle for the fused plan
            // (`tests/step_plan_equivalence.rs`).
            StepPlanMode::Interpreted => step_layers_parallel(
                &pool,
                &mut self.shards,
                &mut self.states,
                params,
                grads,
                |k, i, state, param, grad, ws| {
                    let obs = if sampled {
                        // SAFETY: chunk `k` is claimed by exactly one thread
                        // and records only into ring `k` — the same
                        // disjointness the workspace shard binding relies on.
                        crate::obs::ObsLane {
                            ring: Some(unsafe { rings.lane(k) }),
                            lane: k as u32,
                            layer: i as u32,
                            sampled: true,
                        }
                    } else {
                        crate::obs::ObsLane::none()
                    };
                    match state {
                        EngineLayer::Dense(st) => obs.span("dense", || {
                            st.update_ws(
                                param, grad, lr, hyper.beta1, hyper.beta2, hyper.eps,
                                dense_wd, t, ws,
                            )
                        }),
                        EngineLayer::LowRank(l) => {
                            let refreshed = l.source.refresh_due(t);
                            let ctx = StepCtx { t, lr, hyper, errors: errors_ref, obs };
                            l.rule.step_layer(
                                &metas[i],
                                &mut l.source,
                                &mut l.rotation,
                                &mut l.residual,
                                param,
                                grad,
                                &ctx,
                                ws,
                            );
                            if refreshed && gauge_step {
                                l.last_quality = l.source.quality().map(|q| (t, q));
                            }
                        }
                    }
                },
            ),
        }
        self.errors = errors.into_inner().unwrap();
    }

    fn memory_report(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        for st in &self.states {
            match st {
                EngineLayer::Dense(a) => {
                    r.add("adam_m", a.m.bytes());
                    r.add("adam_v", a.v.bytes());
                }
                EngineLayer::LowRank(l) => {
                    l.rule.memory(&mut r);
                    // index-selection sources store r int32 per layer — the
                    // paper's memory claim; dense bases store C×r floats
                    let family =
                        if l.source.indices().is_some() { "indices" } else { "projector" };
                    r.add(family, l.source.state_bytes());
                    l.rotation.memory(&mut r);
                    l.residual.memory(&mut r);
                }
            }
        }
        for (dim, dct) in &self.shared {
            r.share(&format!("dct_matrix_{dim}"), dct.bytes());
        }
        r
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn projection_errors(&self) -> Option<&BTreeMap<String, f64>> {
        if self.instrumented {
            Some(&self.errors)
        } else {
            None
        }
    }

    fn save_state(&self) -> Option<Vec<u8>> {
        Some(self.serialize_state())
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        self.restore_state(bytes)
    }

    fn broadcast_bytes(&self, meta: &LayerMeta) -> u64 {
        if meta.kind.low_rank_eligible() && self.broadcast == BroadcastKind::LowRankFactor {
            // o_t (R×r floats) + i_t (r int32): §2.3's communication saving
            let (rr, cc) = meta.oriented();
            let r = self.spec.rank.min(cc);
            (rr * r * 4 + r * 4) as u64
        } else {
            (meta.rows * meta.cols * 4) as u64
        }
    }

    fn refresh_gauges(&mut self) -> Vec<(String, u64, crate::obs::SubspaceQuality)> {
        let mut out = Vec::new();
        for (meta, st) in self.metas.iter().zip(self.states.iter_mut()) {
            if let EngineLayer::LowRank(l) = st {
                if let Some((t, q)) = l.last_quality.take() {
                    out.push((meta.name.clone(), t, q));
                }
            }
        }
        out
    }

    fn drain_events(&mut self, out: &mut Vec<crate::obs::Event>) -> u64 {
        self.rings.drain_all(out)
    }

    fn comm_view(&self) -> Option<&dyn SubspaceCommView> {
        Some(self)
    }
}

/// The compressed-sync view over the engine: per-layer rank/basis access
/// plus the refresh lookahead. `step()` increments the counter at entry, so
/// "the next step" as seen from between steps is `self.step + 1` — exactly
/// the `t` the schedule will evaluate.
impl SubspaceCommView for SubspaceEngine {
    fn layer_rank(&self, i: usize) -> Option<usize> {
        match &self.states[i] {
            EngineLayer::LowRank(l) => Some(l.source.rank()),
            EngineLayer::Dense(_) => None,
        }
    }

    fn refresh_pending(&self, i: usize) -> bool {
        match &self.states[i] {
            EngineLayer::LowRank(l) => l.source.refresh_due(self.step + 1),
            EngineLayer::Dense(_) => false,
        }
    }

    fn project_into(&self, i: usize, g: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        let EngineLayer::LowRank(l) = &self.states[i] else {
            panic!("project_into on dense layer {i}");
        };
        l.source.project_into(g, out, ws);
    }

    fn back_into(&self, i: usize, low: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        let EngineLayer::LowRank(l) = &self.states[i] else {
            panic!("back_into on dense layer {i}");
        };
        l.source.back_into(low, out, ws);
    }

    fn save_basis(&self, i: usize, out: &mut Vec<u8>) {
        let EngineLayer::LowRank(l) = &self.states[i] else {
            panic!("save_basis on dense layer {i}");
        };
        l.source.save_state(out);
    }
}

#[cfg(test)]
mod tests;
