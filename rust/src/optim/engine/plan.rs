//! Shape-batched fused step plans (PR 8).
//!
//! At engine build, eligible layers are partitioned into [`StepGroup`]s
//! keyed by `(rows, cols, orientation)` — rank, policy composition and
//! state dtype are engine-wide invariants, so they are part of the key by
//! construction — and each group's policy chain is lowered into a
//! monomorphized step program: the policy axes live in closed enums
//! ([`super::rotation::Rotation`], [`super::residual::Residual`],
//! [`super::rule::Rule`]), so the per-step `Box<dyn>` virtual hops are gone
//! from the hot loop, and the expensive projection pass of every layer in a
//! group is stacked into **one** batched kernel dispatch over the group's
//! concatenated rows ([`SharedDct::similarities_rows_batched_on`] on
//! refresh, [`matmul_rows_batched_on`] against the cached dense bases
//! otherwise) instead of one pool dispatch per layer.
//!
//! Bit-identity is the contract, not a goal: row-partitioned stacking never
//! regroups any element's FP summation order (the underlying kernels are
//! per-row transforms / ascending-`k` row kernels), the group phases reuse
//! the exact same chunk↔shard partition as the interpreted loop so every
//! workspace and typed-store checkout replays on the same shard, and the
//! interpreted per-layer loop is retained behind `step-plan=interpreted`
//! (`FFT_SUBSPACE_STEP_PLAN`) as the differential-testing oracle —
//! `tests/step_plan_equivalence.rs` pins fused `to_bits`-equal to
//! interpreted for every preset × state dtype × lane count.
//!
//! A fused step runs up to three phases per group:
//!
//! 1. **Stage** (one `dispatch_subset`): materialize the oriented gradient
//!    (+ error-feedback replay) into plan-owned staging buffers, or for the
//!    Newton–Schulz rule check the momentum out and accumulate the gradient
//!    ([`NewtonSchulzMomentum::begin_accumulate`]). Skipped when layers can
//!    borrow their gradient as-is.
//! 2. **Batch** (one kernel call): the group's projection pass over the
//!    concatenated rows, written into plan-owned `sims`/`lows` buffers via
//!    raw [`SendPtr`] destinations refilled in place each step.
//! 3. **Finish** (one `dispatch_subset`): the remaining per-layer chain —
//!    selection tail / rotation / residual / moments / parameter write —
//!    through [`ProjIn::Sims`] / [`ProjIn::Low`], which skip exactly the
//!    pass phase 2 already ran.
//!
//! Groups whose pass can't batch (no similarity hook and no cached dense
//! basis, e.g. block-power refreshes or gather-based RandPerm projections)
//! degrade to the **grouped** program: the unchanged per-layer chain inside
//! one dispatch — still enum-dispatched, trivially bit-identical.
//!
//! Memory trade: batching holds one `R×C` staging and/or similarity buffer
//! per layer of a group alive for the step (transient, plan-owned, never
//! checkpointed). For the models here that is bounded by the gradient set
//! itself; `FFT_SUBSPACE_MAX_GROUP_ROWS` caps the concatenated row count a
//! group may stack (the batch kernels' working-set height), splitting
//! oversized shape classes into several groups — bit-identity holds because
//! grouping never regroups any element's FP summation order.
//!
//! Plans are **derived state**: rebuilt on `load_state` (and therefore on
//! trainer rollback), invisible to the checkpoint fingerprint and blobs.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::obs::{Event, ObsLane, RingSet};
use crate::parallel::{partition, SendPtr, ShardedWorkspace, ThreadPool};
use crate::projection::SharedDct;
use crate::tensor::{matmul_rows_batched_on, Matrix, Workspace};

use crate::optim::common::LayerMeta;

use super::residual::ResidualPolicy;
use super::rule::{Hyper, ProjIn, Rule, StepCtx, UpdateRule};
use super::spec::{OptimizerSpec, UpdateRuleKind};
use super::{EngineLayer, LowRankLayer};

/// How the engine executes a step: compiled shape-batched programs or the
/// per-layer interpreted loop (the differential-testing oracle). Config key
/// `step-plan`, env `FFT_SUBSPACE_STEP_PLAN`; default fused. Never part of
/// the checkpoint fingerprint — the two modes are bit-identical.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPlanMode {
    Fused,
    Interpreted,
}

impl StepPlanMode {
    pub fn parse(s: &str) -> anyhow::Result<StepPlanMode> {
        match s.to_ascii_lowercase().as_str() {
            "fused" => Ok(StepPlanMode::Fused),
            "interpreted" | "interp" => Ok(StepPlanMode::Interpreted),
            other => anyhow::bail!(
                "unknown step-plan mode {other:?} (expected fused | interpreted)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StepPlanMode::Fused => "fused",
            StepPlanMode::Interpreted => "interpreted",
        }
    }

    /// Env resolution (`FFT_SUBSPACE_STEP_PLAN`): unset or unrecognized
    /// falls back to the fused default — the strict surface is the config
    /// key (`step-plan=`), which goes through [`StepPlanMode::parse`].
    pub fn from_env() -> StepPlanMode {
        match std::env::var("FFT_SUBSPACE_STEP_PLAN") {
            Ok(v) => StepPlanMode::parse(&v).unwrap_or(StepPlanMode::Fused),
            Err(_) => StepPlanMode::Fused,
        }
    }
}

/// Env resolution of the group-size cap (`FFT_SUBSPACE_MAX_GROUP_ROWS`):
/// the maximum concatenated oriented rows one [`StepGroup`] may stack.
/// Unset, `0` or unparseable = unlimited — the lenient env surface, same
/// contract as [`StepPlanMode::from_env`].
fn max_group_rows_from_env() -> usize {
    parse_group_rows(std::env::var("FFT_SUBSPACE_MAX_GROUP_ROWS").ok().as_deref())
}

/// The pure half of [`max_group_rows_from_env`] (`0` = unlimited).
fn parse_group_rows(v: Option<&str>) -> usize {
    v.and_then(|s| s.trim().parse().ok()).unwrap_or(0)
}

/// One shape group's compiled program: membership, the mode its batched
/// passes run in, and the plan-owned transient buffers (allocated once at
/// build, reused every step — the steady-state step stays allocation-free).
struct StepGroup {
    /// Engine layer indices, ascending (build order).
    layers: Vec<usize>,
    /// Oriented shape (every member identical).
    rr: usize,
    cc: usize,
    rank: usize,
    transposed: bool,
    /// Phase 1 materializes the oriented/EF gradient (AdamW-family rules
    /// with transposed layers or owned-gradient residual policies).
    staged: bool,
    /// Newton–Schulz rule: phase 1 holds the accumulated momentum instead.
    ns: bool,
    /// `Some(use_makhoul)` when refresh similarities can be group-batched
    /// (the DCT family); `None` degrades refresh steps to grouped.
    batch_sims: Option<bool>,
    /// Non-refresh projections can be group-batched against the source's
    /// cached dense basis (`basis_ref`).
    batch_project: bool,
    dct: Option<Arc<SharedDct>>,
    /// Oriented/EF-replayed gradients, `layers.len() × (rr×cc)`.
    stage: Vec<Matrix>,
    /// Batched refresh similarities `S = G·Q`, `layers.len() × (rr×cc)`.
    sims: Vec<Matrix>,
    /// Batched projections `g_low = G·Q_r`, `layers.len() × (rr×rank)`.
    lows: Vec<Matrix>,
    /// Momenta checked out in phase 1 (Newton–Schulz rule), handed back to
    /// `finish_from` in phase 3 under the same chunk↔shard binding.
    held: Vec<Option<Matrix>>,
    /// Raw batch-kernel destinations, refilled in place each step.
    dst_ptrs: Vec<SendPtr<f32>>,
}

/// The engine's compiled step program: shape groups plus the dense-fallback
/// layer list. Derived state — rebuilt on `load_state`, never serialized.
pub(crate) struct EnginePlan {
    groups: Vec<StepGroup>,
    dense: Vec<usize>,
}

/// Pin a closure to the `for<'a> Fn(usize) -> &'a Matrix` shape the batched
/// kernels expect (plain inference ties the return lifetime to the closure
/// body otherwise).
fn as_src<'a, F: Fn(usize) -> &'a Matrix + Sync>(f: F) -> F {
    f
}

/// [`super::common::step_layers_parallel`] over a subset of layers: chunk
/// `k` of the subset binds to workspace shard `k`. Phases 1 and 3 of a
/// group run over the **same** subset with the same partition rule, so a
/// typed-store checkout in phase 1 and its commit in phase 3 land on the
/// same shard — the allocation-free replay the PR-1 contract requires.
fn dispatch_subset(
    pool: &ThreadPool,
    shards: &mut ShardedWorkspace,
    layers: &[usize],
    states: &mut [EngineLayer],
    params: &mut [Matrix],
    f: impl Fn(usize, usize, usize, &mut EngineLayer, &mut Matrix, &mut Workspace) + Sync,
) {
    let n = layers.len();
    if n == 0 {
        return;
    }
    let (per, n_chunks) = partition(pool.threads().min(shards.len()), n);
    let states_p = SendPtr(states.as_mut_ptr());
    let params_p = SendPtr(params.as_mut_ptr());
    let cells = shards.cells();
    pool.par_chunks(n_chunks, |k| {
        let lo = k * per;
        let hi = (lo + per).min(n);
        // SAFETY: chunk k is claimed by exactly one thread; chunks cover
        // disjoint slot ranges and `layers` holds distinct indices, so the
        // state/param derefs never alias. Shard k is used only by chunk k.
        let ws = unsafe { cells.shard(k) };
        for slot in lo..hi {
            let i = layers[slot];
            let st = unsafe { &mut *states_p.0.add(i) };
            let p = unsafe { &mut *params_p.0.add(i) };
            f(k, slot, i, st, p, ws);
        }
    });
}

/// Per-chunk obs lane, identical to the interpreted loop's construction.
fn lane_obs(rings: &RingSet, sampled: bool, k: usize, layer: u32) -> ObsLane<'_> {
    if sampled {
        // SAFETY: chunk `k` is claimed by exactly one thread and records
        // only into ring `k` — the same disjointness the workspace shard
        // binding relies on.
        ObsLane { ring: Some(unsafe { rings.lane(k) }), lane: k as u32, layer, sampled: true }
    } else {
        ObsLane::none()
    }
}

impl EnginePlan {
    pub(crate) fn empty() -> EnginePlan {
        EnginePlan { groups: Vec::new(), dense: Vec::new() }
    }

    pub(crate) fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// Partition layers into shape groups and preallocate each group's
    /// batch buffers. Pure function of the spec, the layer shapes and the
    /// group-row cap — the same plan falls out after any `load_state`. The
    /// cap comes from the spec (config key `max-group-rows=`) when set,
    /// else from the `FFT_SUBSPACE_MAX_GROUP_ROWS` env knob — config wins,
    /// matching every other dual-surface knob.
    pub(crate) fn build(
        spec: &OptimizerSpec,
        metas: &[LayerMeta],
        states: &[EngineLayer],
        shared: &BTreeMap<usize, Arc<SharedDct>>,
    ) -> EnginePlan {
        let cap = if spec.max_group_rows > 0 {
            spec.max_group_rows
        } else {
            max_group_rows_from_env()
        };
        Self::build_with_cap(spec, metas, states, shared, cap)
    }

    /// [`EnginePlan::build`] with an explicit group-size cap: a group may
    /// stack at most `cap_rows` concatenated oriented rows (`0` =
    /// unlimited). Capping only splits membership — per-layer math and FP
    /// summation order are untouched, so every cap is bit-identical.
    pub(crate) fn build_with_cap(
        spec: &OptimizerSpec,
        metas: &[LayerMeta],
        states: &[EngineLayer],
        shared: &BTreeMap<usize, Arc<SharedDct>>,
        cap_rows: usize,
    ) -> EnginePlan {
        let mut dense = Vec::new();
        let mut groups: Vec<StepGroup> = Vec::new();
        let ns = spec.rule == UpdateRuleKind::NewtonSchulz;
        let interval = spec.update_interval.max(1);
        for (i, (meta, st)) in metas.iter().zip(states).enumerate() {
            let l = match st {
                EngineLayer::Dense(_) => {
                    dense.push(i);
                    continue;
                }
                EngineLayer::LowRank(l) => l,
            };
            let (rr, cc) = meta.oriented();
            let transposed = meta.needs_transpose();
            // last-match, not first-match: once a shape class splits under
            // the cap, new members must land in its most recent (open)
            // group, never backfill a closed one — keeps membership a pure
            // function of build order
            if let Some(g) = groups
                .iter_mut()
                .filter(|g| g.rr == rr && g.cc == cc && g.transposed == transposed)
                .last()
                .filter(|g| cap_rows == 0 || (g.layers.len() + 1) * rr <= cap_rows)
            {
                g.layers.push(i);
                continue;
            }
            groups.push(StepGroup {
                layers: vec![i],
                rr,
                cc,
                rank: l.source.rank(),
                transposed,
                staged: transposed || l.residual.wants_owned_grad(),
                ns,
                batch_sims: l.source.batched_sims(),
                batch_project: l.source.basis_ref().is_some(),
                dct: shared.get(&cc).cloned(),
                stage: Vec::new(),
                sims: Vec::new(),
                lows: Vec::new(),
                held: Vec::new(),
                dst_ptrs: Vec::new(),
            });
        }
        for g in &mut groups {
            if g.dct.is_none() {
                g.batch_sims = None;
            }
            let n = g.layers.len();
            // Refresh steps batch iff the source exposes the similarity
            // hook; non-refresh steps batch iff a cached dense basis exists
            // AND non-refresh steps can occur at all (interval > 1).
            let refresh_batched = g.batch_sims.is_some();
            let project_batched = g.batch_project && interval > 1;
            if !refresh_batched && !project_batched {
                continue; // always grouped — no batch buffers needed
            }
            if g.ns {
                g.held = (0..n).map(|_| None).collect();
            } else if g.staged {
                g.stage = (0..n).map(|_| Matrix::zeros(g.rr, g.cc)).collect();
            }
            if refresh_batched {
                g.sims = (0..n).map(|_| Matrix::zeros(g.rr, g.cc)).collect();
            }
            if project_batched {
                g.lows = (0..n).map(|_| Matrix::zeros(g.rr, g.rank)).collect();
            }
            g.dst_ptrs = vec![SendPtr(std::ptr::null_mut()); n];
        }
        crate::obs::count_plan_build(
            groups.len() as u64,
            groups.iter().map(|g| g.layers.len() as u64).sum(),
        );
        EnginePlan { groups, dense }
    }

    /// One fused engine step: per-group three-phase programs plus the dense
    /// fallback dispatch. Bit-identical to the interpreted loop (the
    /// `step-plan=interpreted` oracle) for every composition, dtype and
    /// thread count — `tests/step_plan_equivalence.rs`.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_step(
        &mut self,
        metas: &[LayerMeta],
        states: &mut [EngineLayer],
        params: &mut [Matrix],
        grads: &[Matrix],
        pool: &ThreadPool,
        shards: &mut ShardedWorkspace,
        rings: &RingSet,
        sampled: bool,
        gauge_step: bool,
        t: u64,
        lr: f32,
        hyper: Hyper,
        dense_wd: f32,
        errors: Option<&Mutex<BTreeMap<String, f64>>>,
    ) {
        for g in &mut self.groups {
            let refresh = {
                let EngineLayer::LowRank(l0) = &states[g.layers[0]] else {
                    unreachable!("step group holds a dense layer")
                };
                // uniform across the group: the cadence is spec-wide
                l0.source.refresh_due(t)
            };
            let batched = if refresh {
                g.batch_sims.is_some() && !g.sims.is_empty()
            } else {
                g.batch_project && !g.lows.is_empty()
            };
            if !batched {
                // Grouped program: the unchanged per-layer chain in one
                // dispatch — enum-dispatched, trivially bit-identical.
                let layers: &[usize] = &g.layers;
                dispatch_subset(pool, shards, layers, states, params, |k, _slot, i, st, param, ws| {
                    let obs = lane_obs(rings, sampled, k, i as u32);
                    let EngineLayer::LowRank(l) = st else { unreachable!() };
                    let ctx = StepCtx { t, lr, hyper, errors, obs };
                    l.rule.step_layer(
                        &metas[i],
                        &mut l.source,
                        &mut l.rotation,
                        &mut l.residual,
                        param,
                        &grads[i],
                        &ctx,
                        ws,
                    );
                    if refresh && gauge_step {
                        l.last_quality = l.source.quality().map(|q| (t, q));
                    }
                });
                continue;
            }

            // Disjoint field borrows: the dispatch closures write the batch
            // buffers through raw per-slot pointers while reading group
            // scalars and the shared slices of *other* fields.
            let StepGroup {
                layers,
                rr,
                rank,
                transposed,
                staged,
                ns,
                batch_sims,
                dct,
                stage,
                sims,
                lows,
                held,
                dst_ptrs,
                ..
            } = g;
            let layers: &[usize] = layers;
            let (rr, rank) = (*rr, *rank);
            let (transposed, staged, ns) = (*transposed, *staged, *ns);

            // -- phase 1: stage -------------------------------------------
            if ns {
                let held_p = SendPtr(held.as_mut_ptr());
                dispatch_subset(pool, shards, layers, states, params, |_k, slot, i, st, _param, ws| {
                    let EngineLayer::LowRank(l) = st else { unreachable!() };
                    let Rule::Ns(rule) = &mut l.rule else { unreachable!() };
                    // SAFETY: one writer per slot (disjoint slot ranges).
                    let cell = unsafe { &mut *held_p.0.add(slot) };
                    *cell = Some(rule.begin_accumulate(&metas[i], &grads[i], ws));
                });
            } else if staged {
                let stage_p = SendPtr(stage.as_mut_ptr());
                dispatch_subset(pool, shards, layers, states, params, |_k, slot, i, st, _param, _ws| {
                    let EngineLayer::LowRank(l) = st else { unreachable!() };
                    // SAFETY: one writer per slot (disjoint slot ranges).
                    let buf = unsafe { &mut *stage_p.0.add(slot) };
                    if transposed {
                        grads[i].transpose_into(buf);
                    } else {
                        buf.copy_from(&grads[i]);
                    }
                    l.residual.add_into_grad(buf);
                });
            }

            // -- phase 2: one batched kernel dispatch for the group -------
            // Phase 2 runs on the orchestrating thread between dispatches,
            // so ring 0 is free (SAFETY of `lane_obs(.., 0, ..)`).
            let gobs = lane_obs(rings, sampled, 0, Event::NO_LAYER);
            let stage_ro: &[Matrix] = stage;
            let held_ro: &[Option<Matrix>] = held;
            if refresh {
                for (d, s) in dst_ptrs.iter_mut().zip(sims.iter_mut()) {
                    *d = SendPtr(s.data.as_mut_ptr());
                }
                let use_makhoul = batch_sims.expect("batched refresh without sims hook");
                let dct = dct.as_ref().expect("batched refresh without shared DCT");
                let dsts: &[SendPtr<f32>] = dst_ptrs;
                gobs.span("batch-sims", || {
                    if ns {
                        let src =
                            as_src(|l: usize| held_ro[l].as_ref().expect("held momentum"));
                        dct.similarities_rows_batched_on(pool, rr, use_makhoul, &src, dsts);
                    } else if staged {
                        let src = as_src(|l: usize| &stage_ro[l]);
                        dct.similarities_rows_batched_on(pool, rr, use_makhoul, &src, dsts);
                    } else {
                        let src = as_src(|l: usize| &grads[layers[l]]);
                        dct.similarities_rows_batched_on(pool, rr, use_makhoul, &src, dsts);
                    }
                });
            } else {
                for (d, m) in dst_ptrs.iter_mut().zip(lows.iter_mut()) {
                    *d = SendPtr(m.data.as_mut_ptr());
                }
                let states_ro: &[EngineLayer] = states;
                let basis = as_src(|l: usize| {
                    let EngineLayer::LowRank(ll) = &states_ro[layers[l]] else {
                        unreachable!()
                    };
                    ll.source.basis_ref().expect("batched project without cached basis")
                });
                let dsts: &[SendPtr<f32>] = dst_ptrs;
                gobs.span("batch-project", || {
                    if ns {
                        let src =
                            as_src(|l: usize| held_ro[l].as_ref().expect("held momentum"));
                        matmul_rows_batched_on(pool, rr, &src, &basis, dsts);
                    } else if staged {
                        let src = as_src(|l: usize| &stage_ro[l]);
                        matmul_rows_batched_on(pool, rr, &src, &basis, dsts);
                    } else {
                        let src = as_src(|l: usize| &grads[layers[l]]);
                        matmul_rows_batched_on(pool, rr, &src, &basis, dsts);
                    }
                });
            }
            let _ = rank;

            // -- phase 3: finish ------------------------------------------
            let sims_ro: &[Matrix] = sims;
            let lows_ro: &[Matrix] = lows;
            let held_p = SendPtr(held.as_mut_ptr());
            dispatch_subset(pool, shards, layers, states, params, |k, slot, i, st, param, ws| {
                let obs = lane_obs(rings, sampled, k, i as u32);
                let ctx = StepCtx { t, lr, hyper, errors, obs };
                let EngineLayer::LowRank(l) = st else { unreachable!() };
                let LowRankLayer { source, rotation, residual, rule, last_quality } = l;
                let proj = if refresh {
                    ProjIn::Sims(&sims_ro[slot])
                } else {
                    ProjIn::Low(&lows_ro[slot])
                };
                match rule {
                    Rule::Ns(rule) => {
                        // SAFETY: one writer per slot; same partition as
                        // phase 1, so the commit replays on the checkout's
                        // shard.
                        let cell = unsafe { &mut *held_p.0.add(slot) };
                        let momentum = cell.take().expect("held momentum");
                        rule.finish_from(&metas[i], source, param, momentum, proj, &ctx, ws);
                    }
                    Rule::Adam(adam) => {
                        let gmat: &Matrix =
                            if staged { &stage_ro[slot] } else { &grads[i] };
                        adam.core_with(
                            &metas[i], source, rotation, residual, param, gmat, proj,
                            &ctx, ws,
                        );
                    }
                }
                if refresh && gauge_step {
                    *last_quality = source.quality().map(|q| (t, q));
                }
            });
        }

        // -- dense fallback layers, one dispatch over the plan's list -----
        dispatch_subset(pool, shards, &self.dense, states, params, |k, _slot, i, st, param, ws| {
            let obs = lane_obs(rings, sampled, k, i as u32);
            let EngineLayer::Dense(a) = st else { unreachable!() };
            obs.span("dense", || {
                a.update_ws(
                    param, &grads[i], lr, hyper.beta1, hyper.beta2, hyper.eps, dense_wd,
                    t, ws,
                )
            });
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::ParamKind;

    /// Group count of a 6-layer single-shape-class model under `cap_rows`.
    fn cap_groups(cap: usize) -> usize {
        let metas: Vec<LayerMeta> = (0..6)
            .map(|i| LayerMeta::new(&format!("w{i}"), 16, 8, ParamKind::Linear))
            .collect();
        let eng = OptimizerSpec::dct_adamw(4)
            .update_interval(3)
            .threads(Some(1))
            .build(&metas);
        EnginePlan::build_with_cap(&eng.spec, &eng.metas, &eng.states, &eng.shared, cap)
            .group_count()
    }

    #[test]
    fn group_cap_splits_shape_classes() {
        // unlimited (0) stacks the whole shape class into one group
        assert_eq!(cap_groups(0), 1);
        // each layer contributes 16 oriented rows
        assert_eq!(cap_groups(32), 3); // 2 layers per group
        assert_eq!(cap_groups(48), 2); // 3 layers per group
        assert_eq!(cap_groups(1000), 1); // cap above the class: no split
        // a cap below a single layer's rows degrades to singleton groups
        // (a layer can never be dropped, only isolated)
        assert_eq!(cap_groups(8), 6);
    }

    #[test]
    fn spec_group_cap_feeds_plan_build() {
        // the config-key surface: a spec-carried cap reaches `build`
        // without the env knob (config wins over env when both are set)
        let metas: Vec<LayerMeta> = (0..6)
            .map(|i| LayerMeta::new(&format!("w{i}"), 16, 8, ParamKind::Linear))
            .collect();
        let eng = OptimizerSpec::dct_adamw(4)
            .update_interval(3)
            .threads(Some(1))
            .max_group_rows(32)
            .build(&metas);
        let plan =
            EnginePlan::build(&eng.spec, &eng.metas, &eng.states, &eng.shared);
        assert_eq!(plan.group_count(), 3); // 2 layers (32 rows) per group
    }

    #[test]
    fn group_cap_env_parse_is_lenient() {
        // lenient env surface: unset / 0 / garbage all mean unlimited
        assert_eq!(parse_group_rows(Some("64")), 64);
        assert_eq!(parse_group_rows(Some(" 64 ")), 64);
        assert_eq!(parse_group_rows(Some("not-a-number")), 0);
        assert_eq!(parse_group_rows(Some("0")), 0);
        assert_eq!(parse_group_rows(None), 0);
    }
}
