//! [`SubspaceSource`] — one layer's subspace provider: a [`Projection`]
//! plus the refresh schedule that decides *when* the subspace is
//! recomputed. This is the first of the four policy axes (source, rotation,
//! residual, update rule) the engine composes; the other three are traits,
//! this one is a struct because every method is pure delegation and the
//! schedule is a single integer.

use anyhow::Result;

use crate::projection::Projection;
use crate::tensor::{Matrix, Workspace};
use crate::util::codec::ByteReader;

/// A projection plus its refresh cadence `T_u`.
///
/// `interval == 1` refreshes every step (LDAdam / Trion regime); larger
/// intervals are the GaLore regime where the subspace is held fixed between
/// refreshes. Step 1 always refreshes (every method needs an initial
/// subspace fitted to real gradients).
pub struct SubspaceSource {
    proj: Box<dyn Projection>,
    interval: u64,
}

impl SubspaceSource {
    pub fn new(proj: Box<dyn Projection>, update_interval: usize) -> Self {
        SubspaceSource { proj, interval: update_interval.max(1) as u64 }
    }

    /// The legacy cadence shared by every preset: refresh at `t == 1` and
    /// whenever `t % T_u == 0`.
    pub fn refresh_due(&self, t: u64) -> bool {
        t == 1 || t % self.interval == 0
    }

    pub fn rank(&self) -> usize {
        self.proj.rank()
    }

    pub fn refresh_and_project_into(&mut self, g: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        self.proj.refresh_and_project_into(g, out, ws);
    }

    pub fn project_into(&self, g: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        self.proj.project_into(g, out, ws);
    }

    pub fn back_into(&self, low: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        self.proj.back_into(low, out, ws);
    }

    pub fn basis_into(&self, out: &mut Matrix) {
        self.proj.basis_into(out);
    }

    pub fn rotation_into(&self, prev_basis: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        self.proj.rotation_into(prev_basis, out, ws);
    }

    // -- fused-step-plan hooks (engine/plan.rs) --------------------------

    /// Whether the projection's refresh similarity pass can be computed by
    /// a group-batched kernel call (see [`Projection::batched_sims`]).
    /// `Some(use_makhoul)` for the DCT family, `None` otherwise.
    pub fn batched_sims(&self) -> Option<bool> {
        self.proj.batched_sims()
    }

    /// Refresh from precomputed similarities `s = g·Q` (only valid when
    /// [`SubspaceSource::batched_sims`] is `Some`).
    pub fn refresh_from_sims(&mut self, g: &Matrix, s: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        self.proj.refresh_from_sims(g, s, out, ws);
    }

    /// Borrow of the cached dense basis `Q_r (C×r)` when the projection
    /// keeps one — feeds the group-batched non-refresh project pass.
    pub fn basis_ref(&self) -> Option<&Matrix> {
        self.proj.basis_ref()
    }

    /// Selected column indices for index-selection bases (DCT / RandPerm);
    /// `None` for dense bases. The typed dispatch the fixed-basis rotation
    /// and the low-rank broadcast payload rely on.
    pub fn indices(&self) -> Option<&[usize]> {
        self.proj.indices()
    }

    /// Materialized basis `Q_r (C×r)` — allocating; test/instrumentation
    /// hook, not a hot-path method.
    pub fn basis(&self) -> Matrix {
        self.proj.basis()
    }

    /// Subspace-quality gauges from the most recent refresh (see
    /// [`Projection::quality`]); `None` until the projection has refreshed
    /// on the workspace path or when the family doesn't track them.
    pub fn quality(&self) -> Option<crate::obs::SubspaceQuality> {
        self.proj.quality()
    }

    /// Per-layer state bytes. Per-device *shared* state is accounted by the
    /// engine's own shared-DCT registry (`SubspaceEngine::memory_report`),
    /// not through the source — a new shared-basis projection family must
    /// be wired in there.
    pub fn state_bytes(&self) -> u64 {
        self.proj.state_bytes()
    }

    /// Checkpoint-v2 serialization of the projection's persistent state
    /// (selected indices / dense bases / warm-start flags / RNG streams).
    pub fn save_state(&self, out: &mut Vec<u8>) {
        self.proj.save_state(out);
    }

    /// Twin of [`SubspaceSource::save_state`].
    pub fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        self.proj.load_state(r)
    }
}
