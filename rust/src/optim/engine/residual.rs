//! [`ResidualPolicy`] — what happens to the out-of-subspace gradient
//! `Ξ = G − (G·Q_r)·Q_rᵀ` (Table 3's "residual handling" axis):
//!
//! * [`DiscardResidual`] — GaLore: thrown away.
//! * [`EfResidual`] — LDAdam / DCT-AdamW: accumulated in an error-feedback
//!   buffer (a typed [`StateStore`]: f32 or the paper's 8-bit quantized
//!   form, per [`EfMode`]) and added back to the next gradient before
//!   projection.
//! * [`FiraResidual`] — FIRA: added to the update, norm-scaled by
//!   `φ = ‖u_low‖/‖g_low‖` so it moves with an Adam-calibrated magnitude.
//! * [`SignResidual`] — FRUGAL: fed to stateless SignSGD.
//!
//! The hooks mirror where the legacy optimizers touched the residual:
//! `add_into_grad` before projection (EF replay), `store_residual` right
//! after projection (EF capture — before the Adam moments are touched,
//! exactly like the legacy loops), and `finish_update` which back-projects
//! the subspace update and folds in the policy's residual contribution.

use anyhow::Result;

use crate::optim::common::MemoryReport;
use crate::optim::EfMode;
use crate::tensor::{Matrix, StateStore, Workspace};
use crate::util::codec::ByteReader;

use super::source::SubspaceSource;

pub trait ResidualPolicy: Send {
    /// Whether the policy mutates the oriented gradient in place (error
    /// feedback) — drives the owned-vs-borrowed gradient checkout in the
    /// update rule.
    fn wants_owned_grad(&self) -> bool {
        false
    }

    /// `G ← G + Ξ` before projection (EF replay).
    fn add_into_grad(&self, _g: &mut Matrix) {}

    /// Capture the new residual right after projection. `full` is an
    /// uninitialized R×C scratch the policy may clobber (the update rule
    /// fully overwrites it afterwards).
    fn store_residual(
        &mut self,
        _source: &SubspaceSource,
        _g_low: &Matrix,
        _g: &Matrix,
        _full: &mut Matrix,
        _ws: &mut Workspace,
    ) {
    }

    /// Back-project the subspace update `u_low` into `full (R×C)` and fold
    /// in the policy's residual contribution. The default discards the
    /// residual (GaLore).
    fn finish_update(
        &mut self,
        source: &SubspaceSource,
        _g: &Matrix,
        _g_low: &Matrix,
        u_low: &Matrix,
        full: &mut Matrix,
        ws: &mut Workspace,
    ) {
        source.back_into(u_low, full, ws);
    }

    /// Persistent per-layer residual state (the "ef" memory-report family).
    fn memory(&self, _rep: &mut MemoryReport) {}

    /// Checkpoint-v2 serialization of the policy's persistent state
    /// (bit-exact). Stateless policies write nothing.
    fn save_state(&self, _out: &mut Vec<u8>) {}

    /// Twin of [`ResidualPolicy::save_state`].
    fn load_state(&mut self, _r: &mut ByteReader) -> Result<()> {
        Ok(())
    }
}

/// GaLore: the residual is discarded.
pub struct DiscardResidual;

impl ResidualPolicy for DiscardResidual {}

/// LDAdam / DCT-AdamW error feedback. `EfMode::None` still routes the
/// gradient through the owned checkout (matching the legacy DCT-AdamW loop
/// exactly); the buffer itself is absent and both hooks are no-ops.
pub struct EfResidual {
    store: Option<StateStore>,
}

impl EfResidual {
    pub fn new(mode: EfMode, rows: usize, cols: usize) -> Self {
        EfResidual {
            store: mode.state_dtype().map(|d| StateStore::zeros(d, rows, cols)),
        }
    }
}

impl ResidualPolicy for EfResidual {
    fn wants_owned_grad(&self) -> bool {
        true
    }

    fn add_into_grad(&self, g: &mut Matrix) {
        if let Some(st) = &self.store {
            st.add_into(g);
        }
    }

    fn store_residual(
        &mut self,
        source: &SubspaceSource,
        g_low: &Matrix,
        g: &Matrix,
        full: &mut Matrix,
        ws: &mut Workspace,
    ) {
        // Ξ ← G − g·Qᵀ (residual built in the scratch buffer)
        source.back_into(g_low, full, ws);
        full.sub_from(g);
        if let Some(st) = &mut self.store {
            st.store_from(full);
        }
    }

    fn memory(&self, rep: &mut MemoryReport) {
        rep.add("ef", self.store.as_ref().map_or(0, |st| st.bytes()));
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        if let Some(st) = &self.store {
            st.save(out);
        }
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        match &mut self.store {
            Some(st) => st.load_from(r),
            None => Ok(()),
        }
    }
}

/// FIRA: residual added back norm-scaled by `φ = ‖u_low‖/‖g_low‖`.
pub struct FiraResidual;

impl ResidualPolicy for FiraResidual {
    fn finish_update(
        &mut self,
        source: &SubspaceSource,
        g: &Matrix,
        g_low: &Matrix,
        u_low: &Matrix,
        full: &mut Matrix,
        ws: &mut Workspace,
    ) {
        // φ = ‖u_low‖ / ‖g_low‖ — Adam-calibrated scaling for the residual
        // (FIRA's norm-based scaling)
        let phi = (u_low.fro_norm() / (g_low.fro_norm() + 1e-12)) as f32;
        source.back_into(u_low, full, ws);
        let mut resid = ws.take_uninit(full.rows, full.cols);
        source.back_into(g_low, &mut resid, ws);
        resid.sub_from(g);
        full.axpy(phi, &resid);
        ws.give(resid);
    }
}

/// FRUGAL: the "state-free" branch — SignSGD on the residual.
pub struct SignResidual {
    /// state-free learning-rate multiplier for the SignSGD branch
    pub scale: f32,
}

impl ResidualPolicy for SignResidual {
    fn finish_update(
        &mut self,
        source: &SubspaceSource,
        g: &Matrix,
        g_low: &Matrix,
        u_low: &Matrix,
        full: &mut Matrix,
        ws: &mut Workspace,
    ) {
        source.back_into(u_low, full, ws);
        let mut resid = ws.take_uninit(full.rows, full.cols);
        source.back_into(g_low, &mut resid, ws);
        resid.sub_from(g);
        for (uv, &rv) in full.data.iter_mut().zip(resid.data.iter()) {
            // rust's signum(0.0) == 1.0; SignSGD wants sign(0) = 0
            if rv != 0.0 {
                *uv += self.scale * rv.signum();
            }
        }
        ws.give(resid);
    }
}

/// The closed residual-policy set as a monomorphized enum (fused-plan
/// dispatch; see `engine/plan.rs`). Delegates [`ResidualPolicy`] verbatim.
pub enum Residual {
    Discard(DiscardResidual),
    Ef(EfResidual),
    Fira(FiraResidual),
    Sign(SignResidual),
}

impl ResidualPolicy for Residual {
    fn wants_owned_grad(&self) -> bool {
        match self {
            Residual::Discard(p) => p.wants_owned_grad(),
            Residual::Ef(p) => p.wants_owned_grad(),
            Residual::Fira(p) => p.wants_owned_grad(),
            Residual::Sign(p) => p.wants_owned_grad(),
        }
    }

    fn add_into_grad(&self, g: &mut Matrix) {
        match self {
            Residual::Discard(p) => p.add_into_grad(g),
            Residual::Ef(p) => p.add_into_grad(g),
            Residual::Fira(p) => p.add_into_grad(g),
            Residual::Sign(p) => p.add_into_grad(g),
        }
    }

    fn store_residual(
        &mut self,
        source: &SubspaceSource,
        g_low: &Matrix,
        g: &Matrix,
        full: &mut Matrix,
        ws: &mut Workspace,
    ) {
        match self {
            Residual::Discard(p) => p.store_residual(source, g_low, g, full, ws),
            Residual::Ef(p) => p.store_residual(source, g_low, g, full, ws),
            Residual::Fira(p) => p.store_residual(source, g_low, g, full, ws),
            Residual::Sign(p) => p.store_residual(source, g_low, g, full, ws),
        }
    }

    fn finish_update(
        &mut self,
        source: &SubspaceSource,
        g: &Matrix,
        g_low: &Matrix,
        u_low: &Matrix,
        full: &mut Matrix,
        ws: &mut Workspace,
    ) {
        match self {
            Residual::Discard(p) => p.finish_update(source, g, g_low, u_low, full, ws),
            Residual::Ef(p) => p.finish_update(source, g, g_low, u_low, full, ws),
            Residual::Fira(p) => p.finish_update(source, g, g_low, u_low, full, ws),
            Residual::Sign(p) => p.finish_update(source, g, g_low, u_low, full, ws),
        }
    }

    fn memory(&self, rep: &mut MemoryReport) {
        match self {
            Residual::Discard(p) => p.memory(rep),
            Residual::Ef(p) => p.memory(rep),
            Residual::Fira(p) => p.memory(rep),
            Residual::Sign(p) => p.memory(rep),
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        match self {
            Residual::Discard(p) => p.save_state(out),
            Residual::Ef(p) => p.save_state(out),
            Residual::Fira(p) => p.save_state(out),
            Residual::Sign(p) => p.save_state(out),
        }
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        match self {
            Residual::Discard(p) => p.load_state(r),
            Residual::Ef(p) => p.load_state(r),
            Residual::Fira(p) => p.load_state(r),
            Residual::Sign(p) => p.load_state(r),
        }
    }
}
