//! [`RotationPolicy`] — what happens to the subspace Adam moments when the
//! subspace changes (Algorithm 2's smooth transition, Table 3's "moment
//! rotation" axis):
//!
//! * [`NoRotation`] — GaLore/FRUGAL/FIRA: moments are left in the stale
//!   frame (their large `T_u` makes cross-refresh mixing rare).
//! * [`DenseRotation`] — LDAdam: `R = Q_prevᵀ·Q_crt (r×r)`, `m ← m·R`,
//!   `v ← |v·R|`; stores the previous basis (`C×r` floats per layer).
//! * [`FixedBasisRotation`] — DCT-AdamW: for a *fixed orthogonal* basis the
//!   rotation collapses to 0/1 index matching (`R[i][j] = 1 ⇔
//!   idx_prev[i] == idx_crt[j]`) — a permutation-with-drop, no matmul;
//!   stores `r` previous indices per layer.
//!
//! The engine calls [`RotationPolicy::before_refresh`] immediately before a
//! subspace refresh (snapshot the outgoing frame) and
//! [`RotationPolicy::rotate_moments`] immediately after it. Policies skip
//! the very first refresh themselves — there are no moments to carry into
//! the initial subspace.

use anyhow::Result;

use crate::optim::common::MemoryReport;
use crate::tensor::{matmul_into, Matrix, Workspace};
use crate::util::codec::{self, ByteReader};

use super::source::SubspaceSource;

pub trait RotationPolicy: Send {
    /// Snapshot whatever the rotation needs from the *outgoing* subspace.
    /// Called right before every refresh.
    fn before_refresh(&mut self, source: &SubspaceSource);

    /// Rotate the subspace moments into the just-refreshed subspace.
    /// Called right after every refresh.
    fn rotate_moments(
        &mut self,
        source: &SubspaceSource,
        m: &mut Matrix,
        v: &mut Matrix,
        ws: &mut Workspace,
    );

    /// Persistent per-layer rotation state ("indices_prev" /
    /// "projector_prev" memory-report families).
    fn memory(&self, _rep: &mut MemoryReport) {}

    /// The snapshotted indices (fixed-basis policy only) — test hook.
    fn snapshot_indices(&self) -> Option<&[usize]> {
        None
    }

    /// Checkpoint-v2 serialization of the rotation's cross-refresh state
    /// (snapshots + first-refresh flags — both feed the *next* refresh, so
    /// a bit-identical resume must carry them). Stateless policies write
    /// nothing.
    fn save_state(&self, _out: &mut Vec<u8>) {}

    /// Twin of [`RotationPolicy::save_state`].
    fn load_state(&mut self, _r: &mut ByteReader) -> Result<()> {
        Ok(())
    }
}

/// Rotate subspace moments for a *fixed orthogonal basis*: since
/// `QᵀQ = I`, `R[i][j] = 1 ⇔ idx_prev[i] == idx_crt[j]`, so `m·R` keeps the
/// columns whose index survives and zeroes the rest.
pub fn rotate_fixed_basis(m: &Matrix, idx_prev: &[usize], idx_crt: &[usize]) -> Matrix {
    debug_assert_eq!(m.cols, idx_prev.len());
    let mut out = Matrix::zeros(m.rows, idx_crt.len());
    rotate_fixed_basis_core(m, idx_prev, idx_crt, &mut out);
    out
}

/// In-place [`rotate_fixed_basis`]: `m` (R×|prev|) becomes the rotated
/// R×|crt| matrix, staging through a pooled workspace buffer.
pub fn rotate_fixed_basis_into(
    m: &mut Matrix,
    idx_prev: &[usize],
    idx_crt: &[usize],
    ws: &mut Workspace,
) {
    debug_assert_eq!(m.cols, idx_prev.len());
    let mut out = ws.take(m.rows, idx_crt.len());
    rotate_fixed_basis_core(m, idx_prev, idx_crt, &mut out);
    m.copy_from(&out);
    ws.give(out);
}

/// Shared merge kernel: both index lists are sorted ascending; `out` must
/// arrive zeroed (dropped columns stay zero).
fn rotate_fixed_basis_core(m: &Matrix, idx_prev: &[usize], idx_crt: &[usize], out: &mut Matrix) {
    let (mut a, mut b) = (0usize, 0usize);
    while a < idx_prev.len() && b < idx_crt.len() {
        match idx_prev[a].cmp(&idx_crt[b]) {
            std::cmp::Ordering::Less => a += 1,
            std::cmp::Ordering::Greater => b += 1,
            std::cmp::Ordering::Equal => {
                for i in 0..m.rows {
                    out.data[i * idx_crt.len() + b] = m.data[i * m.cols + a];
                }
                a += 1;
                b += 1;
            }
        }
    }
}

/// Leave moments in the stale frame (GaLore / FRUGAL / FIRA).
pub struct NoRotation;

impl RotationPolicy for NoRotation {
    fn before_refresh(&mut self, _source: &SubspaceSource) {}

    fn rotate_moments(
        &mut self,
        _source: &SubspaceSource,
        _m: &mut Matrix,
        _v: &mut Matrix,
        _ws: &mut Workspace,
    ) {
    }
}

/// DCT-AdamW's index-matching rotation for fixed orthogonal bases.
pub struct FixedBasisRotation {
    idx_prev: Vec<usize>,
    first: bool,
}

impl FixedBasisRotation {
    pub fn new(rank: usize) -> Self {
        // Matches the initial index set of the selection sources (0..r), so
        // the "indices_prev" byte accounting is exact from step 0.
        FixedBasisRotation { idx_prev: (0..rank).collect(), first: true }
    }
}

impl RotationPolicy for FixedBasisRotation {
    fn before_refresh(&mut self, source: &SubspaceSource) {
        let idx = source
            .indices()
            .expect("fixed-basis rotation needs an index-selection source");
        self.idx_prev.clear();
        self.idx_prev.extend_from_slice(idx);
    }

    fn rotate_moments(
        &mut self,
        source: &SubspaceSource,
        m: &mut Matrix,
        v: &mut Matrix,
        ws: &mut Workspace,
    ) {
        if !self.first {
            let idx_crt = source
                .indices()
                .expect("fixed-basis rotation needs an index-selection source");
            rotate_fixed_basis_into(m, &self.idx_prev, idx_crt, ws);
            rotate_fixed_basis_into(v, &self.idx_prev, idx_crt, ws);
            // |v·R| — the rotation here is 0/1 so abs is a no-op, kept for
            // parity with Algorithm 2
            for x in &mut v.data {
                *x = x.abs();
            }
        }
        self.first = false;
    }

    fn memory(&self, rep: &mut MemoryReport) {
        rep.add("indices_prev", (self.idx_prev.len() * 4) as u64);
    }

    fn snapshot_indices(&self) -> Option<&[usize]> {
        Some(&self.idx_prev)
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        codec::put_indices(out, &self.idx_prev);
        codec::put_u8(out, self.first as u8);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        self.idx_prev = r.take_indices()?;
        self.first = r.take_u8()? != 0;
        Ok(())
    }
}

/// LDAdam's dense rotation `R = Q_prevᵀ·Q_crt`; costs a second `C×r`
/// projector per layer — exactly the overhead the fixed-basis variant
/// removes.
pub struct DenseRotation {
    prev_basis: Matrix, // C×r
    first: bool,
}

impl DenseRotation {
    pub fn new(cols: usize, rank: usize) -> Self {
        DenseRotation { prev_basis: Matrix::zeros(cols, rank), first: true }
    }
}

impl RotationPolicy for DenseRotation {
    fn before_refresh(&mut self, source: &SubspaceSource) {
        source.basis_into(&mut self.prev_basis);
    }

    fn rotate_moments(
        &mut self,
        source: &SubspaceSource,
        m: &mut Matrix,
        v: &mut Matrix,
        ws: &mut Workspace,
    ) {
        if !self.first {
            let r = m.cols;
            let mut rot = ws.take_uninit(r, r);
            source.rotation_into(&self.prev_basis, &mut rot, ws);
            let mut tmp = ws.take_uninit(m.rows, r);
            matmul_into(m, &rot, &mut tmp);
            m.copy_from(&tmp);
            matmul_into(v, &rot, &mut tmp);
            v.copy_from(&tmp);
            for x in &mut v.data {
                *x = x.abs();
            }
            ws.give(tmp);
            ws.give(rot);
        }
        self.first = false;
    }

    fn memory(&self, rep: &mut MemoryReport) {
        rep.add("projector_prev", self.prev_basis.bytes());
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        codec::put_matrix(out, &self.prev_basis);
        codec::put_u8(out, self.first as u8);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        r.take_matrix_into(&mut self.prev_basis)?;
        self.first = r.take_u8()? != 0;
        Ok(())
    }
}

/// The closed rotation-policy set as a monomorphized enum (fused-plan
/// dispatch; see `engine/plan.rs`). Delegates [`RotationPolicy`] verbatim.
pub enum Rotation {
    None(NoRotation),
    Fixed(FixedBasisRotation),
    Dense(DenseRotation),
}

impl RotationPolicy for Rotation {
    fn before_refresh(&mut self, source: &SubspaceSource) {
        match self {
            Rotation::None(p) => p.before_refresh(source),
            Rotation::Fixed(p) => p.before_refresh(source),
            Rotation::Dense(p) => p.before_refresh(source),
        }
    }

    fn rotate_moments(
        &mut self,
        source: &SubspaceSource,
        m: &mut Matrix,
        v: &mut Matrix,
        ws: &mut Workspace,
    ) {
        match self {
            Rotation::None(p) => p.rotate_moments(source, m, v, ws),
            Rotation::Fixed(p) => p.rotate_moments(source, m, v, ws),
            Rotation::Dense(p) => p.rotate_moments(source, m, v, ws),
        }
    }

    fn memory(&self, rep: &mut MemoryReport) {
        match self {
            Rotation::None(p) => p.memory(rep),
            Rotation::Fixed(p) => p.memory(rep),
            Rotation::Dense(p) => p.memory(rep),
        }
    }

    fn snapshot_indices(&self) -> Option<&[usize]> {
        match self {
            Rotation::None(p) => p.snapshot_indices(),
            Rotation::Fixed(p) => p.snapshot_indices(),
            Rotation::Dense(p) => p.snapshot_indices(),
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        match self {
            Rotation::None(p) => p.save_state(out),
            Rotation::Fixed(p) => p.save_state(out),
            Rotation::Dense(p) => p.save_state(out),
        }
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        match self {
            Rotation::None(p) => p.load_state(r),
            Rotation::Fixed(p) => p.load_state(r),
            Rotation::Dense(p) => p.load_state(r),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_at_b};
    use crate::util::Pcg64;

    #[test]
    fn rotation_matches_matmul_definition() {
        // rotate_fixed_basis == m · (Q[:,prev]ᵀ Q[:,crt]) for orthogonal Q
        let mut rng = Pcg64::seed(0);
        let q = crate::fft::dct2_matrix(12);
        let prev = vec![0, 3, 5, 9];
        let crt = vec![3, 4, 9, 11];
        let m = Matrix::randn(6, 4, 1.0, &mut rng);
        let got = rotate_fixed_basis(&m, &prev, &crt);
        let qp = q.select_columns(&prev);
        let qc = q.select_columns(&crt);
        let rot = matmul_at_b(&qp, &qc);
        let want = matmul(&m, &rot);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn rotation_into_handles_rank_change() {
        let mut rng = Pcg64::seed(7);
        let mut m = Matrix::randn(3, 4, 1.0, &mut rng);
        let want = rotate_fixed_basis(&m, &[0, 2, 5, 7], &[2, 3, 7]);
        let mut ws = Workspace::new();
        rotate_fixed_basis_into(&mut m, &[0, 2, 5, 7], &[2, 3, 7], &mut ws);
        assert_eq!(m, want);
        assert_eq!(m.shape(), (3, 3));
    }

    #[test]
    fn allocating_twin_needs_no_workspace_and_matches_into() {
        let mut rng = Pcg64::seed(9);
        let m = Matrix::randn(5, 3, 1.0, &mut rng);
        let prev = vec![1, 4, 6];
        let crt = vec![0, 4, 6, 9];
        let want = rotate_fixed_basis(&m, &prev, &crt);
        let mut m2 = m.clone();
        let mut ws = Workspace::new();
        rotate_fixed_basis_into(&mut m2, &prev, &crt, &mut ws);
        assert_eq!(m2, want);
    }
}
