//! [`OptimizerSpec`] — the builder that makes the paper's whole ablation
//! grid expressible as one value: a subspace **source** ([`ProjectionKind`]
//! + refresh cadence), a moment **rotation** policy, a **residual** policy
//! and an inner **update rule**, plus the shared hyper-parameters.
//!
//! The six published methods are presets
//! ([`OptimizerSpec::dct_adamw`] … [`OptimizerSpec::ldadamw`]) whose built
//! engines are bit-identical to the pre-engine hand-written optimizers
//! (pinned by `tests/engine_equivalence.rs`); any other grid point — e.g.
//! GaLore cadence + DCT source + Q8 error feedback — is the same builder
//! with different axes, not a new optimizer file.

use crate::optim::common::{OptimizerConfig, OptimizerKind};
use crate::optim::EfMode;
use crate::projection::{ProjectionKind, RankNorm};
use crate::tensor::StateDtype;

use super::plan::StepPlanMode;

/// Residual-handling axis (Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidualKind {
    /// GaLore: discard the out-of-subspace gradient.
    Discard,
    /// LDAdam / DCT-AdamW: error-feedback buffer at the given resolution.
    ErrorFeedback(EfMode),
    /// FIRA: add the residual back, norm-scaled by ‖u_low‖/‖g_low‖.
    FiraScale,
    /// FRUGAL: SignSGD on the residual.
    SignDescent,
}

/// Moment-rotation axis (Algorithm 2 / LDAdam §3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RotationKind {
    /// Leave moments in the stale frame (GaLore / FRUGAL / FIRA).
    None,
    /// 0/1 index matching — requires an index-selection source
    /// (DCT / RandPerm).
    FixedBasis,
    /// Dense `Q_prevᵀ·Q_crt` — works with any source, costs a second `C×r`
    /// projector per layer.
    Dense,
}

/// Inner update rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateRuleKind {
    /// Fused subspace AdamW (GaLore / LDAdam / DCT-AdamW / FIRA / FRUGAL).
    SubspaceAdamW,
    /// Newton–Schulz orthogonalized momentum (Trion / Dion family).
    NewtonSchulz,
}

/// What a ZeRO owner broadcasts after computing a layer's update (§2.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BroadcastKind {
    /// The full `R×C` update.
    Full,
    /// The low-rank factor + indices (`R×r` floats + `r` int32).
    LowRankFactor,
}

/// Builder-style composable optimizer configuration. Construct with a
/// preset (or [`OptimizerSpec::base`]), adjust axes with the builder
/// methods, then [`build`](OptimizerSpec::build) against the model's layer
/// metas.
#[derive(Clone, Debug)]
pub struct OptimizerSpec {
    pub rank: usize,
    pub projection: ProjectionKind,
    pub update_interval: usize,
    pub rotation: RotationKind,
    pub residual: ResidualKind,
    pub rule: UpdateRuleKind,
    /// Storage precision of the rule's persistent state (Adam moments / NS
    /// momentum) and the dense-fallback moments — the fifth composition
    /// axis (`state-dtype=f32|bf16|q8`). `F32` is bit-invisible (the
    /// preset-equivalence contract); lower precisions are the measurable
    /// side of the paper's optimizer-memory claim. The EF buffer keeps its
    /// own `ErrorFeedback(mode)` resolution.
    pub state_dtype: StateDtype,
    pub broadcast: BroadcastKind,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Trion/Dion momentum μ (Newton–Schulz rule only).
    pub mu: f32,
    /// Newton–Schulz iterations.
    pub ns_steps: usize,
    /// Weight decay for the dense-AdamW fallback layers when it differs
    /// from `weight_decay` (the momentum presets decay only low-rank
    /// layers, matching the legacy Trion/Dion behavior).
    pub dense_weight_decay: Option<f32>,
    /// Record per-layer projection errors each step (Figure 1;
    /// Newton–Schulz rule only).
    pub instrument: bool,
    pub seed: u64,
    /// Per-layer seed derivation: layer `i` gets
    /// `seed ^ ((i as u64) << seed_shift)`. Presets keep their historical
    /// shifts so seeded projections (Random/RandPerm) reproduce the
    /// pre-engine trajectories exactly.
    pub seed_shift: u32,
    /// Execution lanes: `None` shares the process-global pool, `Some(n)` a
    /// private n-lane pool (tests pin 1 vs N for bit-identity).
    pub threads: Option<usize>,
    /// Step execution mode: compiled shape-batched programs (default) or
    /// the per-layer interpreted loop (the differential-testing oracle).
    /// Bit-identical by contract, so deliberately **excluded** from
    /// `describe()`/`resolve_name()` and the checkpoint fingerprint —
    /// checkpoints resume across modes.
    pub step_plan: StepPlanMode,
    /// Row cap for shape-batched step-plan groups (`0` = unlimited; falls
    /// back to `FFT_SUBSPACE_MAX_GROUP_ROWS` when unset). Splitting is
    /// bit-identical by the fusion contract, so this — like `step_plan` —
    /// stays out of `describe()` and the checkpoint fingerprint.
    pub max_group_rows: usize,
    name: Option<String>,
}

impl OptimizerSpec {
    /// Neutral starting point: DCT source, refresh every step, no rotation,
    /// residual discarded, subspace AdamW, paper hyper-parameters.
    pub fn base(rank: usize) -> Self {
        OptimizerSpec {
            rank,
            projection: ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true },
            update_interval: 1,
            rotation: RotationKind::None,
            residual: ResidualKind::Discard,
            rule: UpdateRuleKind::SubspaceAdamW,
            state_dtype: StateDtype::F32,
            broadcast: BroadcastKind::Full,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            mu: 0.95,
            ns_steps: 5,
            dense_weight_decay: None,
            instrument: false,
            seed: 0,
            seed_shift: 8,
            threads: None,
            step_plan: StepPlanMode::from_env(),
            max_group_rows: 0,
            name: None,
        }
    }

    // -- the six published presets ---------------------------------------

    /// DCT-AdamW (Algorithms 2–3): DCT column selection, fixed-basis moment
    /// rotation, quantized error feedback.
    pub fn dct_adamw(rank: usize) -> Self {
        Self::base(rank)
            .rotation(RotationKind::FixedBasis)
            .residual(ResidualKind::ErrorFeedback(EfMode::Q8))
    }

    /// Trion (Algorithm 1): DCT column selection over the momentum,
    /// Newton–Schulz orthogonalization, low-rank ZeRO broadcast.
    pub fn trion(rank: usize) -> Self {
        let mut s = Self::base(rank).rule(UpdateRuleKind::NewtonSchulz);
        s.broadcast = BroadcastKind::LowRankFactor;
        s.dense_weight_decay = Some(0.0);
        s
    }

    /// GaLore: SVD source at a long cadence, residual discarded.
    pub fn galore(rank: usize) -> Self {
        Self::base(rank)
            .projection(ProjectionKind::Svd)
            .update_interval(200)
    }

    /// LDAdamW: block power iteration refreshed every step, dense moment
    /// rotation, full-precision error feedback.
    pub fn ldadamw(rank: usize) -> Self {
        Self::base(rank)
            .projection(ProjectionKind::BlockPower { iters: 2 })
            .rotation(RotationKind::Dense)
            .residual(ResidualKind::ErrorFeedback(EfMode::F32))
    }

    /// FIRA: norm-scaled residual re-injection.
    pub fn fira(rank: usize) -> Self {
        let mut s = Self::base(rank).residual(ResidualKind::FiraScale);
        s.seed_shift = 12;
        s
    }

    /// FRUGAL: SignSGD on the residual.
    pub fn frugal(rank: usize) -> Self {
        let mut s = Self::base(rank).residual(ResidualKind::SignDescent);
        s.seed_shift = 4;
        s
    }

    // -- builder methods --------------------------------------------------

    pub fn rank(mut self, rank: usize) -> Self {
        self.rank = rank;
        self
    }

    /// Subspace source family (the `SubspaceSource` projection).
    pub fn projection(mut self, kind: ProjectionKind) -> Self {
        self.projection = kind;
        self
    }

    /// Refresh cadence `T_u` (1 = every step, GaLore default 200).
    pub fn update_interval(mut self, t: usize) -> Self {
        self.update_interval = t.max(1);
        self
    }

    pub fn rotation(mut self, r: RotationKind) -> Self {
        self.rotation = r;
        self
    }

    pub fn residual(mut self, r: ResidualKind) -> Self {
        self.residual = r;
        self
    }

    pub fn rule(mut self, r: UpdateRuleKind) -> Self {
        self.rule = r;
        self
    }

    /// Storage precision of the persistent rule + dense-fallback state.
    pub fn state_dtype(mut self, d: StateDtype) -> Self {
        self.state_dtype = d;
        self
    }

    pub fn betas(mut self, beta1: f32, beta2: f32) -> Self {
        self.beta1 = beta1;
        self.beta2 = beta2;
        self
    }

    pub fn eps(mut self, eps: f32) -> Self {
        self.eps = eps;
        self
    }

    pub fn weight_decay(mut self, wd: f32) -> Self {
        self.weight_decay = wd;
        self
    }

    pub fn mu(mut self, mu: f32) -> Self {
        self.mu = mu;
        self
    }

    pub fn ns_steps(mut self, n: usize) -> Self {
        self.ns_steps = n;
        self
    }

    pub fn instrument(mut self, on: bool) -> Self {
        self.instrument = on;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn threads(mut self, threads: Option<usize>) -> Self {
        self.threads = threads;
        self
    }

    /// Step execution mode (`step-plan=fused|interpreted`).
    pub fn step_plan(mut self, mode: StepPlanMode) -> Self {
        self.step_plan = mode;
        self
    }

    /// Step-plan group row cap (`max-group-rows=N`; `0` = unlimited /
    /// defer to the env knob).
    pub fn max_group_rows(mut self, cap: usize) -> Self {
        self.max_group_rows = cap;
        self
    }

    /// Override the reported optimizer name (otherwise derived from the
    /// composition, matching the legacy preset names exactly).
    pub fn named(mut self, name: &str) -> Self {
        self.name = Some(name.to_string());
        self
    }

    // -- preset aliasing ---------------------------------------------------

    /// The spec behind a legacy [`OptimizerKind`] under a given
    /// [`OptimizerConfig`] — the exact semantics of the pre-engine
    /// constructors, including which config fields each preset honored
    /// (Trion/LDAdamW always refresh; GaLore pins SVD; LDAdamW pins f32
    /// error feedback). `None` for the dense/full-momentum kinds
    /// (AdamW/Muon/Dion), which stay hand-written.
    pub fn from_kind(kind: &OptimizerKind, cfg: &OptimizerConfig) -> Option<OptimizerSpec> {
        let dct = match &cfg.projection {
            ProjectionKind::Dct { norm, use_makhoul } => {
                ProjectionKind::Dct { norm: *norm, use_makhoul: *use_makhoul }
            }
            _ => ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true },
        };
        let spec = match kind {
            OptimizerKind::DctAdamW => Self::dct_adamw(cfg.rank)
                .projection(dct)
                .residual(ResidualKind::ErrorFeedback(cfg.ef_mode))
                .update_interval(cfg.update_interval),
            OptimizerKind::Trion => Self::trion(cfg.rank).projection(dct),
            OptimizerKind::GaLore => {
                Self::galore(cfg.rank).update_interval(cfg.update_interval)
            }
            OptimizerKind::LdAdamW => Self::ldadamw(cfg.rank),
            OptimizerKind::Fira => Self::fira(cfg.rank)
                .projection(cfg.projection.clone())
                .update_interval(cfg.update_interval),
            OptimizerKind::Frugal => Self::frugal(cfg.rank)
                .projection(cfg.projection.clone())
                .update_interval(cfg.update_interval),
            _ => return None,
        };
        Some(
            spec.betas(cfg.beta1, cfg.beta2)
                .eps(cfg.eps)
                .weight_decay(cfg.weight_decay)
                .mu(cfg.mu)
                .ns_steps(cfg.ns_steps)
                .state_dtype(cfg.state_dtype)
                .instrument(cfg.instrument)
                .seed(cfg.seed)
                .threads(cfg.threads)
                .step_plan(cfg.step_plan)
                .max_group_rows(cfg.max_group_rows),
        )
    }

    /// Check that the composition exists: fixed-basis rotation needs an
    /// index-selection source (DCT / RandPerm), and the Newton–Schulz rule
    /// handles its residual inherently so the rotation/residual axes must
    /// stay at their neutral settings. [`build`](OptimizerSpec::build)
    /// panics on exactly these conditions; config-driven construction calls
    /// this first to fail with an error instead.
    pub fn validate(&self) -> Result<(), String> {
        if self.rotation == RotationKind::FixedBasis
            && !matches!(
                self.projection,
                ProjectionKind::Dct { .. } | ProjectionKind::RandPerm
            )
        {
            return Err(format!(
                "fixed-basis rotation needs an index-selection source \
                 (dct/randperm), got {}",
                self.projection.name()
            ));
        }
        if self.rule == UpdateRuleKind::NewtonSchulz
            && (self.residual != ResidualKind::Discard
                || self.rotation != RotationKind::None)
        {
            return Err(
                "the Newton–Schulz momentum rule handles its residual \
                 inherently (M ← B − (1−μ)·b·Qᵀ); compose it with \
                 residual=discard, rotation=none"
                    .to_string(),
            );
        }
        Ok(())
    }

    // -- naming ------------------------------------------------------------

    /// The engine's reported name: the explicit override if set, else the
    /// legacy preset name when the composition matches a published method
    /// (suffixed `+m:bf16`/`+m:q8` when the state dtype departs from the
    /// bit-exact f32 default), else a synthesized composition string.
    pub(super) fn resolve_name(&self) -> String {
        if let Some(n) = &self.name {
            return n.clone();
        }
        let Some(base) = self.resolve_preset_name() else {
            // off-grid compositions carry the dtype inside the parens
            return self.composed_name();
        };
        match self.state_dtype {
            StateDtype::F32 => base,
            d => format!("{base}+m:{}", d.name()),
        }
    }

    /// The legacy preset name when the composition matches a published
    /// method; `None` for off-grid compositions.
    fn resolve_preset_name(&self) -> Option<String> {
        let proj = self.projection.name();
        Some(match (self.rule, self.residual, self.rotation) {
            (UpdateRuleKind::NewtonSchulz, ResidualKind::Discard, RotationKind::None) => {
                match self.projection {
                    ProjectionKind::Dct { .. } => "trion".to_string(),
                    _ => format!("trion+{proj}"),
                }
            }
            (UpdateRuleKind::SubspaceAdamW, ResidualKind::SignDescent, RotationKind::None) => {
                match self.projection {
                    ProjectionKind::BlockPower { .. } => "frugal".to_string(),
                    _ => format!("frugal+{proj}"),
                }
            }
            (UpdateRuleKind::SubspaceAdamW, ResidualKind::FiraScale, RotationKind::None) => {
                match self.projection {
                    ProjectionKind::Dct { .. } | ProjectionKind::Svd => format!("fira+{proj}"),
                    _ => "fira".to_string(),
                }
            }
            (
                UpdateRuleKind::SubspaceAdamW,
                ResidualKind::ErrorFeedback(EfMode::F32),
                RotationKind::Dense,
            ) if matches!(self.projection, ProjectionKind::BlockPower { .. }) => {
                "ldadamw".to_string()
            }
            (
                UpdateRuleKind::SubspaceAdamW,
                ResidualKind::ErrorFeedback(_),
                RotationKind::FixedBasis,
            ) if matches!(self.projection, ProjectionKind::Dct { .. }) => {
                "dct-adamw".to_string()
            }
            (UpdateRuleKind::SubspaceAdamW, ResidualKind::Discard, RotationKind::None) => {
                match self.projection {
                    ProjectionKind::Svd => "galore".to_string(),
                    ProjectionKind::Dct { .. } => "galore+dct".to_string(),
                    _ => format!("galore+{proj}"),
                }
            }
            _ => return None,
        })
    }

    /// Human-readable policy composition (the `info` command's view of a
    /// preset): every axis spelled out.
    pub fn describe(&self) -> String {
        let rot = match self.rotation {
            RotationKind::None => "none",
            RotationKind::FixedBasis => "fixed-basis",
            RotationKind::Dense => "dense",
        };
        let resid = match self.residual {
            ResidualKind::Discard => "discard".to_string(),
            ResidualKind::ErrorFeedback(EfMode::None) => "ef(none)".to_string(),
            ResidualKind::ErrorFeedback(EfMode::F32) => "ef(f32)".to_string(),
            ResidualKind::ErrorFeedback(EfMode::Q8) => "ef(q8)".to_string(),
            ResidualKind::FiraScale => "fira-scale".to_string(),
            ResidualKind::SignDescent => "sign-sgd".to_string(),
        };
        let rule = match self.rule {
            UpdateRuleKind::SubspaceAdamW => "subspace-adamw",
            UpdateRuleKind::NewtonSchulz => "newton-schulz",
        };
        format!(
            "source={} T_u={} rotation={} residual={} rule={} state={}",
            self.projection.name(),
            self.update_interval,
            rot,
            resid,
            rule,
            self.state_dtype.name()
        )
    }

    /// Synthesized name for off-grid compositions, e.g.
    /// `engine(svd+adamw+ef-q8,T200)` — non-f32 state dtypes appear as a
    /// trailing `,m:bf16` / `,m:q8` segment.
    fn composed_name(&self) -> String {
        let rule = match self.rule {
            UpdateRuleKind::SubspaceAdamW => "adamw",
            UpdateRuleKind::NewtonSchulz => "ns",
        };
        let resid = match self.residual {
            ResidualKind::Discard => "discard",
            ResidualKind::ErrorFeedback(EfMode::None) => "ef-none",
            ResidualKind::ErrorFeedback(EfMode::F32) => "ef-f32",
            ResidualKind::ErrorFeedback(EfMode::Q8) => "ef-q8",
            ResidualKind::FiraScale => "fira",
            ResidualKind::SignDescent => "sign",
        };
        let rot = match self.rotation {
            RotationKind::None => "",
            RotationKind::FixedBasis => "+rot-fixed",
            RotationKind::Dense => "+rot-dense",
        };
        let state = match self.state_dtype {
            StateDtype::F32 => String::new(),
            d => format!(",m:{}", d.name()),
        };
        format!(
            "engine({}+{}+{}{},T{}{})",
            self.projection.name(),
            rule,
            resid,
            rot,
            self.update_interval,
            state
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_legacy_names() {
        assert_eq!(OptimizerSpec::dct_adamw(8).resolve_name(), "dct-adamw");
        assert_eq!(OptimizerSpec::trion(8).resolve_name(), "trion");
        assert_eq!(OptimizerSpec::galore(8).resolve_name(), "galore");
        assert_eq!(OptimizerSpec::ldadamw(8).resolve_name(), "ldadamw");
        assert_eq!(OptimizerSpec::fira(8).resolve_name(), "fira+dct");
        assert_eq!(OptimizerSpec::frugal(8).resolve_name(), "frugal+dct");
        assert_eq!(
            OptimizerSpec::galore(8)
                .projection(ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true })
                .resolve_name(),
            "galore+dct"
        );
        assert_eq!(
            OptimizerSpec::frugal(8).projection(ProjectionKind::RandPerm).resolve_name(),
            "frugal+randperm"
        );
    }

    #[test]
    fn state_dtype_is_part_of_the_name() {
        // f32 is the bit-exact default and invisible in names
        assert_eq!(OptimizerSpec::trion(8).state_dtype, StateDtype::F32);
        assert_eq!(
            OptimizerSpec::trion(8).state_dtype(StateDtype::Bf16).resolve_name(),
            "trion+m:bf16"
        );
        assert_eq!(
            OptimizerSpec::dct_adamw(8).state_dtype(StateDtype::Q8).resolve_name(),
            "dct-adamw+m:q8"
        );
        // off-grid compositions fold it inside the parens
        let s = OptimizerSpec::galore(8)
            .update_interval(200)
            .state_dtype(StateDtype::Bf16)
            .projection(ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true })
            .residual(ResidualKind::ErrorFeedback(EfMode::Q8));
        assert_eq!(s.resolve_name(), "engine(dct+adamw+ef-q8,T200,m:bf16)");
        assert!(s.describe().contains("state=bf16"));
        // cfg threading: from_kind picks the config's dtype up
        let cfg = OptimizerConfig { state_dtype: StateDtype::Bf16, ..Default::default() };
        let t = OptimizerSpec::from_kind(&OptimizerKind::Trion, &cfg).unwrap();
        assert_eq!(t.state_dtype, StateDtype::Bf16);
        assert_eq!(t.resolve_name(), "trion+m:bf16");
    }

    #[test]
    fn novel_combo_gets_composed_name() {
        let s = OptimizerSpec::galore(8)
            .projection(ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true })
            .residual(ResidualKind::ErrorFeedback(EfMode::Q8))
            .update_interval(200);
        assert_eq!(s.resolve_name(), "engine(dct+adamw+ef-q8,T200)");
        assert_eq!(s.clone().named("my-opt").resolve_name(), "my-opt");
    }

    #[test]
    fn from_kind_mirrors_legacy_constructor_quirks() {
        let mut cfg = OptimizerConfig { rank: 16, update_interval: 7, ..Default::default() };
        cfg.projection = ProjectionKind::Svd;
        // GaLore pins SVD whatever the config says, and takes the cadence
        let g = OptimizerSpec::from_kind(&OptimizerKind::GaLore, &cfg).unwrap();
        assert_eq!(g.projection, ProjectionKind::Svd);
        assert_eq!(g.update_interval, 7);
        // Trion/LDAdamW always refresh every step
        let t = OptimizerSpec::from_kind(&OptimizerKind::Trion, &cfg).unwrap();
        assert_eq!(t.update_interval, 1);
        assert_eq!(t.dense_weight_decay, Some(0.0));
        let l = OptimizerSpec::from_kind(&OptimizerKind::LdAdamW, &cfg).unwrap();
        assert_eq!(l.update_interval, 1);
        assert_eq!(l.residual, ResidualKind::ErrorFeedback(EfMode::F32));
        // Trion falls back to the default DCT when the config projection
        // is non-DCT
        assert!(matches!(t.projection, ProjectionKind::Dct { .. }));
        // dense kinds are not engine presets
        assert!(OptimizerSpec::from_kind(&OptimizerKind::AdamW, &cfg).is_none());
        assert!(OptimizerSpec::from_kind(&OptimizerKind::Dion, &cfg).is_none());
    }
}
