//! [`UpdateRule`] — the inner optimizer applied once the subspace policies
//! are fixed. Two families cover the paper:
//!
//! * [`SubspaceAdamW`] — AdamW on the projected gradient (GaLore / LDAdam /
//!   DCT-AdamW / FIRA / FRUGAL). Owns the `R×r` moments and drives the
//!   shared step skeleton: orient → EF replay → project (+rotate on
//!   refresh) → EF capture → fused Adam moments → residual-aware
//!   back-projection → decoupled-decay parameter write.
//! * [`NewtonSchulzMomentum`] — Trion/Dion-style orthogonalized momentum
//!   (Algorithm 1). Owns the full `R×C` momentum; the residual handling is
//!   inherent (`M ← B − (1−μ)·b·Qᵀ` keeps everything the subspace missed),
//!   so it composes with a source and cadence but not with the
//!   rotation/residual policies.
//!
//! The rule is the per-layer step driver: `step_layer` receives the layer's
//! source, rotation and residual policies plus the shared [`StepCtx`] and
//! must stay allocation-free at steady state (every temporary from `ws`).
//!
//! Persistent rule state lives in typed [`StateStore`]s (the
//! `state-dtype` axis): each step checks the moments out as f32, computes,
//! and commits back. F32 stores hand their buffer out by move — zero cost,
//! bit-identical to the pre-store code — while bf16/Q8 stores stage through
//! pooled scratch, which is where the paper's optimizer-memory savings
//! come from.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::Result;

use crate::linalg::newton_schulz_into;
use crate::optim::common::{
    adam_moments_into, shape_factor, take_oriented_owned, AdamScalars, LayerMeta,
    MemoryReport, OrientedGrad,
};
use crate::tensor::{Matrix, StateDtype, StateStore, Workspace};
use crate::util::codec::ByteReader;

use super::residual::ResidualPolicy;
use super::rotation::RotationPolicy;
use super::source::SubspaceSource;

/// Per-step shared scalars, one instance per `Optimizer::step` call.
#[derive(Clone, Copy)]
pub struct Hyper {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

pub struct StepCtx<'a> {
    pub t: u64,
    pub lr: f32,
    pub hyper: Hyper,
    /// Figure-1 instrumentation sink (Newton–Schulz rule only): per-layer
    /// `‖B_t − O_t‖` keyed by layer name. Values are per-layer
    /// deterministic and `BTreeMap` orders by key, so the instrumented
    /// output is identical for any thread count.
    pub errors: Option<&'a Mutex<BTreeMap<String, f64>>>,
    /// Per-chunk obs sink: span timing around the rule's phases, recorded
    /// into this chunk's event ring. [`crate::obs::ObsLane::none`] when
    /// tracing is off or the step isn't sampled — `span` then just runs the
    /// closure (one branch of bookkeeping). Timing is side-channel only and
    /// never feeds back into the update math.
    pub obs: crate::obs::ObsLane<'a>,
}

/// Where a rule's projected gradient comes from. The interpreted engine
/// always computes it inline; the fused step plans (`engine/plan.rs`)
/// precompute the expensive pass for a whole shape group in one batched
/// kernel dispatch and hand each layer its slice:
///
/// * [`ProjIn::Inline`] — the rule runs the source itself (the oracle
///   path; byte-for-byte the pre-plan behavior).
/// * [`ProjIn::Sims`] — refresh steps: the full similarity row block
///   `S = G·Q` (R×C) was computed by a group-batched DCT/matmul pass; the
///   source only runs its selection tail ([`SubspaceSource::refresh_from_sims`]).
/// * [`ProjIn::Low`] — non-refresh steps: the projected gradient
///   `g_low = G·Q_r` (R×r) was computed by a group-batched matmul against
///   the layer's cached dense basis; the rule consumes it directly.
///
/// Bit-identity across the three is the fused-plan contract: the batched
/// kernels partition work by rows, which never regroups any element's FP
/// summation (`tests/step_plan_equivalence.rs`).
#[derive(Clone, Copy)]
pub enum ProjIn<'a> {
    Inline,
    Sims(&'a Matrix),
    Low(&'a Matrix),
}

pub trait UpdateRule: Send {
    /// One low-rank layer step: update `param` in place from `grad`.
    #[allow(clippy::too_many_arguments)]
    fn step_layer(
        &mut self,
        meta: &LayerMeta,
        source: &mut SubspaceSource,
        rotation: &mut dyn RotationPolicy,
        residual: &mut dyn ResidualPolicy,
        param: &mut Matrix,
        grad: &Matrix,
        ctx: &StepCtx,
        ws: &mut Workspace,
    );

    /// Persistent per-layer rule state ("adam_m_low"/"adam_v_low" or
    /// "momentum" memory-report families).
    fn memory(&self, rep: &mut MemoryReport);

    /// The full-rank momentum, materialized to f32 (Newton–Schulz rule) —
    /// test hook.
    fn momentum(&self) -> Option<Matrix> {
        None
    }

    /// Checkpoint-v2 serialization of the rule's stores (bit-exact).
    fn save_state(&self, out: &mut Vec<u8>);

    /// Twin of [`UpdateRule::save_state`].
    fn load_state(&mut self, r: &mut ByteReader) -> Result<()>;
}

/// AdamW on the projected gradient; the skeleton every AdamW-family preset
/// shares, with the rotation/residual hooks at the exact points the legacy
/// loops touched them (pinned by `tests/engine_equivalence.rs`).
pub struct SubspaceAdamW {
    m: StateStore, // R×r
    v: StateStore, // R×r
}

impl SubspaceAdamW {
    pub fn new(dtype: StateDtype, rows: usize, rank: usize) -> Self {
        SubspaceAdamW {
            m: StateStore::zeros(dtype, rows, rank),
            v: StateStore::zeros(dtype, rows, rank),
        }
    }

    /// The step skeleton, parameterized over where the projected gradient
    /// comes from ([`ProjIn`]). `ProjIn::Inline` is byte-for-byte the
    /// pre-plan `core`; the other arms skip exactly the pass a fused plan
    /// already ran, leaving every remaining op (and its workspace take/give
    /// sequence) in the historical order.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn core_with(
        &mut self,
        meta: &LayerMeta,
        source: &mut SubspaceSource,
        rotation: &mut dyn RotationPolicy,
        residual: &mut dyn ResidualPolicy,
        param: &mut Matrix,
        g: &Matrix,
        proj: ProjIn<'_>,
        ctx: &StepCtx,
        ws: &mut Workspace,
    ) {
        let (rr, cc) = meta.oriented();
        let r = source.rank();
        // moments out of their typed stores: f32 by move (no copy), lower
        // precisions dequantized into pooled scratch
        let mut m = self.m.checkout(ws);
        let mut v = self.v.checkout(ws);
        let mut owned_low: Option<Matrix> = None;
        let g_low: &Matrix = match proj {
            ProjIn::Low(low) => low,
            _ => {
                let mut gl = ws.take_uninit(rr, r);
                if source.refresh_due(ctx.t) {
                    ctx.obs.span("refresh", || {
                        rotation.before_refresh(source);
                        match proj {
                            ProjIn::Sims(s) => {
                                source.refresh_from_sims(g, s, &mut gl, ws)
                            }
                            _ => source.refresh_and_project_into(g, &mut gl, ws),
                        }
                    });
                    ctx.obs.span("rotate", || {
                        rotation.rotate_moments(source, &mut m, &mut v, ws)
                    });
                } else {
                    ctx.obs.span("project", || source.project_into(g, &mut gl, ws));
                }
                owned_low = Some(gl);
                owned_low.as_ref().unwrap()
            }
        };
        // residual capture happens before the moments move, as in the
        // legacy EF loops; `full` doubles as the back-projection buffer
        let mut full = ws.take_uninit(rr, cc);
        ctx.obs.span("residual", || {
            residual.store_residual(source, g_low, g, &mut full, ws)
        });
        // AdamW in the subspace — the shared fused kernel
        let sc = AdamScalars::new(ctx.hyper.beta1, ctx.hyper.beta2, ctx.hyper.eps, ctx.t);
        let mut u_low = ws.take_uninit(rr, r);
        ctx.obs.span("rule", || {
            adam_moments_into(&mut u_low.data, &g_low.data, &mut m.data, &mut v.data, &sc)
        });
        // U = u·Qᵀ (+ the policy's residual term), applied in the original
        // orientation without materializing a transpose
        ctx.obs.span("update", || {
            residual.finish_update(source, g, g_low, &u_low, &mut full, ws);
            param.scale(1.0 - ctx.lr * ctx.hyper.weight_decay);
            if meta.needs_transpose() {
                param.axpy_t(-ctx.lr, &full);
            } else {
                param.axpy(-ctx.lr, &full);
            }
        });
        ws.give(u_low);
        ws.give(full);
        if let Some(gl) = owned_low {
            ws.give(gl);
        }
        self.v.commit(v, ws);
        self.m.commit(m, ws);
    }
}

impl UpdateRule for SubspaceAdamW {
    fn step_layer(
        &mut self,
        meta: &LayerMeta,
        source: &mut SubspaceSource,
        rotation: &mut dyn RotationPolicy,
        residual: &mut dyn ResidualPolicy,
        param: &mut Matrix,
        grad: &Matrix,
        ctx: &StepCtx,
        ws: &mut Workspace,
    ) {
        if residual.wants_owned_grad() {
            // oriented gradient, owned: error feedback mutates it
            let mut g = take_oriented_owned(meta, grad, ws);
            residual.add_into_grad(&mut g);
            self.core_with(
                meta, source, rotation, residual, param, &g, ProjIn::Inline, ctx, ws,
            );
            ws.give(g);
        } else {
            // borrow in place unless transposed
            let og = OrientedGrad::take(meta, grad, ws);
            self.core_with(
                meta,
                source,
                rotation,
                residual,
                param,
                og.matrix(),
                ProjIn::Inline,
                ctx,
                ws,
            );
            og.give(ws);
        }
    }

    fn memory(&self, rep: &mut MemoryReport) {
        rep.add("adam_m_low", self.m.bytes());
        rep.add("adam_v_low", self.v.bytes());
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        self.m.save(out);
        self.v.save(out);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        self.m.load_from(r)?;
        self.v.load_from(r)
    }
}

/// Trion's orthogonalized-momentum rule (Algorithm 1): accumulate the
/// gradient into the full momentum, extract the subspace factor, feed the
/// extraction error back (`M ← B − (1−μ)·b·Qᵀ`), Newton–Schulz the
/// low-rank factor and apply `−η·max(1,√(R/C))·o·Qᵀ`.
pub struct NewtonSchulzMomentum {
    momentum: StateStore, // R×C (oriented)
    mu: f32,
    ns_steps: usize,
}

impl NewtonSchulzMomentum {
    pub fn new(dtype: StateDtype, rows: usize, cols: usize, mu: f32, ns_steps: usize) -> Self {
        NewtonSchulzMomentum { momentum: StateStore::zeros(dtype, rows, cols), mu, ns_steps }
    }

    /// First half of the step: check the momentum out of its typed store
    /// and accumulate the gradient (`B = M + G`, transposing on the fly for
    /// wide layers). Fused plans run this in their stage-A dispatch, hold
    /// the returned matrix across the group's batched projection pass, and
    /// hand it back to [`NewtonSchulzMomentum::finish_from`] under the same
    /// chunk↔shard binding, so the store's checkout/commit scratch replays
    /// on one shard exactly as the inline path does.
    pub(crate) fn begin_accumulate(
        &mut self,
        meta: &LayerMeta,
        grad: &Matrix,
        ws: &mut Workspace,
    ) -> Matrix {
        // momentum out of its typed store for the whole step (f32 by move)
        let mut momentum = self.momentum.checkout(ws);
        // B = M + G — accumulate the gradient straight into the momentum,
        // transposing on the fly for wide layers
        if meta.needs_transpose() {
            momentum.axpy_t(1.0, grad);
        } else {
            momentum.axpy(1.0, grad);
        }
        momentum
    }

    /// Second half: subspace extraction (unless a fused plan already ran
    /// it — [`ProjIn`]), inherent error feedback, Newton–Schulz and the
    /// parameter write, then the momentum commit.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn finish_from(
        &mut self,
        meta: &LayerMeta,
        source: &mut SubspaceSource,
        param: &mut Matrix,
        mut momentum: Matrix,
        proj: ProjIn<'_>,
        ctx: &StepCtx,
        ws: &mut Workspace,
    ) {
        let (rr, cc) = meta.oriented();
        let r = source.rank();
        // S = DCT(B); select top-r; b = S[:, i_t] (one pass). A cadence > 1
        // (a non-Trion grid point) reuses the held subspace between
        // refreshes.
        let mut owned_low: Option<Matrix> = None;
        let b_low: &Matrix = match proj {
            ProjIn::Low(low) => low,
            _ => {
                let mut bl = ws.take_uninit(rr, r);
                if source.refresh_due(ctx.t) {
                    ctx.obs.span("refresh", || match proj {
                        ProjIn::Sims(s) => {
                            source.refresh_from_sims(&momentum, s, &mut bl, ws)
                        }
                        _ => source.refresh_and_project_into(&momentum, &mut bl, ws),
                    });
                } else {
                    ctx.obs
                        .span("project", || source.project_into(&momentum, &mut bl, ws));
                }
                owned_low = Some(bl);
                owned_low.as_ref().unwrap()
            }
        };
        // error feedback: M = B − (1−μ)·b·Qᵀ
        let mut back = ws.take_uninit(rr, cc);
        source.back_into(b_low, &mut back, ws);
        momentum.axpy(-(1.0 - self.mu), &back);
        // Newton–Schulz on the LOW-RANK momentum (R×r), workspace-backed so
        // the whole step stays allocation-free (tests/alloc_steady_state.rs)
        let mut o_low = ws.take_uninit(rr, r);
        ctx.obs
            .span("ns", || newton_schulz_into(b_low, self.ns_steps, &mut o_low, ws));
        ctx.obs.span("update", || {
            if let Some(errors) = ctx.errors {
                // restore B while `back` still holds back(b_low), then
                // repurpose `back` for O — computed only once
                let mut b_now = ws.take_uninit(rr, cc);
                b_now.copy_from(&momentum);
                b_now.axpy(1.0 - self.mu, &back);
                source.back_into(&o_low, &mut back, ws); // back = O
                b_now.axpy(-1.0, &back);
                errors.lock().unwrap().insert(meta.name.clone(), b_now.fro_norm());
                ws.give(b_now);
            } else {
                // O = o·Qᵀ, applied without materializing the transpose
                source.back_into(&o_low, &mut back, ws);
            }
            param.scale(1.0 - ctx.lr * ctx.hyper.weight_decay);
            let scale = -ctx.lr * shape_factor(rr, cc);
            if meta.needs_transpose() {
                param.axpy_t(scale, &back);
            } else {
                param.axpy(scale, &back);
            }
        });
        ws.give(o_low);
        ws.give(back);
        if let Some(bl) = owned_low {
            ws.give(bl);
        }
        self.momentum.commit(momentum, ws);
    }
}

impl UpdateRule for NewtonSchulzMomentum {
    fn step_layer(
        &mut self,
        meta: &LayerMeta,
        source: &mut SubspaceSource,
        _rotation: &mut dyn RotationPolicy,
        _residual: &mut dyn ResidualPolicy,
        param: &mut Matrix,
        grad: &Matrix,
        ctx: &StepCtx,
        ws: &mut Workspace,
    ) {
        let momentum = self.begin_accumulate(meta, grad, ws);
        self.finish_from(meta, source, param, momentum, ProjIn::Inline, ctx, ws);
    }

    fn memory(&self, rep: &mut MemoryReport) {
        rep.add("momentum", self.momentum.bytes());
    }

    fn momentum(&self) -> Option<Matrix> {
        Some(self.momentum.to_matrix())
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        self.momentum.save(out);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        self.momentum.load_from(r)
    }
}

/// The closed update-rule set as a monomorphized enum — the fused step
/// plans dispatch on the variant directly (one predictable branch instead
/// of a `Box<dyn>` virtual hop per layer per step). Delegates
/// [`UpdateRule`] verbatim, so the interpreted oracle path drives the same
/// code through the same representation.
pub enum Rule {
    Adam(SubspaceAdamW),
    Ns(NewtonSchulzMomentum),
}

impl UpdateRule for Rule {
    fn step_layer(
        &mut self,
        meta: &LayerMeta,
        source: &mut SubspaceSource,
        rotation: &mut dyn RotationPolicy,
        residual: &mut dyn ResidualPolicy,
        param: &mut Matrix,
        grad: &Matrix,
        ctx: &StepCtx,
        ws: &mut Workspace,
    ) {
        match self {
            Rule::Adam(r) => {
                r.step_layer(meta, source, rotation, residual, param, grad, ctx, ws)
            }
            Rule::Ns(r) => {
                r.step_layer(meta, source, rotation, residual, param, grad, ctx, ws)
            }
        }
    }

    fn memory(&self, rep: &mut MemoryReport) {
        match self {
            Rule::Adam(r) => r.memory(rep),
            Rule::Ns(r) => r.memory(rep),
        }
    }

    fn momentum(&self) -> Option<Matrix> {
        match self {
            Rule::Adam(r) => UpdateRule::momentum(r),
            Rule::Ns(r) => UpdateRule::momentum(r),
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        match self {
            Rule::Adam(r) => r.save_state(out),
            Rule::Ns(r) => r.save_state(out),
        }
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        match self {
            Rule::Adam(x) => x.load_state(r),
            Rule::Ns(x) => x.load_state(r),
        }
    }
}
