//! Engine preset behavior tests — ported from the six deleted hand-written
//! optimizer files (`dct_adamw.rs`, `trion.rs`, `galore.rs`, `fira.rs`,
//! `frugal.rs`, `ldadamw.rs`), now exercising the same semantics through
//! `OptimizerSpec` presets. Bit-exact equivalence with the legacy step
//! loops is pinned separately in `tests/engine_equivalence.rs`.

use super::*;
use crate::optim::common::{EfMode, Optimizer, OptimizerConfig, ParamKind};
use crate::optim::{AdamW, Dion};
use crate::projection::{ProjectionKind, RankNorm};
use crate::tensor::{matmul, matmul_a_bt, Matrix};
use crate::util::Pcg64;

fn dct() -> ProjectionKind {
    ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true }
}

fn quad_err(spec: OptimizerSpec, steps: usize, lr: f32) -> f64 {
    let mut rng = Pcg64::seed(0);
    let t = Matrix::randn(10, 8, 0.5, &mut rng);
    let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
    let mut opt = spec.build(&metas);
    let mut params = vec![Matrix::zeros(10, 8)];
    for _ in 0..steps {
        let g = params[0].sub(&t).scaled(2.0);
        opt.step(&mut params, &[g], lr);
    }
    params[0].sub(&t).fro_norm() / t.fro_norm()
}

// -- DCT-AdamW preset ----------------------------------------------------

#[test]
fn dct_adamw_converges_on_quadratic() {
    let err = quad_err(OptimizerSpec::dct_adamw(4).weight_decay(0.0), 500, 0.05);
    assert!(err < 0.15, "rel err={err}");
}

#[test]
fn dct_adamw_memory_far_below_ldadamw() {
    let metas: Vec<LayerMeta> = (0..8)
        .map(|i| LayerMeta::new(&format!("w{i}"), 128, 128, ParamKind::Linear))
        .collect();
    let dct_rep = OptimizerSpec::dct_adamw(64).build(&metas).memory_report();
    let ld = OptimizerSpec::ldadamw(64).build(&metas).memory_report();
    assert!(dct_rep.total() < ld.total(), "dct={} ld={}", dct_rep.total(), ld.total());
    // index state is exactly 2·r·4 bytes per layer
    assert_eq!(
        dct_rep.per_layer["indices"] + dct_rep.per_layer["indices_prev"],
        8 * 2 * 64 * 4
    );
}

#[test]
fn dct_adamw_t_u_respected_like_galore() {
    let metas = vec![LayerMeta::new("w", 12, 10, ParamKind::Linear)];
    let mut opt =
        OptimizerSpec::dct_adamw(3).weight_decay(0.0).update_interval(4).build(&metas);
    let mut rng = Pcg64::seed(2);
    let mut params = vec![Matrix::zeros(12, 10)];
    let mut all_idx = Vec::new();
    for _ in 0..5 {
        let g = Matrix::randn(12, 10, 1.0, &mut rng);
        opt.step(&mut params, &[g], 0.01);
        all_idx.push(opt.indices(0).unwrap().to_vec());
    }
    // t=1 refreshes; t=2,3 reuse the same indices
    assert_eq!(all_idx[0], all_idx[1]);
    assert_eq!(all_idx[1], all_idx[2]);
    // t=4 refreshed: the rotation snapshot must hold the pre-refresh indices
    assert_eq!(opt.snapshot_indices(0).unwrap(), &all_idx[2][..]);
}

#[test]
fn dct_adamw_wide_layer_transposed_update_matches_tall_layout() {
    // A wide layer (orient → transpose) must produce the transpose of the
    // update its tall twin produces from the transposed gradient.
    let mut rng = Pcg64::seed(8);
    let g = Matrix::randn(6, 15, 1.0, &mut rng); // wide 6×15 → oriented 15×6
    let metas_wide = vec![LayerMeta::new("w", 6, 15, ParamKind::Linear)];
    let metas_tall = vec![LayerMeta::new("w", 15, 6, ParamKind::Linear)];
    let mut wide = OptimizerSpec::dct_adamw(3).weight_decay(0.0).build(&metas_wide);
    let mut tall = OptimizerSpec::dct_adamw(3).weight_decay(0.0).build(&metas_tall);
    let mut pw = vec![Matrix::zeros(6, 15)];
    let mut pt = vec![Matrix::zeros(15, 6)];
    for _ in 0..3 {
        wide.step(&mut pw, &[g.clone()], 0.01);
        tall.step(&mut pt, &[g.transpose()], 0.01);
    }
    assert!(pw[0].max_abs_diff(&pt[0].transpose()) < 1e-6);
}

#[test]
fn dct_adamw_ef_q8_tracks_out_of_subspace_gradient() {
    let metas = vec![LayerMeta::new("w", 8, 8, ParamKind::Linear)];
    let mut opt = OptimizerSpec::dct_adamw(1)
        .weight_decay(0.0)
        .residual(ResidualKind::ErrorFeedback(EfMode::Q8))
        .build(&metas);
    let mut rng = Pcg64::seed(3);
    let g0 = Matrix::randn(8, 8, 1.0, &mut rng);
    let mut params = vec![Matrix::zeros(8, 8)];
    for _ in 0..60 {
        opt.step(&mut params, &[g0.clone()], 0.01);
    }
    let mut agree = 0;
    for k in 0..64 {
        if params[0].data[k] * g0.data[k] < 0.0 {
            agree += 1;
        }
    }
    assert!(agree > 45, "agree={agree}/64");
}

#[test]
fn dct_adamw_no_ef_mode_allocates_nothing() {
    let metas = vec![LayerMeta::new("w", 16, 16, ParamKind::Linear)];
    let rep = OptimizerSpec::dct_adamw(4)
        .residual(ResidualKind::ErrorFeedback(EfMode::None))
        .build(&metas)
        .memory_report();
    assert_eq!(rep.per_layer["ef"], 0);
}

// -- Trion preset --------------------------------------------------------

#[test]
fn trion_converges_on_quadratic() {
    let err = quad_err(OptimizerSpec::trion(4).mu(0.9).weight_decay(0.0), 500, 0.02);
    assert!(err < 0.35, "rel err={err}");
}

#[test]
fn trion_memory_beats_dion() {
    // Same model: Trion stores r ints/layer + one shared DCT; Dion stores a
    // C×r f32 projector per layer. For enough layers Trion wins.
    let metas: Vec<LayerMeta> = (0..12)
        .map(|i| LayerMeta::new(&format!("w{i}"), 128, 128, ParamKind::Linear))
        .collect();
    let trion = OptimizerSpec::trion(64).mu(0.9).weight_decay(0.0).build(&metas);
    let trion_rep = trion.memory_report();
    let cfg = OptimizerConfig { rank: 64, weight_decay: 0.0, mu: 0.9, ..Default::default() };
    let dion = Dion::new(&metas, &cfg).memory_report();
    assert!(
        trion_rep.total() < dion.total(),
        "trion={} dion={}",
        trion_rep.total(),
        dion.total()
    );
    // and the per-layer index cost is exactly r·4 bytes
    assert_eq!(trion_rep.per_layer["indices"], 12 * 64 * 4);
}

#[test]
fn trion_broadcast_is_low_rank() {
    let metas = vec![LayerMeta::new("w", 128, 64, ParamKind::Linear)];
    let opt = OptimizerSpec::trion(8).build(&metas);
    let full = (128 * 64 * 4) as u64;
    let low = opt.broadcast_bytes(&metas[0]);
    assert!(low < full / 4, "low={low} full={full}");
}

#[test]
fn trion_with_dense_source_broadcasts_full() {
    // Rebinding the Trion rule to a dense basis is a legal grid point, but
    // the indices-only ZeRO payload no longer exists — the engine must
    // fall back to full-update broadcast accounting.
    let metas = vec![LayerMeta::new("w", 128, 64, ParamKind::Linear)];
    let opt = OptimizerSpec::trion(8).projection(ProjectionKind::Svd).build(&metas);
    assert_eq!(opt.broadcast_bytes(&metas[0]), 128 * 64 * 4);
}

#[test]
fn trion_update_lies_in_selected_subspace() {
    let mut rng = Pcg64::seed(3);
    let metas = vec![LayerMeta::new("w", 12, 10, ParamKind::Linear)];
    let mut opt = OptimizerSpec::trion(3).mu(0.9).weight_decay(0.0).build(&metas);
    let mut params = vec![Matrix::zeros(12, 10)];
    let g = Matrix::randn(12, 10, 1.0, &mut rng);
    opt.step(&mut params, &[g], 1.0);
    // params = -sf·O where O = o·Q_rᵀ: projecting onto Q_r is lossless
    let q = opt.basis(0).expect("low-rank layer");
    let o = params[0].scaled(-1.0);
    let low = matmul(&o, &q);
    let back = matmul_a_bt(&low, &q);
    assert!(o.max_abs_diff(&back) < 1e-4);
}

#[test]
fn trion_mu_one_keeps_full_momentum() {
    let metas = vec![LayerMeta::new("w", 8, 6, ParamKind::Linear)];
    let mut opt = OptimizerSpec::trion(2).mu(1.0).weight_decay(0.0).build(&metas);
    let mut rng = Pcg64::seed(4);
    let mut params = vec![Matrix::zeros(8, 6)];
    let g = Matrix::randn(8, 6, 1.0, &mut rng);
    opt.step(&mut params, &[g.clone()], 0.01);
    let momentum = opt.momentum(0).expect("NS rule keeps a momentum buffer");
    assert!(momentum.max_abs_diff(&g) < 1e-5);
}

#[test]
fn trion_projection_error_below_dion_on_dct_friendly_signal() {
    // Construct gradients with smooth (low-frequency) row structure — the
    // regime where DCT selection captures more energy than one
    // power-iteration step. Mirrors the Figure-1 experiment.
    let metas = vec![LayerMeta::new("w", 32, 24, ParamKind::Linear)];
    let mut trion = OptimizerSpec::trion(4)
        .mu(0.9)
        .weight_decay(0.0)
        .instrument(true)
        .build(&metas);
    let cfg = OptimizerConfig {
        rank: 4,
        mu: 0.9,
        weight_decay: 0.0,
        instrument: true,
        ..Default::default()
    };
    let mut dion = Dion::new(&metas, &cfg);
    let mut pt = vec![Matrix::zeros(32, 24)];
    let mut pd = vec![Matrix::zeros(32, 24)];
    let mut rng = Pcg64::seed(5);
    let mut last = (0.0, 0.0);
    for step in 0..30 {
        let phase = step as f32 * 0.1;
        let g = Matrix::from_fn(32, 24, |i, j| {
            ((j as f32 * 0.3 + phase).sin() + 0.05 * rng.normal_f32())
                * (1.0 + i as f32 / 32.0)
        });
        trion.step(&mut pt, &[g.clone()], 0.01);
        dion.step(&mut pd, &[g], 0.01);
        last = (
            trion.projection_errors().unwrap()["w"],
            dion.projection_errors().unwrap()["w"],
        );
    }
    assert!(last.0 <= last.1 * 1.2, "trion={} dion={}", last.0, last.1);
}

// -- GaLore preset -------------------------------------------------------

#[test]
fn galore_converges_on_quadratic() {
    let err =
        quad_err(OptimizerSpec::galore(4).weight_decay(0.0).update_interval(10), 600, 0.05);
    assert!(err < 0.4, "rel err={err}");
}

#[test]
fn galore_low_rank_state_is_smaller_than_adamw() {
    let metas = vec![LayerMeta::new("w", 100, 100, ParamKind::Linear)];
    let galore = OptimizerSpec::galore(10).build(&metas).memory_report().total();
    let cfg = OptimizerConfig { rank: 10, ..Default::default() };
    let adam = AdamW::new(&metas, &cfg).memory_report().total();
    assert!(galore < adam / 2, "galore={galore} adam={adam}");
}

#[test]
fn galore_dct_variant_has_smaller_projector_state() {
    let metas = vec![
        LayerMeta::new("a", 64, 64, ParamKind::Linear),
        LayerMeta::new("b", 64, 64, ParamKind::Linear),
    ];
    let svd = OptimizerSpec::galore(16).build(&metas).memory_report();
    let dct_rep = OptimizerSpec::galore(16).projection(dct()).build(&metas).memory_report();
    // index-selection state (r int32) vs dense projector (C×r floats)
    assert!(dct_rep.per_layer["indices"] < svd.per_layer["projector"]);
}

#[test]
fn galore_subspace_refresh_interval_respected() {
    // With interval 5, the basis must be identical between refreshes.
    let metas = vec![LayerMeta::new("w", 12, 8, ParamKind::Linear)];
    let mut opt = OptimizerSpec::galore(3).update_interval(5).build(&metas);
    let mut rng = Pcg64::seed(1);
    let mut params = vec![Matrix::zeros(12, 8)];
    let mut bases = Vec::new();
    for _ in 0..6 {
        let g = Matrix::randn(12, 8, 1.0, &mut rng);
        opt.step(&mut params, &[g], 0.01);
        bases.push(opt.basis(0).unwrap());
    }
    // steps 2..4 (after the step-1 refresh) share the same basis
    assert!(bases[1].max_abs_diff(&bases[2]) < 1e-7);
    assert!(bases[2].max_abs_diff(&bases[3]) < 1e-7);
    // step 5 (t=5, 5%5==0) refreshed
    assert!(bases[3].max_abs_diff(&bases[4]) > 1e-6);
}

// -- FIRA preset ---------------------------------------------------------

#[test]
fn fira_converges_on_quadratic_both_projections() {
    for kind in [ProjectionKind::Svd, dct()] {
        let name = kind.name();
        let err = quad_err(
            OptimizerSpec::fira(3).weight_decay(0.0).update_interval(5).projection(kind),
            400,
            0.05,
        );
        assert!(err < 0.15, "{name} err={err}");
    }
}

#[test]
fn fira_residual_scaling_tracks_adam_magnitude() {
    // With g_low large and u_low ≈ bias-corrected-normalized, φ < 1: the
    // residual contribution must be damped relative to raw SGD.
    let metas = vec![LayerMeta::new("w", 8, 8, ParamKind::Linear)];
    let mut opt = OptimizerSpec::fira(2)
        .weight_decay(0.0)
        .projection(ProjectionKind::Svd)
        .build(&metas);
    let mut rng = Pcg64::seed(1);
    let g = Matrix::randn(8, 8, 10.0, &mut rng); // large gradient
    let mut params = vec![Matrix::zeros(8, 8)];
    opt.step(&mut params, &[g.clone()], 1.0);
    // update magnitude is Adam-like (≈1 per coord), not grad-like (≈10)
    assert!(params[0].abs_max() < 3.0, "{}", params[0].abs_max());
}

#[test]
fn fira_full_rank_recovery_better_than_galore() {
    // A rotating gradient direction defeats the frozen low-rank subspace of
    // GaLore; FIRA's scaled residual keeps up.
    let metas = vec![LayerMeta::new("w", 12, 12, ParamKind::Linear)];
    let mut rng = Pcg64::seed(2);
    let t = Matrix::randn(12, 12, 1.0, &mut rng);
    let mut fira = OptimizerSpec::fira(2)
        .weight_decay(0.0)
        .update_interval(50)
        .projection(ProjectionKind::Svd)
        .build(&metas);
    let mut galore =
        OptimizerSpec::galore(2).weight_decay(0.0).update_interval(50).build(&metas);
    let mut pf = vec![Matrix::zeros(12, 12)];
    let mut pg = vec![Matrix::zeros(12, 12)];
    for _ in 0..300 {
        let gf = pf[0].sub(&t).scaled(2.0);
        fira.step(&mut pf, &[gf], 0.05);
        let gg = pg[0].sub(&t).scaled(2.0);
        galore.step(&mut pg, &[gg], 0.05);
    }
    let ef = pf[0].sub(&t).fro_norm();
    let eg = pg[0].sub(&t).fro_norm();
    assert!(ef < eg, "fira={ef} galore={eg}");
}

// -- FRUGAL preset -------------------------------------------------------

#[test]
fn frugal_converges_with_every_projection() {
    for kind in [
        ProjectionKind::Svd,
        dct(),
        ProjectionKind::Random,
        ProjectionKind::RandPerm,
    ] {
        let name = kind.name();
        let err = quad_err(
            OptimizerSpec::frugal(3).weight_decay(0.0).update_interval(5).projection(kind),
            400,
            0.02,
        );
        // the sign branch keeps full-rank progress: all variants converge
        assert!(err < 0.3, "{name} err={err}");
    }
}

#[test]
fn frugal_state_free_branch_moves_out_of_subspace_coords() {
    // rank-1 subspace + constant residual: SignSGD must still move every
    // coordinate from step one (no EF warm-up needed).
    let metas = vec![LayerMeta::new("w", 6, 6, ParamKind::Linear)];
    let mut opt = OptimizerSpec::frugal(1)
        .weight_decay(0.0)
        .projection(ProjectionKind::Svd)
        .build(&metas);
    let mut rng = Pcg64::seed(1);
    let g = Matrix::randn(6, 6, 1.0, &mut rng);
    let mut params = vec![Matrix::zeros(6, 6)];
    opt.step(&mut params, &[g.clone()], 0.1);
    let moved = params[0].data.iter().filter(|v| v.abs() > 1e-6).count();
    assert!(moved > 30, "moved={moved}/36");
}

#[test]
fn frugal_memory_matches_galore_plus_nothing_extra() {
    // FRUGAL's state-free branch is stateless: memory == GaLore's.
    let metas = vec![LayerMeta::new("w", 32, 32, ParamKind::Linear)];
    let f = OptimizerSpec::frugal(8)
        .projection(ProjectionKind::Svd)
        .build(&metas)
        .memory_report()
        .total();
    let g = OptimizerSpec::galore(8).build(&metas).memory_report().total();
    assert_eq!(f, g);
}

// -- LDAdamW preset ------------------------------------------------------

#[test]
fn ldadamw_converges_on_quadratic() {
    let err = quad_err(OptimizerSpec::ldadamw(4).weight_decay(0.0), 500, 0.05);
    // EF lets the low-rank optimizer recover near-full-rank targets
    assert!(err < 0.15, "rel err={err}");
}

#[test]
fn ldadamw_stores_two_projectors_and_full_ef() {
    let metas = vec![LayerMeta::new("w", 16, 12, ParamKind::Linear)];
    let rep = OptimizerSpec::ldadamw(4).build(&metas).memory_report();
    assert_eq!(rep.per_layer["projector"], 12 * 4 * 4);
    assert_eq!(rep.per_layer["projector_prev"], 12 * 4 * 4);
    assert_eq!(rep.per_layer["ef"], 16 * 12 * 4);
}

#[test]
fn ldadamw_error_feedback_recovers_out_of_subspace_signal() {
    // A constant gradient orthogonal to the chosen subspace must still move
    // parameters once EF accumulates.
    let metas = vec![LayerMeta::new("w", 8, 8, ParamKind::Linear)];
    let mut opt = OptimizerSpec::ldadamw(1).weight_decay(0.0).build(&metas);
    let mut rng = Pcg64::seed(1);
    let g0 = Matrix::randn(8, 8, 1.0, &mut rng);
    let mut params = vec![Matrix::zeros(8, 8)];
    for _ in 0..50 {
        opt.step(&mut params, &[g0.clone()], 0.01);
    }
    // all coordinates moved in direction -g0 (sign agreement mostly)
    let mut agree = 0;
    for k in 0..64 {
        if params[0].data[k] * g0.data[k] < 0.0 {
            agree += 1;
        }
    }
    assert!(agree > 48, "agree={agree}/64");
}

// -- engine-level behavior ----------------------------------------------

#[test]
fn dense_fallback_covers_non_eligible_params() {
    let metas = vec![
        LayerMeta::new("embed", 64, 16, ParamKind::Embed),
        LayerMeta::new("w", 16, 16, ParamKind::Linear),
        LayerMeta::new("norm", 1, 16, ParamKind::Norm),
    ];
    let rep = OptimizerSpec::dct_adamw(4).build(&metas).memory_report();
    // embed + norm on the dense path, the linear layer on the low-rank one
    assert_eq!(rep.per_layer["adam_m"], ((64 * 16 + 16) * 4) as u64);
    assert_eq!(rep.per_layer["adam_m_low"], (16 * 4 * 4) as u64);
}

#[test]
fn momentum_presets_skip_dense_weight_decay() {
    // Legacy Trion applied no decoupled decay on the dense fallback; the
    // AdamW-family presets do. A zero-gradient step exposes the difference.
    let metas = vec![LayerMeta::new("embed", 4, 4, ParamKind::Embed)];
    let zero_g = vec![Matrix::zeros(4, 4)];
    let mut trion = OptimizerSpec::trion(2).weight_decay(0.5).build(&metas);
    let mut params = vec![Matrix::eye(4)];
    trion.step(&mut params, &zero_g, 0.1);
    assert_eq!(params[0], Matrix::eye(4), "trion dense path must not decay");
    let mut dct_opt = OptimizerSpec::dct_adamw(2).weight_decay(0.5).build(&metas);
    let mut params = vec![Matrix::eye(4)];
    dct_opt.step(&mut params, &zero_g, 0.1);
    assert!(params[0].fro_norm() < 2.0, "dct-adamw dense path decays");
}

#[test]
fn novel_grid_point_builds_and_converges() {
    // DCT source + GaLore cadence + Q8 error feedback — not one of the six
    // published methods; one builder expression, no new optimizer file.
    let spec = OptimizerSpec::galore(4)
        .projection(dct())
        .residual(ResidualKind::ErrorFeedback(EfMode::Q8))
        .update_interval(50)
        .weight_decay(0.0);
    assert_eq!(spec.resolve_name(), "engine(dct+adamw+ef-q8,T50)");
    let err = quad_err(spec, 500, 0.05);
    // EF recovers the between-refresh residual; expect dct-adamw-like
    // convergence despite the stale subspace
    assert!(err < 0.3, "rel err={err}");
}

#[test]
#[should_panic(expected = "fixed-basis rotation")]
fn fixed_basis_rotation_rejects_dense_sources() {
    let metas = vec![LayerMeta::new("w", 8, 8, ParamKind::Linear)];
    let _ = OptimizerSpec::dct_adamw(2).projection(ProjectionKind::Svd).build(&metas);
}

#[test]
#[should_panic(expected = "Newton")]
fn ns_rule_rejects_residual_policies() {
    let metas = vec![LayerMeta::new("w", 8, 8, ParamKind::Linear)];
    let _ = OptimizerSpec::trion(2)
        .residual(ResidualKind::ErrorFeedback(EfMode::Q8))
        .build(&metas);
}

// -- typed state storage (state-dtype axis) ------------------------------

#[test]
fn state_dtype_shrinks_rule_state_exactly() {
    use crate::tensor::StateDtype;
    let metas = vec![
        LayerMeta::new("w", 64, 32, ParamKind::Linear),
        LayerMeta::new("norm", 1, 32, ParamKind::Norm),
    ];
    // Trion: momentum is the dominant store (R×C per layer)
    let f32_rep = OptimizerSpec::trion(8).build(&metas).memory_report();
    let bf16_rep = OptimizerSpec::trion(8)
        .state_dtype(StateDtype::Bf16)
        .build(&metas)
        .memory_report();
    assert_eq!(f32_rep.per_layer["momentum"], 64 * 32 * 4);
    assert_eq!(bf16_rep.per_layer["momentum"], 64 * 32 * 2);
    // the dense fallback follows the dtype too
    assert_eq!(f32_rep.per_layer["adam_m"], 32 * 4);
    assert_eq!(bf16_rep.per_layer["adam_m"], 32 * 2);
    // q8 moments: 1 byte/elem + one scale per tensor
    let q8_rep = OptimizerSpec::dct_adamw(8)
        .state_dtype(StateDtype::Q8)
        .build(&metas)
        .memory_report();
    assert_eq!(q8_rep.per_layer["adam_m_low"], 64 * 8 + 4);
}

#[test]
fn bf16_low_rank_state_beats_adam_by_the_paper_margin() {
    use crate::tensor::StateDtype;
    // transformer-ish zoo: the acceptance shape of the bench-mem claim —
    // a low-rank preset with bf16 state ≥ 20% below dense Adam f32
    let mut metas = vec![LayerMeta::new("embed", 256, 64, ParamKind::Embed)];
    for l in 0..2 {
        for w in ["wq", "wk", "wv", "wo"] {
            metas.push(LayerMeta::new(&format!("b{l}.{w}"), 64, 64, ParamKind::Linear));
        }
        metas.push(LayerMeta::new(&format!("b{l}.gate"), 64, 176, ParamKind::Linear));
        metas.push(LayerMeta::new(&format!("b{l}.down"), 176, 64, ParamKind::Linear));
        metas.push(LayerMeta::new(&format!("b{l}.norm"), 1, 64, ParamKind::Norm));
    }
    let cfg = OptimizerConfig { rank: 16, ..Default::default() };
    let adam = AdamW::new(&metas, &cfg).memory_report().total();
    let trion_bf16 = OptimizerSpec::trion(16)
        .state_dtype(StateDtype::Bf16)
        .build(&metas)
        .memory_report()
        .total();
    assert!(
        (trion_bf16 as f64) < 0.8 * adam as f64,
        "trion+bf16 {trion_bf16} vs adam {adam}"
    );
    // and strictly below its own f32 variant
    let trion_f32 = OptimizerSpec::trion(16).build(&metas).memory_report().total();
    assert!(trion_bf16 < trion_f32);
}

#[test]
fn non_f32_state_still_converges() {
    use crate::tensor::StateDtype;
    for dtype in [StateDtype::Bf16, StateDtype::Q8] {
        let err = quad_err(
            OptimizerSpec::dct_adamw(4).weight_decay(0.0).state_dtype(dtype),
            500,
            0.05,
        );
        assert!(err < 0.3, "{dtype:?} rel err={err}");
    }
}

// -- engine state serialization ------------------------------------------

#[test]
fn serialize_restore_state_roundtrips_mid_run() {
    use crate::tensor::StateDtype;
    let metas = vec![
        LayerMeta::new("w", 12, 8, ParamKind::Linear),
        LayerMeta::new("norm", 1, 8, ParamKind::Norm),
    ];
    let mut rng = Pcg64::seed(9);
    let grads: Vec<Vec<Matrix>> = (0..6)
        .map(|_| metas.iter().map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng)).collect())
        .collect();
    for dtype in [StateDtype::F32, StateDtype::Bf16] {
        let spec = OptimizerSpec::dct_adamw(3).state_dtype(dtype).threads(Some(1));
        let mut a = spec.clone().build(&metas);
        let mut params_a: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        for g in &grads[..3] {
            a.step(&mut params_a, g, 1e-2);
        }
        let blob = a.serialize_state();
        let mut b = spec.clone().build(&metas);
        b.restore_state(&blob).unwrap();
        // both continue identically
        let mut params_b = params_a.clone();
        for g in &grads[3..] {
            a.step(&mut params_a, g, 1e-2);
            b.step(&mut params_b, g, 1e-2);
        }
        for (pa, pb) in params_a.iter().zip(&params_b) {
            assert_eq!(
                pa.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                pb.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{dtype:?}"
            );
        }
    }
}

#[test]
fn restore_state_rejects_wrong_composition() {
    let metas = vec![LayerMeta::new("w", 12, 8, ParamKind::Linear)];
    let a = OptimizerSpec::dct_adamw(3).build(&metas);
    let blob = a.serialize_state();
    let mut wrong_preset = OptimizerSpec::trion(3).build(&metas);
    assert!(wrong_preset.restore_state(&blob).is_err());
    let mut wrong_rank = OptimizerSpec::dct_adamw(4).build(&metas);
    assert!(wrong_rank.restore_state(&blob).is_err());
    use crate::tensor::StateDtype;
    let mut wrong_dtype =
        OptimizerSpec::dct_adamw(3).state_dtype(StateDtype::Bf16).build(&metas);
    assert!(wrong_dtype.restore_state(&blob).is_err());
    // truncated blobs error instead of panicking
    let mut same = OptimizerSpec::dct_adamw(3).build(&metas);
    assert!(same.restore_state(&blob[..blob.len() - 3]).is_err());
}
