//! FIRA (Chen et al., 2024): full-rank-quality training under the low-rank
//! memory constraint. The low-rank gradient takes AdamW; the projection
//! residual is added back *norm-scaled* by the ratio of the adaptive
//! subspace update to the raw subspace gradient (φ = ‖u_low‖/‖g_low‖),
//! so the out-of-subspace signal moves with an Adam-calibrated magnitude
//! (Table 3: "norm-based scaling").

use std::sync::Arc;

use crate::parallel::{ShardedWorkspace, ThreadPool};
use crate::projection::{Projection, ProjectionKind};
use crate::tensor::Matrix;

use super::common::{
    adam_moments_into, pool_for, step_layers_parallel, AdamScalars, AdamState,
    LayerMeta, MemoryReport, Optimizer, OptimizerConfig, OrientedGrad,
};

enum LayerState {
    LowRank {
        proj: Box<dyn Projection>,
        m: Matrix,
        v: Matrix,
    },
    Adam(AdamState),
}

pub struct Fira {
    metas: Vec<LayerMeta>,
    states: Vec<LayerState>,
    pool: Arc<ThreadPool>,
    shards: ShardedWorkspace,
    update_interval: usize,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    step: u64,
    proj_name: &'static str,
}

impl Fira {
    pub fn new(metas: &[LayerMeta], cfg: &OptimizerConfig) -> Self {
        Self::with_projection(metas, cfg, cfg.projection.clone())
    }

    pub fn with_projection(
        metas: &[LayerMeta],
        cfg: &OptimizerConfig,
        kind: ProjectionKind,
    ) -> Self {
        let shared = super::common::shared_dct_registry(metas);
        let states = metas
            .iter()
            .enumerate()
            .map(|(i, meta)| {
                if meta.kind.low_rank_eligible() {
                    let (rr, cc) = meta.oriented();
                    let r = cfg.rank.min(cc).min(rr);
                    LayerState::LowRank {
                        proj: kind.build(cc, r, shared.get(&cc).cloned(),
                                         cfg.seed ^ ((i as u64) << 12)),
                        m: Matrix::zeros(rr, r),
                        v: Matrix::zeros(rr, r),
                    }
                } else {
                    LayerState::Adam(AdamState::new(meta.rows, meta.cols))
                }
            })
            .collect();
        let proj_name = kind.name();
        let pool = pool_for(cfg);
        let shards = ShardedWorkspace::for_pool(&pool);
        Fira {
            metas: metas.to_vec(),
            states,
            pool,
            shards,
            update_interval: cfg.update_interval.max(1),
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            weight_decay: cfg.weight_decay,
            step: 0,
            proj_name,
        }
    }
}

impl Optimizer for Fira {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let t = self.step;
        let refresh = t == 1 || t % self.update_interval as u64 == 0;
        let (beta1, beta2, eps, weight_decay) =
            (self.beta1, self.beta2, self.eps, self.weight_decay);
        let metas = &self.metas;
        let pool = Arc::clone(&self.pool);
        step_layers_parallel(
            &pool,
            &mut self.shards,
            &mut self.states,
            params,
            grads,
            |i, state, param, grad, ws| {
                let meta = &metas[i];
                match state {
                    LayerState::Adam(st) => st.update(
                        param, grad, lr, beta1, beta2, eps, weight_decay, t,
                    ),
                    LayerState::LowRank { proj, m, v } => {
                        let (rr, cc) = meta.oriented();
                        let og = OrientedGrad::take(meta, grad, ws);
                        let g = og.matrix();
                        let mut g_low = ws.take_uninit(rr, proj.rank());
                        if refresh {
                            proj.refresh_and_project_into(g, &mut g_low, ws);
                        } else {
                            proj.project_into(g, &mut g_low, ws);
                        }
                        let sc = AdamScalars::new(beta1, beta2, eps, t);
                        let mut u_low = ws.take_uninit(g_low.rows, g_low.cols);
                        adam_moments_into(
                            &mut u_low.data, &g_low.data, &mut m.data, &mut v.data, &sc,
                        );
                        // φ = ‖u_low‖ / ‖g_low‖ — Adam-calibrated scaling for
                        // the residual (FIRA's norm-based scaling)
                        let phi = (u_low.fro_norm() / (g_low.fro_norm() + 1e-12)) as f32;
                        let mut u = ws.take_uninit(rr, cc);
                        proj.back_into(&u_low, &mut u, ws);
                        // residual = g − back(g_low), built in place
                        let mut resid = ws.take_uninit(rr, cc);
                        proj.back_into(&g_low, &mut resid, ws);
                        resid.sub_from(g);
                        u.axpy(phi, &resid);
                        param.scale(1.0 - lr * weight_decay);
                        if meta.needs_transpose() {
                            param.axpy_t(-lr, &u);
                        } else {
                            param.axpy(-lr, &u);
                        }
                        ws.give(resid);
                        ws.give(u);
                        ws.give(u_low);
                        ws.give(g_low);
                        og.give(ws);
                    }
                }
            },
        );
    }

    fn memory_report(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        let mut shared_max = 0u64;
        for st in &self.states {
            match st {
                LayerState::LowRank { proj, m, v } => {
                    r.add("adam_m_low", m.bytes());
                    r.add("adam_v_low", v.bytes());
                    r.add("projector", proj.state_bytes());
                    shared_max = shared_max.max(proj.shared_bytes());
                }
                LayerState::Adam(a) => {
                    r.add("adam_m", a.m.bytes());
                    r.add("adam_v", a.v.bytes());
                }
            }
        }
        if shared_max > 0 {
            r.share("shared_projection", shared_max);
        }
        r
    }

    fn name(&self) -> &'static str {
        match self.proj_name {
            "dct" => "fira+dct",
            "svd" => "fira+svd",
            _ => "fira",
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::optim::common::ParamKind;
    use super::*;
    use crate::projection::RankNorm;
    use crate::util::Pcg64;

    #[test]
    fn converges_on_quadratic_both_projections() {
        for kind in [
            ProjectionKind::Svd,
            ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true },
        ] {
            let mut rng = Pcg64::seed(0);
            let t = Matrix::randn(10, 8, 0.5, &mut rng);
            let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
            let cfg = OptimizerConfig {
                rank: 3,
                weight_decay: 0.0,
                update_interval: 5,
                ..Default::default()
            };
            let mut opt = Fira::with_projection(&metas, &cfg, kind.clone());
            let mut params = vec![Matrix::zeros(10, 8)];
            for _ in 0..400 {
                let g = params[0].sub(&t).scaled(2.0);
                opt.step(&mut params, &[g], 0.05);
            }
            let err = params[0].sub(&t).fro_norm() / t.fro_norm();
            assert!(err < 0.15, "{} err={err}", kind.name());
        }
    }

    #[test]
    fn residual_scaling_tracks_adam_magnitude() {
        // With g_low large and u_low ≈ bias-corrected-normalized, φ < 1:
        // the residual contribution must be damped relative to raw SGD.
        let metas = vec![LayerMeta::new("w", 8, 8, ParamKind::Linear)];
        let cfg = OptimizerConfig {
            rank: 2,
            weight_decay: 0.0,
            projection: ProjectionKind::Svd,
            ..Default::default()
        };
        let mut opt = Fira::new(&metas, &cfg);
        let mut rng = Pcg64::seed(1);
        let g = Matrix::randn(8, 8, 10.0, &mut rng); // large gradient
        let mut params = vec![Matrix::zeros(8, 8)];
        opt.step(&mut params, &[g.clone()], 1.0);
        // update magnitude is Adam-like (≈1 per coord), not grad-like (≈10)
        assert!(params[0].abs_max() < 3.0, "{}", params[0].abs_max());
    }

    #[test]
    fn full_rank_recovery_better_than_galore() {
        // A rotating gradient direction defeats the frozen low-rank subspace
        // of GaLore; FIRA's scaled residual keeps up.
        let metas = vec![LayerMeta::new("w", 12, 12, ParamKind::Linear)];
        let cfg = OptimizerConfig {
            rank: 2,
            weight_decay: 0.0,
            update_interval: 50,
            projection: ProjectionKind::Svd,
            ..Default::default()
        };
        let mut rng = Pcg64::seed(2);
        let t = Matrix::randn(12, 12, 1.0, &mut rng);
        let mut fira = Fira::new(&metas, &cfg);
        let mut galore = super::super::GaLore::new(&metas, &cfg);
        let mut pf = vec![Matrix::zeros(12, 12)];
        let mut pg = vec![Matrix::zeros(12, 12)];
        for _ in 0..300 {
            let gf = pf[0].sub(&t).scaled(2.0);
            fira.step(&mut pf, &[gf], 0.05);
            let gg = pg[0].sub(&t).scaled(2.0);
            galore.step(&mut pg, &[gg], 0.05);
        }
        let ef = pf[0].sub(&t).fro_norm();
        let eg = pg[0].sub(&t).fro_norm();
        assert!(ef < eg, "fira={ef} galore={eg}");
    }
}
