//! Optimizers: the paper's contributions (Trion, DCT-AdamW) and every
//! baseline it evaluates against (AdamW, Muon, Dion, GaLore, LDAdamW,
//! FRUGAL, FIRA).
//!
//! All optimizers implement [`Optimizer`]: they own per-layer state, consume
//! the (already all-reduced) gradients and update the parameter buffers in
//! place. Low-rank treatment applies to 2-D `linear` parameters; `embed` /
//! `head` / `norm` parameters always take dense AdamW, matching the practice
//! of GaLore/LDAdam/Dion ("hidden layers only").
//!
//! Memory accounting is exact: [`MemoryReport`] sums every persistent buffer
//! byte, with shared per-device state (the DCT matrix) deduplicated — this
//! regenerates the paper's memory columns without a GPU to read from.

pub mod common;
pub mod adamw;
pub mod muon;
pub mod dion;
pub mod trion;
pub mod galore;
pub mod ldadamw;
pub mod dct_adamw;
pub mod frugal;
pub mod fira;
pub mod error_feedback;

pub use adamw::AdamW;
pub use common::{
    adam_fused_update, adam_moments_into, build_optimizer, shared_dct_registry,
    step_layers_parallel, AdamScalars, LayerMeta, MemoryReport, Optimizer,
    OptimizerConfig, OptimizerKind, ParamKind,
};
pub use dct_adamw::DctAdamW;
pub use dion::Dion;
pub use fira::Fira;
pub use frugal::Frugal;
pub use galore::GaLore;
pub use ldadamw::LdAdamW;
pub use muon::Muon;
pub use trion::Trion;
