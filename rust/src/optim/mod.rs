//! Optimizers: the paper's contributions (Trion, DCT-AdamW) and every
//! baseline it evaluates against (AdamW, Muon, Dion, GaLore, LDAdamW,
//! FRUGAL, FIRA).
//!
//! The low-rank family is built by the composable [`engine`]: a single
//! [`SubspaceEngine`] step loop parameterized by four policy axes (subspace
//! source, moment rotation, residual handling, inner update rule) through
//! the [`OptimizerSpec`] builder. The six published methods are presets of
//! that builder — `OptimizerSpec::dct_adamw(r)`, `::trion(r)`, … — and
//! [`build_optimizer`] / [`OptimizerKind`] remain as thin aliases for them.
//! Only the dense/full-momentum baselines (AdamW, Muon, Dion) stay
//! hand-written.
//!
//! All optimizers implement [`Optimizer`]: they own per-layer state, consume
//! the (already all-reduced) gradients and update the parameter buffers in
//! place. Low-rank treatment applies to 2-D `linear` parameters; `embed` /
//! `head` / `norm` parameters always take dense AdamW, matching the practice
//! of GaLore/LDAdam/Dion ("hidden layers only").
//!
//! Memory accounting is exact: [`MemoryReport`] sums every persistent buffer
//! byte, with shared per-device state (the DCT matrix) deduplicated — this
//! regenerates the paper's memory columns without a GPU to read from.

pub mod common;
pub mod adamw;
pub mod muon;
pub mod dion;
pub mod engine;
pub mod error_feedback;

pub use adamw::AdamW;
pub use common::{
    adam_fused_update, adam_moments_into, build_optimizer, pool_for_threads,
    shared_dct_registry, step_layers_parallel, AdamScalars, EfMode, LayerMeta,
    MemoryReport, Optimizer, OptimizerConfig, OptimizerKind, ParamKind,
    SubspaceCommView,
};
pub use dion::Dion;
pub use engine::{
    rotate_fixed_basis, rotate_fixed_basis_into, BroadcastKind, OptimizerSpec,
    ResidualKind, RotationKind, StepPlanMode, SubspaceEngine, UpdateRuleKind,
};
pub use muon::Muon;
