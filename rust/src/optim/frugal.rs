//! FRUGAL (Zmushko et al., 2024): the low-rank ("state-full") gradient goes
//! through AdamW; the projection residual ("state-free") is fed to SignSGD
//! instead of being discarded or buffered (Table 3). Projection family is
//! pluggable — SVD / DCT / Random / RandPerm — which is exactly the sweep of
//! Table 6 / Figure 4a.

use std::sync::Arc;

use crate::parallel::{ShardedWorkspace, ThreadPool};
use crate::projection::{Projection, ProjectionKind};
use crate::tensor::Matrix;

use super::common::{
    adam_moments_into, pool_for, step_layers_parallel, AdamScalars, AdamState,
    LayerMeta, MemoryReport, Optimizer, OptimizerConfig, OrientedGrad,
};

enum LayerState {
    LowRank {
        proj: Box<dyn Projection>,
        m: Matrix, // R×r
        v: Matrix, // R×r
    },
    Adam(AdamState),
}

pub struct Frugal {
    metas: Vec<LayerMeta>,
    states: Vec<LayerState>,
    pool: Arc<ThreadPool>,
    shards: ShardedWorkspace,
    update_interval: usize,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    /// state-free learning-rate multiplier for the SignSGD branch
    sign_lr_scale: f32,
    step: u64,
    proj_name: &'static str,
}

impl Frugal {
    pub fn new(metas: &[LayerMeta], cfg: &OptimizerConfig) -> Self {
        Self::with_projection(metas, cfg, cfg.projection.clone())
    }

    pub fn with_projection(
        metas: &[LayerMeta],
        cfg: &OptimizerConfig,
        kind: ProjectionKind,
    ) -> Self {
        let shared = super::common::shared_dct_registry(metas);
        let states = metas
            .iter()
            .enumerate()
            .map(|(i, meta)| {
                if meta.kind.low_rank_eligible() {
                    let (rr, cc) = meta.oriented();
                    let r = cfg.rank.min(cc).min(rr);
                    LayerState::LowRank {
                        proj: kind.build(cc, r, shared.get(&cc).cloned(),
                                         cfg.seed ^ ((i as u64) << 4)),
                        m: Matrix::zeros(rr, r),
                        v: Matrix::zeros(rr, r),
                    }
                } else {
                    LayerState::Adam(AdamState::new(meta.rows, meta.cols))
                }
            })
            .collect();
        let proj_name = kind.name();
        let pool = pool_for(cfg);
        let shards = ShardedWorkspace::for_pool(&pool);
        Frugal {
            metas: metas.to_vec(),
            states,
            pool,
            shards,
            update_interval: cfg.update_interval.max(1),
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            weight_decay: cfg.weight_decay,
            sign_lr_scale: 1.0,
            step: 0,
            proj_name,
        }
    }
}

impl Optimizer for Frugal {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let t = self.step;
        let refresh = t == 1 || t % self.update_interval as u64 == 0;
        let (beta1, beta2, eps, weight_decay, sign_lr_scale) = (
            self.beta1, self.beta2, self.eps, self.weight_decay,
            self.sign_lr_scale,
        );
        let metas = &self.metas;
        let pool = Arc::clone(&self.pool);
        step_layers_parallel(
            &pool,
            &mut self.shards,
            &mut self.states,
            params,
            grads,
            |i, state, param, grad, ws| {
                let meta = &metas[i];
                match state {
                    LayerState::Adam(st) => st.update(
                        param, grad, lr, beta1, beta2, eps, weight_decay, t,
                    ),
                    LayerState::LowRank { proj, m, v } => {
                        let (rr, cc) = meta.oriented();
                        let og = OrientedGrad::take(meta, grad, ws);
                        let g = og.matrix();
                        let mut g_low = ws.take_uninit(rr, proj.rank());
                        if refresh {
                            proj.refresh_and_project_into(g, &mut g_low, ws);
                        } else {
                            proj.project_into(g, &mut g_low, ws);
                        }
                        // state-full branch: AdamW on the subspace gradient
                        let sc = AdamScalars::new(beta1, beta2, eps, t);
                        let mut u_low = ws.take_uninit(g_low.rows, g_low.cols);
                        adam_moments_into(
                            &mut u_low.data, &g_low.data, &mut m.data, &mut v.data, &sc,
                        );
                        let mut u = ws.take_uninit(rr, cc);
                        proj.back_into(&u_low, &mut u, ws);
                        // state-free branch: SignSGD on the residual
                        let mut resid = ws.take_uninit(rr, cc);
                        proj.back_into(&g_low, &mut resid, ws);
                        resid.sub_from(g);
                        for (uv, &rv) in u.data.iter_mut().zip(resid.data.iter()) {
                            // rust's signum(0.0) == 1.0; SignSGD wants
                            // sign(0) = 0
                            if rv != 0.0 {
                                *uv += sign_lr_scale * rv.signum();
                            }
                        }
                        param.scale(1.0 - lr * weight_decay);
                        if meta.needs_transpose() {
                            param.axpy_t(-lr, &u);
                        } else {
                            param.axpy(-lr, &u);
                        }
                        ws.give(resid);
                        ws.give(u);
                        ws.give(u_low);
                        ws.give(g_low);
                        og.give(ws);
                    }
                }
            },
        );
    }

    fn memory_report(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        let mut shared_max = 0u64;
        for st in &self.states {
            match st {
                LayerState::LowRank { proj, m, v } => {
                    r.add("adam_m_low", m.bytes());
                    r.add("adam_v_low", v.bytes());
                    r.add("projector", proj.state_bytes());
                    shared_max = shared_max.max(proj.shared_bytes());
                }
                LayerState::Adam(a) => {
                    r.add("adam_m", a.m.bytes());
                    r.add("adam_v", a.v.bytes());
                }
            }
        }
        if shared_max > 0 {
            r.share("shared_projection", shared_max);
        }
        r
    }

    fn name(&self) -> &'static str {
        match self.proj_name {
            "dct" => "frugal+dct",
            "svd" => "frugal+svd",
            "random" => "frugal+random",
            "randperm" => "frugal+randperm",
            _ => "frugal",
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::optim::common::ParamKind;
    use super::*;
    use crate::projection::RankNorm;
    use crate::util::Pcg64;

    fn quad_converges(kind: ProjectionKind) -> f64 {
        let mut rng = Pcg64::seed(0);
        let t = Matrix::randn(10, 8, 0.5, &mut rng);
        let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
        let cfg = OptimizerConfig {
            rank: 3,
            weight_decay: 0.0,
            update_interval: 5,
            ..Default::default()
        };
        let mut opt = Frugal::with_projection(&metas, &cfg, kind);
        let mut params = vec![Matrix::zeros(10, 8)];
        for _ in 0..400 {
            let g = params[0].sub(&t).scaled(2.0);
            opt.step(&mut params, &[g], 0.02);
        }
        params[0].sub(&t).fro_norm() / t.fro_norm()
    }

    #[test]
    fn converges_with_every_projection() {
        for kind in [
            ProjectionKind::Svd,
            ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true },
            ProjectionKind::Random,
            ProjectionKind::RandPerm,
        ] {
            let err = quad_converges(kind.clone());
            // the sign branch keeps full-rank progress: all variants converge
            assert!(err < 0.3, "{:?} err={err}", kind.name());
        }
    }

    #[test]
    fn state_free_branch_moves_out_of_subspace_coords() {
        // rank-1 subspace + constant residual: SignSGD must still move
        // every coordinate from step one (no EF warm-up needed).
        let metas = vec![LayerMeta::new("w", 6, 6, ParamKind::Linear)];
        let cfg = OptimizerConfig {
            rank: 1,
            weight_decay: 0.0,
            projection: ProjectionKind::Svd,
            ..Default::default()
        };
        let mut opt = Frugal::new(&metas, &cfg);
        let mut rng = Pcg64::seed(1);
        let g = Matrix::randn(6, 6, 1.0, &mut rng);
        let mut params = vec![Matrix::zeros(6, 6)];
        opt.step(&mut params, &[g.clone()], 0.1);
        let moved = params[0].data.iter().filter(|v| v.abs() > 1e-6).count();
        assert!(moved > 30, "moved={moved}/36");
    }

    #[test]
    fn memory_matches_galore_plus_nothing_extra() {
        // FRUGAL's state-free branch is stateless: memory == GaLore's.
        let metas = vec![LayerMeta::new("w", 32, 32, ParamKind::Linear)];
        let cfg = OptimizerConfig { rank: 8, projection: ProjectionKind::Svd, ..Default::default() };
        let f = Frugal::new(&metas, &cfg).memory_report().total();
        let g = super::super::GaLore::new(&metas, &cfg).memory_report().total();
        assert_eq!(f, g);
    }
}
