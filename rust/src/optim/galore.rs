//! GaLore (Zhao et al., 2024): AdamW on an SVD-projected gradient, subspace
//! refreshed every `T_u` steps (default 200 — what made SVD-per-layer
//! feasible), projection error *discarded* (Table 3).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::parallel::{ShardedWorkspace, ThreadPool};
use crate::projection::{Projection, ProjectionKind};
use crate::tensor::Matrix;

use super::common::{
    adam_moments_into, pool_for, step_layers_parallel, AdamScalars, AdamState,
    LayerMeta, MemoryReport, Optimizer, OptimizerConfig, OrientedGrad,
};

enum LayerState {
    LowRank {
        proj: Box<dyn Projection>,
        m: Matrix, // R×r
        v: Matrix, // R×r
    },
    Adam(AdamState),
}

pub struct GaLore {
    metas: Vec<LayerMeta>,
    states: Vec<LayerState>,
    pool: Arc<ThreadPool>,
    shards: ShardedWorkspace,
    update_interval: usize,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    step: u64,
    /// Which projection family to use — SVD for stock GaLore, DCT for the
    /// Table-8 comparison (DCT-AdamW with T_u=200 ≈ "GaLore+DCT").
    projection_name: &'static str,
}

impl GaLore {
    pub fn new(metas: &[LayerMeta], cfg: &OptimizerConfig) -> Self {
        Self::with_projection(metas, cfg, ProjectionKind::Svd)
    }

    pub fn with_projection(
        metas: &[LayerMeta],
        cfg: &OptimizerConfig,
        kind: ProjectionKind,
    ) -> Self {
        let shared = super::common::shared_dct_registry(metas);
        let states = metas
            .iter()
            .enumerate()
            .map(|(i, meta)| {
                if meta.kind.low_rank_eligible() {
                    let (rr, cc) = meta.oriented();
                    let r = cfg.rank.min(cc).min(rr);
                    let proj = kind.build(
                        cc,
                        r,
                        shared.get(&cc).cloned(),
                        cfg.seed ^ (i as u64) << 8,
                    );
                    LayerState::LowRank {
                        proj,
                        m: Matrix::zeros(rr, r),
                        v: Matrix::zeros(rr, r),
                    }
                } else {
                    LayerState::Adam(AdamState::new(meta.rows, meta.cols))
                }
            })
            .collect();
        let projection_name = kind.name();
        let pool = pool_for(cfg);
        let shards = ShardedWorkspace::for_pool(&pool);
        GaLore {
            metas: metas.to_vec(),
            states,
            pool,
            shards,
            update_interval: cfg.update_interval.max(1),
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            weight_decay: cfg.weight_decay,
            step: 0,
            projection_name,
        }
    }

    fn refresh_due(&self) -> bool {
        self.step == 1 || self.step % self.update_interval as u64 == 0
    }
}

impl Optimizer for GaLore {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let refresh = self.refresh_due();
        let t = self.step;
        let (beta1, beta2, eps, weight_decay) =
            (self.beta1, self.beta2, self.eps, self.weight_decay);
        let metas = &self.metas;
        let pool = Arc::clone(&self.pool);
        step_layers_parallel(
            &pool,
            &mut self.shards,
            &mut self.states,
            params,
            grads,
            |i, state, param, grad, ws| {
                let meta = &metas[i];
                match state {
                    LayerState::Adam(st) => st.update(
                        param, grad, lr, beta1, beta2, eps, weight_decay, t,
                    ),
                    LayerState::LowRank { proj, m, v } => {
                        let (rr, cc) = meta.oriented();
                        // oriented gradient: borrow in place unless transposed
                        let og = OrientedGrad::take(meta, grad, ws);
                        let g = og.matrix();
                        let mut g_low = ws.take_uninit(rr, proj.rank());
                        if refresh {
                            proj.refresh_and_project_into(g, &mut g_low, ws);
                        } else {
                            proj.project_into(g, &mut g_low, ws);
                        }
                        // GaLore does NOT rotate m/v across refreshes (its
                        // T_u is large precisely so stale-subspace mixing is
                        // rare).
                        let sc = AdamScalars::new(beta1, beta2, eps, t);
                        let mut u_low = ws.take_uninit(g_low.rows, g_low.cols);
                        adam_moments_into(
                            &mut u_low.data, &g_low.data, &mut m.data, &mut v.data, &sc,
                        );
                        let mut u = ws.take_uninit(rr, cc);
                        proj.back_into(&u_low, &mut u, ws);
                        param.scale(1.0 - lr * weight_decay);
                        if meta.needs_transpose() {
                            param.axpy_t(-lr, &u);
                        } else {
                            param.axpy(-lr, &u);
                        }
                        ws.give(u);
                        ws.give(u_low);
                        ws.give(g_low);
                        og.give(ws);
                    }
                }
            },
        );
    }

    fn memory_report(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        let mut shared_seen: BTreeMap<u64, u64> = BTreeMap::new();
        for st in &self.states {
            match st {
                LayerState::LowRank { proj, m, v } => {
                    r.add("adam_m_low", m.bytes());
                    r.add("adam_v_low", v.bytes());
                    r.add("projector", proj.state_bytes());
                    let sb = proj.shared_bytes();
                    if sb > 0 {
                        shared_seen.insert(sb, sb);
                    }
                }
                LayerState::Adam(a) => {
                    r.add("adam_m", a.m.bytes());
                    r.add("adam_v", a.v.bytes());
                }
            }
        }
        for (i, (_, sb)) in shared_seen.into_iter().enumerate() {
            r.share(&format!("shared_proj_{i}"), sb);
        }
        r
    }

    fn name(&self) -> &'static str {
        if self.projection_name == "dct" {
            "galore+dct"
        } else {
            "galore"
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::optim::common::ParamKind;
    use super::*;
    use crate::projection::RankNorm;
    use crate::util::Pcg64;

    #[test]
    fn converges_on_quadratic() {
        let mut rng = Pcg64::seed(0);
        let t = Matrix::randn(10, 8, 0.5, &mut rng);
        let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
        let cfg = OptimizerConfig {
            rank: 4,
            weight_decay: 0.0,
            update_interval: 10,
            ..Default::default()
        };
        let mut opt = GaLore::new(&metas, &cfg);
        let mut params = vec![Matrix::zeros(10, 8)];
        for _ in 0..600 {
            let g = params[0].sub(&t).scaled(2.0);
            opt.step(&mut params, &[g], 0.05);
        }
        let err = params[0].sub(&t).fro_norm() / t.fro_norm();
        assert!(err < 0.4, "rel err={err}");
    }

    #[test]
    fn low_rank_state_is_smaller_than_adamw() {
        let metas = vec![LayerMeta::new("w", 100, 100, ParamKind::Linear)];
        let cfg = OptimizerConfig { rank: 10, ..Default::default() };
        let galore = GaLore::new(&metas, &cfg).memory_report().total();
        let adam = super::super::AdamW::new(&metas, &cfg).memory_report().total();
        assert!(galore < adam / 2, "galore={galore} adam={adam}");
    }

    #[test]
    fn dct_variant_has_smaller_projector_state() {
        let metas = vec![
            LayerMeta::new("a", 64, 64, ParamKind::Linear),
            LayerMeta::new("b", 64, 64, ParamKind::Linear),
        ];
        let cfg = OptimizerConfig { rank: 16, ..Default::default() };
        let svd = GaLore::new(&metas, &cfg).memory_report();
        let dct = GaLore::with_projection(
            &metas,
            &cfg,
            ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true },
        )
        .memory_report();
        assert!(dct.per_layer["projector"] < svd.per_layer["projector"]);
    }

    #[test]
    fn subspace_refresh_interval_respected() {
        // With interval 5, the basis must be identical between refreshes.
        let metas = vec![LayerMeta::new("w", 12, 8, ParamKind::Linear)];
        let cfg = OptimizerConfig {
            rank: 3,
            update_interval: 5,
            ..Default::default()
        };
        let mut opt = GaLore::new(&metas, &cfg);
        let mut rng = Pcg64::seed(1);
        let mut params = vec![Matrix::zeros(12, 8)];
        let mut bases = Vec::new();
        for _ in 0..6 {
            let g = Matrix::randn(12, 8, 1.0, &mut rng);
            opt.step(&mut params, &[g], 0.01);
            if let LayerState::LowRank { proj, .. } = &opt.states[0] {
                bases.push(proj.basis());
            }
        }
        // steps 2..4 (after the step-1 refresh) share the same basis
        assert!(bases[1].max_abs_diff(&bases[2]) < 1e-7);
        assert!(bases[2].max_abs_diff(&bases[3]) < 1e-7);
        // step 5 (t=5, 5%5==0) refreshed
        assert!(bases[3].max_abs_diff(&bases[4]) > 1e-6);
    }
}
