//! Dion (Ahn et al., 2025): low-rank orthonormal updates via one power
//! iteration + QR per step, with error feedback into the momentum.
//!
//! The baseline Trion improves on. Runtime depends on the rank through the
//! `QR(B·Q_{t-1})` factorization — the rank-dependence Table 1 measures —
//! and a `C×r` projector is stored **per layer** (the memory overhead the
//! DCT side of the paper removes).

use std::collections::BTreeMap;

use crate::linalg::qr_thin;
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Matrix};

use super::common::{
    deorient, orient, shape_factor, AdamState, LayerMeta, MemoryReport, Optimizer,
    OptimizerConfig,
};

enum LayerState {
    LowRank {
        momentum: Matrix, // R×C (oriented)
        q: Matrix,        // C×r right factor, column-normalized
    },
    Adam(AdamState),
}

pub struct Dion {
    metas: Vec<LayerMeta>,
    states: Vec<LayerState>,
    mu: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    step: u64,
    instrument: bool,
    errors: BTreeMap<String, f64>,
}

impl Dion {
    pub fn new(metas: &[LayerMeta], cfg: &OptimizerConfig) -> Self {
        let mut rng = crate::util::Pcg64::new(cfg.seed, 0xd1a0_abcd);
        let states = metas
            .iter()
            .map(|m| {
                if m.kind.low_rank_eligible() {
                    let (rr, cc) = m.oriented();
                    let r = cfg.rank.min(cc);
                    // random orthonormal init for the right factor
                    let g0 = Matrix::randn(cc, r, 1.0, &mut rng);
                    let (q, _) = qr_thin(&g0);
                    LayerState::LowRank { momentum: Matrix::zeros(rr, cc), q }
                } else {
                    LayerState::Adam(AdamState::new(m.rows, m.cols))
                }
            })
            .collect();
        Dion {
            metas: metas.to_vec(),
            states,
            mu: cfg.mu,
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            weight_decay: cfg.weight_decay,
            step: 0,
            instrument: cfg.instrument,
            errors: BTreeMap::new(),
        }
    }
}

impl Optimizer for Dion {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        for i in 0..params.len() {
            let meta = &self.metas[i];
            match &mut self.states[i] {
                LayerState::Adam(st) => st.update(
                    &mut params[i], &grads[i], lr, self.beta1, self.beta2,
                    self.eps, 0.0, self.step,
                ),
                LayerState::LowRank { momentum, q } => {
                    let g = orient(meta, &grads[i]);
                    // B = M + G
                    momentum.axpy(1.0, &g);
                    let b = &*momentum;
                    // P = QR(B·Q_prev)  (R×r, orthonormal) — rank-dependent
                    let z = matmul(b, q);
                    let (p, _) = qr_thin(&z);
                    // R_t = Bᵀ·P  (C×r)
                    let r_mat = matmul_at_b(b, &p);
                    // error feedback: M = B − (1−μ)·P·R_tᵀ
                    let p_rt = matmul_a_bt(&p, &r_mat);
                    momentum.axpy(-(1.0 - self.mu), &p_rt);
                    // column-normalize R_t → next right factor Q_t
                    let mut q_new = r_mat;
                    for j in 0..q_new.cols {
                        let mut n2 = 0.0f64;
                        for i2 in 0..q_new.rows {
                            let v = q_new.at(i2, j) as f64;
                            n2 += v * v;
                        }
                        let inv = 1.0 / (n2.sqrt() as f32 + 1e-8);
                        for i2 in 0..q_new.rows {
                            *q_new.at_mut(i2, j) *= inv;
                        }
                    }
                    // O = P·Q_tᵀ
                    let o = matmul_a_bt(&p, &q_new);
                    if self.instrument {
                        // Δ = ‖B_t − O_t‖ on the pre-EF accumulator
                        let b_now = {
                            let mut b2 = momentum.clone();
                            b2.axpy(1.0 - self.mu, &p_rt); // restore B
                            b2
                        };
                        self.errors
                            .insert(meta.name.clone(), b_now.sub(&o).fro_norm());
                    }
                    *q = q_new;
                    let (rr, cc) = o.shape();
                    let o_full = deorient(meta, o);
                    params[i].scale(1.0 - lr * self.weight_decay);
                    params[i].axpy(-lr * shape_factor(rr, cc), &o_full);
                }
            }
        }
    }

    fn memory_report(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        for st in &self.states {
            match st {
                LayerState::LowRank { momentum, q } => {
                    r.add("momentum", momentum.bytes());
                    r.add("projector", q.bytes()); // per-layer C×r — Dion's cost
                }
                LayerState::Adam(a) => {
                    r.add("adam_m", a.m.bytes());
                    r.add("adam_v", a.v.bytes());
                }
            }
        }
        r
    }

    fn name(&self) -> &str {
        "dion"
    }

    fn projection_errors(&self) -> Option<&BTreeMap<String, f64>> {
        if self.instrument {
            Some(&self.errors)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::optim::common::ParamKind;
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn converges_on_quadratic() {
        let mut rng = Pcg64::seed(0);
        let t = Matrix::randn(10, 8, 0.5, &mut rng);
        let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
        let cfg = OptimizerConfig {
            rank: 4,
            weight_decay: 0.0,
            mu: 0.9,
            ..Default::default()
        };
        let mut opt = Dion::new(&metas, &cfg);
        let mut params = vec![Matrix::zeros(10, 8)];
        for _ in 0..500 {
            let g = params[0].sub(&t).scaled(2.0);
            opt.step(&mut params, &[g], 0.02);
        }
        let err = params[0].sub(&t).fro_norm() / t.fro_norm();
        assert!(err < 0.35, "rel err={err}");
    }

    #[test]
    fn stores_projector_per_layer() {
        let metas = vec![
            LayerMeta::new("a", 16, 12, ParamKind::Linear),
            LayerMeta::new("b", 16, 12, ParamKind::Linear),
        ];
        let cfg = OptimizerConfig { rank: 4, ..Default::default() };
        let rep = Dion::new(&metas, &cfg).memory_report();
        // two C×r projectors (this is Dion's overhead vs Trion's r ints)
        assert_eq!(rep.per_layer["projector"], 2 * 12 * 4 * 4);
    }

    #[test]
    fn wide_layer_is_transposed_internally() {
        let metas = vec![LayerMeta::new("w", 6, 20, ParamKind::Linear)];
        let cfg = OptimizerConfig { rank: 3, weight_decay: 0.0, ..Default::default() };
        let mut opt = Dion::new(&metas, &cfg);
        let mut rng = Pcg64::seed(1);
        let mut params = vec![Matrix::zeros(6, 20)];
        let g = Matrix::randn(6, 20, 1.0, &mut rng);
        opt.step(&mut params, &[g], 0.01);
        assert_eq!(params[0].shape(), (6, 20));
        assert!(params[0].fro_norm() > 0.0);
    }

    #[test]
    fn momentum_error_feedback_keeps_residual() {
        // With μ=1 the captured part stays entirely in momentum: M = B.
        let metas = vec![LayerMeta::new("w", 8, 6, ParamKind::Linear)];
        let cfg = OptimizerConfig {
            rank: 2,
            mu: 1.0,
            weight_decay: 0.0,
            ..Default::default()
        };
        let mut opt = Dion::new(&metas, &cfg);
        let mut rng = Pcg64::seed(2);
        let mut params = vec![Matrix::zeros(8, 6)];
        let g = Matrix::randn(8, 6, 1.0, &mut rng);
        opt.step(&mut params, &[g.clone()], 0.01);
        if let LayerState::LowRank { momentum, .. } = &opt.states[0] {
            assert!(momentum.max_abs_diff(&g) < 1e-5);
        } else {
            panic!("expected low-rank state");
        }
    }
}
