//! Shared optimizer infrastructure: the [`Optimizer`] trait, layer
//! metadata, orientation handling (project the smaller dimension), the
//! parallel layer-stepping driver ([`step_layers_parallel`]), memory
//! reports and the optimizer factory.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::parallel::{SendPtr, ShardedWorkspace, ThreadPool};
use crate::projection::{ProjectionKind, RankNorm, SharedDct};
use crate::simd::{Simd, F32_LANES};
use crate::tensor::{Matrix, StateDtype, StateStore, Workspace};
use crate::util::codec::ByteReader;

/// What a parameter is; drives the low-rank policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    Embed,
    Head,
    Norm,
    Linear,
}

impl ParamKind {
    pub fn parse(s: &str) -> ParamKind {
        match s {
            "embed" => ParamKind::Embed,
            "head" => ParamKind::Head,
            "norm" => ParamKind::Norm,
            _ => ParamKind::Linear,
        }
    }

    /// Only hidden linear layers take the low-rank path.
    pub fn low_rank_eligible(self) -> bool {
        self == ParamKind::Linear
    }
}

/// Per-parameter metadata (name + kind + shape), mirrored from the AOT
/// manifest's `params` list.
#[derive(Clone, Debug)]
pub struct LayerMeta {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub kind: ParamKind,
}

impl LayerMeta {
    pub fn new(name: &str, rows: usize, cols: usize, kind: ParamKind) -> Self {
        LayerMeta { name: name.to_string(), rows, cols, kind }
    }

    /// Right-projection needs the column side to be the smaller one; wide
    /// matrices are handled on their transpose (§2.1 "compress the smallest
    /// dimension").
    pub fn needs_transpose(&self) -> bool {
        self.kind.low_rank_eligible() && self.cols > self.rows
    }

    /// (R, C) in the oriented frame.
    pub fn oriented(&self) -> (usize, usize) {
        if self.needs_transpose() {
            (self.cols, self.rows)
        } else {
            (self.rows, self.cols)
        }
    }
}

/// Orient a gradient so its projected (column) dimension is the smaller one.
pub fn orient(meta: &LayerMeta, g: &Matrix) -> Matrix {
    if meta.needs_transpose() {
        g.transpose()
    } else {
        g.clone()
    }
}

/// Pooled, *owned* oriented gradient — the DctAdamW/LdAdamW idiom (error
/// feedback mutates the buffer, so a copy is mandatory either way). The
/// checkout is non-zeroing: `transpose_into`/`copy_from` overwrite every
/// element. Pair with `ws.give` after the step.
pub fn take_oriented_owned(meta: &LayerMeta, g: &Matrix, ws: &mut Workspace) -> Matrix {
    let (rr, cc) = meta.oriented();
    let mut out = ws.take_uninit(rr, cc);
    if meta.needs_transpose() {
        g.transpose_into(&mut out);
    } else {
        out.copy_from(g);
    }
    out
}

/// Pooled, *borrowed* oriented gradient — the GaLore/FIRA/FRUGAL idiom:
/// wide layers get a pooled transpose, tall layers read the gradient in
/// place through a zero-size staging buffer (which keeps the pool's
/// take/give sequence identical across layer shapes).
pub struct OrientedGrad<'g> {
    buf: Matrix,
    transposed: bool,
    orig: &'g Matrix,
}

impl<'g> OrientedGrad<'g> {
    pub fn take(meta: &LayerMeta, g: &'g Matrix, ws: &mut Workspace) -> Self {
        let (rr, cc) = meta.oriented();
        let transposed = meta.needs_transpose();
        let mut buf = ws.take_uninit(if transposed { rr } else { 0 }, cc);
        if transposed {
            g.transpose_into(&mut buf);
        }
        OrientedGrad { buf, transposed, orig: g }
    }

    /// The oriented gradient to read (R×C in the oriented frame).
    pub fn matrix(&self) -> &Matrix {
        if self.transposed {
            &self.buf
        } else {
            self.orig
        }
    }

    /// Return the staging buffer to the pool.
    pub fn give(self, ws: &mut Workspace) {
        ws.give(self.buf);
    }
}

/// Undo [`orient`] on an update.
pub fn deorient(meta: &LayerMeta, u: Matrix) -> Matrix {
    if meta.needs_transpose() {
        u.transpose()
    } else {
        u
    }
}

/// Exact persistent-memory accounting.
#[derive(Clone, Debug, Default)]
pub struct MemoryReport {
    /// Per-layer state bytes, keyed by buffer family ("momentum", "ef", …).
    pub per_layer: BTreeMap<String, u64>,
    /// Per-device shared state, deduplicated by name ("dct_matrix").
    pub shared: BTreeMap<String, u64>,
}

impl MemoryReport {
    pub fn add(&mut self, family: &str, bytes: u64) {
        *self.per_layer.entry(family.to_string()).or_default() += bytes;
    }

    pub fn share(&mut self, name: &str, bytes: u64) {
        self.shared.insert(name.to_string(), bytes);
    }

    /// Total optimizer state bytes (per-layer + shared).
    pub fn total(&self) -> u64 {
        self.per_layer.values().sum::<u64>() + self.shared.values().sum::<u64>()
    }

    pub fn merge(&mut self, other: &MemoryReport) {
        for (k, v) in &other.per_layer {
            *self.per_layer.entry(k.clone()).or_default() += v;
        }
        for (k, v) in &other.shared {
            self.shared.insert(k.clone(), *v);
        }
    }
}

/// Uniform optimizer interface. `lr` comes from the trainer's schedule.
/// (Not `Send`: AOT-graph-backed optimizers hold PJRT executables, which
/// are `Rc`-backed, so the optimizer *object* lives on the driver thread.
/// Rust-native optimizers still fan their per-layer work out across scoped
/// threads internally via [`step_layers_parallel`].)
pub trait Optimizer {
    /// Apply one step: update `params[i]` in place from `grads[i]`.
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32);

    /// Exact persistent state accounting.
    fn memory_report(&self) -> MemoryReport;

    /// Reported optimizer name. Engine-built optimizers derive it from
    /// their policy composition, so it is a borrowed `&str` rather than a
    /// `&'static str`.
    fn name(&self) -> &str;

    /// Figure-1 instrumentation: after a step, the projection error
    /// `‖B_t − O_t‖₂` per low-rank layer (None for dense optimizers).
    fn projection_errors(&self) -> Option<&BTreeMap<String, f64>> {
        None
    }

    /// Bytes a ZeRO owner must broadcast for layer `i` after computing its
    /// update (the paper's communication argument: low-rank `o_t` + indices
    /// vs the full `O_t`).
    fn broadcast_bytes(&self, meta: &LayerMeta) -> u64 {
        (meta.rows * meta.cols * 4) as u64
    }

    /// Serialize every piece of resumable optimizer state (step counter,
    /// typed stores, subspace/rotation/residual auxiliaries) for checkpoint
    /// v2. `None` = this optimizer does not support state checkpointing
    /// (the AOT-wrapped and hand-written momentum baselines).
    fn save_state(&self) -> Option<Vec<u8>> {
        None
    }

    /// Restore state written by [`Optimizer::save_state`]. Implementations
    /// error on fingerprint/dtype/shape mismatch so a resumed run can never
    /// silently continue with the wrong composition.
    fn load_state(&mut self, _bytes: &[u8]) -> Result<()> {
        anyhow::bail!("{} does not support checkpoint resume", self.name())
    }

    /// Drain the subspace-quality gauges captured at subspace refreshes
    /// since the last call: `(layer name, refresh step, gauges)` per
    /// low-rank layer. Empty for optimizers that don't track them (dense /
    /// AOT-wrapped). Polled by the trainer outside the step hot path, so
    /// the returned `Vec` may allocate — the capture itself must not.
    fn refresh_gauges(&mut self) -> Vec<(String, u64, crate::obs::SubspaceQuality)> {
        Vec::new()
    }

    /// Move buffered obs span events into `out` — merged in ascending lane
    /// order (see `obs::RingSet`), so the stream is deterministic for any
    /// thread count — and return how many events were dropped to full rings
    /// since the last drain. Default: no event source.
    fn drain_events(&mut self, _out: &mut Vec<crate::obs::Event>) -> u64 {
        0
    }

    /// Per-layer subspace structure for the compressed gradient-sync path
    /// (`comm=subspace`, `coordinator::compressed`). `None` (the default)
    /// means the optimizer exposes no projectable subspace and the sync
    /// layer must reduce dense gradients.
    fn comm_view(&self) -> Option<&dyn SubspaceCommView> {
        None
    }
}

/// What the subspace-compressed sync layer needs from an optimizer: which
/// layers have a current basis to project through, the refresh lookahead
/// (a step that will recompute the basis must see the true dense-reduced
/// gradient), projection/back-projection through that basis, and the
/// serialized basis for the post-refresh broadcast. All methods take the
/// engine's layer index `i` (same indexing as `params`/`grads`) and operate
/// in the layer's **oriented** frame (see [`LayerMeta::oriented`]).
pub trait SubspaceCommView {
    /// `Some(rank)` when layer `i` takes the low-rank path; `None` for
    /// dense-fallback layers (embed / head / norm).
    fn layer_rank(&self, i: usize) -> Option<usize>;

    /// True when the **next** optimizer step refreshes layer `i`'s basis —
    /// projecting through the stale basis would change what the refresh
    /// sees, so the sync layer falls back to dense reduction for that step.
    fn refresh_pending(&self, i: usize) -> bool;

    /// Project the oriented gradient `g` (R×C) into the current basis,
    /// writing R×r coefficients into `out`.
    fn project_into(&self, i: usize, g: &Matrix, out: &mut Matrix, ws: &mut Workspace);

    /// Map R×r coefficients back through the current basis into R×C `out`.
    fn back_into(&self, i: usize, low: &Matrix, out: &mut Matrix, ws: &mut Workspace);

    /// Serialize layer `i`'s basis in the `Projection::save_state` wire
    /// format — the payload a rank-0 refresh tree-broadcasts.
    fn save_basis(&self, i: usize, out: &mut Vec<u8>);
}

/// Which optimizer to build.
#[derive(Clone, Debug, PartialEq)]
pub enum OptimizerKind {
    AdamW,
    Muon,
    Dion,
    Trion,
    GaLore,
    LdAdamW,
    DctAdamW,
    Frugal,
    Fira,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Option<OptimizerKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "adamw" => OptimizerKind::AdamW,
            "muon" => OptimizerKind::Muon,
            "dion" => OptimizerKind::Dion,
            "trion" => OptimizerKind::Trion,
            "galore" => OptimizerKind::GaLore,
            "ldadamw" | "ldadam" => OptimizerKind::LdAdamW,
            "dct-adamw" | "dct_adamw" | "dctadamw" => OptimizerKind::DctAdamW,
            "frugal" => OptimizerKind::Frugal,
            "fira" => OptimizerKind::Fira,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::AdamW => "adamw",
            OptimizerKind::Muon => "muon",
            OptimizerKind::Dion => "dion",
            OptimizerKind::Trion => "trion",
            OptimizerKind::GaLore => "galore",
            OptimizerKind::LdAdamW => "ldadamw",
            OptimizerKind::DctAdamW => "dct-adamw",
            OptimizerKind::Frugal => "frugal",
            OptimizerKind::Fira => "fira",
        }
    }
}

/// Hyper-parameters shared across the optimizer family. Defaults follow the
/// paper's experimental section.
#[derive(Clone, Debug)]
pub struct OptimizerConfig {
    pub rank: usize,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Trion/Dion/Muon momentum μ.
    pub mu: f32,
    /// Newton–Schulz iterations.
    pub ns_steps: usize,
    /// Subspace refresh interval T_u (1 for LDAdam/Dion/Trion; 200 GaLore).
    pub update_interval: usize,
    /// Projection used by the projection-pluggable optimizers
    /// (FRUGAL / FIRA / GaLore-style).
    pub projection: ProjectionKind,
    /// Error feedback for DCT-AdamW: None | f32 | quantized-u8.
    pub ef_mode: EfMode,
    /// Storage precision of persistent optimizer state (Adam moments, NS
    /// momentum, dense-fallback moments). `F32` is the exact, bit-invisible
    /// default; `Bf16`/`Q8` trade state fidelity for the paper's memory
    /// savings. The EF buffer keeps its own `ef_mode` resolution.
    pub state_dtype: StateDtype,
    /// Record per-layer projection errors each step (Figure 1).
    pub instrument: bool,
    pub seed: u64,
    /// Execution lanes for [`step_layers_parallel`]: `None` shares the
    /// process-global pool (`FFT_SUBSPACE_THREADS` / cores), `Some(n)`
    /// builds a private n-lane pool (tests pin 1 vs N for bit-identity).
    pub threads: Option<usize>,
    /// Engine step execution: compiled shape-batched programs (`fused`,
    /// default) or the per-layer loop (`interpreted`, the differential-
    /// testing oracle). Bit-identical by contract; config key `step-plan`,
    /// env `FFT_SUBSPACE_STEP_PLAN`.
    pub step_plan: crate::optim::engine::StepPlanMode,
    /// Row cap for shape-batched step-plan groups: groups whose stacked
    /// row count would exceed the cap are split (bounds transient stack
    /// memory; bit-identical by the fusion contract). `0` = unlimited.
    /// Config key `max-group-rows`; the env knob
    /// `FFT_SUBSPACE_MAX_GROUP_ROWS` still applies when this is 0.
    pub max_group_rows: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EfMode {
    None,
    F32,
    Q8,
}

impl Default for OptimizerConfig {
    fn default() -> Self {
        OptimizerConfig {
            rank: 32,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            mu: 0.95,
            ns_steps: 5,
            update_interval: 1,
            projection: ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true },
            ef_mode: EfMode::Q8,
            state_dtype: StateDtype::F32,
            instrument: false,
            seed: 0,
            threads: None,
            step_plan: crate::optim::engine::StepPlanMode::from_env(),
            max_group_rows: 0,
        }
    }
}

/// Resolve an optimizer's thread pool (global unless a private lane count
/// was pinned).
pub fn pool_for_threads(threads: Option<usize>) -> Arc<ThreadPool> {
    match threads {
        Some(n) => Arc::new(ThreadPool::new(n)),
        None => crate::parallel::global(),
    }
}

/// Step disjoint layers concurrently: `f(k, i, &mut states[i],
/// &mut params[i], &grads[i], ws)` runs for every layer `i`, with layers
/// partitioned into contiguous chunks across the pool and chunk `k` bound
/// to workspace shard `k` (see `parallel::ShardedWorkspace` for why that
/// binding keeps the zero-allocation invariant). The chunk index is passed
/// through so lane-scoped telemetry sinks (`obs::RingSet`) can bind to the
/// same shard identity.
///
/// **Determinism contract** (property-tested in
/// `tests/parallel_determinism.rs`): `f`'s output for layer `i` must depend
/// only on `(i, states[i], params[i], grads[i])` — never on `k`, which
/// exists only to route side-channel telemetry — and workspace buffers are
/// either zeroed on checkout or fully overwritten before being read, so
/// results are bit-identical for any thread count, including fully
/// sequential execution (a 1-lane pool).
pub fn step_layers_parallel<S: Send, F>(
    pool: &ThreadPool,
    shards: &mut ShardedWorkspace,
    states: &mut [S],
    params: &mut [Matrix],
    grads: &[Matrix],
    f: F,
) where
    F: Fn(usize, usize, &mut S, &mut Matrix, &Matrix, &mut Workspace) + Sync,
{
    let n = states.len();
    assert_eq!(params.len(), n, "step_layers_parallel: params/states mismatch");
    assert_eq!(grads.len(), n, "step_layers_parallel: grads/states mismatch");
    if n == 0 {
        return;
    }
    // Stable layer→chunk→shard partition (the shared `parallel::partition`
    // rule): constant across steps for a given optimizer instance, so every
    // shard sees the same take/give sequence each step and stops allocating
    // after warmup.
    let (per, n_chunks) = crate::parallel::partition(pool.threads().min(shards.len()), n);
    let states_p = SendPtr(states.as_mut_ptr());
    let params_p = SendPtr(params.as_mut_ptr());
    let cells = shards.cells();
    pool.par_chunks(n_chunks, |k| {
        let lo = k * per;
        let hi = (lo + per).min(n);
        // SAFETY: chunk k is claimed by exactly one thread; chunks cover
        // disjoint layer ranges, and shard k is used only by chunk k. All
        // borrows outlive the blocking par_chunks call.
        let ws = unsafe { cells.shard(k) };
        for i in lo..hi {
            let st = unsafe { &mut *states_p.0.add(i) };
            let p = unsafe { &mut *params_p.0.add(i) };
            f(k, i, st, p, &grads[i], ws);
        }
    });
}

/// Build a shared DCT registry covering every oriented column dimension of
/// the model — "one DCT matrix per GPU" (per distinct dimension; the paper's
/// models have a single d_model, ours add d_ff-oriented layers).
pub fn shared_dct_registry(metas: &[LayerMeta]) -> BTreeMap<usize, Arc<SharedDct>> {
    let mut map = BTreeMap::new();
    for m in metas {
        if m.kind.low_rank_eligible() {
            let (_, c) = m.oriented();
            map.entry(c).or_insert_with(|| Arc::new(SharedDct::new(c)));
        }
    }
    map
}

/// Optimizer factory — a thin preset alias over the composable engine.
/// The six low-rank kinds resolve to [`OptimizerSpec::from_kind`] presets
/// (bit-identical to the pre-engine hand-written optimizers, pinned by
/// `tests/engine_equivalence.rs`); the dense/full-momentum kinds stay
/// hand-written. New grid points should use [`OptimizerSpec`] directly
/// rather than growing this enum.
pub fn build_optimizer(
    kind: &OptimizerKind,
    metas: &[LayerMeta],
    cfg: &OptimizerConfig,
) -> Box<dyn Optimizer> {
    if let Some(spec) = crate::optim::OptimizerSpec::from_kind(kind, cfg) {
        return Box::new(spec.build(metas));
    }
    match kind {
        OptimizerKind::AdamW => Box::new(crate::optim::AdamW::new(metas, cfg)),
        OptimizerKind::Muon => Box::new(crate::optim::Muon::new(metas, cfg)),
        OptimizerKind::Dion => Box::new(crate::optim::Dion::new(metas, cfg)),
        // exhaustive on purpose: a new OptimizerKind must either get a
        // from_kind preset or a hand-written arm, at compile time
        OptimizerKind::Trion
        | OptimizerKind::GaLore
        | OptimizerKind::LdAdamW
        | OptimizerKind::DctAdamW
        | OptimizerKind::Frugal
        | OptimizerKind::Fira => {
            unreachable!("{kind:?} resolves via OptimizerSpec::from_kind")
        }
    }
}

/// Dense AdamW state for a single tensor — embedded by every low-rank
/// optimizer for its non-eligible parameters. The moments live in typed
/// [`StateStore`]s; the default f32 stores compute fully in place, so the
/// pre-store behavior (and its bits) are unchanged.
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: StateStore,
    pub v: StateStore,
}

impl AdamState {
    pub fn new(rows: usize, cols: usize) -> Self {
        Self::with_dtype(StateDtype::F32, rows, cols)
    }

    pub fn with_dtype(dtype: StateDtype, rows: usize, cols: usize) -> Self {
        AdamState {
            m: StateStore::zeros(dtype, rows, cols),
            v: StateStore::zeros(dtype, rows, cols),
        }
    }

    /// One decoupled-weight-decay Adam step on `p` (the shared fused
    /// kernel: moment update, bias correction, decay and parameter write in
    /// one pass). F32 stores run fully in place; lower-precision stores
    /// stage through a transient buffer — hot paths with non-f32 state use
    /// [`AdamState::update_ws`] so the staging is pooled.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &mut self,
        p: &mut Matrix,
        g: &Matrix,
        lr: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
        weight_decay: f32,
        step: u64,
    ) {
        assert_eq!(p.shape(), g.shape(), "adam update shape mismatch");
        let sc = AdamScalars::new(beta1, beta2, eps, step);
        match (&mut self.m, &mut self.v) {
            (StateStore::F32(m), StateStore::F32(v)) => adam_fused_update(
                &mut p.data,
                &g.data,
                &mut m.data,
                &mut v.data,
                lr,
                weight_decay,
                &sc,
            ),
            (m_store, v_store) => {
                let mut ws = Workspace::new();
                let mut m = m_store.checkout(&mut ws);
                let mut v = v_store.checkout(&mut ws);
                adam_fused_update(
                    &mut p.data,
                    &g.data,
                    &mut m.data,
                    &mut v.data,
                    lr,
                    weight_decay,
                    &sc,
                );
                m_store.commit(m, &mut ws);
                v_store.commit(v, &mut ws);
            }
        }
    }

    /// [`AdamState::update`] with pooled de/quantization scratch — the
    /// engine's dense-fallback path (allocation-free at steady state for
    /// every dtype).
    #[allow(clippy::too_many_arguments)]
    pub fn update_ws(
        &mut self,
        p: &mut Matrix,
        g: &Matrix,
        lr: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
        weight_decay: f32,
        step: u64,
        ws: &mut Workspace,
    ) {
        assert_eq!(p.shape(), g.shape(), "adam update shape mismatch");
        let sc = AdamScalars::new(beta1, beta2, eps, step);
        let mut m = self.m.checkout(ws);
        let mut v = self.v.checkout(ws);
        adam_fused_update(
            &mut p.data,
            &g.data,
            &mut m.data,
            &mut v.data,
            lr,
            weight_decay,
            &sc,
        );
        self.m.commit(m, ws);
        self.v.commit(v, ws);
    }

    pub fn bytes(&self) -> u64 {
        self.m.bytes() + self.v.bytes()
    }

    /// Checkpoint-v2 serialization of both moment stores (bit-exact).
    pub fn save(&self, out: &mut Vec<u8>) {
        self.m.save(out);
        self.v.save(out);
    }

    /// Twin of [`AdamState::save`].
    pub fn load_from(&mut self, r: &mut ByteReader) -> Result<()> {
        self.m.load_from(r)?;
        self.v.load_from(r)
    }
}

/// Per-step Adam constants, precomputed once per layer step: betas, their
/// complements and the bias corrections `1 − βᵗ`.
#[derive(Clone, Copy, Debug)]
pub struct AdamScalars {
    pub beta1: f32,
    pub beta2: f32,
    pub omb1: f32,
    pub omb2: f32,
    pub bc1: f32,
    pub bc2: f32,
    pub eps: f32,
}

impl AdamScalars {
    pub fn new(beta1: f32, beta2: f32, eps: f32, step: u64) -> Self {
        AdamScalars {
            beta1,
            beta2,
            omb1: 1.0 - beta1,
            omb2: 1.0 - beta2,
            bc1: 1.0 - beta1.powi(step as i32),
            bc2: 1.0 - beta2.powi(step as i32),
            eps,
        }
    }
}

/// The moment-update core shared by both fused kernels, one lane group at
/// a time, with the exact scalar op sequence every optimizer's hand-rolled
/// loop used: `m′ = β₁·m + (1−β₁)·g`, `v′ = β₂·v + ((1−β₂)·g)·g`. Writes
/// the new moments and returns `(m̂, d) = (m′/bc₁, √(v′/bc₂) + ε)` —
/// *not* the combined direction, because the two consumers associate the
/// final ops differently and each must keep its historical rounding:
/// the subspace loops computed `m̂/d`, the dense loop `(lr·m̂)/d`.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn adam_mhat_den_lanes<S: Simd>(
    gv: S::F32,
    m: &mut [f32],
    v: &mut [f32],
    b1: S::F32,
    b2: S::F32,
    omb1: S::F32,
    omb2: S::F32,
    bc1: S::F32,
    bc2: S::F32,
    eps: S::F32,
) -> (S::F32, S::F32) {
    let mk = S::add(S::mul(b1, S::load(m)), S::mul(omb1, gv));
    let vk = S::add(S::mul(b2, S::load(v)), S::mul(S::mul(omb2, gv), gv));
    S::store(m, mk);
    S::store(v, vk);
    (S::div(mk, bc1), S::add(S::sqrt(S::div(vk, bc2)), eps))
}

/// Scalar twin of [`adam_mhat_den_lanes`] for remainder elements —
/// op-for-op the same IEEE sequence, so scalar and vector lanes agree
/// bitwise.
#[inline(always)]
fn adam_mhat_den_scalar(gi: f32, m: &mut f32, v: &mut f32, sc: &AdamScalars) -> (f32, f32) {
    let mk = sc.beta1 * *m + sc.omb1 * gi;
    let vk = sc.beta2 * *v + sc.omb2 * gi * gi;
    *m = mk;
    *v = vk;
    (mk / sc.bc1, (vk / sc.bc2).sqrt() + sc.eps)
}

/// Subspace Adam moments: update `m`/`v` from `g` and write the
/// bias-corrected update direction into `u` — the loop every low-rank
/// optimizer (DctAdamW/LdAdamW/GaLore/FIRA/FRUGAL) previously hand-rolled.
/// One fused pass, SIMD lanes across elements, bit-identical to the old
/// scalar loops for every backend.
#[inline(always)]
fn adam_moments_g<S: Simd>(
    u: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    sc: &AdamScalars,
) {
    let n = u.len();
    debug_assert!(g.len() == n && m.len() == n && v.len() == n);
    let (b1, b2) = (S::splat(sc.beta1), S::splat(sc.beta2));
    let (omb1, omb2) = (S::splat(sc.omb1), S::splat(sc.omb2));
    let (bc1, bc2, eps) = (S::splat(sc.bc1), S::splat(sc.bc2), S::splat(sc.eps));
    let mut k = 0;
    while k + F32_LANES <= n {
        let (mhat, den) = adam_mhat_den_lanes::<S>(
            S::load(&g[k..]),
            &mut m[k..],
            &mut v[k..],
            b1,
            b2,
            omb1,
            omb2,
            bc1,
            bc2,
            eps,
        );
        S::store(&mut u[k..], S::div(mhat, den));
        k += F32_LANES;
    }
    while k < n {
        let (mhat, den) = adam_mhat_den_scalar(g[k], &mut m[k], &mut v[k], sc);
        u[k] = mhat / den;
        k += 1;
    }
}

crate::simd_dispatch! {
    /// See [`adam_moments_g`]; `u`, `g`, `m`, `v` must be equal length.
    pub fn adam_moments_into(
        u: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], sc: &AdamScalars
    ) = adam_moments_g
}

/// Dense fused AdamW: moments + bias correction + decoupled weight decay +
/// parameter update in one pass — `p′ = (1 − lr·wd)·p − (lr·m̂)/d`, with
/// `lr·m̂` multiplied *before* the divide to keep the exact rounding of the
/// scalar loop this kernel replaced (`wd*p − lr*mhat/(√v̂+ε)` parses as
/// `(lr·mhat)/…`).
#[inline(always)]
fn adam_fused_update_g<S: Simd>(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    lr: f32,
    weight_decay: f32,
    sc: &AdamScalars,
) {
    let n = p.len();
    debug_assert!(g.len() == n && m.len() == n && v.len() == n);
    let wd = 1.0 - lr * weight_decay;
    let (b1, b2) = (S::splat(sc.beta1), S::splat(sc.beta2));
    let (omb1, omb2) = (S::splat(sc.omb1), S::splat(sc.omb2));
    let (bc1, bc2, eps) = (S::splat(sc.bc1), S::splat(sc.bc2), S::splat(sc.eps));
    let (wdv, lrv) = (S::splat(wd), S::splat(lr));
    let mut k = 0;
    while k + F32_LANES <= n {
        let (mhat, den) = adam_mhat_den_lanes::<S>(
            S::load(&g[k..]),
            &mut m[k..],
            &mut v[k..],
            b1,
            b2,
            omb1,
            omb2,
            bc1,
            bc2,
            eps,
        );
        let pv = S::sub(S::mul(wdv, S::load(&p[k..])), S::div(S::mul(lrv, mhat), den));
        S::store(&mut p[k..], pv);
        k += F32_LANES;
    }
    while k < n {
        let (mhat, den) = adam_mhat_den_scalar(g[k], &mut m[k], &mut v[k], sc);
        p[k] = wd * p[k] - lr * mhat / den;
        k += 1;
    }
}

crate::simd_dispatch! {
    /// See [`adam_fused_update_g`]; `p`, `g`, `m`, `v` must be equal length.
    pub fn adam_fused_update(
        p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32],
        lr: f32, weight_decay: f32, sc: &AdamScalars
    ) = adam_fused_update_g
}

/// `max(1, sqrt(R/C))` shape factor used by Muon/Dion/Trion updates.
pub fn shape_factor(rows: usize, cols: usize) -> f32 {
    (rows as f32 / cols as f32).sqrt().max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metas() -> Vec<LayerMeta> {
        vec![
            LayerMeta::new("embed", 257, 64, ParamKind::Embed),
            LayerMeta::new("wq", 64, 64, ParamKind::Linear),
            LayerMeta::new("w_gate", 64, 176, ParamKind::Linear), // wide
            LayerMeta::new("w_down", 176, 64, ParamKind::Linear),
            LayerMeta::new("norm", 1, 64, ParamKind::Norm),
        ]
    }

    #[test]
    fn orientation_projects_smaller_dim() {
        for m in metas() {
            let (r, c) = m.oriented();
            if m.kind.low_rank_eligible() {
                assert!(r >= c, "{}: {r}x{c}", m.name);
            }
        }
    }

    #[test]
    fn step_layers_parallel_visits_every_layer_once_any_thread_count() {
        for threads in [1usize, 3, 8] {
            let pool = ThreadPool::new(threads);
            let mut shards = ShardedWorkspace::for_pool(&pool);
            let mut states: Vec<u64> = vec![0; 7];
            let mut params: Vec<Matrix> = (0..7).map(|_| Matrix::zeros(2, 2)).collect();
            let grads: Vec<Matrix> =
                (0..7).map(|i| Matrix::from_fn(2, 2, |_, _| i as f32)).collect();
            step_layers_parallel(
                &pool,
                &mut shards,
                &mut states,
                &mut params,
                &grads,
                |_k, i, st, p, g, ws| {
                    *st += 1;
                    let tmp = ws.take(2, 2);
                    p.axpy(1.0, g);
                    p.axpy(0.0, &tmp);
                    ws.give(tmp);
                    assert_eq!(g.at(0, 0), i as f32);
                },
            );
            assert!(states.iter().all(|&s| s == 1), "threads={threads}");
            for (i, p) in params.iter().enumerate() {
                assert_eq!(p.at(1, 1), i as f32, "threads={threads}");
            }
        }
    }

    #[test]
    fn oriented_grad_helpers_match_manual_orientation() {
        let mut ws = Workspace::new();
        let g_wide = Matrix::from_fn(3, 5, |i, j| (i * 5 + j) as f32);
        let meta_wide = LayerMeta::new("w", 3, 5, ParamKind::Linear);
        let owned = take_oriented_owned(&meta_wide, &g_wide, &mut ws);
        assert_eq!(owned, g_wide.transpose());
        ws.give(owned);
        let og = OrientedGrad::take(&meta_wide, &g_wide, &mut ws);
        assert_eq!(og.matrix(), &g_wide.transpose());
        og.give(&mut ws);

        let g_tall = Matrix::from_fn(5, 3, |i, j| (i * 3 + j) as f32);
        let meta_tall = LayerMeta::new("w", 5, 3, ParamKind::Linear);
        let owned = take_oriented_owned(&meta_tall, &g_tall, &mut ws);
        assert_eq!(owned, g_tall);
        ws.give(owned);
        let og = OrientedGrad::take(&meta_tall, &g_tall, &mut ws);
        // tall layers are read in place, no copy
        assert!(std::ptr::eq(og.matrix(), &g_tall));
        og.give(&mut ws);
    }

    #[test]
    fn orient_deorient_roundtrip() {
        let meta = LayerMeta::new("w_gate", 4, 9, ParamKind::Linear);
        let g = Matrix::from_fn(4, 9, |i, j| (i * 9 + j) as f32);
        let o = orient(&meta, &g);
        assert_eq!(o.shape(), (9, 4));
        let back = deorient(&meta, o);
        assert_eq!(back, g);
    }

    #[test]
    fn dct_registry_one_matrix_per_dim() {
        let reg = shared_dct_registry(&metas());
        assert_eq!(reg.keys().copied().collect::<Vec<_>>(), vec![64]);
    }

    #[test]
    fn memory_report_dedups_shared() {
        let mut r = MemoryReport::default();
        r.add("momentum", 100);
        r.add("momentum", 50);
        r.share("dct", 1000);
        r.share("dct", 1000);
        assert_eq!(r.total(), 1150);
    }

    #[test]
    fn adam_state_matches_scalar_reference() {
        // One parameter, hand-computed step.
        let mut st = AdamState::new(1, 1);
        let mut p = Matrix::from_vec(1, 1, vec![1.0]);
        let g = Matrix::from_vec(1, 1, vec![0.5]);
        st.update(&mut p, &g, 0.1, 0.9, 0.999, 1e-8, 0.0, 1);
        // m=0.05, v=0.00025; mhat=0.5, vhat=0.25; p = 1 - 0.1*0.5/0.5 = 0.9
        assert!((p.data[0] - 0.9).abs() < 1e-5, "{}", p.data[0]);
        assert!((st.m.as_f32().unwrap().data[0] - 0.05).abs() < 1e-7);
    }

    #[test]
    fn adam_state_typed_storage_tracks_f32_closely() {
        // bf16/q8 moments follow the f32 trajectory approximately and
        // report the reduced byte counts exactly
        let mut rng = Pcg64::seed(11);
        let g_seq: Vec<Matrix> = (0..20).map(|_| Matrix::randn(4, 6, 0.5, &mut rng)).collect();
        let mut exact = AdamState::new(4, 6);
        let mut p_exact = Matrix::zeros(4, 6);
        let mut ws = Workspace::new();
        for dtype in [StateDtype::Bf16, StateDtype::Q8] {
            let mut st = AdamState::with_dtype(dtype, 4, 6);
            let mut p = Matrix::zeros(4, 6);
            for g in &g_seq {
                st.update_ws(&mut p, g, 0.01, 0.9, 0.999, 1e-8, 0.0, 1, &mut ws);
            }
            assert!(p.fro_norm() > 0.0);
            match dtype {
                StateDtype::Bf16 => assert_eq!(st.bytes(), 2 * 4 * 6 * 2),
                _ => assert_eq!(st.bytes(), 2 * (4 * 6 + 4)),
            }
        }
        for g in &g_seq {
            exact.update(&mut p_exact, g, 0.01, 0.9, 0.999, 1e-8, 0.0, 1);
        }
        assert_eq!(exact.bytes(), 2 * 4 * 6 * 4);
    }

    #[test]
    fn adam_state_save_load_roundtrip() {
        use crate::util::codec::ByteReader;
        let mut rng = Pcg64::seed(12);
        for dtype in [StateDtype::F32, StateDtype::Bf16, StateDtype::Q8] {
            let mut st = AdamState::with_dtype(dtype, 3, 5);
            let mut p = Matrix::zeros(3, 5);
            let g = Matrix::randn(3, 5, 1.0, &mut rng);
            st.update(&mut p, &g, 0.01, 0.9, 0.999, 1e-8, 0.0, 1);
            let mut blob = Vec::new();
            st.save(&mut blob);
            let mut fresh = AdamState::with_dtype(dtype, 3, 5);
            let mut r = ByteReader::new(&blob);
            fresh.load_from(&mut r).unwrap();
            r.finish().unwrap();
            assert_eq!(
                st.m.to_matrix().data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                fresh.m.to_matrix().data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{dtype:?}"
            );
        }
    }

    #[test]
    fn optimizer_kind_parsing() {
        assert_eq!(OptimizerKind::parse("Trion"), Some(OptimizerKind::Trion));
        assert_eq!(OptimizerKind::parse("dct-adamw"), Some(OptimizerKind::DctAdamW));
        assert_eq!(OptimizerKind::parse("nope"), None);
    }

    #[test]
    fn shape_factor_wide_vs_tall() {
        assert_eq!(shape_factor(64, 64), 1.0);
        assert!((shape_factor(256, 64) - 2.0).abs() < 1e-6);
        assert_eq!(shape_factor(64, 256), 1.0);
    }
}
