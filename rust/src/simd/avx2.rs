//! x86_64 AVX2 backend: `__m256` / `__m256d` lanes.
//!
//! Every method lowers to a single vector instruction per lane group (or an
//! exact bit manipulation), mirroring the scalar backend op-for-op:
//! separate `vmulps`+`vaddps` (never `vfmadd`), correctly-rounded
//! `vsqrtps`/`vdivps`, sign-bit XOR/ANDNOT for conj/abs, and the
//! `vmovddup`/`vpermilpd`/`vaddsubpd` sequence for [`Simd::cmul`], whose
//! even-lane subtract / odd-lane add is exactly `Complex::mul`'s
//! `re − ·` / `im + ·`.
//!
//! Methods are `#[inline(always)]` and contain raw intrinsics; they are
//! only ever monomorphized inside the `#[target_feature(enable = "avx2")]`
//! shims that `simd_dispatch!` generates, which the dispatcher enters only
//! after `is_x86_feature_detected!("avx2")` succeeded.

use std::arch::x86_64::*;

use crate::fft::Complex;

use super::{Simd, F32_LANES, F64_LANES};

/// AVX2 lanes; see module docs.
#[derive(Clone, Copy)]
pub struct Avx2;

impl Simd for Avx2 {
    type F32 = __m256;
    type F64 = __m256d;

    const NAME: &'static str = "avx2";

    // ---- f32 -----------------------------------------------------------

    #[inline(always)]
    fn splat(x: f32) -> Self::F32 {
        unsafe { _mm256_set1_ps(x) }
    }

    #[inline(always)]
    fn load(s: &[f32]) -> Self::F32 {
        let s = &s[..F32_LANES]; // bounds check once, then raw load
        unsafe { _mm256_loadu_ps(s.as_ptr()) }
    }

    #[inline(always)]
    fn store(s: &mut [f32], v: Self::F32) {
        let s = &mut s[..F32_LANES];
        unsafe { _mm256_storeu_ps(s.as_mut_ptr(), v) }
    }

    #[inline(always)]
    fn add(a: Self::F32, b: Self::F32) -> Self::F32 {
        unsafe { _mm256_add_ps(a, b) }
    }

    #[inline(always)]
    fn sub(a: Self::F32, b: Self::F32) -> Self::F32 {
        unsafe { _mm256_sub_ps(a, b) }
    }

    #[inline(always)]
    fn mul(a: Self::F32, b: Self::F32) -> Self::F32 {
        unsafe { _mm256_mul_ps(a, b) }
    }

    #[inline(always)]
    fn div(a: Self::F32, b: Self::F32) -> Self::F32 {
        unsafe { _mm256_div_ps(a, b) }
    }

    #[inline(always)]
    fn sqrt(a: Self::F32) -> Self::F32 {
        unsafe { _mm256_sqrt_ps(a) }
    }

    #[inline(always)]
    fn to_array(v: Self::F32) -> [f32; F32_LANES] {
        let mut out = [0.0f32; F32_LANES];
        unsafe { _mm256_storeu_ps(out.as_mut_ptr(), v) };
        out
    }

    // ---- f64 -----------------------------------------------------------

    #[inline(always)]
    fn splat64(x: f64) -> Self::F64 {
        unsafe { _mm256_set1_pd(x) }
    }

    #[inline(always)]
    fn load64(s: &[f64]) -> Self::F64 {
        let s = &s[..F64_LANES];
        unsafe { _mm256_loadu_pd(s.as_ptr()) }
    }

    #[inline(always)]
    fn store64(s: &mut [f64], v: Self::F64) {
        let s = &mut s[..F64_LANES];
        unsafe { _mm256_storeu_pd(s.as_mut_ptr(), v) }
    }

    #[inline(always)]
    fn add64(a: Self::F64, b: Self::F64) -> Self::F64 {
        unsafe { _mm256_add_pd(a, b) }
    }

    #[inline(always)]
    fn sub64(a: Self::F64, b: Self::F64) -> Self::F64 {
        unsafe { _mm256_sub_pd(a, b) }
    }

    #[inline(always)]
    fn mul64(a: Self::F64, b: Self::F64) -> Self::F64 {
        unsafe { _mm256_mul_pd(a, b) }
    }

    #[inline(always)]
    fn abs64(a: Self::F64) -> Self::F64 {
        // clear the sign bit — exact
        unsafe { _mm256_andnot_pd(_mm256_set1_pd(-0.0), a) }
    }

    #[inline(always)]
    fn widen4(s: &[f32]) -> Self::F64 {
        let s = &s[..F64_LANES];
        // vcvtps2pd — exact f32→f64 conversion
        unsafe { _mm256_cvtps_pd(_mm_loadu_ps(s.as_ptr())) }
    }

    #[inline(always)]
    fn to_array64(v: Self::F64) -> [f64; F64_LANES] {
        let mut out = [0.0f64; F64_LANES];
        unsafe { _mm256_storeu_pd(out.as_mut_ptr(), v) };
        out
    }

    // ---- complex pairs -------------------------------------------------

    #[inline(always)]
    fn loadc(s: &[Complex]) -> Self::F64 {
        let s = &s[..2];
        // Complex is #[repr(C)] { re: f64, im: f64 } — two Complex are four
        // contiguous f64 lanes.
        unsafe { _mm256_loadu_pd(s.as_ptr() as *const f64) }
    }

    #[inline(always)]
    fn storec(s: &mut [Complex], v: Self::F64) {
        let s = &mut s[..2];
        unsafe { _mm256_storeu_pd(s.as_mut_ptr() as *mut f64, v) }
    }

    #[inline(always)]
    fn cmul(a: Self::F64, b: Self::F64) -> Self::F64 {
        unsafe {
            let ar = _mm256_movedup_pd(a); //       [a0.re, a0.re, a1.re, a1.re]
            let ai = _mm256_permute_pd(a, 0b1111); // [a0.im, a0.im, a1.im, a1.im]
            let bs = _mm256_permute_pd(b, 0b0101); // [b0.im, b0.re, b1.im, b1.re]
            let t1 = _mm256_mul_pd(ar, b); //  [re·re, re·im, …]
            let t2 = _mm256_mul_pd(ai, bs); // [im·im, im·re, …]
            // even lanes t1−t2 (= re), odd lanes t1+t2 (= im) — exactly
            // Complex::mul's one-sub/one-add per component
            _mm256_addsub_pd(t1, t2)
        }
    }

    #[inline(always)]
    fn conjc(v: Self::F64) -> Self::F64 {
        // flip the sign bit of the im lanes — exact
        unsafe { _mm256_xor_pd(v, _mm256_set_pd(-0.0, 0.0, -0.0, 0.0)) }
    }

    #[inline(always)]
    fn swap_pairs(v: Self::F64) -> Self::F64 {
        unsafe { _mm256_permute2f128_pd(v, v, 0x01) }
    }

    // ---- u32 -----------------------------------------------------------

    type U32 = __m256i;

    #[inline(always)]
    fn splat_u32(x: u32) -> Self::U32 {
        unsafe { _mm256_set1_epi32(x as i32) }
    }

    #[inline(always)]
    fn f32_bits(v: Self::F32) -> Self::U32 {
        unsafe { _mm256_castps_si256(v) }
    }

    #[inline(always)]
    fn bits_f32(v: Self::U32) -> Self::F32 {
        unsafe { _mm256_castsi256_ps(v) }
    }

    #[inline(always)]
    fn shr16_u32(v: Self::U32) -> Self::U32 {
        unsafe { _mm256_srli_epi32::<16>(v) }
    }

    #[inline(always)]
    fn shl16_u32(v: Self::U32) -> Self::U32 {
        unsafe { _mm256_slli_epi32::<16>(v) }
    }

    #[inline(always)]
    fn and_u32(a: Self::U32, b: Self::U32) -> Self::U32 {
        unsafe { _mm256_and_si256(a, b) }
    }

    #[inline(always)]
    fn or_u32(a: Self::U32, b: Self::U32) -> Self::U32 {
        unsafe { _mm256_or_si256(a, b) }
    }

    #[inline(always)]
    fn add_u32(a: Self::U32, b: Self::U32) -> Self::U32 {
        unsafe { _mm256_add_epi32(a, b) }
    }

    #[inline(always)]
    fn nan_mask_u32(v: Self::F32) -> Self::U32 {
        // unordered self-compare: all-ones exactly on NaN lanes
        unsafe { _mm256_castps_si256(_mm256_cmp_ps::<_CMP_UNORD_Q>(v, v)) }
    }

    #[inline(always)]
    fn select_u32(mask: Self::U32, a: Self::U32, b: Self::U32) -> Self::U32 {
        // per-byte blend is per-lane correct because mask lanes are
        // all-ones / all-zero
        unsafe { _mm256_blendv_epi8(b, a, mask) }
    }

    #[inline(always)]
    fn widen_u16(s: &[u16]) -> Self::U32 {
        let s = &s[..F32_LANES]; // bounds check once, then raw 16-byte load
        unsafe { _mm256_cvtepu16_epi32(_mm_loadu_si128(s.as_ptr() as *const __m128i)) }
    }

    #[inline(always)]
    fn to_array_u32(v: Self::U32) -> [u32; F32_LANES] {
        let mut out = [0u32; F32_LANES];
        unsafe { _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, v) };
        out
    }
}
