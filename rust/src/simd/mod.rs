//! Runtime-dispatched SIMD primitive layer with a bit-exact scalar twin.
//!
//! Every hot elementwise/inner loop in the crate (matmul micro-kernels, the
//! Makhoul split butterfly and twiddle loops, the radix-2/Bluestein complex
//! products, column-norm accumulators, the fused Adam update) is written
//! once against the [`Simd`] trait and monomorphized per backend:
//!
//! * [`Avx2`] — x86_64 AVX2 (8 f32 / 4 f64 lanes), selected at runtime via
//!   `is_x86_feature_detected!("avx2")`,
//! * [`Neon`] — aarch64 NEON (2×4 f32 / 2×2 f64 lanes), always available on
//!   aarch64,
//! * [`Scalar`] — portable arrays-of-lanes fallback for every other target
//!   and for `FFT_SUBSPACE_SIMD=0`.
//!
//! **The bit-identity contract.** All three backends compute *the exact
//! same bits*, enforced by `tests/simd_bit_identity.rs`. Three rules make
//! that possible; every lane op and every kernel written against this
//! module must follow them:
//!
//! 1. **Lane ops are single IEEE-754 operations** (add, sub, mul, div,
//!    sqrt, abs/conj sign flips, exact f32→f64 widening). All of these are
//!    correctly rounded or exact, so a lane computes the same bits as the
//!    equivalent scalar expression. [`Simd::mul_add`] is deliberately a
//!    *separate* multiply then add — never an FMA, whose single rounding
//!    would diverge from the scalar kernel.
//! 2. **Lanes span independent elements** (distinct output columns /
//!    array slots), so vectorizing never regroups any element's
//!    floating-point summation order. Where a kernel does keep per-lane
//!    partial sums (the `matmul_a_bt` dot products), the horizontal
//!    reduction goes through [`reduce_tree8`] — shared plain-f32 code, so
//!    the order is identical for every backend by construction.
//! 3. **Remainder elements run the same op sequence** in plain scalar
//!    code, which per rule 1 is bit-identical to the lane version.
//!
//! Because the per-element summation order is untouched, the PR-2
//! row-partitioned thread-determinism contract survives unchanged: SIMD ×
//! any thread count is still bit-identical to scalar × one thread.
//!
//! **Dispatch.** Kernels are generic `#[inline(always)]` functions
//! (`fn foo_g<S: Simd>(…)`); the [`simd_dispatch!`] macro generates the
//! public entry that selects a backend once per call and, on x86_64, enters
//! through a `#[target_feature(enable = "avx2")]` shim so the whole
//! monomorphized kernel body compiles with AVX2 codegen. Dispatch cost is
//! one relaxed atomic load + an indirect-free match, so kernels dispatch at
//! the call level (one dispatch per matmul block / FFT row / Adam tensor),
//! never per element.
//!
//! **Adding a new lane op**: implement it in all three backends as the same
//! single IEEE operation sequence, extend the `lane_ops_agree_with_scalar`
//! property test below, and document any op-order subtlety at the trait
//! method. **Adding a new kernel**: write `foo_g<S: Simd>`, keep the scalar
//! tail identical op-for-op, wrap with `simd_dispatch!`, and add it to
//! `tests/simd_bit_identity.rs`.
//!
//! **Overrides**: `FFT_SUBSPACE_SIMD=0` (or `scalar`) forces the scalar
//! backend process-wide; [`set_backend_override`] flips backends at runtime
//! for tests and the `bench-simd` on/off sweeps.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use crate::fft::Complex;

mod scalar;
pub use scalar::Scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "x86_64")]
pub use avx2::Avx2;

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "aarch64")]
pub use neon::Neon;

/// f32 lane width shared by every backend (the scalar backend models the
/// same 8 lanes with an array, which is what makes it bit-exact).
pub const F32_LANES: usize = 8;
/// f64 lane width (= [`C64_LANES`] packed complex numbers).
pub const F64_LANES: usize = 4;
/// Complex numbers per f64 vector (interleaved re/im pairs).
pub const C64_LANES: usize = 2;

/// The f32/f64/complex lane operations the kernels are written against.
///
/// Methods must be implemented as **single IEEE-754 operations per lane**
/// (or exact bit manipulations); see the module docs for the bit-identity
/// contract. All methods are `#[inline(always)]` in every backend so the
/// monomorphized kernels inline into their `#[target_feature]` entry shims.
pub trait Simd: Copy + Send + Sync + 'static {
    /// 8 f32 lanes.
    type F32: Copy;
    /// 4 f64 lanes, also used as 2 interleaved complex numbers.
    type F64: Copy;

    const NAME: &'static str;

    // ---- f32 lanes -----------------------------------------------------

    fn splat(x: f32) -> Self::F32;
    /// Load lanes from `s[..8]` (panics if shorter).
    fn load(s: &[f32]) -> Self::F32;
    /// Store lanes to `s[..8]` (panics if shorter).
    fn store(s: &mut [f32], v: Self::F32);
    fn add(a: Self::F32, b: Self::F32) -> Self::F32;
    fn sub(a: Self::F32, b: Self::F32) -> Self::F32;
    fn mul(a: Self::F32, b: Self::F32) -> Self::F32;
    fn div(a: Self::F32, b: Self::F32) -> Self::F32;
    /// Correctly-rounded per-lane square root (`vsqrtps` / `fsqrt` — exact
    /// per IEEE-754, so it matches `f32::sqrt` bitwise).
    fn sqrt(a: Self::F32) -> Self::F32;
    fn to_array(v: Self::F32) -> [f32; F32_LANES];

    /// `acc + a·b` as a **separate multiply then add** — two roundings,
    /// exactly like the scalar kernels. Never implement this with an FMA:
    /// the fused single rounding would break the scalar↔SIMD bit identity.
    #[inline(always)]
    fn mul_add(acc: Self::F32, a: Self::F32, b: Self::F32) -> Self::F32 {
        Self::add(acc, Self::mul(a, b))
    }

    // ---- f64 lanes -----------------------------------------------------

    fn splat64(x: f64) -> Self::F64;
    /// Load lanes from `s[..4]` (panics if shorter).
    fn load64(s: &[f64]) -> Self::F64;
    fn store64(s: &mut [f64], v: Self::F64);
    fn add64(a: Self::F64, b: Self::F64) -> Self::F64;
    fn sub64(a: Self::F64, b: Self::F64) -> Self::F64;
    fn mul64(a: Self::F64, b: Self::F64) -> Self::F64;
    /// Per-lane |x| (sign-bit clear — exact).
    fn abs64(a: Self::F64) -> Self::F64;
    /// Widen `s[..4]` f32s to 4 f64 lanes (exact conversion).
    fn widen4(s: &[f32]) -> Self::F64;
    fn to_array64(v: Self::F64) -> [f64; F64_LANES];

    // ---- complex pairs (2 × interleaved re,im in an F64) ---------------

    /// Load 2 complex numbers from `s[..2]`.
    fn loadc(s: &[Complex]) -> Self::F64;
    fn storec(s: &mut [Complex], v: Self::F64);
    /// Duplicate one complex number into both pairs.
    #[inline(always)]
    fn splatc(c: Complex) -> Self::F64 {
        Self::loadc(&[c, c])
    }
    /// Per-pair complex multiply with the exact op sequence of
    /// `Complex::mul`: `re = a.re·b.re − a.im·b.im`,
    /// `im = a.re·b.im + a.im·b.re` (two products, one sub/one add each —
    /// no FMA, no reassociation).
    fn cmul(a: Self::F64, b: Self::F64) -> Self::F64;
    /// Per-pair conjugate (flip the im sign bits — exact).
    fn conjc(v: Self::F64) -> Self::F64;
    /// Swap the two complex pairs: `[c0, c1] → [c1, c0]` (for reversed
    /// traversals like the Makhoul conjugate-symmetry half).
    fn swap_pairs(v: Self::F64) -> Self::F64;

    // ---- u32 lanes (8, mirroring the f32 lanes) ------------------------
    //
    // Bit-manipulation surface for the typed-storage pack/unpack kernels
    // (`tensor::store`): every op is an exact integer/bit operation, so the
    // bit-identity contract holds trivially — there is no rounding anywhere
    // in this group.

    /// 8 u32 lanes, the bit-pattern view of [`Simd::F32`].
    type U32: Copy;

    fn splat_u32(x: u32) -> Self::U32;
    /// Reinterpret f32 lanes as their raw IEEE-754 bit patterns (exact).
    fn f32_bits(v: Self::F32) -> Self::U32;
    /// Inverse of [`Simd::f32_bits`] (exact).
    fn bits_f32(v: Self::U32) -> Self::F32;
    /// Per-lane logical shift right by 16.
    fn shr16_u32(v: Self::U32) -> Self::U32;
    /// Per-lane shift left by 16.
    fn shl16_u32(v: Self::U32) -> Self::U32;
    fn and_u32(a: Self::U32, b: Self::U32) -> Self::U32;
    fn or_u32(a: Self::U32, b: Self::U32) -> Self::U32;
    /// Per-lane wrapping add.
    fn add_u32(a: Self::U32, b: Self::U32) -> Self::U32;
    /// All-ones lanes where the f32 lane is NaN, all-zero elsewhere
    /// (unordered self-compare).
    fn nan_mask_u32(v: Self::F32) -> Self::U32;
    /// Per-lane `mask ? a : b`; mask lanes must be all-ones or all-zero.
    fn select_u32(mask: Self::U32, a: Self::U32, b: Self::U32) -> Self::U32;
    /// Widen `s[..8]` u16 values to 8 u32 lanes (panics if shorter).
    fn widen_u16(s: &[u16]) -> Self::U32;
    fn to_array_u32(v: Self::U32) -> [u32; F32_LANES];
}

/// Fixed-order horizontal sum of 8 lanes: `((l0+l1)+(l2+l3)) +
/// ((l4+l5)+(l6+l7))`. Plain f32 code shared by every backend (kernels call
/// it on [`Simd::to_array`] output), so partial-sum reductions are
/// bit-identical across backends by construction.
#[inline(always)]
pub fn reduce_tree8(a: [f32; F32_LANES]) -> f32 {
    ((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]))
}

// ---- runtime backend selection -----------------------------------------

/// Which lane implementation [`backend`] resolved to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => Scalar::NAME,
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => Avx2::NAME,
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => Neon::NAME,
        }
    }
}

const OVERRIDE_NONE: u8 = u8::MAX;
const OVERRIDE_SCALAR: u8 = 0;
#[cfg(target_arch = "x86_64")]
const OVERRIDE_AVX2: u8 = 1;
#[cfg(target_arch = "aarch64")]
const OVERRIDE_NEON: u8 = 2;

static OVERRIDE: AtomicU8 = AtomicU8::new(OVERRIDE_NONE);

/// Best instruction set this CPU offers a backend for.
#[allow(unreachable_code)]
fn detect_native() -> Backend {
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is part of the aarch64 baseline.
        return Backend::Neon;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Backend::Avx2;
        }
    }
    Backend::Scalar
}

/// Detected-once backend: `FFT_SUBSPACE_SIMD=0|scalar` forces scalar,
/// otherwise the best instruction set the CPU reports.
fn detected() -> Backend {
    static DETECTED: OnceLock<Backend> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        if let Ok(v) = std::env::var("FFT_SUBSPACE_SIMD") {
            let v = v.trim().to_ascii_lowercase();
            if v == "0" || v == "scalar" || v == "off" {
                return Backend::Scalar;
            }
        }
        detect_native()
    })
}

/// The backend every `simd_dispatch!`-generated entry point uses for this
/// call: the test/bench override if set, else the process-wide detection.
#[inline]
pub fn backend() -> Backend {
    match OVERRIDE.load(Ordering::Relaxed) {
        OVERRIDE_NONE => detected(),
        #[cfg(target_arch = "x86_64")]
        OVERRIDE_AVX2 => Backend::Avx2,
        #[cfg(target_arch = "aarch64")]
        OVERRIDE_NEON => Backend::Neon,
        _ => Backend::Scalar,
    }
}

/// Force a backend process-wide (tests and the `simd` bench sweeps;
/// production code never calls this). `None` restores auto-detection.
/// Panics if the requested backend is not supported by this CPU — the
/// override can therefore never make a dispatched kernel execute illegal
/// instructions.
pub fn set_backend_override(b: Option<Backend>) {
    let code = match b {
        None => OVERRIDE_NONE,
        Some(Backend::Scalar) => OVERRIDE_SCALAR,
        #[cfg(target_arch = "x86_64")]
        Some(Backend::Avx2) => {
            assert!(
                std::arch::is_x86_feature_detected!("avx2"),
                "AVX2 override requested but the CPU lacks AVX2"
            );
            OVERRIDE_AVX2
        }
        #[cfg(target_arch = "aarch64")]
        Some(Backend::Neon) => OVERRIDE_NEON,
    };
    OVERRIDE.store(code, Ordering::Relaxed);
}

/// Generate the runtime-dispatched entry point for a generic SIMD kernel.
///
/// ```ignore
/// #[inline(always)]
/// fn saxpy_g<S: Simd>(a: f32, x: &[f32], y: &mut [f32]) { … }
/// crate::simd_dispatch! {
///     /// `y += a·x`, vectorized.
///     pub fn saxpy(a: f32, x: &[f32], y: &mut [f32]) = saxpy_g
/// }
/// ```
///
/// The generated function matches on [`backend`] once and calls the
/// monomorphized kernel; the AVX2 arm routes through a nested
/// `#[target_feature(enable = "avx2")]` shim so the whole kernel body —
/// every `#[inline(always)]` lane op — compiles with AVX2 enabled. The
/// shim call is sound because [`backend`] only ever returns `Avx2` after a
/// positive `is_x86_feature_detected!` (enforced again by
/// [`set_backend_override`]).
#[macro_export]
macro_rules! simd_dispatch {
    ($(#[$meta:meta])* $vis:vis fn $name:ident(
        $($arg:ident: $ty:ty),* $(,)?
    ) $(-> $ret:ty)? = $impl_fn:ident) => {
        $(#[$meta])*
        $vis fn $name($($arg: $ty),*) $(-> $ret)? {
            match $crate::simd::backend() {
                #[cfg(target_arch = "x86_64")]
                $crate::simd::Backend::Avx2 => {
                    #[target_feature(enable = "avx2")]
                    unsafe fn avx2_shim($($arg: $ty),*) $(-> $ret)? {
                        $impl_fn::<$crate::simd::Avx2>($($arg),*)
                    }
                    // SAFETY: Backend::Avx2 is only returned on CPUs where
                    // AVX2 detection succeeded (see `simd::backend`).
                    unsafe { avx2_shim($($arg),*) }
                }
                #[cfg(target_arch = "aarch64")]
                $crate::simd::Backend::Neon => {
                    $impl_fn::<$crate::simd::Neon>($($arg),*)
                }
                _ => $impl_fn::<$crate::simd::Scalar>($($arg),*),
            }
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg64};

    /// Tests that touch the process-global override serialize on this lock
    /// (the rest of the suite is override-agnostic: every backend computes
    /// the same bits, so a concurrent flip is invisible to it).
    static OVERRIDE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    /// Run `f::<S>()` for every backend this build supports and assert the
    /// results are bit-identical to the scalar backend's.
    fn check_all_backends<R: PartialEq + std::fmt::Debug>(f: impl Fn(Backend) -> R) {
        let want = f(Backend::Scalar);
        // scalar is deterministic (also keeps `want` used on targets with
        // no vector backend)
        assert_eq!(f(Backend::Scalar), want, "scalar not deterministic");
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            assert_eq!(f(Backend::Avx2), want, "avx2 != scalar");
        }
        #[cfg(target_arch = "aarch64")]
        assert_eq!(f(Backend::Neon), want, "neon != scalar");
    }

    /// The whole f32 surface as one dispatched kernel so the test exercises
    /// the real dispatch path (shims included), not just the trait impls.
    #[inline(always)]
    fn f32_ops_g<S: Simd>(a: &[f32], b: &[f32], out: &mut [f32]) {
        let (va, vb) = (S::load(a), S::load(b));
        let mut r = S::mul_add(S::div(va, vb), va, vb); // a/b + a·b
        r = S::sub(S::add(r, S::splat(0.5)), vb);
        r = S::sqrt(S::mul(r, r));
        S::store(out, r);
        out[0] = reduce_tree8(S::to_array(r));
    }
    crate::simd_dispatch! {
        fn f32_ops(a: &[f32], b: &[f32], out: &mut [f32]) = f32_ops_g
    }

    #[inline(always)]
    fn f64_ops_g<S: Simd>(x: &[f32], y: &[f64], out: &mut [f64]) {
        let w = S::widen4(x);
        let v = S::load64(y);
        let r = S::add64(S::mul64(w, w), S::abs64(S::sub64(v, S::splat64(0.25))));
        S::store64(out, r);
    }
    crate::simd_dispatch! {
        fn f64_ops(x: &[f32], y: &[f64], out: &mut [f64]) = f64_ops_g
    }

    #[inline(always)]
    fn complex_ops_g<S: Simd>(a: &[Complex], b: &[Complex], out: &mut [Complex]) {
        let (va, vb) = (S::loadc(a), S::loadc(b));
        let m = S::cmul(va, vb);
        let r = S::add64(S::conjc(m), S::swap_pairs(S::cmul(vb, S::splatc(a[1]))));
        S::storec(out, r);
    }
    crate::simd_dispatch! {
        fn complex_ops(a: &[Complex], b: &[Complex], out: &mut [Complex]) = complex_ops_g
    }

    /// The whole u32 surface as one dispatched kernel: widen u16 → bit
    /// games mirroring the bf16 pack (shift/and/or/add/select on the NaN
    /// mask) → reinterpret back to f32.
    #[inline(always)]
    fn u32_ops_g<S: Simd>(f: &[f32], h: &[u16], out: &mut [u32]) {
        let v = S::load(f);
        let bits = S::f32_bits(v);
        let hi = S::shr16_u32(bits);
        let lsb = S::and_u32(hi, S::splat_u32(1));
        let rne = S::shr16_u32(S::add_u32(bits, S::add_u32(lsb, S::splat_u32(0x7FFF))));
        let sel = S::select_u32(S::nan_mask_u32(v), S::or_u32(hi, S::splat_u32(0x40)), rne);
        let w = S::add_u32(S::widen_u16(h), sel);
        let arr = S::to_array_u32(S::f32_bits(S::bits_f32(S::shl16_u32(w))));
        out[..F32_LANES].copy_from_slice(&arr);
    }
    crate::simd_dispatch! {
        fn u32_ops(f: &[f32], h: &[u16], out: &mut [u32]) = u32_ops_g
    }

    #[test]
    fn lane_ops_agree_with_scalar() {
        let _guard = OVERRIDE_LOCK.lock().unwrap();
        proptest::check("simd-lane-ops", 32, |rng| {
            let a: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..8).map(|_| rng.normal_f32() + 2.5).collect();
            let y: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
            let ca: Vec<Complex> =
                (0..2).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
            let cb: Vec<Complex> =
                (0..2).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
            let mut f: Vec<f32> = (0..8).map(|_| rng.normal_f32() * 1e3).collect();
            f[rng.usize_below(8)] = f32::NAN; // exercise the NaN-mask select
            let h: Vec<u16> = (0..8).map(|_| rng.next_u64() as u16).collect();
            check_all_backends(|be| {
                set_backend_override(Some(be));
                let mut o32 = vec![0.0f32; 8];
                f32_ops(&a, &b, &mut o32);
                let mut o64 = vec![0.0f64; 4];
                f64_ops(&a, &y, &mut o64);
                let mut oc = vec![Complex::ZERO; 2];
                complex_ops(&ca, &cb, &mut oc);
                let mut ou = vec![0u32; 8];
                u32_ops(&f, &h, &mut ou);
                set_backend_override(None);
                (
                    o32.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    o64.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    oc.iter()
                        .map(|c| (c.re.to_bits(), c.im.to_bits()))
                        .collect::<Vec<_>>(),
                    ou,
                )
            });
        });
    }

    #[test]
    fn cmul_matches_complex_mul_exactly() {
        let mut rng = Pcg64::seed(5);
        for _ in 0..200 {
            let a = [
                Complex::new(rng.normal(), rng.normal()),
                Complex::new(rng.normal(), rng.normal()),
            ];
            let b = [
                Complex::new(rng.normal(), rng.normal()),
                Complex::new(rng.normal(), rng.normal()),
            ];
            // the scalar backend *defines* the contract as Complex::mul
            let got = Scalar::to_array64(Scalar::cmul(Scalar::loadc(&a), Scalar::loadc(&b)));
            for p in 0..2 {
                let want = a[p].mul(b[p]);
                assert_eq!(got[2 * p].to_bits(), want.re.to_bits());
                assert_eq!(got[2 * p + 1].to_bits(), want.im.to_bits());
            }
        }
    }

    #[test]
    fn reduce_tree8_is_fixed_order() {
        let a = [1e8f32, -1e8, 3.5, 0.25, -7.125, 2.0, 1e-3, -1e-3];
        // hand-evaluate the documented tree
        let want = ((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]));
        assert_eq!(reduce_tree8(a).to_bits(), want.to_bits());
    }

    #[test]
    fn override_roundtrip_and_names() {
        let _guard = OVERRIDE_LOCK.lock().unwrap();
        let auto = backend();
        set_backend_override(Some(Backend::Scalar));
        assert_eq!(backend(), Backend::Scalar);
        assert_eq!(backend().name(), "scalar");
        set_backend_override(None);
        assert_eq!(backend(), auto);
    }
}
