//! Portable scalar backend: 8/4 lanes modeled as plain arrays.
//!
//! This is the **reference semantics** for the whole [`Simd`](super::Simd)
//! trait — every per-lane expression here is the exact IEEE-754 op sequence
//! the other backends must reproduce, and it is what runs under
//! `FFT_SUBSPACE_SIMD=0` or on targets without a vector backend. The
//! per-lane loops are simple enough that LLVM usually autovectorizes them
//! anyway; the explicit backends exist so the hot kernels don't depend on
//! the autovectorizer's mood.

use crate::fft::Complex;

use super::{Simd, F32_LANES, F64_LANES};

/// Arrays-of-lanes fallback; see module docs.
#[derive(Clone, Copy)]
pub struct Scalar;

#[inline(always)]
fn map2_32(
    a: [f32; F32_LANES],
    b: [f32; F32_LANES],
    f: impl Fn(f32, f32) -> f32,
) -> [f32; F32_LANES] {
    let mut out = [0.0f32; F32_LANES];
    for l in 0..F32_LANES {
        out[l] = f(a[l], b[l]);
    }
    out
}

#[inline(always)]
fn map2_64(
    a: [f64; F64_LANES],
    b: [f64; F64_LANES],
    f: impl Fn(f64, f64) -> f64,
) -> [f64; F64_LANES] {
    let mut out = [0.0f64; F64_LANES];
    for l in 0..F64_LANES {
        out[l] = f(a[l], b[l]);
    }
    out
}

impl Simd for Scalar {
    type F32 = [f32; F32_LANES];
    type F64 = [f64; F64_LANES];

    const NAME: &'static str = "scalar";

    // ---- f32 -----------------------------------------------------------

    #[inline(always)]
    fn splat(x: f32) -> Self::F32 {
        [x; F32_LANES]
    }

    #[inline(always)]
    fn load(s: &[f32]) -> Self::F32 {
        s[..F32_LANES].try_into().unwrap()
    }

    #[inline(always)]
    fn store(s: &mut [f32], v: Self::F32) {
        s[..F32_LANES].copy_from_slice(&v);
    }

    #[inline(always)]
    fn add(a: Self::F32, b: Self::F32) -> Self::F32 {
        map2_32(a, b, |x, y| x + y)
    }

    #[inline(always)]
    fn sub(a: Self::F32, b: Self::F32) -> Self::F32 {
        map2_32(a, b, |x, y| x - y)
    }

    #[inline(always)]
    fn mul(a: Self::F32, b: Self::F32) -> Self::F32 {
        map2_32(a, b, |x, y| x * y)
    }

    #[inline(always)]
    fn div(a: Self::F32, b: Self::F32) -> Self::F32 {
        map2_32(a, b, |x, y| x / y)
    }

    #[inline(always)]
    fn sqrt(a: Self::F32) -> Self::F32 {
        let mut out = a;
        for v in &mut out {
            *v = v.sqrt();
        }
        out
    }

    #[inline(always)]
    fn to_array(v: Self::F32) -> [f32; F32_LANES] {
        v
    }

    // ---- f64 -----------------------------------------------------------

    #[inline(always)]
    fn splat64(x: f64) -> Self::F64 {
        [x; F64_LANES]
    }

    #[inline(always)]
    fn load64(s: &[f64]) -> Self::F64 {
        s[..F64_LANES].try_into().unwrap()
    }

    #[inline(always)]
    fn store64(s: &mut [f64], v: Self::F64) {
        s[..F64_LANES].copy_from_slice(&v);
    }

    #[inline(always)]
    fn add64(a: Self::F64, b: Self::F64) -> Self::F64 {
        map2_64(a, b, |x, y| x + y)
    }

    #[inline(always)]
    fn sub64(a: Self::F64, b: Self::F64) -> Self::F64 {
        map2_64(a, b, |x, y| x - y)
    }

    #[inline(always)]
    fn mul64(a: Self::F64, b: Self::F64) -> Self::F64 {
        map2_64(a, b, |x, y| x * y)
    }

    #[inline(always)]
    fn abs64(a: Self::F64) -> Self::F64 {
        let mut out = a;
        for v in &mut out {
            *v = v.abs();
        }
        out
    }

    #[inline(always)]
    fn widen4(s: &[f32]) -> Self::F64 {
        let s: [f32; F64_LANES] = s[..F64_LANES].try_into().unwrap();
        [s[0] as f64, s[1] as f64, s[2] as f64, s[3] as f64]
    }

    #[inline(always)]
    fn to_array64(v: Self::F64) -> [f64; F64_LANES] {
        v
    }

    // ---- complex pairs -------------------------------------------------

    #[inline(always)]
    fn loadc(s: &[Complex]) -> Self::F64 {
        let s = &s[..2];
        [s[0].re, s[0].im, s[1].re, s[1].im]
    }

    #[inline(always)]
    fn storec(s: &mut [Complex], v: Self::F64) {
        let s = &mut s[..2];
        s[0] = Complex::new(v[0], v[1]);
        s[1] = Complex::new(v[2], v[3]);
    }

    #[inline(always)]
    fn cmul(a: Self::F64, b: Self::F64) -> Self::F64 {
        // Exactly Complex::mul per pair: two products, one sub / one add.
        [
            a[0] * b[0] - a[1] * b[1],
            a[0] * b[1] + a[1] * b[0],
            a[2] * b[2] - a[3] * b[3],
            a[2] * b[3] + a[3] * b[2],
        ]
    }

    #[inline(always)]
    fn conjc(v: Self::F64) -> Self::F64 {
        [v[0], -v[1], v[2], -v[3]]
    }

    #[inline(always)]
    fn swap_pairs(v: Self::F64) -> Self::F64 {
        [v[2], v[3], v[0], v[1]]
    }

    // ---- u32 -----------------------------------------------------------

    type U32 = [u32; F32_LANES];

    #[inline(always)]
    fn splat_u32(x: u32) -> Self::U32 {
        [x; F32_LANES]
    }

    #[inline(always)]
    fn f32_bits(v: Self::F32) -> Self::U32 {
        let mut out = [0u32; F32_LANES];
        for l in 0..F32_LANES {
            out[l] = v[l].to_bits();
        }
        out
    }

    #[inline(always)]
    fn bits_f32(v: Self::U32) -> Self::F32 {
        let mut out = [0.0f32; F32_LANES];
        for l in 0..F32_LANES {
            out[l] = f32::from_bits(v[l]);
        }
        out
    }

    #[inline(always)]
    fn shr16_u32(v: Self::U32) -> Self::U32 {
        let mut out = v;
        for x in &mut out {
            *x >>= 16;
        }
        out
    }

    #[inline(always)]
    fn shl16_u32(v: Self::U32) -> Self::U32 {
        let mut out = v;
        for x in &mut out {
            *x <<= 16;
        }
        out
    }

    #[inline(always)]
    fn and_u32(a: Self::U32, b: Self::U32) -> Self::U32 {
        let mut out = [0u32; F32_LANES];
        for l in 0..F32_LANES {
            out[l] = a[l] & b[l];
        }
        out
    }

    #[inline(always)]
    fn or_u32(a: Self::U32, b: Self::U32) -> Self::U32 {
        let mut out = [0u32; F32_LANES];
        for l in 0..F32_LANES {
            out[l] = a[l] | b[l];
        }
        out
    }

    #[inline(always)]
    fn add_u32(a: Self::U32, b: Self::U32) -> Self::U32 {
        let mut out = [0u32; F32_LANES];
        for l in 0..F32_LANES {
            out[l] = a[l].wrapping_add(b[l]);
        }
        out
    }

    #[inline(always)]
    fn nan_mask_u32(v: Self::F32) -> Self::U32 {
        let mut out = [0u32; F32_LANES];
        for l in 0..F32_LANES {
            out[l] = if v[l].is_nan() { u32::MAX } else { 0 };
        }
        out
    }

    #[inline(always)]
    fn select_u32(mask: Self::U32, a: Self::U32, b: Self::U32) -> Self::U32 {
        let mut out = [0u32; F32_LANES];
        for l in 0..F32_LANES {
            out[l] = (mask[l] & a[l]) | (!mask[l] & b[l]);
        }
        out
    }

    #[inline(always)]
    fn widen_u16(s: &[u16]) -> Self::U32 {
        let s = &s[..F32_LANES];
        let mut out = [0u32; F32_LANES];
        for l in 0..F32_LANES {
            out[l] = s[l] as u32;
        }
        out
    }

    #[inline(always)]
    fn to_array_u32(v: Self::U32) -> [u32; F32_LANES] {
        v
    }
}
