//! aarch64 NEON backend: paired 128-bit vectors model the shared 8×f32 /
//! 4×f64 lane shape (`[float32x4_t; 2]` / `[float64x2_t; 2]`).
//!
//! NEON is part of the aarch64 baseline, so no runtime detection is needed
//! — only the `FFT_SUBSPACE_SIMD=0` escape hatch applies. Per-lane op
//! sequences mirror the scalar backend exactly: separate `fmul`+`fadd`
//! (never `fmla`), correctly-rounded `fsqrt`/`fdiv`, sign-bit XOR for
//! conj, and a `dup`/`ext`/`fsub`+`fadd` lane blend for [`Simd::cmul`] —
//! a **true subtraction** on the re lane, not `t1 + (−t2)`, because the
//! two differ bitwise when `t2` is NaN (sign/payload propagation) and the
//! bit-identity contract has no finite-input carve-out.

use std::arch::aarch64::*;

use crate::fft::Complex;

use super::{Simd, F32_LANES, F64_LANES};

/// NEON lanes; see module docs.
#[derive(Clone, Copy)]
pub struct Neon;

/// Sign mask flipping lane 1 only (im component) — for `conjc` (IEEE
/// negate is a pure sign-bit flip for every value, NaN included, so XOR is
/// bit-identical to the scalar `-im`).
#[inline(always)]
unsafe fn negate_lane1(v: float64x2_t) -> float64x2_t {
    let mask: uint64x2_t = vsetq_lane_u64(0x8000_0000_0000_0000, vdupq_n_u64(0), 1);
    vreinterpretq_f64_u64(veorq_u64(vreinterpretq_u64_f64(v), mask))
}

/// One complex product `a·b` on a single (re, im) vector — the exact op
/// sequence of `Complex::mul` (see `Simd::cmul`).
#[inline(always)]
unsafe fn cmul_one(a: float64x2_t, b: float64x2_t) -> float64x2_t {
    let ar = vdupq_laneq_f64(a, 0); // [a.re, a.re]
    let ai = vdupq_laneq_f64(a, 1); // [a.im, a.im]
    let bs = vextq_f64(b, b, 1); //    [b.im, b.re]
    let t1 = vmulq_f64(ar, b); //  [re·re, re·im]
    let t2 = vmulq_f64(ai, bs); // [im·im, im·re]
    // True per-lane fsub on the re lane / fadd on the im lane, blended.
    // (Not `t1 + (−t2)`: that diverges from the scalar `t1 − t2` bitwise
    // when t2 is NaN — sign/payload propagation.)
    let d = vsubq_f64(t1, t2); // re lane correct
    let s = vaddq_f64(t1, t2); // im lane correct
    vsetq_lane_f64(vgetq_lane_f64(s, 1), d, 1)
}

impl Simd for Neon {
    type F32 = [float32x4_t; 2];
    type F64 = [float64x2_t; 2];

    const NAME: &'static str = "neon";

    // ---- f32 -----------------------------------------------------------

    #[inline(always)]
    fn splat(x: f32) -> Self::F32 {
        unsafe { [vdupq_n_f32(x), vdupq_n_f32(x)] }
    }

    #[inline(always)]
    fn load(s: &[f32]) -> Self::F32 {
        let s = &s[..F32_LANES]; // bounds check once, then raw loads
        unsafe { [vld1q_f32(s.as_ptr()), vld1q_f32(s.as_ptr().add(4))] }
    }

    #[inline(always)]
    fn store(s: &mut [f32], v: Self::F32) {
        let s = &mut s[..F32_LANES];
        unsafe {
            vst1q_f32(s.as_mut_ptr(), v[0]);
            vst1q_f32(s.as_mut_ptr().add(4), v[1]);
        }
    }

    #[inline(always)]
    fn add(a: Self::F32, b: Self::F32) -> Self::F32 {
        unsafe { [vaddq_f32(a[0], b[0]), vaddq_f32(a[1], b[1])] }
    }

    #[inline(always)]
    fn sub(a: Self::F32, b: Self::F32) -> Self::F32 {
        unsafe { [vsubq_f32(a[0], b[0]), vsubq_f32(a[1], b[1])] }
    }

    #[inline(always)]
    fn mul(a: Self::F32, b: Self::F32) -> Self::F32 {
        unsafe { [vmulq_f32(a[0], b[0]), vmulq_f32(a[1], b[1])] }
    }

    #[inline(always)]
    fn div(a: Self::F32, b: Self::F32) -> Self::F32 {
        unsafe { [vdivq_f32(a[0], b[0]), vdivq_f32(a[1], b[1])] }
    }

    #[inline(always)]
    fn sqrt(a: Self::F32) -> Self::F32 {
        unsafe { [vsqrtq_f32(a[0]), vsqrtq_f32(a[1])] }
    }

    #[inline(always)]
    fn to_array(v: Self::F32) -> [f32; F32_LANES] {
        let mut out = [0.0f32; F32_LANES];
        Self::store(&mut out, v);
        out
    }

    // ---- f64 -----------------------------------------------------------

    #[inline(always)]
    fn splat64(x: f64) -> Self::F64 {
        unsafe { [vdupq_n_f64(x), vdupq_n_f64(x)] }
    }

    #[inline(always)]
    fn load64(s: &[f64]) -> Self::F64 {
        let s = &s[..F64_LANES];
        unsafe { [vld1q_f64(s.as_ptr()), vld1q_f64(s.as_ptr().add(2))] }
    }

    #[inline(always)]
    fn store64(s: &mut [f64], v: Self::F64) {
        let s = &mut s[..F64_LANES];
        unsafe {
            vst1q_f64(s.as_mut_ptr(), v[0]);
            vst1q_f64(s.as_mut_ptr().add(2), v[1]);
        }
    }

    #[inline(always)]
    fn add64(a: Self::F64, b: Self::F64) -> Self::F64 {
        unsafe { [vaddq_f64(a[0], b[0]), vaddq_f64(a[1], b[1])] }
    }

    #[inline(always)]
    fn sub64(a: Self::F64, b: Self::F64) -> Self::F64 {
        unsafe { [vsubq_f64(a[0], b[0]), vsubq_f64(a[1], b[1])] }
    }

    #[inline(always)]
    fn mul64(a: Self::F64, b: Self::F64) -> Self::F64 {
        unsafe { [vmulq_f64(a[0], b[0]), vmulq_f64(a[1], b[1])] }
    }

    #[inline(always)]
    fn abs64(a: Self::F64) -> Self::F64 {
        unsafe { [vabsq_f64(a[0]), vabsq_f64(a[1])] }
    }

    #[inline(always)]
    fn widen4(s: &[f32]) -> Self::F64 {
        let s = &s[..F64_LANES];
        unsafe {
            let v = vld1q_f32(s.as_ptr());
            // fcvtl/fcvtl2 — exact f32→f64 conversion
            [vcvt_f64_f32(vget_low_f32(v)), vcvt_high_f64_f32(v)]
        }
    }

    #[inline(always)]
    fn to_array64(v: Self::F64) -> [f64; F64_LANES] {
        let mut out = [0.0f64; F64_LANES];
        Self::store64(&mut out, v);
        out
    }

    // ---- complex pairs -------------------------------------------------

    #[inline(always)]
    fn loadc(s: &[Complex]) -> Self::F64 {
        let s = &s[..2];
        // Complex is #[repr(C)] { re: f64, im: f64 }
        let p = s.as_ptr() as *const f64;
        unsafe { [vld1q_f64(p), vld1q_f64(p.add(2))] }
    }

    #[inline(always)]
    fn storec(s: &mut [Complex], v: Self::F64) {
        let s = &mut s[..2];
        let p = s.as_mut_ptr() as *mut f64;
        unsafe {
            vst1q_f64(p, v[0]);
            vst1q_f64(p.add(2), v[1]);
        }
    }

    #[inline(always)]
    fn cmul(a: Self::F64, b: Self::F64) -> Self::F64 {
        unsafe { [cmul_one(a[0], b[0]), cmul_one(a[1], b[1])] }
    }

    #[inline(always)]
    fn conjc(v: Self::F64) -> Self::F64 {
        unsafe { [negate_lane1(v[0]), negate_lane1(v[1])] }
    }

    #[inline(always)]
    fn swap_pairs(v: Self::F64) -> Self::F64 {
        [v[1], v[0]]
    }

    // ---- u32 -----------------------------------------------------------

    type U32 = [uint32x4_t; 2];

    #[inline(always)]
    fn splat_u32(x: u32) -> Self::U32 {
        unsafe { [vdupq_n_u32(x), vdupq_n_u32(x)] }
    }

    #[inline(always)]
    fn f32_bits(v: Self::F32) -> Self::U32 {
        unsafe { [vreinterpretq_u32_f32(v[0]), vreinterpretq_u32_f32(v[1])] }
    }

    #[inline(always)]
    fn bits_f32(v: Self::U32) -> Self::F32 {
        unsafe { [vreinterpretq_f32_u32(v[0]), vreinterpretq_f32_u32(v[1])] }
    }

    #[inline(always)]
    fn shr16_u32(v: Self::U32) -> Self::U32 {
        unsafe { [vshrq_n_u32::<16>(v[0]), vshrq_n_u32::<16>(v[1])] }
    }

    #[inline(always)]
    fn shl16_u32(v: Self::U32) -> Self::U32 {
        unsafe { [vshlq_n_u32::<16>(v[0]), vshlq_n_u32::<16>(v[1])] }
    }

    #[inline(always)]
    fn and_u32(a: Self::U32, b: Self::U32) -> Self::U32 {
        unsafe { [vandq_u32(a[0], b[0]), vandq_u32(a[1], b[1])] }
    }

    #[inline(always)]
    fn or_u32(a: Self::U32, b: Self::U32) -> Self::U32 {
        unsafe { [vorrq_u32(a[0], b[0]), vorrq_u32(a[1], b[1])] }
    }

    #[inline(always)]
    fn add_u32(a: Self::U32, b: Self::U32) -> Self::U32 {
        unsafe { [vaddq_u32(a[0], b[0]), vaddq_u32(a[1], b[1])] }
    }

    #[inline(always)]
    fn nan_mask_u32(v: Self::F32) -> Self::U32 {
        // vceqq is all-ones exactly on non-NaN lanes; invert
        unsafe {
            [
                vmvnq_u32(vceqq_f32(v[0], v[0])),
                vmvnq_u32(vceqq_f32(v[1], v[1])),
            ]
        }
    }

    #[inline(always)]
    fn select_u32(mask: Self::U32, a: Self::U32, b: Self::U32) -> Self::U32 {
        unsafe { [vbslq_u32(mask[0], a[0], b[0]), vbslq_u32(mask[1], a[1], b[1])] }
    }

    #[inline(always)]
    fn widen_u16(s: &[u16]) -> Self::U32 {
        let s = &s[..F32_LANES];
        let p = s.as_ptr();
        unsafe { [vmovl_u16(vld1_u16(p)), vmovl_u16(vld1_u16(p.add(4)))] }
    }

    #[inline(always)]
    fn to_array_u32(v: Self::U32) -> [u32; F32_LANES] {
        let mut out = [0u32; F32_LANES];
        unsafe {
            vst1q_u32(out.as_mut_ptr(), v[0]);
            vst1q_u32(out.as_mut_ptr().add(4), v[1]);
        }
        out
    }
}
