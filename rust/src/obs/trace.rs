//! Telemetry exporters: streaming Chrome-trace JSON, a Prometheus-style
//! text dump, and the end-of-run terminal summary table.
//!
//! The trace file is the Trace Event Format's JSON-array flavor —
//! loadable by `chrome://tracing` / Perfetto and by our own
//! `util::json::Json::parse` (which `tests/obs_determinism.rs` uses to
//! prove the file is well-formed). Events stream to disk as they drain,
//! so a crashed run keeps its trace prefix; [`TraceWriter::finish`]
//! closes the array, but Chrome also accepts an unterminated array, so
//! even the unfinished file is inspectable.

use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::{CounterSnapshot, Event};

/// Streaming Chrome-trace writer. One complete event (`"ph":"X"`) per
/// span; `tid` 0 is the trainer thread, engine lane `k` maps to
/// `tid = k + 1`.
pub struct TraceWriter {
    out: BufWriter<File>,
    first: bool,
    line: String,
    pub path: PathBuf,
}

impl TraceWriter {
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let file = File::create(&path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        let mut out = BufWriter::new(file);
        out.write_all(b"[\n")?;
        Ok(TraceWriter { out, first: true, line: String::new(), path })
    }

    /// Write one complete span. `layer == Event::NO_LAYER` omits the
    /// layer arg; `step` tags which trainer step the span belongs to.
    pub fn emit(
        &mut self,
        name: &str,
        tid: u32,
        start_us: u64,
        dur_us: u64,
        step: u64,
        layer: u32,
    ) -> Result<()> {
        self.line.clear();
        let _ = write!(
            self.line,
            "{}{{\"name\":\"{}\",\"cat\":\"step\",\"ph\":\"X\",\"ts\":{},\
             \"dur\":{},\"pid\":0,\"tid\":{},\"args\":{{\"step\":{}",
            if self.first { "" } else { ",\n" },
            name,
            start_us,
            dur_us,
            tid,
            step,
        );
        if layer != Event::NO_LAYER {
            let _ = write!(self.line, ",\"layer\":{layer}");
        }
        self.line.push_str("}}");
        self.first = false;
        self.out.write_all(self.line.as_bytes())?;
        Ok(())
    }

    /// Write a drained engine [`Event`] (lane `k` → `tid k+1`).
    pub fn emit_event(&mut self, e: &Event, step: u64) -> Result<()> {
        self.emit(e.name, e.lane + 1, e.start_us, e.dur_us, step, e.layer)
    }

    /// Close the JSON array and flush to disk.
    pub fn finish(&mut self) -> Result<()> {
        self.out.write_all(b"\n]\n")?;
        self.out.flush()?;
        Ok(())
    }
}

/// Prometheus text-exposition dump of the counters (written to
/// `<run-dir>/metrics.prom` at run end). Monotonic counters get the
/// conventional `_total` suffix under a shared `fft_subspace_` prefix.
pub fn prometheus_text(snap: &CounterSnapshot) -> String {
    let mut out = String::new();
    for (name, value) in snap.entries() {
        let _ = writeln!(out, "# TYPE fft_subspace_{name}_total counter");
        let _ = writeln!(out, "fft_subspace_{name}_total {value}");
    }
    out
}

/// Fixed-width terminal table printed at run end when telemetry is on.
pub fn summary_table(snap: &CounterSnapshot) -> String {
    let mut out = String::from("observability counters:\n");
    for (name, value) in snap.entries() {
        let _ = writeln!(out, "  {name:<20} {value:>14}");
    }
    out.pop(); // trailing newline
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn trace_file_is_loadable_json_with_expected_fields() {
        let path = std::env::temp_dir().join(format!(
            "fft_subspace_trace_test_{}.json",
            std::process::id()
        ));
        let mut tw = TraceWriter::create(&path).unwrap();
        tw.emit("batch", 0, 10, 5, 0, Event::NO_LAYER).unwrap();
        tw.emit_event(
            &Event { name: "project", layer: 3, lane: 1, start_us: 20, dur_us: 2 },
            1,
        )
        .unwrap();
        tw.finish().unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).unwrap();
        let events = doc.as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].req("name").unwrap().as_str().unwrap(), "batch");
        assert_eq!(events[0].req("ph").unwrap().as_str().unwrap(), "X");
        assert!(events[0].req("args").unwrap().get("layer").is_none());
        assert_eq!(events[1].req("tid").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            events[1].req("args").unwrap().req("layer").unwrap().as_usize().unwrap(),
            3
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn prometheus_and_summary_cover_every_counter() {
        let snap = CounterSnapshot { ws_pool_hits: 7, allreduce_bytes: 4096, ..Default::default() };
        let prom = prometheus_text(&snap);
        assert!(prom.contains("fft_subspace_ws_pool_hits_total 7"));
        assert!(prom.contains("fft_subspace_allreduce_bytes_total 4096"));
        let table = summary_table(&snap);
        for (name, _) in snap.entries() {
            assert!(prom.contains(name), "prom missing {name}");
            assert!(table.contains(name), "table missing {name}");
        }
    }
}
