//! Run-wide telemetry: tiered observability that never touches the math.
//!
//! Three tiers, selected once per run (`obs=off|counters|trace`, or the
//! `FFT_SUBSPACE_OBS` env knob when the config sets none):
//!
//! * **off** (default) — every hook site is a single relaxed atomic load
//!   plus a predictable branch; nothing is recorded.
//! * **counters** — monotonic process-global [`Counters`] (workspace pool
//!   hits/misses, FFT plan-cache hits, all-reduce bytes, guard trips,
//!   fault firings, rollbacks, worker retries) and the per-refresh
//!   [`SubspaceQuality`] gauges.
//! * **trace** — everything above plus span timing of every step phase
//!   into per-lane preallocated [`EventRing`]s, exported as a Chrome-trace
//!   `trace.json` ([`trace::TraceWriter`]).
//!
//! **The two contracts every hook must keep** (see ROADMAP
//! "Observability"):
//!
//! 1. *Strictly read-only.* Telemetry may observe values the step already
//!    computes; it may never change what is computed. The training
//!    trajectory is `to_bits`-identical across all three tiers
//!    (`tests/obs_determinism.rs`).
//! 2. *Zero steady-state allocation.* Counters are `static` atomics;
//!    rings are sized at optimizer build time and events are plain-`Copy`
//!    index writes (`tests/alloc_steady_state.rs` counts steps under
//!    every tier). A ring that fills between drains *drops* events (and
//!    counts the drops) rather than growing.
//!
//! Determinism across thread counts follows the `ShardedWorkspace` idiom:
//! ring `k` is bound to chunk `k` of `step_layers_parallel` (not to an OS
//! thread), and [`RingSet::drain_all`] merges rings in fixed ascending
//! lane order — so *which events exist* is identical for any lane count
//! (only the wall-clock timestamps differ, as they must).

pub mod trace;

use std::cell::{Cell, UnsafeCell};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{bail, Result};

// ---------------------------------------------------------------------------
// Tier selection
// ---------------------------------------------------------------------------

/// Observability tier. Ordered: `Counters` includes everything `Off`
/// omits, `Trace` includes everything `Counters` records.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ObsTier {
    Off = 0,
    Counters = 1,
    Trace = 2,
}

impl ObsTier {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "off" | "0" => ObsTier::Off,
            "counters" | "1" => ObsTier::Counters,
            "trace" | "2" => ObsTier::Trace,
            other => bail!("unknown obs tier {other:?} (off|counters|trace)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ObsTier::Off => "off",
            ObsTier::Counters => "counters",
            ObsTier::Trace => "trace",
        }
    }

    /// Tier from `FFT_SUBSPACE_OBS`, or `Off` when unset. The trainer
    /// lets the `obs=` config key win over the environment (same
    /// precedence as `fault=` vs `FFT_SUBSPACE_FAULT`).
    pub fn from_env() -> Result<Self> {
        match std::env::var("FFT_SUBSPACE_OBS") {
            Ok(s) => Self::parse(&s),
            Err(_) => Ok(ObsTier::Off),
        }
    }
}

/// Process-global tier. `u8` repr of [`ObsTier`]; relaxed loads are the
/// whole cost of a disabled hook site.
static TIER: AtomicU8 = AtomicU8::new(0);

/// Per-layer sample interval for gauges and per-layer trace spans
/// (`obs-sample=N`: record only on steps where `t % N == 0`). Step-level
/// trainer phases are always recorded under `trace`.
static SAMPLE: AtomicU64 = AtomicU64::new(1);

pub fn set_tier(t: ObsTier) {
    TIER.store(t as u8, Ordering::Relaxed);
    // Pin the epoch before any hot-path span asks for a timestamp.
    let _ = now_us();
}

#[inline]
pub fn tier() -> ObsTier {
    match TIER.load(Ordering::Relaxed) {
        0 => ObsTier::Off,
        1 => ObsTier::Counters,
        _ => ObsTier::Trace,
    }
}

/// `true` when any telemetry is on (`counters` or `trace`).
#[inline]
pub fn enabled() -> bool {
    TIER.load(Ordering::Relaxed) != 0
}

/// `true` only under the `trace` tier.
#[inline]
pub fn tracing() -> bool {
    TIER.load(Ordering::Relaxed) == 2
}

pub fn set_sample(n: u64) {
    SAMPLE.store(n.max(1), Ordering::Relaxed);
}

/// Deterministic sampling gate for per-layer records at step `t`.
#[inline]
pub fn sample_hit(t: u64) -> bool {
    t % SAMPLE.load(Ordering::Relaxed) == 0
}

// ---------------------------------------------------------------------------
// Timestamps
// ---------------------------------------------------------------------------

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process-local trace epoch (first telemetry
/// touch). `Instant` reads don't allocate, so spans are hot-path safe.
#[inline]
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

// ---------------------------------------------------------------------------
// Counters
// ---------------------------------------------------------------------------

/// Monotonic subsystem counters. One process-global instance
/// ([`counters`]); hook sites are free functions so subsystems don't
/// thread a handle. All increments are gated on [`enabled`].
#[derive(Debug, Default)]
pub struct Counters {
    /// `Workspace` buffer requests served from a pool.
    pub ws_pool_hits: AtomicU64,
    /// `Workspace` buffer requests that had to allocate.
    pub ws_pool_misses: AtomicU64,
    /// Cumulative bytes allocated by pool misses. Pools never shrink, so
    /// this is also the workspace high-water mark.
    pub ws_pool_bytes: AtomicU64,
    /// `fft::cached_plan` served from the process cache / built fresh.
    pub fft_plan_hits: AtomicU64,
    pub fft_plan_builds: AtomicU64,
    /// `fft::cached_dct2_matrix` served from the process cache / built.
    pub dct2_cache_hits: AtomicU64,
    pub dct2_cache_builds: AtomicU64,
    /// Bytes moved by ring all-reduce (mirrors `CommStats`, which is
    /// per-communicator; this is the run-wide total). Under `wire=q8`
    /// this counts the quantized on-wire volume — 1 B/element plus the
    /// per-block scale header — not the f32 equivalent, so the q8 wire
    /// saving is directly visible in the counter.
    pub allreduce_bytes: AtomicU64,
    /// Bytes moved by tree broadcasts (ZeRO update fan-out, subspace
    /// basis sync — mirrors `CommStats::broadcast_bytes` run-wide).
    pub broadcast_bytes: AtomicU64,
    /// Bytes moved by all-gathers (basis-agreement checks under
    /// `comm=subspace` — mirrors `CommStats::all_gather_bytes` run-wide).
    pub all_gather_bytes: AtomicU64,
    /// `StepGuard` verdicts that were not healthy.
    pub guard_trips: AtomicU64,
    /// Injected faults that actually fired.
    pub fault_firings: AtomicU64,
    /// Trainer rollback-restore events.
    pub rollbacks: AtomicU64,
    /// Worker-lane attempts that failed and were retried.
    pub worker_retries: AtomicU64,
    /// Fused step-plan compilations (engine build + every `load_state`
    /// rebuild — plans are derived state).
    pub plan_builds: AtomicU64,
    /// Shape groups across all plan builds.
    pub plan_groups: AtomicU64,
    /// Low-rank layers covered by those groups.
    pub plan_grouped_layers: AtomicU64,
}

static COUNTERS: Counters = Counters {
    ws_pool_hits: AtomicU64::new(0),
    ws_pool_misses: AtomicU64::new(0),
    ws_pool_bytes: AtomicU64::new(0),
    fft_plan_hits: AtomicU64::new(0),
    fft_plan_builds: AtomicU64::new(0),
    dct2_cache_hits: AtomicU64::new(0),
    dct2_cache_builds: AtomicU64::new(0),
    allreduce_bytes: AtomicU64::new(0),
    broadcast_bytes: AtomicU64::new(0),
    all_gather_bytes: AtomicU64::new(0),
    guard_trips: AtomicU64::new(0),
    fault_firings: AtomicU64::new(0),
    rollbacks: AtomicU64::new(0),
    worker_retries: AtomicU64::new(0),
    plan_builds: AtomicU64::new(0),
    plan_groups: AtomicU64::new(0),
    plan_grouped_layers: AtomicU64::new(0),
};

/// The process-global counter block (read-only access; increment through
/// the `count_*` hooks).
pub fn counters() -> &'static Counters {
    &COUNTERS
}

impl Counters {
    pub fn snapshot(&self) -> CounterSnapshot {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        CounterSnapshot {
            ws_pool_hits: ld(&self.ws_pool_hits),
            ws_pool_misses: ld(&self.ws_pool_misses),
            ws_pool_bytes: ld(&self.ws_pool_bytes),
            fft_plan_hits: ld(&self.fft_plan_hits),
            fft_plan_builds: ld(&self.fft_plan_builds),
            dct2_cache_hits: ld(&self.dct2_cache_hits),
            dct2_cache_builds: ld(&self.dct2_cache_builds),
            allreduce_bytes: ld(&self.allreduce_bytes),
            broadcast_bytes: ld(&self.broadcast_bytes),
            all_gather_bytes: ld(&self.all_gather_bytes),
            guard_trips: ld(&self.guard_trips),
            fault_firings: ld(&self.fault_firings),
            rollbacks: ld(&self.rollbacks),
            worker_retries: ld(&self.worker_retries),
            plan_builds: ld(&self.plan_builds),
            plan_groups: ld(&self.plan_groups),
            plan_grouped_layers: ld(&self.plan_grouped_layers),
        }
    }

    /// Zero every counter (tests; runs are per-process so the trainer
    /// resets at run start for a clean per-run dump).
    pub fn reset(&self) {
        for (_, a) in self.cells() {
            a.store(0, Ordering::Relaxed);
        }
    }

    fn cells(&self) -> [(&'static str, &AtomicU64); 17] {
        [
            ("ws_pool_hits", &self.ws_pool_hits),
            ("ws_pool_misses", &self.ws_pool_misses),
            ("ws_pool_bytes", &self.ws_pool_bytes),
            ("fft_plan_hits", &self.fft_plan_hits),
            ("fft_plan_builds", &self.fft_plan_builds),
            ("dct2_cache_hits", &self.dct2_cache_hits),
            ("dct2_cache_builds", &self.dct2_cache_builds),
            ("allreduce_bytes", &self.allreduce_bytes),
            ("broadcast_bytes", &self.broadcast_bytes),
            ("all_gather_bytes", &self.all_gather_bytes),
            ("guard_trips", &self.guard_trips),
            ("fault_firings", &self.fault_firings),
            ("rollbacks", &self.rollbacks),
            ("worker_retries", &self.worker_retries),
            ("plan_builds", &self.plan_builds),
            ("plan_groups", &self.plan_groups),
            ("plan_grouped_layers", &self.plan_grouped_layers),
        ]
    }
}

/// Point-in-time copy of [`Counters`] (plain integers, comparable).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub ws_pool_hits: u64,
    pub ws_pool_misses: u64,
    pub ws_pool_bytes: u64,
    pub fft_plan_hits: u64,
    pub fft_plan_builds: u64,
    pub dct2_cache_hits: u64,
    pub dct2_cache_builds: u64,
    pub allreduce_bytes: u64,
    pub broadcast_bytes: u64,
    pub all_gather_bytes: u64,
    pub guard_trips: u64,
    pub fault_firings: u64,
    pub rollbacks: u64,
    pub worker_retries: u64,
    pub plan_builds: u64,
    pub plan_groups: u64,
    pub plan_grouped_layers: u64,
}

impl CounterSnapshot {
    /// Stable (name, value) listing — the exporters' single source of
    /// field names.
    pub fn entries(&self) -> [(&'static str, u64); 17] {
        [
            ("ws_pool_hits", self.ws_pool_hits),
            ("ws_pool_misses", self.ws_pool_misses),
            ("ws_pool_bytes", self.ws_pool_bytes),
            ("fft_plan_hits", self.fft_plan_hits),
            ("fft_plan_builds", self.fft_plan_builds),
            ("dct2_cache_hits", self.dct2_cache_hits),
            ("dct2_cache_builds", self.dct2_cache_builds),
            ("allreduce_bytes", self.allreduce_bytes),
            ("broadcast_bytes", self.broadcast_bytes),
            ("all_gather_bytes", self.all_gather_bytes),
            ("guard_trips", self.guard_trips),
            ("fault_firings", self.fault_firings),
            ("rollbacks", self.rollbacks),
            ("worker_retries", self.worker_retries),
            ("plan_builds", self.plan_builds),
            ("plan_groups", self.plan_groups),
            ("plan_grouped_layers", self.plan_grouped_layers),
        ]
    }
}

// Hook sites. Each is `if enabled() { one relaxed fetch_add }` — the
// documented "handful of branch-predictable checks" cost of `obs=off`.

#[inline]
pub fn count_ws_pool_hit() {
    if enabled() {
        COUNTERS.ws_pool_hits.fetch_add(1, Ordering::Relaxed);
    }
}

#[inline]
pub fn count_ws_pool_miss(bytes: u64) {
    if enabled() {
        COUNTERS.ws_pool_misses.fetch_add(1, Ordering::Relaxed);
        COUNTERS.ws_pool_bytes.fetch_add(bytes, Ordering::Relaxed);
    }
}

#[inline]
pub fn count_fft_plan(hit: bool) {
    if enabled() {
        let c = if hit { &COUNTERS.fft_plan_hits } else { &COUNTERS.fft_plan_builds };
        c.fetch_add(1, Ordering::Relaxed);
    }
}

#[inline]
pub fn count_dct2_cache(hit: bool) {
    if enabled() {
        let c =
            if hit { &COUNTERS.dct2_cache_hits } else { &COUNTERS.dct2_cache_builds };
        c.fetch_add(1, Ordering::Relaxed);
    }
}

#[inline]
pub fn count_allreduce_bytes(bytes: u64) {
    if enabled() {
        COUNTERS.allreduce_bytes.fetch_add(bytes, Ordering::Relaxed);
    }
}

#[inline]
pub fn count_broadcast_bytes(bytes: u64) {
    if enabled() {
        COUNTERS.broadcast_bytes.fetch_add(bytes, Ordering::Relaxed);
    }
}

#[inline]
pub fn count_all_gather_bytes(bytes: u64) {
    if enabled() {
        COUNTERS.all_gather_bytes.fetch_add(bytes, Ordering::Relaxed);
    }
}

#[inline]
pub fn count_guard_trip() {
    if enabled() {
        COUNTERS.guard_trips.fetch_add(1, Ordering::Relaxed);
    }
}

#[inline]
pub fn count_fault_firing() {
    if enabled() {
        COUNTERS.fault_firings.fetch_add(1, Ordering::Relaxed);
    }
}

#[inline]
pub fn count_rollback() {
    if enabled() {
        COUNTERS.rollbacks.fetch_add(1, Ordering::Relaxed);
    }
}

#[inline]
pub fn count_worker_retry() {
    if enabled() {
        COUNTERS.worker_retries.fetch_add(1, Ordering::Relaxed);
    }
}

/// One fused step-plan compilation (engine build or `load_state` rebuild —
/// restore rebuilds count too, which is the intended rollback signal).
#[inline]
pub fn count_plan_build(groups: u64, grouped_layers: u64) {
    if enabled() {
        COUNTERS.plan_builds.fetch_add(1, Ordering::Relaxed);
        COUNTERS.plan_groups.fetch_add(groups, Ordering::Relaxed);
        COUNTERS.plan_grouped_layers.fetch_add(grouped_layers, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Subspace-quality gauges
// ---------------------------------------------------------------------------

/// Paper-grounded per-layer gauges computed at each subspace refresh,
/// from quantities the refresh already has in hand (no extra passes):
///
/// * `energy_ratio` — Σ of the selected DCT columns' squared L2 norms
///   over the total (`select_top_columns_into`'s f64 accumulators): the
///   fraction of gradient energy the new basis captures (the paper's
///   selection criterion made visible).
/// * `resid_norm` — `sqrt(total − captured)`: the Frobenius norm of the
///   projection residual. Exact for the orthonormal DCT basis, where
///   `‖G‖²_F = ‖S‖²_F` and the residual energy is the unselected mass.
/// * `overlap` — fraction of the new index set shared with the previous
///   refresh's (the 0/1 index matching the fixed-basis rotation uses):
///   basis stability between refreshes. 0.0 on the first refresh.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SubspaceQuality {
    pub energy_ratio: f32,
    pub resid_norm: f32,
    pub overlap: f32,
}

// ---------------------------------------------------------------------------
// Per-lane event rings
// ---------------------------------------------------------------------------

/// One timed span. Plain `Copy` — ring writes are index assignments.
/// `layer == u32::MAX` means "no layer" (step-level phase).
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub name: &'static str,
    pub layer: u32,
    pub lane: u32,
    pub start_us: u64,
    pub dur_us: u64,
}

impl Event {
    pub const NO_LAYER: u32 = u32::MAX;
}

/// Fixed-capacity event buffer with interior mutability, so spans can
/// record through the `&StepCtx` the update rules already receive.
///
/// Not `Sync`: one ring belongs to one `step_layers_parallel` chunk at a
/// time ([`RingSet::lane`] carries the disjointness contract). When full
/// it drops new events (counting them) instead of growing — the
/// zero-allocation contract outranks completeness, and the trainer
/// drains every step so a sized ring never fills in practice.
pub struct EventRing {
    buf: UnsafeCell<Vec<Event>>,
    len: Cell<usize>,
    dropped: Cell<u64>,
}

// SAFETY: all interior state is plain data; moving a ring between
// threads is fine. (It is deliberately NOT Sync — see RingSet.)
unsafe impl Send for EventRing {}

const DUMMY_EVENT: Event =
    Event { name: "", layer: Event::NO_LAYER, lane: 0, start_us: 0, dur_us: 0 };

impl EventRing {
    /// Preallocate space for `cap` events (the only allocation this ring
    /// ever performs).
    pub fn with_capacity(cap: usize) -> Self {
        EventRing {
            buf: UnsafeCell::new(vec![DUMMY_EVENT; cap]),
            len: Cell::new(0),
            dropped: Cell::new(0),
        }
    }

    #[inline]
    pub fn push(&self, e: Event) {
        // SAFETY: `&self` access is exclusive per the RingSet contract
        // (one chunk/thread per ring); the buffer is never resized here.
        let buf = unsafe { &mut *self.buf.get() };
        let len = self.len.get();
        if len < buf.len() {
            buf[len] = e;
            self.len.set(len + 1);
        } else {
            self.dropped.set(self.dropped.get() + 1);
        }
    }

    pub fn len(&self) -> usize {
        self.len.get()
    }

    pub fn is_empty(&self) -> bool {
        self.len.get() == 0
    }

    /// Events dropped because the ring was full (cleared by [`Self::clear`]).
    pub fn dropped(&self) -> u64 {
        self.dropped.get()
    }

    pub fn clear(&self) {
        self.len.set(0);
        self.dropped.set(0);
    }

    /// Copy out the recorded events and reset. `out` may grow — drains
    /// run on the trainer thread between steps, not on the hot path.
    pub fn drain_into(&self, out: &mut Vec<Event>) {
        // SAFETY: exclusive access per the RingSet contract.
        let buf = unsafe { &*self.buf.get() };
        out.extend_from_slice(&buf[..self.len.get()]);
        self.len.set(0);
    }
}

/// The engine's per-lane rings, chunk-indexed exactly like
/// [`crate::parallel::ShardedWorkspace`]: chunk `k` of a
/// `step_layers_parallel` dispatch records only into ring `k`, so the
/// recorded event set is identical for any thread count, and
/// [`Self::drain_all`] merges in fixed ascending lane order.
pub struct RingSet {
    rings: Vec<EventRing>,
}

// SAFETY: `lane` is the only shared access path and its contract
// requires disjoint indices across threads (the par_chunks pattern).
unsafe impl Sync for RingSet {}

impl RingSet {
    /// `lanes` rings of `cap` events each. Pass `cap = 0` when the run's
    /// tier can never trace (pushes become counted drops) — building is
    /// then free and enabling `trace` mid-process stays allocation-safe.
    pub fn new(lanes: usize, cap: usize) -> Self {
        RingSet {
            rings: (0..lanes.max(1)).map(|_| EventRing::with_capacity(cap)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.rings.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rings.is_empty()
    }

    /// Ring `k`, for chunk `k` of a parallel dispatch.
    ///
    /// # Safety
    /// Each index must be live in at most one thread at a time — the
    /// `par_chunks` pattern (chunk `k` is claimed by exactly one thread
    /// and uses only ring `k`) satisfies this by construction.
    pub unsafe fn lane(&self, k: usize) -> &EventRing {
        &self.rings[k]
    }

    /// Drain every ring into `out` in ascending lane order (deterministic
    /// merge for any thread count). Requires `&mut self`, so no chunk can
    /// be writing concurrently. Returns the number of dropped events.
    pub fn drain_all(&mut self, out: &mut Vec<Event>) -> u64 {
        let mut dropped = 0;
        for r in &self.rings {
            dropped += r.dropped();
            r.drain_into(out);
            r.clear();
        }
        dropped
    }
}

// ---------------------------------------------------------------------------
// Span handle threaded through the step
// ---------------------------------------------------------------------------

/// Per-layer span recorder carried by `StepCtx`. `Copy` and two words —
/// an absent lane (`ring: None`, or `sampled: false`) makes
/// [`Self::span`] a direct call of its closure.
#[derive(Clone, Copy)]
pub struct ObsLane<'a> {
    pub ring: Option<&'a EventRing>,
    pub lane: u32,
    pub layer: u32,
    /// Precomputed `tracing() && sample_hit(t)` for this step.
    pub sampled: bool,
}

impl ObsLane<'_> {
    /// The disabled lane (sequential helpers, tests, frozen loops).
    pub fn none() -> ObsLane<'static> {
        ObsLane { ring: None, lane: 0, layer: Event::NO_LAYER, sampled: false }
    }

    /// Run `f`, recording a span into the lane's ring when sampled.
    /// Recording is a `Cell` index write — no allocation, no lock.
    #[inline]
    pub fn span<T>(&self, name: &'static str, f: impl FnOnce() -> T) -> T {
        match self.ring {
            Some(ring) if self.sampled => {
                let t0 = now_us();
                let out = f();
                ring.push(Event {
                    name,
                    layer: self.layer,
                    lane: self.lane,
                    start_us: t0,
                    dur_us: now_us().saturating_sub(t0),
                });
                out
            }
            _ => f(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tier/sample/counter statics are process-global; in-crate tests
    /// that touch them serialize here (same idiom as the fault-latch and
    /// SIMD-override locks).
    pub(crate) static OBS_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        OBS_TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn tier_parse_and_names_round_trip() {
        for t in [ObsTier::Off, ObsTier::Counters, ObsTier::Trace] {
            assert_eq!(ObsTier::parse(t.name()).unwrap(), t);
        }
        assert!(ObsTier::parse("verbose").is_err());
        assert!(ObsTier::Off < ObsTier::Counters);
        assert!(ObsTier::Counters < ObsTier::Trace);
    }

    #[test]
    fn disabled_hooks_record_nothing() {
        let _g = lock();
        set_tier(ObsTier::Off);
        counters().reset();
        count_ws_pool_hit();
        count_allreduce_bytes(1024);
        count_guard_trip();
        assert_eq!(counters().snapshot(), CounterSnapshot::default());
    }

    #[test]
    fn enabled_hooks_accumulate_and_reset() {
        let _g = lock();
        set_tier(ObsTier::Counters);
        counters().reset();
        count_ws_pool_hit();
        count_ws_pool_hit();
        count_ws_pool_miss(256);
        count_fft_plan(true);
        count_fft_plan(false);
        count_allreduce_bytes(100);
        count_allreduce_bytes(24);
        let snap = counters().snapshot();
        assert_eq!(snap.ws_pool_hits, 2);
        assert_eq!(snap.ws_pool_misses, 1);
        assert_eq!(snap.ws_pool_bytes, 256);
        assert_eq!(snap.fft_plan_hits, 1);
        assert_eq!(snap.fft_plan_builds, 1);
        assert_eq!(snap.allreduce_bytes, 124);
        counters().reset();
        assert_eq!(counters().snapshot(), CounterSnapshot::default());
        set_tier(ObsTier::Off);
    }

    #[test]
    fn ring_push_drain_and_overflow() {
        let ring = EventRing::with_capacity(2);
        let mk = |n| Event { name: n, layer: 0, lane: 0, start_us: 0, dur_us: 1 };
        ring.push(mk("a"));
        ring.push(mk("b"));
        ring.push(mk("c")); // full → dropped, not grown
        assert_eq!(ring.len(), 2);
        assert_eq!(ring.dropped(), 1);
        let mut out = Vec::new();
        ring.drain_into(&mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].name, "a");
        assert_eq!(out[1].name, "b");
        assert!(ring.is_empty());
    }

    #[test]
    fn ring_set_drains_in_lane_order() {
        let mut rs = RingSet::new(3, 4);
        for k in [2usize, 0, 1] {
            // SAFETY: single-threaded test.
            let ring = unsafe { rs.lane(k) };
            ring.push(Event {
                name: "x",
                layer: k as u32,
                lane: k as u32,
                start_us: 0,
                dur_us: 0,
            });
        }
        let mut out = Vec::new();
        let dropped = rs.drain_all(&mut out);
        assert_eq!(dropped, 0);
        let lanes: Vec<u32> = out.iter().map(|e| e.lane).collect();
        assert_eq!(lanes, vec![0, 1, 2]);
    }

    #[test]
    fn span_records_only_when_sampled() {
        let ring = EventRing::with_capacity(4);
        let on = ObsLane { ring: Some(&ring), lane: 1, layer: 7, sampled: true };
        let off = ObsLane { ring: Some(&ring), lane: 1, layer: 7, sampled: false };
        assert_eq!(off.span("skipped", || 1 + 1), 2);
        assert_eq!(ring.len(), 0);
        assert_eq!(on.span("kept", || 21 * 2), 42);
        assert_eq!(ring.len(), 1);
        let mut out = Vec::new();
        ring.drain_into(&mut out);
        assert_eq!(out[0].name, "kept");
        assert_eq!(out[0].layer, 7);
        assert_eq!(out[0].lane, 1);
        assert_eq!(ObsLane::none().span("nothing", || 5), 5);
    }

    #[test]
    fn sample_gate_is_modular() {
        let _g = lock();
        set_sample(4);
        assert!(sample_hit(0));
        assert!(!sample_hit(1));
        assert!(sample_hit(8));
        set_sample(0); // clamps to 1: every step sampled
        assert!(sample_hit(3));
        set_sample(1);
    }
}
