//! Deterministic batch loader: fixed-length windows over a token stream,
//! sharded across DDP workers (worker `w` of `W` reads every W-th window —
//! the same partitioning torch's DistributedSampler uses).

use crate::util::Pcg64;

pub struct BatchLoader<'a> {
    tokens: &'a [u16],
    seq_len: usize,
    rng: Pcg64,
}

impl<'a> BatchLoader<'a> {
    pub fn new(tokens: &'a [u16], seq_len: usize, seed: u64) -> Self {
        assert!(tokens.len() > seq_len + 1, "corpus shorter than seq_len");
        BatchLoader { tokens, seq_len, rng: Pcg64::new(seed, 0x10ad_e4) }
    }

    /// One `(batch × seq_len)` i32 batch from random windows.
    pub fn next_batch(&mut self, batch: usize) -> (Vec<i32>, Vec<usize>) {
        let mut out = Vec::with_capacity(batch * self.seq_len);
        let max_start = self.tokens.len() - self.seq_len - 1;
        for _ in 0..batch {
            let start = self.rng.usize_below(max_start);
            out.extend(
                self.tokens[start..start + self.seq_len]
                    .iter()
                    .map(|&t| t as i32),
            );
        }
        (out, vec![batch, self.seq_len])
    }

    /// Advance the loader past `batches` batches of size `batch` without
    /// materializing them: consumes exactly the RNG draws
    /// [`BatchLoader::next_batch`] would (one window start per row), so a
    /// resumed run's loader lands on the identical stream position at zero
    /// allocation cost (checkpoint-v2 fast-forward).
    pub fn skip_batches(&mut self, batches: usize, batch: usize) {
        let max_start = self.tokens.len() - self.seq_len - 1;
        for _ in 0..batches * batch {
            let _ = self.rng.usize_below(max_start);
        }
    }

    /// Worker-sharded batch: worker `w` draws from a disjoint stream (same
    /// global seed, per-worker substream) so DDP shards never collide.
    pub fn worker(&self, w: usize, global_seed: u64) -> BatchLoader<'a> {
        BatchLoader {
            tokens: self.tokens,
            seq_len: self.seq_len,
            rng: Pcg64::new(global_seed, 0x10ad_e4 ^ ((w as u64 + 1) << 20)),
        }
    }

    /// Deterministic evaluation batches: sequential non-overlapping windows.
    pub fn eval_batches(&self, batch: usize, count: usize) -> Vec<(Vec<i32>, Vec<usize>)> {
        let mut out = Vec::with_capacity(count);
        let mut pos = 0usize;
        for _ in 0..count {
            let mut data = Vec::with_capacity(batch * self.seq_len);
            for _ in 0..batch {
                if pos + self.seq_len + 1 >= self.tokens.len() {
                    pos = 0;
                }
                data.extend(
                    self.tokens[pos..pos + self.seq_len]
                        .iter()
                        .map(|&t| t as i32),
                );
                pos += self.seq_len;
            }
            out.push((data, vec![batch, self.seq_len]));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize) -> Vec<u16> {
        (0..n).map(|i| (i % 251) as u16).collect()
    }

    #[test]
    fn batch_shape_and_range() {
        let t = toks(10_000);
        let mut l = BatchLoader::new(&t, 32, 0);
        let (data, shape) = l.next_batch(4);
        assert_eq!(shape, vec![4, 32]);
        assert_eq!(data.len(), 128);
        assert!(data.iter().all(|&x| (0..251).contains(&x)));
    }

    #[test]
    fn deterministic_stream() {
        let t = toks(10_000);
        let mut a = BatchLoader::new(&t, 16, 7);
        let mut b = BatchLoader::new(&t, 16, 7);
        assert_eq!(a.next_batch(2).0, b.next_batch(2).0);
    }

    #[test]
    fn skip_batches_matches_discarded_draws() {
        let t = toks(10_000);
        let mut a = BatchLoader::new(&t, 16, 7);
        let mut b = BatchLoader::new(&t, 16, 7);
        for _ in 0..5 {
            let _ = a.next_batch(3);
        }
        b.skip_batches(5, 3);
        // both loaders continue from the identical stream position
        assert_eq!(a.next_batch(3).0, b.next_batch(3).0);
    }

    #[test]
    fn workers_draw_disjoint_streams() {
        let t = toks(10_000);
        let l = BatchLoader::new(&t, 16, 7);
        let mut w0 = l.worker(0, 7);
        let mut w1 = l.worker(1, 7);
        assert_ne!(w0.next_batch(2).0, w1.next_batch(2).0);
    }

    #[test]
    fn eval_batches_are_sequential_and_stable() {
        let t = toks(10_000);
        let l = BatchLoader::new(&t, 16, 7);
        let e1 = l.eval_batches(2, 3);
        let e2 = l.eval_batches(2, 3);
        assert_eq!(e1.len(), 3);
        for (a, b) in e1.iter().zip(&e2) {
            assert_eq!(a.0, b.0);
        }
        // windows advance
        assert_ne!(e1[0].0, e1[1].0);
    }
}
