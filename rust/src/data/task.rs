//! Fine-tuning task corpus (GSM-8k stand-in, DESIGN.md substitution).
//!
//! Byte-level addition word problems: `"12+345=357\n"`. The structure gives
//! a real generalization target — held-out problems never seen in training —
//! and an *exact-match accuracy* metric: greedy-decode the digits after `=`
//! and compare to the true sum, mirroring how GSM-8k answers are scored.

use crate::util::Pcg64;

#[derive(Clone, Debug)]
pub struct TaskExample {
    /// Full token sequence (prompt + answer + newline), padded to seq_len.
    pub tokens: Vec<u16>,
    /// Position of the first answer byte (the char after '=').
    pub answer_start: usize,
    pub answer: String,
}

pub struct TaskCorpus {
    pub train: Vec<TaskExample>,
    pub test: Vec<TaskExample>,
    pub seq_len: usize,
}

const PAD: u16 = 256; // pad token (vocab is 257 in model.py)

fn make_example(rng: &mut Pcg64, seq_len: usize, max_operand: u64) -> TaskExample {
    let a = rng.below(max_operand);
    let b = rng.below(max_operand);
    let prompt = format!("{a}+{b}=");
    let answer = format!("{}", a + b);
    let text = format!("{prompt}{answer}\n");
    let mut tokens: Vec<u16> = text.bytes().map(|b| b as u16).collect();
    let answer_start = prompt.len();
    assert!(tokens.len() <= seq_len, "seq_len too short for task");
    tokens.resize(seq_len, PAD);
    TaskExample { tokens, answer_start, answer }
}

impl TaskCorpus {
    pub fn generate(train_n: usize, test_n: usize, seq_len: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0x7a5c_c0de);
        let mut train = Vec::with_capacity(train_n);
        let mut test = Vec::with_capacity(test_n);
        // two-digit operands: learnable at this model scale while still
        // requiring real computation (carries) — GSM-8k difficulty scaled
        // with the model, per the DESIGN.md substitution rule.
        while train.len() < train_n {
            train.push(make_example(&mut rng, seq_len, 100));
        }
        while test.len() < test_n {
            test.push(make_example(&mut rng, seq_len, 100));
        }
        TaskCorpus { train, test, seq_len }
    }

    /// Batch of training examples as (data, shape).
    pub fn batch(&self, rng: &mut Pcg64, batch: usize) -> (Vec<i32>, Vec<usize>) {
        let mut data = Vec::with_capacity(batch * self.seq_len);
        for _ in 0..batch {
            let ex = &self.train[rng.usize_below(self.train.len())];
            data.extend(ex.tokens.iter().map(|&t| t as i32));
        }
        (data, vec![batch, self.seq_len])
    }

    /// Exact-match accuracy under teacher-forced greedy scoring: for each
    /// answer position, the model's argmax (computed by the caller from
    /// logits) must equal the gold byte. The caller provides a closure
    /// mapping a token batch to per-position argmax predictions.
    pub fn exact_match<F>(&self, mut predict: F, limit: usize) -> f64
    where
        F: FnMut(&[i32], usize) -> Vec<usize>, // (tokens, seq) -> argmax per pos
    {
        let n = self.test.len().min(limit);
        let mut correct = 0usize;
        for ex in self.test.iter().take(n) {
            let toks: Vec<i32> = ex.tokens.iter().map(|&t| t as i32).collect();
            let preds = predict(&toks, self.seq_len);
            // answer bytes are generated at positions answer_start..; the
            // model predicts token t+1 at position t.
            let ok = ex.answer.bytes().enumerate().all(|(k, gold)| {
                let pos = ex.answer_start + k;
                pos >= 1 && preds.get(pos - 1) == Some(&(gold as usize))
            });
            if ok {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn examples_well_formed() {
        let c = TaskCorpus::generate(100, 20, 32, 0);
        assert_eq!(c.train.len(), 100);
        for ex in &c.train {
            assert_eq!(ex.tokens.len(), 32);
            let text: String = ex.tokens.iter()
                .take_while(|&&t| t != PAD)
                .map(|&t| t as u8 as char)
                .collect();
            // "a+b=c\n" parses and sums correctly
            let (lhs, rhs) = text.trim_end().split_once('=').unwrap();
            let (a, b) = lhs.split_once('+').unwrap();
            let sum: u64 = a.parse::<u64>().unwrap() + b.parse::<u64>().unwrap();
            assert_eq!(sum.to_string(), rhs);
            assert_eq!(rhs, ex.answer);
        }
    }

    #[test]
    fn deterministic() {
        let a = TaskCorpus::generate(10, 5, 32, 3);
        let b = TaskCorpus::generate(10, 5, 32, 3);
        assert_eq!(a.train[0].tokens, b.train[0].tokens);
    }

    #[test]
    fn exact_match_with_oracle_predictor_is_one() {
        let c = TaskCorpus::generate(10, 10, 32, 1);
        // oracle: predict position t+1's gold token at position t
        let examples = c.test.clone();
        let mut i = 0;
        let acc = c.exact_match(
            |_toks, seq| {
                let ex = &examples[i];
                i += 1;
                (1..=seq).map(|p| *ex.tokens.get(p).unwrap_or(&PAD) as usize).collect()
            },
            10,
        );
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn exact_match_with_wrong_predictor_is_zero() {
        let c = TaskCorpus::generate(10, 10, 32, 1);
        let acc = c.exact_match(|_t, seq| vec![0usize; seq], 10);
        assert_eq!(acc, 0.0);
    }
}
