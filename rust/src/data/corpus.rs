//! Synthetic pre-training corpus: an order-2 Markov chain over a byte
//! vocabulary with Zipfian emission priors.
//!
//! Properties that matter for optimizer comparisons (and that plain uniform
//! noise lacks):
//!
//! * non-trivial entropy gap: a model can reduce loss well below log|V| by
//!   learning the transition structure, so optimizer quality separates;
//! * long-range repetition (paragraph motif re-use) so longer training
//!   keeps helping — loss curves stay informative for the full budget;
//! * a held-out split from the same process for validation perplexity.

use crate::util::{rng::zipf_cdf, Pcg64};

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    pub tokens: usize,
    pub seed: u64,
    /// Zipf exponent for the emission prior.
    pub zipf_s: f64,
    /// Number of latent "motifs" (reused sub-sequences).
    pub motifs: usize,
    pub motif_len: usize,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab: 256,
            tokens: 1 << 20,
            seed: 1234,
            zipf_s: 1.1,
            motifs: 64,
            motif_len: 24,
        }
    }
}

pub struct SyntheticCorpus {
    pub train: Vec<u16>,
    pub val: Vec<u16>,
    pub vocab: usize,
}

impl SyntheticCorpus {
    pub fn generate(cfg: &CorpusConfig) -> Self {
        let mut rng = Pcg64::new(cfg.seed, 0xc0_1255);
        let v = cfg.vocab;
        let cdf = zipf_cdf(v, cfg.zipf_s);

        // Sparse order-2 transition structure: each (a, b) context maps to a
        // small candidate set; contexts hash into a table to bound memory.
        const CONTEXTS: usize = 4096;
        const CANDS: usize = 8;
        let mut table = vec![[0u16; CANDS]; CONTEXTS];
        for row in table.iter_mut() {
            for slot in row.iter_mut() {
                *slot = rng.zipf(&cdf) as u16;
            }
        }
        // Motifs: pre-generated snippets spliced in with small probability.
        let motifs: Vec<Vec<u16>> = (0..cfg.motifs)
            .map(|_| {
                (0..cfg.motif_len)
                    .map(|_| rng.zipf(&cdf) as u16)
                    .collect()
            })
            .collect();

        let total = cfg.tokens + cfg.tokens / 10; // +10% val
        let mut out = Vec::with_capacity(total);
        let (mut a, mut b) = (0u16, 1u16);
        while out.len() < total {
            if rng.next_f64() < 0.02 {
                let m = &motifs[rng.usize_below(motifs.len())];
                out.extend_from_slice(m);
                if let [x, y] = m[m.len().saturating_sub(2)..] {
                    a = x;
                    b = y;
                }
                continue;
            }
            let ctx = ((a as usize)
                .wrapping_mul(31)
                .wrapping_add(b as usize))
                % CONTEXTS;
            let cands = &table[ctx];
            // mostly-structured: 85% from the context's candidate set
            let next = if rng.next_f64() < 0.85 {
                cands[rng.usize_below(CANDS)]
            } else {
                rng.zipf(&cdf) as u16
            };
            out.push(next);
            a = b;
            b = next;
        }
        let val = out.split_off(cfg.tokens);
        SyntheticCorpus { train: out, val, vocab: v }
    }

    pub fn train_tokens(&self) -> usize {
        self.train.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SyntheticCorpus {
        SyntheticCorpus::generate(&CorpusConfig {
            tokens: 50_000,
            ..Default::default()
        })
    }

    #[test]
    fn deterministic_from_seed() {
        let a = small();
        let b = small();
        assert_eq!(a.train, b.train);
        assert_eq!(a.val, b.val);
    }

    #[test]
    fn sizes_and_vocab_bounds() {
        let c = small();
        assert_eq!(c.train.len(), 50_000);
        assert_eq!(c.val.len(), 5_000);
        assert!(c.train.iter().all(|&t| (t as usize) < c.vocab));
    }

    #[test]
    fn has_learnable_structure() {
        // Bigram conditional entropy must be clearly below unigram entropy —
        // that's the signal a 1-layer model can learn.
        let c = small();
        let v = c.vocab;
        let mut uni = vec![0f64; v];
        let mut big = std::collections::HashMap::<(u16, u16), f64>::new();
        for w in c.train.windows(2) {
            uni[w[0] as usize] += 1.0;
            *big.entry((w[0], w[1])).or_default() += 1.0;
        }
        let n = (c.train.len() - 1) as f64;
        let h_uni: f64 = uni
            .iter()
            .filter(|&&x| x > 0.0)
            .map(|&x| -(x / n) * (x / n).ln())
            .sum();
        let h_joint: f64 = big
            .values()
            .map(|&x| -(x / n) * (x / n).ln())
            .sum();
        let h_cond = h_joint - h_uni;
        assert!(
            h_cond < h_uni * 0.85,
            "h_cond={h_cond:.3} h_uni={h_uni:.3} — no structure"
        );
    }

    #[test]
    fn different_seeds_differ() {
        let a = small();
        let b = SyntheticCorpus::generate(&CorpusConfig {
            tokens: 50_000,
            seed: 999,
            ..Default::default()
        });
        assert_ne!(a.train[..100], b.train[..100]);
    }
}
