//! Data substrate: synthetic corpora + batch loading.
//!
//! The paper trains on C4 (unavailable offline); we substitute a
//! Zipf–Markov byte corpus whose statistics give informative loss curves
//! (DESIGN.md §Hardware-Adaptation), and a structured arithmetic "task"
//! corpus for the fine-tuning experiments (Tables 7–8) where exact-match
//! accuracy is measurable.

pub mod corpus;
pub mod loader;
pub mod task;

pub use corpus::{SyntheticCorpus, CorpusConfig};
pub use loader::BatchLoader;
pub use task::{TaskCorpus, TaskExample};
