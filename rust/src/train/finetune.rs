//! Fine-tuning driver (Tables 7–8 analog): load pre-trained weights, train
//! on the structured task corpus, report train loss + exact-match accuracy
//! via the `predict_<preset>` artifact, plus memory/runtime — the same
//! columns the paper reports for GSM-8k.

use anyhow::Result;

use crate::data::TaskCorpus;
use crate::optim::{LayerMeta, Optimizer};
use crate::runtime::client::Value;
use crate::runtime::{Executable, Manifest, ModelSpec, Runtime};
use crate::tensor::Matrix;
use crate::train::aot_optim::maybe_wrap_aot;
use crate::train::trainer::{clip_grads, init_params};
use crate::train::{LrSchedule, TrainConfig};
use crate::util::{Pcg64, Timer};

#[derive(Clone, Debug)]
pub struct FinetuneSummary {
    pub optimizer: String,
    pub rank: usize,
    pub final_train_loss: f64,
    pub accuracy: f64,
    pub wall_secs: f64,
    pub optimizer_state_bytes: u64,
}

pub struct Finetuner {
    pub cfg: TrainConfig,
    pub spec: ModelSpec,
    metas: Vec<LayerMeta>,
    fwdbwd: Executable,
    predict: Executable,
    pub params: Vec<Matrix>,
    corpus: TaskCorpus,
}

impl Finetuner {
    pub fn new(
        manifest: &Manifest,
        rt: &Runtime,
        cfg: TrainConfig,
        pretrained: Option<Vec<Matrix>>,
    ) -> Result<Self> {
        let spec = manifest.model_spec(&cfg.preset)?;
        let fwdbwd = rt.load(manifest.find(&format!("fwdbwd_{}", cfg.preset))?)?;
        let predict = rt.load(manifest.find(&format!("predict_{}", cfg.preset))?)?;
        let metas: Vec<LayerMeta> =
            spec.params.iter().map(|p| p.layer_meta()).collect();
        let params = match pretrained {
            Some(p) => {
                anyhow::ensure!(p.len() == spec.params.len(), "checkpoint mismatch");
                p
            }
            None => init_params(&spec, cfg.seed),
        };
        let corpus = TaskCorpus::generate(2048, 256, spec.seq_len, 99);
        Ok(Finetuner { cfg, spec, metas, fwdbwd, predict, params, corpus })
    }

    pub fn run(&mut self, manifest: &Manifest, rt: &Runtime) -> Result<FinetuneSummary> {
        let cfg = self.cfg.clone();
        let mut opt: Box<dyn Optimizer> = cfg.build_optimizer(&self.metas)?;
        if cfg.use_aot_optimizer {
            opt = maybe_wrap_aot(opt, &self.metas, &cfg, manifest, rt)?;
        }
        let sched = LrSchedule::Constant { lr: cfg.lr };
        let mut rng = Pcg64::new(cfg.seed, 0xf17e);
        let timer = Timer::start();
        let mut final_loss = f64::NAN;
        for step in 0..cfg.steps {
            let (tokens, shape) = self.corpus.batch(&mut rng, cfg.batch_per_worker);
            let mut inputs: Vec<Value> =
                self.params.iter().map(|p| Value::F32(p.clone())).collect();
            inputs.push(Value::tokens(tokens, shape));
            let outs = self.fwdbwd.run(&inputs)?;
            final_loss = outs.scalar(0) as f64;
            let grads: Vec<Matrix> = outs.values.into_iter().skip(1).collect();
            let grads = clip_grads(grads, cfg.grad_clip);
            opt.step(&mut self.params, &grads, sched.at(step));
        }
        let accuracy = self.accuracy(64)?;
        Ok(FinetuneSummary {
            optimizer: opt.name().to_string(),
            rank: cfg.opt.rank,
            final_train_loss: final_loss,
            accuracy,
            wall_secs: timer.elapsed_secs(),
            optimizer_state_bytes: opt.memory_report().total(),
        })
    }

    /// Teacher-forced per-digit accuracy on held-out task answers, batched
    /// through the predict artifact. (Per-digit rather than whole-answer
    /// exact match: at this model scale whole-answer EM saturates at 0 for
    /// weak optimizers and hides the ordering the paper's tables compare;
    /// per-digit preserves it. The strict EM scorer remains available as
    /// `TaskCorpus::exact_match`.)
    pub fn accuracy(&self, limit: usize) -> Result<f64> {
        let b = self.spec.batch_per_worker;
        let seq = self.spec.seq_len;
        let n = self.corpus.test.len().min(limit);
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut i = 0usize;
        while i < n {
            let batch: Vec<&crate::data::TaskExample> = (0..b)
                .map(|k| &self.corpus.test[(i + k).min(n - 1)])
                .collect();
            let mut data = Vec::with_capacity(b * seq);
            for ex in &batch {
                data.extend(ex.tokens.iter().map(|&t| t as i32));
            }
            let mut inputs: Vec<Value> =
                self.params.iter().map(|p| Value::F32(p.clone())).collect();
            inputs.push(Value::tokens(data, vec![b, seq]));
            let outs = self.predict.run(&inputs)?;
            let preds = &outs.values[0]; // (b, seq) as f32-cast ints
            for (row, ex) in batch.iter().enumerate().take(n - i) {
                for (k, gold) in ex.answer.bytes().enumerate() {
                    let pos = ex.answer_start + k;
                    if pos >= 1 {
                        total += 1;
                        if preds.at(row, pos - 1) as usize == gold as usize {
                            correct += 1;
                        }
                    }
                }
            }
            i += b;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }
}
