//! Learning-rate schedules: linear warmup + cosine decay (the pre-training
//! default in GaLore/LDAdam/Dion experiments) and constant (fine-tuning).

#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    Constant { lr: f32 },
    /// Linear warmup to `lr`, then cosine decay to `lr·min_ratio`.
    WarmupCosine { lr: f32, warmup: usize, total: usize, min_ratio: f32 },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f32 {
        match *self {
            LrSchedule::Constant { lr } => lr,
            LrSchedule::WarmupCosine { lr, warmup, total, min_ratio } => {
                if warmup > 0 && step < warmup {
                    lr * (step as f32 + 1.0) / warmup as f32
                } else {
                    let t = (step.saturating_sub(warmup)) as f32
                        / (total.saturating_sub(warmup)).max(1) as f32;
                    let cos = 0.5 * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos());
                    lr * (min_ratio + (1.0 - min_ratio) * cos)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::WarmupCosine { lr: 1.0, warmup: 10, total: 100, min_ratio: 0.1 };
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(4) - 0.5).abs() < 1e-6);
        assert!((s.at(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_min() {
        let s = LrSchedule::WarmupCosine { lr: 1.0, warmup: 0, total: 100, min_ratio: 0.1 };
        assert!((s.at(0) - 1.0).abs() < 1e-5);
        assert!(s.at(50) < s.at(10));
        assert!((s.at(100) - 0.1).abs() < 1e-5);
        assert!((s.at(500) - 0.1).abs() < 1e-5); // clamped past total
    }

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant { lr: 0.3 };
        assert_eq!(s.at(0), 0.3);
        assert_eq!(s.at(10_000), 0.3);
    }
}
