//! Parameter checkpointing: a minimal binary format (magic, count, per-
//! tensor rows/cols + f32 payload, little-endian) so the fine-tuning
//! experiments can load the pre-trained weights the pre-training runs save.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Matrix;

const MAGIC: &[u8; 8] = b"FFTSUBv1";

pub fn save(path: impl AsRef<Path>, params: &[Matrix]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for p in params {
        f.write_all(&(p.rows as u32).to_le_bytes())?;
        f.write_all(&(p.cols as u32).to_le_bytes())?;
        for &v in &p.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Vec<Matrix>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad checkpoint magic");
    }
    let mut u32buf = [0u8; 4];
    f.read_exact(&mut u32buf)?;
    let count = u32::from_le_bytes(u32buf) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        f.read_exact(&mut u32buf)?;
        let rows = u32::from_le_bytes(u32buf) as usize;
        f.read_exact(&mut u32buf)?;
        let cols = u32::from_le_bytes(u32buf) as usize;
        let mut data = vec![0f32; rows * cols];
        let mut fbuf = [0u8; 4];
        for v in &mut data {
            f.read_exact(&mut fbuf)?;
            *v = f32::from_le_bytes(fbuf);
        }
        out.push(Matrix::from_vec(rows, cols, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn roundtrip() {
        let mut rng = Pcg64::seed(0);
        let params = vec![
            Matrix::randn(3, 5, 1.0, &mut rng),
            Matrix::randn(1, 7, 1.0, &mut rng),
        ];
        let path = std::env::temp_dir().join("fft_subspace_ckpt_test.bin");
        save(&path, &params).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(params, back);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("fft_subspace_ckpt_bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }
}
