//! Training checkpoints.
//!
//! Two on-disk formats, both little-endian:
//!
//! * **v1 (`FFTSUBv1`)** — params only: magic, tensor count, per-tensor
//!   rows/cols + f32 payload. What the fine-tuning experiments consume.
//! * **v2 (`FFTSUBv2`)** — full training state: the v1 params section
//!   followed by the step counter, the optimizer's reported name, and the
//!   optimizer's opaque state blob (`Optimizer::save_state` — typed stores,
//!   subspace/rotation/residual auxiliaries, RNG streams, all bit-exact),
//!   an *optional* gradient-sync section (`SYNC` marker + length + the
//!   `GradSync::save_state` payload — EF residuals under `comm=subspace`;
//!   omitted entirely when empty, so dense-mode files stay byte-identical
//!   to pre-subsystem writers), closed by a CRC-32 integrity footer
//!   (`CRC2` marker + [`crc32`] of every preceding byte). Footer-less v2
//!   files from before the fault-tolerance PR still load. `resume=`
//!   restores the state and continues the uninterrupted trajectory to the
//!   bit (`tests/resume_determinism.rs`, `tests/comm_determinism.rs`).
//!
//! **Every write is atomic**: the encoded bytes land in `<path>.tmp`,
//! are fsynced, and only then renamed over `path` — so a crash (or the
//! injected tear from [`crate::train::fault`]) mid-write can never
//! destroy the previous good checkpoint, it just leaves a `.tmp` corpse.
//! [`CheckpointRotation`] builds on that to keep a rolling window of
//! in-run snapshots (`ckpt_step%08d.bin`, keep-last-k) that the trainer's
//! `guard=rollback` path restores from.
//!
//! [`load`] / [`load_full`] accept both versions (v1 yields `state: None`).
//! Every header field read from the file is validated against the bytes
//! actually remaining **before** any allocation is sized from it, so a
//! truncated or corrupt file fails with context instead of attempting a
//! huge allocation and erroring at EOF.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::Matrix;
use crate::train::fault;
use crate::util::crc::crc32;

const MAGIC_V1: &[u8; 8] = b"FFTSUBv1";
const MAGIC_V2: &[u8; 8] = b"FFTSUBv2";
/// v2 integrity footer: this marker, then crc32 of every preceding byte.
const CRC_MARKER: &[u8; 4] = b"CRC2";
/// Optional v2 gradient-sync section: this marker, a u64 length, then the
/// `GradSync::save_state` payload. Only written when the payload is
/// non-empty.
const SYNC_MARKER: &[u8; 4] = b"SYNC";

/// The resumable-state section of a v2 checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    /// Completed optimizer steps at save time.
    pub step: u64,
    /// The optimizer's reported name at save time (a human-readable sanity
    /// label; the opaque blob carries its own strict fingerprint).
    pub optimizer: String,
    /// `Optimizer::save_state` payload (empty = params-only resume).
    pub opt_state: Vec<u8>,
    /// `GradSync::save_state` payload (per-worker EF residuals under
    /// `comm=subspace`). Empty under dense sync — and an empty payload is
    /// not written at all, keeping dense-mode files byte-identical to
    /// checkpoints from before the compressed-collectives subsystem.
    pub sync: Vec<u8>,
}

/// A parsed checkpoint of either version.
#[derive(Debug)]
pub struct Checkpoint {
    pub params: Vec<Matrix>,
    /// `Some` for v2 files, `None` for v1 (params-only).
    pub state: Option<TrainState>,
}

fn put_params(out: &mut Vec<u8>, params: &[Matrix]) {
    out.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for p in params {
        out.extend_from_slice(&(p.rows as u32).to_le_bytes());
        out.extend_from_slice(&(p.cols as u32).to_le_bytes());
        for &v in &p.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Serialize a params-only (v1) checkpoint.
fn encode_v1(params: &[Matrix]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC_V1);
    put_params(&mut out, params);
    out
}

/// Serialize a full-state (v2) checkpoint, CRC footer included.
fn encode_v2(params: &[Matrix], state: &TrainState) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC_V2);
    put_params(&mut out, params);
    out.extend_from_slice(&state.step.to_le_bytes());
    out.extend_from_slice(&(state.optimizer.len() as u32).to_le_bytes());
    out.extend_from_slice(state.optimizer.as_bytes());
    out.extend_from_slice(&(state.opt_state.len() as u64).to_le_bytes());
    out.extend_from_slice(&state.opt_state);
    if !state.sync.is_empty() {
        out.extend_from_slice(SYNC_MARKER);
        out.extend_from_slice(&(state.sync.len() as u64).to_le_bytes());
        out.extend_from_slice(&state.sync);
    }
    let crc = crc32(&out);
    out.extend_from_slice(CRC_MARKER);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Atomically replace `path` with `bytes`: write `<path>.tmp`, fsync,
/// rename. The previous file (if any) survives any failure before the
/// rename. Consults the fault injector's tear latch — an armed tear
/// writes only a prefix of the temp file and bails without renaming,
/// simulating a crash mid-write.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
        }
    }
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("creating {tmp:?}"))?;
    if let Some(tear) = fault::take_checkpoint_tear() {
        // injected crash: a prefix hits the disk, the rename never runs —
        // exactly the failure mode the atomic protocol defends against.
        // The .tmp corpse is left behind, as a real crash would leave it.
        f.write_all(&bytes[..tear.min(bytes.len())])?;
        f.sync_all()?;
        bail!(
            "injected fault: checkpoint write to {path:?} torn after \
             {tear} bytes"
        );
    }
    f.write_all(bytes)
        .with_context(|| format!("writing {tmp:?}"))?;
    f.sync_all()
        .with_context(|| format!("fsyncing {tmp:?}"))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {tmp:?} over {path:?}"))?;
    Ok(())
}

/// Save a params-only (v1) checkpoint. Atomic: tmp + fsync + rename.
pub fn save(path: impl AsRef<Path>, params: &[Matrix]) -> Result<()> {
    write_atomic(path.as_ref(), &encode_v1(params))
}

/// Save a full-state (v2) checkpoint: params + step + optimizer state +
/// CRC footer. Atomic: tmp + fsync + rename.
pub fn save_v2(path: impl AsRef<Path>, params: &[Matrix], state: &TrainState) -> Result<()> {
    write_atomic(path.as_ref(), &encode_v2(params, state))
}

/// Load the parameter tensors of a checkpoint (either version).
pub fn load(path: impl AsRef<Path>) -> Result<Vec<Matrix>> {
    Ok(load_full(path)?.params)
}

/// Load a checkpoint, including the v2 training state when present, and
/// verify the CRC footer on v2 files that carry one.
pub fn load_full(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .with_context(|| format!("opening checkpoint {path:?}"))?;
    // Cursor over the in-memory bytes; every count/shape read below is
    // checked against `remaining` before sizing an allocation from it —
    // the untrusted-header hardening.
    let mut pos = 0usize;
    let remaining = |pos: usize| (bytes.len() - pos) as u64;

    ensure!(bytes.len() >= 8, "checkpoint shorter than its magic");
    let v2 = match &bytes[..8] {
        m if m == MAGIC_V1 => false,
        m if m == MAGIC_V2 => true,
        _ => bail!("bad checkpoint magic"),
    };
    pos += 8;

    let take_u32 = |pos: &mut usize| -> Result<u32> {
        ensure!(remaining(*pos) >= 4, "corrupt checkpoint: truncated field");
        let v = u32::from_le_bytes(bytes[*pos..*pos + 4].try_into().unwrap());
        *pos += 4;
        Ok(v)
    };
    let take_u64 = |pos: &mut usize| -> Result<u64> {
        ensure!(remaining(*pos) >= 8, "corrupt checkpoint: truncated field");
        let v = u64::from_le_bytes(bytes[*pos..*pos + 8].try_into().unwrap());
        *pos += 8;
        Ok(v)
    };

    let count = take_u32(&mut pos)? as u64;
    // each tensor needs at least its 8-byte shape header
    ensure!(
        count * 8 <= remaining(pos),
        "corrupt checkpoint: header claims {count} tensors but only {} \
         bytes remain",
        remaining(pos)
    );
    let mut params = Vec::with_capacity(count as usize);
    for i in 0..count {
        let rows = take_u32(&mut pos)? as u64;
        let cols = take_u32(&mut pos)? as u64;
        let nbytes = rows
            .checked_mul(cols)
            .and_then(|e| e.checked_mul(4))
            .filter(|&b| b <= remaining(pos))
            .with_context(|| {
                format!(
                    "corrupt checkpoint: tensor {i} claims {rows}x{cols} \
                     but only {} bytes remain",
                    remaining(pos)
                )
            })?;
        let data: Vec<f32> = bytes[pos..pos + nbytes as usize]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        pos += nbytes as usize;
        params.push(Matrix::from_vec(rows as usize, cols as usize, data));
    }
    if !v2 {
        // strict framing: bytes after the declared tensors mean a corrupt
        // or doubly-written file, not a usable checkpoint
        ensure!(
            remaining(pos) == 0,
            "corrupt checkpoint: {} trailing bytes after the declared \
             {count} tensors",
            remaining(pos)
        );
        return Ok(Checkpoint { params, state: None });
    }

    ensure!(
        remaining(pos) >= 8 + 4,
        "corrupt checkpoint: v2 trailer truncated"
    );
    let step = take_u64(&mut pos)?;
    let name_len = take_u32(&mut pos)? as u64;
    ensure!(
        name_len <= remaining(pos),
        "corrupt checkpoint: optimizer name claims {name_len} bytes, {} \
         remain",
        remaining(pos)
    );
    let optimizer =
        String::from_utf8(bytes[pos..pos + name_len as usize].to_vec())
            .context("checkpoint optimizer name not UTF-8")?;
    pos += name_len as usize;
    ensure!(
        remaining(pos) >= 8,
        "corrupt checkpoint: state length truncated"
    );
    let state_len = take_u64(&mut pos)?;
    ensure!(
        state_len <= remaining(pos),
        "corrupt checkpoint: optimizer state claims {state_len} bytes, {} \
         remain",
        remaining(pos)
    );
    let opt_state = bytes[pos..pos + state_len as usize].to_vec();
    pos += state_len as usize;
    // optional gradient-sync section (absent in dense-mode and pre-PR-9
    // files): marker + u64 length + payload, before the CRC footer
    let sync = if remaining(pos) >= 12 && &bytes[pos..pos + 4] == SYNC_MARKER {
        pos += 4;
        let sync_len = take_u64(&mut pos)?;
        ensure!(
            sync_len <= remaining(pos),
            "corrupt checkpoint: sync state claims {sync_len} bytes, {} \
             remain",
            remaining(pos)
        );
        let payload = bytes[pos..pos + sync_len as usize].to_vec();
        pos += sync_len as usize;
        payload
    } else {
        Vec::new()
    };
    match remaining(pos) {
        // footer-less v2: written before the fault-tolerance PR
        0 => {}
        // CRC footer: marker + crc32 of everything before the footer
        8 => {
            ensure!(
                &bytes[pos..pos + 4] == CRC_MARKER,
                "corrupt checkpoint: 8 trailing bytes without a CRC marker"
            );
            let stored =
                u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
            let actual = crc32(&bytes[..pos]);
            ensure!(
                stored == actual,
                "corrupt checkpoint: CRC mismatch (stored {stored:#010x}, \
                 computed {actual:#010x}) — the file was damaged after \
                 writing"
            );
        }
        n => bail!(
            "corrupt checkpoint: {n} trailing bytes after the optimizer \
             state"
        ),
    }
    Ok(Checkpoint {
        params,
        state: Some(TrainState { step, optimizer, opt_state, sync }),
    })
}

/// Rolling in-run snapshot store: `ckpt_step%08d.bin` files in one
/// directory, pruned to the newest `keep` after every save. The step
/// number lives in the filename, so [`latest_in`] recovers the restore
/// point by listing the directory — no extra index file to corrupt.
pub struct CheckpointRotation {
    dir: PathBuf,
    keep: usize,
}

impl CheckpointRotation {
    /// `keep` is clamped to ≥ 1 (a rotation that retains nothing could
    /// delete the snapshot a rollback is about to need).
    pub fn new(dir: impl Into<PathBuf>, keep: usize) -> Self {
        Self { dir: dir.into(), keep: keep.max(1) }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Snapshot filename for a completed-step count.
    pub fn path_for(&self, step: u64) -> PathBuf {
        self.dir.join(format!("ckpt_step{step:08}.bin"))
    }

    /// Atomically write the snapshot for `step`, then prune to the newest
    /// `keep` snapshots. A failed write (torn, disk full) leaves every
    /// previous snapshot intact — the error propagates, pruning is
    /// skipped.
    pub fn save(
        &self,
        step: u64,
        params: &[Matrix],
        state: &TrainState,
    ) -> Result<PathBuf> {
        let path = self.path_for(step);
        save_v2(&path, params, state)?;
        self.prune()?;
        Ok(path)
    }

    /// Newest retained snapshot as `(step, path)`, or `None` if the
    /// directory holds no snapshots (or doesn't exist yet).
    pub fn latest(&self) -> Result<Option<(u64, PathBuf)>> {
        latest_in(&self.dir)
    }

    fn prune(&self) -> Result<()> {
        let mut steps = list_snapshots(&self.dir)?;
        if steps.len() <= self.keep {
            return Ok(());
        }
        steps.sort_unstable();
        for (_, path) in &steps[..steps.len() - self.keep] {
            std::fs::remove_file(path)
                .with_context(|| format!("pruning old snapshot {path:?}"))?;
        }
        Ok(())
    }
}

/// All `ckpt_step*.bin` snapshots in `dir` as `(step, path)`, unsorted.
/// `.tmp` corpses from torn writes and unrelated files are ignored.
fn list_snapshots(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => {
            return Err(e).with_context(|| format!("listing snapshots in {dir:?}"))
        }
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(stem) =
            name.strip_prefix("ckpt_step").and_then(|s| s.strip_suffix(".bin"))
        else {
            continue;
        };
        if let Ok(step) = stem.parse::<u64>() {
            out.push((step, entry.path()));
        }
    }
    Ok(out)
}

/// Newest snapshot in `dir` as `(step, path)`; `None` for an empty or
/// missing directory.
pub fn latest_in(dir: impl AsRef<Path>) -> Result<Option<(u64, PathBuf)>> {
    Ok(list_snapshots(dir.as_ref())?.into_iter().max_by_key(|(s, _)| *s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn params() -> Vec<Matrix> {
        let mut rng = Pcg64::seed(0);
        vec![
            Matrix::randn(3, 5, 1.0, &mut rng),
            Matrix::randn(1, 7, 1.0, &mut rng),
        ]
    }

    fn state() -> TrainState {
        TrainState {
            step: 123,
            optimizer: "dct-adamw".into(),
            opt_state: vec![7, 0, 255, 1, 2, 3],
            sync: vec![9, 0, 42],
        }
    }

    #[test]
    fn roundtrip() {
        let params = params();
        let path = std::env::temp_dir().join("fft_subspace_ckpt_test.bin");
        save(&path, &params).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(params, back);
        // v1 files carry no state
        assert!(load_full(&path).unwrap().state.is_none());
        // the atomic protocol leaves no temp file behind on success
        assert!(!path.with_extension("bin.tmp").exists());
    }

    #[test]
    fn v2_roundtrip_preserves_state() {
        let params = params();
        let state = state();
        let path = std::env::temp_dir().join("fft_subspace_ckpt_v2_test.bin");
        save_v2(&path, &params, &state).unwrap();
        let ck = load_full(&path).unwrap();
        assert_eq!(ck.params, params);
        assert_eq!(ck.state.unwrap(), state);
        // and the params-only reader accepts v2 files too
        assert_eq!(load(&path).unwrap(), params);
    }

    #[test]
    fn empty_sync_keeps_legacy_layout() {
        // dense-mode state (empty sync) must encode without any SYNC
        // section — byte-identical to files from before the subsystem
        let params = params();
        let st = TrainState { sync: Vec::new(), ..state() };
        let encoded = encode_v2(&params, &st);
        assert!(
            !encoded.windows(4).any(|w| w == SYNC_MARKER),
            "empty sync must not emit a SYNC section"
        );
        let path = std::env::temp_dir().join("fft_subspace_ckpt_nosync.bin");
        std::fs::write(&path, &encoded).unwrap();
        let ck = load_full(&path).unwrap();
        assert_eq!(ck.state.unwrap(), st);
    }

    #[test]
    fn footerless_v2_still_loads() {
        // files written before the CRC footer existed end right after the
        // optimizer state — they must keep loading
        let params = params();
        let state = state();
        let path = std::env::temp_dir().join("fft_subspace_ckpt_nofooter.bin");
        let encoded = encode_v2(&params, &state);
        std::fs::write(&path, &encoded[..encoded.len() - 8]).unwrap();
        let ck = load_full(&path).unwrap();
        assert_eq!(ck.state.unwrap(), state);
    }

    #[test]
    fn rejects_crc_mismatch() {
        // flip one payload bit after writing: the footer must catch it
        let params = params();
        let path = std::env::temp_dir().join("fft_subspace_ckpt_crcflip.bin");
        save_v2(&path, &params, &state()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_full(&path).unwrap_err().to_string();
        // either the CRC catches it, or (if the flip hit a length field)
        // the framing checks do — both report corruption
        assert!(err.contains("corrupt") || err.contains("CRC"), "{err}");
    }

    #[test]
    fn torn_write_preserves_previous_checkpoint() {
        let _guard = crate::train::fault::TEAR_TEST_LOCK
            .lock()
            .unwrap_or_else(|poison| poison.into_inner());
        let dir = std::env::temp_dir().join("fft_subspace_ckpt_tear_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("ckpt.bin");
        let params = params();
        let good = state();
        save_v2(&path, &params, &good).unwrap();

        // injected tear 20 bytes into the next write: the save errors, the
        // previous good file is untouched and still CRC-clean
        let newer = TrainState { step: 456, ..good.clone() };
        crate::train::fault::arm_checkpoint_tear(20);
        let err = save_v2(&path, &params, &newer).unwrap_err().to_string();
        assert!(err.contains("torn"), "{err}");
        let ck = load_full(&path).unwrap();
        assert_eq!(ck.state.unwrap(), good);
        // the crash left its .tmp corpse, which is itself unreadable
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        assert!(load_full(PathBuf::from(tmp)).is_err());
    }

    #[test]
    fn rotation_keeps_last_k_and_finds_latest() {
        let dir = std::env::temp_dir().join("fft_subspace_ckpt_rot_test");
        let _ = std::fs::remove_dir_all(&dir);
        let rot = CheckpointRotation::new(&dir, 2);
        assert!(rot.latest().unwrap().is_none());
        let params = params();
        for step in [3u64, 6, 9, 12] {
            let st = TrainState { step, ..state() };
            rot.save(step, &params, &st).unwrap();
        }
        // keep-last-2: steps 9 and 12 survive, 3 and 6 were pruned
        let (latest_step, latest_path) = rot.latest().unwrap().unwrap();
        assert_eq!(latest_step, 12);
        assert!(rot.path_for(9).exists());
        assert!(!rot.path_for(3).exists());
        assert!(!rot.path_for(6).exists());
        let ck = load_full(&latest_path).unwrap();
        assert_eq!(ck.state.unwrap().step, 12);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("fft_subspace_ckpt_bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_oversized_count_without_allocating() {
        // valid magic, then a tensor count far beyond the file length: the
        // loader must bail on the header check, not Vec::with_capacity(4B)
        let path = std::env::temp_dir().join("fft_subspace_ckpt_count.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"FFTSUBv1");
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
    }

    #[test]
    fn rejects_oversized_tensor_shape() {
        // one tensor claiming u32::MAX × u32::MAX (rows*cols*4 overflows
        // u64's headroom for the file) in a tiny file
        let path = std::env::temp_dir().join("fft_subspace_ckpt_shape.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"FFTSUBv1");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 16]);
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
    }

    #[test]
    fn rejects_trailing_garbage() {
        // two checkpoints concatenated (or any appended bytes) must not
        // silently load as the first one
        let params = params();
        let path = std::env::temp_dir().join("fft_subspace_ckpt_trail.bin");
        save(&path, &params).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"junk");
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn rejects_truncated_v2_trailer() {
        let params = params();
        let state = TrainState {
            step: 9,
            optimizer: "trion".into(),
            opt_state: vec![1; 64],
            sync: Vec::new(),
        };
        let path = std::env::temp_dir().join("fft_subspace_ckpt_trunc.bin");
        save_v2(&path, &params, &state).unwrap();
        let full = std::fs::read(&path).unwrap();
        // chop into the state payload: the declared length now overruns
        std::fs::write(&path, &full[..full.len() - 32]).unwrap();
        let err = load_full(&path).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
        // params are intact, so the params-only path still... also errors:
        // the file declares state it doesn't carry. That is deliberate —
        // a truncated file should never be silently usable.
        assert!(load(&path).is_err());
    }
}
