//! Training checkpoints.
//!
//! Two on-disk formats, both little-endian:
//!
//! * **v1 (`FFTSUBv1`)** — params only: magic, tensor count, per-tensor
//!   rows/cols + f32 payload. What the fine-tuning experiments consume.
//! * **v2 (`FFTSUBv2`)** — full training state: the v1 params section
//!   followed by the step counter, the optimizer's reported name, and the
//!   optimizer's opaque state blob (`Optimizer::save_state` — typed stores,
//!   subspace/rotation/residual auxiliaries, RNG streams, all bit-exact).
//!   `resume=` restores it and continues the uninterrupted trajectory to
//!   the bit (`tests/resume_determinism.rs`).
//!
//! [`load`] / [`load_full`] accept both versions (v1 yields `state: None`).
//! Every header field read from the file is validated against the bytes
//! actually remaining **before** any allocation is sized from it, so a
//! truncated or corrupt file fails with context instead of attempting a
//! huge allocation and erroring at EOF.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::Matrix;

const MAGIC_V1: &[u8; 8] = b"FFTSUBv1";
const MAGIC_V2: &[u8; 8] = b"FFTSUBv2";

/// The resumable-state section of a v2 checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    /// Completed optimizer steps at save time.
    pub step: u64,
    /// The optimizer's reported name at save time (a human-readable sanity
    /// label; the opaque blob carries its own strict fingerprint).
    pub optimizer: String,
    /// `Optimizer::save_state` payload (empty = params-only resume).
    pub opt_state: Vec<u8>,
}

/// A parsed checkpoint of either version.
#[derive(Debug)]
pub struct Checkpoint {
    pub params: Vec<Matrix>,
    /// `Some` for v2 files, `None` for v1 (params-only).
    pub state: Option<TrainState>,
}

fn write_params(f: &mut impl Write, params: &[Matrix]) -> Result<()> {
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for p in params {
        f.write_all(&(p.rows as u32).to_le_bytes())?;
        f.write_all(&(p.cols as u32).to_le_bytes())?;
        for &v in &p.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Save a params-only (v1) checkpoint.
pub fn save(path: impl AsRef<Path>, params: &[Matrix]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC_V1)?;
    write_params(&mut f, params)
}

/// Save a full-state (v2) checkpoint: params + step + optimizer state.
pub fn save_v2(path: impl AsRef<Path>, params: &[Matrix], state: &TrainState) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC_V2)?;
    write_params(&mut f, params)?;
    f.write_all(&state.step.to_le_bytes())?;
    f.write_all(&(state.optimizer.len() as u32).to_le_bytes())?;
    f.write_all(state.optimizer.as_bytes())?;
    f.write_all(&(state.opt_state.len() as u64).to_le_bytes())?;
    f.write_all(&state.opt_state)?;
    Ok(())
}

/// Load the parameter tensors of a checkpoint (either version).
pub fn load(path: impl AsRef<Path>) -> Result<Vec<Matrix>> {
    Ok(load_full(path)?.params)
}

/// Load a checkpoint, including the v2 training state when present.
pub fn load_full(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let path = path.as_ref();
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {path:?}"))?;
    // Every count/shape read below is checked against `remaining` before
    // sizing an allocation from it — the untrusted-header hardening.
    let mut remaining = file.metadata()?.len();
    let mut f = std::io::BufReader::new(file);

    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    ensure!(remaining >= 8, "checkpoint shorter than its magic");
    remaining -= 8;
    let v2 = match &magic {
        m if m == MAGIC_V1 => false,
        m if m == MAGIC_V2 => true,
        _ => bail!("bad checkpoint magic"),
    };

    let mut u32buf = [0u8; 4];
    let mut u64buf = [0u8; 8];
    f.read_exact(&mut u32buf)?;
    remaining -= 4;
    let count = u32::from_le_bytes(u32buf) as u64;
    // each tensor needs at least its 8-byte shape header
    ensure!(
        count * 8 <= remaining,
        "corrupt checkpoint: header claims {count} tensors but only \
         {remaining} bytes remain"
    );
    let mut params = Vec::with_capacity(count as usize);
    for i in 0..count {
        f.read_exact(&mut u32buf)?;
        let rows = u32::from_le_bytes(u32buf) as u64;
        f.read_exact(&mut u32buf)?;
        let cols = u32::from_le_bytes(u32buf) as u64;
        remaining -= 8;
        let bytes = rows
            .checked_mul(cols)
            .and_then(|e| e.checked_mul(4))
            .filter(|&b| b <= remaining)
            .with_context(|| {
                format!(
                    "corrupt checkpoint: tensor {i} claims {rows}x{cols} \
                     but only {remaining} bytes remain"
                )
            })?;
        let mut raw = vec![0u8; bytes as usize];
        f.read_exact(&mut raw)?;
        remaining -= bytes;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        params.push(Matrix::from_vec(rows as usize, cols as usize, data));
    }
    if !v2 {
        // strict framing: bytes after the declared tensors mean a corrupt
        // or doubly-written file, not a usable checkpoint
        ensure!(
            remaining == 0,
            "corrupt checkpoint: {remaining} trailing bytes after the \
             declared {count} tensors"
        );
        return Ok(Checkpoint { params, state: None });
    }

    ensure!(remaining >= 8 + 4, "corrupt checkpoint: v2 trailer truncated");
    f.read_exact(&mut u64buf)?;
    remaining -= 8;
    let step = u64::from_le_bytes(u64buf);
    f.read_exact(&mut u32buf)?;
    remaining -= 4;
    let name_len = u32::from_le_bytes(u32buf) as u64;
    ensure!(
        name_len <= remaining,
        "corrupt checkpoint: optimizer name claims {name_len} bytes, \
         {remaining} remain"
    );
    let mut name = vec![0u8; name_len as usize];
    f.read_exact(&mut name)?;
    remaining -= name_len;
    let optimizer =
        String::from_utf8(name).context("checkpoint optimizer name not UTF-8")?;
    ensure!(remaining >= 8, "corrupt checkpoint: state length truncated");
    f.read_exact(&mut u64buf)?;
    remaining -= 8;
    let state_len = u64::from_le_bytes(u64buf);
    ensure!(
        state_len <= remaining,
        "corrupt checkpoint: optimizer state claims {state_len} bytes, \
         {remaining} remain"
    );
    let mut opt_state = vec![0u8; state_len as usize];
    f.read_exact(&mut opt_state)?;
    remaining -= state_len;
    ensure!(
        remaining == 0,
        "corrupt checkpoint: {remaining} trailing bytes after the optimizer \
         state"
    );
    Ok(Checkpoint {
        params,
        state: Some(TrainState { step, optimizer, opt_state }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn params() -> Vec<Matrix> {
        let mut rng = Pcg64::seed(0);
        vec![
            Matrix::randn(3, 5, 1.0, &mut rng),
            Matrix::randn(1, 7, 1.0, &mut rng),
        ]
    }

    #[test]
    fn roundtrip() {
        let params = params();
        let path = std::env::temp_dir().join("fft_subspace_ckpt_test.bin");
        save(&path, &params).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(params, back);
        // v1 files carry no state
        assert!(load_full(&path).unwrap().state.is_none());
    }

    #[test]
    fn v2_roundtrip_preserves_state() {
        let params = params();
        let state = TrainState {
            step: 123,
            optimizer: "dct-adamw".into(),
            opt_state: vec![7, 0, 255, 1, 2, 3],
        };
        let path = std::env::temp_dir().join("fft_subspace_ckpt_v2_test.bin");
        save_v2(&path, &params, &state).unwrap();
        let ck = load_full(&path).unwrap();
        assert_eq!(ck.params, params);
        assert_eq!(ck.state.unwrap(), state);
        // and the params-only reader accepts v2 files too
        assert_eq!(load(&path).unwrap(), params);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("fft_subspace_ckpt_bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_oversized_count_without_allocating() {
        // valid magic, then a tensor count far beyond the file length: the
        // loader must bail on the header check, not Vec::with_capacity(4B)
        let path = std::env::temp_dir().join("fft_subspace_ckpt_count.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"FFTSUBv1");
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
    }

    #[test]
    fn rejects_oversized_tensor_shape() {
        // one tensor claiming u32::MAX × u32::MAX (rows*cols*4 overflows
        // u64's headroom for the file) in a tiny file
        let path = std::env::temp_dir().join("fft_subspace_ckpt_shape.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"FFTSUBv1");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 16]);
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
    }

    #[test]
    fn rejects_trailing_garbage() {
        // two checkpoints concatenated (or any appended bytes) must not
        // silently load as the first one
        let params = params();
        let path = std::env::temp_dir().join("fft_subspace_ckpt_trail.bin");
        save(&path, &params).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"junk");
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn rejects_truncated_v2_trailer() {
        let params = params();
        let state = TrainState {
            step: 9,
            optimizer: "trion".into(),
            opt_state: vec![1; 64],
        };
        let path = std::env::temp_dir().join("fft_subspace_ckpt_trunc.bin");
        save_v2(&path, &params, &state).unwrap();
        let full = std::fs::read(&path).unwrap();
        // chop into the state payload: the declared length now overruns
        std::fs::write(&path, &full[..full.len() - 32]).unwrap();
        let err = load_full(&path).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
        // params are intact, so the params-only path still... also errors:
        // the file declares state it doesn't carry. That is deliberate —
        // a truncated file should never be silently usable.
        assert!(load(&path).is_err());
    }
}
