//! Deterministic fault injection for the recovery paths.
//!
//! Every fault-tolerance mechanism in this repo (guards, atomic
//! checkpoint writes, worker-lane retry) is exercised by *injected*
//! faults, not by luck: a seeded [`FaultInjector`] built from a
//! [`FaultPlan`] corrupts exactly the step/byte/lane the plan names, and
//! nothing else. Plans come from the `fault=` config key or the
//! `FFT_SUBSPACE_FAULT` environment variable (config wins), with a tiny
//! grammar of comma-separated directives:
//!
//! ```text
//! grad-nan@STEP          poison one element of a gradient at STEP (NaN)
//! grad-nan@STEP.LAYER    …of layer LAYER specifically
//! grad-inf@STEP[.LAYER]  same, with +Inf
//! ckpt-tear@BYTES        truncate the next checkpoint write at BYTES
//! worker-fail@STEP.LANE  panic worker lane LANE once at STEP
//! seed@N                 seed for the corrupted-element choice
//! ```
//!
//! e.g. `FFT_SUBSPACE_FAULT=grad-nan@7.2,ckpt-tear@64`. Steps are 0-based
//! trainer steps. **Every fault is one-shot**: after it fires once it is
//! disarmed, which is what lets a `guard=rollback` run replay through the
//! faulted step cleanly and converge to the fault-free bits.
//!
//! The checkpoint tear is armed through a process-global latch rather
//! than threaded through the checkpoint API: `checkpoint::write_atomic`
//! consults [`take_checkpoint_tear`] (a destructive read) right before
//! writing, so the injection point lives exactly where a real crash
//! would, without the production signature carrying test plumbing.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use anyhow::{bail, Context, Result};

use crate::tensor::Matrix;
use crate::util::Pcg64;

/// Parsed fault specification. `None`/`usize::MAX` fields mean "not
/// armed". See the module docs for the grammar.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// Step whose gradient gets one poisoned element.
    pub grad_step: Option<usize>,
    /// `false` → NaN, `true` → +Inf.
    pub grad_inf: bool,
    /// Layer index to poison; `usize::MAX` → seeded choice over layers.
    pub grad_layer: usize,
    /// Truncate the next checkpoint write after this many bytes.
    pub tear_at: Option<usize>,
    /// `(step, lane)`: panic this worker lane once at this step.
    pub worker_fail: Option<(usize, usize)>,
    /// Seed for the corrupted-element (and layer) choice.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            grad_step: None,
            grad_inf: false,
            grad_layer: usize::MAX,
            tear_at: None,
            worker_fail: None,
            seed: 0,
        }
    }
}

fn parse_num(s: &str, what: &str) -> Result<usize> {
    s.parse::<usize>()
        .with_context(|| format!("fault spec: bad {what} {s:?}"))
}

impl FaultPlan {
    /// Parse a spec string (see module docs). Empty string → empty plan.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (name, arg) = part
                .split_once('@')
                .with_context(|| format!("fault spec: {part:?} is not NAME@ARG"))?;
            match name {
                "grad-nan" | "grad-inf" => {
                    let (step, layer) = match arg.split_once('.') {
                        Some((s, l)) => {
                            (parse_num(s, "step")?, parse_num(l, "layer")?)
                        }
                        None => (parse_num(arg, "step")?, usize::MAX),
                    };
                    plan.grad_step = Some(step);
                    plan.grad_layer = layer;
                    plan.grad_inf = name == "grad-inf";
                }
                "ckpt-tear" => plan.tear_at = Some(parse_num(arg, "byte offset")?),
                "worker-fail" => {
                    let (s, l) = arg.split_once('.').with_context(|| {
                        format!("fault spec: worker-fail wants STEP.LANE, got {arg:?}")
                    })?;
                    plan.worker_fail =
                        Some((parse_num(s, "step")?, parse_num(l, "lane")?));
                }
                "seed" => plan.seed = parse_num(arg, "seed")? as u64,
                _ => bail!("fault spec: unknown directive {name:?} in {part:?}"),
            }
        }
        Ok(plan)
    }

    /// Plan from `FFT_SUBSPACE_FAULT`, or the empty plan if unset.
    pub fn from_env() -> Result<Self> {
        match std::env::var("FFT_SUBSPACE_FAULT") {
            Ok(spec) => Self::parse(&spec),
            Err(_) => Ok(Self::default()),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.grad_step.is_none()
            && self.tear_at.is_none()
            && self.worker_fail.is_none()
    }
}

/// Stream constant separating the injector's RNG from data/init streams.
const FAULT_STREAM: u64 = 0xfa017;

/// Runtime injector: a plan plus one-shot latches. Shared by reference
/// between the trainer and its worker closures (all methods take `&self`).
pub struct FaultInjector {
    plan: FaultPlan,
    grad_fired: AtomicBool,
    worker_fired: AtomicBool,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        Self {
            plan,
            grad_fired: AtomicBool::new(false),
            worker_fired: AtomicBool::new(false),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// If the plan poisons `step`'s gradient and hasn't fired yet, write
    /// NaN/+Inf into one seeded element and return the poison's name.
    /// Deterministic: the element is chosen by a fresh `Pcg64` from the
    /// plan seed, independent of call history.
    pub fn corrupt_grads(
        &self,
        step: usize,
        grads: &mut [Matrix],
    ) -> Option<&'static str> {
        if self.plan.grad_step != Some(step) || grads.is_empty() {
            return None;
        }
        if self.grad_fired.swap(true, Ordering::SeqCst) {
            return None;
        }
        let mut rng = Pcg64::new(self.plan.seed, FAULT_STREAM);
        let layer = if self.plan.grad_layer == usize::MAX {
            rng.usize_below(grads.len())
        } else {
            self.plan.grad_layer.min(grads.len() - 1)
        };
        let g = &mut grads[layer];
        let at = rng.usize_below(g.data.len().max(1));
        let (poison, name) = if self.plan.grad_inf {
            (f32::INFINITY, "grad-inf")
        } else {
            (f32::NAN, "grad-nan")
        };
        g.data[at] = poison;
        crate::obs::count_fault_firing();
        Some(name)
    }

    /// Panic once if the plan fails worker `lane` at `step`. Called from
    /// inside the pool closure so the `WorkerSet` retry path sees it as a
    /// real lane failure.
    pub fn maybe_fail_worker(&self, step: usize, lane: usize) {
        if self.plan.worker_fail == Some((step, lane))
            && !self.worker_fired.swap(true, Ordering::SeqCst)
        {
            crate::obs::count_fault_firing();
            panic!("injected fault: worker lane {lane} failed at step {step}");
        }
    }

    /// Arm the global checkpoint-tear latch from this plan (no-op if the
    /// plan has no tear). One-shot overall: combined with the destructive
    /// read in `take_checkpoint_tear`, only the first write after arming
    /// tears.
    pub fn arm_checkpoint_tear(&self) {
        if let Some(at) = self.plan.tear_at {
            arm_checkpoint_tear(at);
        }
    }
}

/// `usize::MAX` = disarmed. A global latch (not a field on the injector)
/// so `checkpoint::write_atomic` can consult it without its signature
/// carrying test plumbing.
static TEAR_AT: AtomicUsize = AtomicUsize::new(usize::MAX);

/// Arm the next checkpoint write to stop after `at` bytes (no rename).
pub fn arm_checkpoint_tear(at: usize) {
    TEAR_AT.store(at, Ordering::SeqCst);
}

/// Destructive read of the tear latch: returns the armed byte count and
/// disarms. Called by `checkpoint::write_atomic` before each write.
pub fn take_checkpoint_tear() -> Option<usize> {
    let at = TEAR_AT.swap(usize::MAX, Ordering::SeqCst);
    (at != usize::MAX).then_some(at)
}

/// The latch is process-global, so in-crate tests that arm it serialize
/// on this lock (unit tests share one process and run concurrently).
/// Poison-tolerant like the SIMD override lock.
#[cfg(test)]
pub(crate) static TEAR_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let plan =
            FaultPlan::parse("grad-nan@7.2, ckpt-tear@64,worker-fail@3.1,seed@9")
                .unwrap();
        assert_eq!(plan.grad_step, Some(7));
        assert_eq!(plan.grad_layer, 2);
        assert!(!plan.grad_inf);
        assert_eq!(plan.tear_at, Some(64));
        assert_eq!(plan.worker_fail, Some((3, 1)));
        assert_eq!(plan.seed, 9);
    }

    #[test]
    fn parses_inf_without_layer_and_empty() {
        let plan = FaultPlan::parse("grad-inf@5").unwrap();
        assert_eq!(plan.grad_step, Some(5));
        assert_eq!(plan.grad_layer, usize::MAX);
        assert!(plan.grad_inf);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ").unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in ["grad-nan", "grad-nan@x", "worker-fail@3", "bogus@1"] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn grad_corruption_is_one_shot_and_deterministic() {
        let plan = FaultPlan::parse("grad-nan@2.0,seed@5").unwrap();
        let mk = || vec![Matrix::zeros(4, 4), Matrix::zeros(3, 3)];

        let inj_a = FaultInjector::new(plan.clone());
        let mut g_a = mk();
        assert_eq!(inj_a.corrupt_grads(1, &mut g_a), None); // wrong step
        assert_eq!(inj_a.corrupt_grads(2, &mut g_a), Some("grad-nan"));
        let poisoned: Vec<usize> = g_a[0]
            .data
            .iter()
            .enumerate()
            .filter(|(_, x)| x.is_nan())
            .map(|(i, _)| i)
            .collect();
        assert_eq!(poisoned.len(), 1);
        assert!(g_a[1].data.iter().all(|x| x.is_finite()));
        // one-shot: the replayed step is clean
        let mut g_b = mk();
        assert_eq!(inj_a.corrupt_grads(2, &mut g_b), None);
        assert!(g_b[0].data.iter().all(|x| x.is_finite()));

        // a fresh injector with the same plan poisons the same element
        let inj_b = FaultInjector::new(plan);
        let mut g_c = mk();
        inj_b.corrupt_grads(2, &mut g_c);
        assert!(g_c[0].data[poisoned[0]].is_nan());
    }

    #[test]
    fn tear_latch_is_destructive() {
        let _guard =
            TEAR_TEST_LOCK.lock().unwrap_or_else(|poison| poison.into_inner());
        arm_checkpoint_tear(17);
        assert_eq!(take_checkpoint_tear(), Some(17));
        assert_eq!(take_checkpoint_tear(), None);
    }
}
