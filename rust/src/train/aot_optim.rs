//! AOT-graph-backed optimizers: the full three-layer composition.
//!
//! Where `compile/aot.py` exported a per-layer update graph matching a
//! layer's oriented shape (`trion_{R}x{C}_r{r}` / `dctadamw_{R}x{C}_r{r}`),
//! the update runs through PJRT — i.e. through the Pallas kernels of
//! Layer 1 — instead of the rust-native math. Layers without a matching
//! artifact (embeddings, norms, other shapes) fall back to dense AdamW,
//! and integration tests pin the AOT path against the rust-native path.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::fft::dct2_matrix;
use crate::optim::common::{
    deorient, orient, shape_factor, AdamState, LayerMeta, MemoryReport,
    Optimizer, OptimizerKind,
};
use crate::runtime::client::Value;
use crate::runtime::{Executable, Manifest, Runtime};
use crate::tensor::Matrix;
use crate::train::TrainConfig;

enum LayerState {
    /// Trion AOT: momentum threaded through the HLO graph.
    Trion { exe: Executable, momentum: Matrix, dim: usize },
    /// DCT-AdamW AOT: (m, v, ef, idx, t) threaded through the HLO graph.
    DctAdamW {
        exe: Executable,
        m: Matrix,
        v: Matrix,
        ef: Matrix,
        idx: Vec<i32>,
        dim: usize,
        rank: usize,
    },
    /// Dion AOT baseline.
    Dion { exe: Executable, momentum: Matrix, q: Matrix },
    Adam(AdamState),
}

pub struct AotOptimizer {
    metas: Vec<LayerMeta>,
    states: Vec<LayerState>,
    /// DCT matrices per column dimension, shared across layers.
    dct: BTreeMap<usize, Matrix>,
    kind: OptimizerKind,
    default_rank: usize,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    step: u64,
    aot_layers: usize,
}

/// Wrap `inner` with the AOT execution path when the optimizer family has
/// exported update graphs; otherwise return `inner` unchanged.
pub fn maybe_wrap_aot(
    inner: Box<dyn Optimizer>,
    metas: &[LayerMeta],
    cfg: &TrainConfig,
    manifest: &Manifest,
    rt: &Runtime,
) -> Result<Box<dyn Optimizer>> {
    // The exported update graphs encode the stock presets; an engine grid
    // point (source=/residual=/rotation=/rank-norm= overrides) has no
    // matching artifact, so keep the rust-native engine rather than
    // silently running the wrong optimizer.
    if cfg.has_engine_overrides() || cfg.rank_norm_override.is_some() {
        eprintln!(
            "warning: no AOT artifacts exist for engine policy overrides \
             (source/residual/rotation/rank-norm) — the overrides are \
             honored, but {} runs on the rust-native path instead of the \
             AOT graphs",
            inner.name()
        );
        return Ok(inner);
    }
    let family = match cfg.optimizer {
        OptimizerKind::Trion => "trion",
        OptimizerKind::DctAdamW => "dctadamw",
        OptimizerKind::Dion => "dion",
        _ => return Ok(inner),
    };
    let opt = AotOptimizer::new(metas, cfg, manifest, rt, family)?;
    if opt.aot_layers == 0 {
        eprintln!(
            "warning: --use-aot-optimizer set but no {family} artifacts match \
             this preset's layer shapes; using the rust-native path"
        );
        return Ok(inner);
    }
    Ok(Box::new(opt))
}

impl AotOptimizer {
    pub fn new(
        metas: &[LayerMeta],
        cfg: &TrainConfig,
        manifest: &Manifest,
        rt: &Runtime,
        family: &str,
    ) -> Result<Self> {
        let mut dct: BTreeMap<usize, Matrix> = BTreeMap::new();
        let mut states = Vec::with_capacity(metas.len());
        let mut aot_layers = 0usize;
        let mut rng = crate::util::Pcg64::new(cfg.seed, 0xa07);
        for meta in metas {
            let (rr, cc) = meta.oriented();
            let rank = cfg.opt.rank.min(cc);
            let art = if meta.kind.low_rank_eligible() {
                manifest.optimizer_graph(family, rr, cc, rank)
            } else {
                None
            };
            let state = match art {
                None => LayerState::Adam(AdamState::new(meta.rows, meta.cols)),
                Some(spec) => {
                    aot_layers += 1;
                    let exe = rt.load(spec)?;
                    dct.entry(cc).or_insert_with(|| dct2_matrix(cc));
                    match family {
                        "trion" => LayerState::Trion {
                            exe,
                            momentum: Matrix::zeros(rr, cc),
                            dim: cc,
                        },
                        "dctadamw" => LayerState::DctAdamW {
                            exe,
                            m: Matrix::zeros(rr, rank),
                            v: Matrix::zeros(rr, rank),
                            ef: Matrix::zeros(rr, cc),
                            idx: (0..rank as i32).collect(),
                            dim: cc,
                            rank,
                        },
                        "dion" => {
                            let g0 = Matrix::randn(cc, rank, 1.0, &mut rng);
                            let (q, _) = crate::linalg::qr_thin(&g0);
                            LayerState::Dion {
                                exe,
                                momentum: Matrix::zeros(rr, cc),
                                q,
                            }
                        }
                        other => anyhow::bail!("unknown AOT family {other}"),
                    }
                }
            };
            states.push(state);
        }
        Ok(AotOptimizer {
            metas: metas.to_vec(),
            states,
            dct,
            kind: cfg.optimizer.clone(),
            default_rank: cfg.opt.rank,
            beta1: cfg.opt.beta1,
            beta2: cfg.opt.beta2,
            eps: cfg.opt.eps,
            weight_decay: cfg.opt.weight_decay,
            step: 0,
            aot_layers,
        })
    }

    pub fn aot_layer_count(&self) -> usize {
        self.aot_layers
    }
}

impl Optimizer for AotOptimizer {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let t = self.step;
        // Layers whose AOT graph errored this step: demoted to the dense
        // AdamW fallback *after* the loop (replacing states[i] mid-match
        // would fight the borrow on self.states).
        let mut fall_back: Vec<usize> = Vec::new();
        for i in 0..params.len() {
            let meta = &self.metas[i];
            match &mut self.states[i] {
                LayerState::Adam(st) => st.update(
                    &mut params[i], &grads[i], lr, self.beta1, self.beta2,
                    self.eps, 0.0, t,
                ),
                LayerState::Trion { exe, momentum, dim } => {
                    let g = orient(meta, &grads[i]);
                    let q = self.dct[dim].clone();
                    let outs = match exe.run(&[
                        Value::F32(momentum.clone()),
                        Value::F32(g),
                        Value::F32(q),
                    ]) {
                        Ok(outs) => outs,
                        Err(e) => {
                            eprintln!(
                                "warning: trion AOT graph failed for layer \
                                 {} at step {t} ({e:#}) — falling back to \
                                 dense AdamW for this layer",
                                meta.name
                            );
                            fall_back.push(i);
                            continue;
                        }
                    };
                    // outputs: m_new, o_full, o_low, idx
                    *momentum = outs.values[0].clone();
                    let o_full = deorient(meta, outs.values[1].clone());
                    let (rr, cc) = meta.oriented();
                    params[i].scale(1.0 - lr * self.weight_decay);
                    params[i].axpy(-lr * shape_factor(rr, cc), &o_full);
                }
                LayerState::DctAdamW { exe, m, v, ef, idx, dim, rank } => {
                    let g = orient(meta, &grads[i]);
                    let q = self.dct[dim].clone();
                    let idx_vals: Vec<i32> = idx.clone();
                    let outs = match exe.run(&[
                        Value::F32(g),
                        Value::F32(q),
                        Value::F32(m.clone()),
                        Value::F32(v.clone()),
                        Value::F32(ef.clone()),
                        Value::I32(idx_vals, vec![*rank]),
                        Value::Scalar(t as f32),
                    ]) {
                        Ok(outs) => outs,
                        Err(e) => {
                            eprintln!(
                                "warning: dctadamw AOT graph failed for \
                                 layer {} at step {t} ({e:#}) — falling \
                                 back to dense AdamW for this layer",
                                meta.name
                            );
                            fall_back.push(i);
                            continue;
                        }
                    };
                    // outputs: update_full, m, v, ef, idx
                    let update = deorient(meta, outs.values[0].clone());
                    *m = outs.values[1].clone();
                    *v = outs.values[2].clone();
                    *ef = outs.values[3].clone();
                    *idx = outs.values[4].data.iter().map(|&x| x as i32).collect();
                    params[i].scale(1.0 - lr * self.weight_decay);
                    // graph already multiplied by its static lr; rescale to
                    // the schedule's lr
                    let graph_lr = 3e-3f32; // aot.py default
                    params[i].axpy(-lr / graph_lr, &update);
                }
                LayerState::Dion { exe, momentum, q } => {
                    let g = orient(meta, &grads[i]);
                    let outs = match exe.run(&[
                        Value::F32(momentum.clone()),
                        Value::F32(g),
                        Value::F32(q.clone()),
                    ]) {
                        Ok(outs) => outs,
                        Err(e) => {
                            eprintln!(
                                "warning: dion AOT graph failed for layer \
                                 {} at step {t} ({e:#}) — falling back to \
                                 dense AdamW for this layer",
                                meta.name
                            );
                            fall_back.push(i);
                            continue;
                        }
                    };
                    *momentum = outs.values[0].clone();
                    let o_full = deorient(meta, outs.values[1].clone());
                    *q = outs.values[2].clone();
                    let (rr, cc) = meta.oriented();
                    params[i].scale(1.0 - lr * self.weight_decay);
                    params[i].axpy(-lr * shape_factor(rr, cc), &o_full);
                }
            }
        }
        // Permanent demotion: a graph that failed once (lost plugin, bad
        // artifact) is not retried — the layer continues on the rust-native
        // dense path, starting with this step's update.
        for i in fall_back {
            let meta = &self.metas[i];
            let mut st = AdamState::new(meta.rows, meta.cols);
            st.update(
                &mut params[i], &grads[i], lr, self.beta1, self.beta2,
                self.eps, 0.0, t,
            );
            self.states[i] = LayerState::Adam(st);
            self.aot_layers -= 1;
        }
    }

    fn memory_report(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        for st in &self.states {
            match st {
                LayerState::Trion { momentum, .. } => {
                    r.add("momentum", momentum.bytes());
                    r.add("indices", 0); // indices live inside the graph call
                }
                LayerState::DctAdamW { m, v, ef, idx, .. } => {
                    r.add("adam_m_low", m.bytes());
                    r.add("adam_v_low", v.bytes());
                    r.add("ef", ef.bytes());
                    r.add("indices", (idx.len() * 4) as u64);
                }
                LayerState::Dion { momentum, q, .. } => {
                    r.add("momentum", momentum.bytes());
                    r.add("projector", q.bytes());
                }
                LayerState::Adam(a) => {
                    r.add("adam_m", a.m.bytes());
                    r.add("adam_v", a.v.bytes());
                }
            }
        }
        for (dim, q) in &self.dct {
            r.share(&format!("dct_matrix_{dim}"), q.bytes());
        }
        r
    }

    fn name(&self) -> &str {
        match self.kind {
            OptimizerKind::Trion => "trion(aot)",
            OptimizerKind::DctAdamW => "dct-adamw(aot)",
            OptimizerKind::Dion => "dion(aot)",
            _ => "aot",
        }
    }

    fn broadcast_bytes(&self, meta: &LayerMeta) -> u64 {
        // Same §2.3 payloads as the native optimizers: Trion/DCT-AdamW
        // owners send the low-rank factor + indices, receivers reconstruct
        // from their DCT replica; Dion sends the full O_t.
        if meta.kind.low_rank_eligible()
            && matches!(self.kind, OptimizerKind::Trion | OptimizerKind::DctAdamW)
        {
            let (rr, cc) = meta.oriented();
            let r = self
                .dct
                .get(&cc)
                .map(|_| cc.min(rr))
                .unwrap_or(cc)
                .min(self.rank_hint(cc));
            (rr * r * 4 + r * 4) as u64
        } else {
            (meta.rows * meta.cols * 4) as u64
        }
    }
}

impl AotOptimizer {
    fn rank_hint(&self, cols: usize) -> usize {
        // every AOT layer state carries its rank; use the first match
        for st in &self.states {
            match st {
                LayerState::DctAdamW { rank, dim, .. } if *dim == cols => return *rank,
                LayerState::Trion { momentum, dim, .. } if *dim == cols => {
                    let _ = momentum;
                    return self.default_rank;
                }
                _ => {}
            }
        }
        self.default_rank
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{build_optimizer, OptimizerConfig, ParamKind};
    use crate::projection::{ProjectionKind, RankNorm};

    /// Skip (rather than fail) when artifacts or a real PJRT plugin are
    /// missing — e.g. under the offline stub `xla` crate.
    fn setup() -> Option<(Manifest, Runtime)> {
        crate::runtime::testing::pjrt_setup("AOT test")
    }

    fn nano_linear_metas() -> Vec<LayerMeta> {
        vec![
            LayerMeta::new("wq", 64, 64, ParamKind::Linear),
            LayerMeta::new("w_down", 176, 64, ParamKind::Linear),
            LayerMeta::new("w_gate", 64, 176, ParamKind::Linear), // wide → 176x64
        ]
    }

    fn cfg(kind: OptimizerKind) -> TrainConfig {
        let mut c = TrainConfig::default();
        c.optimizer = kind;
        c.opt.rank = 32;
        c.opt.mu = 0.95; // aot.py default
        c.weight_decay = 0.0;
        c.opt.weight_decay = 0.0;
        // match the AOT graphs: matmul similarities, L2 ranking
        c.opt.projection = ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: false };
        c
    }

    #[test]
    fn trion_aot_matches_native_one_step() {
        let (m, rt) = match setup() {
            Some(x) => x,
            None => return,
        };
        let metas = nano_linear_metas();
        let c = cfg(OptimizerKind::Trion);
        let mut aot = AotOptimizer::new(&metas, &c, &m, &rt, "trion").unwrap();
        assert_eq!(aot.aot_layer_count(), 3);
        let mut native = build_optimizer(&OptimizerKind::Trion, &metas, &c.opt);

        let mut rng = crate::util::Pcg64::seed(0);
        let grads: Vec<Matrix> = metas
            .iter()
            .map(|mm| Matrix::randn(mm.rows, mm.cols, 1.0, &mut rng))
            .collect();
        let mut p_aot: Vec<Matrix> =
            metas.iter().map(|mm| Matrix::zeros(mm.rows, mm.cols)).collect();
        let mut p_nat = p_aot.clone();
        for step in 0..3 {
            aot.step(&mut p_aot, &grads, 0.01);
            native.step(&mut p_nat, &grads, 0.01);
            for (a, b) in p_aot.iter().zip(&p_nat) {
                let d = a.max_abs_diff(b);
                assert!(d < 2e-3, "step {step}: aot vs native diff {d}");
            }
        }
    }

    #[test]
    fn dion_aot_matches_native_shapes_and_descends() {
        let (m, rt) = match setup() {
            Some(x) => x,
            None => return,
        };
        let metas = vec![LayerMeta::new("wq", 64, 64, ParamKind::Linear)];
        let c = cfg(OptimizerKind::Dion);
        let mut aot = AotOptimizer::new(&metas, &c, &m, &rt, "dion").unwrap();
        assert_eq!(aot.aot_layer_count(), 1);
        let mut rng = crate::util::Pcg64::seed(1);
        let t = Matrix::randn(64, 64, 0.3, &mut rng);
        let mut params = vec![Matrix::zeros(64, 64)];
        let e0 = params[0].sub(&t).fro_norm();
        for _ in 0..60 {
            let g = params[0].sub(&t).scaled(2.0);
            aot.step(&mut params, &[g], 0.05);
        }
        let e1 = params[0].sub(&t).fro_norm();
        // orthonormal updates have fixed magnitude: linear, not geometric,
        // descent — check meaningful progress, not a contraction factor
        assert!(e1 < e0 * 0.7, "e0={e0} e1={e1}");
    }

    #[test]
    fn dctadamw_aot_runs_and_updates_state() {
        let (m, rt) = match setup() {
            Some(x) => x,
            None => return,
        };
        let metas = vec![LayerMeta::new("wq", 64, 64, ParamKind::Linear)];
        let c = cfg(OptimizerKind::DctAdamW);
        let mut aot = AotOptimizer::new(&metas, &c, &m, &rt, "dctadamw").unwrap();
        let mut rng = crate::util::Pcg64::seed(2);
        let mut params = vec![Matrix::zeros(64, 64)];
        let g = Matrix::randn(64, 64, 1.0, &mut rng);
        aot.step(&mut params, &[g.clone()], 3e-3);
        assert!(params[0].fro_norm() > 0.0);
        if let LayerState::DctAdamW { m: mm, ef, .. } = &aot.states[0] {
            assert!(mm.fro_norm() > 0.0);
            assert!(ef.fro_norm() > 0.0);
        } else {
            panic!();
        }
    }

    #[test]
    fn falls_back_without_artifacts() {
        let (m, rt) = match setup() {
            Some(x) => x,
            None => return,
        };
        // shape with no exported graph
        let metas = vec![LayerMeta::new("w", 50, 50, ParamKind::Linear)];
        let c = cfg(OptimizerKind::Trion);
        let inner = build_optimizer(&OptimizerKind::Trion, &metas, &c.opt);
        let wrapped = maybe_wrap_aot(inner, &metas, &c, &m, &rt).unwrap();
        assert_eq!(wrapped.name(), "trion"); // unchanged native path
    }
}
