//! Run configuration: everything a training run needs, with paper-matching
//! defaults. Parsed from CLI flags (no config-file dependency offline, but
//! `to_json`/`from_json` round-trips so runs are recorded reproducibly).

use anyhow::Result;

use crate::coordinator::{CommMode, WireFormat};
use crate::obs::ObsTier;
use crate::optim::common::EfMode;
use crate::optim::{
    build_optimizer, LayerMeta, Optimizer, OptimizerConfig, OptimizerKind,
    OptimizerSpec, ResidualKind, RotationKind,
};
use crate::projection::{ProjectionKind, RankNorm};
use crate::tensor::StateDtype;
use crate::train::guard::GuardPolicy;
use crate::util::json::{num, obj, s, Json};

/// Config-level residual choice: resolved against `ef-mode` at build time
/// (so `residual=ef ef-mode=q8` works in either key order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidualChoice {
    Discard,
    Ef,
    Fira,
    Sign,
}

impl ResidualChoice {
    fn name(self) -> &'static str {
        match self {
            ResidualChoice::Discard => "discard",
            ResidualChoice::Ef => "ef",
            ResidualChoice::Fira => "fira",
            ResidualChoice::Sign => "sign",
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub preset: String,
    pub optimizer: OptimizerKind,
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub weight_decay: f32,
    pub workers: usize,
    pub batch_per_worker: usize,
    pub grad_clip: f32,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub corpus_tokens: usize,
    pub out_dir: String,
    pub run_name: String,
    pub opt: OptimizerConfig,
    /// Execute per-layer optimizer updates through the AOT HLO graphs where
    /// a matching artifact exists (three-layer composition) instead of the
    /// rust-native math.
    pub use_aot_optimizer: bool,
    /// Engine policy overrides (`source=` / `residual=` / `rotation=` keys):
    /// `None` keeps the preset's own axis; any `Some` switches construction
    /// to the [`OptimizerSpec`] grid, so arbitrary combinations — e.g.
    /// GaLore cadence + DCT source + Q8 error feedback — are one config
    /// line, not a new optimizer file.
    pub source_override: Option<ProjectionKind>,
    pub residual_override: Option<ResidualChoice>,
    pub rotation_override: Option<RotationKind>,
    /// `rank-norm=` key: a deferred override of the DCT ranking norm,
    /// applied at build/dump time to whatever DCT projection ends up
    /// configured (`projection=` and `source=` alike) — so the key composes
    /// with them in any order and always wins over the `dct:l1|l2` grammar.
    pub rank_norm_override: Option<RankNorm>,
    /// `resume=PATH`: load a v2 checkpoint (params + step + optimizer
    /// state) and continue the run bit-identically from its step counter.
    pub resume: Option<String>,
    /// `save-state=PATH`: write a v2 checkpoint at the end of the run.
    pub save_state_to: Option<String>,
    /// `guard=off|skip|rollback`: the numerical-health policy applied to
    /// every step's loss and post-clip gradients (see `train::guard`).
    pub guard: GuardPolicy,
    /// `guard-threshold=X`: trip the guard when loss > X × EMA(loss);
    /// `0` disables spike detection (non-finite checks still run).
    pub guard_threshold: f32,
    /// `checkpoint-interval=N`: write an atomic in-run v2 snapshot every
    /// N completed steps; `0` disables periodic snapshots (rollback still
    /// forces an initial one).
    pub checkpoint_interval: usize,
    /// `checkpoint-dir=PATH`: snapshot directory; defaults to
    /// `{run_dir}/checkpoints`.
    pub checkpoint_dir: Option<String>,
    /// `checkpoint-keep=K`: retain the newest K snapshots (≥ 1).
    pub checkpoint_keep: usize,
    /// `fault=SPEC`: deterministic fault-injection plan (see
    /// `train::fault` for the grammar); wins over `FFT_SUBSPACE_FAULT`.
    pub fault: Option<String>,
    /// `obs=off|counters|trace`: run-wide telemetry tier (see `crate::obs`);
    /// `Off` here falls back to `FFT_SUBSPACE_OBS` at run start, so the
    /// config wins when both are set.
    pub obs: ObsTier,
    /// `trace-out=PATH`: Chrome-trace JSON destination under `obs=trace`;
    /// defaults to `{run_dir}/trace.json`.
    pub trace_out: Option<String>,
    /// `obs-sample=N`: record span events every Nth step only (counters and
    /// refresh gauges keep full cadence). `1` = every step.
    pub obs_sample: usize,
    /// `comm=dense|subspace`: gradient-sync scheme (see
    /// `coordinator::compressed`); `Dense` here falls back to
    /// `FFT_SUBSPACE_COMM` at run start, so the config wins when both are
    /// set. Never part of the checkpoint fingerprint — resumes cross modes
    /// freely.
    pub comm: CommMode,
    /// `wire=f32|q8`: wire format of the subspace-compressed coefficient
    /// blocks (see `coordinator::compressed`); `F32` here falls back to
    /// `FFT_SUBSPACE_WIRE` at run start, so the config wins when both are
    /// set. Like `comm`, never part of the checkpoint fingerprint.
    pub wire: WireFormat,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "nano".into(),
            optimizer: OptimizerKind::Trion,
            steps: 200,
            lr: 0.01, // Dion/Trion optimum reported by the paper
            warmup: 20,
            weight_decay: 0.01,
            workers: 4,
            batch_per_worker: 8,
            grad_clip: 1.0,
            seed: 42,
            eval_every: 50,
            eval_batches: 8,
            corpus_tokens: 1 << 20,
            out_dir: "runs".into(),
            run_name: String::new(),
            opt: OptimizerConfig::default(),
            use_aot_optimizer: false,
            source_override: None,
            residual_override: None,
            rotation_override: None,
            rank_norm_override: None,
            resume: None,
            save_state_to: None,
            guard: GuardPolicy::Off,
            guard_threshold: 0.0,
            checkpoint_interval: 0,
            checkpoint_dir: None,
            checkpoint_keep: 3,
            fault: None,
            obs: ObsTier::Off,
            trace_out: None,
            obs_sample: 1,
            comm: CommMode::Dense,
            wire: WireFormat::F32,
        }
    }
}

/// Shared projection-value grammar for the `projection=` and `source=`
/// keys: `dct[:l1|:l2][:fft|:matmul]`, `svd`, `random`, `randperm`,
/// `block_power`.
pub fn parse_projection(value: &str) -> Result<ProjectionKind> {
    if value == "dct" || value.starts_with("dct:") {
        let mut norm = RankNorm::L2;
        let mut use_makhoul = true;
        for part in value.split(':').skip(1) {
            match part {
                "l1" => norm = RankNorm::L1,
                "l2" => norm = RankNorm::L2,
                "fft" | "makhoul" => use_makhoul = true,
                "matmul" => use_makhoul = false,
                other => anyhow::bail!("unknown dct projection option {other:?}"),
            }
        }
        return Ok(ProjectionKind::Dct { norm, use_makhoul });
    }
    if let Some(n) = value
        .strip_prefix("block_power:")
        .or_else(|| value.strip_prefix("block-power:"))
    {
        return Ok(ProjectionKind::BlockPower { iters: n.parse()? });
    }
    Ok(match value {
        "svd" => ProjectionKind::Svd,
        "random" => ProjectionKind::Random,
        "randperm" => ProjectionKind::RandPerm,
        "block_power" | "block-power" => ProjectionKind::BlockPower { iters: 2 },
        _ => anyhow::bail!("unknown projection {value:?}"),
    })
}

impl TrainConfig {
    /// Whether any engine policy override key (`source=` / `residual=` /
    /// `rotation=`) is set — i.e. the run is an [`OptimizerSpec`] grid
    /// point rather than a plain preset.
    pub fn has_engine_overrides(&self) -> bool {
        self.source_override.is_some()
            || self.residual_override.is_some()
            || self.rotation_override.is_some()
    }

    /// `kind` with the deferred `rank-norm=` override folded in (no-op on
    /// non-DCT kinds).
    fn with_rank_norm(&self, kind: &ProjectionKind) -> ProjectionKind {
        match (self.rank_norm_override, kind) {
            (Some(n), ProjectionKind::Dct { use_makhoul, .. }) => {
                ProjectionKind::Dct { norm: n, use_makhoul: *use_makhoul }
            }
            _ => kind.clone(),
        }
    }

    /// Construct the run's optimizer. Without engine overrides this is the
    /// legacy preset path ([`build_optimizer`], bit-identical to the
    /// pre-engine optimizers); with any `source=`/`residual=`/`rotation=`/
    /// `rank-norm=` override it resolves the preset's [`OptimizerSpec`] and
    /// rebinds the overridden axes. `rank-norm` is folded into the
    /// *resolved* source — after preset resolution and the `source=`
    /// override — and errors if that source is not DCT, so the override can
    /// never be dropped silently (presets like GaLore/LDAdamW pin non-DCT
    /// sources and ignore `projection=`).
    pub fn build_optimizer(&self, metas: &[LayerMeta]) -> Result<Box<dyn Optimizer>> {
        if !self.has_engine_overrides() && self.rank_norm_override.is_none() {
            return Ok(build_optimizer(&self.optimizer, metas, &self.opt));
        }
        let Some(mut spec) = OptimizerSpec::from_kind(&self.optimizer, &self.opt) else {
            anyhow::bail!(
                "engine overrides (source/residual/rotation/rank-norm) need \
                 a low-rank optimizer preset, not {}",
                self.optimizer.name()
            );
        };
        if let Some(p) = &self.source_override {
            spec = spec.projection(p.clone());
        }
        if self.rank_norm_override.is_some() {
            let folded = self.with_rank_norm(&spec.projection);
            if !matches!(folded, ProjectionKind::Dct { .. }) {
                anyhow::bail!(
                    "rank-norm is set but the resolved subspace source is {} \
                     — it only applies to dct",
                    folded.name()
                );
            }
            spec = spec.projection(folded);
        }
        if let Some(r) = self.residual_override {
            spec = spec.residual(match r {
                ResidualChoice::Discard => ResidualKind::Discard,
                ResidualChoice::Ef => ResidualKind::ErrorFeedback(self.opt.ef_mode),
                ResidualChoice::Fira => ResidualKind::FiraScale,
                ResidualChoice::Sign => ResidualKind::SignDescent,
            });
        }
        if let Some(r) = self.rotation_override {
            spec = spec.rotation(r);
        }
        spec.validate().map_err(anyhow::Error::msg)?;
        Ok(Box::new(spec.build(metas)))
    }

    pub fn run_name(&self) -> String {
        if self.run_name.is_empty() {
            format!(
                "{}_{}_r{}_s{}",
                self.preset,
                self.optimizer.name(),
                self.opt.rank,
                self.seed
            )
        } else {
            self.run_name.clone()
        }
    }

    /// The `projection=`/`source=` value string for a [`ProjectionKind`] —
    /// parseable back through [`parse_projection`], so the JSON snapshot
    /// round-trips through [`TrainConfig::apply`].
    fn projection_string(kind: &ProjectionKind) -> String {
        match kind {
            ProjectionKind::Dct { norm, use_makhoul } => format!(
                "dct:{}:{}",
                if *norm == RankNorm::L1 { "l1" } else { "l2" },
                if *use_makhoul { "fft" } else { "matmul" }
            ),
            ProjectionKind::Svd => "svd".into(),
            ProjectionKind::BlockPower { iters } => format!("block_power:{iters}"),
            ProjectionKind::Random => "random".into(),
            ProjectionKind::RandPerm => "randperm".into(),
        }
    }

    pub fn to_json(&self) -> Json {
        // dump *effective* kinds — the deferred rank-norm override folded
        // in — so the recorded config matches what the run executes
        let proj = Self::projection_string(&self.with_rank_norm(&self.opt.projection));
        let mut extra = Vec::new();
        // the rank_norm key is dumped only when the override was set: the
        // norm is always recorded inside the projection/source strings, and
        // an unconditional key would flip a replayed preset run onto the
        // engine-override construction path (skipping e.g. AOT wrapping)
        if let Some(n) = self.rank_norm_override {
            extra.push((
                "rank_norm",
                s(if n == RankNorm::L1 { "l1" } else { "l2" }),
            ));
        }
        if let Some(src) = &self.source_override {
            extra.push((
                "source",
                s(&Self::projection_string(&self.with_rank_norm(src))),
            ));
        }
        if let Some(r) = self.residual_override {
            extra.push(("residual", s(r.name())));
        }
        if let Some(r) = self.rotation_override {
            extra.push((
                "rotation",
                s(match r {
                    RotationKind::None => "none",
                    RotationKind::FixedBasis => "fixed-basis",
                    RotationKind::Dense => "dense",
                }),
            ));
        }
        if let Some(p) = &self.resume {
            extra.push(("resume", s(p)));
        }
        if let Some(p) = &self.save_state_to {
            extra.push(("save_state", s(p)));
        }
        if let Some(d) = &self.checkpoint_dir {
            extra.push(("checkpoint_dir", s(d)));
        }
        if let Some(f) = &self.fault {
            extra.push(("fault", s(f)));
        }
        if let Some(t) = &self.trace_out {
            extra.push(("trace_out", s(t)));
        }
        let mut fields = vec![
            ("preset", s(&self.preset)),
            ("optimizer", s(self.optimizer.name())),
            ("steps", num(self.steps as f64)),
            ("lr", num(self.lr as f64)),
            ("warmup", num(self.warmup as f64)),
            ("weight_decay", num(self.weight_decay as f64)),
            ("workers", num(self.workers as f64)),
            ("batch_per_worker", num(self.batch_per_worker as f64)),
            ("grad_clip", num(self.grad_clip as f64)),
            ("seed", num(self.seed as f64)),
            ("rank", num(self.opt.rank as f64)),
            ("mu", num(self.opt.mu as f64)),
            ("update_interval", num(self.opt.update_interval as f64)),
            ("projection", s(&proj)),
            (
                "ef_mode",
                s(match self.opt.ef_mode {
                    EfMode::None => "none",
                    EfMode::F32 => "f32",
                    EfMode::Q8 => "q8",
                }),
            ),
            ("state_dtype", s(self.opt.state_dtype.name())),
            ("step_plan", s(self.opt.step_plan.name())),
            ("use_aot_optimizer", Json::Bool(self.use_aot_optimizer)),
            // 0 = auto (global pool)
            ("threads", num(self.opt.threads.unwrap_or(0) as f64)),
            ("guard", s(self.guard.name())),
            ("guard_threshold", num(self.guard_threshold as f64)),
            ("checkpoint_interval", num(self.checkpoint_interval as f64)),
            ("checkpoint_keep", num(self.checkpoint_keep as f64)),
            ("obs", s(self.obs.name())),
            ("obs_sample", num(self.obs_sample as f64)),
            ("comm", s(self.comm.name())),
            ("wire", s(self.wire.name())),
            ("max_group_rows", num(self.opt.max_group_rows as f64)),
        ];
        fields.extend(extra);
        obj(fields)
    }

    /// Parse a `key=value` override (CLI plumbing).
    pub fn apply(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "preset" => self.preset = value.into(),
            "optimizer" => {
                self.optimizer = OptimizerKind::parse(value)
                    .ok_or_else(|| anyhow::anyhow!("unknown optimizer {value}"))?
            }
            "steps" => self.steps = value.parse()?,
            "lr" => self.lr = value.parse()?,
            "warmup" => self.warmup = value.parse()?,
            "weight-decay" | "weight_decay" => self.weight_decay = value.parse()?,
            "workers" => self.workers = value.parse()?,
            "batch-per-worker" | "batch_per_worker" => {
                self.batch_per_worker = value.parse()?
            }
            "grad-clip" | "grad_clip" => self.grad_clip = value.parse()?,
            "seed" => {
                self.seed = value.parse()?;
                self.opt.seed = self.seed;
            }
            "eval-every" | "eval_every" => self.eval_every = value.parse()?,
            "eval-batches" | "eval_batches" => self.eval_batches = value.parse()?,
            "corpus-tokens" | "corpus_tokens" => self.corpus_tokens = value.parse()?,
            "out-dir" | "out_dir" => self.out_dir = value.into(),
            "run-name" | "run_name" => self.run_name = value.into(),
            "rank" => self.opt.rank = value.parse()?,
            "mu" => self.opt.mu = value.parse()?,
            "ns-steps" | "ns_steps" => self.opt.ns_steps = value.parse()?,
            "update-interval" | "update_interval" => {
                self.opt.update_interval = value.parse()?
            }
            "instrument" => self.opt.instrument = value.parse()?,
            // 0 = auto (global pool: FFT_SUBSPACE_THREADS / cores)
            "threads" => {
                let n: usize = value.parse()?;
                self.opt.threads = if n == 0 { None } else { Some(n) };
            }
            "use-aot-optimizer" | "use_aot_optimizer" => {
                self.use_aot_optimizer = value.parse()?
            }
            "projection" => self.opt.projection = parse_projection(value)?,
            // ranking norm for DCT column selection (§2.1: ℓ1 or ℓ2) — a
            // deferred override folded into the *resolved* DCT source at
            // build/dump time (build_optimizer is the single authority:
            // presets may fall back to DCT from a non-DCT `projection=`, so
            // applicability can't be judged here), composing with
            // `projection=` / `source=` in any key order and winning over
            // their `dct:l1|l2` grammar
            "rank-norm" | "rank_norm" => {
                self.rank_norm_override = Some(match value {
                    "l1" => RankNorm::L1,
                    "l2" => RankNorm::L2,
                    _ => anyhow::bail!("unknown rank norm {value:?} (l1|l2)"),
                })
            }
            "ef-mode" | "ef_mode" => {
                self.opt.ef_mode = match value {
                    "none" => EfMode::None,
                    "f32" => EfMode::F32,
                    "q8" => EfMode::Q8,
                    _ => anyhow::bail!("unknown ef mode {value}"),
                }
            }
            // storage precision of persistent optimizer state (the fifth
            // engine axis — moments/momentum/dense-fallback; ef-mode keeps
            // governing the EF buffer's own resolution)
            "state-dtype" | "state_dtype" => {
                self.opt.state_dtype = StateDtype::parse(value).ok_or_else(|| {
                    anyhow::anyhow!("unknown state dtype {value:?} (f32|bf16|q8)")
                })?
            }
            // engine step execution: fused shape-batched programs (default)
            // vs the interpreted per-layer oracle — bit-identical, so safe
            // to flip between runs and across checkpoint resumes
            "step-plan" | "step_plan" => {
                self.opt.step_plan = crate::optim::StepPlanMode::parse(value)?
            }
            "resume" => self.resume = Some(value.into()),
            "save-state" | "save_state" => self.save_state_to = Some(value.into()),
            // fault-tolerance: numerical-health guard + in-run snapshots
            "guard" => {
                self.guard = GuardPolicy::parse(value).ok_or_else(|| {
                    anyhow::anyhow!("unknown guard policy {value:?} (off|skip|rollback)")
                })?
            }
            "guard-threshold" | "guard_threshold" => {
                self.guard_threshold = value.parse()?
            }
            "checkpoint-interval" | "checkpoint_interval" => {
                self.checkpoint_interval = value.parse()?
            }
            "checkpoint-dir" | "checkpoint_dir" => {
                self.checkpoint_dir = Some(value.into())
            }
            "checkpoint-keep" | "checkpoint_keep" => {
                self.checkpoint_keep = value.parse()?
            }
            // deterministic fault injection (validated at parse time so a
            // typo'd spec fails the CLI, not the mid-run injection point)
            "fault" => {
                crate::train::fault::FaultPlan::parse(value)?;
                self.fault = Some(value.into());
            }
            // gradient-sync scheme (see `coordinator::compressed`)
            "comm" => self.comm = CommMode::parse(value)?,
            // coefficient-block wire format for comm=subspace
            "wire" => self.wire = WireFormat::parse(value)?,
            // step-plan group row cap (0 = unlimited / defer to env)
            "max-group-rows" | "max_group_rows" => {
                self.opt.max_group_rows = value.parse()?
            }
            // observability tier + exporters (see `crate::obs`)
            "obs" => self.obs = ObsTier::parse(value)?,
            "trace-out" | "trace_out" => self.trace_out = Some(value.into()),
            "obs-sample" | "obs_sample" => {
                let n: usize = value.parse()?;
                anyhow::ensure!(n >= 1, "obs-sample must be >= 1");
                self.obs_sample = n;
            }
            // engine policy overrides — any grid point from config alone
            "source" => self.source_override = Some(parse_projection(value)?),
            "residual" => {
                self.residual_override = Some(match value {
                    "discard" => ResidualChoice::Discard,
                    "ef" => ResidualChoice::Ef,
                    "fira" => ResidualChoice::Fira,
                    "sign" => ResidualChoice::Sign,
                    _ => anyhow::bail!(
                        "unknown residual policy {value:?} (discard|ef|fira|sign)"
                    ),
                })
            }
            "rotation" => {
                self.rotation_override = Some(match value {
                    "none" => RotationKind::None,
                    "fixed-basis" | "fixed_basis" | "fixed" => RotationKind::FixedBasis,
                    "dense" => RotationKind::Dense,
                    _ => anyhow::bail!(
                        "unknown rotation policy {value:?} (none|fixed-basis|dense)"
                    ),
                })
            }
            _ => anyhow::bail!("unknown config key {key:?}"),
        }
        // keep optimizer-level mirrors in sync
        self.opt.weight_decay = self.weight_decay;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_overrides() {
        let mut c = TrainConfig::default();
        c.apply("optimizer", "dion").unwrap();
        c.apply("rank", "64").unwrap();
        c.apply("lr", "0.02").unwrap();
        c.apply("projection", "svd").unwrap();
        assert_eq!(c.optimizer, OptimizerKind::Dion);
        assert_eq!(c.opt.rank, 64);
        assert_eq!(c.opt.projection, ProjectionKind::Svd);
        assert!(c.apply("bogus", "1").is_err());
    }

    #[test]
    fn run_name_auto() {
        let mut c = TrainConfig::default();
        c.apply("optimizer", "trion").unwrap();
        c.apply("rank", "16").unwrap();
        assert_eq!(c.run_name(), "nano_trion_r16_s42");
    }

    #[test]
    fn json_snapshot_parses() {
        let c = TrainConfig::default();
        let j = c.to_json().to_string();
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.req("optimizer").unwrap().as_str().unwrap(), "trion");
        assert_eq!(back.req("rank").unwrap().as_usize().unwrap(), 32);
        // the previously unreachable ef axis is in the dump; the rank-norm
        // key appears only when explicitly overridden (the norm itself is
        // inside the projection string), so preset dumps replay on the
        // preset path
        assert_eq!(back.req("ef_mode").unwrap().as_str().unwrap(), "q8");
        assert!(back.get("rank_norm").is_none());
        assert_eq!(
            back.req("projection").unwrap().as_str().unwrap(),
            "dct:l2:fft"
        );
    }

    #[test]
    fn rank_norm_key_round_trips() {
        let mut c = TrainConfig::default();
        c.apply("rank-norm", "l1").unwrap();
        assert_eq!(c.rank_norm_override, Some(RankNorm::L1));
        let j = c.to_json().to_string();
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.req("rank_norm").unwrap().as_str().unwrap(), "l1");
        assert_eq!(back.req("projection").unwrap().as_str().unwrap(), "dct:l1:fft");
        // the dumped (effective) projection string parses back to the
        // effective kind
        let mut c2 = TrainConfig::default();
        c2.apply("projection", back.req("projection").unwrap().as_str().unwrap())
            .unwrap();
        assert_eq!(
            c2.opt.projection,
            ProjectionKind::Dct { norm: RankNorm::L1, use_makhoul: true }
        );
        // bad values are rejected at parse time; non-dct applicability is
        // resolved at build time (presets may fall back to dct) — see the
        // order-independence test
        assert!(c.apply("rank-norm", "linf").is_err());
    }

    #[test]
    fn rank_norm_is_order_independent_and_covers_source() {
        use crate::optim::ParamKind;
        let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
        // rank-norm BEFORE a projection= that would otherwise default to l2
        let mut c = TrainConfig::default();
        c.apply("rank-norm", "l1").unwrap();
        c.apply("projection", "dct:matmul").unwrap();
        let back = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(back.req("rank_norm").unwrap().as_str().unwrap(), "l1");
        assert_eq!(
            back.req("projection").unwrap().as_str().unwrap(),
            "dct:l1:matmul"
        );
        assert!(c.build_optimizer(&metas).is_ok());
        // rank-norm also rewrites a dct source= override
        let mut c = TrainConfig::default();
        c.apply("optimizer", "galore").unwrap();
        c.apply("source", "dct").unwrap();
        c.apply("rank-norm", "l1").unwrap();
        let back = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(back.req("source").unwrap().as_str().unwrap(), "dct:l1:fft");
        assert!(c.build_optimizer(&metas).is_ok());
        // presets that pin a non-dct source reject a dangling rank-norm at
        // build time instead of dropping it silently
        let mut c = TrainConfig::default();
        c.apply("optimizer", "galore").unwrap();
        c.apply("rank-norm", "l1").unwrap();
        assert!(c.build_optimizer(&metas).is_err());
        // ... and a plain galore run records no rank_norm at all (its
        // resolved source is SVD), so its dump replays cleanly
        let mut c = TrainConfig::default();
        c.apply("optimizer", "galore").unwrap();
        let back = Json::parse(&c.to_json().to_string()).unwrap();
        assert!(back.get("rank_norm").is_none());
        assert!(c.build_optimizer(&metas).is_ok());
        // ... and so does shadowing the dct projection with a non-dct source
        let mut c = TrainConfig::default();
        c.apply("optimizer", "fira").unwrap();
        c.apply("rank-norm", "l1").unwrap();
        c.apply("source", "svd").unwrap();
        assert!(c.build_optimizer(&metas).is_err());
        // a dump from a dct-fallback run (dct-adamw ignores a non-dct
        // projection=) carries no rank_norm key and replays through
        // apply + build without error
        let mut c = TrainConfig::default();
        c.apply("optimizer", "dct-adamw").unwrap();
        c.apply("projection", "svd").unwrap();
        assert!(c.build_optimizer(&metas).is_ok());
        let dump = Json::parse(&c.to_json().to_string()).unwrap();
        assert!(dump.get("rank_norm").is_none());
        let mut replay = TrainConfig::default();
        replay.apply("optimizer", "dct-adamw").unwrap();
        replay
            .apply("projection", dump.req("projection").unwrap().as_str().unwrap())
            .unwrap();
        assert!(replay.build_optimizer(&metas).is_ok());
    }

    #[test]
    fn state_dtype_key_round_trips_and_builds() {
        use crate::optim::ParamKind;
        let mut c = TrainConfig::default();
        // default dumps as f32 and takes the preset (bit-exact) path
        let back = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(back.req("state_dtype").unwrap().as_str().unwrap(), "f32");
        for (v, want) in [
            ("f32", StateDtype::F32),
            ("bf16", StateDtype::Bf16),
            ("q8", StateDtype::Q8),
        ] {
            c.apply("state-dtype", v).unwrap();
            assert_eq!(c.opt.state_dtype, want);
            let back = Json::parse(&c.to_json().to_string()).unwrap();
            assert_eq!(back.req("state_dtype").unwrap().as_str().unwrap(), v);
        }
        assert!(c.apply("state-dtype", "fp8").is_err());
        // the dtype reaches the built optimizer's name (trion default)
        let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
        c.apply("state-dtype", "bf16").unwrap();
        let opt = c.build_optimizer(&metas).unwrap();
        assert_eq!(opt.name(), "trion+m:bf16");
        // ... and composes with the engine-override grid (off-grid combos
        // carry the dtype inside the composed name)
        c.apply("optimizer", "galore").unwrap();
        c.apply("source", "dct").unwrap();
        c.apply("residual", "ef").unwrap();
        c.apply("ef-mode", "q8").unwrap();
        c.apply("update-interval", "50").unwrap();
        let opt = c.build_optimizer(&metas).unwrap();
        assert_eq!(opt.name(), "engine(dct+adamw+ef-q8,T50,m:bf16)");
    }

    #[test]
    fn step_plan_key_round_trips_and_stays_out_of_names() {
        use crate::optim::{ParamKind, StepPlanMode};
        let mut c = TrainConfig::default();
        // default dumps as the env-resolved mode (fused unless the test
        // environment pinned the interpreted oracle)
        let back = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(
            back.req("step_plan").unwrap().as_str().unwrap(),
            c.opt.step_plan.name()
        );
        for (v, want) in
            [("fused", StepPlanMode::Fused), ("interpreted", StepPlanMode::Interpreted)]
        {
            c.apply("step-plan", v).unwrap();
            assert_eq!(c.opt.step_plan, want);
            let back = Json::parse(&c.to_json().to_string()).unwrap();
            assert_eq!(back.req("step_plan").unwrap().as_str().unwrap(), v);
            c.apply("step_plan", v).unwrap();
            assert_eq!(c.opt.step_plan, want);
        }
        assert!(c.apply("step-plan", "jit").is_err());
        // bit-identical modes must not leak into optimizer naming (and so
        // not into checkpoint fingerprints — resumes cross modes freely)
        let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
        c.apply("step-plan", "interpreted").unwrap();
        let interp = c.build_optimizer(&metas).unwrap().name().to_string();
        c.apply("step-plan", "fused").unwrap();
        let fused = c.build_optimizer(&metas).unwrap().name().to_string();
        assert_eq!(interp, fused);
    }

    #[test]
    fn resume_and_save_state_keys_parse() {
        let mut c = TrainConfig::default();
        c.apply("resume", "runs/a/ckpt.bin").unwrap();
        c.apply("save-state", "runs/a/ckpt2.bin").unwrap();
        assert_eq!(c.resume.as_deref(), Some("runs/a/ckpt.bin"));
        assert_eq!(c.save_state_to.as_deref(), Some("runs/a/ckpt2.bin"));
        let back = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(back.req("resume").unwrap().as_str().unwrap(), "runs/a/ckpt.bin");
        assert_eq!(back.req("save_state").unwrap().as_str().unwrap(), "runs/a/ckpt2.bin");
        // absent by default
        let d = Json::parse(&TrainConfig::default().to_json().to_string()).unwrap();
        assert!(d.get("resume").is_none());
        assert!(d.get("save_state").is_none());
    }

    #[test]
    fn guard_and_checkpoint_keys_round_trip() {
        let mut c = TrainConfig::default();
        c.apply("guard", "rollback").unwrap();
        c.apply("guard-threshold", "3.5").unwrap();
        c.apply("checkpoint-interval", "25").unwrap();
        c.apply("checkpoint-dir", "runs/a/snaps").unwrap();
        c.apply("checkpoint-keep", "5").unwrap();
        c.apply("fault", "grad-nan@7.2,ckpt-tear@64").unwrap();
        assert_eq!(c.guard, GuardPolicy::Rollback);
        assert_eq!(c.guard_threshold, 3.5);
        assert_eq!(c.checkpoint_interval, 25);
        assert_eq!(c.checkpoint_dir.as_deref(), Some("runs/a/snaps"));
        assert_eq!(c.checkpoint_keep, 5);
        assert_eq!(c.fault.as_deref(), Some("grad-nan@7.2,ckpt-tear@64"));

        // the config.json dump records the effective values and replays
        // through apply()
        let back = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(back.req("guard").unwrap().as_str().unwrap(), "rollback");
        // numeric field, not a string
        assert!(back.req("guard_threshold").unwrap().as_str().is_err());
        assert_eq!(back.req("guard_threshold").unwrap().as_f64().unwrap(), 3.5);
        let mut replay = TrainConfig::default();
        for key in [
            "guard",
            "checkpoint_dir",
            "fault",
        ] {
            replay
                .apply(key, back.req(key).unwrap().as_str().unwrap())
                .unwrap();
        }
        assert_eq!(replay.guard, GuardPolicy::Rollback);
        assert_eq!(replay.checkpoint_dir.as_deref(), Some("runs/a/snaps"));
        assert_eq!(replay.fault.as_deref(), Some("grad-nan@7.2,ckpt-tear@64"));
        assert_eq!(
            back.req("checkpoint_interval").unwrap().as_usize().unwrap(),
            25
        );
        assert_eq!(back.req("checkpoint_keep").unwrap().as_usize().unwrap(), 5);

        // defaults: guard off, snapshots off, optional keys absent
        let d = Json::parse(&TrainConfig::default().to_json().to_string()).unwrap();
        assert_eq!(d.req("guard").unwrap().as_str().unwrap(), "off");
        assert_eq!(d.req("checkpoint_interval").unwrap().as_usize().unwrap(), 0);
        assert_eq!(d.req("checkpoint_keep").unwrap().as_usize().unwrap(), 3);
        assert!(d.get("checkpoint_dir").is_none());
        assert!(d.get("fault").is_none());

        // bad values are rejected at parse time
        assert!(c.apply("guard", "retry").is_err());
        assert!(c.apply("fault", "bogus@1").is_err());
        assert!(c.apply("checkpoint-interval", "x").is_err());
    }

    #[test]
    fn obs_keys_round_trip() {
        let mut c = TrainConfig::default();
        c.apply("obs", "trace").unwrap();
        c.apply("trace-out", "runs/a/trace.json").unwrap();
        c.apply("obs-sample", "10").unwrap();
        assert_eq!(c.obs, ObsTier::Trace);
        assert_eq!(c.trace_out.as_deref(), Some("runs/a/trace.json"));
        assert_eq!(c.obs_sample, 10);

        // the config.json dump records the effective values and replays
        let back = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(back.req("obs").unwrap().as_str().unwrap(), "trace");
        assert_eq!(
            back.req("trace_out").unwrap().as_str().unwrap(),
            "runs/a/trace.json"
        );
        assert_eq!(back.req("obs_sample").unwrap().as_usize().unwrap(), 10);
        let mut replay = TrainConfig::default();
        replay.apply("obs", back.req("obs").unwrap().as_str().unwrap()).unwrap();
        replay
            .apply("trace_out", back.req("trace_out").unwrap().as_str().unwrap())
            .unwrap();
        assert_eq!(replay.obs, ObsTier::Trace);
        assert_eq!(replay.trace_out.as_deref(), Some("runs/a/trace.json"));

        // defaults: off, sample 1, no trace path
        let d = Json::parse(&TrainConfig::default().to_json().to_string()).unwrap();
        assert_eq!(d.req("obs").unwrap().as_str().unwrap(), "off");
        assert_eq!(d.req("obs_sample").unwrap().as_usize().unwrap(), 1);
        assert!(d.get("trace_out").is_none());

        // bad values are rejected at parse time
        assert!(c.apply("obs", "verbose").is_err());
        assert!(c.apply("obs-sample", "0").is_err());
        assert!(c.apply("obs-sample", "x").is_err());
    }

    #[test]
    fn comm_key_round_trips() {
        let mut c = TrainConfig::default();
        assert_eq!(c.comm, CommMode::Dense);
        c.apply("comm", "subspace").unwrap();
        assert_eq!(c.comm, CommMode::Subspace);
        let back = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(back.req("comm").unwrap().as_str().unwrap(), "subspace");
        let mut replay = TrainConfig::default();
        replay.apply("comm", back.req("comm").unwrap().as_str().unwrap()).unwrap();
        assert_eq!(replay.comm, CommMode::Subspace);
        // default dumps as dense
        let d = Json::parse(&TrainConfig::default().to_json().to_string()).unwrap();
        assert_eq!(d.req("comm").unwrap().as_str().unwrap(), "dense");
        // bad values are rejected at parse time
        assert!(c.apply("comm", "zip").is_err());
    }

    #[test]
    fn wire_key_round_trips() {
        let mut c = TrainConfig::default();
        assert_eq!(c.wire, WireFormat::F32);
        c.apply("wire", "q8").unwrap();
        assert_eq!(c.wire, WireFormat::Q8);
        let back = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(back.req("wire").unwrap().as_str().unwrap(), "q8");
        let mut replay = TrainConfig::default();
        replay.apply("wire", back.req("wire").unwrap().as_str().unwrap()).unwrap();
        assert_eq!(replay.wire, WireFormat::Q8);
        // default dumps as f32
        let d = Json::parse(&TrainConfig::default().to_json().to_string()).unwrap();
        assert_eq!(d.req("wire").unwrap().as_str().unwrap(), "f32");
        // bad values are rejected at parse time
        assert!(c.apply("wire", "bf16").is_err());
    }

    #[test]
    fn max_group_rows_key_round_trips() {
        let mut c = TrainConfig::default();
        assert_eq!(c.opt.max_group_rows, 0);
        c.apply("max-group-rows", "128").unwrap();
        assert_eq!(c.opt.max_group_rows, 128);
        let back = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(back.req("max_group_rows").unwrap().as_usize().unwrap(), 128);
        let mut replay = TrainConfig::default();
        replay.apply("max_group_rows", "128").unwrap();
        assert_eq!(replay.opt.max_group_rows, 128);
        // default dumps as 0 (unlimited / defer to the env knob)
        let d = Json::parse(&TrainConfig::default().to_json().to_string()).unwrap();
        assert_eq!(d.req("max_group_rows").unwrap().as_usize().unwrap(), 0);
        // bad values are rejected at parse time
        assert!(c.apply("max-group-rows", "lots").is_err());
    }

    #[test]
    fn ef_mode_key_round_trips() {
        let mut c = TrainConfig::default();
        for (v, want) in
            [("none", EfMode::None), ("f32", EfMode::F32), ("q8", EfMode::Q8)]
        {
            c.apply("ef-mode", v).unwrap();
            assert_eq!(c.opt.ef_mode, want);
            let back = Json::parse(&c.to_json().to_string()).unwrap();
            assert_eq!(back.req("ef_mode").unwrap().as_str().unwrap(), v);
        }
        assert!(c.apply("ef-mode", "q4").is_err());
    }

    #[test]
    fn dct_projection_grammar_covers_the_full_grid() {
        for (v, norm, mk) in [
            ("dct", RankNorm::L2, true),
            ("dct:l1", RankNorm::L1, true),
            ("dct:l1:matmul", RankNorm::L1, false),
            ("dct:l2:matmul", RankNorm::L2, false),
            ("dct:l2:fft", RankNorm::L2, true),
        ] {
            let got = parse_projection(v).unwrap();
            assert_eq!(got, ProjectionKind::Dct { norm, use_makhoul: mk }, "{v}");
        }
        assert!(parse_projection("dct:l3").is_err());
        // block_power round-trips its iteration count
        assert_eq!(
            parse_projection("block_power:5").unwrap(),
            ProjectionKind::BlockPower { iters: 5 }
        );
        assert_eq!(
            parse_projection("block_power").unwrap(),
            ProjectionKind::BlockPower { iters: 2 }
        );
        assert_eq!(
            TrainConfig::projection_string(&ProjectionKind::BlockPower { iters: 5 }),
            "block_power:5"
        );
        assert!(parse_projection("block_power:x").is_err());
    }

    #[test]
    fn engine_override_keys_build_a_grid_point() {
        use crate::optim::ParamKind;
        let mut c = TrainConfig::default();
        c.apply("optimizer", "galore").unwrap();
        c.apply("update-interval", "50").unwrap();
        c.apply("source", "dct").unwrap();
        c.apply("residual", "ef").unwrap();
        c.apply("ef-mode", "q8").unwrap();
        let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
        let opt = c.build_optimizer(&metas).unwrap();
        assert_eq!(opt.name(), "engine(dct+adamw+ef-q8,T50)");
        // round-trips through the JSON dump
        let back = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(back.req("source").unwrap().as_str().unwrap(), "dct:l2:fft");
        assert_eq!(back.req("residual").unwrap().as_str().unwrap(), "ef");
        // invalid compositions error instead of panicking
        c.apply("rotation", "fixed-basis").unwrap();
        c.apply("source", "svd").unwrap();
        assert!(c.build_optimizer(&metas).is_err());
        // overrides on a dense preset error
        let mut d = TrainConfig::default();
        d.apply("optimizer", "adamw").unwrap();
        d.apply("residual", "ef").unwrap();
        assert!(d.build_optimizer(&metas).is_err());
    }

    #[test]
    fn no_overrides_takes_the_preset_path() {
        use crate::optim::ParamKind;
        let c = TrainConfig::default();
        let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
        let opt = c.build_optimizer(&metas).unwrap();
        assert_eq!(opt.name(), "trion");
    }
}
