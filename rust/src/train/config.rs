//! Run configuration: everything a training run needs, with paper-matching
//! defaults. Parsed from CLI flags (no config-file dependency offline, but
//! `to_json`/`from_json` round-trips so runs are recorded reproducibly).

use anyhow::Result;

use crate::optim::common::EfMode;
use crate::optim::{OptimizerConfig, OptimizerKind};
use crate::projection::{ProjectionKind, RankNorm};
use crate::util::json::{num, obj, s, Json};

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub preset: String,
    pub optimizer: OptimizerKind,
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub weight_decay: f32,
    pub workers: usize,
    pub batch_per_worker: usize,
    pub grad_clip: f32,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub corpus_tokens: usize,
    pub out_dir: String,
    pub run_name: String,
    pub opt: OptimizerConfig,
    /// Execute per-layer optimizer updates through the AOT HLO graphs where
    /// a matching artifact exists (three-layer composition) instead of the
    /// rust-native math.
    pub use_aot_optimizer: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "nano".into(),
            optimizer: OptimizerKind::Trion,
            steps: 200,
            lr: 0.01, // Dion/Trion optimum reported by the paper
            warmup: 20,
            weight_decay: 0.01,
            workers: 4,
            batch_per_worker: 8,
            grad_clip: 1.0,
            seed: 42,
            eval_every: 50,
            eval_batches: 8,
            corpus_tokens: 1 << 20,
            out_dir: "runs".into(),
            run_name: String::new(),
            opt: OptimizerConfig::default(),
            use_aot_optimizer: false,
        }
    }
}

impl TrainConfig {
    pub fn run_name(&self) -> String {
        if self.run_name.is_empty() {
            format!(
                "{}_{}_r{}_s{}",
                self.preset,
                self.optimizer.name(),
                self.opt.rank,
                self.seed
            )
        } else {
            self.run_name.clone()
        }
    }

    pub fn to_json(&self) -> Json {
        let proj = match &self.opt.projection {
            ProjectionKind::Dct { norm, use_makhoul } => format!(
                "dct:{}:{}",
                if *norm == RankNorm::L1 { "l1" } else { "l2" },
                if *use_makhoul { "fft" } else { "matmul" }
            ),
            ProjectionKind::Svd => "svd".into(),
            ProjectionKind::BlockPower { iters } => format!("block_power:{iters}"),
            ProjectionKind::Random => "random".into(),
            ProjectionKind::RandPerm => "randperm".into(),
        };
        obj(vec![
            ("preset", s(&self.preset)),
            ("optimizer", s(self.optimizer.name())),
            ("steps", num(self.steps as f64)),
            ("lr", num(self.lr as f64)),
            ("warmup", num(self.warmup as f64)),
            ("weight_decay", num(self.weight_decay as f64)),
            ("workers", num(self.workers as f64)),
            ("batch_per_worker", num(self.batch_per_worker as f64)),
            ("grad_clip", num(self.grad_clip as f64)),
            ("seed", num(self.seed as f64)),
            ("rank", num(self.opt.rank as f64)),
            ("mu", num(self.opt.mu as f64)),
            ("update_interval", num(self.opt.update_interval as f64)),
            ("projection", s(&proj)),
            (
                "ef_mode",
                s(match self.opt.ef_mode {
                    EfMode::None => "none",
                    EfMode::F32 => "f32",
                    EfMode::Q8 => "q8",
                }),
            ),
            ("use_aot_optimizer", Json::Bool(self.use_aot_optimizer)),
            // 0 = auto (global pool)
            ("threads", num(self.opt.threads.unwrap_or(0) as f64)),
        ])
    }

    /// Parse a `key=value` override (CLI plumbing).
    pub fn apply(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "preset" => self.preset = value.into(),
            "optimizer" => {
                self.optimizer = OptimizerKind::parse(value)
                    .ok_or_else(|| anyhow::anyhow!("unknown optimizer {value}"))?
            }
            "steps" => self.steps = value.parse()?,
            "lr" => self.lr = value.parse()?,
            "warmup" => self.warmup = value.parse()?,
            "weight-decay" | "weight_decay" => self.weight_decay = value.parse()?,
            "workers" => self.workers = value.parse()?,
            "batch-per-worker" | "batch_per_worker" => {
                self.batch_per_worker = value.parse()?
            }
            "grad-clip" | "grad_clip" => self.grad_clip = value.parse()?,
            "seed" => {
                self.seed = value.parse()?;
                self.opt.seed = self.seed;
            }
            "eval-every" | "eval_every" => self.eval_every = value.parse()?,
            "eval-batches" | "eval_batches" => self.eval_batches = value.parse()?,
            "corpus-tokens" | "corpus_tokens" => self.corpus_tokens = value.parse()?,
            "out-dir" | "out_dir" => self.out_dir = value.into(),
            "run-name" | "run_name" => self.run_name = value.into(),
            "rank" => self.opt.rank = value.parse()?,
            "mu" => self.opt.mu = value.parse()?,
            "ns-steps" | "ns_steps" => self.opt.ns_steps = value.parse()?,
            "update-interval" | "update_interval" => {
                self.opt.update_interval = value.parse()?
            }
            "instrument" => self.opt.instrument = value.parse()?,
            // 0 = auto (global pool: FFT_SUBSPACE_THREADS / cores)
            "threads" => {
                let n: usize = value.parse()?;
                self.opt.threads = if n == 0 { None } else { Some(n) };
            }
            "use-aot-optimizer" | "use_aot_optimizer" => {
                self.use_aot_optimizer = value.parse()?
            }
            "projection" => {
                self.opt.projection = match value {
                    "svd" => ProjectionKind::Svd,
                    "random" => ProjectionKind::Random,
                    "randperm" => ProjectionKind::RandPerm,
                    "block_power" | "block-power" => {
                        ProjectionKind::BlockPower { iters: 2 }
                    }
                    "dct" | "dct:l2:fft" => ProjectionKind::Dct {
                        norm: RankNorm::L2,
                        use_makhoul: true,
                    },
                    "dct:l1" => ProjectionKind::Dct {
                        norm: RankNorm::L1,
                        use_makhoul: true,
                    },
                    "dct:l2:matmul" => ProjectionKind::Dct {
                        norm: RankNorm::L2,
                        use_makhoul: false,
                    },
                    _ => anyhow::bail!("unknown projection {value}"),
                }
            }
            "ef-mode" | "ef_mode" => {
                self.opt.ef_mode = match value {
                    "none" => EfMode::None,
                    "f32" => EfMode::F32,
                    "q8" => EfMode::Q8,
                    _ => anyhow::bail!("unknown ef mode {value}"),
                }
            }
            _ => anyhow::bail!("unknown config key {key:?}"),
        }
        // keep optimizer-level mirrors in sync
        self.opt.weight_decay = self.weight_decay;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_overrides() {
        let mut c = TrainConfig::default();
        c.apply("optimizer", "dion").unwrap();
        c.apply("rank", "64").unwrap();
        c.apply("lr", "0.02").unwrap();
        c.apply("projection", "svd").unwrap();
        assert_eq!(c.optimizer, OptimizerKind::Dion);
        assert_eq!(c.opt.rank, 64);
        assert_eq!(c.opt.projection, ProjectionKind::Svd);
        assert!(c.apply("bogus", "1").is_err());
    }

    #[test]
    fn run_name_auto() {
        let mut c = TrainConfig::default();
        c.apply("optimizer", "trion").unwrap();
        c.apply("rank", "16").unwrap();
        assert_eq!(c.run_name(), "nano_trion_r16_s42");
    }

    #[test]
    fn json_snapshot_parses() {
        let c = TrainConfig::default();
        let j = c.to_json().to_string();
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.req("optimizer").unwrap().as_str().unwrap(), "trion");
        assert_eq!(back.req("rank").unwrap().as_usize().unwrap(), 32);
    }
}
