//! The training driver: PJRT fwd/bwd per simulated worker → gradient sync
//! (dense ring all-reduce, or subspace-compressed coefficients under
//! `comm=subspace`) → (optionally AOT-graph) optimizer step under the ZeRO
//! schedule → metrics/eval.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::coordinator::{
    build_grad_sync, CommMode, CommModel, Communicator, GradSync, WireFormat,
    WorkerSet, ZeroSchedule,
};
use crate::data::{BatchLoader, CorpusConfig, SyntheticCorpus};
use crate::obs::{self, trace::TraceWriter, ObsTier};
use crate::optim::{LayerMeta, Optimizer};
use crate::runtime::{Executable, Manifest, ModelSpec, Runtime};
use crate::runtime::client::Value;
use crate::tensor::Matrix;
use crate::train::aot_optim::maybe_wrap_aot;
use crate::train::fault::{FaultInjector, FaultPlan};
use crate::train::guard::{GuardPolicy, StepGuard};
use crate::train::{checkpoint, LrSchedule, TrainConfig};
use crate::util::csv::JsonlWriter;
use crate::util::json::{num, obj, s};
use crate::util::timer::PhaseTimes;
use crate::util::{Pcg64, Timer};

/// Everything a finished run reports (one row of a paper table).
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub run_name: String,
    pub optimizer: String,
    pub preset: String,
    pub rank: usize,
    pub steps: usize,
    pub final_train_loss: f64,
    pub mean_tail_loss: f64,
    pub val_loss: f64,
    pub val_ppl: f64,
    pub wall_secs: f64,
    pub optimizer_state_bytes: u64,
    pub per_worker_state_bytes: u64,
    pub params_bytes: u64,
    pub comm_bytes: u64,
    pub update_broadcast_bytes: u64,
    pub full_broadcast_bytes: u64,
    pub modeled_comm_secs: f64,
    pub metrics_path: PathBuf,
    pub phase_summary: String,
    pub optimizer_secs: f64,
}

impl RunSummary {
    pub fn train_ppl(&self) -> f64 {
        self.mean_tail_loss.exp()
    }
}

pub struct Trainer {
    pub cfg: TrainConfig,
    pub spec: ModelSpec,
    pub metas: Vec<LayerMeta>,
    fwdbwd: Executable,
    eval: Executable,
    pub params: Vec<Matrix>,
    corpus: SyntheticCorpus,
}

impl Trainer {
    pub fn new(manifest: &Manifest, rt: &Runtime, cfg: TrainConfig) -> Result<Self> {
        let spec = manifest.model_spec(&cfg.preset)?;
        anyhow::ensure!(
            cfg.batch_per_worker == spec.batch_per_worker,
            "artifact was lowered for batch_per_worker={}, config asks {} — \
             re-run `make artifacts` with --batch-per-worker",
            spec.batch_per_worker,
            cfg.batch_per_worker
        );
        let fwdbwd = rt
            .load(manifest.find(&format!("fwdbwd_{}", cfg.preset))?)
            .context("loading fwdbwd artifact")?;
        let eval = rt.load(manifest.find(&format!("eval_{}", cfg.preset))?)?;
        let metas: Vec<LayerMeta> =
            spec.params.iter().map(|p| p.layer_meta()).collect();
        let params = init_params(&spec, cfg.seed);
        let corpus = SyntheticCorpus::generate(&CorpusConfig {
            vocab: 256,
            tokens: cfg.corpus_tokens,
            seed: 7_777,
            ..Default::default()
        });
        Ok(Trainer { cfg, spec, metas, fwdbwd, eval, params, corpus })
    }

    /// Run the configured number of steps; streams metrics to
    /// `{out_dir}/{run_name}/metrics.jsonl` and returns the summary row.
    pub fn run(&mut self, manifest: &Manifest, rt: &Runtime) -> Result<RunSummary> {
        let cfg = self.cfg.clone();
        let run_name = cfg.run_name();
        let run_dir = PathBuf::from(&cfg.out_dir).join(&run_name);
        std::fs::create_dir_all(&run_dir)?;
        std::fs::write(run_dir.join("config.json"), cfg.to_json().to_string())?;
        let mut metrics = JsonlWriter::create(run_dir.join("metrics.jsonl"))?;

        // --- observability: resolve the tier before the optimizer builds --
        // (the engine sizes its event rings at build time from the active
        // tier). `obs=` in the config wins over FFT_SUBSPACE_OBS, same
        // precedence as `fault=` vs its env knob.
        let tier =
            if cfg.obs != ObsTier::Off { cfg.obs } else { ObsTier::from_env()? };
        obs::set_tier(tier);
        obs::set_sample(cfg.obs_sample as u64);
        obs::counters().reset();
        let mut tracer = if tier == ObsTier::Trace {
            let path = cfg
                .trace_out
                .clone()
                .map(PathBuf::from)
                .unwrap_or_else(|| run_dir.join("trace.json"));
            Some(TraceWriter::create(&path)?)
        } else {
            None
        };
        // reused drain buffer: per-step engine-event export without a
        // per-step allocation once it reaches steady size
        let mut drained: Vec<obs::Event> = Vec::new();
        let mut trace_dropped = 0u64;

        // optimizer — preset or engine grid point, per the config's
        // source/residual/rotation overrides (optionally AOT-graph-backed
        // for the paper's methods)
        let mut opt: Box<dyn Optimizer> = cfg.build_optimizer(&self.metas)?;
        if cfg.use_aot_optimizer {
            opt = maybe_wrap_aot(opt, &self.metas, &cfg, manifest, rt)?;
        }

        let sched = LrSchedule::WarmupCosine {
            lr: cfg.lr,
            warmup: cfg.warmup,
            total: cfg.steps,
            min_ratio: 0.1,
        };
        let zero = ZeroSchedule::round_robin(self.metas.len(), cfg.workers);
        // Real threads wherever the workload is Send: the ring all-reduce
        // moves its W per-step transfers concurrently, and worker batch
        // staging fans out across the pool. The PJRT fwd/bwd itself stays on
        // this thread — the upstream xla client is Rc-backed (not Send).
        let pool = crate::parallel::global();
        let worker_set = WorkerSet::new(cfg.workers, pool.clone());
        let mut comm = Communicator::with_pool(cfg.workers, CommModel::default(), pool);
        // gradient-sync scheme: `comm=` in the config wins over
        // FFT_SUBSPACE_COMM, same precedence as `obs=` / `fault=`
        let comm_mode = if cfg.comm != CommMode::Dense {
            cfg.comm
        } else {
            CommMode::from_env()
        };
        // wire format of the compressed coefficient blocks: same
        // config-wins-over-env precedence as `comm=`
        let wire = if cfg.wire != WireFormat::F32 {
            cfg.wire
        } else {
            WireFormat::from_env()
        };
        let mut sync: Box<dyn GradSync> =
            build_grad_sync(comm_mode, wire, cfg.workers, &self.metas);
        let base_loader = BatchLoader::new(&self.corpus.train, self.spec.seq_len, cfg.seed);
        let mut workers: Vec<BatchLoader> = (0..cfg.workers)
            .map(|w| base_loader.worker(w, cfg.seed))
            .collect();
        let val_loader = BatchLoader::new(&self.corpus.val, self.spec.seq_len, cfg.seed);

        // --- checkpoint resume (v2): params + optimizer state + step ----
        // The data loaders fast-forward deterministically, so the resumed
        // run consumes the exact batches the uninterrupted run would have —
        // together with the restored optimizer state this makes resumption
        // bit-identical (tests/resume_determinism.rs pins the optimizer
        // layer; the loader replay is plain RNG determinism).
        let mut start_step = 0usize;
        if let Some(path) = &cfg.resume {
            let ck = checkpoint::load_full(path)
                .with_context(|| format!("loading resume checkpoint {path}"))?;
            anyhow::ensure!(
                ck.params.len() == self.params.len(),
                "resume checkpoint has {} params, model has {}",
                ck.params.len(),
                self.params.len()
            );
            let state = ck.state.as_ref().filter(|st| !st.opt_state.is_empty());
            let Some(state) = state else {
                anyhow::bail!(
                    "resume={path} is a params-only checkpoint — use \
                     from-checkpoint for warm starts, resume needs the v2 \
                     optimizer state (save-state=)"
                );
            };
            self.params = ck.params;
            opt.load_state(&state.opt_state)
                .with_context(|| format!("restoring optimizer state from {path}"))?;
            if !state.sync.is_empty() {
                sync.load_state(&state.sync)
                    .with_context(|| format!("restoring sync state from {path}"))?;
            }
            start_step = state.step as usize;
            anyhow::ensure!(
                start_step < cfg.steps,
                "checkpoint is already at step {start_step} of steps={} — \
                 nothing to train; raise steps= to continue the run",
                cfg.steps
            );
            // advance each worker's loader RNG to where the uninterrupted
            // run would be, without materializing the skipped batches
            for wl in workers.iter_mut() {
                wl.skip_batches(start_step, cfg.batch_per_worker);
            }
            println!("resumed {} at step {start_step}/{}", opt.name(), cfg.steps);
        }

        // --- fault tolerance: health guard, in-run snapshots, injection --
        let mut guard = StepGuard::new(cfg.guard, cfg.guard_threshold);
        let fault_plan = match &cfg.fault {
            Some(spec) => FaultPlan::parse(spec)?,
            None => FaultPlan::from_env()?,
        };
        let injector = FaultInjector::new(fault_plan);
        let rollback = cfg.guard == GuardPolicy::Rollback;
        let rotation = if cfg.checkpoint_interval > 0 || rollback {
            let dir = cfg
                .checkpoint_dir
                .clone()
                .map(PathBuf::from)
                .unwrap_or_else(|| run_dir.join("checkpoints"));
            Some(checkpoint::CheckpointRotation::new(dir, cfg.checkpoint_keep))
        } else {
            None
        };
        if let Some(rot) = &rotation {
            // snapshots need resumable optimizer state; discover that now,
            // not at the first mid-run save
            let Some(opt_state) = opt.save_state() else {
                anyhow::bail!(
                    "checkpoint-interval/guard=rollback need an optimizer \
                     with state checkpointing, {} has none",
                    opt.name()
                );
            };
            if rollback {
                // guarantee a restore point exists before the first step —
                // a trip at step start_step must have somewhere to go
                let state = checkpoint::TrainState {
                    step: start_step as u64,
                    optimizer: opt.name().to_string(),
                    opt_state,
                    sync: sync_state(sync.as_ref()),
                };
                rot.save(start_step as u64, &self.params, &state)
                    .context("writing the initial rollback snapshot")?;
            }
        }
        // arm the injected checkpoint tear only now: the initial rollback
        // snapshot above must land, the tear targets a mid-run save
        injector.arm_checkpoint_tear();
        // a genuinely unhealthy model trips on every replay — bound the
        // rollback→replay cycles instead of looping forever
        const MAX_ROLLBACKS: usize = 8;
        let mut rollbacks = 0usize;

        // --- structured memory report at run start (step 0 / resume step) --
        // Always emitted, every tier: the memory table is the paper's
        // headline comparison and costs one record.
        {
            let rep = opt.memory_report();
            let to_obj = |m: &std::collections::BTreeMap<String, u64>| {
                crate::util::json::Json::Obj(
                    m.iter().map(|(k, v)| (k.clone(), num(*v as f64))).collect(),
                )
            };
            metrics.record(&obj(vec![
                ("kind", s("memory_report")),
                ("step", num(start_step as f64)),
                ("optimizer", s(opt.name())),
                ("total_bytes", num(rep.total() as f64)),
                ("per_layer", to_obj(&rep.per_layer)),
                ("shared", to_obj(&rep.shared)),
                // per-worker persisted sync state (EF residuals): ZeRO-
                // sharded, so constant in world size under comm=subspace
                ("sync_state_bytes", num(sync.state_bytes() as f64)),
            ]))?;
        }

        let timer = Timer::start();
        let mut phases = PhaseTimes::new();
        let mut tail_losses: Vec<f64> = Vec::new();
        let mut update_bytes = 0u64;
        let mut full_bytes = 0u64;
        let mut final_loss = f64::NAN;

        let mut step = start_step;
        while step < cfg.steps {
            // --- per-worker batch staging on real threads ----------------
            let bpw = cfg.batch_per_worker;
            let t0 = obs::now_us();
            let batches: Vec<(Vec<i32>, Vec<usize>)> = phases.time("batch", || {
                let mut slots: Vec<Option<(Vec<i32>, Vec<usize>)>> =
                    (0..cfg.workers).map(|_| None).collect();
                let mut pairs: Vec<_> =
                    workers.iter_mut().zip(slots.iter_mut()).collect();
                worker_set.run_mut(&mut pairs, |w, (wl, slot)| {
                    // injected lane failure fires *before* the loader draws,
                    // so the bounded retry replays the exact same batch
                    injector.maybe_fail_worker(step, w);
                    **slot = Some(wl.next_batch(bpw));
                });
                slots.into_iter().map(|s| s.expect("staged batch")).collect()
            });
            trace_phase(&mut tracer, "batch", t0, step)?;

            // --- per-worker fwd/bwd through PJRT (driver thread: the PJRT
            // client is Rc-backed; see coordinator::workers) --------------
            let mut worker_grads: Vec<Vec<Matrix>> = Vec::with_capacity(cfg.workers);
            let mut step_loss = 0.0f64;
            for (tokens, shape) in batches {
                let t0 = obs::now_us();
                let outs = phases.time("fwdbwd", || {
                    let mut inputs: Vec<Value> = self
                        .params
                        .iter()
                        .map(|p| Value::F32(p.clone()))
                        .collect();
                    inputs.push(Value::tokens(tokens, shape));
                    self.fwdbwd.run(&inputs)
                })?;
                trace_phase(&mut tracer, "fwdbwd", t0, step)?;
                step_loss += outs.scalar(0) as f64;
                worker_grads.push(outs.values.into_iter().skip(1).collect());
            }
            step_loss /= cfg.workers as f64;

            // --- gradient sync per parameter (dense ring all-reduce, or
            // r×R coefficient all-reduce under comm=subspace) -------------
            let t0 = obs::now_us();
            let grads: Vec<Matrix> = phases.time("allreduce", || {
                let mut reduced = Vec::new();
                sync.reduce(&mut worker_grads, opt.as_ref(), &mut comm, &mut reduced);
                reduced
            });
            trace_phase(&mut tracer, "allreduce", t0, step)?;

            // --- deterministic fault injection (post-reduce, pre-clip) --
            let mut grads = grads;
            if let Some(kind) = injector.corrupt_grads(step, &mut grads) {
                eprintln!("fault injection: {kind} planted in step {step}'s gradient");
            }

            // --- global gradient clipping -------------------------------
            let grads = clip_grads(grads, cfg.grad_clip);

            // --- numerical-health guard ---------------------------------
            // Checked after clipping (what the optimizer would consume),
            // before any state mutation — a tripped step leaves params and
            // optimizer state exactly as they were.
            let t0 = obs::now_us();
            let verdict = guard.check(step_loss, &grads);
            trace_phase(&mut tracer, "guard", t0, step)?;
            if !verdict.is_healthy() {
                // the regular loss record is skipped on tripped steps (a
                // NaN would poison the JSONL); this event replaces it —
                // and flushes, so a run killed mid-incident keeps it
                metrics.record(&obj(vec![
                    ("step", num(step as f64)),
                    ("guard", s(verdict.reason())),
                    ("policy", s(guard.policy().name())),
                ]))?;
                metrics.flush()?;
                if rollback {
                    rollbacks += 1;
                    obs::count_rollback();
                    anyhow::ensure!(
                        rollbacks <= MAX_ROLLBACKS,
                        "guard tripped {rollbacks} times under rollback \
                         ({} at step {step}) — the model is unhealthy, not \
                         the step; aborting",
                        verdict.reason()
                    );
                    let rot = rotation.as_ref().expect("rollback implies rotation");
                    let Some((_, snap_path)) = rot.latest()? else {
                        anyhow::bail!(
                            "guard tripped at step {step} but no rollback \
                             snapshot exists in {:?}",
                            rot.dir()
                        );
                    };
                    let ck = checkpoint::load_full(&snap_path).with_context(|| {
                        format!("restoring rollback snapshot {snap_path:?}")
                    })?;
                    let state = ck.state.with_context(|| {
                        format!("rollback snapshot {snap_path:?} has no state")
                    })?;
                    self.params = ck.params;
                    opt.load_state(&state.opt_state).with_context(|| {
                        format!("restoring optimizer state from {snap_path:?}")
                    })?;
                    if !state.sync.is_empty() {
                        sync.load_state(&state.sync).with_context(|| {
                            format!("restoring sync state from {snap_path:?}")
                        })?;
                    }
                    let snap_step = state.step as usize;
                    // fresh loaders fast-forwarded to the snapshot: the
                    // replayed window consumes the exact batches the
                    // original pass did (same RNG draws)
                    workers = (0..cfg.workers)
                        .map(|w| base_loader.worker(w, cfg.seed))
                        .collect();
                    for wl in workers.iter_mut() {
                        wl.skip_batches(snap_step, cfg.batch_per_worker);
                    }
                    guard.reset();
                    eprintln!(
                        "guard: {} at step {step} — rolled back to step \
                         {snap_step} ({snap_path:?})",
                        verdict.reason()
                    );
                    step = snap_step;
                } else {
                    // skip: drop the poisoned step on the floor and move on
                    eprintln!(
                        "guard: {} at step {step} — step skipped",
                        verdict.reason()
                    );
                    step += 1;
                }
                continue;
            }
            final_loss = step_loss;

            // --- optimizer step (ZeRO owner-computes + broadcast model) --
            let lr = sched.at(step);
            let t0 = obs::now_us();
            phases.time("optimizer", || {
                opt.step(&mut self.params, &grads, lr);
            });
            trace_phase(&mut tracer, "optimizer", t0, step)?;
            // refresh-boundary hook: compressed sync accounts the rank-0
            // basis broadcast + agreement check for layers that refreshed
            sync.after_step(opt.as_ref(), &mut comm);
            // per-layer engine spans recorded inside opt.step drain here,
            // off the hot path; gauges land in metrics.jsonl
            if let Some(tw) = tracer.as_mut() {
                drained.clear();
                trace_dropped += opt.drain_events(&mut drained);
                for e in &drained {
                    tw.emit_event(e, step as u64)?;
                }
            }
            if obs::enabled() {
                for (layer, t, q) in opt.refresh_gauges() {
                    metrics.record(&obj(vec![
                        ("kind", s("subspace_quality")),
                        ("step", num(t as f64)),
                        ("layer", s(&layer)),
                        ("energy_ratio", num(q.energy_ratio as f64)),
                        ("resid_norm", num(q.resid_norm as f64)),
                        ("overlap", num(q.overlap as f64)),
                    ]))?;
                }
            }
            let zstats = zero.account_step(&self.metas, opt.as_ref(), &mut comm);
            update_bytes += zstats.update_broadcast_bytes;
            full_bytes += zstats.full_broadcast_bytes;

            // --- periodic atomic snapshot (completed-steps semantics) ----
            if let Some(rot) = &rotation {
                let completed = step + 1;
                if cfg.checkpoint_interval > 0
                    && completed % cfg.checkpoint_interval == 0
                {
                    // save_state is Some: checked at rotation setup
                    if let Some(opt_state) = opt.save_state() {
                        let state = checkpoint::TrainState {
                            step: completed as u64,
                            optimizer: opt.name().to_string(),
                            opt_state,
                            sync: sync_state(sync.as_ref()),
                        };
                        let t0 = obs::now_us();
                        let saved = rot.save(completed as u64, &self.params, &state);
                        trace_phase(&mut tracer, "checkpoint", t0, step)?;
                        if let Err(e) = saved {
                            // a failed (torn) snapshot must not kill the
                            // run: the previous good snapshot is intact
                            eprintln!(
                                "warning: snapshot at step {completed} \
                                 failed ({e:#}) — continuing on the \
                                 previous snapshot"
                            );
                        }
                    }
                }
            }

            if step < 5 || step % 10 == 0 || step + 1 == cfg.steps {
                let mut rec = vec![
                    ("step", num(step as f64)),
                    ("loss", num(step_loss)),
                    ("lr", num(lr as f64)),
                    ("wall_secs", num(timer.elapsed_secs())),
                    ("comm_bytes", num(comm.stats.total_bytes() as f64)),
                ];
                if let Some(errs) = opt.projection_errors() {
                    for (k, v) in errs {
                        // stable keys for the fig1 harness
                        rec.push(("proj_err", obj(vec![("layer", s(k)), ("err", num(*v))])));
                        break; // full map dumped separately below
                    }
                    let full: Vec<(String, f64)> =
                        errs.iter().map(|(k, v)| (k.clone(), *v)).collect();
                    let json_obj = crate::util::json::Json::Obj(
                        full.into_iter()
                            .map(|(k, v)| (k, num(v)))
                            .collect(),
                    );
                    rec.push(("proj_errors", json_obj));
                }
                metrics.record(&obj(rec))?;
            }
            if cfg.steps >= 10 && step >= cfg.steps - cfg.steps / 10 {
                tail_losses.push(step_loss);
            }

            // --- periodic eval ------------------------------------------
            if cfg.eval_every > 0
                && (step + 1) % cfg.eval_every == 0
                && step + 1 != cfg.steps
            {
                let (vl, _) = self.evaluate(&val_loader, cfg.eval_batches, &mut phases)?;
                metrics.record(&obj(vec![
                    ("step", num(step as f64)),
                    ("val_loss", num(vl)),
                    ("wall_secs", num(timer.elapsed_secs())),
                ]))?;
            }

            step += 1;
        }

        let (val_loss, val_ppl) =
            self.evaluate(&val_loader, cfg.eval_batches.max(4), &mut phases)?;
        let wall = timer.elapsed_secs();
        let rep = opt.memory_report();
        let mean_tail = if tail_losses.is_empty() {
            final_loss
        } else {
            tail_losses.iter().sum::<f64>() / tail_losses.len() as f64
        };
        metrics.record(&obj(vec![
            ("final", num(1.0)),
            ("val_loss", num(val_loss)),
            ("val_ppl", num(val_ppl)),
            ("wall_secs", num(wall)),
        ]))?;
        // --- telemetry exporters (counters tier and up) ------------------
        if obs::enabled() {
            let snap = obs::counters().snapshot();
            let mut rec = vec![("kind", s("obs_counters"))];
            for (name, value) in snap.entries() {
                rec.push((name, num(value as f64)));
            }
            if trace_dropped > 0 {
                rec.push(("trace_dropped", num(trace_dropped as f64)));
            }
            metrics.record(&obj(rec))?;
            std::fs::write(
                run_dir.join("metrics.prom"),
                obs::trace::prometheus_text(&snap),
            )?;
            println!("{}", obs::trace::summary_table(&snap));
        }
        if let Some(tw) = tracer.as_mut() {
            tw.finish()?;
            println!("trace: {}", tw.path.display());
        }
        metrics.flush()?;

        // --- full-state checkpoint (v2) ---------------------------------
        if let Some(path) = &cfg.save_state_to {
            let Some(opt_state) = opt.save_state() else {
                anyhow::bail!(
                    "save-state={path}: optimizer {} does not support state \
                     checkpointing (use save-checkpoint for params only)",
                    opt.name()
                );
            };
            let state = checkpoint::TrainState {
                step: cfg.steps as u64,
                optimizer: opt.name().to_string(),
                opt_state,
                sync: sync_state(sync.as_ref()),
            };
            checkpoint::save_v2(path, &self.params, &state)
                .with_context(|| format!("writing save-state checkpoint {path}"))?;
            println!("state checkpoint: {path} (resume={path} to continue)");
        }

        Ok(RunSummary {
            run_name,
            optimizer: opt.name().to_string(),
            preset: cfg.preset.clone(),
            rank: cfg.opt.rank,
            steps: cfg.steps,
            final_train_loss: final_loss,
            mean_tail_loss: mean_tail,
            val_loss,
            val_ppl,
            wall_secs: wall,
            optimizer_state_bytes: rep.total(),
            per_worker_state_bytes: zero.per_worker_state_bytes(opt.as_ref()),
            params_bytes: self.params.iter().map(|p| p.bytes()).sum(),
            comm_bytes: comm.stats.total_bytes(),
            update_broadcast_bytes: update_bytes,
            full_broadcast_bytes: full_bytes,
            modeled_comm_secs: comm.stats.modeled_secs,
            metrics_path: run_dir.join("metrics.jsonl"),
            phase_summary: phases.summary(),
            optimizer_secs: phases.secs("optimizer"),
        })
    }

    fn evaluate(
        &self,
        val_loader: &BatchLoader,
        batches: usize,
        phases: &mut PhaseTimes,
    ) -> Result<(f64, f64)> {
        let mut total = 0.0f64;
        let eval_batches = val_loader.eval_batches(self.cfg.batch_per_worker, batches);
        let n = eval_batches.len();
        for (tokens, shape) in eval_batches {
            let outs = phases.time("eval", || {
                let mut inputs: Vec<Value> =
                    self.params.iter().map(|p| Value::F32(p.clone())).collect();
                inputs.push(Value::tokens(tokens, shape));
                self.eval.run(&inputs)
            })?;
            total += outs.scalar(0) as f64;
        }
        let loss = total / n.max(1) as f64;
        Ok((loss, loss.exp()))
    }
}

/// Parameter init mirroring `model.py::init_params` semantics (norms = 1,
/// embeds/heads std 0.02, linears 1/sqrt(fan_in)).
pub fn init_params(spec: &ModelSpec, seed: u64) -> Vec<Matrix> {
    let mut rng = Pcg64::new(seed, 0x1217);
    spec.params
        .iter()
        .map(|p| {
            let meta = p.layer_meta();
            match p.kind {
                crate::optim::ParamKind::Norm => {
                    Matrix::from_vec(meta.rows, meta.cols, vec![1.0; meta.rows * meta.cols])
                }
                crate::optim::ParamKind::Embed | crate::optim::ParamKind::Head => {
                    Matrix::randn(meta.rows, meta.cols, 0.02, &mut rng)
                }
                crate::optim::ParamKind::Linear => {
                    let std = 1.0 / (meta.rows as f32).sqrt();
                    Matrix::randn(meta.rows, meta.cols, std, &mut rng)
                }
            }
        })
        .collect()
}

/// Serialize a sync scheme's cross-step state for checkpoint v2 (empty for
/// stateless schemes — the SYNC section is then omitted entirely).
fn sync_state(sync: &dyn GradSync) -> Vec<u8> {
    let mut out = Vec::new();
    sync.save_state(&mut out);
    out
}

/// Emit a trainer-thread phase span (`tid` 0) when the run is tracing.
/// `t0` is the `obs::now_us()` reading taken just before the phase ran.
fn trace_phase(
    tw: &mut Option<TraceWriter>,
    name: &str,
    t0: u64,
    step: usize,
) -> Result<()> {
    if let Some(tw) = tw {
        tw.emit(
            name,
            0,
            t0,
            obs::now_us().saturating_sub(t0),
            step as u64,
            obs::Event::NO_LAYER,
        )?;
    }
    Ok(())
}

/// Global-norm gradient clipping.
pub fn clip_grads(mut grads: Vec<Matrix>, max_norm: f32) -> Vec<Matrix> {
    if max_norm <= 0.0 {
        return grads;
    }
    let total: f64 = grads.iter().map(|g| g.fro_norm_sq()).sum();
    let norm = total.sqrt() as f32;
    if norm > max_norm {
        let scale = max_norm / norm;
        for g in &mut grads {
            g.scale(scale);
        }
    }
    grads
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_caps_global_norm() {
        let g = vec![
            Matrix::from_vec(1, 2, vec![3.0, 0.0]),
            Matrix::from_vec(1, 2, vec![0.0, 4.0]),
        ];
        let clipped = clip_grads(g, 1.0);
        let norm: f64 = clipped.iter().map(|m| m.fro_norm_sq()).sum::<f64>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
        // direction preserved
        assert!((clipped[0].data[0] / clipped[1].data[1] - 0.75).abs() < 1e-5);
    }

    #[test]
    fn clip_leaves_small_grads_alone() {
        let g = vec![Matrix::from_vec(1, 2, vec![0.1, 0.1])];
        let clipped = clip_grads(g.clone(), 1.0);
        assert_eq!(clipped[0], g[0]);
    }
}
