//! Training stack: config, parameter store, LR schedules and the [`Trainer`]
//! that drives PJRT fwd/bwd → simulated-DDP all-reduce → ZeRO-scheduled
//! optimizer updates → metrics.

pub mod aot_optim;
pub mod checkpoint;
pub mod config;
pub mod fault;
pub mod finetune;
pub mod guard;
pub mod schedule;
pub mod trainer;

pub use config::TrainConfig;
pub use fault::{FaultInjector, FaultPlan};
pub use guard::{GuardPolicy, GuardVerdict, StepGuard};
pub use schedule::LrSchedule;
pub use trainer::{RunSummary, Trainer};
