//! Numerical-health guard for the training loop.
//!
//! Each step, [`StepGuard::check`] scans every gradient with the
//! allocation-free SIMD finite-scan kernel ([`crate::tensor::all_finite`])
//! and validates the step loss — both against non-finites and, optionally,
//! against a loss *spike* relative to a smoothed history. The verdict
//! carries no heap data (layer identity is an index, not a name), so a
//! guarded steady-state step stays allocation-free — pinned by
//! `tests/alloc_steady_state.rs`.
//!
//! The policy decides what the trainer does with a trip:
//!
//! * [`GuardPolicy::Off`]      — no scanning at all (zero overhead).
//! * [`GuardPolicy::Skip`]     — drop the poisoned step: no optimizer
//!   update, no state mutation, training continues at the next batch.
//! * [`GuardPolicy::Rollback`] — restore the latest good in-run snapshot
//!   and replay from there (see `train::trainer`).
//!
//! The loss EMA only absorbs *healthy* losses, so one spike can't drag the
//! baseline up and mask a second spike; on rollback the trainer calls
//! [`StepGuard::reset`] because the replayed window re-reports its losses.

use crate::tensor::{all_finite, Matrix};

/// What the trainer does when the guard trips.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GuardPolicy {
    /// No health checks (the pre-guard behavior).
    Off,
    /// Drop the step: optimizer state and params stay untouched.
    Skip,
    /// Restore the latest good checkpoint and replay.
    Rollback,
}

impl GuardPolicy {
    /// Parse a `guard=` config value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(Self::Off),
            "skip" => Some(Self::Skip),
            "rollback" => Some(Self::Rollback),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Skip => "skip",
            Self::Rollback => "rollback",
        }
    }
}

/// Outcome of one health check. `Healthy` is the hot-path answer; the trip
/// variants identify the first failure found (gradients are scanned in
/// layer order, loss after gradients, spike last).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GuardVerdict {
    Healthy,
    /// Gradient of layer `layer` (index into the meta/grad slices) holds a
    /// NaN or ±Inf.
    NonFiniteGrad { layer: usize },
    /// The reduced step loss itself is NaN or ±Inf.
    NonFiniteLoss,
    /// Loss is finite but exceeds `limit` = threshold × EMA(loss).
    LossSpike { loss: f64, limit: f64 },
}

impl GuardVerdict {
    pub fn is_healthy(&self) -> bool {
        matches!(self, Self::Healthy)
    }

    /// Static-str reason tag for metrics/JSONL (no allocation).
    pub fn reason(&self) -> &'static str {
        match self {
            Self::Healthy => "healthy",
            Self::NonFiniteGrad { .. } => "non-finite-grad",
            Self::NonFiniteLoss => "non-finite-loss",
            Self::LossSpike { .. } => "loss-spike",
        }
    }
}

/// EMA smoothing for the spike baseline. Warm enough after a handful of
/// steps, slow enough that a genuine loss plateau shift doesn't trip.
const EMA_BETA: f64 = 0.9;

/// Per-run guard state: policy, spike threshold, smoothed loss history.
pub struct StepGuard {
    policy: GuardPolicy,
    /// Spike trip point as a multiple of the loss EMA; `0.0` disables
    /// spike detection (non-finite checks still run).
    threshold: f32,
    ema_loss: Option<f64>,
}

impl StepGuard {
    pub fn new(policy: GuardPolicy, threshold: f32) -> Self {
        Self { policy, threshold, ema_loss: None }
    }

    pub fn policy(&self) -> GuardPolicy {
        self.policy
    }

    /// Check one step's reduced loss and post-clip gradients. Allocation-
    /// free. With `GuardPolicy::Off` this is a single branch — no scan.
    /// Trips feed the `obs::guard_trips` counter (an atomic bump — the
    /// verdict itself stays heap-free).
    pub fn check(&mut self, loss: f64, grads: &[Matrix]) -> GuardVerdict {
        if self.policy == GuardPolicy::Off {
            return GuardVerdict::Healthy;
        }
        let verdict = self.scan(loss, grads);
        if !verdict.is_healthy() {
            crate::obs::count_guard_trip();
        }
        verdict
    }

    fn scan(&mut self, loss: f64, grads: &[Matrix]) -> GuardVerdict {
        if let Some(layer) = first_nonfinite_layer(grads) {
            return GuardVerdict::NonFiniteGrad { layer };
        }
        if !loss.is_finite() {
            return GuardVerdict::NonFiniteLoss;
        }
        if self.threshold > 0.0 {
            if let Some(ema) = self.ema_loss {
                let limit = self.threshold as f64 * ema;
                if loss > limit {
                    return GuardVerdict::LossSpike { loss, limit };
                }
            }
        }
        // Only healthy losses feed the baseline — a spike that slipped
        // under the limit still moves it, but a tripped step never does.
        self.ema_loss = Some(match self.ema_loss {
            Some(ema) => EMA_BETA * ema + (1.0 - EMA_BETA) * loss,
            None => loss,
        });
        GuardVerdict::Healthy
    }

    /// Forget the loss history (called after a rollback: the replayed
    /// window re-reports its losses from the snapshot point).
    pub fn reset(&mut self) {
        self.ema_loss = None;
    }
}

/// Scan every gradient for non-finites across the global pool, reporting
/// the **minimum** offending layer index — the same verdict the old serial
/// sweep produced, regardless of which lane finds its hit first.
/// Allocation-free: one stack atomic, chunks over the existing pool.
fn first_nonfinite_layer(grads: &[Matrix]) -> Option<usize> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let n = grads.len();
    if n == 0 {
        return None;
    }
    let pool = crate::parallel::global();
    let (per, n_chunks) = crate::parallel::partition(pool.threads(), n);
    let min = AtomicUsize::new(usize::MAX);
    pool.par_chunks(n_chunks, |k| {
        let lo = k * per;
        let hi = (lo + per).min(n);
        for (i, g) in grads[lo..hi].iter().enumerate() {
            if !all_finite(&g.data) {
                // Layers within a chunk scan in ascending order, so the
                // first hit is this chunk's minimum; fetch_min reduces
                // across chunks.
                min.fetch_min(lo + i, Ordering::Relaxed);
                break;
            }
        }
    });
    match min.load(Ordering::Relaxed) {
        usize::MAX => None,
        i => Some(i),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finite_grads() -> Vec<Matrix> {
        let mut g = Matrix::zeros(3, 4);
        for (i, x) in g.data.iter_mut().enumerate() {
            *x = 0.01 * i as f32 - 0.05;
        }
        vec![g, Matrix::zeros(2, 2)]
    }

    #[test]
    fn off_never_trips() {
        let mut guard = StepGuard::new(GuardPolicy::Off, 2.0);
        let mut grads = finite_grads();
        grads[1].data[0] = f32::NAN;
        assert_eq!(guard.check(f64::NAN, &grads), GuardVerdict::Healthy);
    }

    #[test]
    fn reports_first_nonfinite_layer() {
        let mut guard = StepGuard::new(GuardPolicy::Skip, 0.0);
        let mut grads = finite_grads();
        grads[1].data[3] = f32::NEG_INFINITY;
        assert_eq!(
            guard.check(1.0, &grads),
            GuardVerdict::NonFiniteGrad { layer: 1 }
        );
        grads[0].data[7] = f32::NAN;
        assert_eq!(
            guard.check(1.0, &grads),
            GuardVerdict::NonFiniteGrad { layer: 0 }
        );
    }

    #[test]
    fn parallel_sweep_reports_minimum_offender() {
        let mut guard = StepGuard::new(GuardPolicy::Skip, 0.0);
        // enough layers that the global pool actually chunks the sweep
        let mut grads: Vec<Matrix> = (0..37).map(|_| Matrix::zeros(4, 5)).collect();
        for g in &mut grads {
            g.data.fill(0.5);
        }
        for &i in &[31usize, 7, 22] {
            grads[i].data[2] = f32::NAN;
        }
        assert_eq!(
            guard.check(1.0, &grads),
            GuardVerdict::NonFiniteGrad { layer: 7 }
        );
    }

    #[test]
    fn nonfinite_loss_trips_after_grads_pass() {
        let mut guard = StepGuard::new(GuardPolicy::Rollback, 0.0);
        assert_eq!(
            guard.check(f64::INFINITY, &finite_grads()),
            GuardVerdict::NonFiniteLoss
        );
    }

    #[test]
    fn spike_detection_needs_warm_ema_and_threshold() {
        let grads = finite_grads();
        // threshold 0 → spikes ignored
        let mut off = StepGuard::new(GuardPolicy::Skip, 0.0);
        assert!(off.check(1.0, &grads).is_healthy());
        assert!(off.check(1e9, &grads).is_healthy());

        let mut guard = StepGuard::new(GuardPolicy::Skip, 2.0);
        // first loss seeds the EMA — can't spike with no baseline
        assert!(guard.check(4.0, &grads).is_healthy());
        // within 2× of the baseline
        assert!(guard.check(6.0, &grads).is_healthy());
        // way above 2× EMA → trip, and the EMA must NOT absorb it
        let verdict = guard.check(100.0, &grads);
        assert!(matches!(verdict, GuardVerdict::LossSpike { .. }));
        // baseline unchanged by the trip: same spike trips again
        assert!(matches!(
            guard.check(100.0, &grads),
            GuardVerdict::LossSpike { .. }
        ));
        // reset clears history: the next loss re-seeds instead of tripping
        guard.reset();
        assert!(guard.check(100.0, &grads).is_healthy());
    }
}
