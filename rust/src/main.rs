//! `fft-subspace` — CLI launcher for the FFT/DCT dynamic-subspace
//! low-rank-optimization stack.
//!
//! ```text
//! fft-subspace train [key=value …]         one training run
//! fft-subspace finetune [key=value …]      one fine-tuning run
//! fft-subspace experiment <id> [--quick]   regenerate a paper table/figure
//! fft-subspace inspect                     list AOT artifacts
//! fft-subspace info                        platform + presets + memory table
//! ```
//!
//! (clap is unavailable offline; `key=value` overrides map 1:1 onto
//! [`fft_subspace::train::TrainConfig::apply`].)

use anyhow::{bail, Context, Result};

use fft_subspace::experiments::{self, ExpOptions};
use fft_subspace::optim::{build_optimizer, OptimizerConfig, OptimizerKind, OptimizerSpec};
use fft_subspace::runtime::{Manifest, Runtime};
use fft_subspace::train::finetune::Finetuner;
use fft_subspace::train::{checkpoint, TrainConfig, Trainer};
use fft_subspace::util::human;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir() -> String {
    std::env::var("FFT_SUBSPACE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    match cmd.as_str() {
        "train" => cmd_train(&args[1..], false),
        "finetune" => cmd_train(&args[1..], true),
        "experiment" => cmd_experiment(&args[1..]),
        "inspect" => cmd_inspect(),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} — try `fft-subspace help`"),
    }
}

fn print_usage() {
    println!("{}", include_str!("usage.txt"));
}

fn parse_overrides(args: &[String], cfg: &mut TrainConfig) -> Result<Vec<(String, String)>> {
    let mut extra = Vec::new();
    for a in args {
        if let Some(flag) = a.strip_prefix("--") {
            let (k, v) = flag.split_once('=').unwrap_or((flag, "true"));
            if cfg.apply(k, v).is_err() {
                extra.push((k.to_string(), v.to_string()));
            }
        } else if let Some((k, v)) = a.split_once('=') {
            if cfg.apply(k, v).is_err() {
                extra.push((k.to_string(), v.to_string()));
            }
        } else {
            bail!("unrecognized argument {a:?} (use key=value)");
        }
    }
    Ok(extra)
}

fn cmd_train(args: &[String], finetune: bool) -> Result<()> {
    let mut cfg = TrainConfig::default();
    let extra = parse_overrides(args, &mut cfg)?;
    let mut from_checkpoint: Option<String> = None;
    let mut save_checkpoint: Option<String> = None;
    for (k, v) in extra {
        match k.as_str() {
            "from-checkpoint" | "from_checkpoint" => from_checkpoint = Some(v),
            "save-checkpoint" | "save_checkpoint" => save_checkpoint = Some(v),
            other => bail!("unknown option {other:?}"),
        }
    }

    let manifest = Manifest::load(artifacts_dir())?;
    let rt = Runtime::new()?;
    println!(
        "platform={} preset={} optimizer={} rank={} steps={} workers={}",
        rt.platform(),
        cfg.preset,
        cfg.optimizer.name(),
        cfg.opt.rank,
        cfg.steps,
        cfg.workers
    );

    if finetune {
        let base = match &from_checkpoint {
            Some(p) => Some(checkpoint::load(p).context("loading --from-checkpoint")?),
            None => None,
        };
        let mut ft = Finetuner::new(&manifest, &rt, cfg, base)?;
        let sum = ft.run(&manifest, &rt)?;
        println!(
            "finetune done: optimizer={} loss={:.4} accuracy={:.2}% mem={} wall={}",
            sum.optimizer,
            sum.final_train_loss,
            sum.accuracy * 100.0,
            human::bytes(sum.optimizer_state_bytes),
            human::duration(sum.wall_secs),
        );
        if let Some(p) = save_checkpoint {
            checkpoint::save(&p, &ft.params)?;
            println!("checkpoint: {p}");
        }
    } else {
        let mut tr = Trainer::new(&manifest, &rt, cfg)?;
        if let Some(p) = &from_checkpoint {
            tr.params = checkpoint::load(p)?;
        }
        let sum = tr.run(&manifest, &rt)?;
        println!(
            "train done: optimizer={} train_loss={:.4} val_loss={:.4} (ppl {:.2}) \
             opt_mem={} (zero/worker {}) comm={} wall={}",
            sum.optimizer,
            sum.mean_tail_loss,
            sum.val_loss,
            sum.val_ppl,
            human::bytes(sum.optimizer_state_bytes),
            human::bytes(sum.per_worker_state_bytes),
            human::bytes(sum.comm_bytes),
            human::duration(sum.wall_secs),
        );
        println!("phases: {}", sum.phase_summary);
        println!("metrics: {}", sum.metrics_path.display());
        if let Some(p) = save_checkpoint {
            checkpoint::save(&p, &tr.params)?;
            println!("checkpoint: {p}");
        }
    }
    Ok(())
}

fn cmd_experiment(args: &[String]) -> Result<()> {
    let Some(name) = args.first() else {
        bail!("usage: fft-subspace experiment <table1|table2|table3|table6|table7|table8|fig1|all> [--quick]");
    };
    let mut opts = ExpOptions::default();
    for a in &args[1..] {
        match a.as_str() {
            "--quick" => opts.quick = true,
            other => {
                if let Some(v) = other.strip_prefix("--seed=") {
                    opts.seed = v.parse()?;
                } else if let Some(v) = other.strip_prefix("--out-dir=") {
                    opts.out_dir = v.into();
                } else {
                    bail!("unknown experiment option {other:?}");
                }
            }
        }
    }
    let manifest = Manifest::load(artifacts_dir())?;
    let rt = Runtime::new()?;
    experiments::run(name, &manifest, &rt, &opts)
}

fn cmd_inspect() -> Result<()> {
    let manifest = Manifest::load(artifacts_dir())?;
    println!("{} artifacts in {}:", manifest.artifacts.len(), manifest.dir.display());
    for a in &manifest.artifacts {
        let outs: Vec<String> = a
            .outputs
            .iter()
            .map(|o| format!("{}:{:?}", o.name, o.shape))
            .collect();
        println!(
            "  {:<34} [{}]  in({}) -> out({})",
            a.name,
            a.kind,
            a.inputs.len(),
            outs.join(", ")
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let manifest = Manifest::load(artifacts_dir())?;
    let rt = Runtime::new()?;
    println!("platform: {}", rt.platform());
    println!("presets:");
    for preset in manifest.presets() {
        let spec = manifest.model_spec(&preset)?;
        println!(
            "  {:<6} d_model={:<4} layers={} seq={} params={}",
            preset,
            spec.d_model,
            spec.n_layers,
            spec.seq_len,
            human::params(spec.num_params as u64),
        );
    }
    // optimizer memory table for the default preset (paper's memory story),
    // with each low-rank preset's engine composition spelled out — any
    // other grid point is the same axes via source=/residual=/rotation=
    let spec = manifest.model_spec("micro")?;
    let metas: Vec<_> = spec.params.iter().map(|p| p.layer_meta()).collect();
    let cfg = OptimizerConfig { rank: 32, ..Default::default() };
    println!("\noptimizer state @ micro, rank 32:");
    for kind in [
        OptimizerKind::AdamW,
        OptimizerKind::Muon,
        OptimizerKind::Dion,
        OptimizerKind::Trion,
        OptimizerKind::GaLore,
        OptimizerKind::LdAdamW,
        OptimizerKind::DctAdamW,
        OptimizerKind::Frugal,
        OptimizerKind::Fira,
    ] {
        let opt = build_optimizer(&kind, &metas, &cfg);
        let rep = opt.memory_report();
        let composition = OptimizerSpec::from_kind(&kind, &cfg)
            .map(|s| format!("  [{}]", s.describe()))
            .unwrap_or_default();
        println!(
            "  {:<10} {}{}",
            kind.name(),
            human::bytes(rep.total()),
            composition
        );
    }
    Ok(())
}
