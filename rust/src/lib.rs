//! # fft-subspace
//!
//! Reproduction of **"FFT-based Dynamic Subspace Selection for Low-Rank
//! Adaptive Optimization of Large Language Models"** (Modoranu et al., 2025)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 1 (Pallas, build time)** — fused DCT-similarity + column-norm
//!   kernels, Newton–Schulz orthogonalization, AdamW update kernels
//!   (`python/compile/kernels/`), validated against a pure-jnp oracle.
//! - **Layer 2 (JAX, build time)** — Llama-style transformer forward/backward
//!   and per-layer optimizer update graphs, AOT-lowered to HLO text
//!   (`python/compile/model.py`, `python/compile/aot.py`).
//! - **Layer 3 (Rust, run time)** — this crate: PJRT runtime that loads the
//!   AOT artifacts, a simulated multi-worker DDP/ZeRO coordinator, native
//!   implementations of all optimizers from the paper (Trion, DCT-AdamW) and
//!   every baseline it compares against (AdamW, Muon, Dion, GaLore, LDAdamW,
//!   FRUGAL, FIRA), and the full experiment/bench harness that regenerates
//!   every table and figure of the paper.
//!
//! Python never runs on the training path: `make artifacts` lowers everything
//! once, and the `fft-subspace` binary is self-contained afterwards.
#![allow(clippy::needless_range_loop)]

pub mod util;
pub mod obs;
pub mod parallel;
pub mod simd;
pub mod tensor;
pub mod fft;
pub mod linalg;
pub mod projection;
pub mod optim;
pub mod data;
pub mod runtime;
pub mod coordinator;
pub mod train;
pub mod bench;
pub mod experiments;

pub use tensor::Matrix;
