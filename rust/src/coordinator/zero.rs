//! ZeRO-redundancy update schedule (Rajbhandari et al., 2020), as used by
//! the paper: one worker owns each layer's optimizer state, computes the
//! update locally and broadcasts the result; non-owners allocate no state
//! for that layer. Trion/DCT-AdamW owners broadcast only the low-rank
//! payload (`o_t` + indices) and receivers reconstruct `O_t` from their DCT
//! replica (§2.3).

use crate::optim::{LayerMeta, Optimizer};

use super::collectives::Communicator;

/// Layer→owner assignment (round-robin, the ZeRO default).
#[derive(Clone, Debug)]
pub struct ZeroSchedule {
    pub owners: Vec<usize>,
    pub world: usize,
}

#[derive(Clone, Debug, Default)]
pub struct ZeroStats {
    /// Bytes the owners broadcast after their updates this step.
    pub update_broadcast_bytes: u64,
    /// What a full-parameter broadcast would have cost (the Dion/AdamW
    /// baseline) — the paper's communication-saving headline.
    pub full_broadcast_bytes: u64,
}

impl ZeroSchedule {
    pub fn round_robin(n_layers: usize, world: usize) -> Self {
        ZeroSchedule {
            owners: (0..n_layers).map(|i| i % world.max(1)).collect(),
            world: world.max(1),
        }
    }

    /// Per-worker optimizer state share under this schedule (bytes):
    /// owner-partitioned per-layer state + the replicated shared state.
    pub fn per_worker_state_bytes(&self, opt: &dyn Optimizer) -> u64 {
        let rep = opt.memory_report();
        let per_layer: u64 = rep.per_layer.values().sum();
        let shared: u64 = rep.shared.values().sum();
        per_layer / self.world as u64 + shared
    }

    /// Account the post-update broadcasts of one step: every layer's owner
    /// sends the optimizer-specific payload to the other `W−1` workers.
    pub fn account_step(
        &self,
        metas: &[LayerMeta],
        opt: &dyn Optimizer,
        comm: &mut Communicator,
    ) -> ZeroStats {
        let mut stats = ZeroStats::default();
        for meta in metas {
            let payload = opt.broadcast_bytes(meta);
            let full = (meta.rows * meta.cols * 4) as u64;
            comm.account_broadcast_payload(payload);
            stats.update_broadcast_bytes += payload * (self.world as u64 - 1);
            stats.full_broadcast_bytes += full * (self.world as u64 - 1);
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::collectives::CommModel;
    use crate::optim::{
        build_optimizer, LayerMeta, OptimizerConfig, OptimizerKind, ParamKind,
    };

    fn metas() -> Vec<LayerMeta> {
        (0..6)
            .map(|i| LayerMeta::new(&format!("w{i}"), 64, 64, ParamKind::Linear))
            .collect()
    }

    #[test]
    fn round_robin_balanced() {
        let s = ZeroSchedule::round_robin(10, 4);
        let mut counts = [0usize; 4];
        for &o in &s.owners {
            counts[o] += 1;
        }
        assert!(counts.iter().max().unwrap() - counts.iter().min().unwrap() <= 1);
    }

    #[test]
    fn trion_broadcast_cheaper_than_full() {
        let metas = metas();
        let cfg = OptimizerConfig { rank: 8, ..Default::default() };
        let opt = build_optimizer(&OptimizerKind::Trion, &metas, &cfg);
        let sched = ZeroSchedule::round_robin(metas.len(), 4);
        let mut comm = Communicator::new(4, CommModel::default());
        let stats = sched.account_step(&metas, opt.as_ref(), &mut comm);
        assert!(
            stats.update_broadcast_bytes * 4 < stats.full_broadcast_bytes,
            "low={} full={}",
            stats.update_broadcast_bytes,
            stats.full_broadcast_bytes
        );
    }

    #[test]
    fn adamw_broadcast_equals_full() {
        let metas = metas();
        let cfg = OptimizerConfig::default();
        let opt = build_optimizer(&OptimizerKind::AdamW, &metas, &cfg);
        let sched = ZeroSchedule::round_robin(metas.len(), 4);
        let mut comm = Communicator::new(4, CommModel::default());
        let stats = sched.account_step(&metas, opt.as_ref(), &mut comm);
        assert_eq!(stats.update_broadcast_bytes, stats.full_broadcast_bytes);
    }

    #[test]
    fn zero_sharding_divides_per_layer_state() {
        let metas = metas();
        let cfg = OptimizerConfig { rank: 8, ..Default::default() };
        let opt = build_optimizer(&OptimizerKind::AdamW, &metas, &cfg);
        let s1 = ZeroSchedule::round_robin(metas.len(), 1);
        let s4 = ZeroSchedule::round_robin(metas.len(), 4);
        let b1 = s1.per_worker_state_bytes(opt.as_ref());
        let b4 = s4.per_worker_state_bytes(opt.as_ref());
        assert_eq!(b1, b4 * 4);
    }
}
