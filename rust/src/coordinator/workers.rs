//! Real-thread simulated workers.
//!
//! A [`WorkerSet`] fans per-worker work (`f(worker_id) → T`) out across a
//! [`ThreadPool`] and collects results in worker order — the "8×H100"
//! coordinator's workers actually compute concurrently instead of taking
//! turns on the driver thread. Determinism is the caller's half of the
//! contract and is easy to meet: give each worker its own input shard and
//! RNG substream (as `BatchLoader::worker` already does) and the result
//! vector is identical for any thread count.
//!
//! Scope note: workloads must be `Sync`/`Send`. The PJRT fwd/bwd path is
//! not — the upstream `xla` client is `Rc`-backed (see `runtime/client.rs`)
//! — so `Trainer` runs executables on the driver thread and uses the
//! worker set for the pure-Rust per-worker stages (batch staging) plus the
//! pool-backed ring all-reduce. When `Send` PJRT bindings land, the fwd/bwd
//! closure moves in here unchanged (ROADMAP §Parallel runtime).
//!
//! **Lane retry.** A panic inside one lane's closure no longer takes the
//! whole run down: each lane retries its work up to [`MAX_ATTEMPTS`] times
//! (catching the unwind *inside* the pool closure, so the pool itself never
//! sees it), and only a lane that fails every attempt propagates. Retried
//! work must therefore be idempotent or fail before mutating its state —
//! the trainer's batch staging qualifies (the injected lane fault fires
//! before the loader draws), and `tests/fault_recovery.rs` pins both the
//! recovery and the exhaustion path.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use crate::parallel::{par_for_each_mut, ThreadPool};

/// Attempts per lane before a persistent failure is allowed to propagate.
pub const MAX_ATTEMPTS: usize = 3;

/// Run `attempt()` with bounded retry: the first `MAX_ATTEMPTS - 1`
/// failures are caught and logged, the final attempt runs uncaught so a
/// persistent failure propagates as the panic it is.
fn with_retry(lane: usize, mut attempt: impl FnMut()) {
    for tried in 1..MAX_ATTEMPTS {
        match catch_unwind(AssertUnwindSafe(&mut attempt)) {
            Ok(()) => return,
            Err(cause) => {
                crate::obs::count_worker_retry();
                let msg = cause
                    .downcast_ref::<String>()
                    .map(String::as_str)
                    .or_else(|| cause.downcast_ref::<&str>().copied())
                    .unwrap_or("<non-string panic>");
                eprintln!(
                    "warning: worker lane {lane} failed attempt \
                     {tried}/{MAX_ATTEMPTS} ({msg}) — retrying"
                );
            }
        }
    }
    attempt();
}

/// A fixed-size set of simulated workers executing on real threads.
pub struct WorkerSet {
    pool: Arc<ThreadPool>,
    pub world: usize,
}

impl WorkerSet {
    pub fn new(world: usize, pool: Arc<ThreadPool>) -> Self {
        assert!(world >= 1);
        WorkerSet { world, pool }
    }

    /// Run `f(w)` for every worker `w` concurrently; results come back in
    /// worker order regardless of scheduling. A lane that panics is retried
    /// (bounded, see module docs) before the failure propagates.
    pub fn run<T: Send>(&self, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
        let mut out: Vec<Option<T>> = (0..self.world).map(|_| None).collect();
        par_for_each_mut(&self.pool, &mut out, |w, slot| {
            with_retry(w, || {
                *slot = Some(f(w));
            });
        });
        out.into_iter()
            .map(|o| o.expect("worker produced no result"))
            .collect()
    }

    /// Run `f(w, &mut state[w])` for every worker against its own mutable
    /// state (per-worker loaders, gradient buffers). Same bounded lane
    /// retry as [`WorkerSet::run`] — `f` must be idempotent on its state
    /// or fail before mutating it.
    pub fn run_mut<S: Send>(&self, states: &mut [S], f: impl Fn(usize, &mut S) + Sync) {
        assert_eq!(states.len(), self.world, "WorkerSet state count mismatch");
        par_for_each_mut(&self.pool, states, |w, state| {
            with_retry(w, || f(w, &mut *state));
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::util::Pcg64;

    /// A worker's "fwd/bwd": deterministic per-worker gradient from its own
    /// RNG substream — the shape of work the trainer fans out.
    fn fake_grad(w: usize) -> Matrix {
        let mut rng = Pcg64::new(7, 0x1000 ^ (w as u64));
        let mut g = Matrix::randn(8, 8, 1.0, &mut rng);
        for _ in 0..w {
            g.scale(0.5); // per-worker-dependent compute
        }
        g
    }

    #[test]
    fn results_in_worker_order_any_thread_count() {
        let want: Vec<Matrix> = (0..6).map(fake_grad).collect();
        for threads in [1usize, 2, 8] {
            let ws = WorkerSet::new(6, Arc::new(ThreadPool::new(threads)));
            let got = ws.run(fake_grad);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn run_mut_gives_each_worker_its_own_state() {
        let ws = WorkerSet::new(4, Arc::new(ThreadPool::new(4)));
        let mut counters = vec![0u64; 4];
        ws.run_mut(&mut counters, |w, c| {
            *c = (w as u64 + 1) * 10;
        });
        assert_eq!(counters, vec![10, 20, 30, 40]);
    }

    #[test]
    fn flaky_lane_recovers_via_retry() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let ws = WorkerSet::new(3, Arc::new(ThreadPool::new(2)));
        // lane 1 panics on its first attempt only — the bounded retry must
        // absorb the failure and still return every lane's result in order
        let lane1_calls = AtomicUsize::new(0);
        let got = ws.run(|w| {
            if w == 1 && lane1_calls.fetch_add(1, Ordering::SeqCst) == 0 {
                panic!("injected transient lane failure");
            }
            w * 2
        });
        assert_eq!(got, vec![0, 2, 4]);
        assert_eq!(lane1_calls.load(Ordering::SeqCst), 2);
    }
}
