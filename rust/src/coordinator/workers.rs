//! Real-thread simulated workers.
//!
//! A [`WorkerSet`] fans per-worker work (`f(worker_id) → T`) out across a
//! [`ThreadPool`] and collects results in worker order — the "8×H100"
//! coordinator's workers actually compute concurrently instead of taking
//! turns on the driver thread. Determinism is the caller's half of the
//! contract and is easy to meet: give each worker its own input shard and
//! RNG substream (as `BatchLoader::worker` already does) and the result
//! vector is identical for any thread count.
//!
//! Scope note: workloads must be `Sync`/`Send`. The PJRT fwd/bwd path is
//! not — the upstream `xla` client is `Rc`-backed (see `runtime/client.rs`)
//! — so `Trainer` runs executables on the driver thread and uses the
//! worker set for the pure-Rust per-worker stages (batch staging) plus the
//! pool-backed ring all-reduce. When `Send` PJRT bindings land, the fwd/bwd
//! closure moves in here unchanged (ROADMAP §Parallel runtime).

use std::sync::Arc;

use crate::parallel::{par_for_each_mut, ThreadPool};

/// A fixed-size set of simulated workers executing on real threads.
pub struct WorkerSet {
    pool: Arc<ThreadPool>,
    pub world: usize,
}

impl WorkerSet {
    pub fn new(world: usize, pool: Arc<ThreadPool>) -> Self {
        assert!(world >= 1);
        WorkerSet { world, pool }
    }

    /// Run `f(w)` for every worker `w` concurrently; results come back in
    /// worker order regardless of scheduling.
    pub fn run<T: Send>(&self, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
        let mut out: Vec<Option<T>> = (0..self.world).map(|_| None).collect();
        par_for_each_mut(&self.pool, &mut out, |w, slot| {
            *slot = Some(f(w));
        });
        out.into_iter()
            .map(|o| o.expect("worker produced no result"))
            .collect()
    }

    /// Run `f(w, &mut state[w])` for every worker against its own mutable
    /// state (per-worker loaders, gradient buffers).
    pub fn run_mut<S: Send>(&self, states: &mut [S], f: impl Fn(usize, &mut S) + Sync) {
        assert_eq!(states.len(), self.world, "WorkerSet state count mismatch");
        par_for_each_mut(&self.pool, states, f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::util::Pcg64;

    /// A worker's "fwd/bwd": deterministic per-worker gradient from its own
    /// RNG substream — the shape of work the trainer fans out.
    fn fake_grad(w: usize) -> Matrix {
        let mut rng = Pcg64::new(7, 0x1000 ^ (w as u64));
        let mut g = Matrix::randn(8, 8, 1.0, &mut rng);
        for _ in 0..w {
            g.scale(0.5); // per-worker-dependent compute
        }
        g
    }

    #[test]
    fn results_in_worker_order_any_thread_count() {
        let want: Vec<Matrix> = (0..6).map(fake_grad).collect();
        for threads in [1usize, 2, 8] {
            let ws = WorkerSet::new(6, Arc::new(ThreadPool::new(threads)));
            let got = ws.run(fake_grad);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn run_mut_gives_each_worker_its_own_state() {
        let ws = WorkerSet::new(4, Arc::new(ThreadPool::new(4)));
        let mut counters = vec![0u64; 4];
        ws.run_mut(&mut counters, |w, c| {
            *c = (w as u64 + 1) * 10;
        });
        assert_eq!(counters, vec![10, 20, 30, 40]);
    }
}
