//! Distributed-training coordinator (simulated DDP + ZeRO).
//!
//! The paper runs 8×H100 DDP with the ZeRO-redundancy trick: each layer's
//! optimizer update is computed on one owner GPU and broadcast; Trion
//! broadcasts only the low-rank `o_t` + indices (§2.3). This testbed has
//! one CPU core and an `Rc`-backed PJRT client, so workers are *simulated*:
//! each worker has its own data shard, gradient buffer and RNG stream, and
//! collectives run the real ring/tree algorithms chunk by chunk with exact
//! byte accounting and an α–β time model — the communication *volume* and
//! *schedule* are faithful even though the transport is a memcpy.
//!
//! Since PR 2 the simulation uses real threads where the workload allows:
//! [`WorkerSet`] fans per-worker compute across the process thread pool,
//! and a pool-equipped [`Communicator`] moves each ring step's `W`
//! transfers concurrently — both bit-identical to the sequential schedule
//! (see `parallel::` for the determinism contract). PJRT executables stay
//! on the driver thread (`Rc`-backed upstream client).

pub mod collectives;
pub mod compressed;
pub mod workers;
pub mod zero;

pub use collectives::{CommModel, CommStats, Communicator};
pub use compressed::{DenseSync, SubspaceSync};
pub use workers::WorkerSet;
pub use zero::{ZeroSchedule, ZeroStats};

use crate::optim::Optimizer;
use crate::tensor::Matrix;
use anyhow::Result;

/// Wire format of the subspace-compressed coefficient blocks
/// (`coordinator::compressed`): `f32` ships the r×R blocks as-is, `q8`
/// quantizes each block through the `StateStore` Q8 kernels before the
/// ring moves it (per-block scale rides with the payload; the quantization
/// error folds into the per-worker EF residual). Config key `wire=`, env
/// `FFT_SUBSPACE_WIRE`; default f32. Dense reductions (comm=dense,
/// refresh-boundary and fallback layers) always move f32 — the format
/// only applies to coefficient blocks. Never part of the checkpoint
/// fingerprint.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WireFormat {
    #[default]
    F32,
    Q8,
}

impl WireFormat {
    pub fn parse(s: &str) -> Result<WireFormat> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => Ok(WireFormat::F32),
            "q8" | "int8" => Ok(WireFormat::Q8),
            other => anyhow::bail!(
                "unknown wire format {other:?} (expected f32 | q8)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WireFormat::F32 => "f32",
            WireFormat::Q8 => "q8",
        }
    }

    /// Env resolution (`FFT_SUBSPACE_WIRE`): unset or unrecognized falls
    /// back to the f32 default — the strict surface is the config key
    /// (`wire=`), which goes through [`WireFormat::parse`].
    pub fn from_env() -> WireFormat {
        match std::env::var("FFT_SUBSPACE_WIRE") {
            Ok(v) => WireFormat::parse(&v).unwrap_or(WireFormat::F32),
            Err(_) => WireFormat::F32,
        }
    }
}

/// Which gradient-synchronization path the trainer drives: `dense`
/// all-reduces full C×R gradients (the PR-2 baseline), `subspace` projects
/// each worker's gradient into the layer's current basis first and
/// all-reduces only the r×R coefficients (`coordinator::compressed`).
/// Config key `comm=`, env `FFT_SUBSPACE_COMM`; default dense. Never part
/// of the checkpoint fingerprint.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CommMode {
    #[default]
    Dense,
    Subspace,
}

impl CommMode {
    pub fn parse(s: &str) -> Result<CommMode> {
        match s.to_ascii_lowercase().as_str() {
            "dense" => Ok(CommMode::Dense),
            "subspace" => Ok(CommMode::Subspace),
            other => anyhow::bail!(
                "unknown comm mode {other:?} (expected dense | subspace)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CommMode::Dense => "dense",
            CommMode::Subspace => "subspace",
        }
    }

    /// Env resolution (`FFT_SUBSPACE_COMM`): unset or unrecognized falls
    /// back to the dense default — the strict surface is the config key
    /// (`comm=`), which goes through [`CommMode::parse`].
    pub fn from_env() -> CommMode {
        match std::env::var("FFT_SUBSPACE_COMM") {
            Ok(v) => CommMode::parse(&v).unwrap_or(CommMode::Dense),
            Err(_) => CommMode::Dense,
        }
    }
}

/// The gradient-synchronization abstraction the trainer steps through:
/// given every worker's per-parameter gradients, produce the reduced
/// gradient set the (replicated) optimizer consumes. Implementations own
/// whatever cross-step state the scheme needs (per-worker error-feedback
/// residuals for compressed sync) and expose it for checkpoint v2 — the
/// `sync` section of [`crate::train::TrainState`].
pub trait GradSync {
    /// `CommMode::name()` of the scheme (diagnostics / config echo).
    fn name(&self) -> &'static str;

    /// Reduce `worker_grads[w][pi]` across workers into one gradient per
    /// parameter, delivered into `out` (cleared first — the caller keeps
    /// one reusable vector so steady compressed steps stay allocation-free,
    /// `tests/alloc_steady_state.rs`). May consume (zero-size-replace) the
    /// per-worker buffers; buffers a scheme only stages through are handed
    /// back. All byte movement is accounted on `comm`.
    fn reduce(
        &mut self,
        worker_grads: &mut [Vec<Matrix>],
        opt: &dyn Optimizer,
        comm: &mut Communicator,
        out: &mut Vec<Matrix>,
    );

    /// Called after `opt.step()` consumed the reduced gradients — the
    /// refresh boundary hook where compressed sync accounts the rank-0
    /// basis broadcast and agreement check.
    fn after_step(&mut self, _opt: &dyn Optimizer, _comm: &mut Communicator) {}

    /// Serialize cross-step sync state (EF residuals) for checkpoint v2.
    /// Empty = nothing to persist (dense sync), which keeps dense-mode
    /// checkpoint files byte-identical to pre-subsystem writers.
    fn save_state(&self, _out: &mut Vec<u8>) {}

    /// Twin of [`GradSync::save_state`].
    fn load_state(&mut self, _bytes: &[u8]) -> Result<()> {
        Ok(())
    }

    /// Persistent sync-state bytes (memory accounting).
    fn state_bytes(&self) -> u64 {
        0
    }
}

/// Build the sync scheme for a mode — `world` workers over `n_params`
/// parameters described by `metas`, coefficient blocks moving as `wire`
/// (dense sync ignores the wire format: it never forms coefficient
/// blocks).
pub fn build_grad_sync(
    mode: CommMode,
    wire: WireFormat,
    world: usize,
    metas: &[crate::optim::LayerMeta],
) -> Box<dyn GradSync> {
    match mode {
        CommMode::Dense => Box::new(DenseSync),
        CommMode::Subspace => Box::new(SubspaceSync::new(world, metas, wire)),
    }
}
