//! Distributed-training coordinator (simulated DDP + ZeRO).
//!
//! The paper runs 8×H100 DDP with the ZeRO-redundancy trick: each layer's
//! optimizer update is computed on one owner GPU and broadcast; Trion
//! broadcasts only the low-rank `o_t` + indices (§2.3). This testbed has
//! one CPU core and an `Rc`-backed PJRT client, so workers are *simulated*:
//! each worker has its own data shard, gradient buffer and RNG stream, and
//! collectives run the real ring/tree algorithms chunk by chunk with exact
//! byte accounting and an α–β time model — the communication *volume* and
//! *schedule* are faithful even though the transport is a memcpy.
//!
//! Since PR 2 the simulation uses real threads where the workload allows:
//! [`WorkerSet`] fans per-worker compute across the process thread pool,
//! and a pool-equipped [`Communicator`] moves each ring step's `W`
//! transfers concurrently — both bit-identical to the sequential schedule
//! (see `parallel::` for the determinism contract). PJRT executables stay
//! on the driver thread (`Rc`-backed upstream client).

pub mod collectives;
pub mod workers;
pub mod zero;

pub use collectives::{CommModel, CommStats, Communicator};
pub use workers::WorkerSet;
pub use zero::{ZeroSchedule, ZeroStats};
