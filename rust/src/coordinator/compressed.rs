//! Subspace-compressed gradient synchronization (`comm=subspace`).
//!
//! The paper's optimizer lives in an r-dimensional subspace, but the dense
//! DDP path still ring-all-reduces full C×R gradients every step — the one
//! place the low-rank structure buys nothing. [`SubspaceSync`] closes that
//! gap with three coordinated layers:
//!
//! * **Sender-side compression + error feedback** (the EF-DDP scheme the
//!   projected-gradient convergence analyses assume). On non-refresh steps
//!   (the steady state under `update_interval > 1`) each worker forms
//!   `X_w = G_w + e_w` (its gradient plus its EF residual), projects it
//!   through the layer's *current* basis, and the ring all-reduce moves
//!   only the r×R coefficient matrices — `r/C` of the dense volume per
//!   low-rank layer, byte-exact in
//!   [`CommStats::all_reduce_bytes`](super::CommStats) and the obs
//!   `allreduce_bytes` mirror. The mean coefficients map back through the
//!   basis into the reduced gradient; each worker's unprojected component
//!   `e_w ← X_w − back(project(X_w))` is kept for the next step, so nothing
//!   is silently dropped.
//! * **q8 wire format** ([`WireFormat::Q8`], config `wire=q8`, env
//!   `FFT_SUBSPACE_WIRE`). Each coefficient block is rounded through the
//!   exact `StateStore` Q8 kernels (`q8_quantize_into` /
//!   `q8_dequantize_into`, scale `|x|max/127 + 1e-12`) before the ring
//!   moves it — the wire block is `[scale: f32 LE][r·R q8 payload]`, ~4×
//!   less traffic on top of the `r/C` ratio, accounted per transfer as
//!   `elems·1 + 4` bytes ([`Communicator::all_reduce_mean_wire`]). Because
//!   the EF capture runs on the *dequantized* block, the quantization
//!   error folds into `e_w` alongside the projection error: nothing is
//!   lost, and the residuals stay pinned F32 either way. Dense reductions
//!   (refresh boundaries, fallback layers, `comm=dense`) always move f32.
//! * **Overlapped refresh-boundary reduce.** Refresh steps
//!   (`refresh_pending`) must reduce dense — projecting through the stale
//!   basis would change what the refresh sees — which used to serialize
//!   into the step's p99 latency spike. With a pool-equipped communicator
//!   the dense reductions now pipeline layer-by-layer through
//!   [`ThreadPool::scope`]: layer i's ring transfer runs behind layer
//!   i+1's staging (residual fold + replica take). Layers are disjoint and
//!   each layer's ring schedule is untouched, so the trajectory and the
//!   recorded stats are `to_bits`-identical to the unoverlapped path
//!   (pinned in-module and across lane counts in
//!   `tests/comm_determinism.rs`).
//!
//! **ZeRO-sharded EF state.** The (layer, worker) residual grid is
//! partitioned round-robin over the ring: rank w owns column w — the only
//! column its trajectory ever reads or writes, since every cross-worker
//! interaction flows through the deterministic reduce rather than through
//! stored state. [`GradSync::state_bytes`] therefore reports the owned
//! share (constant in world size — the paper's memory headline survives
//! scale-out), and the v2 `save_state` layout groups shards contiguously
//! by owner so a real deployment persists exactly its own span. Legacy v1
//! (layer-major, fully replicated) blobs still load.
//!
//! After a refresh, [`GradSync::after_step`] accounts the rank-0 tree
//! broadcast of the fresh basis (the `Projection::save_state` wire format)
//! plus a per-worker checksum all-gather for the agreement check.
//!
//! Determinism: the per-worker loop runs in fixed worker order, projections
//! use the sync object's own [`Workspace`], and the coefficient all-reduce
//! is the same bit-identical ring as the dense one — so a fixed
//! `(world, comm, wire)` point is bit-identical across thread counts, SIMD
//! backends and step plans. At `world == 1` the scheme degenerates to the
//! dense passthrough (the all-reduce is a no-op and residuals never
//! activate), making `comm=subspace` `to_bits`-equal to `comm=dense` there
//! for every wire format — the cross-mode equality contract
//! (`tests/comm_determinism.rs`).
//!
//! Allocation: coefficient slabs, EF stores, wire scratch and the workspace
//! pool are sized once (construction / first compressed step); steady-state
//! compressed steps — q8 wire included — reuse them with a fixed take/give
//! sequence and deliver through the caller's reusable `out` vector
//! (`tests/alloc_steady_state.rs`). Only refresh-boundary steps may
//! allocate (the scope boxes its two jobs).

use anyhow::{ensure, Result};

use crate::optim::{LayerMeta, Optimizer, SubspaceCommView};
use crate::parallel::ThreadPool;
use crate::tensor::store::{q8_dequantize_into, q8_quantize_into};
use crate::tensor::{Matrix, StateDtype, StateStore, Workspace};
use crate::util::codec::{self, ByteReader};

use super::{Communicator, GradSync, WireFormat};

/// The PR-2 baseline: ring all-reduce of full C×R gradients, one call per
/// parameter. Stateless — nothing to checkpoint, so dense-mode checkpoint
/// files stay byte-identical to pre-subsystem writers.
pub struct DenseSync;

impl GradSync for DenseSync {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn reduce(
        &mut self,
        worker_grads: &mut [Vec<Matrix>],
        _opt: &dyn Optimizer,
        comm: &mut Communicator,
        out: &mut Vec<Matrix>,
    ) {
        let mut shell = Vec::with_capacity(worker_grads.len());
        dense_reduce(worker_grads, comm, out, &mut shell);
    }
}

/// The dense per-parameter reduction both schemes share (subspace sync
/// falls back to it at `world == 1` and for optimizers without a subspace
/// view).
fn dense_reduce(
    worker_grads: &mut [Vec<Matrix>],
    comm: &mut Communicator,
    out: &mut Vec<Matrix>,
    shell: &mut Vec<Matrix>,
) {
    out.clear();
    let n_params = worker_grads.first().map_or(0, |wg| wg.len());
    for pi in 0..n_params {
        let m = dense_reduce_layer(worker_grads, pi, comm, shell);
        out.push(m);
    }
}

/// Reduce one parameter dense: stage every worker's replica through
/// `shell` (must arrive empty; leaves empty), ring-reduce, hand workers
/// 1.. their now-mean-valued buffers back — the producers overwrite them
/// next step, and returning them keeps the staging free of churn — and
/// return the mean in worker 0's buffer.
fn dense_reduce_layer(
    worker_grads: &mut [Vec<Matrix>],
    pi: usize,
    comm: &mut Communicator,
    shell: &mut Vec<Matrix>,
) -> Matrix {
    debug_assert!(shell.is_empty());
    for wg in worker_grads.iter_mut() {
        shell.push(std::mem::replace(&mut wg[pi], Matrix::zeros(0, 0)));
    }
    comm.all_reduce_mean(shell);
    while shell.len() > 1 {
        let m = shell.pop().unwrap();
        worker_grads[shell.len()][pi] = m;
    }
    shell.pop().unwrap()
}

/// Per-parameter sync state for a low-rank-eligible layer: the per-worker
/// EF residual stores (f32 — the comm-side EF must not perturb the bits the
/// cross-mode contracts pin) and the pre-sized coefficient slabs the ring
/// reduces in place.
struct LayerSlot {
    rr: usize,
    cc: usize,
    transposed: bool,
    /// Per-worker r×R coefficient buffers (ring-reduced in place). Sized on
    /// the first compressed step, once the optimizer's per-layer rank is
    /// known; empty until then.
    coeffs: Vec<Matrix>,
    /// Per-worker EF residual `e_w`, kept in the **oriented** frame.
    /// Column w of the grid is rank w's ZeRO shard (see the module docs).
    resid: Vec<StateStore>,
    /// Whether `resid[w]` holds live state (stores are lazily overwritten
    /// by `store_from`, so a cleared flag is all deactivation needs).
    active: Vec<bool>,
}

/// A dense-path layer staged for the overlapped refresh pipeline: residuals
/// folded, replicas taken, ring transfer not yet run.
struct StagedDense {
    pi: usize,
    replicas: Vec<Matrix>,
}

/// Subspace-compressed sync: see the module docs for the protocol.
pub struct SubspaceSync {
    world: usize,
    wire: WireFormat,
    /// One entry per parameter; `None` for layers that never take the
    /// low-rank path (embed / head / norm).
    slots: Vec<Option<LayerSlot>>,
    /// Layers whose basis the optimizer step just refreshed — recorded by
    /// `reduce`, consumed by `after_step` for the broadcast accounting.
    pending_refresh: Vec<bool>,
    /// Reused basis-serialization buffer for the broadcast accounting.
    basis_blob: Vec<u8>,
    /// Reused dense-path replica staging (sequential path).
    shell: Vec<Matrix>,
    /// Reused q8 wire scratch: the encoded block and its i8 payload.
    wire_buf: Vec<u8>,
    q_scratch: Vec<i8>,
    ws: Workspace,
}

impl SubspaceSync {
    /// Build the per-layer slots from the model metas. EF stores are sized
    /// eagerly (their shape is a pure function of the metas) so checkpoint
    /// save/load works before the first step; coefficient slabs wait for
    /// the optimizer's per-layer rank.
    pub fn new(world: usize, metas: &[LayerMeta], wire: WireFormat) -> Self {
        let slots = metas
            .iter()
            .map(|m| {
                if !m.kind.low_rank_eligible() {
                    return None;
                }
                let (rr, cc) = m.oriented();
                Some(LayerSlot {
                    rr,
                    cc,
                    transposed: m.needs_transpose(),
                    coeffs: Vec::new(),
                    resid: (0..world)
                        .map(|_| StateStore::zeros(StateDtype::F32, rr, cc))
                        .collect(),
                    active: vec![false; world],
                })
            })
            .collect();
        SubspaceSync {
            world,
            wire,
            slots,
            pending_refresh: vec![false; metas.len()],
            basis_blob: Vec::new(),
            shell: Vec::new(),
            wire_buf: Vec::new(),
            q_scratch: Vec::new(),
            ws: Workspace::new(),
        }
    }

    /// Sequential per-layer loop — the steady-state path (allocation-free
    /// after warmup) and the no-pool fallback for refresh steps. Identical
    /// bits and stats to [`SubspaceSync::reduce_overlapped`].
    fn reduce_sequential(
        &mut self,
        worker_grads: &mut [Vec<Matrix>],
        view: &dyn SubspaceCommView,
        comm: &mut Communicator,
        out: &mut Vec<Matrix>,
    ) {
        out.clear();
        for pi in 0..worker_grads[0].len() {
            let rank = view.layer_rank(pi);
            let refresh = self.pending_refresh[pi];
            let slot = self.slots[pi].as_mut().filter(|_| rank.is_some());
            let (Some(slot), Some(r)) = (slot, rank) else {
                // dense-fallback layer: plain dense reduction (no residual
                // can be live — the compressed path never runs here)
                out.push(dense_reduce_layer(worker_grads, pi, comm, &mut self.shell));
                continue;
            };
            if refresh {
                // Refresh boundary: fold each worker's residual into its
                // gradient (deactivating it) and reduce dense, so the
                // refresh is computed from the true mean gradient.
                fold_residuals(slot, pi, worker_grads, &mut self.ws);
                out.push(dense_reduce_layer(worker_grads, pi, comm, &mut self.shell));
                continue;
            }
            stage_compressed(
                slot,
                r,
                pi,
                worker_grads,
                view,
                self.wire,
                &mut self.wire_buf,
                &mut self.q_scratch,
                &mut self.ws,
            );
            ring_coeffs(slot, comm, self.wire);
            out.push(deliver_compressed(slot, pi, worker_grads, view, &mut self.ws));
        }
    }

    /// Refresh-boundary pipeline: layer i's dense ring transfer runs on the
    /// pool behind layer i+1's staging (residual fold + replica take) via
    /// [`ThreadPool::scope`]. The two jobs touch disjoint memory — the ring
    /// owns the already-taken replicas and the communicator, the stage owns
    /// the gradients/slots/workspace — and each layer's ring schedule and
    /// accounting order are exactly the sequential path's, so trajectories
    /// and stats stay `to_bits`-identical. Compressed layers in a mixed
    /// step drain the in-flight transfer first (they need the
    /// communicator) and run inline.
    fn reduce_overlapped(
        &mut self,
        worker_grads: &mut [Vec<Matrix>],
        view: &dyn SubspaceCommView,
        comm: &mut Communicator,
        pool: &ThreadPool,
        out: &mut Vec<Matrix>,
    ) {
        let wire = self.wire;
        let n_params = worker_grads[0].len();
        out.clear();
        out.resize_with(n_params, || Matrix::zeros(0, 0));
        let mut pending: Option<StagedDense> = None;
        for pi in 0..n_params {
            let rank = view.layer_rank(pi);
            let refresh = self.pending_refresh[pi];
            let compressed = !refresh
                && rank.is_some()
                && self.slots[pi].as_ref().is_some();
            if compressed {
                if let Some(prev) = pending.take() {
                    finish_dense(prev, worker_grads, comm, out);
                }
                let slot = self.slots[pi].as_mut().unwrap();
                stage_compressed(
                    slot,
                    rank.unwrap(),
                    pi,
                    worker_grads,
                    view,
                    wire,
                    &mut self.wire_buf,
                    &mut self.q_scratch,
                    &mut self.ws,
                );
                ring_coeffs(slot, comm, wire);
                out[pi] = deliver_compressed(slot, pi, worker_grads, view, &mut self.ws);
                continue;
            }
            match pending.take() {
                None => {
                    pending = Some(stage_dense(
                        &mut self.slots,
                        pi,
                        refresh && rank.is_some(),
                        worker_grads,
                        &mut self.ws,
                    ));
                }
                Some(mut prev) => {
                    let mut cur: Option<StagedDense> = None;
                    {
                        let comm_job = &mut *comm;
                        let replicas = &mut prev.replicas;
                        let slots_job = &mut self.slots;
                        let ws_job = &mut self.ws;
                        let wg_job = &mut *worker_grads;
                        let cur_ref = &mut cur;
                        let fold = refresh && rank.is_some();
                        pool.scope(|s| {
                            s.spawn(move || comm_job.all_reduce_mean(replicas));
                            s.spawn(move || {
                                *cur_ref = Some(stage_dense(
                                    slots_job, pi, fold, wg_job, ws_job,
                                ));
                            });
                        });
                    }
                    deliver_dense(prev, worker_grads, out);
                    pending = cur;
                }
            }
        }
        if let Some(prev) = pending.take() {
            finish_dense(prev, worker_grads, comm, out);
        }
    }
}

/// Fold each worker's live residual into its (de-oriented) gradient and
/// deactivate it — the refresh-boundary lookahead.
fn fold_residuals(
    slot: &mut LayerSlot,
    pi: usize,
    worker_grads: &mut [Vec<Matrix>],
    ws: &mut Workspace,
) {
    for (w, wg) in worker_grads.iter_mut().enumerate() {
        if !slot.active[w] {
            continue;
        }
        let mut e = ws.take(slot.rr, slot.cc);
        slot.resid[w].add_into(&mut e);
        if slot.transposed {
            let mut et = ws.take_uninit(slot.cc, slot.rr);
            e.transpose_into(&mut et);
            wg[pi].axpy(1.0, &et);
            ws.give(et);
        } else {
            wg[pi].axpy(1.0, &e);
        }
        ws.give(e);
        slot.active[w] = false;
    }
}

/// Compressed-step staging: project `X_w = G_w + e_w` per worker, round
/// the coefficient block through the wire format, and capture the new
/// residual `e_w ← X_w − back(wire(project(X_w)))` — projection *and*
/// quantization error in one fold.
#[allow(clippy::too_many_arguments)]
fn stage_compressed(
    slot: &mut LayerSlot,
    r: usize,
    pi: usize,
    worker_grads: &mut [Vec<Matrix>],
    view: &dyn SubspaceCommView,
    wire: WireFormat,
    wire_buf: &mut Vec<u8>,
    q_scratch: &mut Vec<i8>,
    ws: &mut Workspace,
) {
    debug_assert!(r <= slot.cc, "rank exceeds oriented columns");
    if slot.coeffs.is_empty() {
        slot.coeffs = (0..worker_grads.len())
            .map(|_| Matrix::zeros(slot.rr, r))
            .collect();
    }
    for (w, wg) in worker_grads.iter_mut().enumerate() {
        let mut x = ws.take_uninit(slot.rr, slot.cc);
        if slot.transposed {
            wg[pi].transpose_into(&mut x);
        } else {
            x.copy_from(&wg[pi]);
        }
        if slot.active[w] {
            slot.resid[w].add_into(&mut x);
        }
        view.project_into(pi, &x, &mut slot.coeffs[w], ws);
        if wire == WireFormat::Q8 {
            // round the block through the exact bytes the ring will move,
            // so the EF capture below sees what the receivers see
            q8_wire_encode(&slot.coeffs[w], q_scratch, wire_buf);
            q8_wire_decode(wire_buf, q_scratch, &mut slot.coeffs[w]);
        }
        // e_w ← X_w − back(wire-block) — the EF capture idiom
        // (`full.sub_from(x)` is reverse subtraction: full = x − full)
        let mut full = ws.take_uninit(slot.rr, slot.cc);
        view.back_into(pi, &slot.coeffs[w], &mut full, ws);
        full.sub_from(&x);
        slot.resid[w].store_from(&full);
        slot.active[w] = true;
        ws.give(full);
        ws.give(x);
    }
}

/// Ring-reduce the staged coefficient blocks under the wire's byte model.
/// Arithmetic is f32 either way (bit-identity across wire formats of the
/// *schedule*; the q8 values were already rounded at staging).
fn ring_coeffs(slot: &mut LayerSlot, comm: &mut Communicator, wire: WireFormat) {
    match wire {
        WireFormat::F32 => comm.all_reduce_mean(&mut slot.coeffs),
        WireFormat::Q8 => comm.all_reduce_mean_wire(&mut slot.coeffs, 1, 4),
    }
}

/// Map the mean coefficients back through the basis, de-orient, and
/// deliver in worker 0's (consumed) gradient buffer.
fn deliver_compressed(
    slot: &mut LayerSlot,
    pi: usize,
    worker_grads: &mut [Vec<Matrix>],
    view: &dyn SubspaceCommView,
    ws: &mut Workspace,
) -> Matrix {
    let mut out = std::mem::replace(&mut worker_grads[0][pi], Matrix::zeros(0, 0));
    if slot.transposed {
        let mut full = ws.take_uninit(slot.rr, slot.cc);
        view.back_into(pi, &slot.coeffs[0], &mut full, ws);
        full.transpose_into(&mut out);
        ws.give(full);
    } else {
        view.back_into(pi, &slot.coeffs[0], &mut out, ws);
    }
    out
}

/// Overlap-pipeline staging for a dense-path layer: fold residuals (refresh
/// layers), then take every worker's replica. Runs as a scope job — touches
/// only the gradients/slots/workspace, never the communicator.
fn stage_dense(
    slots: &mut [Option<LayerSlot>],
    pi: usize,
    fold: bool,
    worker_grads: &mut [Vec<Matrix>],
    ws: &mut Workspace,
) -> StagedDense {
    if fold {
        if let Some(slot) = slots[pi].as_mut() {
            fold_residuals(slot, pi, worker_grads, ws);
        }
    }
    let replicas = worker_grads
        .iter_mut()
        .map(|wg| std::mem::replace(&mut wg[pi], Matrix::zeros(0, 0)))
        .collect();
    StagedDense { pi, replicas }
}

/// Hand workers 1.. their buffers back and place the mean in `out[pi]`.
fn deliver_dense(
    mut staged: StagedDense,
    worker_grads: &mut [Vec<Matrix>],
    out: &mut [Matrix],
) {
    while staged.replicas.len() > 1 {
        let m = staged.replicas.pop().unwrap();
        worker_grads[staged.replicas.len()][staged.pi] = m;
    }
    out[staged.pi] = staged.replicas.pop().unwrap();
}

/// Drain an in-flight staged dense layer on the calling thread.
fn finish_dense(
    mut staged: StagedDense,
    worker_grads: &mut [Vec<Matrix>],
    comm: &mut Communicator,
    out: &mut [Matrix],
) {
    comm.all_reduce_mean(&mut staged.replicas);
    deliver_dense(staged, worker_grads, out);
}

/// Encode one coefficient block in the q8 wire layout (pinned by
/// `q8_wire_block_layout_is_pinned`): `[scale: f32 LE][rows·cols q8
/// payload, row-major i8]` — no length prefix; receivers know the block
/// shape from the layer plan. Scale and rounding are the exact `StateStore`
/// Q8 arithmetic (`|x|max/127 + 1e-12`, round half away, clamp ±127), so
/// wire blocks are bit-compatible with q8 EF stores.
fn q8_wire_encode(block: &Matrix, q: &mut Vec<i8>, out: &mut Vec<u8>) {
    let scale = block.abs_max() / 127.0 + 1e-12;
    q.resize(block.data.len(), 0);
    q8_quantize_into(q, &block.data, scale);
    out.clear();
    out.extend_from_slice(&scale.to_le_bytes());
    for &v in q.iter() {
        out.push(v as u8);
    }
}

/// Twin of [`q8_wire_encode`]: expand a wire block into `out` (whose shape
/// determines the expected payload length).
fn q8_wire_decode(bytes: &[u8], q: &mut Vec<i8>, out: &mut Matrix) {
    let (head, payload) = bytes.split_at(4);
    let scale = f32::from_le_bytes(head.try_into().unwrap());
    debug_assert_eq!(payload.len(), out.data.len(), "q8 wire payload length");
    q.resize(payload.len(), 0);
    for (d, &b) in q.iter_mut().zip(payload) {
        *d = b as i8;
    }
    q8_dequantize_into(&mut out.data, q, scale);
}

impl GradSync for SubspaceSync {
    fn name(&self) -> &'static str {
        "subspace"
    }

    fn reduce(
        &mut self,
        worker_grads: &mut [Vec<Matrix>],
        opt: &dyn Optimizer,
        comm: &mut Communicator,
        out: &mut Vec<Matrix>,
    ) {
        let world = worker_grads.len();
        assert_eq!(world, self.world, "SubspaceSync built for another world");
        // world == 1: the all-reduce is a no-op and there is nothing to
        // compress — run the exact dense passthrough so `comm=subspace`
        // stays `to_bits`-equal to `comm=dense` (the equality contract).
        // Same for optimizers with no subspace structure to project through.
        let Some(view) = opt.comm_view() else {
            return dense_reduce(worker_grads, comm, out, &mut self.shell);
        };
        if world == 1 {
            return dense_reduce(worker_grads, comm, out, &mut self.shell);
        }

        let n_params = worker_grads[0].len();
        assert_eq!(n_params, self.slots.len(), "SubspaceSync built for another model");
        // classify pass: record the refresh lookahead (consumed by
        // `after_step`) and whether this step pays any dense reduction the
        // overlapped pipeline could hide
        let mut any_refresh = false;
        for pi in 0..n_params {
            let refresh = view.layer_rank(pi).is_some() && view.refresh_pending(pi);
            self.pending_refresh[pi] = refresh;
            any_refresh |= refresh;
        }
        if any_refresh {
            if let Some(pool) = comm.pool().filter(|p| p.threads() > 1) {
                return self.reduce_overlapped(worker_grads, view, comm, &pool, out);
            }
        }
        self.reduce_sequential(worker_grads, view, comm, out);
    }

    fn after_step(&mut self, opt: &dyn Optimizer, comm: &mut Communicator) {
        // The optimizer step just refreshed the flagged layers' bases: in a
        // real deployment rank 0 computed them from the reduced gradient
        // and tree-broadcasts the serialized basis; every worker then
        // all-gathers a 4-byte checksum to verify agreement. The simulated
        // workers share one optimizer, so only the accounting moves here.
        let Some(view) = opt.comm_view() else {
            return;
        };
        let mut any = false;
        for pi in 0..self.pending_refresh.len() {
            if !self.pending_refresh[pi] {
                continue;
            }
            self.pending_refresh[pi] = false;
            any = true;
            self.basis_blob.clear();
            view.save_basis(pi, &mut self.basis_blob);
            comm.account_broadcast_payload(self.basis_blob.len() as u64);
        }
        if any {
            comm.account_all_gather_payload(4);
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        codec::put_str(out, "subspace-sync v2");
        codec::put_u32(out, self.world as u32);
        codec::put_u32(out, self.slots.len() as u32);
        for slot in &self.slots {
            codec::put_u8(out, slot.is_some() as u8);
        }
        // ZeRO shard layout: rank w's owned column of the (layer, worker)
        // grid is one contiguous span, so a real deployment writes (and
        // re-reads on resume) exactly its own section.
        for w in 0..self.world {
            for slot in self.slots.iter().flatten() {
                codec::put_u8(out, slot.active[w] as u8);
                slot.resid[w].save(out);
            }
        }
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(bytes);
        let header = r.take_str()?;
        let sharded = match header.as_str() {
            "subspace-sync v2" => true,
            "subspace-sync v1" => false,
            _ => anyhow::bail!("unknown sync-state header {header:?}"),
        };
        let world = r.take_u32()? as usize;
        ensure!(
            world == self.world,
            "sync state was saved at world={world}, this run is world={}",
            self.world
        );
        let n = r.take_u32()? as usize;
        ensure!(
            n == self.slots.len(),
            "sync state has {n} params, model has {}",
            self.slots.len()
        );
        if sharded {
            for slot in &self.slots {
                let tag = r.take_u8()?;
                ensure!(
                    (tag == 1) == slot.is_some(),
                    "sync-state slot tag mismatch"
                );
            }
            for w in 0..world {
                for slot in self.slots.iter_mut().flatten() {
                    slot.active[w] = r.take_u8()? != 0;
                    slot.resid[w].load_from(&mut r)?;
                }
            }
        } else {
            // legacy v1: layer-major, every worker's shard inline
            for slot in &mut self.slots {
                let tag = r.take_u8()?;
                match slot {
                    None => ensure!(tag == 0, "sync-state slot tag mismatch"),
                    Some(s) => {
                        ensure!(tag == 1, "sync-state slot tag mismatch");
                        for w in 0..world {
                            s.active[w] = r.take_u8()? != 0;
                            s.resid[w].load_from(&mut r)?;
                        }
                    }
                }
            }
        }
        r.finish()
    }

    fn state_bytes(&self) -> u64 {
        // ZeRO-sharded: each rank persists exactly one residual per slot
        // (its own column of the grid), so the per-worker footprint is
        // constant in world size — pinned in `tests/comm_determinism.rs`.
        self.slots
            .iter()
            .flatten()
            .map(|s| s.resid[0].bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{build_grad_sync, CommMode, CommModel};
    use super::*;
    use crate::optim::{
        build_optimizer, OptimizerConfig, OptimizerKind, ParamKind,
    };
    use crate::util::Pcg64;
    use std::sync::Arc;

    fn metas() -> Vec<LayerMeta> {
        vec![
            LayerMeta::new("wq", 24, 16, ParamKind::Linear),
            LayerMeta::new("w_gate", 16, 24, ParamKind::Linear), // wide
            LayerMeta::new("norm", 1, 16, ParamKind::Norm),
        ]
    }

    fn grads_for(world: usize, metas: &[LayerMeta], rng: &mut Pcg64) -> Vec<Vec<Matrix>> {
        (0..world)
            .map(|_| {
                metas
                    .iter()
                    .map(|m| Matrix::randn(m.rows, m.cols, 1.0, rng))
                    .collect()
            })
            .collect()
    }

    fn opt_for(metas: &[LayerMeta]) -> Box<dyn Optimizer> {
        let cfg = OptimizerConfig {
            rank: 4,
            update_interval: 3,
            threads: Some(1),
            ..Default::default()
        };
        build_optimizer(&OptimizerKind::DctAdamW, metas, &cfg)
    }

    #[test]
    fn comm_mode_parse_and_names() {
        assert_eq!(CommMode::parse("dense").unwrap(), CommMode::Dense);
        assert_eq!(CommMode::parse("SUBSPACE").unwrap(), CommMode::Subspace);
        assert!(CommMode::parse("ring").is_err());
        assert_eq!(CommMode::default().name(), "dense");
        assert_eq!(
            build_grad_sync(CommMode::Subspace, WireFormat::F32, 2, &metas()).name(),
            "subspace"
        );
    }

    #[test]
    fn wire_format_parse_and_names() {
        assert_eq!(WireFormat::parse("f32").unwrap(), WireFormat::F32);
        assert_eq!(WireFormat::parse("Q8").unwrap(), WireFormat::Q8);
        assert_eq!(WireFormat::parse("int8").unwrap(), WireFormat::Q8);
        assert!(WireFormat::parse("bf16").is_err());
        assert_eq!(WireFormat::default().name(), "f32");
        assert_eq!(WireFormat::Q8.name(), "q8");
    }

    #[test]
    fn q8_wire_block_layout_is_pinned() {
        let mut m = Matrix::zeros(2, 2);
        m.data.copy_from_slice(&[0.0, 63.5, -127.0, 127.0]);
        let mut q = Vec::new();
        let mut buf = Vec::new();
        q8_wire_encode(&m, &mut q, &mut buf);
        // scale = 127/127 + 1e-12 == 1.0f32 exactly (the addend is far
        // below f32 epsilon at 1.0); payload rounds half away from zero
        assert_eq!(buf, vec![0x00, 0x00, 0x80, 0x3F, 0x00, 64, 0x81, 0x7F]);
        let mut back = Matrix::zeros(2, 2);
        q8_wire_decode(&buf, &mut q, &mut back);
        assert_eq!(back.data, vec![0.0, 64.0, -127.0, 127.0]);
    }

    #[test]
    fn dense_sync_computes_exact_mean() {
        let metas = metas();
        let mut rng = Pcg64::seed(3);
        let world = 3;
        let mut wg = grads_for(world, &metas, &mut rng);
        let mut want = Vec::new();
        for pi in 0..metas.len() {
            let mut m = Matrix::zeros(metas[pi].rows, metas[pi].cols);
            for w in 0..world {
                m.axpy(1.0 / world as f32, &wg[w][pi]);
            }
            want.push(m);
        }
        let opt = opt_for(&metas);
        let mut comm = Communicator::new(world, CommModel::default());
        let mut got = Vec::new();
        DenseSync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!(g.max_abs_diff(w) < 1e-5);
        }
        // staged buffers come back to workers 1.. with the right shapes
        for w in 1..world {
            for pi in 0..metas.len() {
                assert_eq!(wg[w][pi].shape(), (metas[pi].rows, metas[pi].cols));
            }
        }
        assert!(comm.stats.all_reduce_bytes > 0);
    }

    #[test]
    fn world_one_subspace_is_dense_passthrough() {
        let metas = metas();
        for wire in [WireFormat::F32, WireFormat::Q8] {
            let mut rng = Pcg64::seed(4);
            let mut opt_d = opt_for(&metas);
            let mut opt_s = opt_for(&metas);
            let mut dense = DenseSync;
            let mut sub = SubspaceSync::new(1, &metas, wire);
            let mut comm_d = Communicator::new(1, CommModel::default());
            let mut comm_s = Communicator::new(1, CommModel::default());
            let mut params_d: Vec<Matrix> =
                metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
            let mut params_s = params_d.clone();
            let (mut gd, mut gs) = (Vec::new(), Vec::new());
            for step in 0..7 {
                let wg = grads_for(1, &metas, &mut rng);
                let mut wg_d = wg.clone();
                let mut wg_s = wg;
                dense.reduce(&mut wg_d, opt_d.as_ref(), &mut comm_d, &mut gd);
                sub.reduce(&mut wg_s, opt_s.as_ref(), &mut comm_s, &mut gs);
                opt_d.step(&mut params_d, &gd, 1e-2);
                dense.after_step(opt_d.as_ref(), &mut comm_d);
                opt_s.step(&mut params_s, &gs, 1e-2);
                sub.after_step(opt_s.as_ref(), &mut comm_s);
                for (a, b) in params_d.iter().zip(&params_s) {
                    assert_eq!(a, b, "step {step} wire {}", wire.name());
                }
            }
            // world=1 collectives move zero bytes in both modes
            assert_eq!(comm_d.stats.total_bytes(), 0);
            assert_eq!(comm_s.stats.total_bytes(), 0);
        }
    }

    #[test]
    fn compressed_steps_move_rank_ratio_bytes() {
        let metas = metas();
        let world = 4;
        let mut rng = Pcg64::seed(5);
        let mut opt = opt_for(&metas);
        let mut sub = SubspaceSync::new(world, &metas, WireFormat::F32);
        let mut params: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        let mut comm = Communicator::new(world, CommModel::default());
        let mut g = Vec::new();
        // interval 3: steps 1 and 3 refresh (dense), step 4 is compressed
        for _ in 0..3 {
            let mut wg = grads_for(world, &metas, &mut rng);
            sub.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
            opt.step(&mut params, &g, 1e-2);
            sub.after_step(opt.as_ref(), &mut comm);
        }
        let before = comm.stats.all_reduce_bytes;
        let mut wg = grads_for(world, &metas, &mut rng);
        sub.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
        opt.step(&mut params, &g, 1e-2);
        sub.after_step(opt.as_ref(), &mut comm);
        let moved = comm.stats.all_reduce_bytes - before;
        // low-rank layers (oriented 24×16, rank 4) move 24×4 coefficients;
        // the norm layer (1×16) reduces dense. Ring volume ≈ 2·(W−1)·N·4.
        let ring = |n: u64| 2 * (world as u64 - 1) * n * 4;
        let want = 2 * ring(24 * 4) + ring(16);
        // chunk rounding: each of the 2(W−1) ring steps over W chunks can
        // round up by at most one element per worker
        assert!(
            moved.abs_diff(want) <= want / 4 + 64,
            "moved={moved} want≈{want}"
        );
        // and a refresh step accounted a basis broadcast
        assert!(comm.stats.broadcast_bytes > 0);
        assert!(comm.stats.all_gather_bytes > 0);
    }

    #[test]
    fn q8_wire_moves_quarter_bytes() {
        let metas = metas();
        let world = 4;
        let mut bytes = [0u64; 2];
        for (i, wire) in [WireFormat::F32, WireFormat::Q8].into_iter().enumerate() {
            let mut rng = Pcg64::seed(5);
            let mut opt = opt_for(&metas);
            let mut sub = SubspaceSync::new(world, &metas, wire);
            let mut params: Vec<Matrix> =
                metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
            let mut comm = Communicator::new(world, CommModel::default());
            let mut g = Vec::new();
            for _ in 0..3 {
                let mut wg = grads_for(world, &metas, &mut rng);
                sub.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
                opt.step(&mut params, &g, 1e-2);
                sub.after_step(opt.as_ref(), &mut comm);
            }
            let before = comm.stats.all_reduce_bytes;
            let mut wg = grads_for(world, &metas, &mut rng);
            sub.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
            opt.step(&mut params, &g, 1e-2);
            sub.after_step(opt.as_ref(), &mut comm);
            bytes[i] = comm.stats.all_reduce_bytes - before;
        }
        // per compressed block: q8 moves elems·1 + 4 per transfer where f32
        // moved elems·4 — the norm layer reduces dense f32 under both wires
        let w = world as u64;
        let ring_f32 = |n: u64| 2 * (w - 1) * n * 4;
        let ring_q8 = |n: u64| 2 * (w - 1) * n + 2 * (w - 1) * w * 4;
        let want_q8 = 2 * ring_q8(24 * 4) + ring_f32(16);
        assert!(
            bytes[1].abs_diff(want_q8) <= want_q8 / 8 + 64,
            "q8 moved={} want≈{want_q8} (f32 moved={})",
            bytes[1],
            bytes[0]
        );
        // and the compressed fraction shrank ~4×: overall under (f32 −
        // compressed·3/4) with slack for the per-transfer scale overhead
        assert!(bytes[1] < bytes[0] / 2, "q8={} f32={}", bytes[1], bytes[0]);
    }

    #[test]
    fn q8_wire_and_sharding_keep_state_roundtrip_and_convergence() {
        // q8-wire EF must still track the dense trajectory on the smoke
        // quadratic: see tests/comm_determinism.rs for the full version;
        // here we pin that repeated compressed q8 steps keep residuals
        // finite and the save/load round trip bit-exact.
        let metas = metas();
        let world = 3;
        let mut rng = Pcg64::seed(11);
        let mut opt = opt_for(&metas);
        let mut sub = SubspaceSync::new(world, &metas, WireFormat::Q8);
        let mut params: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        let mut comm = Communicator::new(world, CommModel::default());
        let mut g = Vec::new();
        for _ in 0..6 {
            let mut wg = grads_for(world, &metas, &mut rng);
            sub.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
            for m in &g {
                assert!(m.data.iter().all(|v| v.is_finite()));
            }
            opt.step(&mut params, &g, 1e-2);
            sub.after_step(opt.as_ref(), &mut comm);
        }
        let mut blob = Vec::new();
        sub.save_state(&mut blob);
        let mut fresh = SubspaceSync::new(world, &metas, WireFormat::Q8);
        fresh.load_state(&blob).unwrap();
        let mut blob2 = Vec::new();
        fresh.save_state(&mut blob2);
        assert_eq!(blob, blob2);
    }

    #[test]
    fn overlapped_refresh_reduce_is_bit_identical() {
        // Pool-equipped communicator (the overlapped refresh pipeline) vs
        // the sequential path: trajectories and stats must match to the
        // bit for every wire format.
        let metas = metas();
        let world = 3;
        let pool = Arc::new(ThreadPool::new(3));
        for wire in [WireFormat::F32, WireFormat::Q8] {
            let mut rng = Pcg64::seed(8);
            let mut opt_a = opt_for(&metas);
            let mut opt_b = opt_for(&metas);
            let mut sub_a = SubspaceSync::new(world, &metas, wire);
            let mut sub_b = SubspaceSync::new(world, &metas, wire);
            let mut comm_a = Communicator::new(world, CommModel::default());
            let mut comm_b =
                Communicator::with_pool(world, CommModel::default(), pool.clone());
            let mut params_a: Vec<Matrix> =
                metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
            let mut params_b = params_a.clone();
            let (mut ga, mut gb) = (Vec::new(), Vec::new());
            for step in 0..7 {
                let wg = grads_for(world, &metas, &mut rng);
                let mut wa = wg.clone();
                let mut wb = wg;
                sub_a.reduce(&mut wa, opt_a.as_ref(), &mut comm_a, &mut ga);
                sub_b.reduce(&mut wb, opt_b.as_ref(), &mut comm_b, &mut gb);
                for (a, b) in ga.iter().zip(&gb) {
                    assert_eq!(a, b, "reduced grads step {step} wire {}", wire.name());
                }
                opt_a.step(&mut params_a, &ga, 1e-2);
                sub_a.after_step(opt_a.as_ref(), &mut comm_a);
                opt_b.step(&mut params_b, &gb, 1e-2);
                sub_b.after_step(opt_b.as_ref(), &mut comm_b);
                for (a, b) in params_a.iter().zip(&params_b) {
                    assert_eq!(a, b, "params step {step} wire {}", wire.name());
                }
            }
            assert_eq!(comm_a.stats.all_reduce_bytes, comm_b.stats.all_reduce_bytes);
            assert_eq!(comm_a.stats.broadcast_bytes, comm_b.stats.broadcast_bytes);
            assert_eq!(comm_a.stats.hops, comm_b.stats.hops);
            assert_eq!(
                comm_a.stats.modeled_secs.to_bits(),
                comm_b.stats.modeled_secs.to_bits()
            );
        }
    }

    #[test]
    fn sync_state_roundtrips_bit_exact() {
        let metas = metas();
        let world = 2;
        let mut rng = Pcg64::seed(6);
        let mut opt = opt_for(&metas);
        let mut sub = SubspaceSync::new(world, &metas, WireFormat::F32);
        let mut params: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        let mut comm = Communicator::new(world, CommModel::default());
        let mut g = Vec::new();
        for _ in 0..4 {
            let mut wg = grads_for(world, &metas, &mut rng);
            sub.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
            opt.step(&mut params, &g, 1e-2);
            sub.after_step(opt.as_ref(), &mut comm);
        }
        let mut blob = Vec::new();
        sub.save_state(&mut blob);
        assert!(!blob.is_empty());
        let mut fresh = SubspaceSync::new(world, &metas, WireFormat::F32);
        fresh.load_state(&blob).unwrap();
        let mut blob2 = Vec::new();
        fresh.save_state(&mut blob2);
        assert_eq!(blob, blob2);
        assert_eq!(fresh.state_bytes(), sub.state_bytes());
        // wrong world is rejected
        let mut bad = SubspaceSync::new(world + 1, &metas, WireFormat::F32);
        assert!(bad.load_state(&blob).is_err());

        // the retired v1 (layer-major, replicated) layout still loads and
        // lands on the identical state
        let mut v1 = Vec::new();
        codec::put_str(&mut v1, "subspace-sync v1");
        codec::put_u32(&mut v1, sub.world as u32);
        codec::put_u32(&mut v1, sub.slots.len() as u32);
        for slot in &sub.slots {
            match slot {
                None => codec::put_u8(&mut v1, 0),
                Some(s) => {
                    codec::put_u8(&mut v1, 1);
                    for w in 0..sub.world {
                        codec::put_u8(&mut v1, s.active[w] as u8);
                        s.resid[w].save(&mut v1);
                    }
                }
            }
        }
        let mut legacy = SubspaceSync::new(world, &metas, WireFormat::F32);
        legacy.load_state(&v1).unwrap();
        let mut blob3 = Vec::new();
        legacy.save_state(&mut blob3);
        assert_eq!(blob, blob3);
    }

    #[test]
    fn ef_state_bytes_are_zero_sharded() {
        // the per-worker persisted share is constant in world size: one
        // f32 residual per low-rank slot — (24·16 + 16·24) · 4 bytes here
        let metas = metas();
        let want = (24 * 16 + 16 * 24) as u64 * 4;
        for world in [2usize, 4, 8] {
            let sub = SubspaceSync::new(world, &metas, WireFormat::F32);
            assert_eq!(sub.state_bytes(), want, "world={world}");
        }
    }
}
