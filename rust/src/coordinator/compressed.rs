//! Subspace-compressed gradient synchronization (`comm=subspace`).
//!
//! The paper's optimizer lives in an r-dimensional subspace, but the dense
//! DDP path still ring-all-reduces full C×R gradients every step — the one
//! place the low-rank structure buys nothing. [`SubspaceSync`] closes that
//! gap with sender-side compression plus error feedback (the EF-DDP scheme
//! the projected-gradient convergence analyses assume):
//!
//! * **Non-refresh steps** (the steady state under `update_interval > 1`):
//!   each worker forms `X_w = G_w + e_w` (its gradient plus its EF
//!   residual), projects it through the layer's *current* basis, and the
//!   ring all-reduce moves only the r×R coefficient matrices — `r/C` of the
//!   dense volume per low-rank layer, byte-exact in
//!   [`CommStats::all_reduce_bytes`](super::CommStats) and the obs
//!   `allreduce_bytes` mirror. The mean coefficients map back through the
//!   basis into the reduced gradient; each worker's unprojected component
//!   `e_w ← X_w − back(project(X_w))` is kept for the next step, so nothing
//!   is silently dropped.
//! * **Refresh steps** (`refresh_pending`): projecting through the stale
//!   basis would change what the refresh sees, so each worker folds its
//!   residual into its gradient and the step reduces dense. The (single,
//!   replicated) optimizer then computes the refresh from the true reduced
//!   gradient — which is exactly "rank 0 computes, everyone agrees" in the
//!   simulated world — and [`GradSync::after_step`] accounts the tree
//!   broadcast of the fresh basis (the `Projection::save_state` wire
//!   format) plus a per-worker checksum all-gather for the agreement check.
//!
//! Determinism: the per-worker loop runs in fixed worker order on the
//! calling thread, projections use the sync object's own [`Workspace`], and
//! the coefficient all-reduce is the same bit-identical ring as the dense
//! one — so a fixed `(world, comm)` point is bit-identical across thread
//! counts, SIMD backends and step plans. At `world == 1` the scheme
//! degenerates to the dense passthrough (the all-reduce is a no-op and
//! residuals never activate), making `comm=subspace` `to_bits`-equal to
//! `comm=dense` there — the cross-mode equality contract
//! (`tests/comm_determinism.rs`).
//!
//! Allocation: coefficient slabs, EF stores and the workspace pool are
//! sized once (construction / first compressed step); steady-state steps
//! reuse them with a fixed take/give sequence.

use anyhow::{ensure, Result};

use crate::optim::{LayerMeta, Optimizer, SubspaceCommView};
use crate::tensor::{Matrix, StateDtype, StateStore, Workspace};
use crate::util::codec::{self, ByteReader};

use super::{Communicator, GradSync};

/// The PR-2 baseline: ring all-reduce of full C×R gradients, one call per
/// parameter. Stateless — nothing to checkpoint, so dense-mode checkpoint
/// files stay byte-identical to pre-subsystem writers.
pub struct DenseSync;

impl GradSync for DenseSync {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn reduce(
        &mut self,
        worker_grads: &mut [Vec<Matrix>],
        _opt: &dyn Optimizer,
        comm: &mut Communicator,
    ) -> Vec<Matrix> {
        dense_reduce(worker_grads, comm)
    }
}

/// The dense per-parameter reduction both schemes share (subspace sync
/// falls back to it at `world == 1`, for dense-fallback layers and on
/// refresh steps).
fn dense_reduce(
    worker_grads: &mut [Vec<Matrix>],
    comm: &mut Communicator,
) -> Vec<Matrix> {
    let n_params = worker_grads.first().map_or(0, |wg| wg.len());
    let mut reduced = Vec::with_capacity(n_params);
    for pi in 0..n_params {
        let mut replicas: Vec<Matrix> = worker_grads
            .iter_mut()
            .map(|wg| std::mem::replace(&mut wg[pi], Matrix::zeros(0, 0)))
            .collect();
        comm.all_reduce_mean(&mut replicas);
        reduced.push(replicas.swap_remove(0));
    }
    reduced
}

/// Per-parameter sync state for a low-rank-eligible layer: the per-worker
/// EF residual stores (f32 — the comm-side EF must not perturb the bits the
/// cross-mode contracts pin) and the pre-sized coefficient slabs the ring
/// reduces in place.
struct LayerSlot {
    rr: usize,
    cc: usize,
    transposed: bool,
    /// Per-worker r×R coefficient buffers (ring-reduced in place). Sized on
    /// the first compressed step, once the optimizer's per-layer rank is
    /// known; empty until then.
    coeffs: Vec<Matrix>,
    /// Per-worker EF residual `e_w`, kept in the **oriented** frame.
    resid: Vec<StateStore>,
    /// Whether `resid[w]` holds live state (stores are lazily overwritten
    /// by `store_from`, so a cleared flag is all deactivation needs).
    active: Vec<bool>,
}

/// Subspace-compressed sync: see the module docs for the protocol.
pub struct SubspaceSync {
    world: usize,
    /// One entry per parameter; `None` for layers that never take the
    /// low-rank path (embed / head / norm).
    slots: Vec<Option<LayerSlot>>,
    /// Layers whose basis the optimizer step just refreshed — recorded by
    /// `reduce`, consumed by `after_step` for the broadcast accounting.
    pending_refresh: Vec<bool>,
    /// Reused basis-serialization buffer for the broadcast accounting.
    basis_blob: Vec<u8>,
    ws: Workspace,
}

impl SubspaceSync {
    /// Build the per-layer slots from the model metas. EF stores are sized
    /// eagerly (their shape is a pure function of the metas) so checkpoint
    /// save/load works before the first step; coefficient slabs wait for
    /// the optimizer's per-layer rank.
    pub fn new(world: usize, metas: &[LayerMeta]) -> Self {
        let slots = metas
            .iter()
            .map(|m| {
                if !m.kind.low_rank_eligible() {
                    return None;
                }
                let (rr, cc) = m.oriented();
                Some(LayerSlot {
                    rr,
                    cc,
                    transposed: m.needs_transpose(),
                    coeffs: Vec::new(),
                    resid: (0..world)
                        .map(|_| StateStore::zeros(StateDtype::F32, rr, cc))
                        .collect(),
                    active: vec![false; world],
                })
            })
            .collect();
        SubspaceSync {
            world,
            slots,
            pending_refresh: vec![false; metas.len()],
            basis_blob: Vec::new(),
            ws: Workspace::new(),
        }
    }
}

impl GradSync for SubspaceSync {
    fn name(&self) -> &'static str {
        "subspace"
    }

    fn reduce(
        &mut self,
        worker_grads: &mut [Vec<Matrix>],
        opt: &dyn Optimizer,
        comm: &mut Communicator,
    ) -> Vec<Matrix> {
        let world = worker_grads.len();
        assert_eq!(world, self.world, "SubspaceSync built for another world");
        // world == 1: the all-reduce is a no-op and there is nothing to
        // compress — run the exact dense passthrough so `comm=subspace`
        // stays `to_bits`-equal to `comm=dense` (the equality contract).
        // Same for optimizers with no subspace structure to project through.
        let Some(view) = opt.comm_view() else {
            return dense_reduce(worker_grads, comm);
        };
        if world == 1 {
            return dense_reduce(worker_grads, comm);
        }

        let n_params = worker_grads[0].len();
        assert_eq!(n_params, self.slots.len(), "SubspaceSync built for another model");
        let ws = &mut self.ws;
        let mut reduced = Vec::with_capacity(n_params);
        for pi in 0..n_params {
            let rank = view.layer_rank(pi);
            let refresh = rank.is_some() && view.refresh_pending(pi);
            self.pending_refresh[pi] = refresh;
            let slot = self.slots[pi].as_mut().filter(|_| rank.is_some());
            let (Some(slot), Some(r)) = (slot, rank) else {
                // dense-fallback layer: plain dense reduction (no residual
                // can be live — the compressed path never runs here)
                let mut replicas: Vec<Matrix> = worker_grads
                    .iter_mut()
                    .map(|wg| std::mem::replace(&mut wg[pi], Matrix::zeros(0, 0)))
                    .collect();
                comm.all_reduce_mean(&mut replicas);
                reduced.push(replicas.swap_remove(0));
                continue;
            };
            debug_assert!(r <= slot.cc, "rank exceeds oriented columns");

            if refresh {
                // Refresh boundary: fold each worker's residual into its
                // gradient (deactivating it) and reduce dense, so the
                // refresh is computed from the true mean gradient.
                for (w, wg) in worker_grads.iter_mut().enumerate() {
                    if !slot.active[w] {
                        continue;
                    }
                    let mut e = ws.take(slot.rr, slot.cc);
                    slot.resid[w].add_into(&mut e);
                    if slot.transposed {
                        let mut et = ws.take_uninit(slot.cc, slot.rr);
                        e.transpose_into(&mut et);
                        wg[pi].axpy(1.0, &et);
                        ws.give(et);
                    } else {
                        wg[pi].axpy(1.0, &e);
                    }
                    ws.give(e);
                    slot.active[w] = false;
                }
                let mut replicas: Vec<Matrix> = worker_grads
                    .iter_mut()
                    .map(|wg| std::mem::replace(&mut wg[pi], Matrix::zeros(0, 0)))
                    .collect();
                comm.all_reduce_mean(&mut replicas);
                reduced.push(replicas.swap_remove(0));
                continue;
            }

            // Compressed step: project X_w = G_w + e_w per worker, reduce
            // the r×R coefficients, map the mean back through the basis.
            if slot.coeffs.is_empty() {
                slot.coeffs =
                    (0..world).map(|_| Matrix::zeros(slot.rr, r)).collect();
            }
            for (w, wg) in worker_grads.iter_mut().enumerate() {
                let mut x = ws.take_uninit(slot.rr, slot.cc);
                if slot.transposed {
                    wg[pi].transpose_into(&mut x);
                } else {
                    x.copy_from(&wg[pi]);
                }
                if slot.active[w] {
                    slot.resid[w].add_into(&mut x);
                }
                view.project_into(pi, &x, &mut slot.coeffs[w], ws);
                // e_w ← X_w − back(project(X_w)) — the EF capture idiom
                // (`full.sub_from(x)` is reverse subtraction: full = x − full)
                let mut full = ws.take_uninit(slot.rr, slot.cc);
                view.back_into(pi, &slot.coeffs[w], &mut full, ws);
                full.sub_from(&x);
                slot.resid[w].store_from(&full);
                slot.active[w] = true;
                ws.give(full);
                ws.give(x);
            }
            comm.all_reduce_mean(&mut slot.coeffs);
            // every replica holds the mean; deliver back(mean) de-oriented
            // into worker 0's (consumed) gradient buffer
            let mut out =
                std::mem::replace(&mut worker_grads[0][pi], Matrix::zeros(0, 0));
            if slot.transposed {
                let mut full = ws.take_uninit(slot.rr, slot.cc);
                view.back_into(pi, &slot.coeffs[0], &mut full, ws);
                full.transpose_into(&mut out);
                ws.give(full);
            } else {
                view.back_into(pi, &slot.coeffs[0], &mut out, ws);
            }
            reduced.push(out);
        }
        reduced
    }

    fn after_step(&mut self, opt: &dyn Optimizer, comm: &mut Communicator) {
        // The optimizer step just refreshed the flagged layers' bases: in a
        // real deployment rank 0 computed them from the reduced gradient
        // and tree-broadcasts the serialized basis; every worker then
        // all-gathers a 4-byte checksum to verify agreement. The simulated
        // workers share one optimizer, so only the accounting moves here.
        let Some(view) = opt.comm_view() else {
            return;
        };
        let mut any = false;
        for pi in 0..self.pending_refresh.len() {
            if !self.pending_refresh[pi] {
                continue;
            }
            self.pending_refresh[pi] = false;
            any = true;
            self.basis_blob.clear();
            view.save_basis(pi, &mut self.basis_blob);
            comm.account_broadcast_payload(self.basis_blob.len() as u64);
        }
        if any {
            comm.account_all_gather_payload(4);
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        codec::put_str(out, "subspace-sync v1");
        codec::put_u32(out, self.world as u32);
        codec::put_u32(out, self.slots.len() as u32);
        for slot in &self.slots {
            match slot {
                None => codec::put_u8(out, 0),
                Some(s) => {
                    codec::put_u8(out, 1);
                    for w in 0..self.world {
                        codec::put_u8(out, s.active[w] as u8);
                        s.resid[w].save(out);
                    }
                }
            }
        }
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(bytes);
        let header = r.take_str()?;
        ensure!(
            header == "subspace-sync v1",
            "unknown sync-state header {header:?}"
        );
        let world = r.take_u32()? as usize;
        ensure!(
            world == self.world,
            "sync state was saved at world={world}, this run is world={}",
            self.world
        );
        let n = r.take_u32()? as usize;
        ensure!(
            n == self.slots.len(),
            "sync state has {n} params, model has {}",
            self.slots.len()
        );
        for slot in &mut self.slots {
            let tag = r.take_u8()?;
            match slot {
                None => ensure!(tag == 0, "sync-state slot tag mismatch"),
                Some(s) => {
                    ensure!(tag == 1, "sync-state slot tag mismatch");
                    for w in 0..world {
                        s.active[w] = r.take_u8()? != 0;
                        s.resid[w].load_from(&mut r)?;
                    }
                }
            }
        }
        r.finish()
    }

    fn state_bytes(&self) -> u64 {
        self.slots
            .iter()
            .flatten()
            .map(|s| s.resid.iter().map(|st| st.bytes()).sum::<u64>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{build_grad_sync, CommMode, CommModel};
    use super::*;
    use crate::optim::{
        build_optimizer, OptimizerConfig, OptimizerKind, ParamKind,
    };
    use crate::util::Pcg64;

    fn metas() -> Vec<LayerMeta> {
        vec![
            LayerMeta::new("wq", 24, 16, ParamKind::Linear),
            LayerMeta::new("w_gate", 16, 24, ParamKind::Linear), // wide
            LayerMeta::new("norm", 1, 16, ParamKind::Norm),
        ]
    }

    fn grads_for(world: usize, metas: &[LayerMeta], rng: &mut Pcg64) -> Vec<Vec<Matrix>> {
        (0..world)
            .map(|_| {
                metas
                    .iter()
                    .map(|m| Matrix::randn(m.rows, m.cols, 1.0, rng))
                    .collect()
            })
            .collect()
    }

    fn opt_for(metas: &[LayerMeta]) -> Box<dyn Optimizer> {
        let cfg = OptimizerConfig {
            rank: 4,
            update_interval: 3,
            threads: Some(1),
            ..Default::default()
        };
        build_optimizer(&OptimizerKind::DctAdamW, metas, &cfg)
    }

    #[test]
    fn comm_mode_parse_and_names() {
        assert_eq!(CommMode::parse("dense").unwrap(), CommMode::Dense);
        assert_eq!(CommMode::parse("SUBSPACE").unwrap(), CommMode::Subspace);
        assert!(CommMode::parse("ring").is_err());
        assert_eq!(CommMode::default().name(), "dense");
        assert_eq!(
            build_grad_sync(CommMode::Subspace, 2, &metas()).name(),
            "subspace"
        );
    }

    #[test]
    fn dense_sync_computes_exact_mean() {
        let metas = metas();
        let mut rng = Pcg64::seed(3);
        let world = 3;
        let mut wg = grads_for(world, &metas, &mut rng);
        let mut want = Vec::new();
        for pi in 0..metas.len() {
            let mut m = Matrix::zeros(metas[pi].rows, metas[pi].cols);
            for w in 0..world {
                m.axpy(1.0 / world as f32, &wg[w][pi]);
            }
            want.push(m);
        }
        let opt = opt_for(&metas);
        let mut comm = Communicator::new(world, CommModel::default());
        let got = DenseSync.reduce(&mut wg, opt.as_ref(), &mut comm);
        for (g, w) in got.iter().zip(&want) {
            assert!(g.max_abs_diff(w) < 1e-5);
        }
        assert!(comm.stats.all_reduce_bytes > 0);
    }

    #[test]
    fn world_one_subspace_is_dense_passthrough() {
        let metas = metas();
        let mut rng = Pcg64::seed(4);
        let mut opt_d = opt_for(&metas);
        let mut opt_s = opt_for(&metas);
        let mut dense = DenseSync;
        let mut sub = SubspaceSync::new(1, &metas);
        let mut comm_d = Communicator::new(1, CommModel::default());
        let mut comm_s = Communicator::new(1, CommModel::default());
        let mut params_d: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        let mut params_s = params_d.clone();
        for step in 0..7 {
            let wg = grads_for(1, &metas, &mut rng);
            let mut wg_d = wg.clone();
            let mut wg_s = wg;
            let gd = dense.reduce(&mut wg_d, opt_d.as_ref(), &mut comm_d);
            let gs = sub.reduce(&mut wg_s, opt_s.as_ref(), &mut comm_s);
            opt_d.step(&mut params_d, &gd, 1e-2);
            dense.after_step(opt_d.as_ref(), &mut comm_d);
            opt_s.step(&mut params_s, &gs, 1e-2);
            sub.after_step(opt_s.as_ref(), &mut comm_s);
            for (a, b) in params_d.iter().zip(&params_s) {
                assert_eq!(a, b, "step {step}");
            }
        }
        // world=1 collectives move zero bytes in both modes
        assert_eq!(comm_d.stats.total_bytes(), 0);
        assert_eq!(comm_s.stats.total_bytes(), 0);
    }

    #[test]
    fn compressed_steps_move_rank_ratio_bytes() {
        let metas = metas();
        let world = 4;
        let mut rng = Pcg64::seed(5);
        let mut opt = opt_for(&metas);
        let mut sub = SubspaceSync::new(world, &metas);
        let mut params: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        let mut comm = Communicator::new(world, CommModel::default());
        // interval 3: steps 1 and 3 refresh (dense), step 4 is compressed
        for _ in 0..3 {
            let mut wg = grads_for(world, &metas, &mut rng);
            let g = sub.reduce(&mut wg, opt.as_ref(), &mut comm);
            opt.step(&mut params, &g, 1e-2);
            sub.after_step(opt.as_ref(), &mut comm);
        }
        let before = comm.stats.all_reduce_bytes;
        let mut wg = grads_for(world, &metas, &mut rng);
        let g = sub.reduce(&mut wg, opt.as_ref(), &mut comm);
        opt.step(&mut params, &g, 1e-2);
        sub.after_step(opt.as_ref(), &mut comm);
        let moved = comm.stats.all_reduce_bytes - before;
        // low-rank layers (oriented 24×16, rank 4) move 24×4 coefficients;
        // the norm layer (1×16) reduces dense. Ring volume ≈ 2·(W−1)·N·4.
        let ring = |n: u64| 2 * (world as u64 - 1) * n * 4;
        let want = 2 * ring(24 * 4) + ring(16);
        // chunk rounding: each of the 2(W−1) ring steps over W chunks can
        // round up by at most one element per worker
        assert!(
            moved.abs_diff(want) <= want / 4 + 64,
            "moved={moved} want≈{want}"
        );
        // and a refresh step accounted a basis broadcast
        assert!(comm.stats.broadcast_bytes > 0);
        assert!(comm.stats.all_gather_bytes > 0);
    }

    #[test]
    fn sync_state_roundtrips_bit_exact() {
        let metas = metas();
        let world = 2;
        let mut rng = Pcg64::seed(6);
        let mut opt = opt_for(&metas);
        let mut sub = SubspaceSync::new(world, &metas);
        let mut params: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        let mut comm = Communicator::new(world, CommModel::default());
        for _ in 0..4 {
            let mut wg = grads_for(world, &metas, &mut rng);
            let g = sub.reduce(&mut wg, opt.as_ref(), &mut comm);
            opt.step(&mut params, &g, 1e-2);
            sub.after_step(opt.as_ref(), &mut comm);
        }
        let mut blob = Vec::new();
        sub.save_state(&mut blob);
        assert!(!blob.is_empty());
        let mut fresh = SubspaceSync::new(world, &metas);
        fresh.load_state(&blob).unwrap();
        let mut blob2 = Vec::new();
        fresh.save_state(&mut blob2);
        assert_eq!(blob, blob2);
        assert_eq!(fresh.state_bytes(), sub.state_bytes());
        // wrong world is rejected
        let mut bad = SubspaceSync::new(world + 1, &metas);
        assert!(bad.load_state(&blob).is_err());
    }
}
