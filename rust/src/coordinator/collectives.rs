//! Collectives over simulated workers with exact byte accounting.
//!
//! Ring all-reduce (reduce-scatter + all-gather), tree broadcast and ring
//! all-gather are implemented chunk-for-chunk as on a real interconnect;
//! [`CommStats`] records the bytes each primitive moved and the α–β time
//! estimate (`t = hops·α + bytes/β`), which the experiment harness uses to
//! model the paper's 8×H100 NVLink numbers.

use crate::tensor::Matrix;

/// α–β interconnect model. Defaults approximate intra-node NVLink
/// (α = 5 µs/hop, β = 200 GB/s effective per direction).
#[derive(Clone, Copy, Debug)]
pub struct CommModel {
    pub alpha_us: f64,
    pub beta_gbps: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        CommModel { alpha_us: 5.0, beta_gbps: 200.0 }
    }
}

impl CommModel {
    pub fn time_secs(&self, hops: u64, bytes: u64) -> f64 {
        hops as f64 * self.alpha_us * 1e-6
            + bytes as f64 / (self.beta_gbps * 1e9)
    }
}

/// Accumulated communication statistics.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    pub all_reduce_bytes: u64,
    pub broadcast_bytes: u64,
    pub all_gather_bytes: u64,
    pub hops: u64,
    pub modeled_secs: f64,
    pub calls: u64,
}

impl CommStats {
    pub fn total_bytes(&self) -> u64 {
        self.all_reduce_bytes + self.broadcast_bytes + self.all_gather_bytes
    }
}

/// A simulated communicator over `world` workers.
pub struct Communicator {
    pub world: usize,
    model: CommModel,
    pub stats: CommStats,
}

impl Communicator {
    pub fn new(world: usize, model: CommModel) -> Self {
        assert!(world >= 1);
        Communicator { world, model, stats: CommStats::default() }
    }

    /// Ring all-reduce (average) over per-worker gradient replicas.
    /// `buffers[w]` is worker w's copy; on return all copies hold the mean.
    ///
    /// Implements reduce-scatter + all-gather over `world` chunks: each
    /// phase moves `(W−1)/W · N` elements per worker — the standard
    /// `2·(W−1)/W·N` total that the stats record.
    pub fn all_reduce_mean(&mut self, buffers: &mut [Matrix]) {
        let w = buffers.len();
        assert_eq!(w, self.world);
        if w == 1 {
            self.stats.calls += 1;
            return;
        }
        let n = buffers[0].data.len();
        for b in buffers.iter() {
            assert_eq!(b.data.len(), n, "all_reduce shape mismatch");
        }
        let chunk = n.div_ceil(w);
        let bounds: Vec<(usize, usize)> = (0..w)
            .map(|c| (c * chunk, ((c + 1) * chunk).min(n)))
            .collect();

        // Phase 1: reduce-scatter. Step s: worker i sends chunk (i−s) to
        // worker i+1, which accumulates. After W−1 steps worker i owns the
        // fully-reduced chunk (i+1 mod W).
        for s in 0..w - 1 {
            for i in 0..w {
                let src = i;
                let dst = (i + 1) % w;
                let c = (i + w - s) % w;
                let (lo, hi) = bounds[c];
                if lo >= hi {
                    continue;
                }
                // move src's partial chunk into dst's accumulator
                let (a, b) = if src < dst {
                    let (l, r) = buffers.split_at_mut(dst);
                    (&l[src], &mut r[0])
                } else {
                    let (l, r) = buffers.split_at_mut(src);
                    (&r[0], &mut l[dst])
                };
                for k in lo..hi {
                    b.data[k] += a.data[k];
                }
                self.account_ar((hi - lo) as u64 * 4);
            }
        }
        // Scale owned chunks to the mean and phase 2: all-gather them.
        let inv = 1.0 / w as f32;
        for i in 0..w {
            let c = (i + 1) % w;
            let (lo, hi) = bounds[c];
            for k in lo..hi {
                buffers[i].data[k] *= inv;
            }
        }
        for s in 0..w - 1 {
            for i in 0..w {
                let src = i;
                let dst = (i + 1) % w;
                let c = (i + 1 + w - s) % w;
                let (lo, hi) = bounds[c];
                if lo >= hi {
                    continue;
                }
                let (a, b) = if src < dst {
                    let (l, r) = buffers.split_at_mut(dst);
                    (&l[src], &mut r[0])
                } else {
                    let (l, r) = buffers.split_at_mut(src);
                    (&r[0], &mut l[dst])
                };
                b.data[lo..hi].copy_from_slice(&a.data[lo..hi]);
                self.account_ar((hi - lo) as u64 * 4);
            }
        }
        self.stats.calls += 1;
    }

    /// Broadcast `src`'s buffer to all workers (binomial tree: log₂W rounds).
    pub fn broadcast(&mut self, buffers: &mut [Matrix], src: usize) {
        let w = buffers.len();
        assert!(src < w);
        let bytes = (buffers[src].data.len() * 4) as u64;
        // tree: in round k, 2^k holders each send to one new worker
        let mut holders = 1u64;
        let mut rounds = 0u64;
        while holders < w as u64 {
            let sending = holders.min(w as u64 - holders);
            self.stats.broadcast_bytes += bytes * sending;
            holders += sending;
            rounds += 1;
        }
        self.stats.hops += rounds;
        self.stats.modeled_secs += self.model.time_secs(rounds, bytes * rounds);
        let src_data = buffers[src].data.clone();
        for (i, b) in buffers.iter_mut().enumerate() {
            if i != src {
                b.data.copy_from_slice(&src_data);
            }
        }
        self.stats.calls += 1;
    }

    /// Account a broadcast that the caller applied itself (e.g. ZeRO sends
    /// low-rank `o_t` and the receivers reconstruct locally — the payload
    /// is smaller than the parameter, so the caller reports bytes).
    pub fn account_broadcast_payload(&mut self, payload_bytes: u64) {
        let w = self.world as u64;
        if w > 1 {
            let rounds = (w as f64).log2().ceil() as u64;
            self.stats.broadcast_bytes += payload_bytes * (w - 1);
            self.stats.hops += rounds;
            self.stats.modeled_secs +=
                self.model.time_secs(rounds, payload_bytes * rounds);
        }
        self.stats.calls += 1;
    }

    fn account_ar(&mut self, bytes: u64) {
        self.stats.all_reduce_bytes += bytes;
        self.stats.hops += 1;
        self.stats.modeled_secs += self.model.time_secs(1, bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg64};

    #[test]
    fn prop_all_reduce_computes_exact_mean() {
        proptest::check("ring-allreduce==mean", 10, |rng| {
            let w = proptest::size(rng, 1, 8);
            let n = proptest::size(rng, 1, 300);
            let bufs: Vec<Matrix> =
                (0..w).map(|_| Matrix::randn(1, n, 1.0, rng)).collect();
            let mut want = Matrix::zeros(1, n);
            for b in &bufs {
                want.axpy(1.0 / w as f32, b);
            }
            let mut got = bufs;
            let mut comm = Communicator::new(w, CommModel::default());
            comm.all_reduce_mean(&mut got);
            for b in &got {
                assert!(
                    b.max_abs_diff(&want) < 1e-5,
                    "w={w} n={n} diff={}",
                    b.max_abs_diff(&want)
                );
            }
        });
    }

    #[test]
    fn all_reduce_volume_matches_ring_formula() {
        let w = 4;
        let n = 1000usize;
        let mut rng = Pcg64::seed(0);
        let mut bufs: Vec<Matrix> =
            (0..w).map(|_| Matrix::randn(1, n, 1.0, &mut rng)).collect();
        let mut comm = Communicator::new(w, CommModel::default());
        comm.all_reduce_mean(&mut bufs);
        // 2·(W−1)·(N/W) per worker · W workers · 4 bytes ≈ 2·(W−1)·N·4
        let want = 2 * (w as u64 - 1) * n as u64 * 4;
        let got = comm.stats.all_reduce_bytes;
        let tol = want / 10; // chunk rounding
        assert!(got.abs_diff(want) <= tol, "got={got} want≈{want}");
    }

    #[test]
    fn broadcast_replicates_and_accounts() {
        let mut rng = Pcg64::seed(1);
        let src = Matrix::randn(4, 4, 1.0, &mut rng);
        let mut bufs = vec![
            Matrix::zeros(4, 4),
            src.clone(),
            Matrix::zeros(4, 4),
            Matrix::zeros(4, 4),
        ];
        let mut comm = Communicator::new(4, CommModel::default());
        comm.broadcast(&mut bufs, 1);
        for b in &bufs {
            assert_eq!(b, &src);
        }
        // 3 receivers × 64 bytes
        assert_eq!(comm.stats.broadcast_bytes, 3 * 64);
    }

    #[test]
    fn single_worker_is_free() {
        let mut rng = Pcg64::seed(2);
        let mut bufs = vec![Matrix::randn(2, 2, 1.0, &mut rng)];
        let before = bufs[0].clone();
        let mut comm = Communicator::new(1, CommModel::default());
        comm.all_reduce_mean(&mut bufs);
        assert_eq!(bufs[0], before);
        assert_eq!(comm.stats.total_bytes(), 0);
    }

    #[test]
    fn comm_model_time_monotone_in_bytes() {
        let m = CommModel::default();
        assert!(m.time_secs(1, 1000) < m.time_secs(1, 10_000_000));
        assert!(m.time_secs(1, 0) > 0.0); // latency floor
    }
}
