//! Collectives over simulated workers with exact byte accounting.
//!
//! Ring all-reduce (reduce-scatter + all-gather), tree broadcast and ring
//! all-gather are implemented chunk-for-chunk as on a real interconnect;
//! [`CommStats`] records the bytes each primitive moved and the α–β time
//! estimate (`t = hops·α + bytes/β`), which the experiment harness uses to
//! model the paper's 8×H100 NVLink numbers.
//!
//! With a [`ThreadPool`] attached ([`Communicator::with_pool`]), each ring
//! step's `W` transfers execute on real threads — exactly as the `W`
//! links of a physical ring move simultaneously. Within one step the
//! transfers touch disjoint memory (destinations are distinct, and a
//! buffer's read chunk `(i−s) mod W` never equals its written chunk
//! `(i−1−s) mod W` for `W > 1`), and the per-element accumulation order
//! across steps is fixed by the ring schedule, so the parallel path is
//! **bit-identical** to sequential and the recorded stats are equal to the
//! byte. Byte/time accounting always runs on the calling thread in ring
//! order.

use std::sync::Arc;

use crate::parallel::{SendPtr, ThreadPool};
use crate::tensor::Matrix;

/// α–β interconnect model. Defaults approximate intra-node NVLink
/// (α = 5 µs/hop, β = 200 GB/s effective per direction).
#[derive(Clone, Copy, Debug)]
pub struct CommModel {
    pub alpha_us: f64,
    pub beta_gbps: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        CommModel { alpha_us: 5.0, beta_gbps: 200.0 }
    }
}

impl CommModel {
    pub fn time_secs(&self, hops: u64, bytes: u64) -> f64 {
        hops as f64 * self.alpha_us * 1e-6
            + bytes as f64 / (self.beta_gbps * 1e9)
    }
}

/// Accumulated communication statistics.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    pub all_reduce_bytes: u64,
    pub broadcast_bytes: u64,
    pub all_gather_bytes: u64,
    pub hops: u64,
    pub modeled_secs: f64,
    pub calls: u64,
}

impl CommStats {
    pub fn total_bytes(&self) -> u64 {
        self.all_reduce_bytes + self.broadcast_bytes + self.all_gather_bytes
    }
}

/// A simulated communicator over `world` workers.
pub struct Communicator {
    pub world: usize,
    model: CommModel,
    pub stats: CommStats,
    /// When present, ring-step transfers run on real threads.
    pool: Option<Arc<ThreadPool>>,
    /// Reused per-call ring scratch (chunk bounds + buffer pointers) —
    /// sized on the first all-reduce so steady compressed steps stay
    /// inside the counting-allocator proof (`tests/alloc_steady_state.rs`).
    bounds_scratch: Vec<(usize, usize)>,
    ptr_scratch: Vec<SendPtr<f32>>,
}

impl Communicator {
    pub fn new(world: usize, model: CommModel) -> Self {
        assert!(world >= 1);
        Communicator {
            world,
            model,
            stats: CommStats::default(),
            pool: None,
            bounds_scratch: Vec::new(),
            ptr_scratch: Vec::new(),
        }
    }

    /// Communicator whose ring transfers execute on `pool`'s threads
    /// (bit-identical to [`Communicator::new`], including stats).
    pub fn with_pool(world: usize, model: CommModel, pool: Arc<ThreadPool>) -> Self {
        let mut c = Communicator::new(world, model);
        c.pool = Some(pool);
        c
    }

    /// The attached transfer pool, if any — the subspace sync layer borrows
    /// it to overlap a refresh-boundary ring transfer with the next layer's
    /// staging work (`coordinator::compressed`).
    pub fn pool(&self) -> Option<Arc<ThreadPool>> {
        self.pool.clone()
    }

    /// Ring all-reduce (average) over per-worker gradient replicas.
    /// `buffers[w]` is worker w's copy; on return all copies hold the mean.
    ///
    /// Implements reduce-scatter + all-gather over `world` chunks: each
    /// phase moves `(W−1)/W · N` elements per worker — the standard
    /// `2·(W−1)/W·N` total that the stats record.
    pub fn all_reduce_mean(&mut self, buffers: &mut [Matrix]) {
        self.all_reduce_mean_wire(buffers, 4, 0);
    }

    /// [`Communicator::all_reduce_mean`] with an explicit wire model: each
    /// per-hop transfer of `k` elements is accounted as
    /// `k·bytes_per_elem + block_overhead` bytes. Dense f32 traffic is
    /// `(4, 0)` — byte-identical to the historical accounting; the q8
    /// coefficient wire is `(1, 4)` (one byte per element plus the f32
    /// scale riding with every block). The *arithmetic* is always f32 and
    /// bit-identical across wire models — callers quantize the payload
    /// before handing it to the ring, so only the byte/time model changes
    /// here.
    pub fn all_reduce_mean_wire(
        &mut self,
        buffers: &mut [Matrix],
        bytes_per_elem: u64,
        block_overhead: u64,
    ) {
        let w = buffers.len();
        assert_eq!(w, self.world);
        if w == 1 {
            self.stats.calls += 1;
            return;
        }
        let n = buffers[0].data.len();
        for b in buffers.iter() {
            assert_eq!(b.data.len(), n, "all_reduce shape mismatch");
        }
        let chunk = n.div_ceil(w);
        // move the scratch out so the borrow checker sees the accounting
        // calls below as disjoint from it (capacity survives the round trip)
        let mut bounds = std::mem::take(&mut self.bounds_scratch);
        let mut ptrs = std::mem::take(&mut self.ptr_scratch);
        bounds.clear();
        bounds.extend((0..w).map(|c| (c * chunk, ((c + 1) * chunk).min(n))));
        ptrs.clear();
        ptrs.extend(buffers.iter_mut().map(|b| SendPtr(b.data.as_mut_ptr())));

        // Phase 1: reduce-scatter. Step s: worker i sends chunk (i−s) to
        // worker i+1, which accumulates. After W−1 steps worker i owns the
        // fully-reduced chunk (i+1 mod W).
        for s in 0..w - 1 {
            ring_step(self.pool.as_deref(), &ptrs, &bounds, w, s, true);
            for i in 0..w {
                let (lo, hi) = bounds[(i + w - s) % w];
                if lo < hi {
                    self.account_ar((hi - lo) as u64 * bytes_per_elem + block_overhead);
                }
            }
        }
        // Scale owned chunks to the mean and phase 2: all-gather them. The
        // scaling goes through `ptrs` too: re-borrowing `buffers` mutably
        // here would invalidate the raw pointers' provenance (Stacked
        // Borrows) before the phase-2 ring steps dereference them.
        let inv = 1.0 / w as f32;
        for (i, p) in ptrs.iter().enumerate() {
            let (lo, hi) = bounds[(i + 1) % w];
            if lo < hi {
                // SAFETY: single-threaded here; `ptrs` covers live buffers.
                let owned = unsafe { std::slice::from_raw_parts_mut(p.0.add(lo), hi - lo) };
                for v in owned {
                    *v *= inv;
                }
            }
        }
        for s in 0..w - 1 {
            ring_step(self.pool.as_deref(), &ptrs, &bounds, w, s, false);
            for i in 0..w {
                let (lo, hi) = bounds[(i + 1 + w - s) % w];
                if lo < hi {
                    self.account_ar((hi - lo) as u64 * bytes_per_elem + block_overhead);
                }
            }
        }
        self.bounds_scratch = bounds;
        self.ptr_scratch = ptrs;
        self.stats.calls += 1;
    }

    /// Broadcast `src`'s buffer to all workers (binomial tree: log₂W rounds).
    pub fn broadcast(&mut self, buffers: &mut [Matrix], src: usize) {
        let w = buffers.len();
        assert!(src < w);
        let bytes = (buffers[src].data.len() * 4) as u64;
        // tree: in round k, 2^k holders each send to one new worker
        let mut holders = 1u64;
        let mut rounds = 0u64;
        let mut moved = 0u64;
        while holders < w as u64 {
            let sending = holders.min(w as u64 - holders);
            moved += bytes * sending;
            holders += sending;
            rounds += 1;
        }
        self.stats.broadcast_bytes += moved;
        crate::obs::count_broadcast_bytes(moved);
        self.stats.hops += rounds;
        self.stats.modeled_secs += self.model.time_secs(rounds, bytes * rounds);
        let src_data = buffers[src].data.clone();
        for (i, b) in buffers.iter_mut().enumerate() {
            if i != src {
                b.data.copy_from_slice(&src_data);
            }
        }
        self.stats.calls += 1;
    }

    /// Account a broadcast that the caller applied itself (e.g. ZeRO sends
    /// low-rank `o_t` and the receivers reconstruct locally — the payload
    /// is smaller than the parameter, so the caller reports bytes).
    pub fn account_broadcast_payload(&mut self, payload_bytes: u64) {
        let w = self.world as u64;
        if w > 1 {
            let rounds = (w as f64).log2().ceil() as u64;
            self.stats.broadcast_bytes += payload_bytes * (w - 1);
            crate::obs::count_broadcast_bytes(payload_bytes * (w - 1));
            self.stats.hops += rounds;
            self.stats.modeled_secs +=
                self.model.time_secs(rounds, payload_bytes * rounds);
        }
        self.stats.calls += 1;
    }

    /// Account a ring all-gather the caller applied itself (e.g. the
    /// subspace sync layer gathering per-worker basis checksums to verify
    /// agreement after a refresh broadcast). Each of the `W` workers
    /// contributes `per_rank_bytes`; the ring moves every contribution
    /// through `W−1` links, so the wire total is `per_rank · W · (W−1)`.
    pub fn account_all_gather_payload(&mut self, per_rank_bytes: u64) {
        let w = self.world as u64;
        if w > 1 {
            let moved = per_rank_bytes * w * (w - 1);
            self.stats.all_gather_bytes += moved;
            crate::obs::count_all_gather_bytes(moved);
            self.stats.hops += w - 1;
            self.stats.modeled_secs += self.model.time_secs(w - 1, moved);
        }
        self.stats.calls += 1;
    }

    /// Byte/time accounting runs on the calling thread in ring order and
    /// depends only on data sizes — so the `obs::allreduce_bytes` mirror
    /// is identical for any pool size (pinned in
    /// `tests/obs_determinism.rs`).
    fn account_ar(&mut self, bytes: u64) {
        self.stats.all_reduce_bytes += bytes;
        self.stats.hops += 1;
        self.stats.modeled_secs += self.model.time_secs(1, bytes);
        crate::obs::count_allreduce_bytes(bytes);
    }
}

/// One ring step: worker `i` moves chunk `c(i)` into worker `i+1` — an
/// accumulate during reduce-scatter (`reduce`), a copy during all-gather.
/// The `w` transfers run concurrently when a pool is attached.
///
/// Disjointness (the SAFETY argument for the raw slices): within a step the
/// destinations `i+1 mod w` are all distinct, and buffer `i` is *read* at
/// chunk `c(i)` while being *written* (by transfer `i−1`) at chunk
/// `c(i−1)`; `c` is injective in `i` for both phases, so the two ranges
/// never overlap for `w > 1`. Every transfer therefore touches memory no
/// other transfer in the same step touches.
fn ring_step(
    pool: Option<&ThreadPool>,
    ptrs: &[SendPtr<f32>],
    bounds: &[(usize, usize)],
    w: usize,
    s: usize,
    reduce: bool,
) {
    let do_one = |i: usize| {
        let src = i;
        let dst = (i + 1) % w;
        let c = if reduce { (i + w - s) % w } else { (i + 1 + w - s) % w };
        let (lo, hi) = bounds[c];
        if lo >= hi {
            return;
        }
        // SAFETY: see the disjointness argument above; the underlying
        // buffers outlive the (blocking) step.
        unsafe {
            let sp = std::slice::from_raw_parts(ptrs[src].0.add(lo), hi - lo);
            let dp = std::slice::from_raw_parts_mut(ptrs[dst].0.add(lo), hi - lo);
            if reduce {
                for (d, a) in dp.iter_mut().zip(sp) {
                    *d += *a;
                }
            } else {
                dp.copy_from_slice(sp);
            }
        }
    };
    match pool {
        Some(p) if p.threads() > 1 => p.par_for(w, |i| do_one(i)),
        _ => {
            for i in 0..w {
                do_one(i);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg64};

    #[test]
    fn prop_all_reduce_computes_exact_mean() {
        proptest::check("ring-allreduce==mean", 10, |rng| {
            let w = proptest::size(rng, 1, 8);
            let n = proptest::size(rng, 1, 300);
            let bufs: Vec<Matrix> =
                (0..w).map(|_| Matrix::randn(1, n, 1.0, rng)).collect();
            let mut want = Matrix::zeros(1, n);
            for b in &bufs {
                want.axpy(1.0 / w as f32, b);
            }
            let mut got = bufs;
            let mut comm = Communicator::new(w, CommModel::default());
            comm.all_reduce_mean(&mut got);
            for b in &got {
                assert!(
                    b.max_abs_diff(&want) < 1e-5,
                    "w={w} n={n} diff={}",
                    b.max_abs_diff(&want)
                );
            }
        });
    }

    #[test]
    fn prop_pooled_all_reduce_bit_identical_with_equal_stats() {
        let pool = Arc::new(ThreadPool::new(3));
        proptest::check("pooled-allreduce==sequential", 8, |rng| {
            let w = proptest::size(rng, 1, 8);
            let n = proptest::size(rng, 1, 200);
            let bufs: Vec<Matrix> =
                (0..w).map(|_| Matrix::randn(1, n, 1.0, rng)).collect();
            let mut seq = bufs.clone();
            let mut par = bufs;
            let mut c_seq = Communicator::new(w, CommModel::default());
            let mut c_par =
                Communicator::with_pool(w, CommModel::default(), pool.clone());
            c_seq.all_reduce_mean(&mut seq);
            c_par.all_reduce_mean(&mut par);
            for (a, b) in seq.iter().zip(&par) {
                assert_eq!(a, b, "w={w} n={n}");
            }
            assert_eq!(c_seq.stats.all_reduce_bytes, c_par.stats.all_reduce_bytes);
            assert_eq!(c_seq.stats.hops, c_par.stats.hops);
            assert_eq!(c_seq.stats.modeled_secs, c_par.stats.modeled_secs);
        });
    }

    #[test]
    fn all_reduce_volume_matches_ring_formula() {
        let w = 4;
        let n = 1000usize;
        let mut rng = Pcg64::seed(0);
        let mut bufs: Vec<Matrix> =
            (0..w).map(|_| Matrix::randn(1, n, 1.0, &mut rng)).collect();
        let mut comm = Communicator::new(w, CommModel::default());
        comm.all_reduce_mean(&mut bufs);
        // 2·(W−1)·(N/W) per worker · W workers · 4 bytes ≈ 2·(W−1)·N·4
        let want = 2 * (w as u64 - 1) * n as u64 * 4;
        let got = comm.stats.all_reduce_bytes;
        let tol = want / 10; // chunk rounding
        assert!(got.abs_diff(want) <= tol, "got={got} want≈{want}");
    }

    #[test]
    fn wire_accounting_models_q8_blocks() {
        let w = 4;
        let n = 256usize; // divides evenly: every chunk is n/w, no rounding
        let mut rng = Pcg64::seed(7);
        let bufs: Vec<Matrix> =
            (0..w).map(|_| Matrix::randn(1, n, 1.0, &mut rng)).collect();
        let mut f32_bufs = bufs.clone();
        let mut q8_bufs = bufs;
        let mut c_f = Communicator::new(w, CommModel::default());
        let mut c_q = Communicator::new(w, CommModel::default());
        c_f.all_reduce_mean_wire(&mut f32_bufs, 4, 0);
        c_q.all_reduce_mean_wire(&mut q8_bufs, 1, 4);
        // the wire model never touches the arithmetic
        for (a, b) in f32_bufs.iter().zip(&q8_bufs) {
            assert_eq!(a, b);
        }
        // 2·(W−1) ring steps × W transfers, each transfer carrying its
        // chunk (bytes_per_elem·k) plus the per-block scale (overhead)
        let transfers = 2 * (w as u64 - 1) * w as u64;
        assert_eq!(c_f.stats.all_reduce_bytes, 2 * (w as u64 - 1) * n as u64 * 4);
        assert_eq!(
            c_q.stats.all_reduce_bytes,
            2 * (w as u64 - 1) * n as u64 + transfers * 4
        );
        assert_eq!(c_f.stats.hops, c_q.stats.hops);
    }

    #[test]
    fn broadcast_replicates_and_accounts() {
        let mut rng = Pcg64::seed(1);
        let src = Matrix::randn(4, 4, 1.0, &mut rng);
        let mut bufs = vec![
            Matrix::zeros(4, 4),
            src.clone(),
            Matrix::zeros(4, 4),
            Matrix::zeros(4, 4),
        ];
        let mut comm = Communicator::new(4, CommModel::default());
        comm.broadcast(&mut bufs, 1);
        for b in &bufs {
            assert_eq!(b, &src);
        }
        // 3 receivers × 64 bytes
        assert_eq!(comm.stats.broadcast_bytes, 3 * 64);
    }

    #[test]
    fn single_worker_is_free() {
        let mut rng = Pcg64::seed(2);
        let mut bufs = vec![Matrix::randn(2, 2, 1.0, &mut rng)];
        let before = bufs[0].clone();
        let mut comm = Communicator::new(1, CommModel::default());
        comm.all_reduce_mean(&mut bufs);
        assert_eq!(bufs[0], before);
        assert_eq!(comm.stats.total_bytes(), 0);
    }

    #[test]
    fn comm_model_time_monotone_in_bytes() {
        let m = CommModel::default();
        assert!(m.time_secs(1, 1000) < m.time_secs(1, 10_000_000));
        assert!(m.time_secs(1, 0) > 0.0); // latency floor
    }
}
