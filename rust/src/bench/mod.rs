//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `measure` runs warmup + timed iterations and reports median / p10 / p90
//! wall time; benches print criterion-style lines so `cargo bench` output
//! stays familiar. [`BenchRecord`] + [`write_bench_json`] additionally emit
//! a machine-readable JSON file (`BENCH_PROJ.json` and friends) so the perf
//! trajectory is trackable across PRs instead of living in scrollback.

pub mod models;

use std::time::Instant;

use crate::simd::{backend, set_backend_override, Backend};
use crate::util::json::{num, obj, s, Json};

/// Run `f(variant)` once under the forced-scalar SIMD backend and once
/// under auto-detection (override restored afterwards) — the shared driver
/// for every scalar-vs-vector bench sweep. The variant names are unique
/// even when auto-detection resolves to scalar (non-AVX2 x86_64), so
/// `(group, name)` record keys never collide in the emitted JSON.
pub fn with_simd_backends(mut f: impl FnMut(&str)) {
    set_backend_override(Some(Backend::Scalar));
    f("scalar");
    set_backend_override(None);
    let auto = if backend() == Backend::Scalar {
        "scalar_auto"
    } else {
        backend().name()
    };
    f(auto);
    set_backend_override(None);
}

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median_secs: f64,
    pub p10_secs: f64,
    pub p90_secs: f64,
    pub mean_secs: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} median {:>12} (p10 {}, p90 {}, n={})",
            self.name,
            fmt_secs(self.median_secs),
            fmt_secs(self.p10_secs),
            fmt_secs(self.p90_secs),
            self.iters
        )
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Time `f` with `warmup` throwaway calls and `iters` measured calls.
/// The closure's return value is black-boxed to keep the work alive.
pub fn measure<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
    BenchStats {
        name: name.to_string(),
        iters,
        median_secs: pct(0.5),
        p10_secs: pct(0.1),
        p90_secs: pct(0.9),
        mean_secs: times.iter().sum::<f64>() / times.len() as f64,
    }
}

/// One machine-readable benchmark sample: a [`BenchStats`] plus the
/// workload coordinates (group, shape, rank) a tracking tool needs.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Workload family, e.g. "similarity" / "selection" / "svd".
    pub group: String,
    /// Variant inside the family, e.g. "makhoul" / "matmul".
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub rank: usize,
    pub stats: BenchStats,
}

impl BenchRecord {
    pub fn new(group: &str, name: &str, rows: usize, cols: usize, rank: usize,
               stats: BenchStats) -> Self {
        BenchRecord {
            group: group.to_string(),
            name: name.to_string(),
            rows,
            cols,
            rank,
            stats,
        }
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("group", s(&self.group)),
            ("name", s(&self.name)),
            ("rows", num(self.rows as f64)),
            ("cols", num(self.cols as f64)),
            ("rank", num(self.rank as f64)),
            ("iters", num(self.stats.iters as f64)),
            ("median_ns", num((self.stats.median_secs * 1e9).round())),
            ("p10_ns", num((self.stats.p10_secs * 1e9).round())),
            ("p90_ns", num((self.stats.p90_secs * 1e9).round())),
            ("mean_ns", num((self.stats.mean_secs * 1e9).round())),
        ])
    }
}

/// Serialize records to the machine-readable bench file (one top-level
/// object so a single `Json::parse` reads it back).
pub fn bench_records_json(records: &[BenchRecord]) -> String {
    let arr = Json::Arr(records.iter().map(|r| r.to_json()).collect());
    obj(vec![("version", num(1.0)), ("records", arr)]).to_string()
}

/// Write records to `path` (e.g. `BENCH_PROJ.json`).
pub fn write_bench_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    std::fs::write(path, bench_records_json(records))
}

/// Pick an iteration count so a bench takes roughly `budget_secs`.
pub fn auto_iters<T>(f: &mut impl FnMut() -> T, budget_secs: f64) -> usize {
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    ((budget_secs / one) as usize).clamp(3, 200)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_orders_percentiles() {
        let s = measure("noop", 2, 20, || 1 + 1);
        assert!(s.p10_secs <= s.median_secs);
        assert!(s.median_secs <= s.p90_secs);
        assert_eq!(s.iters, 20);
        assert!(s.report().contains("noop"));
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_secs(5e-9).contains("ns"));
        assert!(fmt_secs(5e-5).contains("µs"));
        assert!(fmt_secs(5e-2).contains("ms"));
        assert!(fmt_secs(5.0).contains(" s"));
    }

    #[test]
    fn bench_records_round_trip_through_json() {
        let stats = measure("x", 0, 5, || 2 + 2);
        let recs = vec![
            BenchRecord::new("similarity", "makhoul", 1024, 512, 0, stats.clone()),
            BenchRecord::new("selection", "partition", 1024, 512, 64, stats),
        ];
        let text = bench_records_json(&recs);
        let parsed = Json::parse(&text).unwrap();
        let arr = parsed.req("records").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].req("name").unwrap().as_str().unwrap(), "makhoul");
        assert_eq!(arr[1].req("rank").unwrap().as_usize().unwrap(), 64);
        assert!(arr[0].req("median_ns").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn auto_iters_bounded() {
        let mut f = || std::thread::sleep(std::time::Duration::from_micros(10));
        let n = auto_iters(&mut f, 0.001);
        assert!((3..=200).contains(&n));
    }
}
