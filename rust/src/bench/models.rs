//! Shared synthetic model constructors for the bench binaries.
//!
//! `bench_mem`, `bench_optim_step` and `bench_collectives` used to carry
//! private copies of the same transformer-ish layer zoo; shapes live here
//! once so the benches stay comparable (and BENCH_MEM.json's regenerator
//! comment has a single source of truth to mirror).

use crate::optim::{LayerMeta, ParamKind};

/// Full transformer-ish stack: embed + head + per-block attention/MLP
/// linears and a norm. `d` is the model width, `vocab` the embed/head
/// vocabulary. Attention projections are `d×d`, the MLP is `d×ff`/`ff×d`
/// with `ff = d·11/4`.
pub fn transformer_stack(d: usize, blocks: usize, vocab: usize) -> Vec<LayerMeta> {
    let ff = d * 11 / 4;
    let mut metas = vec![
        LayerMeta::new("embed", vocab, d, ParamKind::Embed),
        LayerMeta::new("head", d, vocab, ParamKind::Head),
    ];
    for l in 0..blocks {
        push_block(&mut metas, l, d, ff, ParamKind::Linear);
        metas.push(LayerMeta::new(&format!("b{l}.norm"), 1, d, ParamKind::Norm));
    }
    metas
}

/// Just the per-block linear set (no embed/head/norm), with a selectable
/// param kind so step benches can flip the same shapes between the
/// low-rank path (`Linear`) and the dense-AdamW fallback (`Head`).
pub fn linear_blocks(d: usize, blocks: usize, kind: ParamKind) -> Vec<LayerMeta> {
    let ff = d * 11 / 4;
    let mut metas = Vec::with_capacity(blocks * 6);
    for l in 0..blocks {
        push_block(&mut metas, l, d, ff, kind);
    }
    metas
}

/// Uniform square-layer model (collectives / ZeRO accounting benches).
pub fn square_stack(layers: usize, d: usize) -> Vec<LayerMeta> {
    (0..layers)
        .map(|i| LayerMeta::new(&format!("w{i}"), d, d, ParamKind::Linear))
        .collect()
}

fn push_block(metas: &mut Vec<LayerMeta>, l: usize, d: usize, ff: usize, kind: ParamKind) {
    for w in ["wq", "wk", "wv", "wo"] {
        metas.push(LayerMeta::new(&format!("b{l}.{w}"), d, d, kind));
    }
    metas.push(LayerMeta::new(&format!("b{l}.gate"), d, ff, kind));
    metas.push(LayerMeta::new(&format!("b{l}.down"), ff, d, kind));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_counts() {
        let m = transformer_stack(128, 4, 256);
        // embed + head + 4 × (6 linears + norm)
        assert_eq!(m.len(), 2 + 4 * 7);
        assert_eq!((m[0].rows, m[0].cols), (256, 128));
        let linears = m.iter().filter(|l| l.kind == ParamKind::Linear).count();
        assert_eq!(linears, 24);

        let lb = linear_blocks(128, 4, ParamKind::Head);
        assert_eq!(lb.len(), 24);
        assert!(lb.iter().all(|l| l.kind == ParamKind::Head));
        // the MLP pair gives the stack its tall + wide shapes
        assert!(lb.iter().any(|l| (l.rows, l.cols) == (128, 352)));
        assert!(lb.iter().any(|l| (l.rows, l.cols) == (352, 128)));

        let sq = square_stack(24, 128);
        assert_eq!(sq.len(), 24);
        assert!(sq.iter().all(|l| l.rows == 128 && l.cols == 128));
    }
}
