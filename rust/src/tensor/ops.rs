//! Matmul kernels: register-tiled, blocked, transpose-aware, row-parallel.
//!
//! Each multiplication the optimizers perform has an allocating entry point
//! and an allocation-free `_into` twin (the hot path — outputs land in
//! [`Workspace`](super::Workspace)-pooled buffers):
//!
//! * [`matmul`] / [`matmul_into`]           — `C = A·B`
//! * [`matmul_at_b`] / [`matmul_at_b_into`] — `C = Aᵀ·B` (Gram matrices)
//! * [`matmul_a_bt`] / [`matmul_a_bt_into`] — `C = A·Bᵀ` (back-projection)
//!
//! The i-k-j kernel is register-tiled: four output rows (resp. four `k`
//! panels / four dot-product accumulators) advance together, so every
//! loaded `B` element feeds four fused multiply-adds instead of one, and
//! the inner loops are branch-free unit-stride FMA streams that
//! autovectorize. The old `aik == 0.0` skip is gone — it broke
//! vectorization for a case (exact zeros mid-gradient) that essentially
//! never occurs in training. The allocating wrappers delegate to the
//! `_into` kernels, so the two are bit-identical by construction. Note
//! `matmul_at_b_into`'s 4-wide `k` panel sums four contributions per
//! expression, which regroups floating-point rounding relative to the
//! pre-tiling kernel — same-run consistency is exact, cross-version
//! reproducibility is to ULP level only.
//!
//! **Parallelism.** Every kernel body runs over an output-*row* range
//! (`mm_block` / `mm_at_b_block` / `mm_a_bt_block`); the `_into` entry
//! points call it with the full range, and the `_into_on` variants
//! partition rows across a [`ThreadPool`]. Each output element's
//! floating-point summation order (ascending `k` within the existing
//! blocking) is a per-row property, so row partitioning yields **the exact
//! bits of the sequential kernel for any thread count** — enforced by
//! `tests/parallel_determinism.rs`.

use crate::parallel::{par_row_slabs, ThreadPool};

use super::Matrix;

/// Panel sizes. 64×cols f32 panels stay well inside L2 for the layer sizes
/// we train (cols ≤ ~1k); the 4-row register tile is the micro-kernel.
const BLOCK_K: usize = 64;
const BLOCK_I: usize = 64;
const MR: usize = 4;

/// `A (m×k) · B (k×n) → (m×n)`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// The i-k-j kernel over output rows `i0..i1`; `c_rows` is C's row slab
/// `[i0·n, i1·n)` and must be zeroed (the kernel accumulates). Per-element
/// summation order is ascending `k` within `BLOCK_K` panels regardless of
/// `i0`, which is what makes row partitioning bit-exact.
fn mm_block(a: &Matrix, b: &Matrix, c_rows: &mut [f32], i0: usize, i1: usize) {
    let n = b.cols;
    let kdim = a.cols;
    debug_assert_eq!(c_rows.len(), (i1 - i0) * n);
    for ib in (i0..i1).step_by(BLOCK_I) {
        let i_end = (ib + BLOCK_I).min(i1);
        for kb in (0..kdim).step_by(BLOCK_K) {
            let k_end = (kb + BLOCK_K).min(kdim);
            let mut i = ib;
            // 4-row micro-kernel: one pass over each B row feeds 4 C rows.
            while i + MR <= i_end {
                let base = (i - i0) * n;
                let block = &mut c_rows[base..base + MR * n];
                let (c0, rest) = block.split_at_mut(n);
                let (c1, rest) = rest.split_at_mut(n);
                let (c2, c3) = rest.split_at_mut(n);
                let a0 = a.row(i);
                let a1 = a.row(i + 1);
                let a2 = a.row(i + 2);
                let a3 = a.row(i + 3);
                for k in kb..k_end {
                    let b_row = &b.data[k * n..k * n + n];
                    let (x0, x1, x2, x3) = (a0[k], a1[k], a2[k], a3[k]);
                    for j in 0..n {
                        let bv = b_row[j];
                        c0[j] += x0 * bv;
                        c1[j] += x1 * bv;
                        c2[j] += x2 * bv;
                        c3[j] += x3 * bv;
                    }
                }
                i += MR;
            }
            // remainder rows
            while i < i_end {
                let a_row = a.row(i);
                let base = (i - i0) * n;
                let c_row = &mut c_rows[base..base + n];
                for k in kb..k_end {
                    let aik = a_row[k];
                    let b_row = &b.data[k * n..k * n + n];
                    for (cv, bv) in c_row.iter_mut().zip(b_row) {
                        *cv += aik * bv;
                    }
                }
                i += 1;
            }
        }
    }
}

/// Allocation-free [`matmul`]: resizes `c` in place and overwrites it.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch: {:?}·{:?}", a.shape(), b.shape());
    let (m, n) = (a.rows, b.cols);
    c.resize_to(m, n);
    if n == 0 {
        return;
    }
    mm_block(a, b, &mut c.data, 0, m);
}

/// Row-parallel [`matmul_into`]: output rows are partitioned across the
/// pool; bit-identical to the sequential kernel for any thread count.
pub fn matmul_into_on(pool: &ThreadPool, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch: {:?}·{:?}", a.shape(), b.shape());
    let (m, n) = (a.rows, b.cols);
    c.resize_to(m, n);
    if n == 0 || m == 0 {
        return;
    }
    par_rows(pool, m, n, &mut c.data, |rows, lo, hi| mm_block(a, b, rows, lo, hi));
}

/// `Aᵀ (k×m)ᵀ · B (k×n) → (m×n)` — A is stored (k×m); result is m×n.
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols, b.cols);
    matmul_at_b_into(a, b, &mut c);
    c
}

/// The k-outer rank-1-update kernel over output rows `m0..m1` (columns of
/// A); `c_rows` must be zeroed. Four `k` panels advance together so each C
/// row is loaded/stored once per four rank-1 updates; per-element order is
/// ascending `k` for every output row, independent of `m0`.
fn mm_at_b_block(a: &Matrix, b: &Matrix, c_rows: &mut [f32], m0: usize, m1: usize) {
    let (kdim, n) = (a.rows, b.cols);
    debug_assert_eq!(c_rows.len(), (m1 - m0) * n);
    let mut k = 0;
    while k + MR <= kdim {
        let a0 = a.row(k);
        let a1 = a.row(k + 1);
        let a2 = a.row(k + 2);
        let a3 = a.row(k + 3);
        let b0 = &b.data[k * n..k * n + n];
        let b1 = &b.data[(k + 1) * n..(k + 1) * n + n];
        let b2 = &b.data[(k + 2) * n..(k + 2) * n + n];
        let b3 = &b.data[(k + 3) * n..(k + 3) * n + n];
        for i in m0..m1 {
            let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
            let base = (i - m0) * n;
            let c_row = &mut c_rows[base..base + n];
            for j in 0..n {
                c_row[j] += x0 * b0[j] + x1 * b1[j] + x2 * b2[j] + x3 * b3[j];
            }
        }
        k += MR;
    }
    while k < kdim {
        let a_row = a.row(k);
        let b_row = &b.data[k * n..k * n + n];
        for i in m0..m1 {
            let aki = a_row[i];
            let base = (i - m0) * n;
            let c_row = &mut c_rows[base..base + n];
            for (cv, bv) in c_row.iter_mut().zip(b_row) {
                *cv += aki * bv;
            }
        }
        k += 1;
    }
}

/// Allocation-free [`matmul_at_b`].
pub fn matmul_at_b_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.rows, b.rows, "matmul_at_b shape mismatch");
    let (m, n) = (a.cols, b.cols);
    c.resize_to(m, n);
    if n == 0 {
        return;
    }
    mm_at_b_block(a, b, &mut c.data, 0, m);
}

/// Row-parallel [`matmul_at_b_into`] (partitioned over A's columns = C's
/// rows); bit-identical to sequential for any thread count.
pub fn matmul_at_b_into_on(pool: &ThreadPool, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.rows, b.rows, "matmul_at_b shape mismatch");
    let (m, n) = (a.cols, b.cols);
    c.resize_to(m, n);
    if n == 0 || m == 0 {
        return;
    }
    par_rows(pool, m, n, &mut c.data, |rows, lo, hi| mm_at_b_block(a, b, rows, lo, hi));
}

/// `A (m×k) · Bᵀ (n×k)ᵀ → (m×n)` — B is stored (n×k).
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_a_bt_into(a, b, &mut c);
    c
}

/// The dot-product kernel over output rows `i0..i1`; assign-style (`c_rows`
/// may be dirty — every element is written). Four dot products (four B
/// rows) run against each A row at once.
fn mm_a_bt_block(a: &Matrix, b: &Matrix, c_rows: &mut [f32], i0: usize, i1: usize) {
    let (kdim, n) = (a.cols, b.rows);
    debug_assert_eq!(c_rows.len(), (i1 - i0) * n);
    for i in i0..i1 {
        let a_row = a.row(i);
        let base = (i - i0) * n;
        let c_row = &mut c_rows[base..base + n];
        let mut j = 0;
        while j + MR <= n {
            let b0 = b.row(j);
            let b1 = b.row(j + 1);
            let b2 = b.row(j + 2);
            let b3 = b.row(j + 3);
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            let mut kk = 0;
            while kk + 4 <= kdim {
                s0 += a_row[kk] * b0[kk]
                    + a_row[kk + 1] * b0[kk + 1]
                    + a_row[kk + 2] * b0[kk + 2]
                    + a_row[kk + 3] * b0[kk + 3];
                s1 += a_row[kk] * b1[kk]
                    + a_row[kk + 1] * b1[kk + 1]
                    + a_row[kk + 2] * b1[kk + 2]
                    + a_row[kk + 3] * b1[kk + 3];
                s2 += a_row[kk] * b2[kk]
                    + a_row[kk + 1] * b2[kk + 1]
                    + a_row[kk + 2] * b2[kk + 2]
                    + a_row[kk + 3] * b2[kk + 3];
                s3 += a_row[kk] * b3[kk]
                    + a_row[kk + 1] * b3[kk + 1]
                    + a_row[kk + 2] * b3[kk + 2]
                    + a_row[kk + 3] * b3[kk + 3];
                kk += 4;
            }
            while kk < kdim {
                s0 += a_row[kk] * b0[kk];
                s1 += a_row[kk] * b1[kk];
                s2 += a_row[kk] * b2[kk];
                s3 += a_row[kk] * b3[kk];
                kk += 1;
            }
            c_row[j] = s0;
            c_row[j + 1] = s1;
            c_row[j + 2] = s2;
            c_row[j + 3] = s3;
            j += MR;
        }
        while j < n {
            let b_row = b.row(j);
            let mut acc = 0.0f32;
            let mut kk = 0;
            while kk + 4 <= kdim {
                acc += a_row[kk] * b_row[kk]
                    + a_row[kk + 1] * b_row[kk + 1]
                    + a_row[kk + 2] * b_row[kk + 2]
                    + a_row[kk + 3] * b_row[kk + 3];
                kk += 4;
            }
            while kk < kdim {
                acc += a_row[kk] * b_row[kk];
                kk += 1;
            }
            c_row[j] = acc;
            j += 1;
        }
    }
}

/// Allocation-free [`matmul_a_bt`].
pub fn matmul_a_bt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_a_bt shape mismatch");
    let (m, n) = (a.rows, b.rows);
    c.resize_for_overwrite(m, n);
    mm_a_bt_block(a, b, &mut c.data, 0, m);
}

/// Row-parallel [`matmul_a_bt_into`]; bit-identical to sequential for any
/// thread count.
pub fn matmul_a_bt_into_on(pool: &ThreadPool, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_a_bt shape mismatch");
    let (m, n) = (a.rows, b.rows);
    c.resize_for_overwrite(m, n);
    if n == 0 || m == 0 {
        return;
    }
    par_rows(pool, m, n, &mut c.data, |rows, lo, hi| mm_a_bt_block(a, b, rows, lo, hi));
}

/// Partition `m` output rows of width `n` into contiguous chunks (one per
/// pool lane) and hand each chunk its disjoint slab of `c_data` — a thin
/// alias over the shared `parallel::par_row_slabs` partitioner.
fn par_rows(
    pool: &ThreadPool,
    m: usize,
    n: usize,
    c_data: &mut [f32],
    body: impl Fn(&mut [f32], usize, usize) + Sync,
) {
    par_row_slabs(pool, m, n, c_data, body);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg64};

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for k in 0..a.cols {
                    acc += (a.at(i, k) as f64) * (b.at(k, j) as f64);
                }
                *c.at_mut(i, j) = acc as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_small() {
        let a = Matrix::from_fn(3, 4, |i, j| (i + j) as f32);
        let b = Matrix::from_fn(4, 2, |i, j| (i as f32) - (j as f32));
        assert_eq!(matmul(&a, &b), naive(&a, &b));
    }

    #[test]
    fn prop_matmul_matches_naive() {
        proptest::check("matmul==naive", 12, |rng| {
            let m = proptest::size(rng, 1, 70);
            let k = proptest::size(rng, 1, 70);
            let n = proptest::size(rng, 1, 70);
            let a = Matrix::randn(m, k, 1.0, rng);
            let b = Matrix::randn(k, n, 1.0, rng);
            let diff = matmul(&a, &b).max_abs_diff(&naive(&a, &b));
            assert!(diff < 1e-3, "diff={diff}");
        });
    }

    #[test]
    fn prop_transposed_variants_consistent() {
        proptest::check("at_b/a_bt==explicit", 12, |rng| {
            let m = proptest::size(rng, 1, 40);
            let k = proptest::size(rng, 1, 40);
            let n = proptest::size(rng, 1, 40);
            let a = Matrix::randn(k, m, 1.0, rng); // stored kxm
            let b = Matrix::randn(k, n, 1.0, rng);
            let got = matmul_at_b(&a, &b);
            let want = matmul(&a.transpose(), &b);
            assert!(got.max_abs_diff(&want) < 1e-3);

            let a2 = Matrix::randn(m, k, 1.0, rng);
            let b2 = Matrix::randn(n, k, 1.0, rng); // stored nxk
            let got2 = matmul_a_bt(&a2, &b2);
            let want2 = matmul(&a2, &b2.transpose());
            assert!(got2.max_abs_diff(&want2) < 1e-3);
        });
    }

    #[test]
    fn prop_into_variants_bit_identical_to_allocating() {
        // The `_into` kernels must produce the exact same bits as their
        // allocating wrappers even when handed dirty, wrongly-shaped
        // output buffers (the workspace reuse pattern).
        proptest::check("into==allocating", 12, |rng| {
            let m = proptest::size(rng, 1, 33);
            let k = proptest::size(rng, 1, 33);
            let n = proptest::size(rng, 1, 33);
            let mut dirty = Matrix::randn(2, 5, 1.0, rng);

            let a = Matrix::randn(m, k, 1.0, rng);
            let b = Matrix::randn(k, n, 1.0, rng);
            matmul_into(&a, &b, &mut dirty);
            assert_eq!(dirty, matmul(&a, &b));

            let at = Matrix::randn(k, m, 1.0, rng);
            matmul_at_b_into(&at, &b, &mut dirty);
            assert_eq!(dirty, matmul_at_b(&at, &b));

            let bt = Matrix::randn(n, k, 1.0, rng);
            matmul_a_bt_into(&a, &bt, &mut dirty);
            assert_eq!(dirty, matmul_a_bt(&a, &bt));
        });
    }

    #[test]
    fn prop_parallel_variants_bit_identical_to_sequential() {
        // Row-partitioned execution must reproduce the sequential bits for
        // every thread count, shape, and (dirty) output buffer.
        let pools = [ThreadPool::new(2), ThreadPool::new(3), ThreadPool::new(8)];
        proptest::check("on==into", 8, |rng| {
            let m = proptest::size(rng, 1, 50);
            let k = proptest::size(rng, 1, 30);
            let n = proptest::size(rng, 1, 30);
            let a = Matrix::randn(m, k, 1.0, rng);
            let b = Matrix::randn(k, n, 1.0, rng);
            let at = Matrix::randn(k, m, 1.0, rng);
            let bt = Matrix::randn(n, k, 1.0, rng);
            let mut out = Matrix::randn(3, 3, 1.0, rng); // dirty
            for pool in &pools {
                matmul_into_on(pool, &a, &b, &mut out);
                assert_eq!(out, matmul(&a, &b), "matmul t={}", pool.threads());

                matmul_at_b_into_on(pool, &at, &b, &mut out);
                assert_eq!(out, matmul_at_b(&at, &b), "at_b t={}", pool.threads());

                matmul_a_bt_into_on(pool, &a, &bt, &mut out);
                assert_eq!(out, matmul_a_bt(&a, &bt), "a_bt t={}", pool.threads());
            }
        });
    }

    #[test]
    fn micro_kernel_handles_remainder_rows() {
        // sizes straddling the 4-row register tile: 1..=9 rows
        let mut rng = Pcg64::seed(11);
        for m in 1..=9usize {
            let a = Matrix::randn(m, 17, 1.0, &mut rng);
            let b = Matrix::randn(17, 5, 1.0, &mut rng);
            let diff = matmul(&a, &b).max_abs_diff(&naive(&a, &b));
            assert!(diff < 1e-3, "m={m} diff={diff}");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seed(4);
        let a = Matrix::randn(9, 9, 1.0, &mut rng);
        assert!(matmul(&a, &Matrix::eye(9)).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&Matrix::eye(9), &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn associativity_within_tolerance() {
        let mut rng = Pcg64::seed(5);
        let a = Matrix::randn(12, 7, 1.0, &mut rng);
        let b = Matrix::randn(7, 9, 1.0, &mut rng);
        let c = Matrix::randn(9, 5, 1.0, &mut rng);
        let left = matmul(&matmul(&a, &b), &c);
        let right = matmul(&a, &matmul(&b, &c));
        assert!(left.max_abs_diff(&right) < 1e-3);
    }
}
