//! Matmul kernels: register-tiled, blocked, transpose-aware, row-parallel.
//!
//! Each multiplication the optimizers perform has an allocating entry point
//! and an allocation-free `_into` twin (the hot path — outputs land in
//! [`Workspace`](super::Workspace)-pooled buffers):
//!
//! * [`matmul`] / [`matmul_into`]           — `C = A·B`
//! * [`matmul_at_b`] / [`matmul_at_b_into`] — `C = Aᵀ·B` (Gram matrices)
//! * [`matmul_a_bt`] / [`matmul_a_bt_into`] — `C = A·Bᵀ` (back-projection)
//!
//! The i-k-j kernel is register-tiled: four output rows (resp. four `k`
//! panels / four dot-product accumulators) advance together, and the inner
//! `j` loops are **explicitly vectorized** through the [`crate::simd`]
//! lane layer — runtime-dispatched AVX2/NEON with a bit-exact scalar
//! fallback (`FFT_SUBSPACE_SIMD=0`). Lanes span distinct output columns
//! and every lane op is a separate IEEE multiply/add (no FMA contraction),
//! so each element's ascending-`k` summation order — and with it the PR-2
//! thread-count bit-identity contract — is untouched; the `matmul_a_bt`
//! dot products keep 8 per-lane partial sums folded by the shared
//! fixed-order tree reduction (`simd::reduce_tree8`), making their order a
//! function of the inner dimension alone. The old `aik == 0.0` skip is
//! gone — it broke vectorization for a case (exact zeros mid-gradient)
//! that essentially never occurs in training. The allocating wrappers
//! delegate to the `_into` kernels, so the two are bit-identical by
//! construction. Note the 4-wide `k` panels and the tree reduction regroup
//! floating-point rounding relative to the pre-tiling kernels —
//! same-run/same-version consistency is exact (any backend, any thread
//! count), cross-version reproducibility is to ULP level only.
//!
//! **Parallelism.** Every kernel body runs over an output-*row* range
//! (`mm_block` / `mm_at_b_block` / `mm_a_bt_block`); the `_into` entry
//! points call it with the full range, and the `_into_on` variants
//! partition rows across a [`ThreadPool`]. Each output element's
//! floating-point summation order (ascending `k` within the existing
//! blocking) is a per-row property, so row partitioning yields **the exact
//! bits of the sequential kernel for any thread count** — enforced by
//! `tests/parallel_determinism.rs`.

use crate::parallel::{par_row_slabs, partition, SendPtr, ThreadPool};
use crate::simd::{reduce_tree8, Simd, F32_LANES};

use super::Matrix;

/// Panel sizes. 64×cols f32 panels stay well inside L2 for the layer sizes
/// we train (cols ≤ ~1k); the 4-row register tile is the micro-kernel.
const BLOCK_K: usize = 64;
const BLOCK_I: usize = 64;
const MR: usize = 4;

/// `A (m×k) · B (k×n) → (m×n)`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// The i-k-j kernel over output rows `i0..i1`; `c_rows` is C's row slab
/// `[i0·n, i1·n)` and must be zeroed (the kernel accumulates). Per-element
/// summation order is ascending `k` within `BLOCK_K` panels regardless of
/// `i0`, which is what makes row partitioning bit-exact. SIMD lanes span
/// distinct output columns (`j`), so vectorization never touches that
/// order — every backend produces the same bits (see `crate::simd`).
#[inline(always)]
fn mm_block_g<S: Simd>(a: &Matrix, b: &Matrix, c_rows: &mut [f32], i0: usize, i1: usize) {
    let n = b.cols;
    let kdim = a.cols;
    debug_assert_eq!(c_rows.len(), (i1 - i0) * n);
    for ib in (i0..i1).step_by(BLOCK_I) {
        let i_end = (ib + BLOCK_I).min(i1);
        for kb in (0..kdim).step_by(BLOCK_K) {
            let k_end = (kb + BLOCK_K).min(kdim);
            let mut i = ib;
            // 4-row micro-kernel: one pass over each B row feeds 4 C rows.
            while i + MR <= i_end {
                let base = (i - i0) * n;
                let block = &mut c_rows[base..base + MR * n];
                let (c0, rest) = block.split_at_mut(n);
                let (c1, rest) = rest.split_at_mut(n);
                let (c2, c3) = rest.split_at_mut(n);
                let a0 = a.row(i);
                let a1 = a.row(i + 1);
                let a2 = a.row(i + 2);
                let a3 = a.row(i + 3);
                for k in kb..k_end {
                    let b_row = &b.data[k * n..k * n + n];
                    let (x0, x1, x2, x3) = (a0[k], a1[k], a2[k], a3[k]);
                    let (v0, v1, v2, v3) =
                        (S::splat(x0), S::splat(x1), S::splat(x2), S::splat(x3));
                    let mut j = 0;
                    while j + F32_LANES <= n {
                        let bv = S::load(&b_row[j..]);
                        let t0 = S::mul_add(S::load(&c0[j..]), v0, bv);
                        S::store(&mut c0[j..], t0);
                        let t1 = S::mul_add(S::load(&c1[j..]), v1, bv);
                        S::store(&mut c1[j..], t1);
                        let t2 = S::mul_add(S::load(&c2[j..]), v2, bv);
                        S::store(&mut c2[j..], t2);
                        let t3 = S::mul_add(S::load(&c3[j..]), v3, bv);
                        S::store(&mut c3[j..], t3);
                        j += F32_LANES;
                    }
                    while j < n {
                        let bv = b_row[j];
                        c0[j] += x0 * bv;
                        c1[j] += x1 * bv;
                        c2[j] += x2 * bv;
                        c3[j] += x3 * bv;
                        j += 1;
                    }
                }
                i += MR;
            }
            // remainder rows
            while i < i_end {
                let a_row = a.row(i);
                let base = (i - i0) * n;
                let c_row = &mut c_rows[base..base + n];
                for k in kb..k_end {
                    let aik = a_row[k];
                    let b_row = &b.data[k * n..k * n + n];
                    let va = S::splat(aik);
                    let mut j = 0;
                    while j + F32_LANES <= n {
                        let bv = S::load(&b_row[j..]);
                        let t = S::mul_add(S::load(&c_row[j..]), va, bv);
                        S::store(&mut c_row[j..], t);
                        j += F32_LANES;
                    }
                    while j < n {
                        c_row[j] += aik * b_row[j];
                        j += 1;
                    }
                }
                i += 1;
            }
        }
    }
}

crate::simd_dispatch! {
    fn mm_block(a: &Matrix, b: &Matrix, c_rows: &mut [f32], i0: usize, i1: usize) = mm_block_g
}

/// Allocation-free [`matmul`]: resizes `c` in place and overwrites it.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch: {:?}·{:?}", a.shape(), b.shape());
    let (m, n) = (a.rows, b.cols);
    c.resize_to(m, n);
    if n == 0 {
        return;
    }
    mm_block(a, b, &mut c.data, 0, m);
}

/// Row-parallel [`matmul_into`]: output rows are partitioned across the
/// pool; bit-identical to the sequential kernel for any thread count.
pub fn matmul_into_on(pool: &ThreadPool, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch: {:?}·{:?}", a.shape(), b.shape());
    let (m, n) = (a.rows, b.cols);
    c.resize_to(m, n);
    if n == 0 || m == 0 {
        return;
    }
    par_rows(pool, m, n, &mut c.data, |rows, lo, hi| mm_block(a, b, rows, lo, hi));
}

/// `Aᵀ (k×m)ᵀ · B (k×n) → (m×n)` — A is stored (k×m); result is m×n.
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols, b.cols);
    matmul_at_b_into(a, b, &mut c);
    c
}

/// The k-outer rank-1-update kernel over output rows `m0..m1` (columns of
/// A); `c_rows` must be zeroed. Four `k` panels advance together so each C
/// row is loaded/stored once per four rank-1 updates; per-element order is
/// ascending `k` for every output row, independent of `m0`. SIMD lanes
/// span distinct output columns; the four panel contributions keep their
/// scalar left-to-right association (`((x0·b0 + x1·b1) + x2·b2) + x3·b3`).
#[inline(always)]
fn mm_at_b_block_g<S: Simd>(a: &Matrix, b: &Matrix, c_rows: &mut [f32], m0: usize, m1: usize) {
    let (kdim, n) = (a.rows, b.cols);
    debug_assert_eq!(c_rows.len(), (m1 - m0) * n);
    let mut k = 0;
    while k + MR <= kdim {
        let a0 = a.row(k);
        let a1 = a.row(k + 1);
        let a2 = a.row(k + 2);
        let a3 = a.row(k + 3);
        let b0 = &b.data[k * n..k * n + n];
        let b1 = &b.data[(k + 1) * n..(k + 1) * n + n];
        let b2 = &b.data[(k + 2) * n..(k + 2) * n + n];
        let b3 = &b.data[(k + 3) * n..(k + 3) * n + n];
        for i in m0..m1 {
            let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
            let (v0, v1, v2, v3) = (S::splat(x0), S::splat(x1), S::splat(x2), S::splat(x3));
            let base = (i - m0) * n;
            let c_row = &mut c_rows[base..base + n];
            let mut j = 0;
            while j + F32_LANES <= n {
                let mut t = S::mul(v0, S::load(&b0[j..]));
                t = S::mul_add(t, v1, S::load(&b1[j..]));
                t = S::mul_add(t, v2, S::load(&b2[j..]));
                t = S::mul_add(t, v3, S::load(&b3[j..]));
                let c = S::add(S::load(&c_row[j..]), t);
                S::store(&mut c_row[j..], c);
                j += F32_LANES;
            }
            while j < n {
                c_row[j] += x0 * b0[j] + x1 * b1[j] + x2 * b2[j] + x3 * b3[j];
                j += 1;
            }
        }
        k += MR;
    }
    while k < kdim {
        let a_row = a.row(k);
        let b_row = &b.data[k * n..k * n + n];
        for i in m0..m1 {
            let aki = a_row[i];
            let va = S::splat(aki);
            let base = (i - m0) * n;
            let c_row = &mut c_rows[base..base + n];
            let mut j = 0;
            while j + F32_LANES <= n {
                let bv = S::load(&b_row[j..]);
                let t = S::mul_add(S::load(&c_row[j..]), va, bv);
                S::store(&mut c_row[j..], t);
                j += F32_LANES;
            }
            while j < n {
                c_row[j] += aki * b_row[j];
                j += 1;
            }
        }
        k += 1;
    }
}

crate::simd_dispatch! {
    fn mm_at_b_block(a: &Matrix, b: &Matrix, c_rows: &mut [f32], m0: usize, m1: usize)
        = mm_at_b_block_g
}

/// Allocation-free [`matmul_at_b`].
pub fn matmul_at_b_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.rows, b.rows, "matmul_at_b shape mismatch");
    let (m, n) = (a.cols, b.cols);
    c.resize_to(m, n);
    if n == 0 {
        return;
    }
    mm_at_b_block(a, b, &mut c.data, 0, m);
}

/// Row-parallel [`matmul_at_b_into`] (partitioned over A's columns = C's
/// rows); bit-identical to sequential for any thread count.
pub fn matmul_at_b_into_on(pool: &ThreadPool, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.rows, b.rows, "matmul_at_b shape mismatch");
    let (m, n) = (a.cols, b.cols);
    c.resize_to(m, n);
    if n == 0 || m == 0 {
        return;
    }
    par_rows(pool, m, n, &mut c.data, |rows, lo, hi| mm_at_b_block(a, b, rows, lo, hi));
}

/// `A (m×k) · Bᵀ (n×k)ᵀ → (m×n)` — B is stored (n×k).
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_a_bt_into(a, b, &mut c);
    c
}

/// One dot product `a_row · b_row` with the kernel's canonical summation
/// order: 8 per-lane partial sums over the `F32_LANES`-aligned prefix, the
/// shared fixed tree reduction ([`reduce_tree8`]), then the tail elements
/// in ascending order. The order depends only on `kdim`, never on the
/// output position or thread count, so row partitioning stays bit-exact.
#[inline(always)]
fn dot_g<S: Simd>(a_row: &[f32], b_row: &[f32], kdim: usize) -> f32 {
    let mut acc = S::splat(0.0);
    let mut kk = 0;
    while kk + F32_LANES <= kdim {
        acc = S::mul_add(acc, S::load(&a_row[kk..]), S::load(&b_row[kk..]));
        kk += F32_LANES;
    }
    let mut s = reduce_tree8(S::to_array(acc));
    while kk < kdim {
        s += a_row[kk] * b_row[kk];
        kk += 1;
    }
    s
}

/// The dot-product kernel over output rows `i0..i1`; assign-style (`c_rows`
/// may be dirty — every element is written). Four dot products (four B
/// rows) run against each A row at once, each accumulating `F32_LANES`
/// per-lane partial sums folded by the shared fixed-order tree reduction —
/// identical bits for every backend, and per-element order is a function
/// of `kdim` alone (see [`dot_g`]).
#[inline(always)]
fn mm_a_bt_block_g<S: Simd>(a: &Matrix, b: &Matrix, c_rows: &mut [f32], i0: usize, i1: usize) {
    let (kdim, n) = (a.cols, b.rows);
    debug_assert_eq!(c_rows.len(), (i1 - i0) * n);
    for i in i0..i1 {
        let a_row = a.row(i);
        let base = (i - i0) * n;
        let c_row = &mut c_rows[base..base + n];
        let mut j = 0;
        while j + MR <= n {
            let b0 = b.row(j);
            let b1 = b.row(j + 1);
            let b2 = b.row(j + 2);
            let b3 = b.row(j + 3);
            let (mut s0, mut s1, mut s2, mut s3) =
                (S::splat(0.0), S::splat(0.0), S::splat(0.0), S::splat(0.0));
            let mut kk = 0;
            while kk + F32_LANES <= kdim {
                let av = S::load(&a_row[kk..]);
                s0 = S::mul_add(s0, av, S::load(&b0[kk..]));
                s1 = S::mul_add(s1, av, S::load(&b1[kk..]));
                s2 = S::mul_add(s2, av, S::load(&b2[kk..]));
                s3 = S::mul_add(s3, av, S::load(&b3[kk..]));
                kk += F32_LANES;
            }
            let mut t0 = reduce_tree8(S::to_array(s0));
            let mut t1 = reduce_tree8(S::to_array(s1));
            let mut t2 = reduce_tree8(S::to_array(s2));
            let mut t3 = reduce_tree8(S::to_array(s3));
            while kk < kdim {
                let av = a_row[kk];
                t0 += av * b0[kk];
                t1 += av * b1[kk];
                t2 += av * b2[kk];
                t3 += av * b3[kk];
                kk += 1;
            }
            c_row[j] = t0;
            c_row[j + 1] = t1;
            c_row[j + 2] = t2;
            c_row[j + 3] = t3;
            j += MR;
        }
        while j < n {
            c_row[j] = dot_g::<S>(a_row, b.row(j), kdim);
            j += 1;
        }
    }
}

crate::simd_dispatch! {
    fn mm_a_bt_block(a: &Matrix, b: &Matrix, c_rows: &mut [f32], i0: usize, i1: usize)
        = mm_a_bt_block_g
}

/// Allocation-free [`matmul_a_bt`].
pub fn matmul_a_bt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_a_bt shape mismatch");
    let (m, n) = (a.rows, b.rows);
    c.resize_for_overwrite(m, n);
    mm_a_bt_block(a, b, &mut c.data, 0, m);
}

/// Row-parallel [`matmul_a_bt_into`]; bit-identical to sequential for any
/// thread count.
pub fn matmul_a_bt_into_on(pool: &ThreadPool, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_a_bt shape mismatch");
    let (m, n) = (a.rows, b.rows);
    c.resize_for_overwrite(m, n);
    if n == 0 || m == 0 {
        return;
    }
    par_rows(pool, m, n, &mut c.data, |rows, lo, hi| mm_a_bt_block(a, b, rows, lo, hi));
}

/// Group-batched [`matmul_into_on`]: `dsts.len()` independent products
/// `src(l) · b(l)`, each with `rows_per_job` output rows, stacked into one
/// pool dispatch partitioned over the *concatenated* row space — the fused
/// step plans' project passes (one dispatch per layer group instead of one
/// per layer). Job `l` writes its `rows_per_job × b(l).cols` result through
/// `dsts[l]`; each destination range is zero-filled here before the
/// accumulating kernel runs, so callers may hand over dirty buffers.
/// `mm_block`'s per-element summation order is ascending `k` independent of
/// the starting row, so any chunking of the flattened row space produces
/// the exact bits of `dsts.len()` sequential [`matmul_into`] calls.
///
/// Safety contract (checked only by `debug_assert`): `dsts[l]` must point
/// to a writable `rows_per_job * b(l).cols` f32 slab and the slabs must be
/// mutually disjoint; `src(l)` must have `rows_per_job` rows matching
/// `b(l).rows` columns.
pub fn matmul_rows_batched_on<'a, 'b>(
    pool: &ThreadPool,
    rows_per_job: usize,
    src: &(impl Fn(usize) -> &'a Matrix + Sync),
    b: &(impl Fn(usize) -> &'b Matrix + Sync),
    dsts: &[SendPtr<f32>],
) {
    let total = dsts.len() * rows_per_job;
    if total == 0 {
        return;
    }
    let (per, n_chunks) = partition(pool.threads(), total);
    pool.par_chunks(n_chunks, |c| {
        let lo = c * per;
        let hi = (lo + per).min(total);
        let mut f = lo;
        while f < hi {
            let l = f / rows_per_job;
            let i0 = f % rows_per_job;
            let i1 = rows_per_job.min(i0 + (hi - f));
            let a = src(l);
            let bm = b(l);
            debug_assert_eq!(a.rows, rows_per_job, "batched matmul: job {l} row count");
            debug_assert_eq!(a.cols, bm.rows, "batched matmul: job {l} shape mismatch");
            let n = bm.cols;
            // SAFETY: rows [i0, i1) of job l's slab — chunks cover disjoint
            // ranges of the flattened row space and slabs are disjoint per
            // the caller contract, so no two chunks alias.
            let slab = unsafe {
                std::slice::from_raw_parts_mut(dsts[l].0.add(i0 * n), (i1 - i0) * n)
            };
            slab.fill(0.0);
            if n > 0 {
                mm_block(a, bm, slab, i0, i1);
            }
            f += i1 - i0;
        }
    });
}

/// Non-finite (NaN/±Inf) detection: an f32 is non-finite iff its exponent
/// bits are all ones, i.e. `bits & 0x7F80_0000 == 0x7F80_0000`. The lane
/// version expresses the equality test with the existing exact u32 ops
/// (no compare lane op needed): `masked + 0x0080_0000` carries into the
/// sign bit iff `masked == 0x7F80_0000` (masked can never exceed it), so
/// `(masked.wrapping_add(0x0080_0000)) & 0x8000_0000` is the per-lane
/// non-finite flag. Flags are OR-accumulated — pure bit manipulation end
/// to end, so the SIMD bit-identity contract holds trivially, and the
/// scalar tail runs the identical op sequence on `f32::to_bits`.
#[inline(always)]
fn all_finite_g<S: Simd>(data: &[f32]) -> bool {
    const EXP: u32 = 0x7F80_0000;
    const CARRY: u32 = 0x0080_0000;
    const SIGN: u32 = 0x8000_0000;
    let exp = S::splat_u32(EXP);
    let carry = S::splat_u32(CARRY);
    let sign = S::splat_u32(SIGN);
    let mut acc = S::splat_u32(0);
    let n = data.len();
    let mut i = 0;
    while i + F32_LANES <= n {
        let bits = S::f32_bits(S::load(&data[i..]));
        let flag = S::and_u32(S::add_u32(S::and_u32(bits, exp), carry), sign);
        acc = S::or_u32(acc, flag);
        i += F32_LANES;
    }
    let mut any = S::to_array_u32(acc).iter().fold(0u32, |a, &b| a | b);
    while i < n {
        any |= (data[i].to_bits() & EXP).wrapping_add(CARRY) & SIGN;
        i += 1;
    }
    any == 0
}

crate::simd_dispatch! {
    /// `true` iff every element of `data` is finite (no NaN, no ±Inf).
    /// Allocation-free single pass; the numerical-health guard scans every
    /// gradient through this before the optimizer step, and the `linalg`
    /// refresh gates use it to keep the previous basis on poisoned input.
    pub fn all_finite(data: &[f32]) -> bool = all_finite_g
}

/// Partition `m` output rows of width `n` into contiguous chunks (one per
/// pool lane) and hand each chunk its disjoint slab of `c_data` — a thin
/// alias over the shared `parallel::par_row_slabs` partitioner.
fn par_rows(
    pool: &ThreadPool,
    m: usize,
    n: usize,
    c_data: &mut [f32],
    body: impl Fn(&mut [f32], usize, usize) + Sync,
) {
    par_row_slabs(pool, m, n, c_data, body);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg64};

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for k in 0..a.cols {
                    acc += (a.at(i, k) as f64) * (b.at(k, j) as f64);
                }
                *c.at_mut(i, j) = acc as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_small() {
        let a = Matrix::from_fn(3, 4, |i, j| (i + j) as f32);
        let b = Matrix::from_fn(4, 2, |i, j| (i as f32) - (j as f32));
        assert_eq!(matmul(&a, &b), naive(&a, &b));
    }

    #[test]
    fn prop_matmul_matches_naive() {
        proptest::check("matmul==naive", 12, |rng| {
            let m = proptest::size(rng, 1, 70);
            let k = proptest::size(rng, 1, 70);
            let n = proptest::size(rng, 1, 70);
            let a = Matrix::randn(m, k, 1.0, rng);
            let b = Matrix::randn(k, n, 1.0, rng);
            let diff = matmul(&a, &b).max_abs_diff(&naive(&a, &b));
            assert!(diff < 1e-3, "diff={diff}");
        });
    }

    #[test]
    fn prop_transposed_variants_consistent() {
        proptest::check("at_b/a_bt==explicit", 12, |rng| {
            let m = proptest::size(rng, 1, 40);
            let k = proptest::size(rng, 1, 40);
            let n = proptest::size(rng, 1, 40);
            let a = Matrix::randn(k, m, 1.0, rng); // stored kxm
            let b = Matrix::randn(k, n, 1.0, rng);
            let got = matmul_at_b(&a, &b);
            let want = matmul(&a.transpose(), &b);
            assert!(got.max_abs_diff(&want) < 1e-3);

            let a2 = Matrix::randn(m, k, 1.0, rng);
            let b2 = Matrix::randn(n, k, 1.0, rng); // stored nxk
            let got2 = matmul_a_bt(&a2, &b2);
            let want2 = matmul(&a2, &b2.transpose());
            assert!(got2.max_abs_diff(&want2) < 1e-3);
        });
    }

    #[test]
    fn prop_into_variants_bit_identical_to_allocating() {
        // The `_into` kernels must produce the exact same bits as their
        // allocating wrappers even when handed dirty, wrongly-shaped
        // output buffers (the workspace reuse pattern).
        proptest::check("into==allocating", 12, |rng| {
            let m = proptest::size(rng, 1, 33);
            let k = proptest::size(rng, 1, 33);
            let n = proptest::size(rng, 1, 33);
            let mut dirty = Matrix::randn(2, 5, 1.0, rng);

            let a = Matrix::randn(m, k, 1.0, rng);
            let b = Matrix::randn(k, n, 1.0, rng);
            matmul_into(&a, &b, &mut dirty);
            assert_eq!(dirty, matmul(&a, &b));

            let at = Matrix::randn(k, m, 1.0, rng);
            matmul_at_b_into(&at, &b, &mut dirty);
            assert_eq!(dirty, matmul_at_b(&at, &b));

            let bt = Matrix::randn(n, k, 1.0, rng);
            matmul_a_bt_into(&a, &bt, &mut dirty);
            assert_eq!(dirty, matmul_a_bt(&a, &bt));
        });
    }

    #[test]
    fn prop_parallel_variants_bit_identical_to_sequential() {
        // Row-partitioned execution must reproduce the sequential bits for
        // every thread count, shape, and (dirty) output buffer.
        let pools = [ThreadPool::new(2), ThreadPool::new(3), ThreadPool::new(8)];
        proptest::check("on==into", 8, |rng| {
            let m = proptest::size(rng, 1, 50);
            let k = proptest::size(rng, 1, 30);
            let n = proptest::size(rng, 1, 30);
            let a = Matrix::randn(m, k, 1.0, rng);
            let b = Matrix::randn(k, n, 1.0, rng);
            let at = Matrix::randn(k, m, 1.0, rng);
            let bt = Matrix::randn(n, k, 1.0, rng);
            let mut out = Matrix::randn(3, 3, 1.0, rng); // dirty
            for pool in &pools {
                matmul_into_on(pool, &a, &b, &mut out);
                assert_eq!(out, matmul(&a, &b), "matmul t={}", pool.threads());

                matmul_at_b_into_on(pool, &at, &b, &mut out);
                assert_eq!(out, matmul_at_b(&at, &b), "at_b t={}", pool.threads());

                matmul_a_bt_into_on(pool, &a, &bt, &mut out);
                assert_eq!(out, matmul_a_bt(&a, &bt), "a_bt t={}", pool.threads());
            }
        });
    }

    #[test]
    fn prop_rows_batched_bit_identical_to_per_job() {
        // The stacked group dispatch must reproduce the exact bits of
        // per-job matmul_into calls for every thread count and chunking.
        let pools = [ThreadPool::new(1), ThreadPool::new(3), ThreadPool::new(8)];
        proptest::check("batched==per-job", 8, |rng| {
            let jobs = proptest::size(rng, 1, 6);
            let m = proptest::size(rng, 1, 20);
            let k = proptest::size(rng, 1, 24);
            let n = proptest::size(rng, 1, 24);
            let srcs: Vec<Matrix> =
                (0..jobs).map(|_| Matrix::randn(m, k, 1.0, rng)).collect();
            let b = Matrix::randn(k, n, 1.0, rng);
            let want: Vec<Matrix> = srcs.iter().map(|a| matmul(a, &b)).collect();
            let mut outs: Vec<Matrix> =
                (0..jobs).map(|_| Matrix::randn(m, n, 1.0, rng)).collect(); // dirty
            for pool in &pools {
                let dsts: Vec<SendPtr<f32>> =
                    outs.iter_mut().map(|o| SendPtr(o.data.as_mut_ptr())).collect();
                matmul_rows_batched_on(pool, m, &|l| &srcs[l], &|_| &b, &dsts);
                for (o, w) in outs.iter().zip(&want) {
                    assert_eq!(o, w, "t={}", pool.threads());
                }
            }
        });
    }

    #[test]
    fn micro_kernel_handles_remainder_rows() {
        // sizes straddling the 4-row register tile: 1..=9 rows
        let mut rng = Pcg64::seed(11);
        for m in 1..=9usize {
            let a = Matrix::randn(m, 17, 1.0, &mut rng);
            let b = Matrix::randn(17, 5, 1.0, &mut rng);
            let diff = matmul(&a, &b).max_abs_diff(&naive(&a, &b));
            assert!(diff < 1e-3, "m={m} diff={diff}");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seed(4);
        let a = Matrix::randn(9, 9, 1.0, &mut rng);
        assert!(matmul(&a, &Matrix::eye(9)).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&Matrix::eye(9), &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn associativity_within_tolerance() {
        let mut rng = Pcg64::seed(5);
        let a = Matrix::randn(12, 7, 1.0, &mut rng);
        let b = Matrix::randn(7, 9, 1.0, &mut rng);
        let c = Matrix::randn(9, 5, 1.0, &mut rng);
        let left = matmul(&matmul(&a, &b), &c);
        let right = matmul(&a, &matmul(&b, &c));
        assert!(left.max_abs_diff(&right) < 1e-3);
    }
}
