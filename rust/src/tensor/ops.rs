//! Matmul kernels: blocked, transpose-aware, single-core cache-tiled.
//!
//! Three entry points cover every multiplication the optimizers perform
//! without materializing transposes:
//!
//! * [`matmul`]      — `C = A·B`
//! * [`matmul_at_b`] — `C = Aᵀ·B`   (e.g. Gram matrices `XᵀX`)
//! * [`matmul_a_bt`] — `C = A·Bᵀ`   (e.g. back-projection `b_t·Q_rᵀ`)
//!
//! The inner loop is an i-k-j kernel over row-major data: the `k`-loop
//! broadcasts `A[i,k]` and runs a unit-stride fused multiply-add over the
//! `B` row, which autovectorizes well; blocking keeps the `B` panel in L2.

use super::Matrix;

/// Panel size (rows of A / rows of B per block). 64×cols f32 panels stay
/// well inside L2 for the layer sizes we train (cols ≤ ~1k).
const BLOCK_K: usize = 64;
const BLOCK_I: usize = 64;

/// `A (m×k) · B (k×n) → (m×n)`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch: {:?}·{:?}", a.shape(), b.shape());
    let (m, kdim, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    for ib in (0..m).step_by(BLOCK_I) {
        let i_end = (ib + BLOCK_I).min(m);
        for kb in (0..kdim).step_by(BLOCK_K) {
            let k_end = (kb + BLOCK_K).min(kdim);
            for i in ib..i_end {
                let a_row = a.row(i);
                let c_row = &mut c.data[i * n..(i + 1) * n];
                for k in kb..k_end {
                    let aik = a_row[k];
                    if aik == 0.0 {
                        continue;
                    }
                    let b_row = &b.data[k * n..(k + 1) * n];
                    for (cv, bv) in c_row.iter_mut().zip(b_row) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
    c
}

/// `Aᵀ (k×m)ᵀ · B (k×n) → (m×n)` — A is stored (k×m); result is m×n.
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_at_b shape mismatch");
    let (kdim, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    // k is the outer loop: both A and B rows are unit-stride.
    for k in 0..kdim {
        let a_row = a.row(k);
        let b_row = b.row(k);
        for i in 0..m {
            let aki = a_row[i];
            if aki == 0.0 {
                continue;
            }
            let c_row = &mut c.data[i * n..(i + 1) * n];
            for (cv, bv) in c_row.iter_mut().zip(b_row) {
                *cv += aki * bv;
            }
        }
    }
    c
}

/// `A (m×k) · Bᵀ (n×k)ᵀ → (m×n)` — B is stored (n×k).
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_a_bt shape mismatch");
    let (m, kdim, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let a_row = a.row(i);
        let c_row = &mut c.data[i * n..(i + 1) * n];
        for j in 0..n {
            let b_row = b.row(j);
            let mut acc = 0.0f32;
            // dot product over unit-stride rows
            let mut kk = 0;
            while kk + 4 <= kdim {
                acc += a_row[kk] * b_row[kk]
                    + a_row[kk + 1] * b_row[kk + 1]
                    + a_row[kk + 2] * b_row[kk + 2]
                    + a_row[kk + 3] * b_row[kk + 3];
                kk += 4;
            }
            while kk < kdim {
                acc += a_row[kk] * b_row[kk];
                kk += 1;
            }
            c_row[j] = acc;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg64};

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for k in 0..a.cols {
                    acc += (a.at(i, k) as f64) * (b.at(k, j) as f64);
                }
                *c.at_mut(i, j) = acc as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_small() {
        let a = Matrix::from_fn(3, 4, |i, j| (i + j) as f32);
        let b = Matrix::from_fn(4, 2, |i, j| (i as f32) - (j as f32));
        assert_eq!(matmul(&a, &b), naive(&a, &b));
    }

    #[test]
    fn prop_matmul_matches_naive() {
        proptest::check("matmul==naive", 12, |rng| {
            let m = proptest::size(rng, 1, 70);
            let k = proptest::size(rng, 1, 70);
            let n = proptest::size(rng, 1, 70);
            let a = Matrix::randn(m, k, 1.0, rng);
            let b = Matrix::randn(k, n, 1.0, rng);
            let diff = matmul(&a, &b).max_abs_diff(&naive(&a, &b));
            assert!(diff < 1e-3, "diff={diff}");
        });
    }

    #[test]
    fn prop_transposed_variants_consistent() {
        proptest::check("at_b/a_bt==explicit", 12, |rng| {
            let m = proptest::size(rng, 1, 40);
            let k = proptest::size(rng, 1, 40);
            let n = proptest::size(rng, 1, 40);
            let a = Matrix::randn(k, m, 1.0, rng); // stored kxm
            let b = Matrix::randn(k, n, 1.0, rng);
            let got = matmul_at_b(&a, &b);
            let want = matmul(&a.transpose(), &b);
            assert!(got.max_abs_diff(&want) < 1e-3);

            let a2 = Matrix::randn(m, k, 1.0, rng);
            let b2 = Matrix::randn(n, k, 1.0, rng); // stored nxk
            let got2 = matmul_a_bt(&a2, &b2);
            let want2 = matmul(&a2, &b2.transpose());
            assert!(got2.max_abs_diff(&want2) < 1e-3);
        });
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seed(4);
        let a = Matrix::randn(9, 9, 1.0, &mut rng);
        assert!(matmul(&a, &Matrix::eye(9)).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&Matrix::eye(9), &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn associativity_within_tolerance() {
        let mut rng = Pcg64::seed(5);
        let a = Matrix::randn(12, 7, 1.0, &mut rng);
        let b = Matrix::randn(7, 9, 1.0, &mut rng);
        let c = Matrix::randn(9, 5, 1.0, &mut rng);
        let left = matmul(&matmul(&a, &b), &c);
        let right = matmul(&a, &matmul(&b, &c));
        assert!(left.max_abs_diff(&right) < 1e-3);
    }
}
