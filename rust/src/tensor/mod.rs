//! Dense row-major f32 matrix substrate.
//!
//! Everything numeric in the coordinator (optimizers, projections, FFT,
//! collectives) operates on [`Matrix`]. The design goal is a small, fully
//! owned BLAS-free kernel set whose hot paths (register-tiled matmul,
//! axpy-style elementwise) are cache-tiled for the single-core testbed.
//! Every hot kernel has an allocation-free `_into` variant writing into a
//! caller-owned buffer; [`Workspace`] pools those buffers so steady-state
//! optimizer steps allocate nothing (see ROADMAP.md §Hot-path
//! architecture). Pool hits/misses feed the `obs` counters, so `make
//! bench-obs` and the run-end summary can show whether a workload's pools
//! actually stay warm.

mod matrix;
mod ops;
mod workspace;
pub mod bf16;
pub mod store;

pub use matrix::Matrix;
pub use store::{StateDtype, StateStore};
pub use ops::{
    all_finite, matmul, matmul_a_bt, matmul_a_bt_into, matmul_a_bt_into_on,
    matmul_at_b, matmul_at_b_into, matmul_at_b_into_on, matmul_into,
    matmul_into_on, matmul_rows_batched_on,
};
pub use workspace::Workspace;
