//! Dense row-major f32 matrix substrate.
//!
//! Everything numeric in the coordinator (optimizers, projections, FFT,
//! collectives) operates on [`Matrix`]. The design goal is a small, fully
//! owned BLAS-free kernel set whose hot paths (blocked matmul, axpy-style
//! elementwise) are cache-tiled for the single-core testbed; see
//! EXPERIMENTS.md §Perf for measured throughput.

mod matrix;
mod ops;
pub mod bf16;

pub use matrix::Matrix;
pub use ops::{matmul, matmul_at_b, matmul_a_bt};
